//! Quickstart: reverse-engineer the Hadamard transform (paper §IV-C).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Builds the dense 32×32 Hadamard matrix, hierarchically factorizes it
//! into 5 sparse butterflies, verifies exactness, and shows the
//! storage/compute gains of the resulting FAμST.

use faust::hierarchical::{factorize, HierarchicalConfig};
use faust::rng::Rng;
use faust::transforms::{hadamard, hadamard_faust};

fn main() {
    let n = 32;
    println!("=== FAuST quickstart: the {n}x{n} Hadamard transform ===\n");

    // 1. The dense operator: n² = 1024 non-zeros, O(n²) to apply.
    let a = hadamard(n);
    println!("dense operator: {} non-zeros", a.nnz());

    // 2. Hierarchically factorize (paper Fig. 5 with the §IV-C setting).
    let cfg = HierarchicalConfig::hadamard(n);
    let fst = factorize(&a, &cfg);
    println!(
        "FAuST: {} factors, s_tot = {}, RC = {:.3}, RCG = {:.1}",
        fst.n_factors(),
        fst.s_tot(),
        fst.rc(),
        fst.rcg()
    );

    // 3. It is exact (the paper's Fig. 6 headline result)...
    let rel = fst.relative_error_fro(&a);
    println!("relative error vs dense: {rel:.2e}");
    assert!(rel < 1e-6, "factorization should be exact");

    // ...and matches the hand-built butterfly reference of Fig. 1.
    let reference = hadamard_faust(n);
    println!(
        "reference butterfly: s_tot = {}, RCG = {:.1}",
        reference.s_tot(),
        reference.rcg()
    );
    assert_eq!(fst.s_tot(), reference.s_tot());

    // 4. Apply it: O(s_tot) instead of O(n²).
    let mut rng = Rng::new(42);
    let x = rng.gauss_vec(n);
    let y_fast = fst.apply(&x);
    let y_dense = a.matvec(&x);
    let max_err = y_fast
        .iter()
        .zip(&y_dense)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0_f64, f64::max);
    println!(
        "apply: {} flops (dense: {}), max |Δ| = {max_err:.2e}",
        fst.flops_per_matvec(),
        2 * n * n
    );
    println!("\nquickstart OK");
}
