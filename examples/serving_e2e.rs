//! End-to-end driver: every layer of the stack composes.
//!
//! ```bash
//! make artifacts && cargo run --release --example serving_e2e
//! ```
//!
//! 1. L3 factorizes a synthetic MEG operator into a FAμST (the paper's
//!    contribution);
//! 2. the coordinator serves three operator backends — dense, FAμST, and
//!    (when `artifacts/` exists) the AOT-compiled PJRT executable produced
//!    by the L2 JAX model calling the L1 Pallas kernel;
//! 3. a client fleet streams matvec requests through the dynamic batcher;
//! 4. the driver reports correctness (all backends agree) and
//!    latency/throughput, plus the headline RCG.

use faust::coordinator::{BatchOp, Coordinator, CoordinatorConfig};
use faust::hierarchical::{factorize, HierarchicalConfig};
use faust::linalg::Mat;
use faust::meg::meg_model;
use faust::rng::Rng;
use faust::runtime::Engine;
use faust::transforms::hadamard_faust;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// PJRT-backed operator. The `xla` crate's client is not `Send`, so a
/// dedicated owner thread holds the [`Engine`] and executes batches
/// shipped over a channel; the `BatchOp` facade is `Send + Sync`.
struct PjrtHad32 {
    tx: Mutex<std::sync::mpsc::Sender<(Mat, std::sync::mpsc::Sender<Mat>)>>,
}

impl PjrtHad32 {
    fn new() -> anyhow::Result<Self> {
        let (tx, rx) = std::sync::mpsc::channel::<(Mat, std::sync::mpsc::Sender<Mat>)>();
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<anyhow::Result<()>>();
        std::thread::Builder::new()
            .name("pjrt-owner".into())
            .spawn(move || {
                // The engine lives (and dies) on this thread.
                let mut engine = match Engine::cpu("artifacts") {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e.into()));
                        return;
                    }
                };
                if let Err(e) = engine.load("faust_apply_had32") {
                    let _ = ready_tx.send(Err(e.into()));
                    return;
                }
                let _ = ready_tx.send(Ok(()));
                let hf = hadamard_faust(32);
                let factors: Vec<Vec<f32>> = hf
                    .factors()
                    .iter()
                    .map(|f| f.to_dense().data().iter().map(|&v| v as f32).collect())
                    .collect();
                let n = 32usize;
                let bfix = 8usize;
                let xdims = [n, bfix];
                let fdims = [n, n];
                while let Ok((x, resp)) = rx.recv() {
                    // The artifact is compiled for batch = 8: split/pad.
                    let total = x.cols();
                    let mut out = Mat::zeros(n, total);
                    let mut c0 = 0;
                    while c0 < total {
                        let bw = bfix.min(total - c0);
                        let mut buf = vec![0f32; n * bfix];
                        for c in 0..bw {
                            for i in 0..n {
                                buf[i * bfix + c] = x.at(i, c0 + c) as f32;
                            }
                        }
                        let mut inputs: Vec<(&[f32], &[usize])> =
                            vec![(&buf, &xdims[..])];
                        for f in &factors {
                            inputs.push((f, &fdims[..]));
                        }
                        let res = engine
                            .run_f32("faust_apply_had32", &inputs)
                            .expect("pjrt exec");
                        for c in 0..bw {
                            for i in 0..n {
                                out.set(i, c0 + c, res[0].0[i * bfix + c] as f64);
                            }
                        }
                        c0 += bw;
                    }
                    let _ = resp.send(out);
                }
            })?;
        ready_rx.recv()??;
        Ok(PjrtHad32 { tx: Mutex::new(tx) })
    }
}

impl BatchOp for PjrtHad32 {
    fn rows(&self) -> usize {
        32
    }
    fn cols(&self) -> usize {
        32
    }
    fn apply_batch(&self, x: &Mat) -> Mat {
        let (rtx, rrx) = std::sync::mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send((x.clone(), rtx))
            .expect("pjrt owner thread gone");
        rrx.recv().expect("pjrt owner thread gone")
    }
    fn flops_per_matvec(&self) -> usize {
        2 * 5 * 2 * 32 // five butterfly factors, 2n nnz each
    }
}

fn main() -> anyhow::Result<()> {
    println!("=== serving_e2e: L1 Pallas -> L2 JAX -> AOT -> L3 rust serving ===\n");

    // ---- Stage 1: factorize the paper's workhorse operator (scaled).
    let (m, n) = (128, 1024);
    let model = meg_model(m, n, 3);
    let cfg = HierarchicalConfig::meg(m, n, 4, 10, 2 * m, 0.8, 1.4 * (m * m) as f64);
    let t0 = Instant::now();
    let fst = factorize(&model.gain, &cfg);
    println!(
        "[L3] factorized {m}x{n} MEG gain: RCG = {:.1}, s_tot = {} ({:.1?})",
        fst.rcg(),
        fst.s_tot(),
        t0.elapsed()
    );

    // ---- Stage 2: register operators with the coordinator.
    let mut ops: Vec<(String, Arc<dyn BatchOp>)> = vec![
        ("meg_dense".into(), Arc::new(model.gain.clone())),
        ("meg_faust".into(), Arc::new(fst.clone())),
        ("had32_faust".into(), Arc::new(hadamard_faust(32))),
    ];
    let mut have_pjrt = false;
    if std::path::Path::new("artifacts/faust_apply_had32.hlo.txt").exists() {
        match PjrtHad32::new() {
            Ok(op) => {
                ops.push(("had32_pjrt".into(), Arc::new(op)));
                have_pjrt = true;
                println!("[runtime] PJRT artifact registered (faust_apply_had32)");
            }
            Err(e) => println!("[runtime] PJRT backend unavailable: {e}"),
        }
    } else {
        println!("[runtime] artifacts/ missing — PJRT backend skipped (run `make artifacts`)");
    }
    let coord = Coordinator::start(
        ops,
        CoordinatorConfig {
            max_batch: 16,
            batch_timeout: Duration::from_micros(300),
            n_workers: 3,
            queue_capacity: 4096,
            adaptive: None,
        },
    );
    let client = coord.client();

    // ---- Stage 3: correctness — all backends agree.
    let mut rng = Rng::new(5);
    let x32 = rng.gauss_vec(32);
    let y_native = client.apply("had32_faust", x32.clone())?;
    if have_pjrt {
        let y_pjrt = client.apply("had32_pjrt", x32.clone())?;
        let max_err = y_native
            .iter()
            .zip(&y_pjrt)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0_f64, f64::max);
        println!("[check] rust-native vs PJRT apply: max |Δ| = {max_err:.2e}");
        assert!(max_err < 1e-4);
    }
    let xm = rng.gauss_vec(n);
    let yd = client.apply("meg_dense", xm.clone())?;
    let yf = client.apply("meg_faust", xm)?;
    let rel: f64 = yd
        .iter()
        .zip(&yf)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt()
        / yd.iter().map(|v| v * v).sum::<f64>().sqrt();
    println!("[check] dense vs FAuST serving output: rel l2 = {rel:.3} (≈ RE, expected)");

    // ---- Stage 4: throughput/latency under concurrent load.
    let n_clients = 4;
    let per_client = 2500;
    println!(
        "\n[load] {n_clients} clients x {per_client} requests against meg_faust + meg_dense"
    );
    for op in ["meg_dense", "meg_faust"] {
        let t0 = Instant::now();
        let mut handles = vec![];
        for t in 0..n_clients {
            let c = client.clone();
            let op = op.to_string();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t as u64);
                let mut pending = Vec::with_capacity(64);
                for _ in 0..per_client {
                    loop {
                        match c.submit(&op, rng.gauss_vec(1024)) {
                            Ok(rx) => {
                                pending.push(rx);
                                break;
                            }
                            Err(_) => {
                                for rx in pending.drain(..) {
                                    let _ = rx.recv();
                                }
                            }
                        }
                    }
                    if pending.len() >= 64 {
                        for rx in pending.drain(..) {
                            let _ = rx.recv();
                        }
                    }
                }
                for rx in pending.drain(..) {
                    let _ = rx.recv();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        let total = (n_clients * per_client) as f64;
        println!(
            "  {op:>10}: {:>8.0} req/s  ({:.2} s total)",
            total / dt,
            dt
        );
    }
    let snap = coord.shutdown();
    println!(
        "\n[metrics] completed={} batches={} mean_batch={:.1} mean_latency={:.0}us gflops={:.2}",
        snap.completed,
        snap.batches,
        snap.mean_batch_size(),
        snap.mean_latency_us(),
        snap.gflops()
    );
    println!("\nserving_e2e OK — all layers compose");
    Ok(())
}
