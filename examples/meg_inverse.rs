//! Accelerating a linear inverse problem with a FAμST (paper §V, scaled).
//!
//! ```bash
//! cargo run --release --example meg_inverse
//! ```
//!
//! Builds a synthetic MEG gain matrix, factorizes it, then solves 2-sparse
//! source-localization problems with OMP using (a) the dense matrix and
//! (b) the FAμST — comparing localization quality and measured flops.

use faust::bench_util::{fmt, Table};
use faust::hierarchical::{factorize, HierarchicalConfig};
use faust::meg::{localization_experiment, meg_model};
use faust::rng::Rng;
use faust::solvers::LinOp;
use std::time::Instant;

fn main() {
    let (m, n) = (128, 2048);
    println!("=== FAuST on a synthetic MEG inverse problem ({m}x{n}) ===\n");
    let model = meg_model(m, n, 7);

    // Factorize with a mid-range configuration (J=4, k=10).
    let cfg = HierarchicalConfig::meg(m, n, 4, 10, 2 * m, 0.8, 1.4 * (m * m) as f64);
    let t0 = Instant::now();
    let fst = factorize(&model.gain, &cfg);
    let mut rng = Rng::new(1);
    println!(
        "factorized in {:.1?}: RCG = {:.1}, RE = {:.4}\n",
        t0.elapsed(),
        fst.rcg(),
        fst.relative_error_spectral(&model.gain, &mut rng)
    );

    let trials = 120;
    let mut table = Table::new(&[
        "separation",
        "matrix",
        "median(cm)",
        "q3(cm)",
        "exact%",
        "flops/apply",
    ]);
    for (dmin, dmax, label) in [(1.0, 5.0, "1-5cm"), (5.0, 8.0, "5-8cm"), (8.0, 100.0, ">8cm")] {
        for (name, op) in [
            ("dense M", &model.gain as &dyn LinOp),
            ("FAuST M^", &fst as &dyn LinOp),
        ] {
            let stats = localization_experiment(&model, op, trials, dmin, dmax, 11);
            table.row(&[
                label.to_string(),
                name.to_string(),
                fmt(stats.median()),
                fmt(stats.quantile(0.75)),
                format!("{:.0}", stats.exact_rate() * 100.0),
                format!("{}", op.flops_per_apply()),
            ]);
        }
    }
    table.print();
    println!("\nThe FAuST localizes nearly as well with ~{:.0}x fewer flops.", fst.rcg());
}
