//! FAμST dictionary learning for image denoising (paper §VI-C, scaled).
//!
//! ```bash
//! cargo run --release --example image_denoising
//! ```
//!
//! Learns (a) a dense K-SVD dictionary, (b) a FAμST dictionary
//! (hierarchically factorized while refitting to the data — Fig. 11), and
//! compares them with the overcomplete-DCT baseline on a noisy image.
//! Writes before/after PGMs to /tmp for inspection.

use faust::dictlearn::{faust_dictionary_learning, ksvd, KsvdConfig};
use faust::hierarchical::HierarchicalConfig;
use faust::image::{add_noise, corpus, denoise, psnr, random_patches, write_pgm};
use faust::rng::Rng;
use faust::transforms::overcomplete_dct;
use std::time::Instant;

fn main() {
    let size = 128;
    let sigma = 30.0;
    let p = 8;
    let natoms = 128;
    let imgs = corpus(size);
    let (name, img) = &imgs[9]; // a "mixed" image — the typical case
    println!("=== FAuST dictionary denoising: '{name}' {size}x{size}, sigma={sigma} ===\n");

    let mut rng = Rng::new(3);
    let noisy = add_noise(img, sigma, &mut rng);
    println!("noisy PSNR: {:.2} dB", psnr(&noisy, img));
    write_pgm(&noisy, "/tmp/faust_noisy.pgm").ok();

    // Training patches from the noisy image itself (paper: 10 000).
    let patches = random_patches(&noisy, p, 2000, &mut rng);

    // --- Dense dictionary learning (K-SVD, the DDL baseline).
    let kcfg = KsvdConfig { n_atoms: natoms, sparsity: 5, n_iter: 8, seed: 1 };
    let t0 = Instant::now();
    let ddl = ksvd(&patches, &kcfg);
    let d1 = denoise(&noisy, &ddl.dict, p, 5, 2);
    println!(
        "DDL (K-SVD, {} params): {:.2} dB  [{:.1?}]",
        p * p * natoms,
        psnr(&d1, img),
        t0.elapsed()
    );
    write_pgm(&d1, "/tmp/faust_ddl.pgm").ok();

    // --- FAuST dictionary (Fig. 11): J=4 factors.
    let hcfg = HierarchicalConfig::dictionary(
        p * p,
        natoms,
        4,
        4,
        4 * p * p,
        0.5,
        (p * p * p * p) as f64,
    );
    let t0 = Instant::now();
    let (fst, _) = faust_dictionary_learning(&patches, &kcfg, &hcfg);
    let d2 = denoise(&noisy, &fst, p, 5, 2);
    println!(
        "FAuST (s_tot = {}, RCG = {:.1}): {:.2} dB  [{:.1?}]",
        fst.s_tot(),
        fst.rcg(),
        psnr(&d2, img),
        t0.elapsed()
    );
    write_pgm(&d2, "/tmp/faust_faust.pgm").ok();

    // --- Overcomplete DCT (analytic baseline).
    let dct = overcomplete_dct(p, 144);
    let d3 = denoise(&noisy, &dct, p, 5, 2);
    println!("DCT (144 atoms): {:.2} dB", psnr(&d3, img));
    write_pgm(&d3, "/tmp/faust_dct.pgm").ok();

    println!("\nwrote /tmp/faust_{{noisy,ddl,faust,dct}}.pgm");
}
