//! Online tracking of a drifting operator vs periodic batch
//! refactorization, at an equal flop budget (ISSUE 9, ROADMAP item i).
//!
//! The true operator drifts slowly: every pass, adjacent row pairs of
//! the Hadamard transform rotate by a small Givens angle, so after `t`
//! passes the target is `Rᵗ·H`. The drifted operator stays *exactly*
//! representable under the bench's constraint profile (the rotation
//! folds into the leftmost butterfly factor, doubling its per-row/col
//! budget to 4), which makes the comparison about *tracking*, not
//! model capacity. Two learners watch the same drift:
//!
//! - **online** — an [`OnlineLearner`] warm-started from the butterfly
//!   factors streams every pass's columns through weighted mini-batch
//!   PALM sweeps with forgetting, epoch-swapping improved generations
//!   through a live [`Registry`].
//! - **periodic** — every `refresh_every` passes, a full batch
//!   [`palm4msa`] refit from the same butterfly prior on a snapshot of
//!   the current operator. Its per-refresh iteration count is set so
//!   both paths spend the *same number of PALM sweeps* overall
//!   (verified via [`iterations_total`] deltas — one sweep is one
//!   counter tick on both paths), so the only difference is streaming
//!   vs burst refresh.
//!
//! The gated claims (`BENCH_online.json` vs `benches/baseline.json`):
//! the online path tracks the moving operator to a small relative
//! error while the periodic path — fresh fits notwithstanding — goes
//! stale between refreshes; online keeps publishing generations
//! (≥ 3 swaps); warm-starting converges far faster than a cold
//! default init on the same stream; and the whole online run is
//! bitwise identical across ctx thread counts.
//!
//! CI runs `-- --json` and gates every metric; all keys are
//! `online_`-prefixed so `scripts/bench_gate.py` refuses any future
//! unbaselined addition loudly.
//!
//! [`palm4msa`]: faust::palm::palm4msa
//! [`iterations_total`]: faust::palm::iterations_total

use faust::bench_util::{fmt, BenchReport, Table};
use faust::cli::Args;
use faust::coordinator::{
    BatchOp, Metrics, OnlineLearnConfig, OnlineLearner, Registry,
};
use faust::engine::ExecCtx;
use faust::faust::Faust;
use faust::linalg::Mat;
use faust::palm::online::{OnlineConfig, OnlinePalm};
use faust::palm::{iterations_total, palm4msa_with_ctx, FactorState, PalmConfig};
use faust::prox::Constraint;
use faust::transforms::{hadamard, hadamard_faust};
use std::sync::Arc;

/// Rotate adjacent row pairs of `a` by `theta` in place (a block-Givens
/// drift step). Composing `t` steps rotates each pair by `t·theta`, so
/// the drifted operator is `Rᵗ·H` and the staleness of a generation fit
/// `k` passes ago is exactly `2·sin(k·theta/2)` in relative Frobenius
/// error — the geometry the gates below lean on.
fn rotate_rows(a: &mut Mat, theta: f64) {
    let (s, c) = theta.sin_cos();
    let (rows, cols) = a.shape();
    let mut i = 0;
    while i + 1 < rows {
        for j in 0..cols {
            let (u, v) = (a.at(i, j), a.at(i + 1, j));
            a.set(i, j, c * u - s * v);
            a.set(i + 1, j, s * u + c * v);
        }
        i += 2;
    }
}

/// The butterfly prior both paths start from: the exact Hadamard
/// factorization as dense PALM factors (rightmost first).
fn butterfly_init(n: usize) -> FactorState {
    let hf = hadamard_faust(n);
    FactorState {
        mats: hf.factors().iter().map(|f| f.to_dense()).collect(),
        lambda: hf.lambda(),
    }
}

/// 2-sparse butterflies everywhere except the leftmost factor, which
/// gets a 4-per-row/col budget so it can absorb the pair rotation
/// (`R·S` has ≤ 4 nonzeros per row and per column when `S` has 2).
fn drift_constraints(nfac: usize) -> Vec<Constraint> {
    let mut cons = vec![Constraint::SpRowCol(2); nfac];
    cons[nfac - 1] = Constraint::SpRowCol(4);
    cons
}

struct OnlineRun {
    swaps: u64,
    sweeps: u64,
    rel_err: f64,
    state: FactorState,
}

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    let n: usize = args.get("n", 32);
    let passes: usize = args.get("passes", 48);
    let theta: f64 = args.get("theta", 0.02);
    let batch_cols: usize = args.get("batch-cols", 4).max(1);
    let refresh_every: usize = args.get("refresh-every", 16).max(1);
    let rho: f64 = args.get("rho", 0.7);
    assert!(n.is_power_of_two() && n >= 4, "--n must be a power of two ≥ 4");
    assert!(passes % refresh_every == 0, "--passes must be a multiple of --refresh-every");
    let nfac = n.trailing_zeros() as usize;

    println!(
        "# online drift — streaming vs periodic refit at equal flops \
         (n={n}, passes={passes}, θ={theta} rad/pass, ρ={rho})\n"
    );

    // The drift sequence: a_seq[t] is the true operator during pass t.
    let mut a = hadamard(n);
    let mut a_seq = Vec::with_capacity(passes);
    for _ in 0..passes {
        a_seq.push(a.clone());
        rotate_rows(&mut a, theta);
    }
    let a_final = a_seq.last().expect("passes ≥ 1");

    // ---- Online path: stream every pass's columns, publish through a
    // live registry under the coordinator's cadence policy. ----
    let run_online = |threads: usize| -> OnlineRun {
        let registry = Arc::new(Registry::new(None));
        registry
            .register("drift", Arc::new(hadamard(n)) as Arc<dyn BatchOp>)
            .expect("fresh registry");
        let cfg = OnlineConfig::new(PalmConfig::new(drift_constraints(nfac), 1))
            .with_forgetting(rho);
        let mut learner = OnlineLearner::new(
            "drift",
            registry.clone(),
            Arc::new(Metrics::new()),
            OnlinePalm::warm(butterfly_init(n), cfg),
            OnlineLearnConfig { batch_cols, swap_every: 4, min_gain: 0.0 },
        );
        let ctx = ExecCtx::new(threads);
        let publish = |f: &Faust| Arc::new(f.clone()) as Arc<dyn BatchOp>;
        let i0 = iterations_total();
        for a_t in &a_seq {
            for col in 0..n {
                learner.observe(col, a_t.col(col));
                while learner.try_step(&ctx, &publish).is_some() {}
            }
        }
        OnlineRun {
            swaps: learner.swaps(),
            sweeps: iterations_total() - i0,
            rel_err: learner.palm().to_faust().relative_error_fro(a_final),
            state: learner.palm().state().clone(),
        }
    };
    let online = run_online(2);

    // ---- Periodic path: batch refit from the same butterfly prior
    // every refresh_every passes, with the whole online sweep budget
    // split evenly across the refits. ----
    let refreshes = passes / refresh_every;
    let per_refresh = (online.sweeps as usize / refreshes).max(1);
    let ctx = ExecCtx::new(2);
    let i0 = iterations_total();
    let mut fresh_errs = Vec::with_capacity(refreshes);
    let mut current: Option<Faust> = None;
    for (t, a_t) in a_seq.iter().enumerate() {
        if t % refresh_every == 0 {
            let res = palm4msa_with_ctx(
                &ctx,
                a_t,
                butterfly_init(n),
                &PalmConfig::new(drift_constraints(nfac), per_refresh),
            );
            let f = res.state.into_faust();
            fresh_errs.push(f.relative_error_fro(a_t));
            current = Some(f);
        }
    }
    let periodic_iters = iterations_total() - i0;
    let periodic_fresh =
        fresh_errs.iter().cloned().fold(0.0f64, f64::max);
    // Staleness at the end of the run: the last refit is refresh_every
    // passes old by the time the final operator is measured.
    let periodic_stale = current
        .expect("at least one refresh")
        .relative_error_fro(a_final);
    let flop_parity = periodic_iters as f64 / online.sweeps as f64;

    // ---- Warm vs cold convergence on a static (already-drifted)
    // target: same stream, same budget, only the init differs. ----
    let mut target = hadamard(n);
    rotate_rows(&mut target, 0.1);
    let static_batches = 12;
    let run_static = |init: FactorState| -> f64 {
        let mut ol = OnlinePalm::warm(
            init,
            OnlineConfig::new(PalmConfig::new(drift_constraints(nfac), 1)),
        );
        for _ in 0..static_batches {
            let batch: Vec<(usize, Vec<f64>)> =
                (0..n).map(|c| (c, target.col(c))).collect();
            ol.step(&ctx, &batch);
        }
        ol.to_faust().relative_error_fro(&target)
    };
    let warm_err = run_static(butterfly_init(n));
    let dims: Vec<(usize, usize)> = vec![(n, n); nfac];
    let cold_err = run_static(FactorState::default_init(&dims));

    // ---- Determinism: the full online run, bit for bit, at another
    // thread count. ----
    let online_t1 = run_online(1);
    let mut bitwise = (online_t1.swaps == online.swaps
        && online_t1.state.lambda.to_bits() == online.state.lambda.to_bits())
        as u64;
    for (p, q) in online_t1.state.mats.iter().zip(&online.state.mats) {
        if p.data() != q.data() {
            bitwise = 0;
        }
    }

    let mut table = Table::new(&["path", "rel_err_final", "palm_sweeps", "swaps/refits"]);
    table.row(&[
        "online".to_string(),
        fmt(online.rel_err),
        online.sweeps.to_string(),
        online.swaps.to_string(),
    ]);
    table.row(&[
        "periodic".to_string(),
        fmt(periodic_stale),
        periodic_iters.to_string(),
        refreshes.to_string(),
    ]);
    table.print();
    println!(
        "\n# periodic refits land at {} fresh but go {} stale; online tracks at {} \
         ({}x better) on the same {} sweeps; warm start {} vs cold {} after {} batches",
        fmt(periodic_fresh),
        fmt(periodic_stale),
        fmt(online.rel_err),
        fmt(periodic_stale / online.rel_err.max(1e-12)),
        online.sweeps,
        fmt(warm_err),
        fmt(cold_err),
        static_batches,
    );

    // The bench is its own smoke test: fail loudly here, not just in
    // the baseline gate.
    assert!(online.rel_err < periodic_stale, "online must beat the stale periodic refit");
    assert!(online.swaps >= 3, "online must keep publishing under drift");
    assert!(warm_err < cold_err, "warm start must beat cold on the same stream");
    assert_eq!(bitwise, 1, "online run must be bitwise thread-invariant");

    if args.flag("json") {
        let mut rep = BenchReport::new("online");
        rep.push("online_tracking_rel_err", online.rel_err);
        rep.push("online_periodic_fresh_rel_err", periodic_fresh);
        rep.push("online_periodic_stale_rel_err", periodic_stale);
        rep.push(
            "online_vs_periodic_err_ratio",
            online.rel_err / periodic_stale.max(1e-12),
        );
        rep.push("online_sweeps", online.sweeps as f64);
        rep.push("online_flop_parity", flop_parity);
        rep.push("online_swaps", online.swaps as f64);
        rep.push("online_warm_rel_err", warm_err);
        rep.push("online_cold_start_rel_err", cold_err);
        rep.push("online_warm_vs_cold_gain", cold_err / warm_err.max(1e-12));
        rep.push("online_bitwise_identical", bitwise as f64);
        match rep.write(args.get_str("json-dir").unwrap_or(".")) {
            Ok(p) => println!("# wrote {p}"),
            Err(e) => eprintln!("# json write failed: {e}"),
        }
    }
}
