//! Ablations of the design choices DESIGN.md calls out:
//!
//! 1. hierarchical vs direct palm4MSA (the paper's §IV motivation);
//! 2. global refit on/off (Fig. 5 line 5);
//! 3. split-init assignment: zero-residual (ours/toolbox) vs zero-sparse
//!    (paper Fig. 4 text reading) — the deviation documented in DESIGN.md;
//! 4. residual constraint family: splincol vs global-sp on Hadamard;
//! 5. per-column vs global rightmost constraint on the MEG operator
//!    (§V-A remark);
//! 6. ρ sensitivity on the MEG operator.

use faust::bench_util::{fmt, Table};
use faust::hierarchical::{factorize, HierarchicalConfig};
use faust::linalg::Mat;
use faust::meg::meg_model;
use faust::palm::{palm4msa, FactorState, PalmConfig};
use faust::prox::Constraint;
use faust::rng::Rng;
use faust::transforms::hadamard;

fn main() {
    let n = 32usize;
    let a = hadamard(n);

    println!("# ablation 1+2+3+4 — Hadamard-{n} exactness under variants\n");
    let mut table = Table::new(&["variant", "rel_err", "s_tot", "RCG"]);

    // (baseline) full algorithm.
    let cfg = HierarchicalConfig::hadamard(n);
    let fst = factorize(&a, &cfg);
    table.row(&[
        "baseline (hier, refit, zero-resid, splincol)".into(),
        format!("{:.1e}", fst.relative_error_fro(&a)),
        fst.s_tot().to_string(),
        fmt(fst.rcg()),
    ]);

    // (1) direct palm4MSA with J factors, no hierarchy.
    let j = cfg.n_factors();
    let mut dcfg = PalmConfig::new(vec![Constraint::SpRowCol(2); j], 200);
    dcfg.seed = 1;
    let dims: Vec<(usize, usize)> = vec![(n, n); j];
    let direct = palm4msa(&a, FactorState::default_init(&dims), &dcfg);
    let dfst = direct.state.into_faust();
    table.row(&[
        "direct palm4MSA (no hierarchy)".into(),
        format!("{:.1e}", dfst.relative_error_fro(&a)),
        dfst.s_tot().to_string(),
        fmt(dfst.rcg()),
    ]);

    // (2) hierarchy without the global refit.
    let mut cfg2 = HierarchicalConfig::hadamard(n);
    cfg2.skip_global = true;
    let fst2 = factorize(&a, &cfg2);
    table.row(&[
        "no global refit (Fig.5 line 5 off)".into(),
        format!("{:.1e}", fst2.relative_error_fro(&a)),
        fst2.s_tot().to_string(),
        fmt(fst2.rcg()),
    ]);

    // (3) zero-sparse split init (the literal Fig. 4 reading).
    // Emulated by a manual 2-split with the swapped init.
    let split_swapped = {
        let mut c = PalmConfig::new(
            vec![Constraint::SpRowCol(2), Constraint::SpRowCol(n / 2)],
            cfg.n_iter_split,
        );
        c.seed = 2;
        let init = FactorState {
            mats: vec![Mat::zeros(n, n), Mat::eye(n, n)],
            lambda: 1.0,
        };
        palm4msa(&a, init, &c)
    };
    let sfst = split_swapped.state.into_faust();
    table.row(&[
        "first split, zero-SPARSE init (literal paper)".into(),
        format!("{:.1e}", sfst.relative_error_fro(&a)),
        sfst.s_tot().to_string(),
        fmt(sfst.rcg()),
    ]);

    // zero-residual init (toolbox convention — what the library uses).
    let split_ok = {
        let mut c = PalmConfig::new(
            vec![Constraint::SpRowCol(2), Constraint::SpRowCol(n / 2)],
            cfg.n_iter_split,
        );
        c.seed = 2;
        let init = FactorState {
            mats: vec![Mat::eye(n, n), Mat::zeros(n, n)],
            lambda: 1.0,
        };
        palm4msa(&a, init, &c)
    };
    let ofst = split_ok.state.into_faust();
    table.row(&[
        "first split, zero-RESIDUAL init (toolbox)".into(),
        format!("{:.1e}", ofst.relative_error_fro(&a)),
        ofst.s_tot().to_string(),
        fmt(ofst.rcg()),
    ]);

    // (4) global-sp residual constraints instead of splincol.
    let mut cfg4 = HierarchicalConfig::hadamard(n);
    for (l, lev) in cfg4.levels.iter_mut().enumerate() {
        lev.residual = Constraint::SpGlobal(n * n / (1 << (l + 1)));
        lev.factor = Constraint::SpGlobal(2 * n);
    }
    let fst4 = factorize(&a, &cfg4);
    table.row(&[
        "global-sp constraints (paper text literal)".into(),
        format!("{:.1e}", fst4.relative_error_fro(&a)),
        fst4.s_tot().to_string(),
        fmt(fst4.rcg()),
    ]);
    table.print();

    // (5) per-column vs global rightmost constraint on MEG (§V-A remark).
    println!("\n# ablation 5 — rightmost-factor constraint on the MEG operator (§V-A remark)\n");
    let (m, nn) = (128, 1024);
    let model = meg_model(m, nn, 42);
    let mut rng = Rng::new(5);
    let mut t5 = Table::new(&["rightmost constraint", "RCG", "RE", "null columns"]);
    for (label, cfgv) in [
        (
            "spcol(k) per-column",
            HierarchicalConfig::meg(m, nn, 4, 10, 2 * m, 0.8, 1.4 * (m * m) as f64),
        ),
        (
            "global kn",
            HierarchicalConfig::meg_global_rightmost(m, nn, 4, 10, 2 * m, 0.8, 1.4 * (m * m) as f64),
        ),
    ] {
        let f = factorize(&model.gain, &cfgv);
        let re = f.relative_error_spectral(&model.gain, &mut rng);
        // Count null columns of the rightmost factor.
        let s1 = f.factors()[0].to_dense();
        let nulls = (0..s1.cols())
            .filter(|&j| s1.col(j).iter().all(|&v| v == 0.0))
            .count();
        t5.row(&[label.into(), fmt(f.rcg()), fmt(re), nulls.to_string()]);
    }
    t5.print();

    // (6) rho sensitivity.
    println!("\n# ablation 6 — residual-decay rate rho (paper: 0.8; 'qualitatively similar' for others)\n");
    let mut t6 = Table::new(&["rho", "RCG", "RE"]);
    for rho in [0.5, 0.65, 0.8, 0.9] {
        let cfgv = HierarchicalConfig::meg(m, nn, 4, 10, 2 * m, rho, 1.4 * (m * m) as f64);
        let f = factorize(&model.gain, &cfgv);
        t6.row(&[
            format!("{rho}"),
            fmt(f.rcg()),
            fmt(f.relative_error_spectral(&model.gain, &mut rng)),
        ]);
    }
    t6.print();
}
