//! Paper Fig. 2: FAμST vs truncated SVD on the complexity/error plane.
//!
//! The paper plots relative spectral error ‖A − Â‖₂/‖A‖₂ against RCG for
//! the 204×8193 MEG matrix: the truncated-SVD curve is dominated by the
//! FAμST points. We reproduce the *shape* on the synthetic MEG operator
//! (scaled by default; FAUST_BENCH_FULL=1 runs the paper's 204×8193).

use faust::bench_util::{fmt, Table};
use faust::hierarchical::{factorize, HierarchicalConfig};
use faust::linalg::{spectral_norm_iter, svd_randomized};
use faust::meg::meg_model;
use faust::rng::Rng;
use std::time::Instant;

fn main() {
    let full = std::env::var("FAUST_BENCH_FULL").is_ok();
    let (m, n) = if full { (204, 8193) } else { (128, 2048) };
    println!("# Fig. 2 — FAuST vs truncated SVD ({m}x{n} synthetic MEG gain)");
    println!("# paper shape: FAuSTs reach much lower error at equal RCG\n");
    let model = meg_model(m, n, 42);
    let mut rng = Rng::new(1);
    let a_norm = spectral_norm_iter(&model.gain, &mut rng, 200, 1e-10);

    let mut table = Table::new(&["method", "config", "RCG", "RE (spectral)", "time_s"]);

    // --- Truncated SVD curve: RCG of rank-r storage = mn / (r(m+n+1)).
    for r in [2usize, 5, 10, 20, 40, 80] {
        if r >= m {
            continue;
        }
        let t0 = Instant::now();
        let svd = svd_randomized(&model.gain, r, 8, 2, &mut rng);
        let dt = t0.elapsed().as_secs_f64();
        let err = spectral_norm_iter(&model.gain.sub(&svd.reconstruct()), &mut rng, 120, 1e-9)
            / a_norm;
        let rcg = (m * n) as f64 / (r * (m + n + 1)) as f64;
        table.row(&[
            "truncSVD".into(),
            format!("rank {r}"),
            fmt(rcg),
            fmt(err),
            fmt(dt),
        ]);
    }

    // --- FAuST points: four configurations as in the paper's Fig. 2.
    let configs: &[(usize, usize)] = &[(4, 5), (4, 10), (5, 15), (4, 25)];
    for &(j, k) in configs {
        let cfg = HierarchicalConfig::meg(m, n, j, k, 2 * m, 0.8, 1.4 * (m * m) as f64);
        let t0 = Instant::now();
        let fst = factorize(&model.gain, &cfg);
        let dt = t0.elapsed().as_secs_f64();
        let err = fst.relative_error_spectral(&model.gain, &mut rng);
        table.row(&[
            "FAuST".into(),
            format!("J={j} k={k}"),
            fmt(fst.rcg()),
            fmt(err),
            fmt(dt),
        ]);
    }
    table.print();
}
