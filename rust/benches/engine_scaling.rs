//! Engine scaling: planned + pooled apply vs the seed's serial
//! per-factor CSR chain, across Hadamard, MEG-like, and dictionary-like
//! operators, single- vs multi-threaded, with arena-alloc accounting —
//! plus a scalar-vs-tiled comparison of the dense-stage microkernels
//! (ISSUE 5) on the serving path's batch shapes.
//!
//! Acceptance target (ISSUE 1): for a 1024×1024 operator with ≥4 factors
//! at batch ≥ 32, planned multi-threaded apply ≥ 2× the naive serial
//! chain, with zero steady-state allocations in the apply loop.
//!
//! With `--json` the run emits `BENCH_engine_scaling.json` (planned
//! speedup + steady-state allocs at the acceptance point, dense-stage
//! scalar/tiled timings, and the f32-vs-f64 precision-tier comparison of
//! ISSUE 7 — gated with an in-bench ≥1.4× assertion on AVX2+); CI
//! uploads it and gates it against `benches/baseline.json` alongside the
//! factorize smoke.

use faust::bench_util::{
    compare_apply_f32_vs_f64, compare_scalar_vs_tiled, fmt, time_auto, BenchReport, Table,
};
use faust::cli::Args;
use faust::engine::{kernel, ApplyEngine};
use faust::faust::Faust;
use faust::linalg::Mat;
use faust::rng::Rng;
use faust::sparse::{Coo, Csr};
use faust::transforms::hadamard_faust;
use std::hint::black_box;

/// Random rightmost-first chain with `nnz_per_row` entries per factor row.
fn random_chain(dims: &[usize], nnz_per_row: usize, seed: u64) -> Faust {
    let mut rng = Rng::new(seed);
    let factors: Vec<Csr> = (0..dims.len() - 1)
        .map(|i| {
            let (r, c) = (dims[i + 1], dims[i]);
            let mut coo = Coo::new(r, c);
            for row in 0..r {
                for col in rng.sample_indices(c, nnz_per_row.min(c)) {
                    coo.push(row, col, rng.gauss());
                }
            }
            Csr::from_coo(&coo)
        })
        .collect();
    Faust::new(factors, 1.0)
}

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    let full = std::env::var("FAUST_BENCH_FULL").is_ok();
    let ms = if full { 150.0 } else { 50.0 };
    let ops: Vec<(&str, Faust)> = vec![
        ("hadamard-1024 (10 factors)", hadamard_faust(1024)),
        (
            "meg-like 256x1024 (4 factors)",
            random_chain(&[1024, 1024, 1024, 1024, 256], 8, 1),
        ),
        (
            "dict-like 64x512 (3 factors)",
            random_chain(&[512, 256, 128, 64], 6, 2),
        ),
    ];
    println!("# engine scaling — planned/pooled apply vs naive serial per-factor CSR chain\n");
    let mut table = Table::new(&[
        "operator",
        "batch",
        "threads",
        "naive_us",
        "planned_us",
        "speedup",
        "arena_allocs",
        "arena_reuses",
    ]);
    let mut acceptance: Option<(f64, u64)> = None;
    for (name, fst) in &ops {
        let mut rng = Rng::new(7);
        for &batch in &[1usize, 32, 128] {
            let x = Mat::randn(fst.cols(), batch, &mut rng);
            let tn = time_auto(ms, || black_box(fst.apply_mat_naive(black_box(&x))));
            for &threads in &[1usize, 2, 4] {
                let engine = ApplyEngine::with_threads(threads);
                let op = engine.op_batch_hint(fst, batch);
                let mut out = Mat::zeros(fst.rows(), batch);
                // Warm the arena, then measure the steady state.
                op.apply_batch_into(&x, &mut out);
                let warm = engine.metrics();
                let tp = time_auto(ms, || {
                    op.apply_batch_into(black_box(&x), &mut out);
                });
                let m = engine.metrics();
                let steady_allocs = m.arena_allocs - warm.arena_allocs;
                let steady_reuses = m.arena_reuses - warm.arena_reuses;
                let speedup = tn.median_ns / tp.median_ns;
                table.row(&[
                    name.to_string(),
                    batch.to_string(),
                    threads.to_string(),
                    fmt(tn.median_us()),
                    fmt(tp.median_us()),
                    fmt(speedup),
                    steady_allocs.to_string(),
                    steady_reuses.to_string(),
                ]);
                if *name == ops[0].0 && batch == 32 && threads == 4 {
                    acceptance = Some((speedup, steady_allocs));
                }
            }
        }
    }
    table.print();

    // Dense-stage microkernel comparison (ISSUE 5): a 512×512 dense
    // stage applied to a 32-column batch — the mixed dense/sparse plan
    // regime — via the shared bench_util scalar-vs-tiled protocol (same
    // harness as the gated factorize_scaling GEMM-stage comparison).
    let (sd, sb) = (512usize, 32usize);
    let cmp = compare_scalar_vs_tiled(sd, sd, sb, ms, 0xE512);
    let dense_stage_speedup = cmp.speedup();
    println!(
        "\n# dense stage {sd}x{sd} @ batch {sb}, 1 thread, {}-lane {:?} kernel: \
         scalar={:.1}us tiled={:.1}us speedup={dense_stage_speedup:.2}x",
        cmp.lanes,
        kernel::simd_level(),
        cmp.scalar.median_us(),
        cmp.tiled.median_us(),
    );

    // f32 serving tier (ISSUE 7): the same 512-dim dense stage, f64
    // tiled vs f32 tiled — element width is the only variable, so this
    // isolates what the precision tier buys (half the bytes, twice the
    // lanes per SIMD op).
    let mut prng = Rng::new(0xF32E);
    let a64 = Mat::randn(sd, sd, &mut prng);
    let b64 = Mat::randn(sd, sb, &mut prng);
    let (a32, b32) = (a64.to_f32(), b64.to_f32());
    let mut out64 = vec![0.0f64; sd * sb];
    let mut out32 = vec![0.0f32; sd * sb];
    let t64 = time_auto(ms, || {
        kernel::gemm_tiled_rows(&a64, b64.data(), sb, 0, sd, &mut out64);
        black_box(&mut out64);
    });
    let t32 = time_auto(ms, || {
        kernel::gemm_tiled_rows(&a32, b32.data(), sb, 0, sd, &mut out32);
        black_box(&mut out32);
    });
    let f32_dense_stage_speedup = t64.median_ns / t32.median_ns;
    println!(
        "\n# f32 dense stage {sd}x{sd} @ batch {sb}: f64={:.1}us f32={:.1}us \
         speedup={f32_dense_stage_speedup:.2}x ({}-lane f32 chunks)",
        t64.median_us(),
        t32.median_us(),
        kernel::lane_width_of::<f32>(),
    );

    // End-to-end 512-dim apply through the full plan/arena machinery:
    // f64 master plan vs its quantized f32 serving plan (shared
    // bench_util protocol — error checked against the declared bound).
    let dense_512 = Faust::from_dense_factors(
        &[Mat::randn(sd, sd, &mut prng)],
        1.0,
    );
    let (pcmp, pbound) = compare_apply_f32_vs_f64(&dense_512, sb, ms, 0xF32A);
    let f32_apply_speedup = pcmp.speedup();
    println!(
        "# f32 plan apply {sd}-dim @ batch {sb}: f64={:.1}us f32={:.1}us \
         speedup={f32_apply_speedup:.2}x rel_err={:.2e} (declared {:.2e})",
        pcmp.t64.median_us(),
        pcmp.t32.median_us(),
        pcmp.max_rel_err,
        pbound.declared_rel_err,
    );
    // The headline claim is asserted in-bench on hardware that can back
    // it: with AVX2+ lane chunks the f32 tier must beat the f64 tiled
    // path by >=1.4x on the 512-dim apply. Portable builds only report.
    let lvl = kernel::simd_level();
    if matches!(lvl, kernel::SimdLevel::Avx2 | kernel::SimdLevel::Avx512) {
        assert!(
            f32_apply_speedup >= 1.4,
            "f32 512-dim apply must be >=1.4x the f64 tiled path on {lvl:?}: \
             got {f32_apply_speedup:.2}x"
        );
    }

    if let Some((speedup, allocs)) = acceptance {
        let speed_ok = speedup >= 2.0;
        let alloc_ok = allocs == 0;
        println!(
            "\n# acceptance (1024x1024, 10 factors, batch=32, threads=4): \
             speedup={speedup:.2}x [{}], steady-state arena allocs={allocs} [{}]",
            if speed_ok { "PASS >=2x" } else { "FAIL <2x" },
            if alloc_ok { "PASS zero-alloc" } else { "FAIL" },
        );
    }
    println!("# naive = serial per-factor CSR spmm with per-layer allocation (seed apply path)");

    if args.flag("json") {
        let mut report = BenchReport::new("engine_scaling");
        report.push("simd_lanes", cmp.lanes as f64);
        report.push("dense_stage_scalar_us", cmp.scalar.median_us());
        report.push("dense_stage_tiled_us", cmp.tiled.median_us());
        report.push("dense_stage_tiled_speedup", dense_stage_speedup);
        report.push("f32_dense_stage_speedup", f32_dense_stage_speedup);
        report.push("f32_apply_speedup", f32_apply_speedup);
        report.push("f32_max_rel_err", pcmp.max_rel_err);
        if let Some((speedup, allocs)) = acceptance {
            report.push("planned_speedup_b32t4", speedup);
            report.push("steady_allocs_b32t4", allocs as f64);
        }
        match report.write(args.get_str("json-dir").unwrap_or(".")) {
            Ok(p) => println!("# wrote {p}"),
            Err(e) => {
                eprintln!("failed to write bench json: {e}");
                std::process::exit(1);
            }
        }
    }
}
