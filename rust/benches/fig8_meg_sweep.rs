//! Paper Fig. 8: the factorization compromise on the MEG operator.
//!
//! Sweep of (J, k, s) producing the RCG-vs-RE scatter: paper settings are
//! J∈{2..10}, k∈{5,10,15,20,25,30}, s∈{2m,4m,8m}, ρ=0.8, P=1.4m² on the
//! 204×8193 gain (127 configs, (J−1)×10 min each in Matlab). Default here
//! is a reduced grid on a scaled operator; FAUST_BENCH_FULL=1 widens it.
//!
//! Expected shape (paper §V-A): k controls overall RCG; larger J lowers
//! RCG but too-large J raises RE; J=2 never the best trade-off.

use faust::bench_util::{fmt, Table};
use faust::hierarchical::{factorize, HierarchicalConfig};
use faust::meg::meg_model;
use faust::rng::Rng;
use std::time::Instant;

fn main() {
    let full = std::env::var("FAUST_BENCH_FULL").is_ok();
    let (m, n) = if full { (204, 8193) } else { (128, 2048) };
    let js: &[usize] = if full { &[2, 3, 4, 5, 6, 8, 10] } else { &[2, 3, 4, 6] };
    let ks: &[usize] = if full { &[5, 10, 15, 20, 25, 30] } else { &[5, 10, 20, 30] };
    let ss: &[usize] = if full { &[2, 4, 8] } else { &[2, 8] };
    println!("# Fig. 8 — factorization compromise ({m}x{n} synthetic MEG gain)");
    println!("# paper shape: k drives RCG; J trades error vs complexity; J=2 never best\n");
    let model = meg_model(m, n, 42);
    let mut rng = Rng::new(9);
    let mut table = Table::new(&["J", "k", "s/m", "RCG", "RE (spectral)", "time_s"]);
    let mut best_per_k: std::collections::HashMap<usize, (f64, usize, f64)> =
        std::collections::HashMap::new();
    for &k in ks {
        for &j in js {
            for &s_m in ss {
                let cfg = HierarchicalConfig::meg(
                    m,
                    n,
                    j,
                    k,
                    s_m * m,
                    0.8,
                    1.4 * (m * m) as f64,
                );
                let t0 = Instant::now();
                let fst = factorize(&model.gain, &cfg);
                let dt = t0.elapsed().as_secs_f64();
                let re = fst.relative_error_spectral(&model.gain, &mut rng);
                table.row(&[
                    j.to_string(),
                    k.to_string(),
                    s_m.to_string(),
                    fmt(fst.rcg()),
                    fmt(re),
                    fmt(dt),
                ]);
                let e = best_per_k.entry(k).or_insert((f64::INFINITY, 0, 0.0));
                if re < e.0 {
                    *e = (re, j, fst.rcg());
                }
            }
        }
    }
    table.print();
    println!("\n# lowest-RE configuration per k (the paper's highlighted M^ points):");
    let mut ks_sorted: Vec<_> = best_per_k.keys().copied().collect();
    ks_sorted.sort_unstable();
    for k in ks_sorted {
        let (re, j, rcg) = best_per_k[&k];
        println!("#   k={k:<3} -> J={j}, RCG={rcg:.1}, RE={re:.4}");
    }
}
