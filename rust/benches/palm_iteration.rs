//! Algorithm-cost bench: palm4MSA per-iteration cost scaling, and the
//! hierarchical overhead factor (§IV-B3: "roughly J−1 times the basic
//! palm4MSA").

use faust::bench_util::{fmt, time_auto, Table};
use faust::linalg::Mat;
use faust::palm::{palm4msa, FactorState, PalmConfig};
use faust::prox::Constraint;
use faust::rng::Rng;
use std::hint::black_box;

fn main() {
    println!("# palm4MSA per-iteration cost vs problem size (2-factor split)\n");
    let mut table = Table::new(&["n", "iter_us", "its/s"]);
    for n in [32usize, 64, 128, 256] {
        let mut rng = Rng::new(1);
        let a = Mat::randn(n, n, &mut rng);
        let cfg = PalmConfig::new(
            vec![Constraint::SpRowCol(2), Constraint::SpRowCol(n / 2)],
            1,
        );
        // Time exactly one iteration from a warm state.
        let warm = {
            let c10 = PalmConfig::new(cfg.constraints.clone(), 10);
            palm4msa(&a, FactorState::default_init(&[(n, n), (n, n)]), &c10).state
        };
        let t = time_auto(100.0, || {
            black_box(palm4msa(&a, warm.clone(), &cfg));
        });
        table.row(&[
            n.to_string(),
            fmt(t.median_us()),
            fmt(1e9 / t.median_ns),
        ]);
    }
    table.print();

    println!("\n# hierarchical total cost vs direct palm4MSA (J factors, n=64)");
    let n = 64usize;
    let a = faust::transforms::hadamard(n);
    let hcfg = faust::hierarchical::HierarchicalConfig::hadamard(n);
    let t_h = time_auto(500.0, || {
        black_box(faust::hierarchical::factorize(&a, &hcfg));
    });
    let j = hcfg.n_factors();
    let direct_cfg = PalmConfig::new(
        (0..j)
            .map(|i| {
                if i == j - 1 {
                    Constraint::SpRowCol(2)
                } else {
                    Constraint::SpRowCol(2)
                }
            })
            .collect(),
        hcfg.n_iter_split,
    );
    let dims: Vec<(usize, usize)> = vec![(n, n); j];
    let t_d = time_auto(500.0, || {
        black_box(palm4msa(&a, FactorState::default_init(&dims), &direct_cfg));
    });
    println!(
        "hierarchical: {:.1} ms   direct palm4MSA (same split iters): {:.1} ms   ratio: {:.1} (paper predicts ~J-1 = {})",
        t_h.median_ms(),
        t_d.median_ms(),
        t_h.median_ns / t_d.median_ns,
        j - 1
    );
}
