//! Fleet factorization scaling: N concurrent hierarchical factorizations
//! on one shared ctx (cross-operator batched PALM sweeps) vs the same N
//! jobs run sequentially through `factorize_with_ctx`.
//!
//! Acceptance (ISSUE 4): ≥1.3× throughput for a 16-operator fleet vs 16
//! sequential factorizations at 4 threads, and **bitwise identity**
//! between the fleet results and the sequential runs. Both are asserted:
//! divergence always exits non-zero, and a sub-1.3× speedup exits
//! non-zero on hardware that can express it (≥4 cores and ≥4 threads —
//! below that the speedup is capped by the core count and only the
//! baseline.json noise-aware floor gates it).
//!
//! CI runs the 2-thread smoke (`-- --ops 12 --n 32 --threads 2 --json`)
//! and gates the emitted `BENCH_fleet_scaling.json` against
//! `benches/baseline.json`; locally, `cargo bench --bench fleet_scaling`
//! runs the 4-thread / 16-operator acceptance configuration.

use faust::bench_util::{fleet_compare, fmt, BenchReport, Table};
use faust::cli::Args;
use faust::engine::ExecCtx;

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    let ops: usize = args.get("ops", 16);
    let n: usize = args.get("n", 64);
    let threads: usize = args.get("threads", 4);
    assert!(n.is_power_of_two() && n >= 8, "--n must be a power of two >= 8");
    assert!(ops >= 1, "--ops must be >= 1");
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    println!(
        "# fleet scaling — {ops} × {n}-point Hadamard factorizations, \
         {threads} threads, machine cores={cores}\n"
    );

    // One member per "subject": same operator size, per-member seeds →
    // distinct factorization trajectories (§V holds one gain matrix per
    // subject). The protocol is bench_util::fleet_compare, shared with
    // the `faust fleet` CLI so the two cannot drift apart.
    let ctx = ExecCtx::new(threads);
    let cmp = fleet_compare(ops, n, &ctx);
    let (seq_s, fleet_s) = (cmp.seq_s, cmp.fleet_s);
    let (identical, max_rel) = (cmp.identical, cmp.max_rel_err);
    let speedup = cmp.speedup();
    let m = &cmp.metrics;

    let mut table = Table::new(&["mode", "wall_s", "ops/s", "speedup"]);
    table.row(&[
        "sequential".into(),
        format!("{seq_s:.3}"),
        fmt(ops as f64 / seq_s),
        fmt(1.0),
    ]);
    table.row(&[
        "fleet".into(),
        format!("{fleet_s:.3}"),
        fmt(ops as f64 / fleet_s),
        fmt(speedup),
    ]);
    table.print();
    println!(
        "\n# fused gemms: {} (in {} dispatches, {} solo), batched power \
         iterations: {}",
        m.fused_gemms, m.fused_calls, m.solo_gemms, m.spectral_jobs
    );
    let speed_ok = speedup >= 1.3;
    println!(
        "# acceptance ({ops} ops, {threads} threads on {cores} cores): \
         fleet speedup={speedup:.2}x [{}], bitwise identical to sequential [{}], \
         max rel err={max_rel:.2e}",
        if speed_ok {
            "PASS >=1.3x"
        } else if cores < 4 {
            "capped by core count"
        } else {
            "FAIL <1.3x"
        },
        if identical { "PASS" } else { "FAIL" },
    );

    if args.flag("json") {
        let mut report = BenchReport::new("fleet_scaling");
        report.push("ops", ops as f64);
        report.push("n", n as f64);
        report.push("threads", threads as f64);
        report.push("cores", cores as f64);
        report.push("wall_s_sequential", seq_s);
        report.push("wall_s_fleet", fleet_s);
        report.push("fleet_speedup", speedup);
        report.push("max_rel_err", max_rel);
        report.push("bitwise_identical", if identical { 1.0 } else { 0.0 });
        report.push("fused_gemms", m.fused_gemms as f64);
        match report.write(args.get_str("json-dir").unwrap_or(".")) {
            Ok(p) => println!("# wrote {p}"),
            Err(e) => {
                eprintln!("failed to write bench json: {e}");
                std::process::exit(1);
            }
        }
    }
    if !identical {
        eprintln!("fleet factorization diverged bitwise from sequential runs");
        std::process::exit(1);
    }
    // The >=1.3x acceptance is an assertion, not a printout — but only
    // where the hardware can express it (the 2-core CI smoke gates a
    // noise-aware floor via baseline.json instead).
    if cores >= 4 && threads >= 4 && !speed_ok {
        eprintln!(
            "fleet speedup {speedup:.2}x below the 1.3x acceptance threshold \
             ({threads} threads on {cores} cores)"
        );
        std::process::exit(1);
    }
}
