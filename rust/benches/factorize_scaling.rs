//! Factorization scaling: hierarchical Hadamard factorization on the
//! engine's `ExecCtx`, swept over thread counts, with a bitwise
//! determinism check and a scalar-vs-tiled dense-microkernel comparison.
//!
//! Acceptance (ISSUE 2): ≥2x wall-clock speedup for the 512-point
//! Hadamard factorization at 8 threads vs the serial path — on hardware
//! with ≥8 cores; the achievable speedup is capped by the machine's core
//! count, which is printed alongside — and bitwise-identical factors for
//! a fixed seed at every thread count (this part is asserted: a
//! non-deterministic run exits non-zero).
//!
//! Acceptance (ISSUE 5): ≥1.25x single-thread speedup of the
//! register-tiled `engine::kernel` GEMM over the scalar reference on the
//! 512-dim dense stages PALM sweeps bottom out in, reported here
//! (`gemm512_tiled_speedup`) and enforced as a `min` rule in
//! `benches/baseline.json`. The tiled result is also checked against the
//! scalar one in-process (≤ 1e-12 relative) before it is reported.
//!
//! CI runs the 256-point smoke (`-- --n 256 --max-threads 2 --json`),
//! uploads the emitted `BENCH_factorize_scaling.json` as an artifact and
//! gates it against `benches/baseline.json`; locally, `cargo bench
//! --bench factorize_scaling` sweeps 1..8 threads at n=512. The GEMM
//! stage comparison always runs at dim 512 so the gated metric measures
//! the same shape on every configuration.

use faust::bench_util::{compare_scalar_vs_tiled, fmt, BenchReport, Table};
use faust::cli::Args;
use faust::engine::{kernel, ExecCtx};
use faust::hierarchical::{factorize_with_ctx, HierarchicalConfig};
use faust::testutil::faust_fingerprint;
use faust::transforms::hadamard;
use std::time::Instant;

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    let n: usize = args.get("n", 512);
    let max_threads: usize = args.get("max-threads", 8);
    assert!(n.is_power_of_two() && n >= 8, "--n must be a power of two >= 8");
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let a = hadamard(n);
    let cfg = HierarchicalConfig::hadamard(n);
    println!(
        "# factorize scaling — {n}-point Hadamard, J={} factors, machine cores={cores}\n",
        cfg.n_factors()
    );
    let mut table = Table::new(&["threads", "wall_s", "speedup", "rel_err", "bitwise_identical"]);
    let mut baseline: Option<(f64, (u64, Vec<Vec<u64>>))> = None;
    let mut top_speedup = 1.0_f64;
    let mut all_identical = true;
    let mut threads = 1usize;
    while threads <= max_threads {
        let ctx = ExecCtx::new(threads);
        let t0 = Instant::now();
        let fst = factorize_with_ctx(&ctx, &a, &cfg);
        let dt = t0.elapsed().as_secs_f64();
        let rel = fst.relative_error_fro(&a);
        let fp = faust_fingerprint(&fst);
        let (identical, speedup) = match &baseline {
            None => (true, 1.0),
            Some((t1, fp1)) => {
                let same = *fp1 == fp;
                if !same {
                    all_identical = false;
                }
                (same, t1 / dt)
            }
        };
        if baseline.is_none() {
            baseline = Some((dt, fp));
        }
        top_speedup = top_speedup.max(speedup);
        table.row(&[
            threads.to_string(),
            format!("{dt:.3}"),
            fmt(speedup),
            format!("{rel:.2e}"),
            identical.to_string(),
        ]);
        threads *= 2;
    }
    table.print();

    // Scalar-vs-tiled microkernel comparison on the dense GEMM stage size
    // the PALM sweeps of a 512-dim operator bottom out in (ISSUE 5 /
    // ROADMAP item d), via the shared bench_util protocol (one harness
    // for both gated benches). The dim is pinned to 512 so the gated
    // `gemm512_*` metrics always measure the same shape, whatever `--n`
    // the factorization sweep ran at.
    let gd: usize = 512;
    let cmp = compare_scalar_vs_tiled(gd, gd, gd, 80.0, 0xD512);
    let gemm_speedup = cmp.speedup();
    println!(
        "\n# dense {gd}-dim GEMM stage, 1 thread, {}-lane {:?} kernel: \
         scalar={:.2}ms tiled={:.2}ms speedup={gemm_speedup:.2}x [{}] (max rel dev {:.1e})",
        cmp.lanes,
        kernel::simd_level(),
        cmp.scalar.median_ms(),
        cmp.tiled.median_ms(),
        if gemm_speedup >= 1.25 { "PASS >=1.25x" } else { "FAIL <1.25x" },
        cmp.max_rel_dev,
    );

    if args.flag("json") {
        let (serial_s, _) = baseline.as_ref().expect("at least one thread count ran");
        let mut report = BenchReport::new("factorize_scaling");
        report.push("n", n as f64);
        report.push("max_threads", max_threads as f64);
        report.push("cores", cores as f64);
        report.push("wall_s_serial", *serial_s);
        report.push("best_speedup", top_speedup);
        report.push("bitwise_identical", if all_identical { 1.0 } else { 0.0 });
        report.push("gemm_dim", gd as f64);
        report.push("simd_lanes", cmp.lanes as f64);
        report.push("gemm512_scalar_ms", cmp.scalar.median_ms());
        report.push("gemm512_tiled_ms", cmp.tiled.median_ms());
        report.push("gemm512_tiled_speedup", gemm_speedup);
        match report.write(args.get_str("json-dir").unwrap_or(".")) {
            Ok(p) => println!("# wrote {p}"),
            Err(e) => {
                eprintln!("failed to write bench json: {e}");
                std::process::exit(1);
            }
        }
    }
    let speed_ok = top_speedup >= 2.0;
    println!(
        "\n# acceptance ({n}-point, up to {max_threads} threads on {cores} cores): \
         best speedup={top_speedup:.2}x [{}], deterministic across threads [{}]",
        if speed_ok {
            "PASS >=2x"
        } else if cores < 4 {
            "capped by core count"
        } else {
            "FAIL <2x"
        },
        if all_identical { "PASS" } else { "FAIL" },
    );
    if !all_identical {
        eprintln!("non-deterministic factorization across thread counts");
        std::process::exit(1);
    }
}
