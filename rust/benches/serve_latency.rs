//! L3-ingress bench: **open-loop** serving latency under Poisson load
//! across the three QoS classes, over real loopback TCP through the full
//! `wire → admission → batcher → registry → engine` path.
//!
//! Open-loop means senders pace by an absolute arrival schedule and
//! never wait for responses — server slowdown shows up as tail latency
//! instead of silently reducing the offered rate (the coordinated-
//! omission trap of closed-loop serving benchmarks). Mid-run the
//! operator is epoch-swapped between its dense and FAμST backends
//! (`--swaps` times) while traffic flows; every OK payload is verified
//! against the dense reference, so a misroute or a torn swap is a
//! counted failure, not a silent wrong answer.
//!
//! Default shape is the CI soak: 100k requests at 25k req/s aggregate
//! (~4-5 s wall), split ~30/40/30 across interactive/standard/bulk,
//! served under `--precision auto:1e-3` (ISSUE 7) so the registry's
//! per-operator precision selection — and its interaction with
//! mid-traffic epoch swaps — is what the soak exercises. After the main
//! soak a paired pair of mini streams (identical load, f64 vs f32 wire
//! dtype) measures the f32 tier's tail latency, gated in
//! `baseline.json` by an f32-not-slower ratio rule.
//! With `--json` the per-class p50/p99/p999 and shed rates land in
//! `BENCH_serve_latency.json`, gated by `scripts/bench_gate.py` against
//! `benches/baseline.json`; the bench exits non-zero on any misrouted
//! or protocol-error count.

use faust::bench_util::{fmt, open_loop_load, BenchReport, ClassLoadReport, OpenLoopConfig, Table};
use faust::coordinator::{
    AdaptiveBatchConfig, BatchOp, Coordinator, CoordinatorConfig, Precision, QosClass,
};
use faust::server::wire::Dtype;
use faust::server::{Server, ServerConfig};
use faust::transforms::{hadamard, hadamard_faust};
use std::sync::Arc;
use std::time::Duration;

struct Args {
    n: usize,
    rate: f64,
    requests: usize,
    swaps: usize,
    workers: usize,
    seed: u64,
    precision: Precision,
    json: bool,
    json_dir: String,
}

fn parse_args() -> Args {
    let mut a = Args {
        n: 64,
        rate: 25_000.0,
        requests: 100_000,
        swaps: 2,
        workers: 4,
        seed: 42,
        precision: Precision::Auto(1e-3),
        json: false,
        json_dir: ".".to_string(),
    };
    let argv: Vec<String> = std::env::args().collect();
    let mut i = 1;
    let take = |i: &mut usize| -> String {
        *i += 1;
        argv.get(*i).cloned().unwrap_or_else(|| {
            eprintln!("missing value for {}", argv[*i - 1]);
            std::process::exit(2);
        })
    };
    while i < argv.len() {
        match argv[i].as_str() {
            "--n" => a.n = take(&mut i).parse().expect("--n"),
            "--rate" => a.rate = take(&mut i).parse().expect("--rate"),
            "--requests" => a.requests = take(&mut i).parse().expect("--requests"),
            "--swaps" => a.swaps = take(&mut i).parse().expect("--swaps"),
            "--workers" => a.workers = take(&mut i).parse().expect("--workers"),
            "--seed" => a.seed = take(&mut i).parse().expect("--seed"),
            "--precision" => a.precision = take(&mut i).parse().expect("--precision"),
            "--json" => a.json = true,
            "--json-dir" => a.json_dir = take(&mut i),
            "--bench" => {} // ignore libtest's flag when invoked via cargo bench
            other => {
                eprintln!(
                    "unknown arg {other}\nusage: serve_latency [--n D] [--rate R] \
                     [--requests N] [--swaps S] [--workers W] [--seed S] \
                     [--precision f64|f32|auto[:EPS]] [--json] [--json-dir DIR]"
                );
                std::process::exit(2);
            }
        }
        i += 1;
    }
    a
}

fn main() {
    let args = parse_args();
    let n = args.n;
    println!(
        "# serve_latency — open-loop Poisson load over loopback TCP\n\
         # n={n} rate={} req/s requests={} swaps={} workers={} precision={}\n",
        args.rate, args.requests, args.swaps, args.workers, args.precision
    );

    // Under f32/auto serving the FAμST generations may execute in f32,
    // so payload verification against the dense f64 reference needs a
    // tolerance that absorbs the declared quantization error; pure-f64
    // serving keeps the historical tight bound.
    let precision_tol = if matches!(args.precision, Precision::F64) {
        1e-6
    } else {
        1e-3
    };

    let dense = hadamard(n);
    let coord = Coordinator::start(
        vec![("h".to_string(), Arc::new(dense.clone()) as Arc<dyn BatchOp>)],
        CoordinatorConfig {
            max_batch: 32,
            batch_timeout: Duration::from_micros(200),
            n_workers: args.workers,
            queue_capacity: 8192,
            adaptive: Some(AdaptiveBatchConfig::default()),
            precision: args.precision,
            n_shards: 1,
            online: None,
        },
    );
    let server = Server::start(coord.client(), ServerConfig::default()).expect("bind loopback");
    let addr = server.local_addr().to_string();

    // Mid-traffic refactorize: swap the live operator between its dense
    // and FAμST backends while the load runs. Same linear map, so the
    // payload verification must keep passing across every swap.
    let expected_wall = args.requests as f64 / args.rate.max(1.0);
    let registry = coord.registry();
    let swaps = args.swaps;
    let swap_thread = std::thread::spawn(move || {
        let mut done = 0usize;
        let gap = expected_wall / (swaps + 1) as f64;
        for k in 0..swaps {
            std::thread::sleep(Duration::from_secs_f64(gap));
            let op: Arc<dyn BatchOp> = if k % 2 == 0 {
                Arc::new(hadamard_faust(n))
            } else {
                Arc::new(hadamard(n))
            };
            if registry.swap_epoch("h", op).is_ok() {
                done += 1;
            }
        }
        done
    });

    // One open-loop stream per class, ~30/40/30 of the aggregate.
    let shares = [
        (QosClass::Interactive, 0.3),
        (QosClass::Standard, 0.4),
        (QosClass::Bulk, 0.3),
    ];
    let mut handles = Vec::new();
    let mut assigned = 0usize;
    for (k, (class, share)) in shares.iter().enumerate() {
        let requests = if k + 1 == shares.len() {
            args.requests - assigned // remainder keeps the total exact
        } else {
            (args.requests as f64 * share) as usize
        };
        assigned += requests;
        let cfg = OpenLoopConfig {
            addr: addr.clone(),
            op: "h".to_string(),
            class: *class,
            rate_hz: args.rate * share,
            requests,
            dim: n,
            seed: args.seed.wrapping_add(k as u64),
            dtype: Dtype::F64,
            verify_tol: precision_tol,
        };
        let verify = dense.clone();
        handles.push(std::thread::spawn(move || open_loop_load(&cfg, Some(&verify))));
    }
    let reports: Vec<ClassLoadReport> = handles
        .into_iter()
        .map(|h| h.join().expect("load thread").expect("load stream"))
        .collect();
    let swaps_done = swap_thread.join().expect("swap thread");

    // Paired mini streams (ISSUE 7): identical sequential load, first on
    // the f64 wire dtype then on f32, against the now-quiet server. The
    // f32 tier halves payload bytes each way, so its tail must not be
    // slower than f64's beyond noise — gated by the f32-not-slower ratio
    // rule on {f64,f32}_mini_p99_us in baseline.json.
    let mini_requests = (args.requests / 10).clamp(1_000, 20_000);
    let mut mini: Vec<ClassLoadReport> = Vec::new();
    for (j, dtype) in [Dtype::F64, Dtype::F32].into_iter().enumerate() {
        let cfg = OpenLoopConfig {
            addr: addr.clone(),
            op: "h".to_string(),
            class: QosClass::Standard,
            rate_hz: args.rate * 0.4,
            requests: mini_requests,
            dim: n,
            seed: args.seed.wrapping_add(0x11D + j as u64),
            dtype,
            // f32 wire quantization costs up to ~1e-4 absolute at these
            // magnitudes, on top of whatever the serving tier allows.
            verify_tol: precision_tol.max(if dtype == Dtype::F32 { 1e-4 } else { 0.0 }),
        };
        let r = open_loop_load(&cfg, Some(&dense)).expect("mini stream");
        println!(
            "# mini dtype={dtype}: sent={} ok={} shed={} p99={:.1}us",
            r.sent, r.ok, r.shed, r.latency.p99_us
        );
        mini.push(r);
    }

    server.shutdown();
    let snap = coord.shutdown();

    let mut table = Table::new(&[
        "class", "sent", "ok", "shed", "p50_us", "p99_us", "p999_us", "epochs",
    ]);
    let mut epochs = std::collections::BTreeSet::new();
    let (mut sent, mut ok, mut shed, mut misrouted, mut protocol_errors, mut other) =
        (0usize, 0usize, 0usize, 0usize, 0usize, 0usize);
    let mut wall_s = 0.0f64;
    for r in &reports {
        table.row(&[
            r.class.name().to_string(),
            r.sent.to_string(),
            r.ok.to_string(),
            r.shed.to_string(),
            fmt(r.latency.p50_us),
            fmt(r.latency.p99_us),
            fmt(r.latency.p999_us),
            r.epochs.len().to_string(),
        ]);
        sent += r.sent;
        ok += r.ok;
        shed += r.shed;
        misrouted += r.misrouted;
        protocol_errors += r.protocol_errors;
        other += r.other_errors;
        epochs.extend(r.epochs.iter().copied());
        wall_s = wall_s.max(r.wall_s);
    }
    table.print();
    let shed_rate_total = if sent == 0 { 0.0 } else { shed as f64 / sent as f64 };
    let rps = sent as f64 / wall_s.max(1e-9);
    println!(
        "\n# sent={sent} ok={ok} shed={shed} ({:.2}%) other_errors={other} \
         misrouted={misrouted} protocol_errors={protocol_errors}",
        shed_rate_total * 100.0
    );
    println!(
        "# wall={wall_s:.2}s achieved={rps:.0} req/s swaps={swaps_done} \
         epochs_observed={} ingress_accepted={} hwm={}",
        epochs.len(),
        snap.ingress_accepted,
        snap.ingress_queue_hwm
    );

    // The soak contract: every response routed to its request, every
    // shed typed; anything else fails the bench outright. The dtype mini
    // streams are held to the same contract.
    let mini_clean = mini.iter().all(|r| {
        r.misrouted == 0 && r.protocol_errors == 0 && r.ok + r.shed + r.other_errors == r.sent
    });
    let clean =
        misrouted == 0 && protocol_errors == 0 && ok + shed + other == sent && mini_clean;
    println!(
        "# soak: {} (zero misrouted, zero protocol errors, every request answered)",
        if clean { "PASS" } else { "FAIL" }
    );

    if args.json {
        let mut rep = BenchReport::new("serve_latency");
        for r in &reports {
            let c = r.class.name();
            rep.push(&format!("{c}_p50_us"), r.latency.p50_us);
            rep.push(&format!("{c}_p99_us"), r.latency.p99_us);
            rep.push(&format!("{c}_p999_us"), r.latency.p999_us);
            rep.push(&format!("{c}_shed_rate"), r.shed_rate());
        }
        rep.push("f64_mini_p99_us", mini[0].latency.p99_us);
        rep.push("f32_mini_p99_us", mini[1].latency.p99_us);
        rep.push(
            "f32_mini_p99_ratio",
            mini[1].latency.p99_us / mini[0].latency.p99_us.max(1e-9),
        );
        rep.push("requests", sent as f64);
        rep.push("shed_rate_total", shed_rate_total);
        rep.push("misrouted", misrouted as f64);
        rep.push("protocol_errors", protocol_errors as f64);
        rep.push("epochs_observed", epochs.len() as f64);
        rep.push("swaps_done", swaps_done as f64);
        rep.push("wall_s", wall_s);
        rep.push("rps", rps);
        match rep.write(&args.json_dir) {
            Ok(path) => println!("# wrote {path}"),
            Err(e) => {
                eprintln!("failed to write report: {e}");
                std::process::exit(1);
            }
        }
    }
    if !clean {
        std::process::exit(1);
    }
}
