//! Paper Fig. 6 / §IV-C: hierarchical factorization of the Hadamard
//! matrix is exact, with butterfly complexity, across sizes.
//!
//! Paper series: n = 32 (Fig. 6), behaviour identical up to n = 1024 with
//! O(n²)-ish running time. We sweep n and report exactness, s_tot vs the
//! 2n·log2(n) reference, RCG, and wall time.

use faust::bench_util::{fmt, Table};
use faust::hierarchical::{factorize, HierarchicalConfig};
use faust::transforms::{hadamard, hadamard_faust};
use std::time::Instant;

fn main() {
    let full = std::env::var("FAUST_BENCH_FULL").is_ok();
    let sizes: &[usize] = if full { &[16, 32, 64, 128, 256, 512] } else { &[16, 32, 64, 128] };
    println!("# Fig. 6 — reverse-engineering the Hadamard transform");
    println!("# paper: exact factorization, s_tot = 2n·log2(n), runtime O(n²)\n");
    let mut table = Table::new(&[
        "n",
        "rel_err",
        "s_tot",
        "s_tot_ref",
        "RCG",
        "RCG_ref",
        "time_s",
    ]);
    for &n in sizes {
        let a = hadamard(n);
        let cfg = HierarchicalConfig::hadamard(n);
        let t0 = Instant::now();
        let fst = factorize(&a, &cfg);
        let dt = t0.elapsed().as_secs_f64();
        let reference = hadamard_faust(n);
        table.row(&[
            n.to_string(),
            format!("{:.1e}", fst.relative_error_fro(&a)),
            fst.s_tot().to_string(),
            reference.s_tot().to_string(),
            fmt(fst.rcg()),
            fmt(reference.rcg()),
            fmt(dt),
        ]);
    }
    table.print();
}
