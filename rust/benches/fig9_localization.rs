//! Paper Fig. 9: brain-source localization with FAμST approximations.
//!
//! 2-sparse sources at controlled separations; OMP recovery with the true
//! gain M vs FAμSTs of increasing RCG. Paper shape: M̂ with RCG ≤ ~11
//! localizes almost as well as M (>75% exact for d > 8 cm); very high RCG
//! (M̂₁₆, M̂₂₅) degrades.

use faust::bench_util::{fmt, Table};
use faust::hierarchical::{factorize, HierarchicalConfig};
use faust::meg::{localization_experiment, meg_model};
use faust::solvers::LinOp;
use std::time::Instant;

fn main() {
    let full = std::env::var("FAUST_BENCH_FULL").is_ok();
    let (m, n) = if full { (204, 8193) } else { (128, 2048) };
    let trials = if full { 500 } else { 150 };
    println!("# Fig. 9 — source localization, {trials} trials/bin ({m}x{n} gain)");
    println!("# paper shape: moderate-RCG FAuSTs ~ match M; extreme RCG degrades\n");
    let model = meg_model(m, n, 42);

    // FAuSTs of increasing RCG (k controls it, as Fig. 8 showed).
    let mut ops: Vec<(String, Box<dyn LinOp>)> =
        vec![("M dense".into(), Box::new(model.gain.clone()))];
    for &(j, k) in &[(4usize, 25usize), (4, 10), (4, 5)] {
        let cfg = HierarchicalConfig::meg(m, n, j, k, 2 * m, 0.8, 1.4 * (m * m) as f64);
        let t0 = Instant::now();
        let fst = factorize(&model.gain, &cfg);
        eprintln!(
            "# factorized J={j} k={k}: RCG={:.1} ({:.1?})",
            fst.rcg(),
            t0.elapsed()
        );
        ops.push((format!("M^ RCG={:.0}", fst.rcg()), Box::new(fst)));
    }

    let mut table = Table::new(&["separation", "matrix", "median(cm)", "mean(cm)", "q3(cm)", "exact%"]);
    for (dmin, dmax, label) in [(1.0, 5.0, "1-5cm"), (5.0, 8.0, "5-8cm"), (8.0, 100.0, ">8cm")] {
        for (name, op) in &ops {
            let stats = localization_experiment(&model, op.as_ref(), trials, dmin, dmax, 17);
            table.row(&[
                label.to_string(),
                name.clone(),
                fmt(stats.median()),
                fmt(stats.mean()),
                fmt(stats.quantile(0.75)),
                format!("{:.0}", stats.exact_rate() * 100.0),
            ]);
        }
    }
    table.print();
}
