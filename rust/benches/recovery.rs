//! Restart economics of the durable operator store (ROADMAP item l).
//!
//! Cold start pays the full hierarchical PALM factorization for every
//! operator in the fleet before it can serve; warm start replays the
//! store directory instead. This bench measures both paths on the same
//! fleet and proves the warm path never touches the solver: the
//! process-wide PALM iteration counter must not move during restore.
//!
//! CI runs the 2-op smoke (`-- --ops 2 --n 32 --json`) and gates
//! `BENCH_recovery.json` against `benches/baseline.json` — the headline
//! ceiling is `warm_start_ms` (restore must stay under the budget) and
//! `warm_palm_iters` (exactly zero re-factorization).

use faust::bench_util::{fmt, BenchReport, Table};
use faust::cli::Args;
use faust::coordinator::{BatchOp, Registry};
use faust::engine::ApplyEngine;
use faust::hierarchical::{factorize, HierarchicalConfig};
use faust::palm::iterations_total;
use faust::transforms::hadamard;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    let n: usize = args.get("n", 64);
    let ops: usize = args.get("ops", 4).max(1);
    let threads: usize = args.get("threads", 2);
    let dir = std::env::temp_dir().join(format!("faust_bench_recovery_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();

    println!("# store recovery — cold factorize vs warm restore (n={n}, ops={ops})\n");
    let engine = ApplyEngine::with_threads(threads);
    let h = hadamard(n);
    let cfg = HierarchicalConfig::hadamard(n);

    // ---- Cold path: learn the whole fleet, then snapshot it. ----
    let iters0 = iterations_total();
    let t_cold = Instant::now();
    let registry = Registry::new(None);
    for k in 0..ops {
        let learned = factorize(&h, &cfg);
        registry
            .register(format!("op{k}"), Arc::new(engine.op(&learned)) as Arc<dyn BatchOp>)
            .expect("fresh registry");
    }
    let report = registry.persist_all(&dir).expect("snapshot");
    let cold_ms = t_cold.elapsed().as_secs_f64() * 1e3;
    let cold_iters = iterations_total() - iters0;
    assert_eq!(report.persisted.len(), ops);
    assert!(cold_iters > 0, "cold start must run PALM");

    let store_bytes: u64 = std::fs::read_dir(&dir)
        .expect("store dir")
        .filter_map(|e| e.ok())
        .filter_map(|e| e.metadata().ok())
        .map(|m| m.len())
        .sum();

    // ---- Warm path: a fresh registry restored from the store alone. ----
    let iters1 = iterations_total();
    let t_warm = Instant::now();
    let warm = Registry::new(None);
    let restore = warm
        .load_store(&dir, |_, f| Arc::new(engine.op(f)) as Arc<dyn BatchOp>)
        .expect("store readable");
    let warm_ms = t_warm.elapsed().as_secs_f64() * 1e3;
    let warm_iters = iterations_total() - iters1;
    assert_eq!(restore.loaded.len(), ops, "every operator must restore");
    assert!(restore.corrupt.is_empty());

    // Restored generations must serve the cold fleet's exact bits.
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.3).cos()).collect();
    for name in &restore.loaded {
        let cold_op = registry.get_serving(name).expect("cold live");
        let warm_op = warm.get_serving(name).expect("warm live");
        let a = cold_op.0.apply_batch(&faust::linalg::Mat::from_vec(n, 1, x.clone()));
        let b = warm_op.0.apply_batch(&faust::linalg::Mat::from_vec(n, 1, x.clone()));
        for i in 0..n {
            assert_eq!(
                a.data()[i].to_bits(),
                b.data()[i].to_bits(),
                "{name}: warm restore changed bits at row {i}"
            );
        }
    }

    let mut table = Table::new(&["path", "ms", "palm_iters", "ops", "store_bytes"]);
    table.row(&[
        "cold".to_string(),
        fmt(cold_ms),
        cold_iters.to_string(),
        ops.to_string(),
        store_bytes.to_string(),
    ]);
    table.row(&[
        "warm".to_string(),
        fmt(warm_ms),
        warm_iters.to_string(),
        restore.loaded.len().to_string(),
        "-".to_string(),
    ]);
    table.print();
    println!(
        "\n# warm restore is {}x faster than cold factorization and runs zero PALM iterations",
        fmt(cold_ms / warm_ms.max(1e-9))
    );

    if args.flag("json") {
        let mut rep = BenchReport::new("recovery");
        rep.push("cold_start_ms", cold_ms);
        rep.push("warm_start_ms", warm_ms);
        rep.push("cold_palm_iters", cold_iters as f64);
        rep.push("warm_palm_iters", warm_iters as f64);
        rep.push("ops_restored", restore.loaded.len() as f64);
        rep.push("store_bytes", store_bytes as f64);
        match rep.write(args.get_str("json-dir").unwrap_or(".")) {
            Ok(p) => println!("# wrote {p}"),
            Err(e) => eprintln!("# json write failed: {e}"),
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}
