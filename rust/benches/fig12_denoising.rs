//! Paper Fig. 12: image denoising — FAμST dictionaries vs dense K-SVD
//! (DDL) vs overcomplete DCT, across noise levels.
//!
//! Paper shape: at strong noise (σ = 30, 50) FAμST beats DDL (fewer
//! parameters → less noise overfitting) and DCT; at low noise DDL wins
//! (adaptivity), especially on heavy texture; sparser FAμSTs do better at
//! high σ, worse at low σ.

use faust::bench_util::{fmt, Table};
use faust::dictlearn::{faust_dictionary_learning, ksvd, KsvdConfig};
use faust::hierarchical::HierarchicalConfig;
use faust::image::{add_noise, corpus, denoise, psnr, random_patches};
use faust::rng::Rng;
use faust::transforms::overcomplete_dct;

fn main() {
    let full = std::env::var("FAUST_BENCH_FULL").is_ok();
    let size = if full { 256 } else { 128 };
    let n_train = if full { 6000 } else { 2000 };
    let sigmas: &[f64] = if full { &[10.0, 15.0, 20.0, 30.0, 50.0] } else { &[10.0, 30.0, 50.0] };
    let p = 8usize;
    let natoms = 128usize;
    let stride = if full { 2 } else { 3 };
    println!("# Fig. 12 — denoising: FAuST vs DDL (K-SVD) vs DCT ({size}x{size}, {natoms} atoms)");
    println!("# paper shape: FAuST > DDL at high sigma; DDL wins at low sigma on texture\n");

    let imgs = corpus(size);
    // One image per regime: texture (worst for FAuST), smooth (best), mixed (typical).
    let picks: Vec<usize> = vec![3, 6, 9];
    let mut table = Table::new(&[
        "image", "sigma", "noisy_dB", "DDL_dB", "FAuST_dB", "FAuST_s_tot", "DCT_dB",
        "FAuST-DDL", "DCT-DDL",
    ]);
    for &pi in &picks {
        let (name, img) = &imgs[pi];
        for &sigma in sigmas {
            let mut rng = Rng::new(7 + pi as u64);
            let noisy = add_noise(img, sigma, &mut rng);
            let patches = random_patches(&noisy, p, n_train, &mut rng);
            let kcfg = KsvdConfig { n_atoms: natoms, sparsity: 5, n_iter: 8, seed: 1 };
            // DDL baseline.
            let ddl = ksvd(&patches, &kcfg);
            let d_ddl = denoise(&noisy, &ddl.dict, p, 5, stride);
            // FAuST dictionary (Fig. 11), mid-sparsity config.
            let hcfg = HierarchicalConfig::dictionary(
                p * p,
                natoms,
                4,
                4,
                4 * p * p,
                0.5,
                (p * p * p * p) as f64,
            );
            let (fst, _) = faust_dictionary_learning(&patches, &kcfg, &hcfg);
            let d_fst = denoise(&noisy, &fst, p, 5, stride);
            // DCT baseline.
            let dct = overcomplete_dct(p, 144);
            let d_dct = denoise(&noisy, &dct, p, 5, stride);
            let (pn, pd, pf, pc) = (
                psnr(&noisy, img),
                psnr(&d_ddl, img),
                psnr(&d_fst, img),
                psnr(&d_dct, img),
            );
            table.row(&[
                name.clone(),
                format!("{sigma}"),
                fmt(pn),
                fmt(pd),
                fmt(pf),
                fst.s_tot().to_string(),
                fmt(pc),
                format!("{:+.2}", pf - pd),
                format!("{:+.2}", pc - pd),
            ]);
        }
    }
    table.print();
}
