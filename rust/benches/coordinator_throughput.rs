//! L3 serving bench: coordinator throughput/latency, batching on vs off,
//! dense vs FAμST backends.

use faust::bench_util::{fmt, Table};
use faust::coordinator::{BatchOp, Coordinator, CoordinatorConfig};
use faust::rng::Rng;
use faust::transforms::{hadamard, hadamard_faust};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn run_load(
    op_name: &str,
    ops: Vec<(String, Arc<dyn BatchOp>)>,
    max_batch: usize,
    n_workers: usize,
    requests: usize,
    dim: usize,
) -> (f64, f64, f64) {
    let coord = Coordinator::start(
        ops,
        CoordinatorConfig {
            max_batch,
            batch_timeout: Duration::from_micros(200),
            n_workers,
            queue_capacity: 8192,
        },
    );
    let client = coord.client();
    let n_threads = 4;
    let per = requests / n_threads;
    let t0 = Instant::now();
    let mut handles = vec![];
    for t in 0..n_threads {
        let c = client.clone();
        let op = op_name.to_string();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(t as u64);
            let mut pending = Vec::with_capacity(128);
            for _ in 0..per {
                loop {
                    match c.submit(&op, rng.gauss_vec(dim)) {
                        Ok(rx) => {
                            pending.push(rx);
                            break;
                        }
                        Err(_) => {
                            for rx in pending.drain(..) {
                                let _ = rx.recv();
                            }
                        }
                    }
                }
                if pending.len() >= 128 {
                    for rx in pending.drain(..) {
                        let _ = rx.recv();
                    }
                }
            }
            for rx in pending.drain(..) {
                let _ = rx.recv();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    let snap = coord.shutdown();
    (
        requests as f64 / dt,
        snap.mean_latency_us(),
        snap.mean_batch_size(),
    )
}

fn main() {
    let full = std::env::var("FAUST_BENCH_FULL").is_ok();
    let n = 256usize;
    let requests = if full { 60_000 } else { 20_000 };
    println!("# coordinator throughput — {n}x{n} operator, {requests} requests, 4 client threads\n");
    let dense = Arc::new(hadamard(n));
    let fst = Arc::new(hadamard_faust(n));
    let mut table = Table::new(&[
        "backend",
        "max_batch",
        "workers",
        "req/s",
        "mean_latency_us",
        "mean_batch",
    ]);
    for (backend, op) in [
        ("dense", dense.clone() as Arc<dyn BatchOp>),
        ("faust", fst.clone() as Arc<dyn BatchOp>),
    ] {
        for (mb, wk) in [(1usize, 1usize), (1, 4), (32, 1), (32, 4), (128, 4)] {
            let (rps, lat, batch) = run_load(
                "op",
                vec![("op".to_string(), op.clone())],
                mb,
                wk,
                requests,
                n,
            );
            table.row(&[
                backend.to_string(),
                mb.to_string(),
                wk.to_string(),
                fmt(rps),
                fmt(lat),
                fmt(batch),
            ]);
        }
    }
    table.print();
    println!("\n# expected: faust > dense at every setting; batching lifts both (spmm/matmul locality)");
}
