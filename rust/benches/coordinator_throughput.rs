//! L3 serving bench: coordinator throughput/latency across batching modes
//! — fixed batch sizes vs plan-aware adaptive sizing — on dense and FAμST
//! backends. The adaptive row derives each operator's batch width from
//! its plan's flop/byte `CostProfile` (see `coordinator::target_batch`).

use faust::bench_util::{fmt, Table};
use faust::coordinator::{
    target_batch, AdaptiveBatchConfig, BatchOp, Coordinator, CoordinatorConfig,
};
use faust::rng::Rng;
use faust::transforms::{hadamard, hadamard_faust};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn run_load(
    op_name: &str,
    ops: Vec<(String, Arc<dyn BatchOp>)>,
    cfg: CoordinatorConfig,
    requests: usize,
    dim: usize,
) -> (f64, f64, f64) {
    let coord = Coordinator::start(ops, cfg);
    let client = coord.client();
    let n_threads = 4;
    let per = requests / n_threads;
    let t0 = Instant::now();
    let mut handles = vec![];
    for t in 0..n_threads {
        let c = client.clone();
        let op = op_name.to_string();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(t as u64);
            let mut pending = Vec::with_capacity(128);
            for _ in 0..per {
                loop {
                    match c.submit(&op, rng.gauss_vec(dim)) {
                        Ok(rx) => {
                            pending.push(rx);
                            break;
                        }
                        Err(_) => {
                            for rx in pending.drain(..) {
                                let _ = rx.recv();
                            }
                        }
                    }
                }
                if pending.len() >= 128 {
                    for rx in pending.drain(..) {
                        let _ = rx.recv();
                    }
                }
            }
            for rx in pending.drain(..) {
                let _ = rx.recv();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    let snap = coord.shutdown();
    (
        requests as f64 / dt,
        snap.mean_latency_us(),
        snap.mean_batch_size(),
    )
}

fn config(mode: Mode, workers: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        max_batch: match mode {
            Mode::Fixed(b) => b,
            Mode::Adaptive => 32,
        },
        batch_timeout: Duration::from_micros(200),
        n_workers: workers,
        queue_capacity: 8192,
        adaptive: match mode {
            Mode::Fixed(_) => None,
            Mode::Adaptive => Some(AdaptiveBatchConfig::default()),
        },
        ..CoordinatorConfig::default()
    }
}

#[derive(Clone, Copy)]
enum Mode {
    Fixed(usize),
    Adaptive,
}

fn main() {
    let full = std::env::var("FAUST_BENCH_FULL").is_ok();
    let n = 256usize;
    let requests = if full { 60_000 } else { 20_000 };
    println!(
        "# coordinator throughput — {n}x{n} operator, {requests} requests, \
         4 client threads, fixed vs plan-aware adaptive batching\n"
    );
    let dense = Arc::new(hadamard(n));
    let fst = Arc::new(hadamard_faust(n));
    let acfg = AdaptiveBatchConfig::default();
    let mut table = Table::new(&[
        "backend",
        "batching",
        "workers",
        "req/s",
        "mean_latency_us",
        "mean_batch",
    ]);
    // (backend, workers) -> (best fixed rps, adaptive rps)
    let mut summary: Vec<(String, usize, f64, f64)> = Vec::new();
    for (backend, op) in [
        ("dense", dense.clone() as Arc<dyn BatchOp>),
        ("faust", fst.clone() as Arc<dyn BatchOp>),
    ] {
        let target = op
            .cost_profile()
            .map(|p| target_batch(&p, &acfg))
            .unwrap_or(0);
        for wk in [1usize, 4] {
            let mut best_fixed = 0.0f64;
            let mut adaptive_rps = 0.0f64;
            for mode in [
                Mode::Fixed(1),
                Mode::Fixed(32),
                Mode::Fixed(128),
                Mode::Adaptive,
            ] {
                let (rps, lat, batch) = run_load(
                    "op",
                    vec![("op".to_string(), op.clone())],
                    config(mode, wk),
                    requests,
                    n,
                );
                let label = match mode {
                    Mode::Fixed(b) => format!("fixed({b})"),
                    Mode::Adaptive => format!("adaptive({target})"),
                };
                match mode {
                    Mode::Fixed(_) => best_fixed = best_fixed.max(rps),
                    Mode::Adaptive => adaptive_rps = rps,
                }
                table.row(&[
                    backend.to_string(),
                    label,
                    wk.to_string(),
                    fmt(rps),
                    fmt(lat),
                    fmt(batch),
                ]);
            }
            summary.push((backend.to_string(), wk, best_fixed, adaptive_rps));
        }
    }
    table.print();
    println!("\n# adaptive vs best fixed setting (>= 1.00x within noise expected):");
    for (backend, wk, best_fixed, adaptive) in &summary {
        println!(
            "#   {backend} workers={wk}: adaptive/best-fixed = {:.2}x",
            adaptive / best_fixed.max(1e-9)
        );
    }
    println!(
        "# expected: faust > dense at every setting; adaptive matches the best\n\
         # fixed sweep point without hand-tuning, and never exceeds its arena cap"
    );
}
