//! §II-B claims measured: FAμST storage and matvec speed vs dense.
//!
//! The paper argues storage and multiplication gains of order RCG. A CSR
//! spmv chain is memory-bound, so the measured wall-clock gain is below
//! the flop gain — we report both, plus the batched (spmm) path the
//! coordinator uses, and the PJRT-compiled apply when artifacts exist.

use faust::bench_util::{fmt, time_auto, Table};
use faust::rng::Rng;
use faust::transforms::{hadamard, hadamard_faust};
use std::hint::black_box;

fn main() {
    println!("# §II-B — measured matvec speed & storage vs RCG (Hadamard family)\n");
    let mut table = Table::new(&[
        "n",
        "RCG (flops)",
        "dense_us",
        "faust_us",
        "speedup",
        "batch32_speedup",
        "dense_bytes",
        "faust_bytes",
    ]);
    for n in [64usize, 128, 256, 512, 1024] {
        let a = hadamard(n);
        let f = hadamard_faust(n);
        let mut rng = Rng::new(1);
        let x = rng.gauss_vec(n);
        let td = time_auto(30.0, || black_box(a.matvec(black_box(&x))));
        let tf = time_auto(30.0, || black_box(f.apply(black_box(&x))));
        // Batched: 32 vectors at once (coordinator path).
        let xb = faust::linalg::Mat::randn(n, 32, &mut rng);
        let tdb = time_auto(30.0, || black_box(a.matmul(black_box(&xb))));
        let tfb = time_auto(30.0, || black_box(f.apply_mat(black_box(&xb))));
        table.row(&[
            n.to_string(),
            fmt(f.rcg()),
            fmt(td.median_us()),
            fmt(tf.median_us()),
            fmt(td.median_ns / tf.median_ns),
            fmt(tdb.median_ns / tfb.median_ns),
            (n * n * 8).to_string(),
            f.storage_bytes().to_string(),
        ]);
    }
    table.print();
    println!("\n# expected: speedup grows ~ with RCG = n/(2 log2 n); spmv is memory-bound so measured < flop ratio");
}
