//! Dictionary learning: K-SVD (Aharon–Elad–Bruckstein) and the FAμST
//! dictionary-learning driver built on the hierarchical algorithm (Fig. 11).
//!
//! K-SVD is the paper's *Dense Dictionary Learning* (DDL) baseline in the
//! denoising experiment (§VI-C); the atom update uses the rank-1
//! power-iteration approximation (as in the efficient implementation [47]).
//!
//! The dense residual GEMMs and the hierarchical factorization both run
//! on the engine's [`ExecCtx`]: [`ksvd`]/[`faust_dictionary_learning`]
//! use the process-default ctx, the `_with_ctx` variants pin an explicit
//! one so training shares a serving engine's pool.

#![forbid(unsafe_code)]

use crate::engine::ExecCtx;
use crate::faust::Faust;
use crate::hierarchical::{factorize_dict_with_ctx, HierarchicalConfig};
use crate::linalg::{rank1_approx, Mat};
use crate::rng::Rng;
use crate::solvers::omp_batch;

/// Configuration for K-SVD.
#[derive(Clone, Debug)]
pub struct KsvdConfig {
    /// Number of atoms `n`.
    pub n_atoms: usize,
    /// Sparsity per training vector (OMP atoms per patch).
    pub sparsity: usize,
    /// Outer iterations (paper uses 50).
    pub n_iter: usize,
    pub seed: u64,
}

/// Result of a K-SVD run.
pub struct KsvdResult {
    /// Learned dictionary (`m × n_atoms`, unit-norm columns).
    pub dict: Mat,
    /// Final coefficients (`n_atoms × L`).
    pub gamma: Mat,
    /// Representation error `‖Y − DΓ‖_F / ‖Y‖_F` per iteration.
    pub error_trace: Vec<f64>,
}

/// Initialize a dictionary from random training columns (normalized).
pub fn init_dict_from_data(y: &Mat, n_atoms: usize, rng: &mut Rng) -> Mat {
    let l = y.cols();
    let mut d = Mat::zeros(y.rows(), n_atoms);
    let picks = if n_atoms <= l {
        rng.sample_indices(l, n_atoms)
    } else {
        (0..n_atoms).map(|i| i % l).collect()
    };
    for (a, &c) in picks.iter().enumerate() {
        let col = y.col(c);
        let n: f64 = col.iter().map(|x| x * x).sum::<f64>().sqrt();
        if n > 1e-12 {
            for i in 0..y.rows() {
                d.set(i, a, col[i] / n);
            }
        } else {
            // degenerate training column: random atom
            let g = rng.gauss_vec(y.rows());
            let gn: f64 = g.iter().map(|x| x * x).sum::<f64>().sqrt();
            for i in 0..y.rows() {
                d.set(i, a, g[i] / gn);
            }
        }
    }
    d
}

/// Run K-SVD on training data `y` (`m × L`) on the process-default
/// [`ExecCtx`].
pub fn ksvd(y: &Mat, cfg: &KsvdConfig) -> KsvdResult {
    ksvd_with_ctx(ExecCtx::global(), y, cfg)
}

/// [`ksvd`] on an explicit execution context (the `D·Γ` residual GEMMs
/// run pooled; the per-atom rank-1 updates stay serial — they are tiny).
pub fn ksvd_with_ctx(ctx: &ExecCtx, y: &Mat, cfg: &KsvdConfig) -> KsvdResult {
    let mut rng = Rng::new(cfg.seed);
    let mut dict = init_dict_from_data(y, cfg.n_atoms, &mut rng);
    let mut gamma = omp_batch(&dict, y, cfg.sparsity);
    let yn = y.fro().max(1e-300);
    let mut trace = Vec::with_capacity(cfg.n_iter);
    for _iter in 0..cfg.n_iter {
        // --- Atom-by-atom update.
        for a in 0..cfg.n_atoms {
            // Samples using atom a.
            let users: Vec<usize> = (0..gamma.cols())
                .filter(|&c| gamma.at(a, c) != 0.0)
                .collect();
            if users.is_empty() {
                // Replace a dead atom with the worst-represented sample.
                let resid = ctx.gemm(&dict, &gamma).sub(y);
                let mut worst = 0;
                let mut worst_norm = -1.0;
                for c in 0..y.cols() {
                    let n: f64 = resid.col(c).iter().map(|x| x * x).sum();
                    if n > worst_norm {
                        worst_norm = n;
                        worst = c;
                    }
                }
                let col = y.col(worst);
                let n: f64 = col.iter().map(|x| x * x).sum::<f64>().sqrt();
                if n > 1e-12 {
                    for i in 0..y.rows() {
                        dict.set(i, a, col[i] / n);
                    }
                }
                continue;
            }
            // Restricted residual E = Y_u − Σ_{b≠a} d_b γ_{b,u}.
            let mut e = Mat::zeros(y.rows(), users.len());
            for (uc, &c) in users.iter().enumerate() {
                for i in 0..y.rows() {
                    e.set(i, uc, y.at(i, c));
                }
            }
            // Subtract the contribution of all atoms except a.
            for b in 0..cfg.n_atoms {
                if b == a {
                    continue;
                }
                let db = dict.col(b);
                for (uc, &c) in users.iter().enumerate() {
                    let g = gamma.at(b, c);
                    if g == 0.0 {
                        continue;
                    }
                    for i in 0..y.rows() {
                        let v = e.at(i, uc) - db[i] * g;
                        e.set(i, uc, v);
                    }
                }
            }
            // Rank-1 approximation of E: new atom + coefficients.
            let (u, sigma, v) = rank1_approx(&e, &mut rng, 30);
            if sigma <= 1e-300 {
                continue;
            }
            dict.set_col(a, &u);
            for (uc, &c) in users.iter().enumerate() {
                gamma.set(a, c, sigma * v[uc]);
            }
        }
        // --- Sparse coding step.
        gamma = omp_batch(&dict, y, cfg.sparsity);
        trace.push(ctx.gemm(&dict, &gamma).sub(y).fro() / yn);
    }
    KsvdResult { dict, gamma, error_trace: trace }
}

/// FAμST dictionary learning (paper Fig. 10/11): run K-SVD to get an
/// initial dense dictionary, then hierarchically factorize it while
/// re-fitting to the data. Returns the FAμST dictionary and the final
/// sparse codes.
pub fn faust_dictionary_learning(
    y: &Mat,
    ksvd_cfg: &KsvdConfig,
    hier_cfg: &HierarchicalConfig,
) -> (Faust, Mat) {
    faust_dictionary_learning_with_ctx(ExecCtx::global(), y, ksvd_cfg, hier_cfg)
}

/// [`faust_dictionary_learning`] on an explicit execution context: both
/// the K-SVD warm-up and the hierarchical factorization run on `ctx`.
pub fn faust_dictionary_learning_with_ctx(
    ctx: &ExecCtx,
    y: &Mat,
    ksvd_cfg: &KsvdConfig,
    hier_cfg: &HierarchicalConfig,
) -> (Faust, Mat) {
    let base = ksvd_with_ctx(ctx, y, ksvd_cfg);
    let sparsity = ksvd_cfg.sparsity;
    let coder = move |yy: &Mat, d: &Mat| -> Mat { omp_batch(d, yy, sparsity) };
    factorize_dict_with_ctx(ctx, y, &base.dict, &base.gamma, hier_cfg, &coder)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic dictionary-learning problem: planted dictionary + k-sparse
    /// codes (+ optional noise).
    fn planted(
        m: usize,
        natoms: usize,
        l: usize,
        k: usize,
        noise: f64,
        rng: &mut Rng,
    ) -> (Mat, Mat) {
        let mut d = Mat::randn(m, natoms, rng);
        d.normalize_cols();
        let mut gamma = Mat::zeros(natoms, l);
        for c in 0..l {
            for i in rng.sample_indices(natoms, k) {
                gamma.set(i, c, rng.gauss() * 2.0);
            }
        }
        let mut y = d.matmul(&gamma);
        if noise > 0.0 {
            for v in y.data_mut() {
                *v += noise * rng.gauss();
            }
        }
        (y, d)
    }

    #[test]
    fn ksvd_reduces_error_monotonically_enough() {
        let mut rng = Rng::new(151);
        let (y, _) = planted(12, 20, 120, 3, 0.0, &mut rng);
        let cfg = KsvdConfig { n_atoms: 20, sparsity: 3, n_iter: 12, seed: 1 };
        let res = ksvd(&y, &cfg);
        let first = res.error_trace.first().unwrap();
        let last = res.error_trace.last().unwrap();
        assert!(last <= first, "error increased: {first} -> {last}");
        assert!(*last < 0.5, "final error too large: {last}");
    }

    #[test]
    fn ksvd_dictionary_atoms_unit_norm() {
        let mut rng = Rng::new(152);
        let (y, _) = planted(10, 16, 80, 2, 0.05, &mut rng);
        let cfg = KsvdConfig { n_atoms: 16, sparsity: 2, n_iter: 5, seed: 2 };
        let res = ksvd(&y, &cfg);
        for j in 0..16 {
            let n: f64 = res.dict.col(j).iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!((n - 1.0).abs() < 1e-8, "atom {j} norm {n}");
        }
    }

    #[test]
    fn ksvd_exact_on_trivial_problem() {
        // Y's columns ARE the atoms: K-SVD should fit almost exactly.
        let mut rng = Rng::new(153);
        let (y, _) = planted(8, 8, 64, 1, 0.0, &mut rng);
        let cfg = KsvdConfig { n_atoms: 8, sparsity: 1, n_iter: 15, seed: 3 };
        let res = ksvd(&y, &cfg);
        assert!(res.error_trace.last().unwrap() < &0.15);
    }

    #[test]
    fn faust_dictionary_learning_end_to_end() {
        let mut rng = Rng::new(154);
        let (y, _) = planted(8, 12, 100, 2, 0.02, &mut rng);
        let kcfg = KsvdConfig { n_atoms: 12, sparsity: 2, n_iter: 6, seed: 4 };
        let hcfg = HierarchicalConfig::dictionary(8, 12, 3, 4, 32, 0.7, 64.0);
        let (fst, gamma) = faust_dictionary_learning(&y, &kcfg, &hcfg);
        assert_eq!(fst.rows(), 8);
        assert_eq!(fst.cols(), 12);
        assert_eq!(gamma.shape(), (12, 100));
        // The FAμST dictionary should still represent the data reasonably.
        let err = fst.to_dense().matmul(&gamma).sub(&y).fro() / y.fro();
        assert!(err < 0.8, "err={err}");
        // And it should actually be cheaper than dense.
        assert!(fst.s_tot() < 8 * 12 * 3);
    }
}
