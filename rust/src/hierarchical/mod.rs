//! Hierarchical factorization (paper Fig. 5) and its dictionary-learning
//! variant (paper Fig. 11).
//!
//! The residual `T_{ℓ-1}` is repeatedly split in two by palm4MSA — one
//! sparse factor `S_ℓ` (constraint `E_ℓ`) and one less-sparse residual
//! `T_ℓ` (constraint `Ẽ_ℓ`) — followed by a *global* palm4MSA refit of all
//! factors introduced so far. The analogy with greedy layer-wise
//! pre-training + fine-tuning of deep networks is the paper's §IV-A.
//!
//! **Paper map:** Fig. 5 (the algorithm) and Fig. 11 (its
//! dictionary-learning variant) are this module; its outputs drive the
//! fig6 Hadamard recovery ([`HierarchicalConfig::hadamard`], §IV-C), the
//! fig8 MEG factorization sweep ([`HierarchicalConfig::meg`], §V) and
//! the fig12 denoising dictionaries ([`HierarchicalConfig::dictionary`],
//! §VI via [`crate::dictlearn`]).
//!
//! Every split and refit runs on the engine's
//! [`ExecCtx`](crate::engine::ExecCtx) (pooled cost-dispatched GEMMs,
//! pooled power iterations): [`factorize`]/[`factorize_traced`]/
//! [`factorize_dict`] use the process-default ctx, the `_with_ctx`
//! variants pin an explicit one (e.g. a serving engine's via
//! `ApplyEngine::ctx()`). Per-level error tracking reuses each refit's
//! cached [`PalmResult::product`](crate::palm::PalmResult::product)
//! instead of re-multiplying the factor chain. Results are bitwise
//! identical across thread counts for a fixed seed.
//!
//! **Fleets.** [`factorize_fleet`] / [`factorize_fleet_with_ctx`]
//! factorize many operators *concurrently* on one shared pool — the
//! paper's deployments hold one gain matrix per subject (§V) and one
//! dictionary per class (§VI) — batching the split/refit kernels of
//! separate members into fused cross-operator dispatches
//! ([`FleetCtx`]); members finish independently (no global barrier), so
//! a serving registry can hot-swap each operator the moment its own
//! factorization completes (`Registry::refactorize_fleet`). Fleet
//! results are bitwise identical to the same jobs run one at a time.

#![forbid(unsafe_code)]

use crate::engine::{ExecCtx, FleetCtx};
use crate::faust::Faust;
use crate::linalg::Mat;
use crate::palm::{
    palm4msa_fleet_with_ctx, palm4msa_with_ctx, FactorState, FleetProblem, PalmConfig,
};
use crate::prox::Constraint;
use crate::rng::Rng;

/// Constraints for one hierarchical level ℓ.
#[derive(Clone, Debug)]
pub struct LevelConstraints {
    /// `Ẽ_ℓ` — the residual (left factor `T_ℓ`).
    pub residual: Constraint,
    /// `E_ℓ` — the sparse right factor `S_ℓ`.
    pub factor: Constraint,
}

/// Full configuration of the hierarchical algorithm.
#[derive(Clone, Debug)]
pub struct HierarchicalConfig {
    /// Per-level constraints, `levels.len() = J - 1`.
    pub levels: Vec<LevelConstraints>,
    /// Residual shapes: `residual_dims[ℓ-1]` = shape of `T_ℓ`
    /// (the right factor's shape is inferred from the chain).
    pub residual_dims: Vec<(usize, usize)>,
    /// palm4MSA iterations for each 2-factor split (paper uses e.g. 50).
    pub n_iter_split: usize,
    /// palm4MSA iterations for each global refit.
    pub n_iter_global: usize,
    /// Skip the global refit (ablation of Fig. 5 line 5).
    pub skip_global: bool,
    /// Leave the residual unconstrained (normalization only) during the
    /// 2-factor *split* and enforce `Ẽ_ℓ` at the global refit instead.
    ///
    /// Empirically this is required for the paper's exactness results: a
    /// binding residual sparsity constraint during the split traps PALM in
    /// poor stationary points (see DESIGN.md §Deviations), while at the
    /// refit the warm start makes `Ẽ_ℓ` non-binding whenever the split
    /// found the right structure. Ignored when `skip_global` is set (the
    /// split then must enforce the budget itself).
    pub dense_split_residual: bool,
    /// Scale of the random init of the split's sparse factor. The paper's
    /// all-zeros default init is degenerate on operators with massive
    /// magnitude ties (Hadamard: every |entry| equal) — the first
    /// projection then picks an arbitrary support that PALM cannot escape.
    /// A tiny random init breaks the ties; 0 restores the paper's default.
    pub split_init_scale: f64,
    /// Step-size margin α (§III-C3).
    pub alpha: f64,
    /// RNG seed (split inits + spectral-norm power iterations).
    pub seed: u64,
}

impl HierarchicalConfig {
    /// Paper §IV-C Hadamard setting for `n = 2^N`:
    /// `J = N` factors, `Ẽ_ℓ = {‖T‖₀ ≤ n²/2^ℓ}`, `E_ℓ` butterfly-sparse
    /// (2 non-zeros per row and column — the FAμST toolbox's `splincol(2)`,
    /// whose total budget matches the paper's `‖S‖₀ ≤ 2n`).
    pub fn hadamard(n: usize) -> Self {
        assert!(n.is_power_of_two() && n >= 2, "Hadamard needs n = 2^N ≥ 2");
        let j = n.trailing_zeros() as usize;
        let levels = (1..j)
            .map(|l| LevelConstraints {
                residual: Constraint::SpRowCol(n >> l),
                factor: Constraint::SpRowCol(2),
            })
            .collect();
        HierarchicalConfig {
            levels,
            residual_dims: vec![(n, n); j - 1],
            n_iter_split: 60,
            n_iter_global: 30,
            skip_global: false,
            dense_split_residual: false,
            split_init_scale: 0.0,
            alpha: 1e-3,
            seed: 0xFA57,
        }
    }

    /// Paper §V-A MEG setting for an `m×n` operator:
    /// rightmost factor `S_1` is `m×n` with `k`-sparse columns; factors
    /// `S_2..S_J` are `m×m` with global sparsity `s`; residuals `T_ℓ` are
    /// `m×m` with geometrically decreasing sparsity `P ρ^{ℓ-1}`.
    pub fn meg(
        m: usize,
        n: usize,
        j: usize,
        k: usize,
        s: usize,
        rho: f64,
        p_cap: f64,
    ) -> Self {
        assert!(j >= 2);
        let _ = n;
        let levels = (1..j)
            .map(|l| {
                let resid_budget = ((p_cap * rho.powi(l as i32 - 1)).round() as usize)
                    .min(m * m)
                    .max(1);
                LevelConstraints {
                    residual: Constraint::SpGlobal(resid_budget),
                    factor: if l == 1 {
                        Constraint::SpCol(k)
                    } else {
                        Constraint::SpGlobal(s)
                    },
                }
            })
            .collect();
        HierarchicalConfig {
            levels,
            residual_dims: vec![(m, m); j - 1],
            n_iter_split: 50,
            n_iter_global: 50,
            skip_global: false,
            dense_split_residual: false,
            split_init_scale: 0.0,
            alpha: 1e-3,
            seed: 0xFA57,
        }
    }

    /// §V-A remark variant: global sparsity `k·n` on the rightmost factor
    /// instead of per-column (slightly better RE, but allows null columns).
    pub fn meg_global_rightmost(
        m: usize,
        n: usize,
        j: usize,
        k: usize,
        s: usize,
        rho: f64,
        p_cap: f64,
    ) -> Self {
        let mut cfg = Self::meg(m, n, j, k, s, rho, p_cap);
        cfg.levels[0].factor = Constraint::SpGlobal(k * n);
        cfg
    }

    /// Paper §VI-C dictionary setting: dictionary `D ∈ R^{m×n}`,
    /// `J` factors with `S_J..S_2 ∈ R^{m×m}`, `S_1 ∈ R^{m×n}`;
    /// `k`-sparse columns on `S_1`, global sparsity `s` elsewhere,
    /// residual budgets `P ρ^{ℓ-1}`.
    pub fn dictionary(
        m: usize,
        n: usize,
        j: usize,
        k: usize,
        s: usize,
        rho: f64,
        p_cap: f64,
    ) -> Self {
        Self::meg(m, n, j, k, s, rho, p_cap)
    }

    /// Total number of factors J.
    pub fn n_factors(&self) -> usize {
        self.levels.len() + 1
    }

    fn split_cfg(&self, level: usize, resid_shape: (usize, usize)) -> PalmConfig {
        // Residual constraint during the split: dense-normalized by
        // default (see `dense_split_residual`), or the configured `Ẽ_ℓ`
        // when the global refit is skipped.
        let resid = if self.dense_split_residual && !self.skip_global {
            Constraint::SpGlobal(resid_shape.0 * resid_shape.1)
        } else {
            self.levels[level].residual.clone()
        };
        let mut c = PalmConfig::new(
            vec![self.levels[level].factor.clone(), resid],
            self.n_iter_split,
        );
        c.alpha = self.alpha;
        c.seed = self.seed ^ (level as u64);
        c
    }

    /// Split initialization: **residual = 0, sparse factor = Id** (the
    /// FAμST toolbox convention — their factor indexing is left-to-right,
    /// so the paper's "S₁⁰ = 0" zero-initializes the *residual* side of
    /// each 2-factor split). The opposite assignment (zeroing the sparse
    /// factor) traps PALM in poor stationary points on tie-heavy operators
    /// like Hadamard — see DESIGN.md §Deviations and `bench ablations`.
    ///
    /// `split_init_scale > 0` adds a tiny random perturbation to the
    /// sparse factor (extra tie-breaking; off by default).
    fn split_init(&self, level: usize, dims: &[(usize, usize)]) -> FactorState {
        let (sr, sc) = dims[0];
        let (tr, tc) = dims[1];
        let mut s = Mat::eye(sr, sc);
        if self.split_init_scale > 0.0 {
            let mut rng = Rng::new(self.seed ^ (0xA11CE + level as u64));
            let pert = Mat::randn(sr, sc, &mut rng);
            s.axpy(self.split_init_scale, &pert);
        }
        FactorState { mats: vec![s, Mat::zeros(tr, tc)], lambda: 1.0 }
    }
}

/// Hierarchical factorization of `a` (paper Fig. 5) on the
/// process-default [`ExecCtx`]. Returns the FAμST
/// `λ · T_{J-1} S_{J-1} ⋯ S_1` with `S_J := T_{J-1}`.
///
/// ```
/// use faust::hierarchical::{factorize, HierarchicalConfig};
/// use faust::transforms::hadamard;
///
/// // Reverse-engineer the 16-point Hadamard transform (paper §IV-C).
/// let n = 16;
/// let a = hadamard(n);
/// let f = factorize(&a, &HierarchicalConfig::hadamard(n));
/// assert_eq!(f.n_factors(), 4);             // J = log2(16) butterflies
/// assert!(f.relative_error_fro(&a) < 1e-6); // exact re-factorization
/// assert!(f.rcg() > 1.5);                   // …at a real flop discount
/// ```
pub fn factorize(a: &Mat, cfg: &HierarchicalConfig) -> Faust {
    factorize_with_ctx(ExecCtx::global(), a, cfg)
}

/// [`factorize`] on an explicit execution context.
pub fn factorize_with_ctx(ctx: &ExecCtx, a: &Mat, cfg: &HierarchicalConfig) -> Faust {
    factorize_traced_with_ctx(ctx, a, cfg).0
}

/// Like [`factorize`] but also returns the relative Frobenius error after
/// each level's global refit (used by the benches).
pub fn factorize_traced(a: &Mat, cfg: &HierarchicalConfig) -> (Faust, Vec<f64>) {
    factorize_traced_with_ctx(ExecCtx::global(), a, cfg)
}

/// [`factorize_traced`] on an explicit execution context.
pub fn factorize_traced_with_ctx(
    ctx: &ExecCtx,
    a: &Mat,
    cfg: &HierarchicalConfig,
) -> (Faust, Vec<f64>) {
    let jm1 = cfg.levels.len();
    assert!(jm1 >= 1, "need at least one split level");
    let a_fro = a.fro().max(1e-300);

    // Current factorization state: S factors rightmost-first, residual T,
    // global λ.
    let mut s_factors: Vec<Mat> = Vec::with_capacity(jm1);
    let mut residual = a.clone();
    let mut lambda = 1.0;
    let mut errs = Vec::with_capacity(jm1);

    for l in 0..jm1 {
        // --- Split: T_{ℓ-1} ≈ λ' T_ℓ S_ℓ (palm4MSA, default init).
        let (rt_rows, _rt_cols) = cfg.residual_dims[l];
        let s_shape = (rt_rows.min(residual.rows()), residual.cols());
        // Chain: residual (r×c) ≈ T_ℓ (r × s_rows) * S_ℓ (s_rows × c).
        let s_rows = s_shape.0;
        let dims = vec![(s_rows, residual.cols()), (residual.rows(), s_rows)];
        let split_init = cfg.split_init(l, &dims);
        let split = palm4msa_with_ctx(
            ctx,
            &residual,
            split_init,
            &cfg.split_cfg(l, (residual.rows(), s_rows)),
        );
        let f1 = split.state.mats[0].clone(); // S_ℓ
        let mut f2 = split.state.mats[1].clone(); // T_ℓ
        f2.scale(split.state.lambda); // T_ℓ ← λ' F_2  (Fig. 5 line 4)
        s_factors.push(f1);
        residual = f2;

        // The refit's cached product (reused for the error trace below).
        let mut level_product: Option<Mat> = None;
        if !cfg.skip_global {
            // --- Global refit of {T_ℓ, S_ℓ..S_1} against A (Fig. 5 line 5),
            // init = current values.
            let mut mats = s_factors.clone();
            mats.push(residual.clone());
            let mut constraints: Vec<Constraint> = (0..=l)
                .map(|i| cfg.levels[i].factor.clone())
                .collect();
            constraints.push(cfg.levels[l].residual.clone());
            // Normalize factors into their constraint sets for a feasible
            // warm start (the split already returns feasible S/T, but the
            // λ' folding above denormalizes the residual).
            let rf = residual.fro();
            let mut init = FactorState { mats, lambda: lambda * rf.max(1e-300) };
            let last = init.mats.len() - 1;
            if rf > 0.0 {
                init.mats[last].scale(1.0 / rf);
            }
            init.lambda = {
                // optimal λ for the warm start
                let p = init.product_ctx(ctx);
                let d = p.fro2();
                if d > 0.0 {
                    a.dot(&p) / d
                } else {
                    1.0
                }
            };
            let mut gcfg = PalmConfig::new(constraints, cfg.n_iter_global);
            gcfg.alpha = cfg.alpha;
            gcfg.seed = cfg.seed ^ (0x1000 + l as u64);
            let refit = palm4msa_with_ctx(ctx, a, init, &gcfg);
            lambda = refit.state.lambda;
            let nm = refit.state.mats.len();
            s_factors = refit.state.mats[..nm - 1].to_vec();
            residual = refit.state.mats[nm - 1].clone();
            level_product = Some(refit.product);
        }

        // Track the current overall error ‖A − λ T Π S‖ / ‖A‖, reusing the
        // refit's prefix-product cache output — the pre-ctx code paid an
        // extra O(level) GEMM chain here every level (O(J²) per run).
        let err = match level_product {
            Some(p) => {
                let mut approx = p;
                approx.scale(lambda);
                approx.sub(a).fro() / a_fro
            }
            None => {
                // skip_global ablation: no refit product to reuse.
                let mut prod = s_factors[0].clone();
                for m in &s_factors[1..] {
                    prod = ctx.gemm(m, &prod);
                }
                prod = ctx.gemm(&residual, &prod);
                prod.sub(a).fro() / a_fro
            }
        };
        errs.push(err);
    }

    // S_J ← T_{J-1}.
    let mut mats = s_factors;
    mats.push(residual);
    let final_lambda = if cfg.skip_global {
        // Never refit: λ stayed folded into the residual.
        1.0
    } else {
        lambda
    };
    (Faust::from_dense_factors(&mats, final_lambda), errs)
}

/// Factorize a *fleet* of operators concurrently on the process-default
/// execution context (see [`factorize_fleet_with_ctx`]).
///
/// ```
/// use faust::hierarchical::{factorize_fleet, HierarchicalConfig};
/// use faust::transforms::hadamard;
///
/// // Two subjects' operators (paper §V holds one gain matrix per
/// // subject) factorized concurrently on one shared pool.
/// let a = hadamard(8);
/// let cfg = HierarchicalConfig::hadamard(8);
/// let fleet = factorize_fleet(&[(&a, &cfg), (&a, &cfg)]);
/// assert_eq!(fleet.len(), 2);
/// for f in &fleet {
///     assert!(f.relative_error_fro(&a) < 1e-6);
/// }
/// ```
pub fn factorize_fleet(jobs: &[(&Mat, &HierarchicalConfig)]) -> Vec<Faust> {
    factorize_fleet_with_ctx(&FleetCtx::new(ExecCtx::global().clone()), jobs)
}

/// [`factorize_fleet`] on an explicit fleet context.
pub fn factorize_fleet_with_ctx(
    fleet: &FleetCtx,
    jobs: &[(&Mat, &HierarchicalConfig)],
) -> Vec<Faust> {
    factorize_fleet_traced_with_ctx(fleet, jobs, |_, _| {})
        .into_iter()
        .map(|(f, _)| f)
        .collect()
}

/// Per-member bookkeeping of the lockstep hierarchical fleet.
struct HierMember<'a> {
    a: &'a Mat,
    cfg: &'a HierarchicalConfig,
    a_fro: f64,
    s_factors: Vec<Mat>,
    residual: Mat,
    lambda: f64,
    errs: Vec<f64>,
    finished: Option<Faust>,
}

/// Hierarchical factorization of many operators *concurrently* on one
/// shared context, with per-level error traces and an early-completion
/// hook.
///
/// Every live member advances through Fig. 5 in lockstep — 2-factor
/// split, global refit, error tracking — and the palm4MSA inner loops of
/// *separate members* batch into fused cross-operator dispatches (see
/// [`palm4msa_fleet_with_ctx`]). Members may have different shapes and
/// level counts: a member whose hierarchy is exhausted finishes early,
/// `on_done(index, &faust)` fires the moment *its* factorization
/// completes (not at a global barrier — the registry's
/// `refactorize_fleet` hot-swaps each operator from this hook while the
/// rest of the fleet keeps training), and the member drops out of all
/// later fused batches.
///
/// Results are bitwise identical to running
/// [`factorize_traced_with_ctx`] on each job independently.
pub fn factorize_fleet_traced_with_ctx(
    fleet: &FleetCtx,
    jobs: &[(&Mat, &HierarchicalConfig)],
    mut on_done: impl FnMut(usize, &Faust),
) -> Vec<(Faust, Vec<f64>)> {
    let ctx = fleet.ctx();
    let mut members: Vec<HierMember> = jobs
        .iter()
        .map(|&(a, cfg)| {
            assert!(!cfg.levels.is_empty(), "need at least one split level");
            HierMember {
                a,
                cfg,
                a_fro: a.fro().max(1e-300),
                s_factors: Vec::with_capacity(cfg.levels.len()),
                residual: a.clone(),
                lambda: 1.0,
                errs: Vec::with_capacity(cfg.levels.len()),
                finished: None,
            }
        })
        .collect();

    let max_levels = members.iter().map(|m| m.cfg.levels.len()).max().unwrap_or(0);
    for l in 0..max_levels {
        let live: Vec<usize> = (0..members.len())
            .filter(|&i| members[i].finished.is_none() && l < members[i].cfg.levels.len())
            .collect();
        if live.is_empty() {
            break;
        }

        // --- Split: T_{ℓ-1} ≈ λ' T_ℓ S_ℓ for every live member, batched
        // into one fleet palm call (Fig. 5 lines 3–4).
        {
            let mut problems: Vec<FleetProblem> = Vec::with_capacity(live.len());
            for &i in &live {
                let m = &members[i];
                let (rt_rows, _) = m.cfg.residual_dims[l];
                let s_rows = rt_rows.min(m.residual.rows());
                let dims = vec![(s_rows, m.residual.cols()), (m.residual.rows(), s_rows)];
                problems.push(FleetProblem {
                    a: &m.residual,
                    init: m.cfg.split_init(l, &dims),
                    cfg: m.cfg.split_cfg(l, (m.residual.rows(), s_rows)),
                });
            }
            let results = palm4msa_fleet_with_ctx(fleet, problems);
            for (&i, res) in live.iter().zip(results) {
                let m = &mut members[i];
                let f1 = res.state.mats[0].clone(); // S_ℓ
                let mut f2 = res.state.mats[1].clone(); // T_ℓ
                f2.scale(res.state.lambda); // T_ℓ ← λ' F_2 (Fig. 5 line 4)
                m.s_factors.push(f1);
                m.residual = f2;
            }
        }

        // --- Global refit of {T_ℓ, S_ℓ..S_1} against A (Fig. 5 line 5)
        // for members that keep it, batched likewise.
        let refitting: Vec<usize> = live
            .iter()
            .copied()
            .filter(|&i| !members[i].cfg.skip_global)
            .collect();
        let mut level_products: Vec<Option<Mat>> = members.iter().map(|_| None).collect();
        if !refitting.is_empty() {
            // Warm-start assembly per member (identical to the solo path;
            // the init-λ product chains run solo — they are one GEMM
            // chain per level vs. n_iter_global chains inside the refit).
            let mut inits: Vec<FactorState> = Vec::with_capacity(refitting.len());
            let mut gcfgs: Vec<PalmConfig> = Vec::with_capacity(refitting.len());
            for &i in &refitting {
                let m = &members[i];
                let mut mats = m.s_factors.clone();
                mats.push(m.residual.clone());
                let mut constraints: Vec<Constraint> = (0..=l)
                    .map(|k| m.cfg.levels[k].factor.clone())
                    .collect();
                constraints.push(m.cfg.levels[l].residual.clone());
                let rf = m.residual.fro();
                let mut init = FactorState { mats, lambda: m.lambda * rf.max(1e-300) };
                let last = init.mats.len() - 1;
                if rf > 0.0 {
                    init.mats[last].scale(1.0 / rf);
                }
                init.lambda = {
                    let p = init.product_ctx(ctx);
                    let d = p.fro2();
                    if d > 0.0 {
                        m.a.dot(&p) / d
                    } else {
                        1.0
                    }
                };
                let mut gcfg = PalmConfig::new(constraints, m.cfg.n_iter_global);
                gcfg.alpha = m.cfg.alpha;
                gcfg.seed = m.cfg.seed ^ (0x1000 + l as u64);
                inits.push(init);
                gcfgs.push(gcfg);
            }
            let problems: Vec<FleetProblem> = refitting
                .iter()
                .zip(inits)
                .zip(&gcfgs)
                .map(|((&i, init), gcfg)| FleetProblem {
                    a: members[i].a,
                    init,
                    cfg: gcfg.clone(),
                })
                .collect();
            let results = palm4msa_fleet_with_ctx(fleet, problems);
            for (&i, res) in refitting.iter().zip(results) {
                let m = &mut members[i];
                m.lambda = res.state.lambda;
                let nm = res.state.mats.len();
                m.s_factors = res.state.mats[..nm - 1].to_vec();
                m.residual = res.state.mats[nm - 1].clone();
                level_products[i] = Some(res.product);
            }
        }

        // --- Per-level error ‖A − λ T Π S‖ / ‖A‖, reusing each refit's
        // cached product (the skip_global ablation re-multiplies solo).
        for &i in &live {
            let m = &mut members[i];
            let err = match level_products[i].take() {
                Some(p) => {
                    let mut approx = p;
                    approx.scale(m.lambda);
                    approx.sub(m.a).fro() / m.a_fro
                }
                None => {
                    let mut prod = m.s_factors[0].clone();
                    for f in &m.s_factors[1..] {
                        prod = ctx.gemm(f, &prod);
                    }
                    prod = ctx.gemm(&m.residual, &prod);
                    prod.sub(m.a).fro() / m.a_fro
                }
            };
            m.errs.push(err);
        }

        // --- Members whose hierarchy is exhausted finish *now*: build
        // the FAμST and fire the completion hook while the rest of the
        // fleet keeps training (no global barrier).
        for &i in &live {
            if members[i].cfg.levels.len() == l + 1 {
                let m = &mut members[i];
                let mut mats = std::mem::take(&mut m.s_factors);
                mats.push(m.residual.clone());
                let final_lambda = if m.cfg.skip_global { 1.0 } else { m.lambda };
                let f = Faust::from_dense_factors(&mats, final_lambda);
                on_done(i, &f);
                m.finished = Some(f);
            }
        }
    }

    members
        .into_iter()
        .map(|m| {
            let f = m.finished.expect("every member completes its hierarchy");
            (f, m.errs)
        })
        .collect()
}

/// Sparse-coding callback used by the dictionary variant: given the data
/// `Y` and the current dictionary (dense, `m×n`), return coefficients
/// `Γ ∈ R^{n×L}`.
pub type SparseCoder<'a> = dyn Fn(&Mat, &Mat) -> Mat + 'a;

/// Hierarchical factorization for dictionary learning (paper Fig. 11).
///
/// Factorizes the initial dictionary `d0` while keeping it adapted to the
/// data `y`: each level does (i) a 2-factor split of the residual, (ii) a
/// global palm4MSA refit **against Y** with the coefficient matrix Γ frozen
/// as the rightmost factor, (iii) a coefficient update
/// `Γ ← sparse_coder(Y, D)`.
pub fn factorize_dict(
    y: &Mat,
    d0: &Mat,
    gamma0: &Mat,
    cfg: &HierarchicalConfig,
    sparse_coder: &SparseCoder,
) -> (Faust, Mat) {
    factorize_dict_with_ctx(ExecCtx::global(), y, d0, gamma0, cfg, sparse_coder)
}

/// [`factorize_dict`] on an explicit execution context.
pub fn factorize_dict_with_ctx(
    ctx: &ExecCtx,
    y: &Mat,
    d0: &Mat,
    gamma0: &Mat,
    cfg: &HierarchicalConfig,
    sparse_coder: &SparseCoder,
) -> (Faust, Mat) {
    let jm1 = cfg.levels.len();
    assert_eq!(d0.cols(), gamma0.rows(), "D/Γ shape mismatch");
    assert_eq!(d0.rows(), y.rows());
    assert_eq!(gamma0.cols(), y.cols());

    let mut s_factors: Vec<Mat> = Vec::with_capacity(jm1);
    let mut residual = d0.clone();
    let mut gamma = gamma0.clone();
    let mut lambda = 1.0;

    for l in 0..jm1 {
        // (i) split the residual (same as Fig. 5 line 3).
        let s_rows = cfg.residual_dims[l].0.min(residual.rows());
        let dims = vec![(s_rows, residual.cols()), (residual.rows(), s_rows)];
        let split = palm4msa_with_ctx(
            ctx,
            &residual,
            cfg.split_init(l, &dims),
            &cfg.split_cfg(l, (residual.rows(), s_rows)),
        );
        let f1 = split.state.mats[0].clone();
        let mut f2 = split.state.mats[1].clone();
        f2.scale(split.state.lambda);
        s_factors.push(f1);
        residual = f2;

        // (ii) global refit against Y with Γ frozen (Fig. 11 line 4):
        // Y ≈ λ T_ℓ S_ℓ ⋯ S_1 Γ.
        let mut mats = vec![gamma.clone()];
        mats.extend(s_factors.iter().cloned());
        // Normalize residual into its set for the warm start.
        let rf = residual.fro().max(1e-300);
        let mut resid_n = residual.clone();
        resid_n.scale(1.0 / rf);
        mats.push(resid_n);
        let mut constraints = vec![Constraint::Frozen];
        constraints.extend((0..=l).map(|i| cfg.levels[i].factor.clone()));
        constraints.push(cfg.levels[l].residual.clone());
        let mut init = FactorState { mats, lambda: lambda * rf };
        init.lambda = {
            let p = init.product_ctx(ctx);
            let d = p.fro2();
            if d > 0.0 {
                y.dot(&p) / d
            } else {
                1.0
            }
        };
        let mut gcfg = PalmConfig::new(constraints, cfg.n_iter_global);
        gcfg.alpha = cfg.alpha;
        gcfg.seed = cfg.seed ^ (0x2000 + l as u64);
        let refit = palm4msa_with_ctx(ctx, y, init, &gcfg);
        lambda = refit.state.lambda;
        let nm = refit.state.mats.len();
        s_factors = refit.state.mats[1..nm - 1].to_vec();
        residual = refit.state.mats[nm - 1].clone();

        // (iii) coefficient update (Fig. 11 line 5): Γ = sparseCoding(Y, D).
        // The refit's cached product is D·Γ (Γ rides frozen as the
        // rightmost factor), so the dictionary itself still needs its own
        // chain — multiplied on the ctx pool.
        let mut dict = s_factors[0].clone();
        for m in &s_factors[1..] {
            dict = ctx.gemm(m, &dict);
        }
        dict = ctx.gemm(&residual, &dict);
        dict.scale(lambda);
        gamma = sparse_coder(y, &dict);
    }

    let mut mats = s_factors;
    mats.push(residual);
    (Faust::from_dense_factors(&mats, lambda), gamma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::transforms::hadamard;

    #[test]
    fn hadamard_16_is_reverse_engineered_exactly() {
        let n = 16;
        let a = hadamard(n);
        let cfg = HierarchicalConfig::hadamard(n);
        let (fst, errs) = factorize_traced(&a, &cfg);
        assert_eq!(fst.n_factors(), 4);
        let rel = fst.relative_error_fro(&a);
        assert!(rel < 1e-6, "Hadamard-16 not exact: rel={rel}, trace={errs:?}");
        // Complexity matches the butterfly: each factor ≤ 2n nnz.
        for f in fst.factors() {
            assert!(f.nnz() <= 2 * n);
        }
        assert!(fst.rcg() >= n as f64 / (2.0 * (n as f64).log2()) * 0.99);
    }

    #[test]
    fn config_constructors_have_expected_budgets() {
        let cfg = HierarchicalConfig::hadamard(32);
        assert_eq!(cfg.n_factors(), 5);
        assert_eq!(cfg.levels[0].residual, Constraint::SpRowCol(16));
        assert_eq!(cfg.levels[0].factor, Constraint::SpRowCol(2));
        // Residual row-budgets halve per level (n/2^ℓ).
        assert_eq!(cfg.levels[3].residual, Constraint::SpRowCol(2));

        let mcfg = HierarchicalConfig::meg(204, 8193, 4, 10, 408, 0.8, 0.7 * 204.0 * 204.0);
        assert_eq!(mcfg.n_factors(), 4);
        assert_eq!(mcfg.levels[0].factor, Constraint::SpCol(10));
        assert_eq!(mcfg.levels[1].factor, Constraint::SpGlobal(408));
        // Residual budgets decrease geometrically (P below the m² cap).
        let b = |c: &Constraint| match c {
            Constraint::SpGlobal(s) => *s,
            _ => panic!(),
        };
        assert!(b(&mcfg.levels[1].residual) < b(&mcfg.levels[0].residual));
    }

    #[test]
    fn error_trace_is_reported_per_level() {
        let a = hadamard(8);
        let cfg = HierarchicalConfig::hadamard(8);
        let (_, errs) = factorize_traced(&a, &cfg);
        assert_eq!(errs.len(), cfg.levels.len());
        assert!(errs.last().unwrap() < &1e-6);
    }

    #[test]
    fn random_matrix_factorization_controls_error() {
        // Dense random 16x16 with generous budgets: error should be small
        // but nonzero; RCG > 1.
        let mut rng = Rng::new(101);
        let a = Mat::randn(16, 16, &mut rng);
        // Budgets must sum below 16² = 256 for RCG > 1:
        // S₁ ≤ 6·16 = 96, S₂ ≤ 48, T₂ ≤ 80·0.8 = 64 → ≤ 208.
        let cfg = HierarchicalConfig::meg(16, 16, 3, 6, 48, 0.8, 80.0);
        let fst = factorize(&a, &cfg);
        let rel = fst.relative_error_fro(&a);
        assert!(rel < 0.95, "rel={rel}");
        assert!(fst.rcg() > 1.0, "rcg={} s_tot={}", fst.rcg(), fst.s_tot());
    }

    #[test]
    fn skip_global_ablation_is_worse_or_equal() {
        let a = hadamard(16);
        let mut cfg = HierarchicalConfig::hadamard(16);
        cfg.seed = 7;
        let with_global = factorize(&a, &cfg).relative_error_fro(&a);
        cfg.skip_global = true;
        let without = factorize(&a, &cfg).relative_error_fro(&a);
        assert!(
            with_global <= without + 1e-9,
            "global refit hurt: with={with_global} without={without}"
        );
    }

    #[test]
    fn fleet_factorization_matches_solo_runs_bitwise() {
        use crate::testutil::faust_fingerprint;
        // Ragged fleet: different sizes, level counts and seeds — each
        // member must reproduce its solo run bit for bit, and members
        // with shorter hierarchies must finish early.
        let h8 = hadamard(8);
        let h16 = hadamard(16);
        let mut rng = Rng::new(77);
        let r12 = Mat::randn(12, 12, &mut rng);
        let cfg8 = HierarchicalConfig::hadamard(8);
        let mut cfg16 = HierarchicalConfig::hadamard(16);
        cfg16.seed = 99;
        let mut cfgr = HierarchicalConfig::meg(12, 12, 3, 4, 30, 0.8, 60.0);
        cfgr.n_iter_split = 12;
        cfgr.n_iter_global = 6;
        let jobs: Vec<(&Mat, &HierarchicalConfig)> =
            vec![(&h8, &cfg8), (&h16, &cfg16), (&r12, &cfgr)];
        let ctx = ExecCtx::new(4);
        let solo: Vec<(Faust, Vec<f64>)> = jobs
            .iter()
            .map(|&(a, cfg)| factorize_traced_with_ctx(&ctx, a, cfg))
            .collect();
        let fleet = FleetCtx::new(ctx);
        let mut done_order: Vec<usize> = vec![];
        let got = factorize_fleet_traced_with_ctx(&fleet, &jobs, |i, f| {
            // The hook fires with the finished operator, usable at once.
            assert!(f.rows() > 0);
            done_order.push(i);
        });
        assert_eq!(done_order.len(), 3, "every member completes exactly once");
        // The 2-level member (J=3 hadamard-8… levels=2) finishes before
        // the 3-level hadamard-16 member — completion is per-member, not
        // a global barrier.
        let pos8 = done_order.iter().position(|&i| i == 0).unwrap();
        let pos16 = done_order.iter().position(|&i| i == 1).unwrap();
        assert!(pos8 < pos16, "shorter hierarchy must finish first");
        for ((gf, ge), (wf, we)) in got.iter().zip(&solo) {
            assert_eq!(faust_fingerprint(gf), faust_fingerprint(wf));
            assert_eq!(ge.len(), we.len());
            for (x, y) in ge.iter().zip(we) {
                assert_eq!(x.to_bits(), y.to_bits(), "error trace diverged");
            }
        }
        // The fleet actually fused cross-operator work.
        assert!(fleet.metrics().fused_gemms > 0, "no cross-operator fusion happened");
    }

    #[test]
    fn fleet_skip_global_member_rides_along() {
        let a = hadamard(8);
        let mut cfg_skip = HierarchicalConfig::hadamard(8);
        cfg_skip.skip_global = true;
        let cfg_full = HierarchicalConfig::hadamard(8);
        let ctx = ExecCtx::new(2);
        let solo_skip = factorize_with_ctx(&ctx, &a, &cfg_skip);
        let solo_full = factorize_with_ctx(&ctx, &a, &cfg_full);
        let fleet = FleetCtx::new(ctx);
        let got = factorize_fleet_with_ctx(&fleet, &[(&a, &cfg_skip), (&a, &cfg_full)]);
        use crate::testutil::faust_fingerprint;
        assert_eq!(faust_fingerprint(&got[0]), faust_fingerprint(&solo_skip));
        assert_eq!(faust_fingerprint(&got[1]), faust_fingerprint(&solo_full));
    }

    #[test]
    fn dictionary_variant_runs_and_fits() {
        let mut rng = Rng::new(103);
        // Tiny synthetic dictionary-learning problem.
        let m = 8;
        let natoms = 12;
        let nsamples = 40;
        let d0 = {
            let mut d = Mat::randn(m, natoms, &mut rng);
            d.normalize_cols();
            d
        };
        // 2-sparse codes.
        let mut gamma0 = Mat::zeros(natoms, nsamples);
        for j in 0..nsamples {
            for i in rng.sample_indices(natoms, 2) {
                gamma0.set(i, j, rng.gauss());
            }
        }
        let y = d0.matmul(&gamma0);
        let cfg = HierarchicalConfig::dictionary(m, natoms, 3, 4, 2 * m * 2, 0.7, (m * m) as f64);
        let coder = |y: &Mat, d: &Mat| -> Mat {
            crate::solvers::omp_batch(d, y, 2)
        };
        let (fst, gamma) = factorize_dict(&y, &d0, &gamma0, &cfg, &coder);
        assert_eq!(fst.rows(), m);
        assert_eq!(fst.cols(), natoms);
        assert_eq!(gamma.shape(), (natoms, nsamples));
        // The factorized dictionary with refreshed codes should still
        // explain a decent part of Y.
        let resid = fst.to_dense().matmul(&gamma).sub(&y).fro() / y.fro();
        assert!(resid < 0.9, "resid={resid}");
    }
}
