//! The FAμST operator type: `A ≈ λ · S_J ⋯ S_1`.
//!
//! Factors are stored sparse (CSR) right-to-left as in the paper
//! (`factors[0] = S_1` applies first to the input). Apply and transpose
//! apply cost `O(s_tot)`; [`Faust::rc`]/[`Faust::rcg`] implement the
//! paper's Definition II.1.
//!
//! Every apply path routes through the [`crate::engine`] subsystem: a
//! cost-modeled [`ApplyPlan`] is compiled lazily on first use and cached
//! (factors are immutable after construction, so the cache never goes
//! stale), kernels run on the process-wide engine pool, and scratch comes
//! from a per-thread ping-pong [`Arena`](crate::engine::Arena) —
//! steady-state applies allocate only their output buffer.
//!
//! **Paper map:** §II defines the operator and its RC/RCG metrics; a
//! `Faust` is the object every experiment produces and consumes — the
//! fig6 Hadamard refactorization (§IV-C), the fig8 MEG gain surrogate
//! (§V, served through [`crate::coordinator`]), and the fig12 denoising
//! dictionary (§VI, via [`crate::dictlearn`]).

#![forbid(unsafe_code)]

use crate::engine::{self, ApplyPlan, F32Bound, PlanConfig};
use crate::linalg::{spectral_norm_iter, Mat};
use crate::rng::Rng;
use crate::sparse::{Coo, Csr};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;
use std::sync::{Arc, OnceLock};

/// Multi-layer sparse operator `λ · S_J ⋯ S_1 ∈ R^{m×n}`.
#[derive(Clone, Debug)]
pub struct Faust {
    /// Sparse factors, rightmost first: `factors[0] = S_1 (a_2×a_1)`,
    /// `factors[J-1] = S_J (m×a_J)`. Stored behind `Arc` so compiled
    /// plans alias the same CSR buffers for unfused sparse stages instead
    /// of holding a second copy of every factor (MEG-scale operators used
    /// to pay ~2× factor memory per plan).
    factors: Vec<Arc<Csr>>,
    /// Global scale λ.
    lambda: f64,
    /// Lazily-compiled engine plan shared by all apply paths.
    plan: OnceLock<Arc<ApplyPlan>>,
    /// Lazily-quantized f32 serving plan + its probe-calibrated error
    /// bound (ROADMAP item j). Factors quantize exactly once per
    /// operator; factorization itself never touches f32.
    plan_f32: OnceLock<(Arc<ApplyPlan<f32>>, F32Bound)>,
}

impl Faust {
    /// Build from rightmost-first sparse factors and a scale.
    pub fn new(factors: Vec<Csr>, lambda: f64) -> Self {
        Self::from_shared(factors.into_iter().map(Arc::new).collect(), lambda)
    }

    /// Build from already-shared factors without copying — the dual of
    /// [`Faust::factors`] for callers that assemble operators from
    /// existing `Arc<Csr>` handles.
    pub fn from_shared(factors: Vec<Arc<Csr>>, lambda: f64) -> Self {
        assert!(!factors.is_empty(), "FAuST needs at least one factor");
        for w in factors.windows(2) {
            assert_eq!(
                w[1].cols(),
                w[0].rows(),
                "factor chain dimension mismatch"
            );
        }
        Faust { factors, lambda, plan: OnceLock::new(), plan_f32: OnceLock::new() }
    }

    /// The compiled execution plan (built on first use, then cached).
    pub fn plan(&self) -> Arc<ApplyPlan> {
        self.plan
            .get_or_init(|| Arc::new(ApplyPlan::compile(self, &PlanConfig::default())))
            .clone()
    }

    /// The quantized f32 serving plan and its calibrated error bound,
    /// derived from [`Faust::plan`] on first use and cached — repeated
    /// epoch swaps of the same operator never re-quantize.
    pub fn plan_f32(&self) -> (Arc<ApplyPlan<f32>>, F32Bound) {
        self.plan_f32
            .get_or_init(|| {
                let (p, b) = self.plan().to_f32_with_bound(engine::global().pool());
                (Arc::new(p), b)
            })
            .clone()
    }

    /// Install a previously-measured f32 bound (from a [`crate::store`]
    /// snapshot) so the first f32 serving request never re-probes:
    /// quantizes the factors now and seeds the [`Faust::plan_f32`] cache
    /// with `bound`. No-op if the f32 plan was already built. The probe
    /// itself is deterministic (fixed seed, thread-invariant kernels), so
    /// a stale bound cannot arise — this only skips the probe work.
    pub fn preload_f32_bound(&self, bound: F32Bound) {
        let _ = self.plan_f32.set((Arc::new(self.plan().to_f32()), bound));
    }

    /// Build from dense factors, sparsifying exact zeros.
    pub fn from_dense_factors(factors: &[Mat], lambda: f64) -> Self {
        Self::new(
            factors.iter().map(|m| Csr::from_dense(m, 0.0)).collect(),
            lambda,
        )
    }

    /// Trivial single-factor FAμST wrapping a dense matrix (RC = density).
    pub fn from_dense(a: &Mat) -> Self {
        Self::new(vec![Csr::from_dense(a, 0.0)], 1.0)
    }

    /// Number of factors `J`.
    pub fn n_factors(&self) -> usize {
        self.factors.len()
    }

    /// The factors, rightmost (applied first) first. Shared handles:
    /// unfused sparse plan stages alias these same allocations.
    pub fn factors(&self) -> &[Arc<Csr>] {
        &self.factors
    }

    /// Scale λ.
    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    /// Output dimension `m`.
    pub fn rows(&self) -> usize {
        self.factors.last().unwrap().rows()
    }

    /// Input dimension `n`.
    pub fn cols(&self) -> usize {
        self.factors[0].cols()
    }

    /// Total non-zeros `s_tot` across factors.
    pub fn s_tot(&self) -> usize {
        self.factors.iter().map(|f| f.nnz()).sum()
    }

    /// Relative Complexity (Definition II.1): `s_tot / (m·n)` — the paper
    /// normalizes by `‖A‖₀` of the dense operator, i.e. `m·n` for generic
    /// dense `A`.
    pub fn rc(&self) -> f64 {
        self.s_tot() as f64 / (self.rows() * self.cols()) as f64
    }

    /// Relative Complexity Gain `RCG = 1 / RC`.
    pub fn rcg(&self) -> f64 {
        1.0 / self.rc()
    }

    /// Flops for one matvec (2 per stored non-zero).
    pub fn flops_per_matvec(&self) -> usize {
        self.factors.iter().map(|f| f.flops_per_matvec()).sum()
    }

    /// COO storage bytes across all factors (§II-B1).
    pub fn storage_bytes(&self) -> usize {
        self.factors
            .iter()
            .map(|f| f.to_coo().storage_bytes())
            .sum::<usize>()
            + 8 // λ
            + 4 * (self.n_factors() + 1) // the a_1..a_{J+1} sizes
    }

    /// Apply: `y = λ S_J ⋯ S_1 x` in `O(s_tot)`, through the cached
    /// engine plan (fusion + per-factor strategy) with per-thread
    /// ping-pong scratch — only the output vector is allocated.
    ///
    /// ```
    /// use faust::transforms::{hadamard, hadamard_faust};
    ///
    /// let n = 16;
    /// let f = hadamard_faust(n); // butterfly FAμST: 2n nnz per factor
    /// let x: Vec<f64> = (0..n).map(|i| i as f64).collect();
    /// let y = f.apply(&x);                  // O(2n·log n) flops
    /// let want = hadamard(n).matvec(&x);    // O(n²) reference
    /// for i in 0..n {
    ///     assert!((y[i] - want[i]).abs() < 1e-12);
    /// }
    /// assert!(f.rcg() > 1.0); // the speedup the paper's RCG predicts
    /// ```
    pub fn apply(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols(), "faust apply dim mismatch");
        let plan = self.plan();
        let mut y = vec![0.0; self.rows()];
        engine::with_thread_arena(|arena| {
            plan.execute_into(engine::global().pool(), arena, x, &mut y);
        });
        y
    }

    /// Transpose apply: `y = λ S_1ᵀ ⋯ S_Jᵀ x` (pre-transposed plan chain).
    pub fn apply_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows(), "faust apply_t dim mismatch");
        let plan = self.plan();
        let mut y = vec![0.0; self.cols()];
        engine::with_thread_arena(|arena| {
            plan.execute_t_into(engine::global().pool(), arena, x, &mut y);
        });
        y
    }

    /// Batched apply: `Y = λ S_J ⋯ S_1 X` with `X ∈ R^{n×b}` column-batch.
    pub fn apply_mat(&self, x: &Mat) -> Mat {
        assert_eq!(x.rows(), self.cols(), "faust apply_mat dim mismatch");
        let plan = self.plan();
        let mut out = Mat::zeros(self.rows(), x.cols());
        engine::with_thread_arena(|arena| {
            plan.execute_batch_into(
                engine::global().pool(),
                arena,
                x.data(),
                x.cols(),
                out.data_mut(),
            );
        });
        out
    }

    /// Batched transpose apply.
    pub fn apply_t_mat(&self, x: &Mat) -> Mat {
        assert_eq!(x.rows(), self.rows(), "faust apply_t_mat dim mismatch");
        let plan = self.plan();
        let mut out = Mat::zeros(self.cols(), x.cols());
        engine::with_thread_arena(|arena| {
            plan.execute_t_batch_into(
                engine::global().pool(),
                arena,
                x.data(),
                x.cols(),
                out.data_mut(),
            );
        });
        out
    }

    /// Reference batched apply: one serial CSR spmm per factor with a
    /// fresh allocation each layer — the seed's pre-engine hot path, kept
    /// as the baseline the engine benches and `faust engine` measure
    /// against (never compiles or consults a plan).
    pub fn apply_mat_naive(&self, x: &Mat) -> Mat {
        assert_eq!(x.rows(), self.cols(), "faust apply_mat_naive dim mismatch");
        let mut cur = self.factors[0].spmm(x);
        for f in &self.factors[1..] {
            cur = f.spmm(&cur);
        }
        cur.scale(self.lambda);
        cur
    }

    /// Densify: `λ S_J ⋯ S_1` as a dense matrix.
    pub fn to_dense(&self) -> Mat {
        let mut acc = self.factors[0].to_dense();
        for f in &self.factors[1..] {
            acc = f.spmm(&acc);
        }
        acc.scale(self.lambda);
        acc
    }

    /// Relative Frobenius approximation error vs a reference operator.
    pub fn relative_error_fro(&self, a: &Mat) -> f64 {
        self.to_dense().rel_fro_err(a)
    }

    /// Relative spectral-norm error `‖A − Â‖₂ / ‖A‖₂` (the paper's RE, (6)),
    /// estimated by power iteration.
    pub fn relative_error_spectral(&self, a: &Mat, rng: &mut Rng) -> f64 {
        let diff = a.sub(&self.to_dense());
        let num = spectral_norm_iter(&diff, rng, 120, 1e-9);
        let den = spectral_norm_iter(a, rng, 120, 1e-9);
        num / den.max(1e-300)
    }

    /// Column `j` of the (scaled) dense operator, in `O(s_tot)` — used by
    /// OMP to fetch atoms lazily without densifying.
    pub fn column(&self, j: usize) -> Vec<f64> {
        let mut e = vec![0.0; self.cols()];
        e[j] = 1.0;
        self.apply(&e)
    }

    /// Serialize to a simple line-oriented text format.
    ///
    /// Format: header `FAUST v1 <J> <lambda>`, then per factor a line
    /// `FACTOR <rows> <cols> <nnz>` followed by `nnz` lines `i j v`.
    pub fn save(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let f = std::fs::File::create(path)?;
        let mut w = BufWriter::new(f);
        writeln!(w, "FAUST v1 {} {:.17e}", self.n_factors(), self.lambda)?;
        for fac in &self.factors {
            let coo = fac.to_coo();
            writeln!(w, "FACTOR {} {} {}", fac.rows(), fac.cols(), coo.nnz())?;
            for k in 0..coo.nnz() {
                writeln!(
                    w,
                    "{} {} {:.17e}",
                    coo.row_idx[k], coo.col_idx[k], coo.vals[k]
                )?;
            }
        }
        Ok(())
    }

    /// Load from the [`Faust::save`] format.
    pub fn load(path: impl AsRef<Path>) -> std::io::Result<Self> {
        let f = std::fs::File::open(path)?;
        let mut lines = BufReader::new(f).lines();
        let header = lines
            .next()
            .ok_or_else(|| bad("empty file"))??;
        let hp: Vec<&str> = header.split_whitespace().collect();
        if hp.len() != 4 || hp[0] != "FAUST" || hp[1] != "v1" {
            return Err(bad("bad header"));
        }
        let nfac: usize = hp[2].parse().map_err(|_| bad("bad J"))?;
        let lambda: f64 = hp[3].parse().map_err(|_| bad("bad lambda"))?;
        let mut factors = Vec::with_capacity(nfac);
        for _ in 0..nfac {
            let fl = lines.next().ok_or_else(|| bad("missing factor"))??;
            let fp: Vec<&str> = fl.split_whitespace().collect();
            if fp.len() != 4 || fp[0] != "FACTOR" {
                return Err(bad("bad factor header"));
            }
            let rows: usize = fp[1].parse().map_err(|_| bad("rows"))?;
            let cols: usize = fp[2].parse().map_err(|_| bad("cols"))?;
            let nnz: usize = fp[3].parse().map_err(|_| bad("nnz"))?;
            let mut coo = Coo::new(rows, cols);
            for _ in 0..nnz {
                let el = lines.next().ok_or_else(|| bad("missing entry"))??;
                let ep: Vec<&str> = el.split_whitespace().collect();
                if ep.len() != 3 {
                    return Err(bad("bad entry"));
                }
                coo.push(
                    ep[0].parse().map_err(|_| bad("i"))?,
                    ep[1].parse().map_err(|_| bad("j"))?,
                    ep[2].parse().map_err(|_| bad("v"))?,
                );
            }
            factors.push(Csr::from_coo(&coo));
        }
        Ok(Faust::new(factors, lambda))
    }
}

fn bad(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, format!("faust load: {msg}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_faust(rng: &mut Rng) -> (Faust, Mat) {
        // 3-factor chain 6×8 = (6×4)(4×4)(4×8) with sparse-ish factors.
        let mk = |r: usize, c: usize, nnz: usize, rng: &mut Rng| {
            let mut m = Mat::zeros(r, c);
            for i in rng.sample_indices(r * c, nnz) {
                m.data_mut()[i] = rng.gauss();
            }
            m
        };
        let s1 = mk(4, 8, 12, rng);
        let s2 = mk(4, 4, 8, rng);
        let s3 = mk(6, 4, 10, rng);
        let lambda = 1.7;
        let dense = s3.matmul(&s2).matmul(&s1).scaled(lambda);
        (Faust::from_dense_factors(&[s1, s2, s3], lambda), dense)
    }

    #[test]
    fn apply_matches_dense() {
        let mut rng = Rng::new(81);
        let (f, dense) = small_faust(&mut rng);
        assert_eq!(f.rows(), 6);
        assert_eq!(f.cols(), 8);
        let x = rng.gauss_vec(8);
        let y1 = f.apply(&x);
        let y2 = dense.matvec(&x);
        for i in 0..6 {
            assert!((y1[i] - y2[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn apply_t_matches_dense_transpose() {
        let mut rng = Rng::new(82);
        let (f, dense) = small_faust(&mut rng);
        let x = rng.gauss_vec(6);
        let y1 = f.apply_t(&x);
        let y2 = dense.matvec_t(&x);
        for i in 0..8 {
            assert!((y1[i] - y2[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn batched_apply_matches_vector_apply() {
        let mut rng = Rng::new(83);
        let (f, _) = small_faust(&mut rng);
        let x = Mat::randn(8, 5, &mut rng);
        let y = f.apply_mat(&x);
        for j in 0..5 {
            let xv = x.col(j);
            let yv = f.apply(&xv);
            for i in 0..6 {
                assert!((y.at(i, j) - yv[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn to_dense_matches_chain() {
        let mut rng = Rng::new(84);
        let (f, dense) = small_faust(&mut rng);
        assert!(f.to_dense().rel_fro_err(&dense) < 1e-13);
        assert!(f.relative_error_fro(&dense) < 1e-13);
    }

    #[test]
    fn rc_accounting() {
        let mut rng = Rng::new(85);
        let (f, _) = small_faust(&mut rng);
        assert_eq!(f.s_tot(), 30);
        let rc = 30.0 / 48.0;
        assert!((f.rc() - rc).abs() < 1e-15);
        assert!((f.rcg() - 1.0 / rc).abs() < 1e-12);
        assert_eq!(f.flops_per_matvec(), 60);
    }

    #[test]
    fn column_extraction() {
        let mut rng = Rng::new(86);
        let (f, dense) = small_faust(&mut rng);
        for j in [0usize, 3, 7] {
            let c = f.column(j);
            for i in 0..6 {
                assert!((c[i] - dense.at(i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Rng::new(87);
        let (f, dense) = small_faust(&mut rng);
        let dir = std::env::temp_dir().join("faust_test_save");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("op.faust");
        f.save(&path).unwrap();
        let g = Faust::load(&path).unwrap();
        assert_eq!(g.n_factors(), f.n_factors());
        assert!((g.lambda() - f.lambda()).abs() < 1e-15);
        assert!(g.to_dense().rel_fro_err(&dense) < 1e-12);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn spectral_error_zero_for_exact() {
        let mut rng = Rng::new(88);
        let (f, dense) = small_faust(&mut rng);
        let re = f.relative_error_spectral(&dense, &mut rng);
        assert!(re < 1e-7, "re={re}");
    }

    #[test]
    fn naive_and_planned_batched_apply_agree() {
        let mut rng = Rng::new(90);
        let (f, dense) = small_faust(&mut rng);
        let x = Mat::randn(8, 4, &mut rng);
        let planned = f.apply_mat(&x);
        let naive = f.apply_mat_naive(&x);
        assert!(planned.rel_fro_err(&naive) < 1e-12);
        assert!(naive.rel_fro_err(&dense.matmul(&x)) < 1e-12);
    }

    #[test]
    fn plan_is_cached_and_shared() {
        let mut rng = Rng::new(89);
        let (f, dense) = small_faust(&mut rng);
        let p1 = f.plan();
        let p2 = f.plan();
        assert!(Arc::ptr_eq(&p1, &p2), "plan must be compiled once");
        // A clone keeps a working (possibly shared) plan.
        let g = f.clone();
        let x = rng.gauss_vec(8);
        let y1 = g.apply(&x);
        let y2 = dense.matvec(&x);
        for i in 0..6 {
            assert!((y1[i] - y2[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn from_dense_factors_never_counts_zeros() {
        // Regression: explicitly-stored zeros must not inflate nnz and
        // thereby corrupt the RC/RCG metrics (Definition II.1).
        let m = Mat::from_vec(2, 2, vec![1.0, 0.0, -0.0, 3.0]);
        let f = Faust::from_dense_factors(std::slice::from_ref(&m), 2.0);
        assert_eq!(f.s_tot(), 2);
        assert!((f.rc() - 0.5).abs() < 1e-15);
        assert!((f.rcg() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn load_drops_explicit_zero_entries() {
        // A serialized operator carrying explicit `0.0` entries must not
        // come back with inflated s_tot / deflated RCG.
        let dir = std::env::temp_dir().join("faust_test_zero_load");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("zeros.faust");
        std::fs::write(
            &path,
            "FAUST v1 1 1.0\nFACTOR 2 2 3\n0 0 1.0\n0 1 0.0\n1 1 2.0\n",
        )
        .unwrap();
        let f = Faust::load(&path).unwrap();
        assert_eq!(f.s_tot(), 2, "explicit zero survived load");
        let y = f.apply(&[1.0, 1.0]);
        assert!((y[0] - 1.0).abs() < 1e-15);
        assert!((y[1] - 2.0).abs() < 1e-15);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    #[should_panic(expected = "dimension mismatch")]
    fn mismatched_chain_panics() {
        let a = Csr::from_dense(&Mat::eye(3, 4), 0.0);
        let b = Csr::from_dense(&Mat::eye(5, 5), 0.0);
        let _ = Faust::new(vec![a, b], 1.0);
    }
}
