//! palm4MSA — PALM for Multi-layer Sparse Approximation (paper Fig. 4).
//!
//! Minimizes `½‖A − λ S_J ⋯ S_1‖_F² + Σ δ_{E_j}(S_j)` by alternating
//! projected-gradient steps on each factor (step size from the Lipschitz
//! modulus `λ² ‖L‖₂² ‖R‖₂²`, Appendix B) and a closed-form update of λ.
//! Convergence to a stationary point follows from Bolte–Sabach–Teboulle's
//! PALM theory (§III-B conditions (i)–(v); indicator penalties of the
//! semi-algebraic sets of Appendix A).
//!
//! Execution runs on the engine's [`ExecCtx`]: every GEMM in the sweep is
//! cost-dispatched (serial / row-parallel / transpose-rewrite) on the
//! shared thread pool, and the per-factor Lipschitz moduli come from
//! pooled power iterations. Zero-config callers get the process-default
//! ctx through [`palm4msa`]; [`palm4msa_with_ctx`] pins an explicit one
//! (e.g. a serving engine's, via `ApplyEngine::ctx()`). All ctx kernels
//! are bitwise thread-invariant, so a fixed seed reproduces identical
//! factors at any thread count.
//!
//! **Paper map:** Fig. 4 is this module; every experiment bottoms out
//! here through [`crate::hierarchical`] — fig6 (Hadamard §IV-C), fig8
//! (MEG §V) and fig12 (denoising dictionaries §VI) are hierarchies of
//! palm4MSA splits and refits.
//!
//! Partial products are managed by a per-sweep prefix-product cache
//! (the private `SweepCache`): the fixed side's suffix products are built once per
//! sweep, the moving side grows incrementally with each updated factor,
//! and the full updated product falls out of the sweep for free — the λ
//! update, the objective, and callers (via [`PalmResult::product`]) all
//! reuse it instead of re-multiplying the chain.

use crate::engine::ExecCtx;
use crate::faust::Faust;
use crate::linalg::Mat;
use crate::prox::Constraint;

/// Configuration for one palm4MSA run.
#[derive(Clone, Debug)]
pub struct PalmConfig {
    /// Constraint set per factor, **rightmost first** (`constraints[0]` is
    /// `E` for `S_1`).
    pub constraints: Vec<Constraint>,
    /// Number of outer iterations (the paper's stopping criterion).
    pub n_iter: usize,
    /// Step-size margin: `c_j = (1+alpha) λ² ‖L‖₂² ‖R‖₂²` (§III-C3 uses
    /// `alpha = 1e-3`).
    pub alpha: f64,
    /// Early stop when the relative objective decrease falls below this
    /// (0 disables early stopping — the paper uses a fixed iteration count).
    pub rel_tol: f64,
    /// Seed for the power-iteration starting vectors.
    pub seed: u64,
    /// Factor update order within a sweep. The paper's Fig. 4 sweeps
    /// `j = 1..J` (right to left in the product `S_J ⋯ S_1`); the FAμST
    /// reference implementation defaults to the opposite
    /// (`is_update_way_R2L = false`, i.e. leftmost first).
    pub update_order: UpdateOrder,
}

/// Gauss–Seidel sweep direction over the factors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateOrder {
    /// `S_1` first (paper Fig. 4).
    RightToLeft,
    /// `S_J` first (FAμST toolbox default).
    LeftToRight,
}

impl PalmConfig {
    /// Paper defaults: `alpha = 1e-3`, fixed iteration count.
    pub fn new(constraints: Vec<Constraint>, n_iter: usize) -> Self {
        PalmConfig {
            constraints,
            n_iter,
            alpha: 1e-3,
            rel_tol: 0.0,
            seed: 0x5EED,
            update_order: UpdateOrder::RightToLeft,
        }
    }
}

/// The block of variables PALM optimizes: factors (rightmost first) + λ.
#[derive(Clone, Debug)]
pub struct FactorState {
    /// `mats[0] = S_1` … `mats[J-1] = S_J`.
    pub mats: Vec<Mat>,
    pub lambda: f64,
}

impl FactorState {
    /// Paper §III-C3 default init: `λ=1`, `S_1 = 0`, `S_j = Id` for `j≥2`,
    /// for the factor shapes `dims[j] = (a_{j+1}, a_j)` (rightmost first).
    pub fn default_init(dims: &[(usize, usize)]) -> Self {
        let mats = dims
            .iter()
            .enumerate()
            .map(|(j, &(r, c))| if j == 0 { Mat::zeros(r, c) } else { Mat::eye(r, c) })
            .collect();
        FactorState { mats, lambda: 1.0 }
    }

    /// Current dense product `S_J ⋯ S_1` (λ not applied), on the
    /// process-default [`ExecCtx`]. Callers sitting on a [`PalmResult`]
    /// should prefer its cached [`PalmResult::product`].
    pub fn product(&self) -> Mat {
        self.product_ctx(ExecCtx::global())
    }

    /// [`FactorState::product`] on an explicit execution context.
    pub fn product_ctx(&self, ctx: &ExecCtx) -> Mat {
        let mut acc = self.mats[0].clone();
        for m in &self.mats[1..] {
            acc = ctx.gemm(m, &acc);
        }
        acc
    }

    /// Objective `½ ‖A − λ Π S_j‖_F²`.
    pub fn objective(&self, a: &Mat) -> f64 {
        self.objective_with(a, &self.product())
    }

    /// Objective reusing an already-computed factor product (e.g.
    /// [`PalmResult::product`]) instead of re-multiplying the chain.
    /// One fused pass, no temporaries.
    pub fn objective_with(&self, a: &Mat, product: &Mat) -> f64 {
        assert_eq!(a.shape(), product.shape(), "objective product shape");
        let lam = self.lambda;
        0.5 * a
            .data()
            .iter()
            .zip(product.data())
            .map(|(av, pv)| {
                let d = av - lam * pv;
                d * d
            })
            .sum::<f64>()
    }

    /// Convert into a [`Faust`] operator (exact-zero sparsification).
    pub fn into_faust(self) -> Faust {
        Faust::from_dense_factors(&self.mats, self.lambda)
    }
}

/// Result of a palm4MSA run.
pub struct PalmResult {
    pub state: FactorState,
    /// Objective value after every outer iteration (index 0 = after iter 1).
    pub objective_trace: Vec<f64>,
    /// Iterations actually performed (≤ `n_iter` if early-stopped).
    pub iters_run: usize,
    /// Final dense product `S_J ⋯ S_1` of `state.mats` (λ not applied) —
    /// the last sweep's prefix-product cache output, handed to callers so
    /// objective/error evaluation never re-multiplies the chain.
    pub product: Mat,
}

/// Per-sweep prefix-product cache (the L/R sides of Fig. 4's gradient).
///
/// `fixed[j]` holds the product of the *pre-sweep* factor values on the
/// far side of factor `j` — suffix products built once per sweep in `J−1`
/// GEMMs — while `moving` is grown incrementally as factors are updated.
/// After a complete sweep `moving` *is* the full updated product
/// `S_J ⋯ S_1`, which the λ update, the objective, and
/// [`PalmResult::product`] reuse: without the cache each factor update
/// would recompute its partial chains from scratch (O(J²) GEMMs per
/// sweep instead of O(J)).
struct SweepCache {
    fixed: Vec<Option<Mat>>,
    moving: Option<Mat>,
}

impl SweepCache {
    /// Build the fixed-side suffix products of the pre-sweep factors:
    /// for R2L `fixed[j] = S_J ⋯ S_{j+1}` (left side); for L2R
    /// `fixed[j] = S_{j-1} ⋯ S_1` (right side).
    fn build(ctx: &ExecCtx, mats: &[Mat], order: UpdateOrder) -> SweepCache {
        let nfac = mats.len();
        let mut fixed: Vec<Option<Mat>> = vec![None; nfac];
        match order {
            UpdateOrder::RightToLeft => {
                for j in (0..nfac - 1).rev() {
                    fixed[j] = Some(match &fixed[j + 1] {
                        None => mats[j + 1].clone(),
                        Some(m) => ctx.gemm(m, &mats[j + 1]),
                    });
                }
            }
            UpdateOrder::LeftToRight => {
                for j in 1..nfac {
                    fixed[j] = Some(match &fixed[j - 1] {
                        None => mats[j - 1].clone(),
                        Some(m) => ctx.gemm(&mats[j - 1], m),
                    });
                }
            }
        }
        SweepCache { fixed, moving: None }
    }

    /// The (L, R) side products seen by factor `j` mid-sweep: old factors
    /// on the fixed side, already-updated factors on the moving side.
    fn sides(&self, j: usize, order: UpdateOrder) -> (Option<&Mat>, Option<&Mat>) {
        match order {
            UpdateOrder::RightToLeft => (self.fixed[j].as_ref(), self.moving.as_ref()),
            UpdateOrder::LeftToRight => (self.moving.as_ref(), self.fixed[j].as_ref()),
        }
    }

    /// Fold the (possibly updated) factor into the moving-side product.
    fn fold(&mut self, ctx: &ExecCtx, mat: &Mat, order: UpdateOrder) {
        self.moving = Some(match (order, self.moving.take()) {
            (_, None) => mat.clone(),
            (UpdateOrder::RightToLeft, Some(am)) => ctx.gemm(mat, &am),
            (UpdateOrder::LeftToRight, Some(am)) => ctx.gemm(&am, mat),
        });
    }

    /// The full updated product `S_J ⋯ S_1` after a complete sweep.
    fn into_product(self) -> Mat {
        self.moving.expect("at least one factor folded")
    }
}

/// Run palm4MSA on operator `a` from `init` (see paper Fig. 4), on the
/// process-default [`ExecCtx`].
///
/// `init.mats` must match `cfg.constraints` in length and chain to the
/// shape of `a`.
///
/// ```
/// use faust::linalg::Mat;
/// use faust::palm::{palm4msa, FactorState, PalmConfig};
/// use faust::prox::Constraint;
///
/// // Two-factor split of the 4-point Hadamard under butterfly sparsity
/// // (the inner step of hierarchical factorization, paper Fig. 4/5).
/// let a = faust::transforms::hadamard(4);
/// let init = FactorState {
///     mats: vec![Mat::eye(4, 4), Mat::zeros(4, 4)],
///     lambda: 1.0,
/// };
/// let cfg = PalmConfig::new(
///     vec![Constraint::SpRowCol(2), Constraint::SpRowCol(2)],
///     40,
/// );
/// let res = palm4msa(&a, init, &cfg);
/// // PALM descends monotonically toward a stationary point (§III-B)…
/// assert!(res
///     .objective_trace
///     .windows(2)
///     .all(|w| w[1] <= w[0] * (1.0 + 1e-9) + 1e-12));
/// // …and the result converts into a servable FAμST operator.
/// let f = res.state.into_faust();
/// assert_eq!((f.rows(), f.cols()), (4, 4));
/// ```
pub fn palm4msa(a: &Mat, init: FactorState, cfg: &PalmConfig) -> PalmResult {
    palm4msa_with_ctx(ExecCtx::global(), a, init, cfg)
}

/// [`palm4msa`] on an explicit execution context: all GEMMs and power
/// iterations run on `ctx`'s pool. Results are bitwise identical across
/// thread counts (the ctx kernels are thread-invariant).
pub fn palm4msa_with_ctx(
    ctx: &ExecCtx,
    a: &Mat,
    init: FactorState,
    cfg: &PalmConfig,
) -> PalmResult {
    let nfac = cfg.constraints.len();
    assert_eq!(init.mats.len(), nfac, "constraint/factor count mismatch");
    assert_eq!(init.mats[0].cols(), a.cols(), "rightmost factor input dim");
    assert_eq!(
        init.mats.last().unwrap().rows(),
        a.rows(),
        "leftmost factor output dim"
    );
    let mut st = init;
    // Warm-start caches for the per-factor power iterations (the factor
    // chain changes slowly between outer iterations, so the previous
    // dominant singular vector is an excellent start — see §Perf).
    let mut l_warm: Vec<Vec<f64>> = vec![vec![]; nfac];
    let mut r_warm: Vec<Vec<f64>> = vec![vec![]; nfac];
    let mut trace = Vec::with_capacity(cfg.n_iter);
    let mut prev_obj = f64::INFINITY;
    let mut iters_run = 0;
    let mut product: Option<Mat> = None;
    for _iter in 0..cfg.n_iter {
        // Gauss–Seidel sweep. For RightToLeft (paper Fig. 4): factor j
        // sees *old* factors on its left (cached suffix products) and
        // *updated* factors on its right (the incrementally grown moving
        // side). LeftToRight is the mirror (FAμST toolbox default).
        let order: Vec<usize> = match cfg.update_order {
            UpdateOrder::RightToLeft => (0..nfac).collect(),
            UpdateOrder::LeftToRight => (0..nfac).rev().collect(),
        };
        let mut cache = SweepCache::build(ctx, &st.mats, cfg.update_order);
        for &j in &order {
            let (l, r) = cache.sides(j, cfg.update_order);
            if !matches!(cfg.constraints[j], Constraint::Frozen) {
                // Lipschitz modulus: λ² ‖L‖₂² ‖R‖₂² (Appendix B).
                let l_norm =
                    l.map_or(1.0, |m| ctx.spectral_norm_warm(m, &mut l_warm[j], 50, 1e-9));
                let r_norm =
                    r.map_or(1.0, |m| ctx.spectral_norm_warm(m, &mut r_warm[j], 50, 1e-9));
                let c = (1.0 + cfg.alpha)
                    * st.lambda
                    * st.lambda
                    * l_norm
                    * l_norm
                    * r_norm
                    * r_norm;
                if c <= 0.0 || !c.is_finite() {
                    // Degenerate chain (L or R exactly zero): gradient is
                    // zero — just project the current value.
                    st.mats[j] = cfg.constraints[j].project(&st.mats[j]);
                } else {
                    // grad = λ Lᵀ (λ L S R − A) Rᵀ, identity sides elided;
                    // GEMMs cost-dispatched on the ctx (§Perf).
                    let s = &st.mats[j];
                    let ls = match l {
                        None => s.clone(),
                        Some(lm) => ctx.gemm(lm, s),
                    };
                    let lsr = match r {
                        None => ls,
                        Some(rm) => ctx.gemm(&ls, rm),
                    };
                    let mut err = lsr;
                    err.scale(st.lambda);
                    err = err.sub(a);
                    let lt_err = match l {
                        None => err,
                        Some(lm) => ctx.gemm_tn(lm, &err),
                    };
                    let mut grad = match r {
                        None => lt_err,
                        Some(rm) => ctx.gemm_nt(&lt_err, rm),
                    };
                    grad.scale(st.lambda);
                    let mut stepped = st.mats[j].clone();
                    stepped.axpy(-1.0 / c, &grad);
                    st.mats[j] = cfg.constraints[j].project(&stepped);
                }
            }
            cache.fold(ctx, &st.mats[j], cfg.update_order);
        }
        // λ update: λ = Tr(Aᵀ Â) / Tr(Âᵀ Â) with Â = Π S_j (Fig. 4 line 9)
        // — Â comes out of the sweep cache for free.
        let a_hat = cache.into_product();
        let denom = a_hat.fro2();
        if denom > 0.0 {
            st.lambda = a.dot(&a_hat) / denom;
        }
        iters_run += 1;
        let obj = st.objective_with(a, &a_hat);
        product = Some(a_hat);
        trace.push(obj);
        if cfg.rel_tol > 0.0 && prev_obj.is_finite() {
            // Objective change measured relative to the data energy
            // ½‖A‖_F² (so convergence to an exact factorization — obj → 0
            // geometrically — also triggers the stop).
            let denom = 0.5 * a.fro2();
            let rel = (prev_obj - obj).abs() / denom.max(1e-300);
            if rel < cfg.rel_tol {
                break;
            }
        }
        prev_obj = obj;
    }
    let product = match product {
        Some(p) => p,
        // n_iter = 0: no sweep ran — compute the init's product directly.
        None => st.product_ctx(ctx),
    };
    PalmResult { state: st, objective_trace: trace, iters_run, product }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prox::Constraint;
    use crate::rng::Rng;

    /// Build a random exactly-factorizable A = S2 * S1 with sparse factors.
    fn planted(rng: &mut Rng, n: usize, nnz: usize) -> (Mat, Mat, Mat) {
        let mk = |rng: &mut Rng| {
            let mut m = Mat::zeros(n, n);
            for i in rng.sample_indices(n * n, nnz) {
                m.data_mut()[i] = rng.gauss();
            }
            // Keep diagonal present so the product is well-conditioned-ish.
            for i in 0..n {
                if m.at(i, i) == 0.0 {
                    m.set(i, i, 1.0);
                }
            }
            m
        };
        let s1 = mk(rng);
        let s2 = mk(rng);
        let a = s2.matmul(&s1);
        (a, s2, s1)
    }

    #[test]
    fn objective_is_monotone_decreasing() {
        let mut rng = Rng::new(91);
        let (a, _, _) = planted(&mut rng, 8, 20);
        let cfg = PalmConfig::new(
            vec![Constraint::SpGlobal(28), Constraint::SpGlobal(28)],
            40,
        );
        let init = FactorState::default_init(&[(8, 8), (8, 8)]);
        let res = palm4msa(&a, init, &cfg);
        for w in res.objective_trace.windows(2) {
            assert!(
                w[1] <= w[0] * (1.0 + 1e-9) + 1e-12,
                "objective increased: {} -> {}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn factors_stay_feasible() {
        let mut rng = Rng::new(92);
        let (a, _, _) = planted(&mut rng, 6, 12);
        let cs = vec![Constraint::SpGlobal(16), Constraint::SpGlobal(16)];
        let cfg = PalmConfig::new(cs.clone(), 15);
        let init = FactorState::default_init(&[(6, 6), (6, 6)]);
        let res = palm4msa(&a, init, &cfg);
        for (s, c) in res.state.mats.iter().zip(&cs) {
            assert!(c.is_feasible(s, 1e-9));
        }
    }

    #[test]
    fn two_factor_split_reduces_error_substantially() {
        let mut rng = Rng::new(93);
        let (a, _, _) = planted(&mut rng, 8, 24);
        let cfg = PalmConfig::new(
            vec![Constraint::SpGlobal(32), Constraint::SpGlobal(32)],
            200,
        );
        let init = FactorState::default_init(&[(8, 8), (8, 8)]);
        let res = palm4msa(&a, init, &cfg);
        let rel = res.state.into_faust().relative_error_fro(&a);
        assert!(rel < 0.35, "relative error too high: {rel}");
    }

    #[test]
    fn lambda_update_is_optimal_scale() {
        // After the run, perturbing λ can only increase the objective.
        let mut rng = Rng::new(94);
        let (a, _, _) = planted(&mut rng, 6, 14);
        let cfg = PalmConfig::new(
            vec![Constraint::SpGlobal(18), Constraint::SpGlobal(18)],
            10,
        );
        let init = FactorState::default_init(&[(6, 6), (6, 6)]);
        let res = palm4msa(&a, init, &cfg);
        let base = res.state.objective(&a);
        for d in [-0.1, -0.01, 0.01, 0.1] {
            let mut st = res.state.clone();
            st.lambda *= 1.0 + d;
            assert!(st.objective(&a) >= base - 1e-9);
        }
    }

    #[test]
    fn cached_product_matches_state_product() {
        // PalmResult::product is the final sweep's cache output — it must
        // equal the chain re-multiplication it replaces.
        let mut rng = Rng::new(98);
        let (a, _, _) = planted(&mut rng, 7, 16);
        let cfg = PalmConfig::new(
            vec![Constraint::SpGlobal(24), Constraint::SpGlobal(24)],
            12,
        );
        let init = FactorState::default_init(&[(7, 7), (7, 7)]);
        let res = palm4msa(&a, init, &cfg);
        let recomputed = res.state.product();
        assert!(res.product.rel_fro_err(&recomputed) < 1e-12);
        // Objective through the cache equals the from-scratch objective.
        let o1 = res.state.objective_with(&a, &res.product);
        let o2 = res.state.objective(&a);
        assert!((o1 - o2).abs() <= 1e-12 * (1.0 + o2.abs()));
    }

    #[test]
    fn frozen_factor_is_untouched() {
        let mut rng = Rng::new(95);
        let gamma = Mat::randn(6, 9, &mut rng);
        let d = Mat::randn(6, 6, &mut rng);
        let y = d.matmul(&gamma);
        let init = FactorState {
            mats: vec![gamma.clone(), Mat::eye(6, 6), Mat::eye(6, 6)],
            lambda: 1.0,
        };
        let cfg = PalmConfig::new(
            vec![
                Constraint::Frozen,
                Constraint::SpGlobal(20),
                Constraint::SpGlobal(20),
            ],
            10,
        );
        let res = palm4msa(&y, init, &cfg);
        assert!(res.state.mats[0].rel_fro_err(&gamma) < 1e-15);
    }

    #[test]
    fn rectangular_chain_shapes() {
        // A 4×10 ≈ (4×6)(6×10): exercise non-square suffix/R bookkeeping.
        let mut rng = Rng::new(96);
        let s1 = Mat::randn(6, 10, &mut rng);
        let s2 = Mat::randn(4, 6, &mut rng);
        let a = s2.matmul(&s1);
        let cfg = PalmConfig::new(
            vec![Constraint::SpGlobal(60), Constraint::SpGlobal(24)],
            60,
        );
        let init = FactorState::default_init(&[(6, 10), (4, 6)]);
        let res = palm4msa(&a, init, &cfg);
        // Fully dense budgets -> should fit very well.
        let rel = res.state.into_faust().relative_error_fro(&a);
        assert!(rel < 0.05, "rel={rel}");
    }

    #[test]
    fn early_stop_triggers() {
        let mut rng = Rng::new(97);
        let (a, _, _) = planted(&mut rng, 6, 12);
        let mut cfg = PalmConfig::new(
            vec![Constraint::SpGlobal(36), Constraint::SpGlobal(36)],
            500,
        );
        cfg.rel_tol = 1e-8;
        let init = FactorState::default_init(&[(6, 6), (6, 6)]);
        let res = palm4msa(&a, init, &cfg);
        assert!(res.iters_run < 500, "early stop never fired");
    }

    #[test]
    fn explicit_ctx_matches_default_path() {
        let mut rng = Rng::new(99);
        let (a, _, _) = planted(&mut rng, 8, 20);
        let cfg = PalmConfig::new(
            vec![Constraint::SpGlobal(28), Constraint::SpGlobal(28)],
            15,
        );
        let base = palm4msa(&a, FactorState::default_init(&[(8, 8), (8, 8)]), &cfg);
        for threads in [1usize, 4] {
            let ctx = ExecCtx::new(threads);
            let res = palm4msa_with_ctx(
                &ctx,
                &a,
                FactorState::default_init(&[(8, 8), (8, 8)]),
                &cfg,
            );
            assert!((res.state.lambda - base.state.lambda).abs() < 1e-12);
            for (m1, m2) in res.state.mats.iter().zip(&base.state.mats) {
                assert!(m1.rel_fro_err(m2) < 1e-12, "threads={threads}");
            }
        }
    }
}
