//! palm4MSA — PALM for Multi-layer Sparse Approximation (paper Fig. 4).
//!
//! Minimizes `½‖A − λ S_J ⋯ S_1‖_F² + Σ δ_{E_j}(S_j)` by alternating
//! projected-gradient steps on each factor (step size from the Lipschitz
//! modulus `λ² ‖L‖₂² ‖R‖₂²`, Appendix B) and a closed-form update of λ.
//! Convergence to a stationary point follows from Bolte–Sabach–Teboulle's
//! PALM theory (§III-B conditions (i)–(v); indicator penalties of the
//! semi-algebraic sets of Appendix A).
//!
//! Execution runs on the engine's [`ExecCtx`]: every GEMM in the sweep is
//! cost-dispatched (serial / row-parallel / transpose-rewrite) on the
//! shared thread pool, and the per-factor Lipschitz moduli come from
//! pooled power iterations. Zero-config callers get the process-default
//! ctx through [`palm4msa`]; [`palm4msa_with_ctx`] pins an explicit one
//! (e.g. a serving engine's, via `ApplyEngine::ctx()`). All ctx kernels
//! are bitwise thread-invariant, so a fixed seed reproduces identical
//! factors at any thread count.
//!
//! **Paper map:** Fig. 4 is this module; every experiment bottoms out
//! here through [`crate::hierarchical`] — fig6 (Hadamard §IV-C), fig8
//! (MEG §V) and fig12 (denoising dictionaries §VI) are hierarchies of
//! palm4MSA splits and refits.
//!
//! Partial products are managed by a per-sweep prefix-product cache
//! (the private `SweepCache`): the fixed side's suffix products are built once per
//! sweep, the moving side grows incrementally with each updated factor,
//! and the full updated product falls out of the sweep for free — the λ
//! update, the objective, and callers (via [`PalmResult::product`]) all
//! reuse it instead of re-multiplying the chain.
//!
//! **Fleets.** Real deployments factorize many operators at once (one MEG
//! gain per subject, §V; one dictionary per class, §VI) whose individual
//! GEMMs are too small to keep a pool busy. [`palm4msa_fleet_with_ctx`]
//! runs a whole fleet of independent problems through the sweep in
//! lockstep, batching each stage's per-member kernels into fused
//! [`FleetCtx`] dispatches, with per-member convergence: results are
//! bitwise identical to N separate [`palm4msa_with_ctx`] runs.

#![forbid(unsafe_code)]

pub mod online;

use crate::engine::{ExecCtx, FleetCtx};
use crate::faust::Faust;
use crate::linalg::Mat;
use crate::prox::Constraint;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide count of PALM outer iterations (solo + fleet drivers).
static ITERATIONS_TOTAL: AtomicU64 = AtomicU64::new(0);

/// Total PALM outer iterations this process has ever run, across every
/// solo and fleet factorization. The crash-recovery tests use the delta
/// of this counter as the zero-re-factorization witness: a warm restart
/// from a persisted store ([`crate::store`]) must leave it unchanged.
pub fn iterations_total() -> u64 {
    ITERATIONS_TOTAL.load(Ordering::Relaxed)
}

/// Configuration for one palm4MSA run.
#[derive(Clone, Debug)]
pub struct PalmConfig {
    /// Constraint set per factor, **rightmost first** (`constraints[0]` is
    /// `E` for `S_1`).
    pub constraints: Vec<Constraint>,
    /// Number of outer iterations (the paper's stopping criterion).
    pub n_iter: usize,
    /// Step-size margin: `c_j = (1+alpha) λ² ‖L‖₂² ‖R‖₂²` (§III-C3 uses
    /// `alpha = 1e-3`).
    pub alpha: f64,
    /// Early stop when the relative objective decrease falls below this
    /// (0 disables early stopping — the paper uses a fixed iteration count).
    pub rel_tol: f64,
    /// Seed for the power-iteration starting vectors.
    pub seed: u64,
    /// Factor update order within a sweep. The paper's Fig. 4 sweeps
    /// `j = 1..J` (right to left in the product `S_J ⋯ S_1`); the FAμST
    /// reference implementation defaults to the opposite
    /// (`is_update_way_R2L = false`, i.e. leftmost first).
    pub update_order: UpdateOrder,
}

/// Gauss–Seidel sweep direction over the factors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateOrder {
    /// `S_1` first (paper Fig. 4).
    RightToLeft,
    /// `S_J` first (FAμST toolbox default).
    LeftToRight,
}

impl PalmConfig {
    /// Paper defaults: `alpha = 1e-3`, fixed iteration count.
    pub fn new(constraints: Vec<Constraint>, n_iter: usize) -> Self {
        PalmConfig {
            constraints,
            n_iter,
            alpha: 1e-3,
            rel_tol: 0.0,
            seed: 0x5EED,
            update_order: UpdateOrder::RightToLeft,
        }
    }
}

/// The block of variables PALM optimizes: factors (rightmost first) + λ.
#[derive(Clone, Debug)]
pub struct FactorState {
    /// `mats[0] = S_1` … `mats[J-1] = S_J`.
    pub mats: Vec<Mat>,
    pub lambda: f64,
}

impl FactorState {
    /// Paper §III-C3 default init: `λ=1`, `S_1 = 0`, `S_j = Id` for `j≥2`,
    /// for the factor shapes `dims[j] = (a_{j+1}, a_j)` (rightmost first).
    pub fn default_init(dims: &[(usize, usize)]) -> Self {
        let mats = dims
            .iter()
            .enumerate()
            .map(|(j, &(r, c))| if j == 0 { Mat::zeros(r, c) } else { Mat::eye(r, c) })
            .collect();
        FactorState { mats, lambda: 1.0 }
    }

    /// Current dense product `S_J ⋯ S_1` (λ not applied), on the
    /// process-default [`ExecCtx`]. Callers sitting on a [`PalmResult`]
    /// should prefer its cached [`PalmResult::product`].
    pub fn product(&self) -> Mat {
        self.product_ctx(ExecCtx::global())
    }

    /// [`FactorState::product`] on an explicit execution context.
    pub fn product_ctx(&self, ctx: &ExecCtx) -> Mat {
        let mut acc = self.mats[0].clone();
        for m in &self.mats[1..] {
            acc = ctx.gemm(m, &acc);
        }
        acc
    }

    /// Objective `½ ‖A − λ Π S_j‖_F²`.
    pub fn objective(&self, a: &Mat) -> f64 {
        self.objective_with(a, &self.product())
    }

    /// Objective reusing an already-computed factor product (e.g.
    /// [`PalmResult::product`]) instead of re-multiplying the chain.
    /// One fused pass, no temporaries.
    pub fn objective_with(&self, a: &Mat, product: &Mat) -> f64 {
        assert_eq!(a.shape(), product.shape(), "objective product shape");
        objective_of(a, product, self.lambda)
    }

    /// Convert into a [`Faust`] operator (exact-zero sparsification).
    pub fn into_faust(self) -> Faust {
        Faust::from_dense_factors(&self.mats, self.lambda)
    }
}

/// Result of a palm4MSA run.
pub struct PalmResult {
    pub state: FactorState,
    /// Objective value after every outer iteration (index 0 = after iter 1).
    pub objective_trace: Vec<f64>,
    /// Iterations actually performed (≤ `n_iter` if early-stopped).
    pub iters_run: usize,
    /// Final dense product `S_J ⋯ S_1` of `state.mats` (λ not applied) —
    /// the last sweep's prefix-product cache output, handed to callers so
    /// objective/error evaluation never re-multiplies the chain.
    pub product: Mat,
}

/// Per-sweep prefix-product cache (the L/R sides of Fig. 4's gradient).
///
/// `fixed[j]` holds the product of the *pre-sweep* factor values on the
/// far side of factor `j` — suffix products built once per sweep in `J−1`
/// GEMMs — while `moving` is grown incrementally as factors are updated.
/// After a complete sweep `moving` *is* the full updated product
/// `S_J ⋯ S_1`, which the λ update, the objective, and
/// [`PalmResult::product`] reuse: without the cache each factor update
/// would recompute its partial chains from scratch (O(J²) GEMMs per
/// sweep instead of O(J)).
struct SweepCache {
    fixed: Vec<Option<Mat>>,
    moving: Option<Mat>,
}

impl SweepCache {
    /// Build the fixed-side suffix products of the pre-sweep factors:
    /// for R2L `fixed[j] = S_J ⋯ S_{j+1}` (left side); for L2R
    /// `fixed[j] = S_{j-1} ⋯ S_1` (right side).
    fn build(ctx: &ExecCtx, mats: &[Mat], order: UpdateOrder) -> SweepCache {
        let nfac = mats.len();
        let mut fixed: Vec<Option<Mat>> = vec![None; nfac];
        match order {
            UpdateOrder::RightToLeft => {
                for j in (0..nfac - 1).rev() {
                    fixed[j] = Some(match &fixed[j + 1] {
                        None => mats[j + 1].clone(),
                        Some(m) => ctx.gemm(m, &mats[j + 1]),
                    });
                }
            }
            UpdateOrder::LeftToRight => {
                for j in 1..nfac {
                    fixed[j] = Some(match &fixed[j - 1] {
                        None => mats[j - 1].clone(),
                        Some(m) => ctx.gemm(&mats[j - 1], m),
                    });
                }
            }
        }
        SweepCache { fixed, moving: None }
    }

    /// The (L, R) side products seen by factor `j` mid-sweep: old factors
    /// on the fixed side, already-updated factors on the moving side.
    fn sides(&self, j: usize, order: UpdateOrder) -> (Option<&Mat>, Option<&Mat>) {
        match order {
            UpdateOrder::RightToLeft => (self.fixed[j].as_ref(), self.moving.as_ref()),
            UpdateOrder::LeftToRight => (self.moving.as_ref(), self.fixed[j].as_ref()),
        }
    }

    /// Fold the (possibly updated) factor into the moving-side product.
    fn fold(&mut self, ctx: &ExecCtx, mat: &Mat, order: UpdateOrder) {
        self.moving = Some(match (order, self.moving.take()) {
            (_, None) => mat.clone(),
            (UpdateOrder::RightToLeft, Some(am)) => ctx.gemm(mat, &am),
            (UpdateOrder::LeftToRight, Some(am)) => ctx.gemm(&am, mat),
        });
    }

    /// The full updated product `S_J ⋯ S_1` after a complete sweep.
    fn into_product(self) -> Mat {
        self.moving.expect("at least one factor folded")
    }
}

/// Run palm4MSA on operator `a` from `init` (see paper Fig. 4), on the
/// process-default [`ExecCtx`].
///
/// `init.mats` must match `cfg.constraints` in length and chain to the
/// shape of `a`.
///
/// ```
/// use faust::linalg::Mat;
/// use faust::palm::{palm4msa, FactorState, PalmConfig};
/// use faust::prox::Constraint;
///
/// // Two-factor split of the 4-point Hadamard under butterfly sparsity
/// // (the inner step of hierarchical factorization, paper Fig. 4/5).
/// let a = faust::transforms::hadamard(4);
/// let init = FactorState {
///     mats: vec![Mat::eye(4, 4), Mat::zeros(4, 4)],
///     lambda: 1.0,
/// };
/// let cfg = PalmConfig::new(
///     vec![Constraint::SpRowCol(2), Constraint::SpRowCol(2)],
///     40,
/// );
/// let res = palm4msa(&a, init, &cfg);
/// // PALM descends monotonically toward a stationary point (§III-B)…
/// assert!(res
///     .objective_trace
///     .windows(2)
///     .all(|w| w[1] <= w[0] * (1.0 + 1e-9) + 1e-12));
/// // …and the result converts into a servable FAμST operator.
/// let f = res.state.into_faust();
/// assert_eq!((f.rows(), f.cols()), (4, 4));
/// ```
pub fn palm4msa(a: &Mat, init: FactorState, cfg: &PalmConfig) -> PalmResult {
    palm4msa_with_ctx(ExecCtx::global(), a, init, cfg)
}

/// [`palm4msa`] on an explicit execution context: all GEMMs and power
/// iterations run on `ctx`'s pool. Results are bitwise identical across
/// thread counts (the ctx kernels are thread-invariant).
pub fn palm4msa_with_ctx(
    ctx: &ExecCtx,
    a: &Mat,
    init: FactorState,
    cfg: &PalmConfig,
) -> PalmResult {
    let nfac = cfg.constraints.len();
    assert_eq!(init.mats.len(), nfac, "constraint/factor count mismatch");
    assert_eq!(init.mats[0].cols(), a.cols(), "rightmost factor input dim");
    assert_eq!(
        init.mats.last().unwrap().rows(),
        a.rows(),
        "leftmost factor output dim"
    );
    let mut st = init;
    // Warm-start caches for the per-factor power iterations (the factor
    // chain changes slowly between outer iterations, so the previous
    // dominant singular vector is an excellent start — see §Perf).
    let mut l_warm: Vec<Vec<f64>> = vec![vec![]; nfac];
    let mut r_warm: Vec<Vec<f64>> = vec![vec![]; nfac];
    let mut trace = Vec::with_capacity(cfg.n_iter);
    let mut prev_obj = f64::INFINITY;
    let mut iters_run = 0;
    let mut product: Option<Mat> = None;
    for _iter in 0..cfg.n_iter {
        // Gauss–Seidel sweep. For RightToLeft (paper Fig. 4): factor j
        // sees *old* factors on its left (cached suffix products) and
        // *updated* factors on its right (the incrementally grown moving
        // side). LeftToRight is the mirror (FAμST toolbox default).
        let order: Vec<usize> = match cfg.update_order {
            UpdateOrder::RightToLeft => (0..nfac).collect(),
            UpdateOrder::LeftToRight => (0..nfac).rev().collect(),
        };
        let mut cache = SweepCache::build(ctx, &st.mats, cfg.update_order);
        for &j in &order {
            let (l, r) = cache.sides(j, cfg.update_order);
            if !matches!(cfg.constraints[j], Constraint::Frozen) {
                // Lipschitz modulus: λ² ‖L‖₂² ‖R‖₂² (Appendix B).
                let l_norm =
                    l.map_or(1.0, |m| ctx.spectral_norm_warm(m, &mut l_warm[j], 50, 1e-9));
                let r_norm =
                    r.map_or(1.0, |m| ctx.spectral_norm_warm(m, &mut r_warm[j], 50, 1e-9));
                let c = (1.0 + cfg.alpha)
                    * st.lambda
                    * st.lambda
                    * l_norm
                    * l_norm
                    * r_norm
                    * r_norm;
                if c <= 0.0 || !c.is_finite() {
                    // Degenerate chain (L or R exactly zero): gradient is
                    // zero — just project the current value.
                    st.mats[j] = cfg.constraints[j].project(&st.mats[j]);
                } else {
                    // grad = λ Lᵀ (λ L S R − A) Rᵀ, identity sides elided;
                    // GEMMs cost-dispatched on the ctx (§Perf).
                    let s = &st.mats[j];
                    let ls = match l {
                        None => s.clone(),
                        Some(lm) => ctx.gemm(lm, s),
                    };
                    let lsr = match r {
                        None => ls,
                        Some(rm) => ctx.gemm(&ls, rm),
                    };
                    let mut err = lsr;
                    err.scale(st.lambda);
                    err = err.sub(a);
                    let lt_err = match l {
                        None => err,
                        Some(lm) => ctx.gemm_tn(lm, &err),
                    };
                    let mut grad = match r {
                        None => lt_err,
                        Some(rm) => ctx.gemm_nt(&lt_err, rm),
                    };
                    grad.scale(st.lambda);
                    let mut stepped = st.mats[j].clone();
                    stepped.axpy(-1.0 / c, &grad);
                    st.mats[j] = cfg.constraints[j].project(&stepped);
                }
            }
            cache.fold(ctx, &st.mats[j], cfg.update_order);
        }
        // λ update: λ = Tr(Aᵀ Â) / Tr(Âᵀ Â) with Â = Π S_j (Fig. 4 line 9)
        // — Â comes out of the sweep cache for free.
        let a_hat = cache.into_product();
        let denom = a_hat.fro2();
        if denom > 0.0 {
            st.lambda = a.dot(&a_hat) / denom;
        }
        iters_run += 1;
        ITERATIONS_TOTAL.fetch_add(1, Ordering::Relaxed);
        let obj = st.objective_with(a, &a_hat);
        product = Some(a_hat);
        trace.push(obj);
        if cfg.rel_tol > 0.0 && prev_obj.is_finite() {
            // Objective change measured relative to the data energy
            // ½‖A‖_F² (so convergence to an exact factorization — obj → 0
            // geometrically — also triggers the stop).
            let denom = 0.5 * a.fro2();
            let rel = (prev_obj - obj).abs() / denom.max(1e-300);
            if rel < cfg.rel_tol {
                break;
            }
        }
        prev_obj = obj;
    }
    let product = match product {
        Some(p) => p,
        // n_iter = 0: no sweep ran — compute the init's product directly.
        None => st.product_ctx(ctx),
    };
    PalmResult { state: st, objective_trace: trace, iters_run, product }
}

/// `½ ‖A − λ·P‖_F²` in one fused pass — shared by the solo path
/// ([`FactorState::objective_with`]) and the fleet sweep driver so both
/// accumulate the sum in identical order (bitwise-identity contract).
fn objective_of(a: &Mat, product: &Mat, lambda: f64) -> f64 {
    0.5 * a
        .data()
        .iter()
        .zip(product.data())
        .map(|(av, pv)| {
            let d = av - lambda * pv;
            d * d
        })
        .sum::<f64>()
}

/// One member of a fleet palm4MSA call: its own target operator, warm
/// start and configuration. Members are completely independent problems;
/// the fleet driver only shares *execution* (fused cross-operator
/// dispatch), never state.
pub struct FleetProblem<'a> {
    /// Target operator `A`.
    pub a: &'a Mat,
    /// Initial factors + λ.
    pub init: FactorState,
    /// Per-member configuration (iteration budgets, constraints and
    /// sweep orders may all differ across the fleet).
    pub cfg: PalmConfig,
}

/// Per-member bookkeeping of the lockstep fleet sweep.
struct FleetMember<'a> {
    a: &'a Mat,
    cfg: PalmConfig,
    st: FactorState,
    /// Sweep visit order (factor indices), fixed per member.
    order: Vec<usize>,
    nfac: usize,
    l_warm: Vec<Vec<f64>>,
    r_warm: Vec<Vec<f64>>,
    trace: Vec<f64>,
    prev_obj: f64,
    iters_run: usize,
    product: Option<Mat>,
    done: bool,
}

/// What a sweep position does for one member, decided after the
/// Lipschitz stage.
enum StepKind {
    Frozen,
    Degenerate,
    Grad { c: f64 },
}

/// [`palm4msa`] over a fleet of independent problems on the
/// process-default execution context (see [`palm4msa_fleet_with_ctx`]).
pub fn palm4msa_fleet(problems: Vec<FleetProblem>) -> Vec<PalmResult> {
    palm4msa_fleet_with_ctx(&FleetCtx::new(ExecCtx::global().clone()), problems)
}

/// Run many palm4MSA problems *concurrently* on one shared context.
///
/// The driver advances every live member through the same sweep stages in
/// lockstep — fixed-side cache build, Lipschitz power iterations,
/// gradient GEMMs, projected steps, moving-side folds, λ/objective
/// updates — and batches each stage's independent per-member kernels into
/// fused [`FleetCtx`] dispatches. Members converge independently: a
/// member that exhausts its `n_iter` or trips its `rel_tol` early stop
/// drops out of every subsequent fused batch while the rest keep going.
/// Members may have different shapes, factor counts, constraint sets,
/// sweep orders and iteration budgets.
///
/// Results are **bitwise identical** to running
/// [`palm4msa_with_ctx`] on each problem independently (at any thread
/// count): every fused kernel reuses the solo path's serial per-chunk
/// routines and cost-model decisions. The fleet proptests enforce this.
pub fn palm4msa_fleet_with_ctx(
    fleet: &FleetCtx,
    problems: Vec<FleetProblem>,
) -> Vec<PalmResult> {
    let ctx = fleet.ctx();
    let mut members: Vec<FleetMember> = problems
        .into_iter()
        .map(|p| {
            let nfac = p.cfg.constraints.len();
            assert_eq!(p.init.mats.len(), nfac, "constraint/factor count mismatch");
            assert_eq!(p.init.mats[0].cols(), p.a.cols(), "rightmost factor input dim");
            assert_eq!(
                p.init.mats.last().unwrap().rows(),
                p.a.rows(),
                "leftmost factor output dim"
            );
            let order: Vec<usize> = match p.cfg.update_order {
                UpdateOrder::RightToLeft => (0..nfac).collect(),
                UpdateOrder::LeftToRight => (0..nfac).rev().collect(),
            };
            let done = p.cfg.n_iter == 0;
            FleetMember {
                a: p.a,
                st: p.init,
                order,
                nfac,
                l_warm: vec![vec![]; nfac],
                r_warm: vec![vec![]; nfac],
                trace: Vec::with_capacity(p.cfg.n_iter),
                prev_obj: f64::INFINITY,
                iters_run: 0,
                product: None,
                done,
                cfg: p.cfg,
            }
        })
        .collect();

    // One pass of this loop = one palm4MSA outer iteration for every
    // still-live member.
    loop {
        let live: Vec<usize> = (0..members.len()).filter(|&i| !members[i].done).collect();
        if live.is_empty() {
            break;
        }

        // --- Fixed-side cache build, lockstep over suffix depth: step s
        // folds one more pre-sweep factor per member; the independent
        // per-member products fuse into one dispatch.
        let mut caches: Vec<Option<SweepCache>> = members.iter().map(|_| None).collect();
        for &i in &live {
            caches[i] = Some(SweepCache { fixed: vec![None; members[i].nfac], moving: None });
        }
        let max_steps = live.iter().map(|&i| members[i].nfac - 1).max().unwrap_or(0);
        for s in 0..max_steps {
            let mut pairs: Vec<(&Mat, &Mat)> = Vec::new();
            let mut gemm_slots: Vec<(usize, usize)> = Vec::new();
            let mut clone_slots: Vec<(usize, usize, Mat)> = Vec::new();
            for &i in &live {
                let m = &members[i];
                if s >= m.nfac - 1 {
                    continue;
                }
                // Same visit order as SweepCache::build: R2L fills
                // fixed[j] from nfac−2 downward, L2R from 1 upward.
                let cache = caches[i].as_ref().expect("live member has a cache");
                match m.cfg.update_order {
                    UpdateOrder::RightToLeft => {
                        let j = m.nfac - 2 - s;
                        match &cache.fixed[j + 1] {
                            None => clone_slots.push((i, j, m.st.mats[j + 1].clone())),
                            Some(src) => {
                                pairs.push((src, &m.st.mats[j + 1]));
                                gemm_slots.push((i, j));
                            }
                        }
                    }
                    UpdateOrder::LeftToRight => {
                        let j = 1 + s;
                        match &cache.fixed[j - 1] {
                            None => clone_slots.push((i, j, m.st.mats[j - 1].clone())),
                            Some(src) => {
                                pairs.push((&m.st.mats[j - 1], src));
                                gemm_slots.push((i, j));
                            }
                        }
                    }
                }
            }
            let outs = fleet.gemm_many(&pairs);
            for ((i, j), out) in gemm_slots.into_iter().zip(outs) {
                caches[i].as_mut().expect("cache").fixed[j] = Some(out);
            }
            for (i, j, m) in clone_slots {
                caches[i].as_mut().expect("cache").fixed[j] = Some(m);
            }
        }

        // --- Gauss–Seidel sweep, lockstep over sweep position t: every
        // live member updates its t-th factor (in its own order) with the
        // same staged kernels the solo path runs, batched across members.
        let max_pos = live.iter().map(|&i| members[i].nfac).max().unwrap_or(0);
        for t in 0..max_pos {
            let mut pos: Vec<(usize, usize)> = Vec::new();
            for &i in &live {
                if t < members[i].nfac {
                    pos.push((i, members[i].order[t]));
                }
            }
            if pos.is_empty() {
                continue;
            }
            let npos = pos.len();

            // Stage A: Lipschitz spectral norms — batched warm-started
            // power iterations (identity sides default to 1.0).
            let mut l_norm = vec![1.0f64; npos];
            let mut r_norm = vec![1.0f64; npos];
            {
                let mut spec_jobs: Vec<(&Mat, Vec<f64>)> = Vec::new();
                let mut spec_slots: Vec<(usize, bool)> = Vec::new();
                for (p, &(i, j)) in pos.iter().enumerate() {
                    if matches!(members[i].cfg.constraints[j], Constraint::Frozen) {
                        continue;
                    }
                    let order = members[i].cfg.update_order;
                    let (l, r) = caches[i].as_ref().expect("cache").sides(j, order);
                    if let Some(lm) = l {
                        let warm = std::mem::take(&mut members[i].l_warm[j]);
                        spec_jobs.push((lm, warm));
                        spec_slots.push((p, true));
                    }
                    if let Some(rm) = r {
                        let warm = std::mem::take(&mut members[i].r_warm[j]);
                        spec_jobs.push((rm, warm));
                        spec_slots.push((p, false));
                    }
                }
                let spec_out = fleet.spectral_norm_many(spec_jobs, 50, 1e-9);
                for ((p, is_left), (norm, warm)) in spec_slots.into_iter().zip(spec_out) {
                    let (i, j) = pos[p];
                    if is_left {
                        l_norm[p] = norm;
                        members[i].l_warm[j] = warm;
                    } else {
                        r_norm[p] = norm;
                        members[i].r_warm[j] = warm;
                    }
                }
            }

            // Stage B: classify — frozen factors skip, degenerate chains
            // (zero L/R) project in place, the rest take a gradient step
            // with modulus c = (1+α) λ² ‖L‖₂² ‖R‖₂² (Appendix B).
            let kinds: Vec<StepKind> = pos
                .iter()
                .enumerate()
                .map(|(p, &(i, j))| {
                    let m = &members[i];
                    if matches!(m.cfg.constraints[j], Constraint::Frozen) {
                        return StepKind::Frozen;
                    }
                    let c = (1.0 + m.cfg.alpha)
                        * m.st.lambda
                        * m.st.lambda
                        * l_norm[p]
                        * l_norm[p]
                        * r_norm[p]
                        * r_norm[p];
                    if c <= 0.0 || !c.is_finite() {
                        StepKind::Degenerate
                    } else {
                        StepKind::Grad { c }
                    }
                })
                .collect();
            let grads: Vec<usize> = (0..npos)
                .filter(|&p| matches!(kinds[p], StepKind::Grad { .. }))
                .collect();
            // `store[p]` carries the gradient pipeline value for position
            // p through stages C→G (ls → lsr → err → Lᵀerr → grad).
            let mut store: Vec<Option<Mat>> = std::iter::repeat_with(|| None).take(npos).collect();

            // Stage C: ls = L·S (members whose L side is identity pass
            // their factor through unchanged).
            {
                let mut pairs: Vec<(&Mat, &Mat)> = Vec::new();
                let mut slots: Vec<usize> = Vec::new();
                for &p in &grads {
                    let (i, j) = pos[p];
                    let order = members[i].cfg.update_order;
                    let (l, _) = caches[i].as_ref().expect("cache").sides(j, order);
                    let s = &members[i].st.mats[j];
                    match l {
                        Some(lm) => {
                            pairs.push((lm, s));
                            slots.push(p);
                        }
                        None => store[p] = Some(s.clone()),
                    }
                }
                let outs = fleet.gemm_many(&pairs);
                for (p, o) in slots.into_iter().zip(outs) {
                    store[p] = Some(o);
                }
            }

            // Stage D: lsr = (L·S)·R.
            {
                let mut pairs: Vec<(&Mat, &Mat)> = Vec::new();
                let mut slots: Vec<usize> = Vec::new();
                for &p in &grads {
                    let (i, j) = pos[p];
                    let order = members[i].cfg.update_order;
                    let (_, r) = caches[i].as_ref().expect("cache").sides(j, order);
                    if let Some(rm) = r {
                        pairs.push((store[p].as_ref().expect("ls computed"), rm));
                        slots.push(p);
                    }
                }
                let outs = fleet.gemm_many(&pairs);
                for (p, o) in slots.into_iter().zip(outs) {
                    store[p] = Some(o);
                }
            }

            // Stage E: err = λ·(LSR) − A — element-wise, fleet-mapped.
            {
                let jobs: Vec<(usize, Mat, f64, &Mat)> = grads
                    .iter()
                    .map(|&p| {
                        let (i, _) = pos[p];
                        (
                            p,
                            store[p].take().expect("lsr computed"),
                            members[i].st.lambda,
                            members[i].a,
                        )
                    })
                    .collect();
                let outs = fleet.map_many(jobs, |(p, mut lsr, lambda, a)| {
                    lsr.scale(lambda);
                    (p, lsr.sub(a))
                });
                for (p, e) in outs {
                    store[p] = Some(e);
                }
            }

            // Stage F: Lᵀ·err (the Lᵀ materialization matches the solo
            // gemm_tn path, so the rewrite decision sees the same bits).
            {
                let mut lts: Vec<Option<Mat>> =
                    std::iter::repeat_with(|| None).take(npos).collect();
                for &p in &grads {
                    let (i, j) = pos[p];
                    let order = members[i].cfg.update_order;
                    let (l, _) = caches[i].as_ref().expect("cache").sides(j, order);
                    if let Some(lm) = l {
                        lts[p] = Some(lm.t());
                    }
                }
                let mut pairs: Vec<(&Mat, &Mat)> = Vec::new();
                let mut slots: Vec<usize> = Vec::new();
                for &p in &grads {
                    if let Some(lt) = &lts[p] {
                        pairs.push((lt, store[p].as_ref().expect("err computed")));
                        slots.push(p);
                    }
                }
                let outs = fleet.gemm_many(&pairs);
                for (p, o) in slots.into_iter().zip(outs) {
                    store[p] = Some(o);
                }
            }

            // Stage G: grad = (Lᵀ err)·Rᵀ.
            {
                let mut rts: Vec<Option<Mat>> =
                    std::iter::repeat_with(|| None).take(npos).collect();
                for &p in &grads {
                    let (i, j) = pos[p];
                    let order = members[i].cfg.update_order;
                    let (_, r) = caches[i].as_ref().expect("cache").sides(j, order);
                    if let Some(rm) = r {
                        rts[p] = Some(rm.t());
                    }
                }
                let mut pairs: Vec<(&Mat, &Mat)> = Vec::new();
                let mut slots: Vec<usize> = Vec::new();
                for &p in &grads {
                    if let Some(rt) = &rts[p] {
                        pairs.push((store[p].as_ref().expect("lt_err computed"), rt));
                        slots.push(p);
                    }
                }
                let outs = fleet.gemm_many(&pairs);
                for (p, o) in slots.into_iter().zip(outs) {
                    store[p] = Some(o);
                }
            }

            // Stage H: projected gradient step (or plain projection for
            // degenerate chains) — proximal ops fleet-mapped.
            {
                type StepJob = (usize, Option<(Mat, f64)>, f64, Mat, Constraint);
                let mut jobs: Vec<StepJob> = Vec::new();
                for (p, &(i, j)) in pos.iter().enumerate() {
                    let m = &members[i];
                    match kinds[p] {
                        StepKind::Frozen => {}
                        StepKind::Degenerate => jobs.push((
                            p,
                            None,
                            m.st.lambda,
                            m.st.mats[j].clone(),
                            m.cfg.constraints[j].clone(),
                        )),
                        StepKind::Grad { c } => jobs.push((
                            p,
                            Some((store[p].take().expect("grad computed"), c)),
                            m.st.lambda,
                            m.st.mats[j].clone(),
                            m.cfg.constraints[j].clone(),
                        )),
                    }
                }
                let outs = fleet.map_many(jobs, |(p, grad_c, lambda, s, cst)| {
                    let newm = match grad_c {
                        Some((mut grad, c)) => {
                            grad.scale(lambda);
                            let mut stepped = s;
                            stepped.axpy(-1.0 / c, &grad);
                            cst.project(&stepped)
                        }
                        None => cst.project(&s),
                    };
                    (p, newm)
                });
                for (p, newm) in outs {
                    let (i, j) = pos[p];
                    members[i].st.mats[j] = newm;
                }
            }

            // Stage I: fold the (possibly updated) factor into the
            // moving-side product — frozen factors fold too.
            {
                let mut pairs: Vec<(&Mat, &Mat)> = Vec::new();
                let mut slots: Vec<usize> = Vec::new();
                let mut clones: Vec<(usize, Mat)> = Vec::new();
                for &(i, j) in &pos {
                    let order = members[i].cfg.update_order;
                    let mat = &members[i].st.mats[j];
                    match (&caches[i].as_ref().expect("cache").moving, order) {
                        (None, _) => clones.push((i, mat.clone())),
                        (Some(mv), UpdateOrder::RightToLeft) => {
                            pairs.push((mat, mv));
                            slots.push(i);
                        }
                        (Some(mv), UpdateOrder::LeftToRight) => {
                            pairs.push((mv, mat));
                            slots.push(i);
                        }
                    }
                }
                let outs = fleet.gemm_many(&pairs);
                for (i, o) in slots.into_iter().zip(outs) {
                    caches[i].as_mut().expect("cache").moving = Some(o);
                }
                for (i, m) in clones {
                    caches[i].as_mut().expect("cache").moving = Some(m);
                }
            }
        }

        // --- λ update, objective, convergence — per member, fleet-mapped
        // (Fig. 4 line 9; Â falls out of the sweep cache for free).
        {
            let jobs: Vec<(usize, Mat, f64, &Mat)> = live
                .iter()
                .map(|&i| {
                    let a_hat = caches[i]
                        .as_mut()
                        .expect("cache")
                        .moving
                        .take()
                        .expect("at least one factor folded");
                    (i, a_hat, members[i].st.lambda, members[i].a)
                })
                .collect();
            let outs = fleet.map_many(jobs, |(i, a_hat, lambda_old, a)| {
                let denom = a_hat.fro2();
                let lambda = if denom > 0.0 { a.dot(&a_hat) / denom } else { lambda_old };
                let obj = objective_of(a, &a_hat, lambda);
                (i, a_hat, lambda, obj)
            });
            for (i, a_hat, lambda, obj) in outs {
                let m = &mut members[i];
                m.st.lambda = lambda;
                m.iters_run += 1;
                ITERATIONS_TOTAL.fetch_add(1, Ordering::Relaxed);
                m.trace.push(obj);
                m.product = Some(a_hat);
                let mut stop = m.iters_run >= m.cfg.n_iter;
                if m.cfg.rel_tol > 0.0 && m.prev_obj.is_finite() {
                    // Same stop rule as the solo driver: objective change
                    // relative to the data energy ½‖A‖_F².
                    let denom = 0.5 * m.a.fro2();
                    let rel = (m.prev_obj - obj).abs() / denom.max(1e-300);
                    if rel < m.cfg.rel_tol {
                        stop = true;
                    }
                }
                m.prev_obj = obj;
                if stop {
                    m.done = true;
                }
            }
        }
    }

    members
        .into_iter()
        .map(|m| {
            let product = match m.product {
                Some(p) => p,
                // n_iter = 0: no sweep ran — compute the init's product.
                None => m.st.product_ctx(ctx),
            };
            PalmResult {
                state: m.st,
                objective_trace: m.trace,
                iters_run: m.iters_run,
                product,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prox::Constraint;
    use crate::rng::Rng;

    /// Build a random exactly-factorizable A = S2 * S1 with sparse factors.
    fn planted(rng: &mut Rng, n: usize, nnz: usize) -> (Mat, Mat, Mat) {
        let mk = |rng: &mut Rng| {
            let mut m = Mat::zeros(n, n);
            for i in rng.sample_indices(n * n, nnz) {
                m.data_mut()[i] = rng.gauss();
            }
            // Keep diagonal present so the product is well-conditioned-ish.
            for i in 0..n {
                if m.at(i, i) == 0.0 {
                    m.set(i, i, 1.0);
                }
            }
            m
        };
        let s1 = mk(rng);
        let s2 = mk(rng);
        let a = s2.matmul(&s1);
        (a, s2, s1)
    }

    #[test]
    fn objective_is_monotone_decreasing() {
        let mut rng = Rng::new(91);
        let (a, _, _) = planted(&mut rng, 8, 20);
        let cfg = PalmConfig::new(
            vec![Constraint::SpGlobal(28), Constraint::SpGlobal(28)],
            40,
        );
        let init = FactorState::default_init(&[(8, 8), (8, 8)]);
        let res = palm4msa(&a, init, &cfg);
        for w in res.objective_trace.windows(2) {
            assert!(
                w[1] <= w[0] * (1.0 + 1e-9) + 1e-12,
                "objective increased: {} -> {}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn factors_stay_feasible() {
        let mut rng = Rng::new(92);
        let (a, _, _) = planted(&mut rng, 6, 12);
        let cs = vec![Constraint::SpGlobal(16), Constraint::SpGlobal(16)];
        let cfg = PalmConfig::new(cs.clone(), 15);
        let init = FactorState::default_init(&[(6, 6), (6, 6)]);
        let res = palm4msa(&a, init, &cfg);
        for (s, c) in res.state.mats.iter().zip(&cs) {
            assert!(c.is_feasible(s, 1e-9));
        }
    }

    #[test]
    fn two_factor_split_reduces_error_substantially() {
        let mut rng = Rng::new(93);
        let (a, _, _) = planted(&mut rng, 8, 24);
        let cfg = PalmConfig::new(
            vec![Constraint::SpGlobal(32), Constraint::SpGlobal(32)],
            200,
        );
        let init = FactorState::default_init(&[(8, 8), (8, 8)]);
        let res = palm4msa(&a, init, &cfg);
        let rel = res.state.into_faust().relative_error_fro(&a);
        assert!(rel < 0.35, "relative error too high: {rel}");
    }

    #[test]
    fn lambda_update_is_optimal_scale() {
        // After the run, perturbing λ can only increase the objective.
        let mut rng = Rng::new(94);
        let (a, _, _) = planted(&mut rng, 6, 14);
        let cfg = PalmConfig::new(
            vec![Constraint::SpGlobal(18), Constraint::SpGlobal(18)],
            10,
        );
        let init = FactorState::default_init(&[(6, 6), (6, 6)]);
        let res = palm4msa(&a, init, &cfg);
        let base = res.state.objective(&a);
        for d in [-0.1, -0.01, 0.01, 0.1] {
            let mut st = res.state.clone();
            st.lambda *= 1.0 + d;
            assert!(st.objective(&a) >= base - 1e-9);
        }
    }

    #[test]
    fn cached_product_matches_state_product() {
        // PalmResult::product is the final sweep's cache output — it must
        // equal the chain re-multiplication it replaces.
        let mut rng = Rng::new(98);
        let (a, _, _) = planted(&mut rng, 7, 16);
        let cfg = PalmConfig::new(
            vec![Constraint::SpGlobal(24), Constraint::SpGlobal(24)],
            12,
        );
        let init = FactorState::default_init(&[(7, 7), (7, 7)]);
        let res = palm4msa(&a, init, &cfg);
        let recomputed = res.state.product();
        assert!(res.product.rel_fro_err(&recomputed) < 1e-12);
        // Objective through the cache equals the from-scratch objective.
        let o1 = res.state.objective_with(&a, &res.product);
        let o2 = res.state.objective(&a);
        assert!((o1 - o2).abs() <= 1e-12 * (1.0 + o2.abs()));
    }

    #[test]
    fn frozen_factor_is_untouched() {
        let mut rng = Rng::new(95);
        let gamma = Mat::randn(6, 9, &mut rng);
        let d = Mat::randn(6, 6, &mut rng);
        let y = d.matmul(&gamma);
        let init = FactorState {
            mats: vec![gamma.clone(), Mat::eye(6, 6), Mat::eye(6, 6)],
            lambda: 1.0,
        };
        let cfg = PalmConfig::new(
            vec![
                Constraint::Frozen,
                Constraint::SpGlobal(20),
                Constraint::SpGlobal(20),
            ],
            10,
        );
        let res = palm4msa(&y, init, &cfg);
        assert!(res.state.mats[0].rel_fro_err(&gamma) < 1e-15);
    }

    #[test]
    fn rectangular_chain_shapes() {
        // A 4×10 ≈ (4×6)(6×10): exercise non-square suffix/R bookkeeping.
        let mut rng = Rng::new(96);
        let s1 = Mat::randn(6, 10, &mut rng);
        let s2 = Mat::randn(4, 6, &mut rng);
        let a = s2.matmul(&s1);
        let cfg = PalmConfig::new(
            vec![Constraint::SpGlobal(60), Constraint::SpGlobal(24)],
            60,
        );
        let init = FactorState::default_init(&[(6, 10), (4, 6)]);
        let res = palm4msa(&a, init, &cfg);
        // Fully dense budgets -> should fit very well.
        let rel = res.state.into_faust().relative_error_fro(&a);
        assert!(rel < 0.05, "rel={rel}");
    }

    #[test]
    fn early_stop_triggers() {
        let mut rng = Rng::new(97);
        let (a, _, _) = planted(&mut rng, 6, 12);
        let mut cfg = PalmConfig::new(
            vec![Constraint::SpGlobal(36), Constraint::SpGlobal(36)],
            500,
        );
        cfg.rel_tol = 1e-8;
        let init = FactorState::default_init(&[(6, 6), (6, 6)]);
        let res = palm4msa(&a, init, &cfg);
        assert!(res.iters_run < 500, "early stop never fired");
    }

    /// Byte-level comparison of two factor states.
    fn assert_states_bitwise_eq(a: &FactorState, b: &FactorState, tag: &str) {
        assert_eq!(a.lambda.to_bits(), b.lambda.to_bits(), "{tag}: lambda");
        assert_eq!(a.mats.len(), b.mats.len(), "{tag}: factor count");
        for (p, q) in a.mats.iter().zip(&b.mats) {
            assert_eq!(p.data(), q.data(), "{tag}: factor bits");
        }
    }

    #[test]
    fn fleet_matches_independent_runs_bitwise() {
        // Heterogeneous fleet: different shapes, budgets, sweep orders.
        let mut rng = Rng::new(8101);
        let (a1, _, _) = planted(&mut rng, 8, 20);
        let (a2, _, _) = planted(&mut rng, 6, 12);
        let s1 = Mat::randn(6, 10, &mut rng);
        let s2 = Mat::randn(4, 6, &mut rng);
        let a3 = s2.matmul(&s1);
        let cfg1 = PalmConfig::new(
            vec![Constraint::SpGlobal(28), Constraint::SpGlobal(28)],
            14,
        );
        let mut cfg2 = PalmConfig::new(
            vec![Constraint::SpGlobal(18), Constraint::SpGlobal(18)],
            9,
        );
        cfg2.update_order = UpdateOrder::LeftToRight;
        let cfg3 = PalmConfig::new(
            vec![Constraint::SpGlobal(60), Constraint::SpGlobal(24)],
            11,
        );
        let mk_inits = || {
            vec![
                FactorState::default_init(&[(8, 8), (8, 8)]),
                FactorState::default_init(&[(6, 6), (6, 6)]),
                FactorState::default_init(&[(6, 10), (4, 6)]),
            ]
        };
        let targets = [&a1, &a2, &a3];
        let cfgs = [&cfg1, &cfg2, &cfg3];
        for threads in [1usize, 4] {
            let ctx = ExecCtx::new(threads);
            let solo: Vec<PalmResult> = targets
                .into_iter()
                .zip(mk_inits())
                .zip(cfgs)
                .map(|((a, init), cfg)| palm4msa_with_ctx(&ctx, a, init, cfg))
                .collect();
            let fleet = FleetCtx::new(ctx);
            let problems: Vec<FleetProblem> = targets
                .into_iter()
                .zip(mk_inits())
                .zip(cfgs)
                .map(|((a, init), cfg)| FleetProblem { a, init, cfg: cfg.clone() })
                .collect();
            let got = palm4msa_fleet_with_ctx(&fleet, problems);
            assert_eq!(got.len(), solo.len());
            for (k, (g, w)) in got.iter().zip(&solo).enumerate() {
                let tag = format!("member {k}, {threads} threads");
                assert_states_bitwise_eq(&g.state, &w.state, &tag);
                assert_eq!(g.iters_run, w.iters_run, "{tag}: iters");
                assert_eq!(g.objective_trace.len(), w.objective_trace.len(), "{tag}");
                for (x, y) in g.objective_trace.iter().zip(&w.objective_trace) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{tag}: trace");
                }
                assert_eq!(g.product.data(), w.product.data(), "{tag}: product");
            }
        }
    }

    #[test]
    fn fleet_members_converge_independently() {
        // One member early-stops, one runs a tiny budget, one runs zero
        // iterations — each must match its own solo run exactly.
        let mut rng = Rng::new(8102);
        let (a1, _, _) = planted(&mut rng, 6, 12);
        let (a2, _, _) = planted(&mut rng, 7, 16);
        let mut cfg_stop = PalmConfig::new(
            vec![Constraint::SpGlobal(36), Constraint::SpGlobal(36)],
            500,
        );
        cfg_stop.rel_tol = 1e-8;
        let cfg_short = PalmConfig::new(
            vec![Constraint::SpGlobal(24), Constraint::SpGlobal(24)],
            3,
        );
        let cfg_zero = PalmConfig::new(
            vec![Constraint::SpGlobal(24), Constraint::SpGlobal(24)],
            0,
        );
        let ctx = ExecCtx::new(2);
        let solo_stop = palm4msa_with_ctx(
            &ctx,
            &a1,
            FactorState::default_init(&[(6, 6), (6, 6)]),
            &cfg_stop,
        );
        let solo_short = palm4msa_with_ctx(
            &ctx,
            &a2,
            FactorState::default_init(&[(7, 7), (7, 7)]),
            &cfg_short,
        );
        let solo_zero = palm4msa_with_ctx(
            &ctx,
            &a2,
            FactorState::default_init(&[(7, 7), (7, 7)]),
            &cfg_zero,
        );
        assert!(solo_stop.iters_run < 500, "early stop must fire for this seed");
        let fleet = FleetCtx::new(ctx);
        let got = palm4msa_fleet_with_ctx(
            &fleet,
            vec![
                FleetProblem {
                    a: &a1,
                    init: FactorState::default_init(&[(6, 6), (6, 6)]),
                    cfg: cfg_stop,
                },
                FleetProblem {
                    a: &a2,
                    init: FactorState::default_init(&[(7, 7), (7, 7)]),
                    cfg: cfg_short,
                },
                FleetProblem {
                    a: &a2,
                    init: FactorState::default_init(&[(7, 7), (7, 7)]),
                    cfg: cfg_zero,
                },
            ],
        );
        for (g, w) in got.iter().zip([&solo_stop, &solo_short, &solo_zero]) {
            assert_eq!(g.iters_run, w.iters_run);
            assert_states_bitwise_eq(&g.state, &w.state, "dropout member");
            assert_eq!(g.product.data(), w.product.data());
        }
    }

    #[test]
    fn fleet_with_frozen_factor_matches_solo() {
        let mut rng = Rng::new(8103);
        let gamma = Mat::randn(6, 9, &mut rng);
        let d = Mat::randn(6, 6, &mut rng);
        let y = d.matmul(&gamma);
        let mk_init = || FactorState {
            mats: vec![gamma.clone(), Mat::eye(6, 6), Mat::eye(6, 6)],
            lambda: 1.0,
        };
        let cfg = PalmConfig::new(
            vec![
                Constraint::Frozen,
                Constraint::SpGlobal(20),
                Constraint::SpGlobal(20),
            ],
            8,
        );
        let ctx = ExecCtx::new(2);
        let solo = palm4msa_with_ctx(&ctx, &y, mk_init(), &cfg);
        let fleet = FleetCtx::new(ctx);
        let got = palm4msa_fleet_with_ctx(
            &fleet,
            vec![FleetProblem { a: &y, init: mk_init(), cfg }],
        );
        assert_states_bitwise_eq(&got[0].state, &solo.state, "frozen");
        assert!(got[0].state.mats[0].rel_fro_err(&gamma) < 1e-15);
    }

    #[test]
    fn explicit_ctx_matches_default_path() {
        let mut rng = Rng::new(99);
        let (a, _, _) = planted(&mut rng, 8, 20);
        let cfg = PalmConfig::new(
            vec![Constraint::SpGlobal(28), Constraint::SpGlobal(28)],
            15,
        );
        let base = palm4msa(&a, FactorState::default_init(&[(8, 8), (8, 8)]), &cfg);
        for threads in [1usize, 4] {
            let ctx = ExecCtx::new(threads);
            let res = palm4msa_with_ctx(
                &ctx,
                &a,
                FactorState::default_init(&[(8, 8), (8, 8)]),
                &cfg,
            );
            assert!((res.state.lambda - base.state.lambda).abs() < 1e-12);
            for (m1, m2) in res.state.mats.iter().zip(&base.state.mats) {
                assert!(m1.rel_fro_err(m2) < 1e-12, "threads={threads}");
            }
        }
    }
}
