//! Online / streaming palm4MSA — mini-batch surrogate factorization
//! (ROADMAP item i; Mairal et al., *Online Learning for Matrix
//! Factorization and Sparse Coding*).
//!
//! The batch driver ([`super::palm4msa_with_ctx`]) needs the whole target
//! `A` up front. A *serving* system sees `A` one column at a time — the
//! request payloads flowing through the coordinator, or a sensor stream
//! whose underlying operator drifts. This module maintains the sparse
//! factorization *incrementally* from that stream.
//!
//! # State
//!
//! An [`OnlinePalm`] learner carries:
//!
//! | field        | meaning                                                    |
//! |--------------|------------------------------------------------------------|
//! | `state`      | the PALM variables: factors `S_1..S_J` + λ                 |
//! | `surrogate`  | `Â ∈ R^{m×n}` — per-column running average of observations |
//! | `weights`    | `w ∈ R^n` — per-column observation mass (0 = never seen)   |
//!
//! Observing column `j` with payload `a` folds it into the surrogate:
//!
//! ```text
//! w_j = 0:   â_j ← a,                      w_j ← 1        (first sighting)
//! w_j > 0:   â_j ← (w_j·â_j + a)/(w_j+1),  w_j ← w_j + 1  (running mean)
//! ```
//!
//! and a forgetting factor `ρ ∈ (0, 1]` ([`OnlineConfig::forgetting`]),
//! applied once per mini-batch, decays every `w_j` so stale observations
//! lose mass under drift (`ρ = 1` never forgets — the pure running-mean
//! regime).
//!
//! # Update
//!
//! Each [`OnlinePalm::sweep`] runs one Gauss–Seidel pass of projected
//! gradient steps on the *weighted* surrogate objective
//!
//! ```text
//! f(S, λ) = ½ ‖(Â − λ S_J ⋯ S_1) D‖_F²,   D = diag(√w_1 … √w_n)
//! ```
//!
//! reusing the batch driver's prefix-product sweep cache, its warm-started
//! power iterations, and its exact kernel sequence. The weighting enters
//! in precisely four places: the residual's columns are scaled by `w_j`,
//! the Lipschitz modulus picks up a `max_j w_j` factor (‖R D‖₂² ≤
//! ‖R‖₂² max w), and the λ and objective accumulations weight their
//! per-column terms. Because multiplying by `1.0` is bitwise exact, a
//! fresh learner whose mini-batch covered every column exactly once (all
//! `w_j = 1`) reproduces one batch PALM iteration **bitwise** — the
//! online/batch boundary proptest below pins this.
//!
//! # Determinism
//!
//! Given a fixed observation stream, sweeps are bitwise reproducible at
//! any thread count (all ctx kernels are thread-invariant), and every
//! sweep increments the process-wide [`super::iterations_total`] witness.
//!
//! # Example: stream columns, watch the error fall
//!
//! ```
//! use faust::engine::ExecCtx;
//! use faust::palm::online::{OnlineConfig, OnlinePalm};
//! use faust::palm::PalmConfig;
//! use faust::prox::Constraint;
//!
//! let a = faust::transforms::hadamard(4);
//! let cfg = OnlineConfig::new(PalmConfig::new(
//!     vec![Constraint::SpRowCol(2), Constraint::SpRowCol(2)],
//!     1,
//! ));
//! let mut learner = OnlinePalm::cold(&[(4, 4), (4, 4)], cfg);
//! let ctx = ExecCtx::new(1);
//! let mut first = f64::NAN;
//! let mut last = f64::NAN;
//! for pass in 0..40 {
//!     // One mini-batch per pass: every column of the (static) target.
//!     let batch: Vec<(usize, Vec<f64>)> = (0..4).map(|j| (j, a.col(j))).collect();
//!     let step = learner.step(&ctx, &batch);
//!     if pass == 0 {
//!         first = step.rel_err;
//!     }
//!     last = step.rel_err;
//! }
//! // The weighted relative error falls as the stream accumulates.
//! assert!(last < 0.5 * first, "rel_err {first} -> {last} never fell");
//! assert!(last < 0.05, "hadamard should factorize nearly exactly: {last}");
//! ```

use super::{FactorState, PalmConfig, SweepCache, UpdateOrder, ITERATIONS_TOTAL};
use crate::engine::ExecCtx;
use crate::faust::Faust;
use crate::linalg::Mat;
use crate::prox::Constraint;
use std::sync::atomic::Ordering;

/// Configuration of one online learner: the PALM geometry (constraints,
/// step margin, sweep order — `n_iter` is ignored; the *stream* decides
/// how many sweeps run) plus the streaming-specific forgetting factor.
#[derive(Clone, Debug)]
pub struct OnlineConfig {
    /// Constraint set, step margin `alpha`, and sweep order. `n_iter`
    /// and `rel_tol` are unused — sweeps run as mini-batches arrive.
    pub palm: PalmConfig,
    /// Per-mini-batch decay `ρ ∈ (0, 1]` of every column's observation
    /// mass. `1.0` (the default) never forgets: the surrogate is the
    /// exact running mean of all observations. Under drift, `ρ < 1`
    /// lets fresh observations outweigh stale ones.
    pub forgetting: f64,
}

impl OnlineConfig {
    /// `palm` geometry with no forgetting (`ρ = 1`).
    pub fn new(palm: PalmConfig) -> Self {
        OnlineConfig { palm, forgetting: 1.0 }
    }

    /// Same geometry with forgetting factor `rho` (clamped to (0, 1]).
    pub fn with_forgetting(mut self, rho: f64) -> Self {
        self.forgetting = if rho.is_finite() { rho.clamp(f64::MIN_POSITIVE, 1.0) } else { 1.0 };
        self
    }
}

/// What one [`OnlinePalm::sweep`] reports.
#[derive(Clone, Copy, Debug)]
pub struct OnlineStep {
    /// Weighted surrogate objective `½ Σ_j w_j ‖â_j − λ (Π S)_j‖²` after
    /// the sweep. Grows with accumulated observation mass — compare
    /// [`OnlineStep::rel_err`] across sweeps, not this.
    pub objective: f64,
    /// Scale-invariant weighted relative error
    /// `‖(Â − λΠS) D‖_F / ‖Â D‖_F` — the drift-tracking signal the
    /// coordinator's swap cadence and metrics report.
    pub rel_err: f64,
    /// λ after the sweep's closed-form update.
    pub lambda: f64,
}

/// A streaming palm4MSA learner (see the module docs).
#[derive(Clone, Debug)]
pub struct OnlinePalm {
    cfg: OnlineConfig,
    st: FactorState,
    surrogate: Mat,
    weights: Vec<f64>,
    l_warm: Vec<Vec<f64>>,
    r_warm: Vec<Vec<f64>>,
    cols_seen: u64,
    batches: u64,
}

impl OnlinePalm {
    /// Cold start: paper-default factor init (`S_1 = 0`, rest identity,
    /// `λ = 1`) for the factor shapes `dims[j] = (rows, cols)`,
    /// rightmost first (same convention as [`FactorState::default_init`]).
    pub fn cold(dims: &[(usize, usize)], cfg: OnlineConfig) -> OnlinePalm {
        OnlinePalm::warm(FactorState::default_init(dims), cfg)
    }

    /// Warm start from an existing factor state — the serving
    /// generation's factors and λ, so the stream refines rather than
    /// relearns (the coordinator's `OnlineLearner` path).
    pub fn warm(init: FactorState, cfg: OnlineConfig) -> OnlinePalm {
        let nfac = init.mats.len();
        assert_eq!(cfg.palm.constraints.len(), nfac, "constraint/factor count mismatch");
        let rows = init.mats.last().expect("at least one factor").rows();
        let cols = init.mats[0].cols();
        OnlinePalm {
            cfg,
            st: init,
            surrogate: Mat::zeros(rows, cols),
            weights: vec![0.0; cols],
            l_warm: vec![vec![]; nfac],
            r_warm: vec![vec![]; nfac],
            cols_seen: 0,
            batches: 0,
        }
    }

    /// Resume from persisted surrogate state (a store snapshot's online
    /// section): `warm` plus the surrogate, weights and counters exactly
    /// as they were at persist time.
    pub fn from_parts(
        init: FactorState,
        cfg: OnlineConfig,
        surrogate: Mat,
        weights: Vec<f64>,
        cols_seen: u64,
        batches: u64,
    ) -> OnlinePalm {
        let mut ol = OnlinePalm::warm(init, cfg);
        assert_eq!(surrogate.shape(), ol.surrogate.shape(), "surrogate shape mismatch");
        assert_eq!(weights.len(), ol.weights.len(), "weight count mismatch");
        ol.surrogate = surrogate;
        ol.weights = weights;
        ol.cols_seen = cols_seen;
        ol.batches = batches;
        ol
    }

    /// Fold one observed column into the surrogate (no decay — decay is
    /// per mini-batch, applied by [`OnlinePalm::step`]).
    ///
    /// # Panics
    /// If `j` is out of range or `col` has the wrong length.
    pub fn observe(&mut self, j: usize, col: &[f64]) {
        let (m, n) = self.surrogate.shape();
        assert!(j < n, "column index {j} out of range (n = {n})");
        assert_eq!(col.len(), m, "observed column length");
        let w = self.weights[j];
        if w == 0.0 {
            // First sighting: bitwise copy (the running-mean arithmetic
            // would round, and `0·0 + a` can flip -0.0 signs).
            for (i, &v) in col.iter().enumerate() {
                self.surrogate.set(i, j, v);
            }
            self.weights[j] = 1.0;
        } else {
            let inv = 1.0 / (w + 1.0);
            for (i, &v) in col.iter().enumerate() {
                let old = self.surrogate.at(i, j);
                self.surrogate.set(i, j, (w * old + v) * inv);
            }
            self.weights[j] = w + 1.0;
        }
        self.cols_seen += 1;
    }

    /// Decay every column's observation mass by the forgetting factor
    /// (one mini-batch boundary). A no-op when `ρ = 1`.
    pub fn decay(&mut self) {
        let rho = self.cfg.forgetting;
        if rho < 1.0 {
            for w in &mut self.weights {
                *w *= rho;
            }
        }
    }

    /// One mini-batch: decay, fold every `(column, payload)` observation
    /// into the surrogate, then run one weighted sweep.
    pub fn step(&mut self, ctx: &ExecCtx, batch: &[(usize, Vec<f64>)]) -> OnlineStep {
        self.decay();
        for (j, col) in batch {
            self.observe(*j, col);
        }
        self.batches += 1;
        self.sweep(ctx)
    }

    /// One weighted Gauss–Seidel sweep over the factors + λ update —
    /// the batch driver's exact kernel sequence on the surrogate, with
    /// the four weighted deviations described in the module docs.
    pub fn sweep(&mut self, ctx: &ExecCtx) -> OnlineStep {
        let cfg = &self.cfg.palm;
        let st = &mut self.st;
        let a = &self.surrogate;
        let nfac = cfg.constraints.len();
        let max_w = self.weights.iter().cloned().fold(0.0f64, f64::max);
        let order: Vec<usize> = match cfg.update_order {
            UpdateOrder::RightToLeft => (0..nfac).collect(),
            UpdateOrder::LeftToRight => (0..nfac).rev().collect(),
        };
        let mut cache = SweepCache::build(ctx, &st.mats, cfg.update_order);
        for &j in &order {
            let (l, r) = cache.sides(j, cfg.update_order);
            if !matches!(cfg.constraints[j], Constraint::Frozen) {
                // Lipschitz modulus of the weighted objective:
                // λ² ‖L‖₂² ‖R D‖₂² ≤ λ² ‖L‖₂² ‖R‖₂² · max_j w_j.
                let l_norm =
                    l.map_or(1.0, |m| ctx.spectral_norm_warm(m, &mut self.l_warm[j], 50, 1e-9));
                let r_norm =
                    r.map_or(1.0, |m| ctx.spectral_norm_warm(m, &mut self.r_warm[j], 50, 1e-9));
                let c = (1.0 + cfg.alpha)
                    * st.lambda
                    * st.lambda
                    * l_norm
                    * l_norm
                    * r_norm
                    * r_norm
                    * max_w;
                if c <= 0.0 || !c.is_finite() {
                    // Degenerate chain or empty surrogate: project only.
                    st.mats[j] = cfg.constraints[j].project(&st.mats[j]);
                } else {
                    // grad = λ Lᵀ ((λ L S R − Â) W) Rᵀ, W = diag(w).
                    let s = &st.mats[j];
                    let ls = match l {
                        None => s.clone(),
                        Some(lm) => ctx.gemm(lm, s),
                    };
                    let lsr = match r {
                        None => ls,
                        Some(rm) => ctx.gemm(&ls, rm),
                    };
                    let mut err = lsr;
                    err.scale(st.lambda);
                    err = err.sub(a);
                    scale_cols(&mut err, &self.weights);
                    let lt_err = match l {
                        None => err,
                        Some(lm) => ctx.gemm_tn(lm, &err),
                    };
                    let mut grad = match r {
                        None => lt_err,
                        Some(rm) => ctx.gemm_nt(&lt_err, rm),
                    };
                    grad.scale(st.lambda);
                    let mut stepped = st.mats[j].clone();
                    stepped.axpy(-1.0 / c, &grad);
                    st.mats[j] = cfg.constraints[j].project(&stepped);
                }
            }
            cache.fold(ctx, &st.mats[j], cfg.update_order);
        }
        // Weighted closed-form λ: Tr(Aᵀ Â W) / Tr(Âᵀ Â W), accumulated
        // in the batch driver's data order so `w ≡ 1` matches bitwise.
        let a_hat = cache.into_product();
        let denom = weighted_dot(&a_hat, &a_hat, &self.weights);
        if denom > 0.0 {
            st.lambda = weighted_dot(a, &a_hat, &self.weights) / denom;
        }
        ITERATIONS_TOTAL.fetch_add(1, Ordering::Relaxed);
        let objective = weighted_objective(a, &a_hat, st.lambda, &self.weights);
        let energy = weighted_dot(a, a, &self.weights);
        let rel_err = if energy > 0.0 { (2.0 * objective / energy).sqrt() } else { 0.0 };
        OnlineStep { objective, rel_err, lambda: st.lambda }
    }

    /// The current factor state (factors + λ).
    pub fn state(&self) -> &FactorState {
        &self.st
    }

    /// Weighted relative error of an *arbitrary* factor state measured
    /// against the current surrogate — the same metric as
    /// [`OnlineStep::rel_err`]. This is how a swap policy re-scores a
    /// previously published generation: under drift the surrogate keeps
    /// moving, so a generation's error is a function of *now*, not of
    /// when it shipped.
    pub fn rel_err_of(&self, ctx: &ExecCtx, st: &FactorState) -> f64 {
        let a = &self.surrogate;
        let energy = weighted_dot(a, a, &self.weights);
        if energy <= 0.0 {
            return 0.0;
        }
        let a_hat = st.product_ctx(ctx);
        let objective = weighted_objective(a, &a_hat, st.lambda, &self.weights);
        (2.0 * objective / energy).sqrt()
    }

    /// The surrogate `Â` (running per-column means).
    pub fn surrogate(&self) -> &Mat {
        &self.surrogate
    }

    /// Per-column observation mass `w` (0 = never observed).
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    /// Total columns ever observed (with repetition).
    pub fn cols_seen(&self) -> u64 {
        self.cols_seen
    }

    /// Mini-batches stepped so far.
    pub fn batches(&self) -> u64 {
        self.batches
    }

    /// Snapshot the current factors as a servable [`Faust`] (the
    /// generation the coordinator epoch-swaps in).
    pub fn to_faust(&self) -> Faust {
        self.st.clone().into_faust()
    }
}

/// Scale column `j` of `m` by `w[j]` in place.
fn scale_cols(m: &mut Mat, w: &[f64]) {
    let cols = m.cols();
    for (idx, v) in m.data_mut().iter_mut().enumerate() {
        *v *= w[idx % cols];
    }
}

/// `Σ_{i,j} a[i,j]·b[i,j]·w[j]`, accumulated in row-major data order —
/// with `w ≡ 1` this is bitwise [`Mat::dot`] / [`Mat::fro2`].
fn weighted_dot(a: &Mat, b: &Mat, w: &[f64]) -> f64 {
    let cols = a.cols();
    a.data()
        .iter()
        .zip(b.data())
        .enumerate()
        .map(|(idx, (av, bv))| av * bv * w[idx % cols])
        .sum()
}

/// `½ Σ_{i,j} w_j (a[i,j] − λ p[i,j])²` in data order — with `w ≡ 1`
/// this is bitwise `objective_of`.
fn weighted_objective(a: &Mat, product: &Mat, lambda: f64, w: &[f64]) -> f64 {
    let cols = a.cols();
    0.5 * a
        .data()
        .iter()
        .zip(product.data())
        .enumerate()
        .map(|(idx, (av, pv))| {
            let d = av - lambda * pv;
            d * d * w[idx % cols]
        })
        .sum::<f64>()
}

#[cfg(test)]
mod tests {
    use super::super::{palm4msa_with_ctx, PalmConfig};
    use super::*;
    use crate::rng::Rng;
    use crate::testutil::{check, ensure, PropConfig};

    fn assert_states_bitwise_eq(a: &FactorState, b: &FactorState, tag: &str) {
        assert_eq!(a.lambda.to_bits(), b.lambda.to_bits(), "{tag}: lambda");
        assert_eq!(a.mats.len(), b.mats.len(), "{tag}: factor count");
        for (p, q) in a.mats.iter().zip(&b.mats) {
            assert_eq!(p.data(), q.data(), "{tag}: factor bits");
        }
    }

    /// The online/batch boundary contract (ISSUE 9): one cold mini-batch
    /// covering *all* columns exactly once, warm start disabled, is one
    /// full batch PALM sweep — bitwise, across shapes, sweep orders,
    /// constraint budgets and thread counts.
    #[test]
    fn cold_full_cover_batch_is_one_palm_sweep_bitwise() {
        check(
            "online_full_cover_matches_palm",
            &PropConfig { cases: 48, ..PropConfig::default() },
            |rng| {
                let m = 3 + rng.below(6);
                let n = 3 + rng.below(6);
                let k = 2 + rng.below(5);
                let a = crate::testutil::gen::mat_shaped(rng, m, n);
                let dims = [(k, n), (m, k)];
                let budget1 = 1 + rng.below(k * n);
                let budget2 = 1 + rng.below(m * k);
                let mut cfg = PalmConfig::new(
                    vec![Constraint::SpGlobal(budget1), Constraint::SpGlobal(budget2)],
                    1,
                );
                if rng.below(2) == 1 {
                    cfg.update_order = UpdateOrder::LeftToRight;
                }
                let threads = [1usize, 4][rng.below(2)];
                let ctx = ExecCtx::new(threads);
                let solo =
                    palm4msa_with_ctx(&ctx, &a, FactorState::default_init(&dims), &cfg);

                let mut ol = OnlinePalm::cold(&dims, OnlineConfig::new(cfg));
                // Observe every column exactly once, in a shuffled order
                // (surrogate assembly is order-independent for first
                // sightings), then sweep.
                let mut idx: Vec<usize> = (0..n).collect();
                rng.shuffle(&mut idx);
                for &j in &idx {
                    ol.observe(j, &a.col(j));
                }
                ensure(ol.weights().iter().all(|&w| w == 1.0), "uniform unit weights")?;
                ensure(ol.surrogate().data() == a.data(), "surrogate == target bitwise")?;
                let step = ol.sweep(&ctx);

                ensure(
                    ol.state().lambda.to_bits() == solo.state.lambda.to_bits(),
                    format!("lambda {} != {}", ol.state().lambda, solo.state.lambda),
                )?;
                for (p, q) in ol.state().mats.iter().zip(&solo.state.mats) {
                    ensure(p.data() == q.data(), "factor bits diverged")?;
                }
                ensure(
                    step.objective.to_bits() == solo.objective_trace[0].to_bits(),
                    format!(
                        "objective {} != {}",
                        step.objective, solo.objective_trace[0]
                    ),
                )?;
                Ok(())
            },
        );
    }

    #[test]
    fn repeated_stream_converges_like_batch_palm() {
        // Streaming the same static operator's columns over and over
        // (uniform weights throughout) follows the batch trajectory:
        // after T mini-batches the learner is as good as T batch sweeps.
        let mut rng = Rng::new(71);
        let a = crate::transforms::hadamard(8);
        let cfg = PalmConfig::new(
            vec![Constraint::SpRowCol(2); 3],
            1,
        );
        let ctx = ExecCtx::new(2);
        let dims = [(8, 8), (8, 8), (8, 8)];
        let mut ol = OnlinePalm::cold(&dims, OnlineConfig::new(cfg));
        let mut last = f64::INFINITY;
        for _ in 0..30 {
            let mut idx: Vec<usize> = (0..8).collect();
            rng.shuffle(&mut idx);
            let batch: Vec<(usize, Vec<f64>)> = idx.iter().map(|&j| (j, a.col(j))).collect();
            last = ol.step(&ctx, &batch).rel_err;
        }
        assert!(last < 1e-3, "streamed hadamard never converged: rel_err={last}");
        let f = ol.to_faust();
        assert!(f.relative_error_fro(&a) < 1e-3);
    }

    #[test]
    fn rel_err_of_scores_states_against_the_current_surrogate() {
        // The learner's own state scores its last sweep's error, and a
        // stale snapshot scores *worse* once forgetting has moved the
        // surrogate on to a different operator — the property the swap
        // policy's staleness-aware gate relies on.
        let mut rng = Rng::new(33);
        let n = 6;
        let a0 = crate::linalg::Mat::randn(n, n, &mut rng);
        let a1 = crate::linalg::Mat::randn(n, n, &mut rng);
        let cfg = OnlineConfig::new(PalmConfig::new(
            vec![Constraint::SpGlobal(n * n); 2],
            1,
        ))
        .with_forgetting(0.5);
        let ctx = ExecCtx::new(1);
        let mut ol = OnlinePalm::cold(&[(n, n); 2], cfg);
        let feed = |ol: &mut OnlinePalm, a: &crate::linalg::Mat, passes: usize| {
            let mut last = f64::NAN;
            for _ in 0..passes {
                let batch: Vec<(usize, Vec<f64>)> =
                    (0..n).map(|j| (j, a.col(j))).collect();
                last = ol.step(&ctx, &batch).rel_err;
            }
            last
        };
        let r0 = feed(&mut ol, &a0, 20);
        let st0 = ol.state().clone();
        let scored = ol.rel_err_of(&ctx, &st0);
        assert!(
            (scored - r0).abs() <= 1e-9 * r0.max(1.0),
            "self-score {scored} far from last sweep's rel_err {r0}"
        );
        feed(&mut ol, &a1, 20);
        let stale = ol.rel_err_of(&ctx, &st0);
        let fresh = ol.rel_err_of(&ctx, ol.state());
        assert!(
            fresh < stale,
            "stale snapshot must score worse on the moved surrogate: {fresh} vs {stale}"
        );
    }

    #[test]
    fn warm_start_refines_instead_of_relearning() {
        // A warm learner seeded with an already-good factorization must
        // start at (and stay near) that error, while a cold learner
        // starts far worse after the same single mini-batch.
        let a = crate::transforms::hadamard(8);
        let cfg = PalmConfig::new(vec![Constraint::SpRowCol(2); 3], 60);
        let ctx = ExecCtx::new(1);
        let dims = [(8, 8), (8, 8), (8, 8)];
        let batch_res =
            palm4msa_with_ctx(&ctx, &a, FactorState::default_init(&dims), &cfg);
        let mut one = cfg.clone();
        one.n_iter = 1;
        let batch: Vec<(usize, Vec<f64>)> = (0..8).map(|j| (j, a.col(j))).collect();

        let mut warm = OnlinePalm::warm(batch_res.state.clone(), OnlineConfig::new(one.clone()));
        let warm_err = warm.step(&ctx, &batch).rel_err;

        let mut cold = OnlinePalm::cold(&dims, OnlineConfig::new(one));
        let cold_err = cold.step(&ctx, &batch).rel_err;

        assert!(
            warm_err < cold_err * 0.5,
            "warm start no better than cold: warm={warm_err} cold={cold_err}"
        );
    }

    #[test]
    fn forgetting_tracks_a_replaced_operator() {
        // The operator changes wholesale mid-stream. With forgetting the
        // learner re-converges to the new operator; the surrogate's mass
        // decays so fresh columns dominate.
        let mut rng = Rng::new(72);
        let a0 = Mat::randn(6, 6, &mut rng);
        let a1 = Mat::randn(6, 6, &mut rng);
        let cfg = PalmConfig::new(
            vec![Constraint::SpGlobal(30), Constraint::SpGlobal(30)],
            1,
        );
        let ctx = ExecCtx::new(1);
        let dims = [(6, 6), (6, 6)];
        let mut ol =
            OnlinePalm::cold(&dims, OnlineConfig::new(cfg).with_forgetting(0.5));
        let feed = |ol: &mut OnlinePalm, ctx: &ExecCtx, a: &Mat, passes: usize| {
            let mut last = f64::INFINITY;
            for _ in 0..passes {
                let batch: Vec<(usize, Vec<f64>)> =
                    (0..6).map(|j| (j, a.col(j))).collect();
                last = ol.step(ctx, &batch).rel_err;
            }
            last
        };
        let _ = feed(&mut ol, &ctx, &a0, 40);
        let _ = feed(&mut ol, &ctx, &a1, 40);
        // Re-converged to the *new* operator, not stuck on the old one.
        let f = ol.to_faust();
        let (drifted, stale) = (f.relative_error_fro(&a1), f.relative_error_fro(&a0));
        assert!(drifted < stale, "learner still fits the stale operator: {drifted} vs {stale}");
    }

    #[test]
    fn from_parts_round_trips_learner_state() {
        let mut rng = Rng::new(73);
        let a = Mat::randn(5, 5, &mut rng);
        let cfg = PalmConfig::new(
            vec![Constraint::SpGlobal(15), Constraint::SpGlobal(15)],
            1,
        );
        let ctx = ExecCtx::new(1);
        let mut ol = OnlinePalm::cold(&[(5, 5), (5, 5)], OnlineConfig::new(cfg.clone()));
        for _ in 0..3 {
            let batch: Vec<(usize, Vec<f64>)> = (0..5).map(|j| (j, a.col(j))).collect();
            ol.step(&ctx, &batch);
        }
        // Two independent resumes from the same persisted parts take
        // bitwise-identical next steps (no hidden state beyond the
        // parts; power-iteration warm caches rebuild in one sweep).
        let resume = || {
            OnlinePalm::from_parts(
                ol.state().clone(),
                OnlineConfig::new(cfg.clone()),
                ol.surrogate().clone(),
                ol.weights().to_vec(),
                ol.cols_seen(),
                ol.batches(),
            )
        };
        let mut x = resume();
        let mut y = resume();
        assert_eq!(x.cols_seen(), 15);
        assert_eq!(x.batches(), 3);
        assert_eq!(x.surrogate().data(), ol.surrogate().data());
        let batch: Vec<(usize, Vec<f64>)> = (0..5).map(|j| (j, a.col(j))).collect();
        let sx = x.step(&ctx, &batch);
        let sy = y.step(&ctx, &batch);
        assert_eq!(sx.objective.to_bits(), sy.objective.to_bits());
        assert_states_bitwise_eq(x.state(), y.state(), "resumed step");
    }

    #[test]
    fn sweeps_count_into_the_global_witness() {
        let before = crate::palm::iterations_total();
        let a = crate::transforms::hadamard(4);
        let cfg = PalmConfig::new(vec![Constraint::SpRowCol(2); 2], 1);
        let ctx = ExecCtx::new(1);
        let mut ol = OnlinePalm::cold(&[(4, 4), (4, 4)], OnlineConfig::new(cfg));
        let batch: Vec<(usize, Vec<f64>)> = (0..4).map(|j| (j, a.col(j))).collect();
        ol.step(&ctx, &batch);
        ol.step(&ctx, &batch);
        assert!(crate::palm::iterations_total() >= before + 2);
    }
}
