//! Dense row-major matrix with the operations the FAuST stack needs,
//! generic over the engine's [`Scalar`] element type (default `f64`).
//!
//! This is deliberately a small, dependency-free dense kernel set: GEMM in
//! the four transpose variants (blocked, written so the inner loops are
//! auto-vectorizable), axpy-style updates, norms, and slicing. The heavy
//! lifting in the library (palm4MSA gradients, K-SVD, OMP Gram updates)
//! bottoms out here. The structural accessors (rows, slicing, transpose)
//! are generic so the f32 serving tier ([`Mat<f32>`], ROADMAP item j) can
//! run the same register-tiled kernels; the factorization math stays
//! `f64`-only — quantization happens once per plan build, never inside a
//! solver.

use crate::engine::kernel::Scalar;
use crate::rng::Rng;
use std::fmt;

/// Dense row-major matrix of [`Scalar`] elements (`f64` by default).
#[derive(Clone, PartialEq)]
pub struct Mat<S = f64> {
    rows: usize,
    cols: usize,
    data: Vec<S>,
}

// Bounded on `S: Debug` (not `Scalar`) so `#[derive(Debug)]` on
// containers of `Mat<S>` — whose derived impls only add per-type-param
// `Debug` bounds — stays well-formed.
impl<S: fmt::Debug> fmt::Debug for Mat<S> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let rmax = self.rows.min(6);
        let cmax = self.cols.min(8);
        for i in 0..rmax {
            write!(f, "  ")?;
            for j in 0..cmax {
                write!(f, "{:>10.4?} ", self.data[i * self.cols + j])?;
            }
            writeln!(f, "{}", if cmax < self.cols { "…" } else { "" })?;
        }
        if rmax < self.rows {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

impl<S: Scalar> Mat<S> {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![S::ZERO; rows * cols] }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<S>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer length mismatch");
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)` pair.
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> S {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: S) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn data(&self) -> &[S] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [S] {
        &mut self.data
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[S] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [S] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy of column `j`.
    pub fn col(&self, j: usize) -> Vec<S> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    /// Overwrite column `j`.
    pub fn set_col(&mut self, j: usize, v: &[S]) {
        assert_eq!(v.len(), self.rows);
        for (i, &x) in v.iter().enumerate() {
            self.set(i, j, x);
        }
    }

    /// Transposed copy.
    pub fn t(&self) -> Mat<S> {
        let mut out = Mat::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on big operators.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// Frobenius norm (accumulated in f64 for both element types).
    pub fn fro(&self) -> f64 {
        self.data
            .iter()
            .map(|x| {
                let v = x.to_f64();
                v * v
            })
            .sum::<f64>()
            .sqrt()
    }

    /// Number of non-zero entries (`‖·‖₀`).
    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|x| **x != S::ZERO).count()
    }

    /// Quantize/convert every entry to another scalar type (f64 → f32
    /// rounds to nearest; f32 → f64 is exact).
    pub fn convert<T: Scalar>(&self) -> Mat<T> {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|v| T::from_f64(v.to_f64())).collect(),
        }
    }
}

impl Mat<f64> {
    /// Quantized f32 copy (the serving tier's one-time plan-build
    /// conversion).
    pub fn to_f32(&self) -> Mat<f32> {
        self.convert()
    }
}

impl Mat<f32> {
    /// Exact widening back to the f64 reference representation.
    pub fn to_f64(&self) -> Mat<f64> {
        self.convert()
    }
}

impl Mat {
    /// Rectangular identity: ones on the main diagonal, zeros elsewhere
    /// (the paper's default initialization for factors `j >= 2`).
    pub fn eye(rows: usize, cols: usize) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows.min(cols) {
            m.set(i, i, 1.0);
        }
        m
    }

    /// Build from a closure over `(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// iid standard-Gaussian matrix.
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        Mat { rows, cols, data: rng.gauss_vec(rows * cols) }
    }

    /// Squared Frobenius norm.
    pub fn fro2(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>()
    }

    /// Scale in place.
    pub fn scale(&mut self, s: f64) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// Scaled copy.
    pub fn scaled(&self, s: f64) -> Mat {
        let mut m = self.clone();
        m.scale(s);
        m
    }

    /// `self + other`.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!(self.shape(), other.shape());
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// `self - other`.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!(self.shape(), other.shape());
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// In-place `self += s * other`.
    pub fn axpy(&mut self, s: f64, other: &Mat) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }

    /// Frobenius inner product `<self, other>`.
    pub fn dot(&self, other: &Mat) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data.iter().zip(&other.data).map(|(a, b)| a * b).sum()
    }

    /// Matrix–vector product `self * x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "matvec dim mismatch");
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for (a, b) in row.iter().zip(x) {
                acc += a * b;
            }
            y[i] = acc;
        }
        y
    }

    /// Transposed matrix–vector product `selfᵀ * x`.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows, "matvec_t dim mismatch");
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            let row = self.row(i);
            for (yj, &a) in y.iter_mut().zip(row) {
                *yj += xi * a;
            }
        }
        y
    }

    /// `self * other` — blocked ikj GEMM (auto-vectorizable inner loop).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        const BK: usize = 64;
        for kb in (0..k).step_by(BK) {
            let kend = (kb + BK).min(k);
            for i in 0..m {
                let arow = &self.data[i * k..(i + 1) * k];
                let orow = &mut out.data[i * n..(i + 1) * n];
                for kk in kb..kend {
                    let a = arow[kk];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &other.data[kk * n..(kk + 1) * n];
                    for (o, &b) in orow.iter_mut().zip(brow) {
                        *o += a * b;
                    }
                }
            }
        }
        out
    }

    /// `selfᵀ * other` without forming the transpose.
    pub fn matmul_tn(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "matmul_tn dim mismatch");
        let (k, m, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        for kk in 0..k {
            let arow = &self.data[kk * m..(kk + 1) * m];
            let brow = &other.data[kk * n..(kk + 1) * n];
            for i in 0..m {
                let a = arow[i];
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * n..(i + 1) * n];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// `self * otherᵀ` without forming the transpose.
    pub fn matmul_nt(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_nt dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out.data[i * n..(i + 1) * n];
            for (j, o) in orow.iter_mut().enumerate() {
                let brow = &other.data[j * k..(j + 1) * k];
                let mut acc = 0.0;
                for (a, b) in arow.iter().zip(brow) {
                    acc += a * b;
                }
                *o = acc;
            }
        }
        out
    }

    /// Trace of `selfᵀ * other` computed without the product (Frobenius dot).
    pub fn trace_tn(&self, other: &Mat) -> f64 {
        self.dot(other)
    }

    /// Extract the sub-matrix of the given rows/cols ranges.
    pub fn submatrix(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Mat {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        Mat::from_fn(r1 - r0, c1 - c0, |i, j| self.at(r0 + i, c0 + j))
    }

    /// Gather the given columns into a new matrix (OMP support extraction).
    pub fn select_cols(&self, idx: &[usize]) -> Mat {
        Mat::from_fn(self.rows, idx.len(), |i, j| self.at(i, idx[j]))
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0_f64, |m, x| m.max(x.abs()))
    }

    /// Normalize each column to unit l2 norm; returns the original norms.
    /// Zero columns are left untouched (norm reported as 0).
    pub fn normalize_cols(&mut self) -> Vec<f64> {
        let mut norms = vec![0.0; self.cols];
        for i in 0..self.rows {
            for j in 0..self.cols {
                let v = self.at(i, j);
                norms[j] += v * v;
            }
        }
        for n in &mut norms {
            *n = n.sqrt();
        }
        for i in 0..self.rows {
            for j in 0..self.cols {
                if norms[j] > 0.0 {
                    let v = self.at(i, j) / norms[j];
                    self.set(i, j, v);
                }
            }
        }
        norms
    }

    /// Relative Frobenius distance `‖self − other‖_F / ‖other‖_F`.
    pub fn rel_fro_err(&self, reference: &Mat) -> f64 {
        self.sub(reference).fro() / reference.fro().max(1e-300)
    }
}

/// Product of a chain of matrices `ms[0] * ms[1] * … * ms[k-1]`.
/// Returns identity of size `fallback` if the chain is empty.
pub fn chain_product(ms: &[&Mat], fallback: usize) -> Mat {
    match ms.split_first() {
        None => Mat::eye(fallback, fallback),
        Some((first, rest)) => {
            let mut acc = (*first).clone();
            for m in rest {
                acc = acc.matmul(m);
            }
            acc
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn eye_matmul_identity() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(5, 7, &mut rng);
        let i5 = Mat::eye(5, 5);
        let i7 = Mat::eye(7, 7);
        assert!(i5.matmul(&a).rel_fro_err(&a) < 1e-15);
        assert!(a.matmul(&i7).rel_fro_err(&a) < 1e-15);
    }

    #[test]
    fn matmul_against_naive() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(13, 9, &mut rng);
        let b = Mat::randn(9, 11, &mut rng);
        let c = a.matmul(&b);
        for i in 0..13 {
            for j in 0..11 {
                let mut acc = 0.0;
                for k in 0..9 {
                    acc += a.at(i, k) * b.at(k, j);
                }
                assert!(approx(c.at(i, j), acc, 1e-12));
            }
        }
    }

    #[test]
    fn transpose_variants_consistent() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(8, 6, &mut rng);
        let b = Mat::randn(8, 5, &mut rng);
        let c = Mat::randn(4, 6, &mut rng);
        // AᵀB
        assert!(a.matmul_tn(&b).rel_fro_err(&a.t().matmul(&b)) < 1e-13);
        // ACᵀ
        assert!(a.matmul_nt(&c).rel_fro_err(&a.matmul(&c.t())) < 1e-13);
        // (Aᵀ)ᵀ = A
        assert!(a.t().t().rel_fro_err(&a) < 1e-15);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(10, 7, &mut rng);
        let x = rng.gauss_vec(7);
        let xm = Mat::from_vec(7, 1, x.clone());
        let y = a.matvec(&x);
        let ym = a.matmul(&xm);
        for i in 0..10 {
            assert!(approx(y[i], ym.at(i, 0), 1e-13));
        }
        // transpose path
        let z = rng.gauss_vec(10);
        let yt = a.matvec_t(&z);
        let zt = a.t().matvec(&z);
        for j in 0..7 {
            assert!(approx(yt[j], zt[j], 1e-13));
        }
    }

    #[test]
    fn norms_and_nnz() {
        let m = Mat::from_vec(2, 2, vec![3.0, 0.0, 0.0, 4.0]);
        assert!(approx(m.fro(), 5.0, 1e-15));
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn normalize_cols_unit_norm() {
        let mut rng = Rng::new(5);
        let mut a = Mat::randn(6, 4, &mut rng);
        let norms = a.normalize_cols();
        for j in 0..4 {
            let c = a.col(j);
            let n: f64 = c.iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!(approx(n, 1.0, 1e-12));
            assert!(norms[j] > 0.0);
        }
    }

    #[test]
    fn chain_product_empty_and_order() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        let id = chain_product(&[], 3);
        assert!(id.rel_fro_err(&Mat::eye(3, 3)) < 1e-15);
        let ab = chain_product(&[&a, &b], 0);
        assert!(ab.rel_fro_err(&a.matmul(&b)) < 1e-15);
    }

    #[test]
    fn select_cols_and_submatrix() {
        let a = Mat::from_fn(4, 5, |i, j| (i * 5 + j) as f64);
        let s = a.select_cols(&[4, 0]);
        assert_eq!(s.shape(), (4, 2));
        assert_eq!(s.at(2, 0), a.at(2, 4));
        assert_eq!(s.at(3, 1), a.at(3, 0));
        let sub = a.submatrix(1, 3, 2, 5);
        assert_eq!(sub.shape(), (2, 3));
        assert_eq!(sub.at(0, 0), a.at(1, 2));
    }
}
