//! Householder QR decomposition.
//!
//! Used by the randomized range finder in [`crate::linalg::svd`] and by the
//! least-squares solves inside OMP. Thin QR only (`m >= n` produces
//! `Q ∈ R^{m×n}`, `R ∈ R^{n×n}`).

use super::mat::Mat;

/// Thin Householder QR: `a = q * r` with orthonormal columns in `q`.
pub fn qr_thin(a: &Mat) -> (Mat, Mat) {
    let (m, n) = a.shape();
    let k = m.min(n);
    let mut r = a.clone();
    // Householder vectors stored per reflection.
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(k);
    for j in 0..k {
        // Build the Householder vector for column j below the diagonal.
        let mut v = vec![0.0; m - j];
        let mut norm2 = 0.0;
        for i in j..m {
            let x = r.at(i, j);
            v[i - j] = x;
            norm2 += x * x;
        }
        let norm = norm2.sqrt();
        if norm == 0.0 {
            vs.push(v); // zero column: identity reflection
            continue;
        }
        let alpha = if v[0] >= 0.0 { -norm } else { norm };
        v[0] -= alpha;
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 == 0.0 {
            vs.push(v);
            continue;
        }
        // Apply reflection H = I - 2 v vᵀ / (vᵀv) to R[j.., j..].
        for c in j..n {
            let mut dot = 0.0;
            for i in j..m {
                dot += v[i - j] * r.at(i, c);
            }
            let s = 2.0 * dot / vnorm2;
            for i in j..m {
                let val = r.at(i, c) - s * v[i - j];
                r.set(i, c, val);
            }
        }
        vs.push(v);
    }
    // Accumulate Q by applying reflections (in reverse) to the thin identity.
    let mut q = Mat::eye(m, k);
    for j in (0..k).rev() {
        let v = &vs[j];
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 == 0.0 {
            continue;
        }
        for c in 0..k {
            let mut dot = 0.0;
            for i in j..m {
                dot += v[i - j] * q.at(i, c);
            }
            let s = 2.0 * dot / vnorm2;
            for i in j..m {
                let val = q.at(i, c) - s * v[i - j];
                q.set(i, c, val);
            }
        }
    }
    // R is the top k×n block, upper triangular.
    let rt = r.submatrix(0, k, 0, n);
    (q, rt)
}

/// Solve the upper-triangular system `r x = b` by back substitution.
pub fn solve_upper(r: &Mat, b: &[f64]) -> Vec<f64> {
    let n = r.cols();
    assert_eq!(r.rows(), n, "solve_upper expects square R");
    assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut acc = b[i];
        for j in (i + 1)..n {
            acc -= r.at(i, j) * x[j];
        }
        let d = r.at(i, i);
        x[i] = if d.abs() > 1e-300 { acc / d } else { 0.0 };
    }
    x
}

/// Least squares `min ‖a x − b‖₂` via thin QR (for m ≥ n, full column rank).
pub fn lstsq(a: &Mat, b: &[f64]) -> Vec<f64> {
    let (q, r) = qr_thin(a);
    let qtb = q.matvec_t(b);
    solve_upper(&r, &qtb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Rng::new(21);
        for &(m, n) in &[(8usize, 5usize), (10, 10), (6, 3), (12, 7)] {
            let a = Mat::randn(m, n, &mut rng);
            let (q, r) = qr_thin(&a);
            assert_eq!(q.shape(), (m, m.min(n)));
            let qr = q.matmul(&r);
            assert!(qr.rel_fro_err(&a) < 1e-12, "m={m} n={n}");
        }
    }

    #[test]
    fn q_has_orthonormal_columns() {
        let mut rng = Rng::new(22);
        let a = Mat::randn(20, 8, &mut rng);
        let (q, _) = qr_thin(&a);
        let qtq = q.matmul_tn(&q);
        assert!(qtq.rel_fro_err(&Mat::eye(8, 8)) < 1e-12);
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Rng::new(23);
        let a = Mat::randn(9, 6, &mut rng);
        let (_, r) = qr_thin(&a);
        for i in 0..r.rows() {
            for j in 0..i.min(r.cols()) {
                assert!(r.at(i, j).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn lstsq_recovers_exact_solution() {
        let mut rng = Rng::new(24);
        let a = Mat::randn(15, 6, &mut rng);
        let x_true = rng.gauss_vec(6);
        let b = a.matvec(&x_true);
        let x = lstsq(&a, &b);
        for i in 0..6 {
            assert!((x[i] - x_true[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn qr_handles_rank_deficiency_gracefully() {
        // Two identical columns; QR should still reconstruct A.
        let mut rng = Rng::new(25);
        let mut a = Mat::randn(7, 4, &mut rng);
        let c0 = a.col(0);
        a.set_col(2, &c0);
        let (q, r) = qr_thin(&a);
        assert!(q.matmul(&r).rel_fro_err(&a) < 1e-12);
    }
}
