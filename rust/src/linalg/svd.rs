//! Singular value decompositions.
//!
//! Two engines, both dependency-free:
//! - [`svd_jacobi`]: one-sided Jacobi SVD — slow but very robust; used for
//!   small blocks (K-SVD atom updates, SVD-in-randomized-SVD).
//! - [`svd_randomized`]: Halko–Martinsson–Tropp randomized range finder +
//!   Jacobi on the small projected matrix — used for the truncated-SVD
//!   baseline on the 204×8193 MEG operator (paper Fig. 2).

use super::mat::Mat;
use super::qr::qr_thin;
use crate::rng::Rng;

/// Result of a (possibly truncated) SVD: `a ≈ u * diag(s) * vᵀ`.
pub struct Svd {
    pub u: Mat,
    pub s: Vec<f64>,
    pub v: Mat,
}

impl Svd {
    /// Reconstruct the (truncated) matrix `u diag(s) vᵀ`.
    pub fn reconstruct(&self) -> Mat {
        let k = self.s.len();
        let mut us = self.u.clone();
        for i in 0..us.rows() {
            for j in 0..k {
                let v = us.at(i, j) * self.s[j];
                us.set(i, j, v);
            }
        }
        us.matmul_nt(&self.v)
    }

    /// Keep only the top `k` singular triplets.
    pub fn truncate(&self, k: usize) -> Svd {
        let k = k.min(self.s.len());
        Svd {
            u: self.u.submatrix(0, self.u.rows(), 0, k),
            s: self.s[..k].to_vec(),
            v: self.v.submatrix(0, self.v.rows(), 0, k),
        }
    }
}

/// One-sided Jacobi SVD of `a` (m×n, any shape). Returns full rank-min(m,n)
/// decomposition with singular values sorted descending.
pub fn svd_jacobi(a: &Mat) -> Svd {
    // Work on the transpose when m < n so the rotated side is the long one.
    if a.rows() < a.cols() {
        let s = svd_jacobi(&a.t());
        return Svd { u: s.v, s: s.s, v: s.u };
    }
    let (m, n) = a.shape();
    let mut u = a.clone(); // columns will converge to u_i * s_i
    let mut v = Mat::eye(n, n);
    let eps = 1e-14;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0_f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Compute the 2x2 Gram block for columns p, q.
                let mut app = 0.0;
                let mut aqq = 0.0;
                let mut apq = 0.0;
                for i in 0..m {
                    let x = u.at(i, p);
                    let y = u.at(i, q);
                    app += x * x;
                    aqq += y * y;
                    apq += x * y;
                }
                off = off.max(apq.abs() / (app * aqq).sqrt().max(1e-300));
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                // Jacobi rotation zeroing the off-diagonal.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let x = u.at(i, p);
                    let y = u.at(i, q);
                    u.set(i, p, c * x - s * y);
                    u.set(i, q, s * x + c * y);
                }
                for i in 0..n {
                    let x = v.at(i, p);
                    let y = v.at(i, q);
                    v.set(i, p, c * x - s * y);
                    v.set(i, q, s * x + c * y);
                }
            }
        }
        if off < eps {
            break;
        }
    }
    // Extract singular values = column norms of u; normalize u's columns.
    let mut sv: Vec<(f64, usize)> = (0..n)
        .map(|j| {
            let nrm: f64 = (0..m).map(|i| u.at(i, j) * u.at(i, j)).sum::<f64>().sqrt();
            (nrm, j)
        })
        .collect();
    sv.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut u_out = Mat::zeros(m, n);
    let mut v_out = Mat::zeros(n, n);
    let mut s_out = Vec::with_capacity(n);
    for (rank, &(nrm, j)) in sv.iter().enumerate() {
        s_out.push(nrm);
        if nrm > 1e-300 {
            for i in 0..m {
                u_out.set(i, rank, u.at(i, j) / nrm);
            }
        }
        for i in 0..n {
            v_out.set(i, rank, v.at(i, j));
        }
    }
    Svd { u: u_out, s: s_out, v: v_out }
}

/// Randomized truncated SVD of rank `k` with `p` oversampling columns and
/// `q` power iterations (Halko et al. 2011).
pub fn svd_randomized(a: &Mat, k: usize, p: usize, q: usize, rng: &mut Rng) -> Svd {
    let (m, n) = a.shape();
    let l = (k + p).min(m.min(n));
    // Range finder on the shorter side.
    let omega = Mat::randn(n, l, rng);
    let mut y = a.matmul(&omega); // m×l
    let (mut qmat, _) = qr_thin(&y);
    for _ in 0..q {
        // Power iteration with re-orthonormalization for accuracy.
        let z = a.matmul_tn(&qmat); // n×l
        let (qz, _) = qr_thin(&z);
        y = a.matmul(&qz);
        let (qy, _) = qr_thin(&y);
        qmat = qy;
    }
    // Project: B = Qᵀ A  (l×n), small SVD on B.
    let b = qmat.matmul_tn(a);
    let sb = svd_jacobi(&b);
    let u = qmat.matmul(&sb.u);
    Svd { u, s: sb.s, v: sb.v }.truncate(k)
}

/// Spectral norm `‖a‖₂` via power iteration on `aᵀa`.
pub fn spectral_norm(a: &Mat, rng: &mut Rng) -> f64 {
    spectral_norm_iter(a, rng, 60, 1e-10)
}

/// Spectral norm with explicit iteration/tolerance control.
pub fn spectral_norm_iter(a: &Mat, rng: &mut Rng, max_iter: usize, tol: f64) -> f64 {
    let mut x = rng.gauss_vec(a.cols());
    spectral_norm_warm(a, &mut x, max_iter, tol)
}

/// Power iteration with a caller-owned starting vector, updated in place.
///
/// Re-using the converged vector across closely-related matrices (e.g. a
/// PALM factor between consecutive outer iterations) makes the iteration
/// converge in O(1) steps instead of tens — the warm-start cache in
/// `palm4msa` relies on this. A vector of the wrong length (or all-zero)
/// is re-seeded deterministically.
pub fn spectral_norm_warm(a: &Mat, x: &mut Vec<f64>, max_iter: usize, tol: f64) -> f64 {
    let (m, n) = a.shape();
    if m == 0 || n == 0 {
        return 0.0;
    }
    spectral_norm_with(n, x, max_iter, tol, |xv, z| {
        let y = a.matvec(xv);
        z.copy_from_slice(&a.matvec_t(&y));
    })
}

/// Power-iteration driver generic over the Gram apply `z ← AᵀA x` — the
/// serial [`spectral_norm_warm`] and the engine's pooled
/// `ExecCtx::spectral_norm_warm` share this loop (and therefore the exact
/// warm-start, re-seed, and stopping semantics). `n` is `A`'s column
/// count; `x` is the caller-owned warm-start vector (re-seeded
/// deterministically when absent or all-zero), updated in place.
pub fn spectral_norm_with(
    n: usize,
    x: &mut Vec<f64>,
    max_iter: usize,
    tol: f64,
    mut gram_apply: impl FnMut(&[f64], &mut [f64]),
) -> f64 {
    let fresh = x.len() != n || x.iter().all(|&v| v == 0.0);
    if fresh {
        let mut rng = Rng::new(0x5EC);
        *x = rng.gauss_vec(n);
    }
    let mut z = vec![0.0; n];
    let mut norm_prev = 0.0;
    for _ in 0..max_iter {
        gram_apply(x, &mut z);
        let nz: f64 = z.iter().map(|v| v * v).sum::<f64>().sqrt();
        if nz < 1e-300 {
            return 0.0;
        }
        for (xi, zi) in x.iter_mut().zip(&z) {
            *xi = zi / nz;
        }
        let norm = nz.sqrt(); // ‖AᵀA x‖ → σ² so σ = sqrt
        if (norm - norm_prev).abs() <= tol * norm.max(1e-300) {
            return norm;
        }
        norm_prev = norm;
    }
    norm_prev
}

/// Best rank-1 approximation `(u, sigma, v)` via power iteration
/// (the work-horse of the K-SVD atom update).
pub fn rank1_approx(a: &Mat, rng: &mut Rng, max_iter: usize) -> (Vec<f64>, f64, Vec<f64>) {
    let (m, n) = a.shape();
    let mut v = rng.gauss_vec(n);
    let nv: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    for x in &mut v {
        *x /= nv.max(1e-300);
    }
    let mut u = vec![0.0; m];
    let mut sigma = 0.0;
    for _ in 0..max_iter {
        u = a.matvec(&v);
        let nu: f64 = u.iter().map(|x| x * x).sum::<f64>().sqrt();
        if nu < 1e-300 {
            return (vec![0.0; m], 0.0, v);
        }
        for x in &mut u {
            *x /= nu;
        }
        v = a.matvec_t(&u);
        let nvv: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        if nvv < 1e-300 {
            return (u, 0.0, vec![0.0; n]);
        }
        for x in &mut v {
            *x /= nvv;
        }
        if (nvv - sigma).abs() <= 1e-12 * nvv {
            sigma = nvv;
            break;
        }
        sigma = nvv;
    }
    (u, sigma, v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jacobi_reconstructs_random() {
        let mut rng = Rng::new(31);
        for &(m, n) in &[(6usize, 6usize), (10, 4), (4, 10)] {
            let a = Mat::randn(m, n, &mut rng);
            let s = svd_jacobi(&a);
            assert!(s.reconstruct().rel_fro_err(&a) < 1e-10, "shape {m}x{n}");
            // Singular values descending and non-negative.
            for w in s.s.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
            assert!(s.s.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn jacobi_orthonormal_factors() {
        let mut rng = Rng::new(32);
        let a = Mat::randn(8, 5, &mut rng);
        let s = svd_jacobi(&a);
        let utu = s.u.matmul_tn(&s.u);
        let vtv = s.v.matmul_tn(&s.v);
        assert!(utu.rel_fro_err(&Mat::eye(5, 5)) < 1e-10);
        assert!(vtv.rel_fro_err(&Mat::eye(5, 5)) < 1e-10);
    }

    #[test]
    fn jacobi_known_diagonal() {
        let a = Mat::from_vec(3, 3, vec![3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0]);
        let s = svd_jacobi(&a);
        assert!((s.s[0] - 3.0).abs() < 1e-12);
        assert!((s.s[1] - 2.0).abs() < 1e-12);
        assert!((s.s[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn randomized_matches_jacobi_on_low_rank() {
        let mut rng = Rng::new(33);
        // Exactly rank-3 matrix.
        let u = Mat::randn(30, 3, &mut rng);
        let v = Mat::randn(3, 40, &mut rng);
        let a = u.matmul(&v);
        let s = svd_randomized(&a, 3, 5, 2, &mut rng);
        assert!(s.reconstruct().rel_fro_err(&a) < 1e-8);
    }

    #[test]
    fn truncation_error_matches_tail() {
        let mut rng = Rng::new(34);
        let a = Mat::randn(12, 12, &mut rng);
        let s = svd_jacobi(&a);
        let k = 5;
        let tk = s.truncate(k);
        let err = tk.reconstruct().sub(&a).fro();
        let tail: f64 = s.s[k..].iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!((err - tail).abs() < 1e-8, "err={err} tail={tail}");
    }

    #[test]
    fn spectral_norm_matches_top_singular_value() {
        let mut rng = Rng::new(35);
        let a = Mat::randn(15, 9, &mut rng);
        let s = svd_jacobi(&a);
        let sn = spectral_norm(&a, &mut rng);
        assert!((sn - s.s[0]).abs() < 1e-6 * s.s[0], "sn={sn} s0={}", s.s[0]);
    }

    #[test]
    fn rank1_dominant_direction() {
        let mut rng = Rng::new(36);
        let a = Mat::randn(10, 8, &mut rng);
        let s = svd_jacobi(&a);
        let (_, sigma, _) = rank1_approx(&a, &mut rng, 200);
        assert!((sigma - s.s[0]).abs() < 1e-6 * s.s[0]);
    }
}
