//! Dense linear-algebra substrate: matrices, QR, SVD, spectral norms.

#![forbid(unsafe_code)]

mod mat;
pub mod qr;
pub mod svd;

pub use mat::{chain_product, Mat};
pub use qr::{lstsq, qr_thin, solve_upper};
pub use svd::{
    rank1_approx, spectral_norm, spectral_norm_iter, spectral_norm_warm,
    spectral_norm_with, svd_jacobi, svd_randomized, Svd,
};
