//! Graph signal processing substrate (paper §I motivation + §VII future
//! work): graph Laplacians, the graph Fourier transform (GFT), and FAμST
//! approximations of it.
//!
//! The paper argues that graph Fourier/wavelet operators "have no known
//! general sparse forms, and consequently no associated fast algorithms",
//! making them prime FAμST targets. This module builds the operators the
//! follow-up literature (Le Magoarou et al., "Approximate fast graph
//! Fourier transforms via multi-layer sparse approximations", 2018)
//! factorizes: Laplacians of ring / grid / random-geometric / Erdős–Rényi
//! graphs and their eigenbases via a symmetric Jacobi eigensolver.

#![forbid(unsafe_code)]

use crate::linalg::Mat;
use crate::rng::Rng;

/// Undirected weighted graph as an adjacency matrix (symmetric, zero
/// diagonal).
#[derive(Clone, Debug)]
pub struct Graph {
    pub adjacency: Mat,
}

impl Graph {
    /// Ring graph on `n` vertices (circulant Laplacian — its GFT is the
    /// DFT, which *does* have a fast algorithm; useful as a sanity case).
    pub fn ring(n: usize) -> Self {
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            let j = (i + 1) % n;
            a.set(i, j, 1.0);
            a.set(j, i, 1.0);
        }
        Graph { adjacency: a }
    }

    /// `rows × cols` 4-neighbour grid graph.
    pub fn grid(rows: usize, cols: usize) -> Self {
        let n = rows * cols;
        let mut a = Mat::zeros(n, n);
        let idx = |r: usize, c: usize| r * cols + c;
        for r in 0..rows {
            for c in 0..cols {
                if r + 1 < rows {
                    a.set(idx(r, c), idx(r + 1, c), 1.0);
                    a.set(idx(r + 1, c), idx(r, c), 1.0);
                }
                if c + 1 < cols {
                    a.set(idx(r, c), idx(r, c + 1), 1.0);
                    a.set(idx(r, c + 1), idx(r, c), 1.0);
                }
            }
        }
        Graph { adjacency: a }
    }

    /// Random geometric graph: `n` uniform points in the unit square,
    /// edges between pairs closer than `radius` (the "sensor network"
    /// graph of the GSP literature — irregular, no fast transform known).
    pub fn random_geometric(n: usize, radius: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let pts: Vec<(f64, f64)> = (0..n).map(|_| (rng.uniform(), rng.uniform())).collect();
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in (i + 1)..n {
                let dx = pts[i].0 - pts[j].0;
                let dy = pts[i].1 - pts[j].1;
                if (dx * dx + dy * dy).sqrt() < radius {
                    a.set(i, j, 1.0);
                    a.set(j, i, 1.0);
                }
            }
        }
        Graph { adjacency: a }
    }

    /// Erdős–Rényi graph with edge probability `p`.
    pub fn erdos_renyi(n: usize, p: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in (i + 1)..n {
                if rng.uniform() < p {
                    a.set(i, j, 1.0);
                    a.set(j, i, 1.0);
                }
            }
        }
        Graph { adjacency: a }
    }

    /// Number of vertices.
    pub fn n(&self) -> usize {
        self.adjacency.rows()
    }

    /// Number of edges.
    pub fn n_edges(&self) -> usize {
        self.adjacency.nnz() / 2
    }

    /// Combinatorial Laplacian `L = D − A`.
    pub fn laplacian(&self) -> Mat {
        let n = self.n();
        let mut l = self.adjacency.scaled(-1.0);
        for i in 0..n {
            let deg: f64 = self.adjacency.row(i).iter().sum();
            l.set(i, i, deg);
        }
        l
    }
}

/// Eigendecomposition of a symmetric matrix by cyclic Jacobi rotations.
/// Returns `(eigenvalues ascending, eigenvectors as columns)` with
/// `M = V diag(w) Vᵀ`.
pub fn eig_sym(m: &Mat) -> (Vec<f64>, Mat) {
    let n = m.rows();
    assert_eq!(m.cols(), n, "eig_sym needs a square matrix");
    let mut a = m.clone();
    let mut v = Mat::eye(n, n);
    for _sweep in 0..100 {
        let mut off = 0.0_f64;
        for p in 0..n {
            for q in (p + 1)..n {
                off = off.max(a.at(p, q).abs());
            }
        }
        if off < 1e-12 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a.at(p, q);
                if apq.abs() < 1e-14 {
                    continue;
                }
                let app = a.at(p, p);
                let aqq = a.at(q, q);
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                // A ← JᵀAJ on rows/cols p, q.
                for k in 0..n {
                    let akp = a.at(k, p);
                    let akq = a.at(k, q);
                    a.set(k, p, c * akp - s * akq);
                    a.set(k, q, s * akp + c * akq);
                }
                for k in 0..n {
                    let apk = a.at(p, k);
                    let aqk = a.at(q, k);
                    a.set(p, k, c * apk - s * aqk);
                    a.set(q, k, s * apk + c * aqk);
                }
                for k in 0..n {
                    let vkp = v.at(k, p);
                    let vkq = v.at(k, q);
                    v.set(k, p, c * vkp - s * vkq);
                    v.set(k, q, s * vkp + c * vkq);
                }
            }
        }
    }
    // Sort ascending by eigenvalue.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| a.at(i, i).partial_cmp(&a.at(j, j)).unwrap());
    let w: Vec<f64> = order.iter().map(|&i| a.at(i, i)).collect();
    let mut vs = Mat::zeros(n, n);
    for (new, &old) in order.iter().enumerate() {
        for k in 0..n {
            vs.set(k, new, v.at(k, old));
        }
    }
    (w, vs)
}

/// Graph Fourier transform: the analysis operator `Uᵀ` (rows = Laplacian
/// eigenvectors, frequencies ascending). `x̂ = gft * x`.
pub fn gft(g: &Graph) -> Mat {
    let (_, u) = eig_sym(&g.laplacian());
    u.t()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hierarchical::{factorize, HierarchicalConfig};
    use crate::prox::Constraint;

    #[test]
    fn graph_constructors_shapes() {
        let r = Graph::ring(8);
        assert_eq!(r.n(), 8);
        assert_eq!(r.n_edges(), 8);
        let g = Graph::grid(3, 4);
        assert_eq!(g.n(), 12);
        assert_eq!(g.n_edges(), 3 * 3 + 2 * 4); // 17 grid edges
        let e = Graph::erdos_renyi(20, 0.3, 1);
        assert!(e.n_edges() > 0);
    }

    #[test]
    fn laplacian_rows_sum_to_zero_and_psd() {
        let g = Graph::random_geometric(24, 0.35, 2);
        let l = g.laplacian();
        for i in 0..g.n() {
            let s: f64 = l.row(i).iter().sum();
            assert!(s.abs() < 1e-12);
        }
        let (w, _) = eig_sym(&l);
        assert!(w[0] > -1e-9, "Laplacian not PSD: {}", w[0]);
        // Connected-ish graph: constant vector is the 0-eigenvector.
        assert!(w[0].abs() < 1e-9);
    }

    #[test]
    fn eig_sym_reconstructs() {
        let g = Graph::grid(4, 4);
        let l = g.laplacian();
        let (w, v) = eig_sym(&l);
        // V diag(w) Vᵀ == L
        let mut vd = v.clone();
        for i in 0..vd.rows() {
            for j in 0..vd.cols() {
                let x = vd.at(i, j) * w[j];
                vd.set(i, j, x);
            }
        }
        assert!(vd.matmul_nt(&v).rel_fro_err(&l) < 1e-9);
        // Orthonormal eigenbasis.
        assert!(v.matmul_tn(&v).rel_fro_err(&Mat::eye(16, 16)) < 1e-9);
    }

    #[test]
    fn gft_is_orthonormal_and_diagonalizes() {
        let g = Graph::ring(16);
        let f = gft(&g);
        assert!(f.matmul_nt(&f).rel_fro_err(&Mat::eye(16, 16)) < 1e-9);
        // F L Fᵀ diagonal.
        let fl = f.matmul(&g.laplacian()).matmul_nt(&f);
        let mut offdiag = 0.0_f64;
        for i in 0..16 {
            for j in 0..16 {
                if i != j {
                    offdiag = offdiag.max(fl.at(i, j).abs());
                }
            }
        }
        assert!(offdiag < 1e-8, "not diagonalized: {offdiag}");
    }

    #[test]
    fn gft_of_irregular_graph_admits_faust_approximation() {
        // The paper's §VII pitch: approximate the (dense, no-fast-form)
        // GFT of an irregular graph by a FAμST with RCG > 1 at moderate
        // error.
        let g = Graph::random_geometric(32, 0.3, 3);
        let f = gft(&g);
        let mut cfg = HierarchicalConfig::hadamard(32); // same shape family
        for lev in cfg.levels.iter_mut() {
            lev.factor = Constraint::SpRowCol(4);
        }
        cfg.levels.truncate(3); // J = 4 factors
        cfg.residual_dims.truncate(3);
        let fst = factorize(&f, &cfg);
        let rel = fst.relative_error_fro(&f);
        assert!(fst.rcg() > 1.0, "rcg={}", fst.rcg());
        assert!(rel < 0.8, "rel={rel}");
    }
}
