//! Durable operator store: versioned, checksummed on-disk snapshots of
//! learned FAμST operators (ROADMAP item l).
//!
//! A factorization is expensive to *learn* (PALM/hierarchical runs) and
//! cheap to *apply* — so the learned factors are the asset worth keeping.
//! This module serializes a [`Faust`] (CSR factors + λ) together with its
//! registry identity (name, epoch) and the probe-calibrated
//! [`F32Bound`] from the mixed-precision tier, so a restarted
//! `serve --store DIR` is warm in milliseconds instead of re-running
//! PALM. [`crate::coordinator::Registry::persist_all`] and
//! [`crate::coordinator::Registry::load_store`] drive it fleet-wide.
//!
//! # On-disk format (`.fstore`, version 1)
//!
//! One operator per file, all integers little-endian, in the spirit of
//! the wire protocol ([`crate::server::wire`]): length-prefixed,
//! magic-tagged, versioned — and, because files (unlike sockets) can be
//! torn by a crash mid-write, additionally CRC-sealed:
//!
//! ```text
//! file  := u32 body_len | body | u32 crc32(body)      (CRC-32/IEEE)
//! body  := u16 magic (0xFA5D)
//!        | u8  version (1)
//!        | u8  flags (bit0: f32 bound present)
//!        | u8  name_len | name_len × u8 name          (see below)
//!        | u64 epoch                                  (registry epoch at persist)
//!        | f64 λ                                      (bit pattern)
//!        | u32 n_factors (≥ 1)
//!        | [ f64 measured_rel_err | f64 declared_rel_err ]   (iff flags bit0)
//!        | n_factors × factor                         (rightmost first: S_1 first)
//! factor := u32 rows | u32 cols | u32 nnz
//!        | (rows+1) × u32 indptr | nnz × u32 indices | nnz × f64 vals
//! ```
//!
//! Operator names double as file stems (`<name>.fstore`), so they are
//! restricted to 1–64 bytes of `[A-Za-z0-9._-]` not starting with a dot
//! — anything else is a typed [`StoreError::BadName`], never a path
//! traversal.
//!
//! # Integrity contract
//!
//! - **Bitwise round-trip.** Factors are written verbatim from the CSR
//!   arrays and reassembled with [`Csr::from_raw_parts`] (no re-sort, no
//!   zero-dropping), so `persist → load` preserves every value bit and
//!   therefore the compiled plan's [`CostProfile`] and all downstream
//!   results — proptested in this module via `faust_fingerprint`.
//! - **Torn and corrupt files are typed errors, never panics and never
//!   silently wrong data.** The length prefix is checked against the
//!   actual file size, the CRC seals the body (every single-bit flip is
//!   caught), and every structural invariant that the checksum cannot
//!   express (indptr monotonicity, column bounds, factor chain
//!   dimensions) is re-validated on load. [`load_dir`] skips bad files
//!   with a [`StoreError`] per file and loads the rest.
//! - **Atomic replace.** [`save_op`] writes to a dotfile in the same
//!   directory and `rename`s over the target, so a crash mid-persist
//!   leaves either the old snapshot or the new one, never a torn file
//!   under the live name (the tmp dotfile is ignored by [`load_dir`]).
//!
//! # Learner snapshots (`.lstore`, ROADMAP item i)
//!
//! The online-learning tier ([`crate::palm::online`]) has *in-progress*
//! state worth keeping too: the running surrogate Â, the per-column
//! weights, and the current (dense, mid-optimization) factor iterates —
//! none of which fit the operator format above. [`StoredLearner`] saves
//! them in a sibling record with its **own magic** ([`LEARNER_MAGIC`])
//! and **own extension** ([`LEARNER_EXTENSION`]), under the same
//! length-prefix + CRC framing. Keeping the namespaces disjoint means
//! [`load_dir`]'s `*.fstore` scan never sees learner files (and a
//! learner file renamed to `.fstore` dies on its magic, not silently) —
//! the v1 operator format is untouched. A warm restart resumes learning
//! via [`StoredLearner::resume`], bitwise where it left off.

#![forbid(unsafe_code)]

use crate::engine::F32Bound;
use crate::faust::Faust;
use crate::sparse::Csr;
use std::fmt;
use std::path::{Path, PathBuf};

/// File magic: `0xFA5D` ("FAuST Durable") — deliberately distinct from
/// the wire protocol's `0xFA57` so a store file fed to a socket (or vice
/// versa) fails loudly on the first two bytes.
pub const MAGIC: u16 = 0xFA5D;
/// Current format version.
pub const VERSION: u8 = 1;
/// Oldest version this build still reads.
pub const MIN_VERSION: u8 = 1;
/// Hard cap on `body_len` (checked before any allocation, like the wire
/// protocol's `MAX_FRAME`): 256 MiB comfortably holds MEG-scale fleets
/// while bounding what a corrupt length prefix can make us allocate.
pub const MAX_BODY: usize = 256 << 20;
/// Extension of live snapshot files in a store directory.
pub const EXTENSION: &str = "fstore";
/// File magic of learner snapshots: `0xFA5E` — distinct from both the
/// operator store's `0xFA5D` and the wire protocol's `0xFA57`, so a
/// file fed to the wrong decoder fails on its first two bytes.
pub const LEARNER_MAGIC: u16 = 0xFA5E;
/// Extension of in-progress online-learner snapshots. Disjoint from
/// [`EXTENSION`] so [`load_dir`]'s operator scan never sees them.
pub const LEARNER_EXTENSION: &str = "lstore";

const FLAG_F32_BOUND: u8 = 1;
const MAX_NAME: usize = 64;
const MAX_FACTORS: u32 = 65_536;

/// Everything the registry needs to resurrect one served operator.
#[derive(Clone, Debug)]
pub struct StoredOp {
    /// Registry name (also the file stem).
    pub name: String,
    /// Registry epoch at persist time — `load_store` advances the
    /// restored registry's epoch counter past the max of these, so
    /// post-restart generations always sort after the snapshot.
    pub epoch: u64,
    /// The operator itself, bitwise identical to the persisted one.
    pub faust: Faust,
    /// The measured f32 quantization bound, if the operator had an f32
    /// serving generation when persisted (reinstalled on load so the
    /// warm server never re-probes).
    pub f32_bound: Option<F32Bound>,
}

/// Typed failure taxonomy for the store. Everything a torn, corrupt, or
/// hostile file can do surfaces here — never a panic, never silent
/// wrong data.
#[derive(Clone, Debug, PartialEq)]
pub enum StoreError {
    /// Filesystem-level failure (open/read/write/rename), with context.
    Io(std::io::ErrorKind, String),
    /// File ends before the declared content does (torn write).
    Truncated { need: usize, have: usize },
    /// Declared body length exceeds [`MAX_BODY`] (corrupt prefix or a
    /// file from a much bigger deployment — refused before allocating).
    Oversized { len: usize, cap: usize },
    /// File is longer than `4 + body_len + 4` (trailing garbage —
    /// a snapshot never has any).
    TrailingGarbage { declared: usize, actual: usize },
    /// First two body bytes are not [`MAGIC`].
    BadMagic(u16),
    /// Version outside `[MIN_VERSION, VERSION]`.
    BadVersion(u8),
    /// CRC-32 seal does not match the body (bit rot / torn write that
    /// kept the length intact).
    ChecksumMismatch { want: u32, got: u32 },
    /// Operator name is empty, too long, or not `[A-Za-z0-9._-]`
    /// (or starts with `.` — reserved for tmp files).
    BadName(String),
    /// Body passed the checksum but violates a structural invariant
    /// (encoder bug or a deliberately crafted file) — e.g. indptr
    /// non-monotone, column index out of range, factor chain dimension
    /// mismatch.
    Malformed(String),
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::Io(kind, ctx) => write!(f, "store io error ({kind:?}): {ctx}"),
            StoreError::Truncated { need, have } => {
                write!(f, "store file truncated: need {need} bytes, have {have}")
            }
            StoreError::Oversized { len, cap } => {
                write!(f, "store body length {len} exceeds cap {cap}")
            }
            StoreError::TrailingGarbage { declared, actual } => write!(
                f,
                "store file has trailing garbage: declared {declared} bytes, file has {actual}"
            ),
            StoreError::BadMagic(m) => write!(f, "bad store magic {m:#06x}"),
            StoreError::BadVersion(v) => write!(
                f,
                "unsupported store version {v} (this build reads {MIN_VERSION}..={VERSION})"
            ),
            StoreError::ChecksumMismatch { want, got } => {
                write!(f, "store checksum mismatch: sealed {want:#010x}, computed {got:#010x}")
            }
            StoreError::BadName(n) => write!(f, "invalid operator name {n:?}"),
            StoreError::Malformed(why) => write!(f, "malformed store body: {why}"),
        }
    }
}

impl std::error::Error for StoreError {}

fn io_err(ctx: &str, e: std::io::Error) -> StoreError {
    StoreError::Io(e.kind(), format!("{ctx}: {e}"))
}

// ---------------------------------------------------------------------------
// CRC-32 (IEEE 802.3, reflected 0xEDB88320) — std-only, table built at
// compile time. Detects all single-bit and burst-≤32 errors, which is
// exactly the torn-write/bit-rot class the bit-flip proptest exercises.

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// CRC-32/IEEE of `bytes` (the seal over the body section).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------------
// Encode

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

/// Is `name` usable as both a registry key and a file stem?
pub fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= MAX_NAME
        && !name.starts_with('.')
        && name
            .bytes()
            .all(|b| b.is_ascii_alphanumeric() || b == b'.' || b == b'_' || b == b'-')
}

/// Serialize one operator to the full file image (length prefix + body +
/// CRC seal). Pure function of the input — the round-trip proptests run
/// against this and [`decode_op`] without touching a filesystem.
pub fn encode_op(op: &StoredOp) -> Result<Vec<u8>, StoreError> {
    if !valid_name(&op.name) {
        return Err(StoreError::BadName(op.name.clone()));
    }
    let n_factors = op.faust.n_factors();
    if n_factors as u64 > MAX_FACTORS as u64 {
        return Err(StoreError::Malformed(format!("{n_factors} factors exceeds cap")));
    }
    let mut body = Vec::new();
    put_u16(&mut body, MAGIC);
    body.push(VERSION);
    body.push(if op.f32_bound.is_some() { FLAG_F32_BOUND } else { 0 });
    body.push(op.name.len() as u8);
    body.extend_from_slice(op.name.as_bytes());
    put_u64(&mut body, op.epoch);
    put_f64(&mut body, op.faust.lambda());
    put_u32(&mut body, n_factors as u32);
    if let Some(b) = op.f32_bound {
        put_f64(&mut body, b.measured_rel_err);
        put_f64(&mut body, b.declared_rel_err);
    }
    for fac in op.faust.factors() {
        let (rows, cols, nnz) = (fac.rows(), fac.cols(), fac.nnz());
        if rows > u32::MAX as usize || cols > u32::MAX as usize || nnz > u32::MAX as usize {
            return Err(StoreError::Malformed(format!(
                "factor {rows}×{cols} (nnz {nnz}) exceeds u32 index space"
            )));
        }
        put_u32(&mut body, rows as u32);
        put_u32(&mut body, cols as u32);
        put_u32(&mut body, nnz as u32);
        for &p in &fac.indptr {
            put_u32(&mut body, p);
        }
        for &j in &fac.indices {
            put_u32(&mut body, j);
        }
        for &v in &fac.vals {
            put_f64(&mut body, v);
        }
    }
    if body.len() > MAX_BODY {
        return Err(StoreError::Oversized { len: body.len(), cap: MAX_BODY });
    }
    let mut out = Vec::with_capacity(body.len() + 8);
    put_u32(&mut out, body.len() as u32);
    out.extend_from_slice(&body);
    put_u32(&mut out, crc32(&body));
    Ok(out)
}

// ---------------------------------------------------------------------------
// Decode

/// Bounds-checked little-endian cursor over a CRC-validated body. A read
/// past the end means the (checksum-correct) body is internally
/// inconsistent, so overruns surface as [`StoreError::Malformed`].
struct Cur<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], StoreError> {
        let end = self
            .off
            .checked_add(n)
            .ok_or_else(|| StoreError::Malformed(format!("{what}: length overflow")))?;
        if end > self.b.len() {
            return Err(StoreError::Malformed(format!(
                "{what}: body overrun at offset {} (need {n}, have {})",
                self.off,
                self.b.len() - self.off
            )));
        }
        let s = &self.b[self.off..end];
        self.off = end;
        Ok(s)
    }
    fn u8(&mut self, what: &str) -> Result<u8, StoreError> {
        Ok(self.take(1, what)?[0])
    }
    fn u16(&mut self, what: &str) -> Result<u16, StoreError> {
        Ok(u16::from_le_bytes(self.take(2, what)?.try_into().unwrap()))
    }
    fn u32(&mut self, what: &str) -> Result<u32, StoreError> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }
    fn u64(&mut self, what: &str) -> Result<u64, StoreError> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }
    fn f64(&mut self, what: &str) -> Result<f64, StoreError> {
        Ok(f64::from_bits(self.u64(what)?))
    }
    fn u32_vec(&mut self, n: usize, what: &str) -> Result<Vec<u32>, StoreError> {
        let raw = self.take(n.checked_mul(4).ok_or_else(|| {
            StoreError::Malformed(format!("{what}: count overflow"))
        })?, what)?;
        Ok(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }
    fn f64_vec(&mut self, n: usize, what: &str) -> Result<Vec<f64>, StoreError> {
        let raw = self.take(n.checked_mul(8).ok_or_else(|| {
            StoreError::Malformed(format!("{what}: count overflow"))
        })?, what)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_bits(u64::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }
}

/// Parse a full file image produced by [`encode_op`]. Every corruption
/// mode returns a typed [`StoreError`]; this function never panics on
/// any input (proptested with truncation, bit-flip, and random-bytes
/// corpora below).
pub fn decode_op(bytes: &[u8]) -> Result<StoredOp, StoreError> {
    if bytes.len() < 4 {
        return Err(StoreError::Truncated { need: 4, have: bytes.len() });
    }
    let body_len = u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize;
    if body_len > MAX_BODY {
        return Err(StoreError::Oversized { len: body_len, cap: MAX_BODY });
    }
    let total = 4 + body_len + 4;
    if bytes.len() < total {
        return Err(StoreError::Truncated { need: total, have: bytes.len() });
    }
    if bytes.len() > total {
        return Err(StoreError::TrailingGarbage { declared: total, actual: bytes.len() });
    }
    let body = &bytes[4..4 + body_len];
    let want = u32::from_le_bytes(bytes[4 + body_len..].try_into().unwrap());
    let got = crc32(body);
    if want != got {
        return Err(StoreError::ChecksumMismatch { want, got });
    }

    let mut c = Cur { b: body, off: 0 };
    let magic = c.u16("magic")?;
    if magic != MAGIC {
        return Err(StoreError::BadMagic(magic));
    }
    let version = c.u8("version")?;
    if !(MIN_VERSION..=VERSION).contains(&version) {
        return Err(StoreError::BadVersion(version));
    }
    let flags = c.u8("flags")?;
    if flags & !FLAG_F32_BOUND != 0 {
        return Err(StoreError::Malformed(format!("unknown flag bits {flags:#04x}")));
    }
    let name_len = c.u8("name_len")? as usize;
    let name_raw = c.take(name_len, "name")?;
    let name = std::str::from_utf8(name_raw)
        .map_err(|_| StoreError::BadName(format!("{name_raw:?}")))?
        .to_string();
    if !valid_name(&name) {
        return Err(StoreError::BadName(name));
    }
    let epoch = c.u64("epoch")?;
    let lambda = c.f64("lambda")?;
    let n_factors = c.u32("n_factors")?;
    if n_factors == 0 || n_factors > MAX_FACTORS {
        return Err(StoreError::Malformed(format!("factor count {n_factors} out of range")));
    }
    let f32_bound = if flags & FLAG_F32_BOUND != 0 {
        Some(F32Bound {
            measured_rel_err: c.f64("measured_rel_err")?,
            declared_rel_err: c.f64("declared_rel_err")?,
        })
    } else {
        None
    };
    let mut factors: Vec<std::sync::Arc<Csr>> = Vec::with_capacity(n_factors as usize);
    for k in 0..n_factors {
        let rows = c.u32("rows")? as usize;
        let cols = c.u32("cols")? as usize;
        let nnz = c.u32("nnz")? as usize;
        let indptr = c.u32_vec(rows + 1, "indptr")?;
        let indices = c.u32_vec(nnz, "indices")?;
        let vals = c.f64_vec(nnz, "vals")?;
        // from_raw_parts re-checks every CSR invariant (monotone indptr,
        // in-range columns, nnz accounting) — a checksum-valid but
        // crafted body still cannot reach the apply kernels malformed.
        let fac = Csr::from_raw_parts(rows, cols, indptr, indices, vals)
            .map_err(|e| StoreError::Malformed(format!("factor {k}: {e}")))?;
        if let Some(prev) = factors.last() {
            if fac.cols() != prev.rows() {
                return Err(StoreError::Malformed(format!(
                    "factor chain mismatch at {k}: {}×{} after output dim {}",
                    fac.rows(),
                    fac.cols(),
                    prev.rows()
                )));
            }
        }
        factors.push(std::sync::Arc::new(fac));
    }
    if c.off != body.len() {
        return Err(StoreError::Malformed(format!(
            "{} unread bytes after last factor",
            body.len() - c.off
        )));
    }
    Ok(StoredOp { name, epoch, faust: Faust::from_shared(factors, lambda), f32_bound })
}

// ---------------------------------------------------------------------------
// Filesystem layer

/// Path of `name`'s live snapshot inside `dir`.
pub fn op_path(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("{name}.{EXTENSION}"))
}

/// Persist one operator into `dir` atomically: encode, write to a
/// same-directory dotfile, fsync, rename over `<name>.fstore`. Returns
/// the final path.
pub fn save_op(dir: &Path, op: &StoredOp) -> Result<PathBuf, StoreError> {
    let bytes = encode_op(op)?;
    std::fs::create_dir_all(dir).map_err(|e| io_err("create store dir", e))?;
    let tmp = dir.join(format!(".{}.{EXTENSION}.tmp", op.name));
    let path = op_path(dir, &op.name);
    {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp).map_err(|e| io_err("create tmp snapshot", e))?;
        f.write_all(&bytes).map_err(|e| io_err("write snapshot", e))?;
        f.sync_all().map_err(|e| io_err("sync snapshot", e))?;
    }
    std::fs::rename(&tmp, &path).map_err(|e| io_err("publish snapshot", e))?;
    Ok(path)
}

/// Load one snapshot file (size-capped before reading, then
/// [`decode_op`]).
pub fn load_op(path: &Path) -> Result<StoredOp, StoreError> {
    let meta = std::fs::metadata(path).map_err(|e| io_err("stat snapshot", e))?;
    if meta.len() > (MAX_BODY + 8) as u64 {
        return Err(StoreError::Oversized { len: meta.len() as usize, cap: MAX_BODY });
    }
    let bytes = std::fs::read(path).map_err(|e| io_err("read snapshot", e))?;
    decode_op(&bytes)
}

/// Result of scanning a store directory: everything loadable, plus a
/// typed reason for every file that was not.
#[derive(Debug, Default)]
pub struct LoadedStore {
    /// Successfully decoded operators, sorted by name.
    pub ops: Vec<StoredOp>,
    /// Files that failed to load and why (torn writes, bit rot, foreign
    /// files) — reported, skipped, never fatal to the rest of the fleet.
    pub skipped: Vec<(PathBuf, StoreError)>,
}

/// Scan `dir` for `*.fstore` snapshots. Corrupt files land in
/// [`LoadedStore::skipped`]; only a missing/unreadable directory is an
/// `Err`. An existing-but-empty directory yields an empty `ops` (the
/// cold-start signal for `serve --store`).
pub fn load_dir(dir: &Path) -> Result<LoadedStore, StoreError> {
    let rd = std::fs::read_dir(dir).map_err(|e| io_err("open store dir", e))?;
    let mut paths: Vec<PathBuf> = Vec::new();
    for ent in rd {
        let ent = ent.map_err(|e| io_err("scan store dir", e))?;
        let p = ent.path();
        let hidden = match p.file_name().and_then(|n| n.to_str()) {
            Some(n) => n.starts_with('.'),
            None => true,
        };
        if !hidden && p.extension().and_then(|e| e.to_str()) == Some(EXTENSION) {
            paths.push(p);
        }
    }
    paths.sort();
    let mut out = LoadedStore::default();
    for p in paths {
        match load_op(&p) {
            Ok(op) => out.ops.push(op),
            Err(e) => out.skipped.push((p, e)),
        }
    }
    out.ops.sort_by(|a, b| a.name.cmp(&b.name));
    Ok(out)
}

// ---------------------------------------------------------------------------
// Learner snapshots (.lstore): in-progress online-factorization state.

/// Everything needed to resume a [`crate::palm::online::OnlinePalm`]
/// bitwise where it left off: the dense factor iterates + λ, the running
/// surrogate Â, the per-column observation weights, and the stream
/// counters. The *configuration* (constraints, forgetting, step policy)
/// is deliberately not stored — the caller that resumes knows it, just
/// as `serve --store` supplies the publish hook on restore.
#[derive(Clone, Debug)]
pub struct StoredLearner {
    /// Registry operator this learner publishes to (also the file stem;
    /// same naming rules as [`StoredOp::name`]).
    pub name: String,
    /// Dense factor iterates, rightmost first (S_1 first) — mid-descent
    /// values, so they live here and not in a `.fstore`.
    pub mats: Vec<crate::linalg::Mat>,
    /// Current scale λ.
    pub lambda: f64,
    /// Running weighted column surrogate Â.
    pub surrogate: crate::linalg::Mat,
    /// Per-column observation weights (one per surrogate column).
    pub weights: Vec<f64>,
    /// Total columns observed.
    pub cols_seen: u64,
    /// Mini-batches swept.
    pub batches: u64,
}

impl StoredLearner {
    /// Snapshot a live learner's resumable state.
    pub fn from_online(name: impl Into<String>, ol: &crate::palm::online::OnlinePalm) -> Self {
        StoredLearner {
            name: name.into(),
            mats: ol.state().mats.clone(),
            lambda: ol.state().lambda,
            surrogate: ol.surrogate().clone(),
            weights: ol.weights().to_vec(),
            cols_seen: ol.cols_seen(),
            batches: ol.batches(),
        }
    }

    /// Rebuild the learner under `cfg` (the constraint set and
    /// forgetting factor the caller knows). Feeding the resumed learner
    /// the rest of the stream is bitwise identical to never having
    /// stopped — proptested below.
    ///
    /// # Panics
    /// If `cfg`'s factor dimensions disagree with the snapshot (a caller
    /// bug, not file corruption — corruption is caught in
    /// [`decode_learner`]).
    pub fn resume(self, cfg: crate::palm::online::OnlineConfig) -> crate::palm::online::OnlinePalm {
        let init = crate::palm::FactorState { mats: self.mats, lambda: self.lambda };
        crate::palm::online::OnlinePalm::from_parts(
            init,
            cfg,
            self.surrogate,
            self.weights,
            self.cols_seen,
            self.batches,
        )
    }
}

fn put_mat(out: &mut Vec<u8>, m: &crate::linalg::Mat) -> Result<(), StoreError> {
    if m.rows() > u32::MAX as usize || m.cols() > u32::MAX as usize {
        return Err(StoreError::Malformed(format!(
            "matrix {}×{} exceeds u32 index space",
            m.rows(),
            m.cols()
        )));
    }
    put_u32(out, m.rows() as u32);
    put_u32(out, m.cols() as u32);
    for &v in m.data() {
        put_f64(out, v);
    }
    Ok(())
}

fn read_mat(c: &mut Cur<'_>, what: &str) -> Result<crate::linalg::Mat, StoreError> {
    let rows = c.u32("rows")? as usize;
    let cols = c.u32("cols")? as usize;
    let n = rows.checked_mul(cols).ok_or_else(|| {
        StoreError::Malformed(format!("{what}: {rows}×{cols} element count overflow"))
    })?;
    let data = c.f64_vec(n, what)?;
    Ok(crate::linalg::Mat::from_vec(rows, cols, data))
}

/// Serialize a learner snapshot to its full file image (same framing as
/// [`encode_op`]: `u32 body_len | body | u32 crc32(body)`, body led by
/// [`LEARNER_MAGIC`]).
pub fn encode_learner(l: &StoredLearner) -> Result<Vec<u8>, StoreError> {
    if !valid_name(&l.name) {
        return Err(StoreError::BadName(l.name.clone()));
    }
    if l.mats.is_empty() || l.mats.len() as u64 > MAX_FACTORS as u64 {
        return Err(StoreError::Malformed(format!(
            "learner factor count {} out of range",
            l.mats.len()
        )));
    }
    let mut body = Vec::new();
    put_u16(&mut body, LEARNER_MAGIC);
    body.push(VERSION);
    body.push(0); // flags: none defined yet, rejected non-zero on load
    body.push(l.name.len() as u8);
    body.extend_from_slice(l.name.as_bytes());
    put_u64(&mut body, l.cols_seen);
    put_u64(&mut body, l.batches);
    put_f64(&mut body, l.lambda);
    put_u32(&mut body, l.mats.len() as u32);
    for m in &l.mats {
        put_mat(&mut body, m)?;
    }
    put_mat(&mut body, &l.surrogate)?;
    put_u32(
        &mut body,
        u32::try_from(l.weights.len())
            .map_err(|_| StoreError::Malformed("weight count exceeds u32".into()))?,
    );
    for &w in &l.weights {
        put_f64(&mut body, w);
    }
    if body.len() > MAX_BODY {
        return Err(StoreError::Oversized { len: body.len(), cap: MAX_BODY });
    }
    let mut out = Vec::with_capacity(body.len() + 8);
    put_u32(&mut out, body.len() as u32);
    out.extend_from_slice(&body);
    put_u32(&mut out, crc32(&body));
    Ok(out)
}

/// Parse a learner snapshot produced by [`encode_learner`]. Same totality
/// contract as [`decode_op`]: every corruption mode is a typed
/// [`StoreError`], never a panic.
pub fn decode_learner(bytes: &[u8]) -> Result<StoredLearner, StoreError> {
    if bytes.len() < 4 {
        return Err(StoreError::Truncated { need: 4, have: bytes.len() });
    }
    let body_len = u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize;
    if body_len > MAX_BODY {
        return Err(StoreError::Oversized { len: body_len, cap: MAX_BODY });
    }
    let total = 4 + body_len + 4;
    if bytes.len() < total {
        return Err(StoreError::Truncated { need: total, have: bytes.len() });
    }
    if bytes.len() > total {
        return Err(StoreError::TrailingGarbage { declared: total, actual: bytes.len() });
    }
    let body = &bytes[4..4 + body_len];
    let want = u32::from_le_bytes(bytes[4 + body_len..].try_into().unwrap());
    let got = crc32(body);
    if want != got {
        return Err(StoreError::ChecksumMismatch { want, got });
    }

    let mut c = Cur { b: body, off: 0 };
    let magic = c.u16("magic")?;
    if magic != LEARNER_MAGIC {
        return Err(StoreError::BadMagic(magic));
    }
    let version = c.u8("version")?;
    if !(MIN_VERSION..=VERSION).contains(&version) {
        return Err(StoreError::BadVersion(version));
    }
    let flags = c.u8("flags")?;
    if flags != 0 {
        return Err(StoreError::Malformed(format!("unknown learner flag bits {flags:#04x}")));
    }
    let name_len = c.u8("name_len")? as usize;
    let name_raw = c.take(name_len, "name")?;
    let name = std::str::from_utf8(name_raw)
        .map_err(|_| StoreError::BadName(format!("{name_raw:?}")))?
        .to_string();
    if !valid_name(&name) {
        return Err(StoreError::BadName(name));
    }
    let cols_seen = c.u64("cols_seen")?;
    let batches = c.u64("batches")?;
    let lambda = c.f64("lambda")?;
    let n_factors = c.u32("n_factors")?;
    if n_factors == 0 || n_factors > MAX_FACTORS {
        return Err(StoreError::Malformed(format!(
            "learner factor count {n_factors} out of range"
        )));
    }
    let mut mats: Vec<crate::linalg::Mat> = Vec::with_capacity(n_factors as usize);
    for k in 0..n_factors {
        let m = read_mat(&mut c, "factor")?;
        if let Some(prev) = mats.last() {
            // Rightmost first: the next (left) factor consumes the
            // previous one's output dimension.
            if m.cols() != prev.rows() {
                return Err(StoreError::Malformed(format!(
                    "learner factor chain mismatch at {k}: {}×{} after output dim {}",
                    m.rows(),
                    m.cols(),
                    prev.rows()
                )));
            }
        }
        mats.push(m);
    }
    let surrogate = read_mat(&mut c, "surrogate")?;
    let (prod_rows, prod_cols) = (mats[mats.len() - 1].rows(), mats[0].cols());
    if surrogate.rows() != prod_rows || surrogate.cols() != prod_cols {
        return Err(StoreError::Malformed(format!(
            "surrogate {}×{} does not match factor product {prod_rows}×{prod_cols}",
            surrogate.rows(),
            surrogate.cols()
        )));
    }
    let n_weights = c.u32("n_weights")? as usize;
    if n_weights != surrogate.cols() {
        return Err(StoreError::Malformed(format!(
            "{n_weights} weights for {} surrogate columns",
            surrogate.cols()
        )));
    }
    let weights = c.f64_vec(n_weights, "weights")?;
    if c.off != body.len() {
        return Err(StoreError::Malformed(format!(
            "{} unread bytes after weights",
            body.len() - c.off
        )));
    }
    Ok(StoredLearner { name, mats, lambda, surrogate, weights, cols_seen, batches })
}

/// Path of `name`'s learner snapshot inside `dir`.
pub fn learner_path(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("{name}.{LEARNER_EXTENSION}"))
}

/// Persist one learner snapshot atomically (same dotfile + fsync +
/// rename discipline as [`save_op`]). Returns the final path.
pub fn save_learner(dir: &Path, l: &StoredLearner) -> Result<PathBuf, StoreError> {
    let bytes = encode_learner(l)?;
    std::fs::create_dir_all(dir).map_err(|e| io_err("create store dir", e))?;
    let tmp = dir.join(format!(".{}.{LEARNER_EXTENSION}.tmp", l.name));
    let path = learner_path(dir, &l.name);
    {
        use std::io::Write;
        let mut f =
            std::fs::File::create(&tmp).map_err(|e| io_err("create tmp learner snapshot", e))?;
        f.write_all(&bytes).map_err(|e| io_err("write learner snapshot", e))?;
        f.sync_all().map_err(|e| io_err("sync learner snapshot", e))?;
    }
    std::fs::rename(&tmp, &path).map_err(|e| io_err("publish learner snapshot", e))?;
    Ok(path)
}

/// Load one learner snapshot (size-capped, then [`decode_learner`]).
pub fn load_learner(path: &Path) -> Result<StoredLearner, StoreError> {
    let meta = std::fs::metadata(path).map_err(|e| io_err("stat learner snapshot", e))?;
    if meta.len() > (MAX_BODY + 8) as u64 {
        return Err(StoreError::Oversized { len: meta.len() as usize, cap: MAX_BODY });
    }
    let bytes = std::fs::read(path).map_err(|e| io_err("read learner snapshot", e))?;
    decode_learner(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Rng;
    use crate::testutil::{check, ensure, faust_fingerprint, gen, PropConfig};

    /// Random valid fleet member: 1–4 factors with random chain dims,
    /// random sparsity (possibly fully dense, possibly a zero factor),
    /// random λ (occasionally negative or subnormal-ish tiny).
    fn arb_stored_op(rng: &mut Rng, tag: usize) -> StoredOp {
        let j = 1 + rng.below(4);
        let mut dims: Vec<usize> = (0..=j).map(|_| 1 + rng.below(12)).collect();
        if rng.below(4) == 0 {
            dims[0] = dims[j]; // occasionally square end to end
        }
        let mut factors = Vec::with_capacity(j);
        for k in 0..j {
            // chain: factors[k] maps dims[k] -> dims[k+1]
            let (r, c) = (dims[k + 1], dims[k]);
            let nnz = rng.below(r * c + 1);
            let m = gen::sparse_mat(rng, r, c, nnz);
            factors.push(Csr::from_dense(&m, 0.0));
        }
        let lambda = match rng.below(8) {
            0 => -rng.gauss() * 1e3,
            1 => rng.gauss() * 1e-12,
            _ => 1.0 + rng.uniform(),
        };
        let f32_bound = if rng.below(2) == 0 {
            Some(F32Bound {
                measured_rel_err: rng.uniform() * 1e-6,
                declared_rel_err: rng.uniform() * 1e-4,
            })
        } else {
            None
        };
        StoredOp {
            name: format!("op{tag}_{}", rng.below(1000)),
            epoch: rng.below(1 << 20) as u64,
            faust: Faust::new(factors, lambda),
            f32_bound,
        }
    }

    fn canonical_op() -> StoredOp {
        let mut rng = Rng::new(0x57_0BE);
        let s1 = gen::sparse_mat(&mut rng, 4, 6, 9);
        let s2 = gen::sparse_mat(&mut rng, 5, 4, 8);
        StoredOp {
            name: "canon".into(),
            epoch: 42,
            faust: Faust::new(vec![Csr::from_dense(&s1, 0.0), Csr::from_dense(&s2, 0.0)], 1.25),
            f32_bound: Some(F32Bound { measured_rel_err: 3e-8, declared_rel_err: 2e-6 }),
        }
    }

    fn tmp_store_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("faust_store_{}_{tag}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    #[test]
    fn round_trip_is_bitwise_and_profile_preserving() {
        check("store round-trip identity", &PropConfig::default(), |rng| {
            let op = arb_stored_op(rng, 0);
            let bytes = encode_op(&op).map_err(|e| e.to_string())?;
            let back = decode_op(&bytes).map_err(|e| e.to_string())?;
            ensure(back.name == op.name, "name changed")?;
            ensure(back.epoch == op.epoch, "epoch changed")?;
            ensure(
                faust_fingerprint(&back.faust) == faust_fingerprint(&op.faust),
                "factor/λ bits changed across persist→load",
            )?;
            // Same bits ⇒ same compiled plan cost profile. This is the
            // contract that makes shard placement and adaptive batching
            // identical before and after a restart.
            ensure(
                back.faust.plan().profile() == op.faust.plan().profile(),
                "CostProfile changed across persist→load",
            )?;
            match (op.f32_bound, back.f32_bound) {
                (None, None) => Ok(()),
                (Some(a), Some(b)) => ensure(
                    a.measured_rel_err.to_bits() == b.measured_rel_err.to_bits()
                        && a.declared_rel_err.to_bits() == b.declared_rel_err.to_bits(),
                    "f32 bound bits changed",
                ),
                _ => Err("f32 bound presence flipped".into()),
            }
        });
    }

    #[test]
    fn every_truncation_is_a_typed_error() {
        let bytes = encode_op(&canonical_op()).unwrap();
        for cut in 0..bytes.len() {
            let r = decode_op(&bytes[..cut]);
            assert!(r.is_err(), "prefix of {cut}/{} bytes decoded Ok", bytes.len());
        }
        // And one past the end: appended garbage is typed too.
        let mut longer = bytes.clone();
        longer.push(0);
        assert!(matches!(decode_op(&longer), Err(StoreError::TrailingGarbage { .. })));
        assert!(decode_op(&bytes).is_ok());
    }

    #[test]
    fn every_single_bit_flip_is_a_typed_error() {
        let bytes = encode_op(&canonical_op()).unwrap();
        for i in 0..bytes.len() {
            let mut m = bytes.clone();
            m[i] ^= 1 << (i % 8);
            let r = decode_op(&m);
            assert!(
                r.is_err(),
                "bit flip at byte {i} (of {}) decoded Ok — silent corruption",
                bytes.len()
            );
        }
    }

    #[test]
    fn random_byte_soup_never_panics() {
        check(
            "store decode total on garbage",
            &PropConfig { cases: 256, base_seed: 0x50FA }, // cheap cases, go wide
            |rng| {
                let n = rng.below(200);
                let soup: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
                // Typed Err expected; Ok would be a miracle but is not wrong.
                let _ = decode_op(&soup);
                Ok(())
            },
        );
    }

    #[test]
    fn checksum_valid_but_inconsistent_body_is_malformed_not_a_panic() {
        // Rebuild the canonical op's file with a corrupted factor header
        // and a RE-SEALED checksum: the CRC is fine, the structure lies.
        let op = canonical_op();
        let bytes = encode_op(&op).unwrap();
        // body offset of first factor's `cols` field:
        // 4 (len prefix) + 2 magic + 1 ver + 1 flags + 1 name_len + name
        // + 8 epoch + 8 λ + 4 n_factors + 16 bound + 4 rows
        let off = 4 + 2 + 1 + 1 + 1 + op.name.len() + 8 + 8 + 4 + 16 + 4;
        let mut m = bytes.clone();
        m[off..off + 4].copy_from_slice(&999u32.to_le_bytes()); // cols := 999
        let body_len = m.len() - 8;
        let seal = crc32(&m[4..4 + body_len]);
        let at = 4 + body_len;
        m[at..at + 4].copy_from_slice(&seal.to_le_bytes());
        match decode_op(&m) {
            Err(StoreError::Malformed(_)) => {}
            other => panic!("crafted body gave {other:?}, wanted Malformed"),
        }
    }

    #[test]
    fn bad_names_are_rejected_on_both_sides() {
        let long = "x".repeat(65);
        for bad in ["", "a/b", "../up", ".hidden", long.as_str(), "sp ace"] {
            let mut op = canonical_op();
            op.name = bad.to_string();
            assert!(
                matches!(encode_op(&op), Err(StoreError::BadName(_))),
                "encode accepted name {bad:?}"
            );
        }
        assert!(valid_name("ok-name_1.2"));
    }

    #[test]
    fn save_load_dir_skips_corrupt_files_and_loads_the_rest() {
        let dir = tmp_store_dir("dirscan");
        let mut a = canonical_op();
        a.name = "alpha".into();
        let mut b = canonical_op();
        b.name = "beta".into();
        b.f32_bound = None;
        save_op(&dir, &a).unwrap();
        let b_path = save_op(&dir, &b).unwrap();

        // A torn copy of a valid file and a foreign garbage file.
        let valid = std::fs::read(&b_path).unwrap();
        std::fs::write(dir.join("torn.fstore"), &valid[..valid.len() / 2]).unwrap();
        std::fs::write(dir.join("garbage.fstore"), b"not a snapshot").unwrap();
        // Stray tmp dotfile from a crashed persist: ignored entirely.
        std::fs::write(dir.join(".gamma.fstore.tmp"), b"half-written").unwrap();

        let loaded = load_dir(&dir).unwrap();
        let names: Vec<&str> = loaded.ops.iter().map(|o| o.name.as_str()).collect();
        assert_eq!(names, ["alpha", "beta"]);
        assert_eq!(loaded.skipped.len(), 2, "torn + garbage must both be reported");
        for (_, err) in &loaded.skipped {
            assert!(matches!(
                err,
                StoreError::Truncated { .. } | StoreError::ChecksumMismatch { .. }
            ));
        }
        assert_eq!(
            faust_fingerprint(&loaded.ops[0].faust),
            faust_fingerprint(&a.faust)
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_overwrites_atomically_under_the_same_name() {
        let dir = tmp_store_dir("overwrite");
        let mut op = canonical_op();
        save_op(&dir, &op).unwrap();
        op.epoch = 43;
        op.faust = Faust::from_dense(&Mat::eye(3, 3));
        save_op(&dir, &op).unwrap();
        let loaded = load_dir(&dir).unwrap();
        assert_eq!(loaded.ops.len(), 1);
        assert_eq!(loaded.ops[0].epoch, 43);
        assert_eq!(loaded.ops[0].faust.rows(), 3);
        assert!(loaded.skipped.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wire_and_store_magics_differ() {
        // A store file fed to the wire decoder (or vice versa) must die
        // on the first two bytes, not limp along.
        assert_ne!(MAGIC, crate::server::wire::MAGIC);
    }

    #[test]
    fn oversized_declared_length_is_refused_before_allocation() {
        let mut bytes = encode_op(&canonical_op()).unwrap();
        bytes[..4].copy_from_slice(&(u32::MAX).to_le_bytes());
        assert!(matches!(decode_op(&bytes), Err(StoreError::Oversized { .. })));
    }

    // -- learner snapshots (.lstore) ------------------------------------

    use crate::engine::ExecCtx;
    use crate::palm::online::{OnlineConfig, OnlinePalm};
    use crate::palm::PalmConfig;
    use crate::prox::Constraint;

    fn learner_cfg(j: usize) -> OnlineConfig {
        OnlineConfig::new(PalmConfig::new(vec![Constraint::SpRowCol(2); j], 1))
            .with_forgetting(0.75)
    }

    /// A learner mid-stream: n=8 Hadamard columns, two mini-batches in.
    fn canonical_learner() -> StoredLearner {
        let n = 8;
        let a = crate::transforms::hadamard(n);
        let mut ol = OnlinePalm::cold(&[(n, n); 3], learner_cfg(3));
        let ctx = ExecCtx::new(1);
        for _ in 0..2 {
            let batch: Vec<(usize, Vec<f64>)> = (0..n).map(|j| (j, a.col(j))).collect();
            ol.step(&ctx, &batch);
        }
        StoredLearner::from_online("learner1", &ol)
    }

    fn mats_bits(mats: &[Mat]) -> Vec<u64> {
        mats.iter().flat_map(|m| m.data().iter().map(|v| v.to_bits())).collect()
    }

    #[test]
    fn learner_round_trip_is_bitwise() {
        let l = canonical_learner();
        let back = decode_learner(&encode_learner(&l).unwrap()).unwrap();
        assert_eq!(back.name, l.name);
        assert_eq!((back.cols_seen, back.batches), (l.cols_seen, l.batches));
        assert_eq!(back.lambda.to_bits(), l.lambda.to_bits());
        assert_eq!(mats_bits(&back.mats), mats_bits(&l.mats));
        assert_eq!(
            mats_bits(std::slice::from_ref(&back.surrogate)),
            mats_bits(std::slice::from_ref(&l.surrogate))
        );
        let wb: Vec<u64> = back.weights.iter().map(|w| w.to_bits()).collect();
        let wl: Vec<u64> = l.weights.iter().map(|w| w.to_bits()).collect();
        assert_eq!(wb, wl);
    }

    #[test]
    fn learner_resume_is_bitwise_identical_to_uninterrupted() {
        // Run A straight through 4 mini-batches; run B for 2, snapshot
        // through the full disk encoding, resume, and finish. Same bits.
        let n = 8;
        let a = crate::transforms::hadamard(n);
        let ctx = ExecCtx::new(1);
        let batch = |p: usize| -> Vec<(usize, Vec<f64>)> {
            // Vary the stream a little so later batches aren't clones.
            (0..n).map(|j| ((j + p) % n, a.col((j + p) % n))).collect()
        };
        let mut full = OnlinePalm::cold(&[(n, n); 3], learner_cfg(3));
        for p in 0..4 {
            full.step(&ctx, &batch(p));
        }
        let mut half = OnlinePalm::cold(&[(n, n); 3], learner_cfg(3));
        for p in 0..2 {
            half.step(&ctx, &batch(p));
        }
        let snap = StoredLearner::from_online("resume-me", &half);
        let restored = decode_learner(&encode_learner(&snap).unwrap()).unwrap();
        let mut resumed = restored.resume(learner_cfg(3));
        for p in 2..4 {
            resumed.step(&ctx, &batch(p));
        }
        assert_eq!(resumed.cols_seen(), full.cols_seen());
        assert_eq!(resumed.batches(), full.batches());
        assert_eq!(
            resumed.state().lambda.to_bits(),
            full.state().lambda.to_bits(),
            "λ diverged across snapshot/resume"
        );
        assert_eq!(
            mats_bits(&resumed.state().mats),
            mats_bits(&full.state().mats),
            "factor bits diverged across snapshot/resume"
        );
    }

    #[test]
    fn learner_corruption_is_typed_never_a_panic() {
        let bytes = encode_learner(&canonical_learner()).unwrap();
        for cut in 0..bytes.len() {
            assert!(decode_learner(&bytes[..cut]).is_err(), "prefix {cut} decoded Ok");
        }
        // Sampled bit flips (the image is dense-f64 heavy, so the full
        // per-byte sweep the .fstore test runs would be slow here).
        for i in (0..bytes.len()).step_by(7) {
            let mut m = bytes.clone();
            m[i] ^= 1 << (i % 8);
            assert!(decode_learner(&m).is_err(), "bit flip at byte {i} decoded Ok");
        }
        assert!(decode_learner(&bytes).is_ok());
    }

    #[test]
    fn learner_and_operator_namespaces_are_disjoint() {
        assert_ne!(LEARNER_MAGIC, MAGIC);
        assert_ne!(LEARNER_MAGIC, crate::server::wire::MAGIC);
        // Cross-fed images die on the magic, not deeper.
        let lbytes = encode_learner(&canonical_learner()).unwrap();
        assert!(matches!(decode_op(&lbytes), Err(StoreError::BadMagic(m)) if m == LEARNER_MAGIC));
        let obytes = encode_op(&canonical_op()).unwrap();
        assert!(matches!(decode_learner(&obytes), Err(StoreError::BadMagic(m)) if m == MAGIC));
        // An operator-store scan neither loads nor reports learner files.
        let dir = tmp_store_dir("lstore_disjoint");
        let mut op = canonical_op();
        op.name = "alpha".into();
        save_op(&dir, &op).unwrap();
        let lpath = save_learner(&dir, &canonical_learner()).unwrap();
        assert_eq!(lpath.extension().and_then(|e| e.to_str()), Some(LEARNER_EXTENSION));
        let loaded = load_dir(&dir).unwrap();
        assert_eq!(loaded.ops.len(), 1);
        assert!(loaded.skipped.is_empty(), "learner files must be invisible to load_dir");
        // And the learner file itself loads back through its own path.
        assert_eq!(load_learner(&lpath).unwrap().name, "learner1");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Part of the miri-scoped suite (`cargo miri test miri_`): both
    /// codecs round-tripped fully in memory — no filesystem, so the test
    /// runs under Miri's default isolation. The byte-twiddling here
    /// (checksum seal, little-endian field packing, length-prefixed
    /// sections) is exactly the code most worth running under an
    /// interpreter that checks every slice index and integer cast.
    #[test]
    fn miri_store_codec_round_trip() {
        let op = canonical_op();
        let bytes = encode_op(&op).unwrap();
        let back = decode_op(&bytes).unwrap();
        assert_eq!(back.name, op.name);
        assert_eq!(back.epoch, op.epoch);
        assert_eq!(faust_fingerprint(&back.faust), faust_fingerprint(&op.faust));
        // Learner codec, with a hand-built snapshot: cheap enough for the
        // interpreter (no PALM steps, no thread pool).
        let l = StoredLearner {
            name: "miri_l".into(),
            mats: vec![Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0])],
            lambda: 0.5,
            surrogate: Mat::from_vec(2, 2, vec![0.1, 0.2, 0.3, 0.4]),
            weights: vec![1.0, 2.0],
            cols_seen: 7,
            batches: 3,
        };
        let lback = decode_learner(&encode_learner(&l).unwrap()).unwrap();
        assert_eq!(lback.name, l.name);
        assert_eq!(mats_bits(&lback.mats), mats_bits(&l.mats));
        assert_eq!(lback.lambda.to_bits(), l.lambda.to_bits());
        assert_eq!(mats_bits(&[lback.surrogate]), mats_bits(&[l.surrogate]));
        assert_eq!(lback.weights, l.weights);
        assert_eq!((lback.cols_seen, lback.batches), (7, 3));
    }
}
