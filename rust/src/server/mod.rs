//! Network ingress: a `std`-only TCP serving front end over the
//! coordinator.
//!
//! This is the L3-ingress layer of the serving pipeline — the full path
//! a request travels is now
//!
//! ```text
//! wire → admission → batcher → registry → engine
//! ```
//!
//! - [`wire`]: a compact length-prefixed binary protocol (format spec in
//!   the module docs) with typed error responses;
//! - [`admission`]: load shedding *before* the batcher — depth and
//!   modeled-cost watermarks with per-QoS-class headroom, typed
//!   `Overloaded` rejections, per-class shed counters in
//!   [`MetricsSnapshot`](crate::coordinator::MetricsSnapshot);
//! - per-connection reader/writer threads with a bounded ticket queue
//!   ([`conn`](self)): responses leave each connection in request order
//!   (FIFO), so misrouting is structurally impossible;
//! - QoS classes ([`QosClass`](crate::coordinator::QosClass)) ride the
//!   wire into the coordinator's class-keyed batcher, making batch
//!   sizing traffic-class-aware end to end;
//! - graceful lifecycle: [`Server::shutdown`] stops accepting, signals
//!   every reader, drains in-flight responses and joins all threads.
//!   Registry swaps
//!   ([`swap_epoch`](crate::coordinator::Registry::swap_epoch)) remain
//!   safe mid-connection — in-flight batches drain on their
//!   generation's `Arc`, and each OK response reports the epoch that
//!   served it.
//!
//! tokio is not available offline; like the coordinator, the front end
//! is `std::thread` + blocking sockets with timeouts — a compute-bound
//! matvec service saturates on worker flops long before thread-per-
//! connection ingress becomes the bottleneck.
//!
//! ```no_run
//! use faust::coordinator::{Coordinator, CoordinatorConfig, BatchOp, QosClass};
//! use faust::server::{Server, ServerConfig, ServeConn};
//! use faust::transforms::hadamard;
//! use std::sync::Arc;
//!
//! let n = 16;
//! let coord = Coordinator::start(
//!     vec![("h".to_string(), Arc::new(hadamard(n)) as Arc<dyn BatchOp>)],
//!     CoordinatorConfig::default(),
//! );
//! let server = Server::start(coord.client(), ServerConfig::default()).unwrap();
//! let mut conn = ServeConn::connect(&server.local_addr().to_string()).unwrap();
//! let _resp = conn.apply("h", QosClass::Interactive, vec![1.0; n]).unwrap();
//! server.shutdown();
//! coord.shutdown();
//! ```

// Ingress is safe-Rust protocols over sockets and the `engine::sync`
// shim; raw pointers stay confined to `engine::{kernel,pool}`.
#![forbid(unsafe_code)]

pub mod admission;
mod client;
mod conn;
pub mod wire;

pub use admission::{try_admit, Admission, AdmissionConfig, Overloaded, Permit};
pub use client::{ServeConn, ServeReceiver, ServeSender};

use crate::coordinator::{Client, Registry};
use crate::engine::sync::{AtomicBool, Ordering};
use std::net::{SocketAddr, TcpListener};
use std::path::PathBuf;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Ingress server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Listen address; port 0 picks an ephemeral port (see
    /// [`Server::local_addr`]).
    pub addr: String,
    /// Admission-controller watermarks.
    pub admission: AdmissionConfig,
    /// Bound of each connection's reader → writer ticket queue: a
    /// client that pipelines faster than it drains responses blocks its
    /// own reader instead of ballooning server memory.
    pub conn_queue: usize,
    /// Socket read timeout — how often an idle reader polls the stop
    /// flag; latency of graceful shutdown, not of requests.
    pub read_timeout: Duration,
    /// Durable operator store ([`crate::store`]). When set,
    /// [`Server::shutdown`] writes a final
    /// [`Registry::persist_all`] snapshot *after* the drain, so the
    /// learned fleet survives the process — a restart with
    /// `Registry::load_store` comes back warm. `None` (the default)
    /// keeps the pre-durability behavior.
    pub store_dir: Option<PathBuf>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            admission: AdmissionConfig::default(),
            conn_queue: 256,
            read_timeout: Duration::from_millis(50),
            store_dir: None,
        }
    }
}

/// The running ingress server: accept loop + per-connection threads.
pub struct Server {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    /// Kept for the final shutdown snapshot (the accept loop owns the
    /// `Client`; the registry must outlive it to persist after drain).
    registry: Arc<Registry>,
    store_dir: Option<PathBuf>,
}

impl Server {
    /// Bind `cfg.addr` and start serving `client`'s coordinator.
    pub fn start(client: Client, cfg: ServerConfig) -> std::io::Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        // Non-blocking accept so the loop can poll the stop flag
        // without a signal mechanism.
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let admission = Arc::new(Admission::new(cfg.admission.clone(), client.metrics_handle()));
        let a_stop = stop.clone();
        let registry = client.registry().clone();
        let store_dir = cfg.store_dir.clone();
        let accept = std::thread::Builder::new()
            .name("faust-accept".into())
            .spawn(move || accept_loop(listener, client, admission, cfg, a_stop))
            .expect("spawn accept loop");
        Ok(Server { local_addr, stop, accept: Some(accept), registry, store_dir })
    }

    /// The bound address (resolves the ephemeral port of `addr:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Graceful shutdown: stop accepting, signal every connection
    /// reader, drain in-flight responses to their clients, join all
    /// threads — then, if a [`ServerConfig::store_dir`] was configured,
    /// write a final registry snapshot. The snapshot runs *after* the
    /// drain, so it captures every swap the served traffic observed
    /// (the pre-durability server drained responses but dropped all
    /// registry state on the floor).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(dir) = &self.store_dir {
            if let Err(e) = self.registry.persist_all(dir) {
                // Shutdown must stay infallible for callers; a failed
                // final snapshot is loud but non-fatal (the previous
                // snapshot, if any, stays intact — saves are atomic).
                eprintln!("faust-server: final snapshot to {} failed: {e}", dir.display());
            }
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    client: Client,
    admission: Arc<Admission>,
    cfg: ServerConfig,
    stop: Arc<AtomicBool>,
) {
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    loop {
        if stop.load(Ordering::Acquire) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let c = client.clone();
                let a = admission.clone();
                let s = stop.clone();
                let queue = cfg.conn_queue;
                let rt = cfg.read_timeout;
                if let Ok(h) = std::thread::Builder::new()
                    .name("faust-conn".into())
                    .spawn(move || conn::serve_conn(stream, c, a, queue, rt, s))
                {
                    conns.push(h);
                }
                // Spawn failure: the stream drops (connection refused at
                // the TCP level); nothing to clean up.
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
                // Reap finished connection threads so a long-lived
                // server does not accumulate handles.
                conns.retain(|h| !h.is_finished());
            }
            Err(_) => break,
        }
    }
    // Drain: every reader observes `stop` within its read timeout, its
    // writer flushes in-flight responses, then the thread exits.
    for h in conns {
        let _ = h.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatchOp, Coordinator, CoordinatorConfig, QosClass};
    use crate::server::wire::{ErrorCode, WireResponse};
    use crate::transforms::hadamard;
    use std::io::Write;

    fn start_service() -> (Coordinator, Server, crate::linalg::Mat) {
        let n = 16;
        let h = hadamard(n);
        let coord = Coordinator::start(
            vec![("h".to_string(), Arc::new(h.clone()) as Arc<dyn BatchOp>)],
            CoordinatorConfig::default(),
        );
        let server = Server::start(coord.client(), ServerConfig::default()).unwrap();
        (coord, server, h)
    }

    #[test]
    fn serves_a_matvec_over_loopback() {
        let (coord, server, h) = start_service();
        let mut conn = ServeConn::connect(&server.local_addr().to_string()).unwrap();
        let x: Vec<f64> = (0..16).map(|i| i as f64 - 7.5).collect();
        let want = h.matvec(&x);
        match conn.apply("h", QosClass::Interactive, x).unwrap() {
            WireResponse::Ok { epoch, rows, cols, data, .. } => {
                assert_eq!((rows, cols), (16, 1));
                assert!(epoch >= 1);
                for i in 0..16 {
                    assert!((data[i] - want[i]).abs() < 1e-12);
                }
            }
            other => panic!("unexpected response: {other:?}"),
        }
        server.shutdown();
        let snap = coord.shutdown();
        assert_eq!(snap.ingress_accepted, 1);
        assert_eq!(snap.ingress_connections, 1);
        assert_eq!(snap.ingress_active_connections, 0);
    }

    #[test]
    fn f32_wire_tier_serves_over_loopback_and_echoes_dtype() {
        use crate::server::wire::Dtype;
        let (coord, server, h) = start_service();
        let mut conn = ServeConn::connect(&server.local_addr().to_string()).unwrap();
        conn.set_dtype(Dtype::F32);
        // Half-integer inputs are exactly representable in f32, so only
        // the operator's own f64 arithmetic separates the two tiers.
        let x: Vec<f64> = (0..16).map(|i| i as f64 * 0.5 - 4.0).collect();
        let want = h.matvec(&x);
        match conn.apply("h", QosClass::Standard, x).unwrap() {
            WireResponse::Ok { dtype, rows, cols, data, .. } => {
                assert_eq!(dtype, Dtype::F32, "response must echo the request dtype");
                assert_eq!((rows, cols), (16, 1));
                for i in 0..16 {
                    let rel = (data[i] - want[i]).abs() / want[i].abs().max(1.0);
                    assert!(rel < 1e-6, "f32 wire tier drifted: {} vs {}", data[i], want[i]);
                }
            }
            other => panic!("unexpected response: {other:?}"),
        }
        server.shutdown();
        coord.shutdown();
    }

    #[test]
    fn v1_client_negotiates_down_to_f64_frames() {
        use crate::server::wire::{self, Dtype, WireRequest};
        let (coord, server, h) = start_service();
        let mut stream = std::net::TcpStream::connect(server.local_addr()).unwrap();
        let x: Vec<f64> = (0..16).map(|i| (i as f64).cos()).collect();
        let want = h.matvec(&x);
        let req = WireRequest {
            req_id: 77,
            op: "h".to_string(),
            class: QosClass::Standard,
            deadline_us: 0,
            dtype: Dtype::F64,
            version: 1,
            rows: 16,
            cols: 1,
            data: x,
        };
        wire::write_frame(&mut stream, &wire::encode_request(&req)).unwrap();
        let body = wire::read_frame(&mut stream).unwrap().expect("response frame");
        assert_eq!(body[2], 1, "server must answer a v1 client at version 1");
        match wire::decode_response(&body).unwrap() {
            WireResponse::Ok { req_id, dtype, data, .. } => {
                assert_eq!(req_id, 77);
                assert_eq!(dtype, Dtype::F64);
                for i in 0..16 {
                    assert!((data[i] - want[i]).abs() < 1e-12);
                }
            }
            other => panic!("unexpected response: {other:?}"),
        }
        server.shutdown();
        coord.shutdown();
    }

    #[test]
    fn unknown_operator_is_a_typed_response_not_a_close() {
        let (coord, server, h) = start_service();
        let mut conn = ServeConn::connect(&server.local_addr().to_string()).unwrap();
        match conn.apply("ghost", QosClass::Standard, vec![0.0; 16]).unwrap() {
            WireResponse::Err { code, .. } => assert_eq!(code, ErrorCode::UnknownOperator),
            other => panic!("unexpected response: {other:?}"),
        }
        // The connection survived the error.
        let x = vec![1.0; 16];
        let want = h.matvec(&x);
        match conn.apply("h", QosClass::Standard, x).unwrap() {
            WireResponse::Ok { data, .. } => {
                assert!((data[0] - want[0]).abs() < 1e-12);
            }
            other => panic!("unexpected response: {other:?}"),
        }
        server.shutdown();
        coord.shutdown();
    }

    #[test]
    fn garbage_framing_closes_only_the_offending_connection() {
        let (coord, server, h) = start_service();
        let addr = server.local_addr().to_string();
        // A connection that speaks garbage (bad magic in the body).
        let mut bad = std::net::TcpStream::connect(&addr).unwrap();
        let mut frame = Vec::new();
        frame.extend_from_slice(&26u32.to_le_bytes());
        frame.extend_from_slice(&[0u8; 26]); // magic 0x0000: framing breaker
        bad.write_all(&frame).unwrap();
        // The server closes it; a well-behaved connection still works.
        let mut good = ServeConn::connect(&addr).unwrap();
        let x = vec![1.0; 16];
        let want = h.matvec(&x);
        match good.apply("h", QosClass::Bulk, x).unwrap() {
            WireResponse::Ok { data, .. } => assert!((data[0] - want[0]).abs() < 1e-12),
            other => panic!("unexpected response: {other:?}"),
        }
        server.shutdown();
        coord.shutdown();
    }

    #[test]
    fn shutdown_writes_a_loadable_complete_final_snapshot() {
        // Regression: the pre-durability shutdown drained responses but
        // dropped every learned operator. With a store_dir, the final
        // snapshot must be present, loadable, and cover the whole
        // persistable fleet — including a generation swapped in
        // mid-serve.
        use crate::coordinator::Registry;
        use crate::engine::ApplyEngine;
        use crate::transforms::hadamard_faust;
        let dir = std::env::temp_dir()
            .join(format!("faust_server_snap_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let n = 16;
        let engine = ApplyEngine::with_threads(1);
        let coord = Coordinator::start(
            vec![
                (
                    "h".to_string(),
                    Arc::new(engine.op(&hadamard_faust(n))) as Arc<dyn BatchOp>,
                ),
                (
                    "g".to_string(),
                    Arc::new(engine.op(&hadamard_faust(8))) as Arc<dyn BatchOp>,
                ),
            ],
            CoordinatorConfig::default(),
        );
        let cfg = ServerConfig { store_dir: Some(dir.clone()), ..ServerConfig::default() };
        let server = Server::start(coord.client(), cfg).unwrap();
        let mut conn = ServeConn::connect(&server.local_addr().to_string()).unwrap();
        conn.apply("h", QosClass::Standard, vec![1.0; n]).unwrap();
        // A mid-serve swap must land in the final snapshot's epochs.
        let swapped_epoch = coord
            .registry()
            .swap_epoch(
                "h",
                Arc::new(engine.op(&hadamard_faust(n))) as Arc<dyn BatchOp>,
            )
            .unwrap();
        server.shutdown();
        // The snapshot is loadable and complete: both operators, and
        // "h" at (or past) its swapped epoch.
        let restored = Registry::new(None);
        let report = restored
            .load_store(&dir, |_, f| Arc::new(engine.op(f)) as Arc<dyn BatchOp>)
            .unwrap();
        assert_eq!(report.loaded, vec!["g".to_string(), "h".to_string()]);
        assert!(report.corrupt.is_empty() && report.rejected.is_empty());
        assert_eq!(restored.get("h").unwrap().rows(), n);
        assert_eq!(restored.get("g").unwrap().rows(), 8);
        assert!(restored.epoch() >= swapped_epoch);
        let snap = coord.shutdown();
        assert_eq!(snap.store_persisted, 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shutdown_drains_inflight_responses() {
        let (coord, server, h) = start_service();
        let mut conn = ServeConn::connect(&server.local_addr().to_string()).unwrap();
        // Pipeline a burst, then shut the server down before reading.
        let x: Vec<f64> = (0..16).map(|i| (i as f64).sin()).collect();
        let want = h.matvec(&x);
        for _ in 0..8 {
            conn.send("h", QosClass::Standard, 0, 16, 1, x.clone()).unwrap();
        }
        std::thread::sleep(Duration::from_millis(30));
        server.shutdown();
        // Every pipelined request was answered before the close.
        for _ in 0..8 {
            match conn.recv().unwrap() {
                WireResponse::Ok { data, .. } => {
                    assert!((data[3] - want[3]).abs() < 1e-12);
                }
                other => panic!("request lost in shutdown: {other:?}"),
            }
        }
        coord.shutdown();
    }
}

/// Loom model of the connection FIFO-ticket / shutdown-drain protocol
/// (`cargo test --features loom-model --release loom_`). `std::sync::mpsc`
/// has no loom twin, so — like `coordinator::online` — the model rebuilds
/// the bounded reader→writer ticket queue on the `engine::sync`
/// primitives and proves the two contracts `serve_conn` is trusted for:
/// responses leave in request order (FIFO), and raising `stop` never
/// drops a ticket the reader already enqueued (drain-before-join).
#[cfg(all(test, feature = "loom-model"))]
mod loom_tests {
    use crate::engine::sync::{AtomicBool, Condvar, Mutex, Ordering};
    use loom::sync::Arc;
    use loom::thread;
    use std::collections::VecDeque;

    /// Bounded FIFO ticket queue: capacity 1 (worst-case backpressure),
    /// closed flag, a condvar per direction — the shape
    /// `sync_channel(conn_queue)` gives each connection.
    struct TicketQueue {
        q: Mutex<(VecDeque<u32>, bool)>,
        can_send: Condvar,
        can_recv: Condvar,
    }

    impl TicketQueue {
        fn new() -> Self {
            TicketQueue {
                q: Mutex::new((VecDeque::new(), false)),
                can_send: Condvar::new(),
                can_recv: Condvar::new(),
            }
        }

        /// Blocking bounded send — the reader pushing a ticket.
        fn send(&self, t: u32) {
            let mut g = self.q.lock().unwrap();
            while !g.0.is_empty() {
                g = self.can_send.wait(g).unwrap();
            }
            g.0.push_back(t);
            self.can_recv.notify_one();
        }

        /// Close (the reader dropping its sender after observing stop).
        fn close(&self) {
            let mut g = self.q.lock().unwrap();
            g.1 = true;
            self.can_recv.notify_one();
        }

        /// Writer receive: FIFO, `None` only once closed *and* drained.
        fn recv(&self) -> Option<u32> {
            let mut g = self.q.lock().unwrap();
            loop {
                if let Some(t) = g.0.pop_front() {
                    self.can_send.notify_one();
                    return Some(t);
                }
                if g.1 {
                    return None;
                }
                g = self.can_recv.wait(g).unwrap();
            }
        }
    }

    /// A reader pipelining tickets races `Server::shutdown` raising the
    /// stop flag: whatever the interleaving, the writer drains exactly
    /// the tickets the reader enqueued, in order, and every thread
    /// terminates (loom flags a lost wakeup as a deadlock).
    #[test]
    fn loom_shutdown_never_drops_an_enqueued_ticket() {
        loom::model(|| {
            let q = Arc::new(TicketQueue::new());
            let stop = Arc::new(AtomicBool::new(false));
            let writer = {
                let q = q.clone();
                thread::spawn(move || {
                    let mut written = Vec::new();
                    while let Some(t) = q.recv() {
                        written.push(t);
                    }
                    written
                })
            };
            {
                let stop = stop.clone();
                thread::spawn(move || stop.store(true, Ordering::Release));
            }
            // Main thread is the reader: pipeline tickets until the stop
            // flag is observed, then close the queue (drop the sender).
            let mut sent = Vec::new();
            for t in 1..=2u32 {
                if stop.load(Ordering::Acquire) {
                    break;
                }
                q.send(t);
                sent.push(t);
            }
            q.close();
            let written = writer.join().unwrap();
            assert_eq!(written, sent, "shutdown dropped or reordered an in-flight response");
        });
    }
}
