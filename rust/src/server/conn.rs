//! Per-connection plumbing: a reader on the accepting thread and a
//! dedicated writer thread, joined by a bounded queue.
//!
//! The reader parses frames, runs admission, fans a request's columns
//! into the coordinator and pushes a [`Pending`] ticket into the writer
//! queue. The writer resolves tickets **in order**, so responses leave
//! the connection in request order (FIFO) — the invariant that makes
//! misrouting impossible without any per-request bookkeeping on the
//! client. The bounded queue is intake backpressure: a client that
//! pipelines faster than it reads its responses eventually blocks its
//! own reader instead of ballooning server memory.
//!
//! Error discipline: a malformed-but-delimited body gets a typed
//! [`ErrorCode::Malformed`] response and the connection stays up; an
//! error that breaks framing (bad magic, oversized announcement,
//! truncation) closes the connection. Neither path ever panics a
//! connection thread.

use super::admission::{self, Admission, Permit};
use super::wire::{self, Dtype, ErrorCode, WireError, WireRequest, WireResponse};
use crate::coordinator::{Client, ServeError};
use crate::engine::sync::{AtomicBool, Ordering};
use std::io::Read;
use std::net::TcpStream;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::time::Duration;

/// A ticket in the writer queue: either an already-resolved response or
/// the per-column response channels of an admitted request. Every ticket
/// remembers the request's protocol version so the writer answers each
/// client in the layout it speaks (v1 clients get dtype-less f64
/// responses, whatever tier served them).
enum Pending {
    Ready(WireResponse, u8),
    InFlight {
        req_id: u64,
        /// Registry epoch of the generation resolved at submit time.
        epoch: u64,
        rows: usize,
        cols: usize,
        /// Payload dtype the response travels as (echoes the request).
        dtype: Dtype,
        /// Protocol version the request arrived at.
        version: u8,
        rxs: Vec<Receiver<Result<Vec<f64>, ServeError>>>,
        /// Admission reservation, released when the ticket resolves.
        _permit: Permit,
    },
}

/// Serve one accepted connection to completion. Returns when the peer
/// closes, framing breaks, or `stop` is observed; in-flight requests
/// are drained (their responses written) before the connection closes.
pub(crate) fn serve_conn(
    stream: TcpStream,
    client: Client,
    admission: Arc<Admission>,
    queue_bound: usize,
    read_timeout: Duration,
    stop: Arc<AtomicBool>,
) {
    let metrics = client.metrics_handle();
    metrics.record_conn_opened();
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(read_timeout.max(Duration::from_millis(1))));
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => {
            metrics.record_conn_closed();
            return;
        }
    };
    let (tx, rx) = sync_channel::<Pending>(queue_bound.max(1));
    let writer = std::thread::Builder::new()
        .name("faust-conn-writer".into())
        .spawn(move || writer_loop(write_half, rx));
    match writer {
        Ok(writer) => {
            reader_loop(stream, &client, &admission, &tx, &stop);
            // Closing the queue lets the writer drain every in-flight
            // ticket (graceful drain), then exit.
            drop(tx);
            let _ = writer.join();
        }
        Err(_) => drop(tx),
    }
    metrics.record_conn_closed();
}

fn reader_loop(
    mut stream: TcpStream,
    client: &Client,
    admission: &Arc<Admission>,
    tx: &SyncSender<Pending>,
    stop: &AtomicBool,
) {
    loop {
        let body = match read_frame_polling(&mut stream, stop) {
            Ok(Some(b)) => b,
            // Clean close, stop observed, or broken framing: either way
            // the read side is done.
            Ok(None) | Err(_) => return,
        };
        let ticket = match wire::decode_request(&body) {
            Ok(req) => handle_request(client, admission, req),
            Err(e) if !e.breaks_framing() => Pending::Ready(
                WireResponse::Err {
                    req_id: peek_req_id(&body),
                    code: ErrorCode::Malformed,
                    msg: e.to_string(),
                },
                peek_version(&body),
            ),
            Err(_) => return,
        };
        if tx.send(ticket).is_err() {
            return; // writer gone (peer closed its read side)
        }
    }
}

/// Best-effort req_id extraction from a body that failed to decode, so
/// even a Malformed response correlates when the prefix was intact.
fn peek_req_id(body: &[u8]) -> u64 {
    if body.len() >= 12 {
        let mut x = [0u8; 8];
        x.copy_from_slice(&body[4..12]);
        u64::from_le_bytes(x)
    } else {
        0
    }
}

/// Best-effort protocol version of a body that failed to decode, so the
/// Malformed response is written in a layout the peer can parse.
fn peek_version(body: &[u8]) -> u8 {
    match body.get(2) {
        Some(&v) if (wire::MIN_VERSION..=wire::VERSION).contains(&v) => v,
        _ => wire::VERSION,
    }
}

/// Admission + submission for one decoded request.
fn handle_request(client: &Client, admission: &Arc<Admission>, req: WireRequest) -> Pending {
    let req_id = req.req_id;
    let version = req.version;
    let ready_err = |code: ErrorCode, msg: String| {
        Pending::Ready(WireResponse::Err { req_id, code, msg }, version)
    };
    let handle = match client.registry().get(&req.op) {
        Some(h) => h,
        None => {
            let e = ServeError::UnknownOperator(req.op.clone());
            return ready_err(ErrorCode::UnknownOperator, e.to_string());
        }
    };
    if req.rows != handle.cols() {
        let e = ServeError::WrongDimension { expected: handle.cols(), got: req.rows };
        return ready_err(ErrorCode::WrongDimension, e.to_string());
    }
    let epoch = client.registry().epoch_of(&req.op).unwrap_or(0);
    if req.cols == 0 {
        return Pending::Ready(
            WireResponse::Ok {
                req_id,
                epoch,
                rows: handle.rows(),
                cols: 0,
                dtype: req.dtype,
                data: Vec::new(),
            },
            version,
        );
    }
    let cost = handle.flops_per_matvec() as u64 * req.cols as u64;
    let permit = match admission::try_admit(admission, req.class, cost) {
        Ok(p) => p,
        Err(_) => return ready_err(ErrorCode::Overloaded, "shed by admission control".into()),
    };
    let deadline = if req.deadline_us == 0 {
        None
    } else {
        Some(Duration::from_micros(req.deadline_us as u64))
    };
    let mut rxs = Vec::with_capacity(req.cols);
    for c in 0..req.cols {
        let x = req.data[c * req.rows..(c + 1) * req.rows].to_vec();
        match client.submit_class(&req.op, x, req.class, deadline) {
            Ok(rx) => rxs.push(rx),
            // One column failing to submit fails the whole request with
            // the mapped typed code (QueueFull → Overloaded); responses
            // of already-submitted columns are discarded.
            Err(e) => return ready_err(ErrorCode::from_serve_error(&e), e.to_string()),
        }
    }
    Pending::InFlight {
        req_id,
        epoch,
        rows: handle.rows(),
        cols: req.cols,
        dtype: req.dtype,
        version,
        rxs,
        _permit: permit,
    }
}

fn writer_loop(mut stream: TcpStream, rx: Receiver<Pending>) {
    while let Ok(ticket) = rx.recv() {
        let (resp, version) = match ticket {
            Pending::Ready(r, version) => (r, version),
            Pending::InFlight { req_id, epoch, rows, cols, dtype, version, rxs, _permit } => {
                let mut data = vec![0.0; rows * cols];
                let mut failure: Option<ServeError> = None;
                for (c, crx) in rxs.into_iter().enumerate() {
                    match crx.recv() {
                        Ok(Ok(y)) if y.len() == rows => {
                            data[c * rows..(c + 1) * rows].copy_from_slice(&y);
                        }
                        // A reshape (retire + register) resolved this
                        // column against a different-shape generation.
                        Ok(Ok(y)) => {
                            failure.get_or_insert(ServeError::WrongDimension {
                                expected: rows,
                                got: y.len(),
                            });
                        }
                        Ok(Err(e)) => {
                            failure.get_or_insert(e);
                        }
                        Err(_) => {
                            failure.get_or_insert(ServeError::ShuttingDown);
                        }
                    }
                }
                let resp = match failure {
                    None => WireResponse::Ok { req_id, epoch, rows, cols, dtype, data },
                    Some(e) => WireResponse::Err {
                        req_id,
                        code: ErrorCode::from_serve_error(&e),
                        msg: e.to_string(),
                    },
                };
                (resp, version)
            }
        };
        if wire::write_frame(&mut stream, &wire::encode_response(&resp, version)).is_err() {
            // Peer is gone: drop the remaining tickets (their permits
            // release on drop) and let the reader notice on its side.
            return;
        }
    }
}

/// [`wire::read_frame`] adapted to a socket with a read timeout: the
/// timeout only polls for the *start* of a frame (checking `stop` while
/// idle); once a frame has begun, reads continue through timeouts so a
/// slow sender cannot desynchronize framing. If `stop` is raised
/// mid-frame the reader allows a bounded grace (~20 poll intervals) for
/// the frame to complete, then gives up.
fn read_frame_polling(
    stream: &mut TcpStream,
    stop: &AtomicBool,
) -> Result<Option<Vec<u8>>, WireError> {
    const STOP_GRACE_POLLS: u32 = 20;
    let mut stop_polls = 0u32;
    let mut timed_out = |mid_frame: bool| -> bool {
        // Returns true when the caller should abort the read.
        if stop.load(Ordering::Acquire) {
            if !mid_frame {
                return true;
            }
            stop_polls += 1;
            return stop_polls > STOP_GRACE_POLLS;
        }
        false
    };
    let mut len = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match stream.read(&mut len[got..]) {
            Ok(0) => {
                return if got == 0 { Ok(None) } else { Err(WireError::Truncated) };
            }
            Ok(n) => got += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if timed_out(got > 0) {
                    return if got == 0 { Ok(None) } else { Err(WireError::Truncated) };
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(WireError::Io(e.kind())),
        }
    }
    let body_len = u32::from_le_bytes(len);
    if body_len > wire::MAX_FRAME {
        return Err(WireError::Oversized(body_len));
    }
    let mut body = vec![0u8; body_len as usize];
    let mut at = 0usize;
    while at < body.len() {
        match stream.read(&mut body[at..]) {
            Ok(0) => return Err(WireError::Truncated),
            Ok(n) => at += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if timed_out(true) {
                    return Err(WireError::Truncated);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(WireError::Io(e.kind())),
        }
    }
    Ok(Some(body))
}
