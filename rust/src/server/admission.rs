//! Admission control: shed load *before* it reaches the batcher.
//!
//! The coordinator's bounded request queue is the last line of defense;
//! by the time it fills, every queued request is already paying the
//! backlog's latency. The admission controller sits at the wire instead
//! and bounds two things:
//!
//! - **depth**: how many wire requests may be in flight at once
//!   (submitted but not yet answered);
//! - **modeled cost**: the summed flops of in-flight requests, so one
//!   batch of huge-operator columns cannot crowd out thousands of cheap
//!   interactive matvecs behind an innocent-looking depth number.
//!
//! Watermarks are **per class**: each QoS class sees only a fraction of
//! the global budget ([`AdmissionConfig::class_headroom`]), ordered so
//! bulk sheds first and interactive last. A rejected request surfaces to
//! the client as the typed [`ErrorCode::Overloaded`]
//! (see [`super::wire`]) and bumps the per-class shed counter in
//! [`MetricsSnapshot`](crate::coordinator::MetricsSnapshot) — shedding
//! is never a dropped connection or a silent stall.
//!
//! Accounting is add-then-check: a permit optimistically reserves its
//! depth/cost, checks the class watermark, and backs out on rejection.
//! Two racing requests can thus each see the other's reservation — the
//! controller may shed a request that would *just* have fit, never
//! admit one over budget. Release is RAII ([`Permit`]), so an IO error
//! or panic on the connection path cannot leak budget.

use crate::coordinator::{Metrics, QosClass};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Watermarks for the admission controller.
#[derive(Clone, Debug)]
pub struct AdmissionConfig {
    /// Global cap on in-flight wire requests.
    pub max_inflight: u64,
    /// Global cap on the summed modeled cost (flops per matvec × cols)
    /// of in-flight requests.
    pub max_inflight_cost: u64,
    /// Per-class fraction of the global budgets, indexed by
    /// [`QosClass::index`]. Bulk's headroom is lowest so it sheds
    /// first; interactive keeps admitting until the global cap.
    pub class_headroom: [f64; 3],
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            max_inflight: 4096,
            max_inflight_cost: 1 << 32,
            class_headroom: [1.0, 0.85, 0.6],
        }
    }
}

/// The typed rejection: this request was shed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Overloaded;

/// Shared admission state (one per server, shared by all connections).
pub struct Admission {
    cfg: AdmissionConfig,
    inflight: AtomicU64,
    inflight_cost: AtomicU64,
    metrics: Arc<Metrics>,
}

impl Admission {
    pub fn new(cfg: AdmissionConfig, metrics: Arc<Metrics>) -> Self {
        Admission {
            cfg,
            inflight: AtomicU64::new(0),
            inflight_cost: AtomicU64::new(0),
            metrics,
        }
    }

    /// Current in-flight depth (tests / introspection).
    pub fn depth(&self) -> u64 {
        self.inflight.load(Ordering::Acquire)
    }
}

/// Try to admit a request of modeled `cost` under `class`. On success
/// the returned [`Permit`] holds the reservation until dropped; on
/// rejection the per-class shed counter is bumped and nothing is held.
pub fn try_admit(
    admission: &Arc<Admission>,
    class: QosClass,
    cost: u64,
) -> Result<Permit, Overloaded> {
    let a = admission;
    let depth = a.inflight.fetch_add(1, Ordering::AcqRel) + 1;
    let total = a.inflight_cost.fetch_add(cost, Ordering::AcqRel) + cost;
    let h = a.cfg.class_headroom[class.index()].clamp(0.0, 1.0);
    let depth_cap = (a.cfg.max_inflight as f64 * h) as u64;
    let cost_cap = (a.cfg.max_inflight_cost as f64 * h) as u64;
    if depth > depth_cap.max(1) || total > cost_cap.max(cost) {
        a.inflight.fetch_sub(1, Ordering::AcqRel);
        a.inflight_cost.fetch_sub(cost, Ordering::AcqRel);
        a.metrics.record_ingress_shed(class);
        return Err(Overloaded);
    }
    a.metrics.record_ingress_accepted();
    a.metrics.record_ingress_depth(depth);
    Ok(Permit { admission: a.clone(), cost })
}

/// RAII reservation: releases its depth and cost on drop.
pub struct Permit {
    admission: Arc<Admission>,
    cost: u64,
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.admission.inflight.fetch_sub(1, Ordering::AcqRel);
        self.admission.inflight_cost.fetch_sub(self.cost, Ordering::AcqRel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn admission(max_inflight: u64, max_cost: u64) -> Arc<Admission> {
        Arc::new(Admission::new(
            AdmissionConfig {
                max_inflight,
                max_inflight_cost: max_cost,
                ..AdmissionConfig::default()
            },
            Arc::new(Metrics::new()),
        ))
    }

    #[test]
    fn depth_watermark_sheds_and_releases() {
        let a = admission(2, u64::MAX / 2);
        let p1 = try_admit(&a, QosClass::Interactive, 1).unwrap();
        let p2 = try_admit(&a, QosClass::Interactive, 1).unwrap();
        assert_eq!(a.depth(), 2);
        // Full: the third is shed (typed, counted).
        assert!(matches!(try_admit(&a, QosClass::Interactive, 1), Err(Overloaded)));
        assert_eq!(a.metrics.snapshot().ingress_shed, [1, 0, 0]);
        // A release frees the slot.
        drop(p1);
        let _p3 = try_admit(&a, QosClass::Interactive, 1).unwrap();
        drop(p2);
        assert_eq!(a.depth(), 1);
        let s = a.metrics.snapshot();
        assert_eq!(s.ingress_accepted, 3);
        assert_eq!(s.ingress_queue_hwm, 2);
    }

    #[test]
    fn cost_watermark_sheds_expensive_load() {
        let a = admission(1000, 100);
        let _p = try_admit(&a, QosClass::Interactive, 90).unwrap();
        // Depth is fine but the summed cost would blow the budget.
        assert!(matches!(try_admit(&a, QosClass::Interactive, 50), Err(Overloaded)));
        // A cheap request still fits.
        let _q = try_admit(&a, QosClass::Interactive, 5).unwrap();
        // A single over-budget request on an idle controller is still
        // admitted (cost_cap.max(cost)): nothing smaller could ever run
        // otherwise, and depth still bounds it.
        let b = admission(1000, 10);
        assert!(try_admit(&b, QosClass::Interactive, 50).is_ok());
    }

    #[test]
    fn bulk_sheds_before_interactive() {
        // Headroom [1.0, 0.85, 0.6] over max_inflight 10: bulk is cut
        // off at 6 while interactive still admits.
        let a = admission(10, u64::MAX / 2);
        let mut permits = Vec::new();
        for _ in 0..6 {
            permits.push(try_admit(&a, QosClass::Bulk, 1).unwrap());
        }
        assert!(matches!(try_admit(&a, QosClass::Bulk, 1), Err(Overloaded)));
        let p = try_admit(&a, QosClass::Interactive, 1).unwrap();
        assert_eq!(a.depth(), 7);
        drop(p);
        drop(permits);
        assert_eq!(a.depth(), 0);
        assert_eq!(a.metrics.snapshot().ingress_shed, [0, 0, 1]);
    }

    #[test]
    fn failed_admission_leaks_no_budget() {
        let a = admission(1, u64::MAX / 2);
        let p = try_admit(&a, QosClass::Standard, 1).unwrap();
        for _ in 0..100 {
            assert!(try_admit(&a, QosClass::Standard, 1).is_err());
        }
        // The 100 rejections backed out their reservations.
        drop(p);
        assert_eq!(a.depth(), 0);
        assert!(try_admit(&a, QosClass::Standard, 1).is_ok());
    }
}
