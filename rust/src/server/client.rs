//! Minimal `std`-only client side of the wire protocol: connect, send
//! framed requests, read framed responses. Used by the CLI `client`
//! subcommand, the open-loop load generator
//! ([`crate::bench_util::open_loop_load`]) and the loopback tests.

use super::wire::{self, Dtype, WireError, WireRequest, WireResponse};
use crate::coordinator::QosClass;
use std::net::TcpStream;

/// A blocking client connection. Payloads travel as f64 unless
/// [`ServeConn::set_dtype`] selects the f32 wire tier (half the payload
/// bytes each way; values quantize to f32 in transit).
pub struct ServeConn {
    stream: TcpStream,
    next_id: u64,
    dtype: Dtype,
}

impl ServeConn {
    pub fn connect(addr: &str) -> std::io::Result<ServeConn> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(ServeConn { stream, next_id: 0, dtype: Dtype::F64 })
    }

    /// Select the payload element type for every subsequent send.
    pub fn set_dtype(&mut self, dtype: Dtype) {
        self.dtype = dtype;
    }

    /// Send one request without waiting for its response (pipelining);
    /// returns the request id. Responses arrive in request order.
    pub fn send(
        &mut self,
        op: &str,
        class: QosClass,
        deadline_us: u32,
        rows: usize,
        cols: usize,
        data: Vec<f64>,
    ) -> Result<u64, WireError> {
        let req_id = self.next_id;
        self.next_id += 1;
        let req = WireRequest {
            req_id,
            op: op.to_string(),
            class,
            deadline_us,
            dtype: self.dtype,
            version: wire::VERSION,
            rows,
            cols,
            data,
        };
        wire::write_frame(&mut self.stream, &wire::encode_request(&req))?;
        Ok(req_id)
    }

    /// Read the next response (FIFO). A clean peer close surfaces as
    /// [`WireError::Truncated`].
    pub fn recv(&mut self) -> Result<WireResponse, WireError> {
        let body = wire::read_frame(&mut self.stream)?.ok_or(WireError::Truncated)?;
        wire::decode_response(&body)
    }

    /// Blocking single matvec: send one column, wait for its response.
    pub fn apply(
        &mut self,
        op: &str,
        class: QosClass,
        x: Vec<f64>,
    ) -> Result<WireResponse, WireError> {
        let rows = x.len();
        self.send(op, class, 0, rows, 1, x)?;
        self.recv()
    }

    /// Split into independently-usable halves: open-loop load
    /// generation paces sends by the clock on one thread while another
    /// drains responses.
    pub fn split(self) -> std::io::Result<(ServeSender, ServeReceiver)> {
        let read_half = self.stream.try_clone()?;
        Ok((
            ServeSender { stream: self.stream, next_id: self.next_id, dtype: self.dtype },
            ServeReceiver { stream: read_half },
        ))
    }
}

/// Write half of a split [`ServeConn`] (inherits the conn's dtype).
pub struct ServeSender {
    stream: TcpStream,
    next_id: u64,
    dtype: Dtype,
}

impl ServeSender {
    /// Same contract as [`ServeConn::send`].
    pub fn send(
        &mut self,
        op: &str,
        class: QosClass,
        deadline_us: u32,
        rows: usize,
        cols: usize,
        data: Vec<f64>,
    ) -> Result<u64, WireError> {
        let req_id = self.next_id;
        self.next_id += 1;
        let req = WireRequest {
            req_id,
            op: op.to_string(),
            class,
            deadline_us,
            dtype: self.dtype,
            version: wire::VERSION,
            rows,
            cols,
            data,
        };
        wire::write_frame(&mut self.stream, &wire::encode_request(&req))?;
        Ok(req_id)
    }
}

/// Read half of a split [`ServeConn`].
pub struct ServeReceiver {
    stream: TcpStream,
}

impl ServeReceiver {
    /// Same contract as [`ServeConn::recv`].
    pub fn recv(&mut self) -> Result<WireResponse, WireError> {
        let body = wire::read_frame(&mut self.stream)?.ok_or(WireError::Truncated)?;
        wire::decode_response(&body)
    }
}
