//! Wire protocol of the ingress server: a compact little-endian binary
//! framing, `std`-only on both ends.
//!
//! # Frame layout
//!
//! Every message (either direction) is one *frame*:
//!
//! ```text
//! u32  body_len            length of the body that follows
//! [u8; body_len]           the body
//! ```
//!
//! `body_len` is capped at [`MAX_FRAME`] (16 MiB); a larger
//! announcement is rejected as [`WireError::Oversized`] before any
//! allocation, so a hostile peer cannot balloon server memory.
//!
//! # Request body (client → server), version 2
//!
//! ```text
//! u16  magic               0xFA57
//! u8   version             2
//! u8   kind                0 = request
//! u64  req_id              caller-chosen correlation id, echoed back
//! u8   class               QoS class: 0 interactive, 1 standard, 2 bulk
//! u8   dtype               payload element type: 0 = f64, 1 = f32
//! u8   name_len            operator-name length in bytes
//! u32  deadline_us         per-request deadline override in µs
//!                          (0 ⇒ use the class's default budget)
//! u32  rows                input rows (must equal the operator's cols)
//! u32  cols                number of input columns in this request
//! [u8; name_len]           operator name (UTF-8)
//! [dtype; rows*cols]       payload, little-endian, column-major
//! ```
//!
//! `body_len` must equal `27 + name_len + elem·rows·cols` *exactly*
//! (`elem` = 8 for f64, 4 for f32); anything else is
//! [`WireError::LengthMismatch`]. A decode failure on a well-delimited
//! frame is answered with a typed [`ErrorCode::Malformed`] response and
//! the connection stays up; a failure that breaks framing itself (bad
//! magic/version, oversized announcement, short read) closes the
//! connection.
//!
//! **Version 1** (the PR 6 protocol) has no `dtype` byte — its header is
//! 26 bytes and its payload always f64. Both ends still speak it: a v1
//! request is decoded as [`Dtype::F64`] and answered with a v1 response,
//! so old clients transparently negotiate down to the f64 tier. An f32
//! request halves payload bytes in both directions.
//!
//! # Response body (server → client), version 2
//!
//! ```text
//! u16  magic               0xFA57
//! u8   version             2 (echoes the request's version)
//! u8   kind                1 = ok, 2 = error
//! u64  req_id              echoed from the request
//! -- kind = 1 (ok) --
//! u64  epoch               registry epoch of the operator generation
//!                          that served this request
//! u32  rows                output rows
//! u32  cols                output columns (== request cols)
//! u8   dtype               payload element type (echoes the request)
//! [dtype; rows*cols]       result, little-endian, column-major
//! -- kind = 2 (error) --
//! u8   code                see [`ErrorCode`]
//! u16  msg_len             diagnostic-message length
//! [u8; msg_len]            human-readable diagnostic (UTF-8)
//! ```
//!
//! Version-1 ok responses carry no `dtype` byte (payload f64 at offset
//! 28); error responses have the same layout at both versions.
//!
//! Responses on one connection are written in request order (FIFO), so
//! `req_id` is a convenience for pipelining clients, not a requirement
//! for correlation.

use crate::coordinator::{QosClass, ServeError};
use std::io::{Read, Write};

/// Protocol magic: the first two body bytes of every message.
pub const MAGIC: u16 = 0xFA57;
/// Newest protocol version this build speaks (and the version
/// [`encode_request`] emits by default).
pub const VERSION: u8 = 2;
/// Oldest protocol version still accepted (the dtype-less PR 6 layout).
pub const MIN_VERSION: u8 = 1;
/// Hard cap on one frame's body length (16 MiB).
pub const MAX_FRAME: u32 = 1 << 24;

/// Fixed-size prefix of a v1 request body, before name and payload.
const REQ_HEADER_V1: usize = 26;
/// Fixed-size prefix of a v2 request body (v1 plus the dtype byte).
const REQ_HEADER_V2: usize = 27;
/// Fixed-size prefix of every response body (magic/version/kind/req_id).
const RESP_HEADER: usize = 12;

/// Message kinds (`kind` byte).
const KIND_REQUEST: u8 = 0;
const KIND_OK: u8 = 1;
const KIND_ERR: u8 = 2;

/// Payload element type carried on the wire (version ≥ 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Dtype {
    F64 = 0,
    F32 = 1,
}

impl Dtype {
    pub fn from_u8(b: u8) -> Option<Dtype> {
        match b {
            0 => Some(Dtype::F64),
            1 => Some(Dtype::F32),
            _ => None,
        }
    }

    /// Bytes per payload element.
    pub fn elem_bytes(self) -> usize {
        match self {
            Dtype::F64 => 8,
            Dtype::F32 => 4,
        }
    }

    /// Lower-case name (CLI flags, metrics keys).
    pub fn name(self) -> &'static str {
        match self {
            Dtype::F64 => "f64",
            Dtype::F32 => "f32",
        }
    }
}

impl std::fmt::Display for Dtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Dtype {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "f64" => Ok(Dtype::F64),
            "f32" => Ok(Dtype::F32),
            other => Err(format!("unknown dtype '{other}' (f64|f32)")),
        }
    }
}

/// A decoded request frame.
#[derive(Clone, Debug, PartialEq)]
pub struct WireRequest {
    pub req_id: u64,
    pub op: String,
    pub class: QosClass,
    /// Per-request deadline override in µs; 0 means "class default".
    pub deadline_us: u32,
    /// Payload element type (always [`Dtype::F64`] on v1 frames). The
    /// response payload is encoded at the same dtype.
    pub dtype: Dtype,
    /// Protocol version the frame was (or will be) encoded at; responses
    /// echo it so old clients never see a layout they can't parse.
    pub version: u8,
    pub rows: usize,
    pub cols: usize,
    /// Column-major `rows × cols` payload, widened to f64 on decode.
    pub data: Vec<f64>,
}

/// Typed error codes carried in error responses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum ErrorCode {
    UnknownOperator = 1,
    WrongDimension = 2,
    /// Shed by the admission controller (or the coordinator's bounded
    /// queue) — the *only* way load shedding surfaces to a client.
    Overloaded = 3,
    ShuttingDown = 4,
    /// The frame was well-delimited but its body failed to decode.
    Malformed = 5,
}

impl ErrorCode {
    pub fn from_u8(b: u8) -> Option<ErrorCode> {
        match b {
            1 => Some(ErrorCode::UnknownOperator),
            2 => Some(ErrorCode::WrongDimension),
            3 => Some(ErrorCode::Overloaded),
            4 => Some(ErrorCode::ShuttingDown),
            5 => Some(ErrorCode::Malformed),
            _ => None,
        }
    }

    /// Map a coordinator error onto its wire code. `QueueFull` is
    /// deliberately `Overloaded`: to a client, shedding at the admission
    /// controller and shedding at the coordinator's bounded queue are
    /// the same typed condition.
    pub fn from_serve_error(e: &ServeError) -> ErrorCode {
        match e {
            ServeError::UnknownOperator(_) => ErrorCode::UnknownOperator,
            ServeError::WrongDimension { .. } => ErrorCode::WrongDimension,
            ServeError::QueueFull => ErrorCode::Overloaded,
            ServeError::ShuttingDown => ErrorCode::ShuttingDown,
        }
    }
}

/// A decoded response frame.
#[derive(Clone, Debug, PartialEq)]
pub enum WireResponse {
    Ok {
        req_id: u64,
        /// Registry epoch of the operator generation that served this.
        epoch: u64,
        rows: usize,
        cols: usize,
        /// Element type the payload travels as (echoes the request;
        /// [`Dtype::F64`] on v1 frames).
        dtype: Dtype,
        /// Column-major `rows × cols` result, widened to f64 on decode.
        data: Vec<f64>,
    },
    Err {
        req_id: u64,
        code: ErrorCode,
        msg: String,
    },
}

impl WireResponse {
    pub fn req_id(&self) -> u64 {
        match self {
            WireResponse::Ok { req_id, .. } | WireResponse::Err { req_id, .. } => *req_id,
        }
    }
}

/// Decode/IO errors. [`Truncated`](WireError::Truncated),
/// [`Oversized`](WireError::Oversized), [`BadMagic`](WireError::BadMagic)
/// and [`BadVersion`](WireError::BadVersion) break framing and close the
/// connection; the remaining decode variants are answered with a typed
/// [`ErrorCode::Malformed`] response on a connection that stays up.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// Stream ended (or a read failed) mid-frame.
    Truncated,
    /// Announced body length exceeds [`MAX_FRAME`].
    Oversized(u32),
    BadMagic(u16),
    BadVersion(u8),
    BadKind(u8),
    BadClass(u8),
    /// Unknown payload element type byte (v2 frames).
    BadDtype(u8),
    /// `body_len` disagrees with the lengths the header announces.
    LengthMismatch { announced: usize, expected: usize },
    /// Operator name is not UTF-8.
    BadName,
    /// Underlying socket error.
    Io(std::io::ErrorKind),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "stream truncated mid-frame"),
            WireError::Oversized(n) => {
                write!(f, "frame body of {n} bytes exceeds the {MAX_FRAME}-byte cap")
            }
            WireError::BadMagic(m) => write!(f, "bad magic 0x{m:04X} (want 0x{MAGIC:04X})"),
            WireError::BadVersion(v) => write!(f, "unsupported protocol version {v}"),
            WireError::BadKind(k) => write!(f, "unexpected message kind {k}"),
            WireError::BadClass(c) => write!(f, "unknown QoS class byte {c}"),
            WireError::BadDtype(d) => write!(f, "unknown dtype byte {d}"),
            WireError::LengthMismatch { announced, expected } => {
                write!(f, "body length {announced} != expected {expected}")
            }
            WireError::BadName => write!(f, "operator name is not UTF-8"),
            WireError::Io(k) => write!(f, "socket error: {k:?}"),
        }
    }
}

impl std::error::Error for WireError {}

impl WireError {
    /// Whether this error breaks framing (connection must close) rather
    /// than being answerable with a typed `Malformed` response.
    pub fn breaks_framing(&self) -> bool {
        matches!(
            self,
            WireError::Truncated
                | WireError::Oversized(_)
                | WireError::BadMagic(_)
                | WireError::BadVersion(_)
                | WireError::Io(_)
        )
    }
}

// ---- little-endian cursor helpers ---------------------------------------

fn get_u16(b: &[u8], at: usize) -> u16 {
    u16::from_le_bytes([b[at], b[at + 1]])
}

fn get_u32(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]])
}

fn get_u64(b: &[u8], at: usize) -> u64 {
    let mut x = [0u8; 8];
    x.copy_from_slice(&b[at..at + 8]);
    u64::from_le_bytes(x)
}

// ---- payload helpers -----------------------------------------------------

/// Append `data` to `out` at `dtype` width (f32 narrows on the way out).
fn push_payload(out: &mut Vec<u8>, data: &[f64], dtype: Dtype) {
    match dtype {
        Dtype::F64 => {
            for v in data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        Dtype::F32 => {
            for v in data {
                out.extend_from_slice(&(*v as f32).to_le_bytes());
            }
        }
    }
}

/// Read `n_vals` elements at `dtype` width starting at `at`, widening to
/// f64. The caller has already length-checked the slice.
fn read_payload(body: &[u8], at: usize, n_vals: usize, dtype: Dtype) -> Vec<f64> {
    let mut data = Vec::with_capacity(n_vals);
    let mut at = at;
    match dtype {
        Dtype::F64 => {
            for _ in 0..n_vals {
                let mut x = [0u8; 8];
                x.copy_from_slice(&body[at..at + 8]);
                data.push(f64::from_le_bytes(x));
                at += 8;
            }
        }
        Dtype::F32 => {
            for _ in 0..n_vals {
                let x = [body[at], body[at + 1], body[at + 2], body[at + 3]];
                data.push(f32::from_le_bytes(x) as f64);
                at += 4;
            }
        }
    }
    data
}

/// Shared `rows·cols` overflow/frame-cap guard.
fn checked_vals(
    rows: usize,
    cols: usize,
    elem: usize,
    announced: usize,
) -> Result<usize, WireError> {
    rows.checked_mul(cols)
        .filter(|&n| n <= (MAX_FRAME as usize) / elem)
        .ok_or(WireError::LengthMismatch { announced, expected: usize::MAX })
}

// ---- encode --------------------------------------------------------------

/// Encode a request into one frame (length prefix included), at the
/// request's own `version` (v1 frames carry no dtype byte and must be
/// [`Dtype::F64`]).
///
/// # Panics
/// If `data.len() != rows * cols`, the operator name exceeds 255 bytes,
/// the version is unsupported, or a v1 request asks for f32 — all
/// caller bugs, not wire conditions.
pub fn encode_request(req: &WireRequest) -> Vec<u8> {
    assert_eq!(req.data.len(), req.rows * req.cols, "payload/shape mismatch");
    assert!(req.op.len() <= u8::MAX as usize, "operator name too long");
    assert!(
        (MIN_VERSION..=VERSION).contains(&req.version),
        "unsupported request version {}",
        req.version
    );
    assert!(
        req.version >= 2 || req.dtype == Dtype::F64,
        "v1 frames cannot carry f32 payloads"
    );
    let header = if req.version == 1 { REQ_HEADER_V1 } else { REQ_HEADER_V2 };
    let body_len = header + req.op.len() + req.dtype.elem_bytes() * req.data.len();
    let mut out = Vec::with_capacity(4 + body_len);
    out.extend_from_slice(&(body_len as u32).to_le_bytes());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(req.version);
    out.push(KIND_REQUEST);
    out.extend_from_slice(&req.req_id.to_le_bytes());
    out.push(req.class as u8);
    if req.version >= 2 {
        out.push(req.dtype as u8);
    }
    out.push(req.op.len() as u8);
    out.extend_from_slice(&req.deadline_us.to_le_bytes());
    out.extend_from_slice(&(req.rows as u32).to_le_bytes());
    out.extend_from_slice(&(req.cols as u32).to_le_bytes());
    out.extend_from_slice(req.op.as_bytes());
    push_payload(&mut out, &req.data, req.dtype);
    out
}

/// Encode a response into one frame (length prefix included), at the
/// `version` the request arrived at — a v1 client is answered with the
/// v1 layout (f64 payload, no dtype byte) regardless of the Ok variant's
/// dtype, so old clients transparently negotiate down.
pub fn encode_response(resp: &WireResponse, version: u8) -> Vec<u8> {
    assert!(
        (MIN_VERSION..=VERSION).contains(&version),
        "unsupported response version {version}"
    );
    match resp {
        WireResponse::Ok { req_id, epoch, rows, cols, dtype, data } => {
            assert_eq!(data.len(), rows * cols, "payload/shape mismatch");
            let dtype = if version == 1 { Dtype::F64 } else { *dtype };
            let tail = if version == 1 { 16 } else { 17 };
            let body_len = RESP_HEADER + tail + dtype.elem_bytes() * data.len();
            let mut out = Vec::with_capacity(4 + body_len);
            out.extend_from_slice(&(body_len as u32).to_le_bytes());
            out.extend_from_slice(&MAGIC.to_le_bytes());
            out.push(version);
            out.push(KIND_OK);
            out.extend_from_slice(&req_id.to_le_bytes());
            out.extend_from_slice(&epoch.to_le_bytes());
            out.extend_from_slice(&(*rows as u32).to_le_bytes());
            out.extend_from_slice(&(*cols as u32).to_le_bytes());
            if version >= 2 {
                out.push(dtype as u8);
            }
            push_payload(&mut out, data, dtype);
            out
        }
        WireResponse::Err { req_id, code, msg } => {
            let msg = &msg.as_bytes()[..msg.len().min(u16::MAX as usize)];
            let body_len = RESP_HEADER + 3 + msg.len();
            let mut out = Vec::with_capacity(4 + body_len);
            out.extend_from_slice(&(body_len as u32).to_le_bytes());
            out.extend_from_slice(&MAGIC.to_le_bytes());
            out.push(version);
            out.push(KIND_ERR);
            out.extend_from_slice(&req_id.to_le_bytes());
            out.push(*code as u8);
            out.extend_from_slice(&(msg.len() as u16).to_le_bytes());
            out.extend_from_slice(msg);
            out
        }
    }
}

// ---- decode --------------------------------------------------------------

/// Decode one request body (the frame's payload, length prefix already
/// stripped by [`read_frame`]). Accepts versions [`MIN_VERSION`] through
/// [`VERSION`]; v1 bodies decode with `dtype = F64`.
pub fn decode_request(body: &[u8]) -> Result<WireRequest, WireError> {
    if body.len() < REQ_HEADER_V1 {
        return Err(WireError::LengthMismatch {
            announced: body.len(),
            expected: REQ_HEADER_V1,
        });
    }
    let magic = get_u16(body, 0);
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let version = body[2];
    if !(MIN_VERSION..=VERSION).contains(&version) {
        return Err(WireError::BadVersion(version));
    }
    if body[3] != KIND_REQUEST {
        return Err(WireError::BadKind(body[3]));
    }
    let req_id = get_u64(body, 4);
    let class = QosClass::from_u8(body[12]).ok_or(WireError::BadClass(body[12]))?;
    let (header, dtype) = if version == 1 {
        (REQ_HEADER_V1, Dtype::F64)
    } else {
        if body.len() < REQ_HEADER_V2 {
            return Err(WireError::LengthMismatch {
                announced: body.len(),
                expected: REQ_HEADER_V2,
            });
        }
        (REQ_HEADER_V2, Dtype::from_u8(body[13]).ok_or(WireError::BadDtype(body[13]))?)
    };
    // v1: name_len at 13, deadline at 14; v2: shifted one byte by dtype.
    let off = header - REQ_HEADER_V1;
    let name_len = body[13 + off] as usize;
    let deadline_us = get_u32(body, 14 + off);
    let rows = get_u32(body, 18 + off) as usize;
    let cols = get_u32(body, 22 + off) as usize;
    let n_vals = checked_vals(rows, cols, dtype.elem_bytes(), body.len())?;
    let expected = header + name_len + dtype.elem_bytes() * n_vals;
    if body.len() != expected {
        return Err(WireError::LengthMismatch { announced: body.len(), expected });
    }
    let op = std::str::from_utf8(&body[header..header + name_len])
        .map_err(|_| WireError::BadName)?
        .to_string();
    let data = read_payload(body, header + name_len, n_vals, dtype);
    Ok(WireRequest { req_id, op, class, deadline_us, dtype, version, rows, cols, data })
}

/// Decode one response body (either version; v1 ok bodies decode with
/// `dtype = F64`).
pub fn decode_response(body: &[u8]) -> Result<WireResponse, WireError> {
    if body.len() < RESP_HEADER {
        return Err(WireError::LengthMismatch { announced: body.len(), expected: RESP_HEADER });
    }
    let magic = get_u16(body, 0);
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let version = body[2];
    if !(MIN_VERSION..=VERSION).contains(&version) {
        return Err(WireError::BadVersion(version));
    }
    let req_id = get_u64(body, 4);
    match body[3] {
        KIND_OK => {
            let tail = if version == 1 { 16 } else { 17 };
            if body.len() < RESP_HEADER + tail {
                return Err(WireError::LengthMismatch {
                    announced: body.len(),
                    expected: RESP_HEADER + tail,
                });
            }
            let epoch = get_u64(body, RESP_HEADER);
            let rows = get_u32(body, RESP_HEADER + 8) as usize;
            let cols = get_u32(body, RESP_HEADER + 12) as usize;
            let dtype = if version == 1 {
                Dtype::F64
            } else {
                Dtype::from_u8(body[RESP_HEADER + 16])
                    .ok_or(WireError::BadDtype(body[RESP_HEADER + 16]))?
            };
            let n_vals = checked_vals(rows, cols, dtype.elem_bytes(), body.len())?;
            let expected = RESP_HEADER + tail + dtype.elem_bytes() * n_vals;
            if body.len() != expected {
                return Err(WireError::LengthMismatch { announced: body.len(), expected });
            }
            let data = read_payload(body, RESP_HEADER + tail, n_vals, dtype);
            Ok(WireResponse::Ok { req_id, epoch, rows, cols, dtype, data })
        }
        KIND_ERR => {
            if body.len() < RESP_HEADER + 3 {
                return Err(WireError::LengthMismatch {
                    announced: body.len(),
                    expected: RESP_HEADER + 3,
                });
            }
            let code =
                ErrorCode::from_u8(body[RESP_HEADER]).ok_or(WireError::BadKind(body[RESP_HEADER]))?;
            let msg_len = get_u16(body, RESP_HEADER + 1) as usize;
            let expected = RESP_HEADER + 3 + msg_len;
            if body.len() != expected {
                return Err(WireError::LengthMismatch { announced: body.len(), expected });
            }
            let msg = String::from_utf8_lossy(&body[RESP_HEADER + 3..]).into_owned();
            Ok(WireResponse::Err { req_id, code, msg })
        }
        k => Err(WireError::BadKind(k)),
    }
}

// ---- framed IO -----------------------------------------------------------

/// Read one frame's body from `r`. Returns `Ok(None)` on a clean close
/// (EOF exactly at a frame boundary); EOF mid-frame is
/// [`WireError::Truncated`]. An oversized length announcement is
/// rejected *before* allocating the body.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>, WireError> {
    let mut len = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut len[got..]) {
            Ok(0) => {
                return if got == 0 { Ok(None) } else { Err(WireError::Truncated) };
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(WireError::Io(e.kind())),
        }
    }
    let body_len = u32::from_le_bytes(len);
    if body_len > MAX_FRAME {
        return Err(WireError::Oversized(body_len));
    }
    let mut body = vec![0u8; body_len as usize];
    let mut at = 0;
    while at < body.len() {
        match r.read(&mut body[at..]) {
            Ok(0) => return Err(WireError::Truncated),
            Ok(n) => at += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(WireError::Io(e.kind())),
        }
    }
    Ok(Some(body))
}

/// Write one pre-encoded frame (as produced by the `encode_*` fns).
pub fn write_frame(w: &mut impl Write, frame: &[u8]) -> Result<(), WireError> {
    w.write_all(frame).map_err(|e| WireError::Io(e.kind()))?;
    w.flush().map_err(|e| WireError::Io(e.kind()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(rows: usize, cols: usize, class: QosClass) -> WireRequest {
        WireRequest {
            req_id: 42,
            op: "h".to_string(),
            class,
            deadline_us: 150,
            dtype: Dtype::F64,
            version: VERSION,
            rows,
            cols,
            data: (0..rows * cols).map(|i| i as f64 * 0.5 - 3.0).collect(),
        }
    }

    #[test]
    fn request_round_trips() {
        for class in QosClass::ALL {
            let r = req(4, 3, class);
            let frame = encode_request(&r);
            let announced = u32::from_le_bytes(frame[..4].try_into().unwrap()) as usize;
            assert_eq!(announced, frame.len() - 4);
            let back = decode_request(&frame[4..]).unwrap();
            assert_eq!(back, r);
        }
    }

    #[test]
    fn v1_request_round_trips_as_f64() {
        // The PR 6 layout: no dtype byte, 26-byte header. It must keep
        // decoding (old clients negotiate down to the f64 tier).
        let mut r = req(4, 3, QosClass::Standard);
        r.version = 1;
        let frame = encode_request(&r);
        // Header really is one byte shorter than v2's.
        assert_eq!(frame.len(), 4 + 26 + 1 + 8 * 12);
        let back = decode_request(&frame[4..]).unwrap();
        assert_eq!(back, r);
        assert_eq!(back.dtype, Dtype::F64);
        assert_eq!(back.version, 1);
    }

    #[test]
    fn f32_request_halves_payload_bytes_and_quantizes() {
        let mut r64 = req(16, 4, QosClass::Bulk);
        let mut r32 = r64.clone();
        r32.dtype = Dtype::F32;
        let f64_frame = encode_request(&r64);
        let f32_frame = encode_request(&r32);
        assert_eq!(
            f64_frame.len() - f32_frame.len(),
            4 * 16 * 4,
            "f32 payload should save 4 bytes per element"
        );
        let back = decode_request(&f32_frame[4..]).unwrap();
        assert_eq!(back.dtype, Dtype::F32);
        for (a, b) in back.data.iter().zip(r32.data.iter()) {
            assert_eq!(*a, *b as f32 as f64, "decode must widen the quantized value");
        }
        // Values representable in f32 (halves) survive exactly.
        r64.data = vec![0.5; 64];
        r32.data = vec![0.5; 64];
        let back = decode_request(&encode_request(&r32)[4..]).unwrap();
        assert_eq!(back.data, r64.data);
    }

    #[test]
    fn responses_round_trip() {
        let ok = WireResponse::Ok {
            req_id: 7,
            epoch: 3,
            rows: 2,
            cols: 2,
            dtype: Dtype::F64,
            data: vec![1.0, -2.5, 3.25, 0.0],
        };
        let frame = encode_response(&ok, VERSION);
        assert_eq!(decode_response(&frame[4..]).unwrap(), ok);

        // f32 payload round-trips (values exactly representable).
        let ok32 = WireResponse::Ok {
            req_id: 8,
            epoch: 3,
            rows: 2,
            cols: 1,
            dtype: Dtype::F32,
            data: vec![1.5, -0.25],
        };
        let frame32 = encode_response(&ok32, VERSION);
        assert!(frame32.len() < frame.len());
        assert_eq!(decode_response(&frame32[4..]).unwrap(), ok32);

        let err = WireResponse::Err {
            req_id: 9,
            code: ErrorCode::Overloaded,
            msg: "shed".to_string(),
        };
        let frame = encode_response(&err, VERSION);
        assert_eq!(decode_response(&frame[4..]).unwrap(), err);
    }

    #[test]
    fn v1_response_negotiates_down_to_f64() {
        // A server holding an f32 result answers a v1 client with the v1
        // layout: version byte 1, no dtype byte, widened f64 payload.
        let ok = WireResponse::Ok {
            req_id: 5,
            epoch: 2,
            rows: 2,
            cols: 1,
            dtype: Dtype::F32,
            data: vec![0.5, -1.25],
        };
        let frame = encode_response(&ok, 1);
        assert_eq!(frame[4 + 2], 1, "version byte must echo the request");
        assert_eq!(frame.len(), 4 + 12 + 16 + 8 * 2);
        match decode_response(&frame[4..]).unwrap() {
            WireResponse::Ok { dtype, data, .. } => {
                assert_eq!(dtype, Dtype::F64);
                assert_eq!(data, vec![0.5, -1.25]);
            }
            other => panic!("unexpected {other:?}"),
        }
        // Error responses share one layout across versions.
        let err = WireResponse::Err {
            req_id: 9,
            code: ErrorCode::ShuttingDown,
            msg: "bye".to_string(),
        };
        let f1 = encode_response(&err, 1);
        let f2 = encode_response(&err, 2);
        assert_eq!(f1.len(), f2.len());
        assert_eq!(decode_response(&f1[4..]).unwrap(), err);
        assert_eq!(decode_response(&f2[4..]).unwrap(), err);
    }

    #[test]
    fn framed_io_round_trips_over_a_buffer() {
        let r = req(3, 2, QosClass::Bulk);
        let mut buf = Vec::new();
        write_frame(&mut buf, &encode_request(&r)).unwrap();
        write_frame(&mut buf, &encode_request(&r)).unwrap();
        let mut cur = std::io::Cursor::new(buf);
        for _ in 0..2 {
            let body = read_frame(&mut cur).unwrap().expect("frame present");
            assert_eq!(decode_request(&body).unwrap(), r);
        }
        // Clean close at the boundary.
        assert_eq!(read_frame(&mut cur).unwrap(), None);
    }

    #[test]
    fn truncation_is_typed_never_a_panic() {
        let frame = encode_request(&req(4, 4, QosClass::Standard));
        // Cut the stream at every byte offset: mid-prefix and mid-body
        // are Truncated; offset 0 is a clean close.
        for cut in 0..frame.len() {
            let mut cur = std::io::Cursor::new(frame[..cut].to_vec());
            match read_frame(&mut cur) {
                Ok(None) => assert_eq!(cut, 0, "clean close only at offset 0"),
                Err(WireError::Truncated) => assert!(cut > 0),
                other => panic!("cut at {cut}: unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn oversized_announcement_rejected_before_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        buf.extend_from_slice(&[0u8; 16]);
        let mut cur = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut cur), Err(WireError::Oversized(MAX_FRAME + 1)));
    }

    #[test]
    fn malformed_bodies_are_typed_errors() {
        let good = encode_request(&req(2, 2, QosClass::Interactive));
        let body = &good[4..];

        // Bad magic.
        let mut b = body.to_vec();
        b[0] ^= 0xFF;
        assert!(matches!(decode_request(&b), Err(WireError::BadMagic(_))));

        // Bad version.
        let mut b = body.to_vec();
        b[2] = 99;
        assert_eq!(decode_request(&b), Err(WireError::BadVersion(99)));

        // Bad class byte.
        let mut b = body.to_vec();
        b[12] = 7;
        assert_eq!(decode_request(&b), Err(WireError::BadClass(7)));

        // Bad dtype byte (v2 frames only).
        let mut b = body.to_vec();
        b[13] = 9;
        assert_eq!(decode_request(&b), Err(WireError::BadDtype(9)));

        // Body shorter than the header announces.
        let b = &body[..body.len() - 1];
        assert!(matches!(decode_request(b), Err(WireError::LengthMismatch { .. })));

        // Shape whose payload would overflow the frame cap.
        let mut b = body.to_vec();
        b[19..23].copy_from_slice(&u32::MAX.to_le_bytes());
        b[23..27].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(decode_request(&b), Err(WireError::LengthMismatch { .. })));

        // Non-UTF-8 operator name.
        let mut r = req(1, 1, QosClass::Standard);
        r.op = "ab".to_string();
        let mut frame = encode_request(&r);
        frame[4 + 27] = 0xFF; // first name byte (27-byte v2 header)
        frame[4 + 28] = 0xFE;
        assert_eq!(decode_request(&frame[4..]), Err(WireError::BadName));
    }

    #[test]
    fn framing_breakers_vs_answerable_errors() {
        assert!(WireError::Truncated.breaks_framing());
        assert!(WireError::Oversized(0).breaks_framing());
        assert!(WireError::BadMagic(0).breaks_framing());
        assert!(!WireError::BadClass(9).breaks_framing());
        assert!(!WireError::BadDtype(9).breaks_framing());
        assert!(!WireError::LengthMismatch { announced: 0, expected: 1 }.breaks_framing());
        assert!(!WireError::BadName.breaks_framing());
    }

    #[test]
    fn serve_errors_map_onto_wire_codes() {
        assert_eq!(
            ErrorCode::from_serve_error(&ServeError::QueueFull),
            ErrorCode::Overloaded
        );
        assert_eq!(
            ErrorCode::from_serve_error(&ServeError::UnknownOperator("x".into())),
            ErrorCode::UnknownOperator
        );
        assert_eq!(
            ErrorCode::from_serve_error(&ServeError::WrongDimension { expected: 2, got: 3 }),
            ErrorCode::WrongDimension
        );
        assert_eq!(
            ErrorCode::from_serve_error(&ServeError::ShuttingDown),
            ErrorCode::ShuttingDown
        );
    }
}
