//! Chunked worker pool + row-partitioned parallel sparse/dense kernels.
//!
//! `std::thread` only (no rayon in the offline vendor set). The pool keeps
//! `n_threads − 1` persistent workers; the calling thread executes the
//! first chunk itself, so `ThreadPool::new(1)` degenerates to inline serial
//! execution with zero dispatch overhead. Work items are contiguous row
//! ranges of an output matrix, which makes every kernel here data-race-free
//! by construction: each range owns a disjoint slice of the output.
//!
//! The parallel `spmv`/`spmm`/`gemm` entry points are shared by the
//! [`crate::engine`] executor and the coordinator's batch workers. Dense
//! GEMM/gemv inner loops live in [`super::kernel`] — the pooled dispatch
//! here packs the `B` operand once on the calling thread, splits the
//! output at the microkernel's `MR` tile boundaries (so tile membership,
//! and therefore every output bit, is independent of the thread count),
//! and hands each chunk the shared read-only panel.

use super::kernel;
use super::kernel::Scalar;
use super::sync::{AtomicBool, Condvar, Mutex, Ordering};
use crate::linalg::Mat;
use crate::sparse::Csr;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Target amount of work (flops) per dispatched chunk; below this,
/// splitting costs more in wake-ups than it saves in compute. Also the
/// unit of the fleet crossover: a GEMM whose total flops cannot feed
/// every pool thread a full grain is better batched *across* operators
/// than split *within* one (see [`crate::engine::FleetCtx`]).
pub(crate) const PAR_GRAIN_FLOPS: usize = 16_384;

/// One scheduled row range. The closure pointer is only dereferenced while
/// the submitting call is blocked in [`Latch::wait`], which keeps the
/// borrow alive — the scoped-pool invariant.
struct Task {
    f: *const (dyn Fn(usize, usize) + Sync),
    start: usize,
    end: usize,
    latch: Arc<Latch>,
}

// SAFETY: the raw closure pointer is valid for the task's whole lifetime
// because `par_ranges` does not return until the latch opens.
unsafe impl Send for Task {}

/// Countdown latch with panic propagation.
struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
    panicked: AtomicBool,
}

impl Latch {
    fn new(n: usize) -> Self {
        Latch { remaining: Mutex::new(n), cv: Condvar::new(), panicked: AtomicBool::new(false) }
    }

    fn count_down(&self) {
        let mut g = self.remaining.lock().unwrap();
        *g -= 1;
        if *g == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut g = self.remaining.lock().unwrap();
        while *g > 0 {
            g = self.cv.wait(g).unwrap();
        }
    }
}

/// Shared injector queue (mpsc receivers are not cloneable).
struct TaskQueue {
    q: Mutex<VecDeque<Task>>,
    cv: Condvar,
    closed: AtomicBool,
}

impl TaskQueue {
    fn new() -> Self {
        TaskQueue {
            q: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            closed: AtomicBool::new(false),
        }
    }

    fn push(&self, t: Task) {
        self.q.lock().unwrap().push_back(t);
        self.cv.notify_one();
    }

    fn pop(&self) -> Option<Task> {
        let mut g = self.q.lock().unwrap();
        loop {
            if let Some(t) = g.pop_front() {
                return Some(t);
            }
            if self.closed.load(Ordering::Acquire) {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    fn close(&self) {
        self.closed.store(true, Ordering::Release);
        self.cv.notify_all();
    }
}

/// Persistent chunked worker pool for row-partitioned kernels.
pub struct ThreadPool {
    queue: Arc<TaskQueue>,
    workers: Vec<JoinHandle<()>>,
    n_threads: usize,
}

impl ThreadPool {
    /// Pool executing with `n_threads` total threads (the caller counts as
    /// one; `n_threads − 1` workers are spawned). `0` is treated as `1`.
    pub fn new(n_threads: usize) -> Self {
        let n_threads = n_threads.max(1);
        let queue = Arc::new(TaskQueue::new());
        let mut workers = Vec::with_capacity(n_threads - 1);
        for w in 0..n_threads - 1 {
            let q = queue.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("faust-engine-{w}"))
                    .spawn(move || worker_loop(q))
                    .expect("spawn engine worker"),
            );
        }
        ThreadPool { queue, workers, n_threads }
    }

    /// Inline-only pool (no workers, no dispatch overhead).
    pub fn serial() -> Self {
        Self::new(1)
    }

    /// Total threads participating in a `par_ranges` call.
    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    /// Run `f(start, end)` over a partition of `[0, n)` into contiguous
    /// chunks of at least `min_chunk` items, parallel across the pool.
    /// Blocks until every chunk has finished; panics in any chunk are
    /// re-raised here after all chunks complete.
    pub fn par_ranges(&self, n: usize, min_chunk: usize, f: impl Fn(usize, usize) + Sync) {
        if n == 0 {
            return;
        }
        let min_chunk = min_chunk.max(1);
        let max_chunks = n.div_ceil(min_chunk);
        let nchunks = self.n_threads.min(max_chunks).max(1);
        if self.workers.is_empty() || nchunks == 1 {
            f(0, n);
            return;
        }
        let chunk = n.div_ceil(nchunks);
        // When `n` sits just above `nchunks × min_chunk`, the ceil-divided
        // chunk width overshoots and later nominal chunks start past `n`.
        // Clamp both endpoints to `n` and drop the empties so the
        // invariant workers rely on — `start < end <= n`, every index
        // covered exactly once — holds by construction rather than by the
        // filter alone (the awkward-size sweep test pins it).
        let ranges: Vec<(usize, usize)> = (0..nchunks)
            .map(|c| ((c * chunk).min(n), ((c + 1) * chunk).min(n)))
            .filter(|(s, e)| s < e)
            .collect();
        let latch = Arc::new(Latch::new(ranges.len() - 1));
        let fref: &(dyn Fn(usize, usize) + Sync) = &f;
        let fptr = fref as *const (dyn Fn(usize, usize) + Sync);
        for &(s, e) in &ranges[1..] {
            self.queue.push(Task { f: fptr, start: s, end: e, latch: latch.clone() });
        }
        // The caller works too — chunk 0 runs inline.
        let inline_panic = catch_unwind(AssertUnwindSafe(|| f(ranges[0].0, ranges[0].1)));
        latch.wait();
        if inline_panic.is_err() || latch.panicked.load(Ordering::Acquire) {
            panic!("engine pool task panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(queue: Arc<TaskQueue>) {
    while let Some(task) = queue.pop() {
        // SAFETY: the submitter blocks on the latch until we count down,
        // so the closure behind the raw pointer is still alive.
        let f = unsafe { &*task.f };
        let result = catch_unwind(AssertUnwindSafe(|| f(task.start, task.end)));
        if result.is_err() {
            task.latch.panicked.store(true, Ordering::Release);
        }
        task.latch.count_down();
    }
}

/// Raw output pointer that may cross thread boundaries; every user hands
/// each thread a disjoint row range, so aliased writes cannot occur.
struct SendPtr<S>(*mut S);
// SAFETY: the pointer targets a caller-owned output buffer that outlives
// the `par_ranges` call, and every user hands each thread a disjoint row
// range of it, so no two threads ever touch the same element.
unsafe impl<S> Send for SendPtr<S> {}
// SAFETY: shared references to the wrapper only copy the address; all
// writes through it go to the disjoint per-thread ranges above.
unsafe impl<S> Sync for SendPtr<S> {}
impl<S> Clone for SendPtr<S> {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}
impl<S> Copy for SendPtr<S> {}

/// Serial CSR spmm over an output row range, slice layout (row-major,
/// `bcols` columns). `out` holds exactly rows `[start, end)`.
fn spmm_rows<S: Scalar>(
    a: &Csr<S>,
    b: &[S],
    bcols: usize,
    start: usize,
    end: usize,
    out: &mut [S],
) {
    debug_assert_eq!(out.len(), (end - start) * bcols);
    for i in start..end {
        let orow = &mut out[(i - start) * bcols..(i - start + 1) * bcols];
        orow.fill(S::ZERO);
        let lo = a.indptr[i] as usize;
        let hi = a.indptr[i + 1] as usize;
        for k in lo..hi {
            let av = a.vals[k];
            let brow = &b[a.indices[k] as usize * bcols..][..bcols];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// Serial dense GEMM over an output row range, slice layout. Shared by
/// the fleet's fused per-operator tasks and (via tile-aligned chunks)
/// the pooled [`par_gemm_into`] path: both routes run the same
/// [`super::kernel`] microkernels over the same absolute tile grid, so
/// every output element accumulates in the same order — the
/// bitwise-invariance contract.
pub(crate) fn gemm_rows<S: Scalar>(
    a: &Mat<S>,
    b: &[S],
    bcols: usize,
    start: usize,
    end: usize,
    out: &mut [S],
) {
    kernel::gemm_tiled_rows(a, b, bcols, start, end, out);
}

/// Minimum rows per chunk so each dispatched chunk carries at least
/// [`PAR_GRAIN_FLOPS`] of work.
fn grain_rows(total_flops: usize, rows: usize) -> usize {
    let per_row = total_flops / rows.max(1);
    (PAR_GRAIN_FLOPS / per_row.max(1)).max(1)
}

/// Row-parallel sparse × dense (slice layout): `out = A · B`,
/// `B ∈ R^{A.cols × bcols}`, `out ∈ R^{A.rows × bcols}`.
pub fn par_spmm_into<S: Scalar>(
    pool: &ThreadPool,
    a: &Csr<S>,
    b: &[S],
    bcols: usize,
    out: &mut [S],
) {
    assert_eq!(b.len(), a.cols() * bcols, "par_spmm b dim mismatch");
    assert_eq!(out.len(), a.rows() * bcols, "par_spmm out dim mismatch");
    let min_rows = grain_rows(2 * a.nnz() * bcols, a.rows());
    let optr = SendPtr(out.as_mut_ptr());
    pool.par_ranges(a.rows(), min_rows, |s, e| {
        // SAFETY: ranges are disjoint, so each chunk owns its out rows.
        let chunk =
            unsafe { std::slice::from_raw_parts_mut(optr.0.add(s * bcols), (e - s) * bcols) };
        spmm_rows(a, b, bcols, s, e, chunk);
    });
}

/// Row-parallel dense GEMM (slice layout): `out = A · B`, routed through
/// the [`super::kernel`] microkernels. For tile-eligible shapes `B` is
/// packed once on the calling thread and the output is split at `MR`
/// tile boundaries, so the tile grid (and every output bit) is the same
/// at any thread count; narrow products fall back to the scalar
/// reference chunked by rows.
pub fn par_gemm_into<S: Scalar>(
    pool: &ThreadPool,
    a: &Mat<S>,
    b: &[S],
    bcols: usize,
    out: &mut [S],
) {
    assert_eq!(b.len(), a.cols() * bcols, "par_gemm b dim mismatch");
    assert_eq!(out.len(), a.rows() * bcols, "par_gemm out dim mismatch");
    let m = a.rows();
    if m == 0 || bcols == 0 {
        return;
    }
    let min_rows = grain_rows(2 * m * a.cols() * bcols, m);
    let optr = SendPtr(out.as_mut_ptr());
    if !kernel::tiled_applies(m, bcols) {
        pool.par_ranges(m, min_rows, |s, e| {
            // SAFETY: disjoint ranges (see par_spmm_into).
            let chunk = unsafe {
                std::slice::from_raw_parts_mut(optr.0.add(s * bcols), (e - s) * bcols)
            };
            kernel::gemm_scalar_rows(a, b, bcols, s, e, chunk);
        });
        return;
    }
    kernel::with_pack_panel(b, a.cols(), bcols, |panel| {
        let ntiles = m.div_ceil(kernel::MR);
        let min_tiles = min_rows.div_ceil(kernel::MR);
        pool.par_ranges(ntiles, min_tiles, |ts, te| {
            let rs = ts * kernel::MR;
            let re = (te * kernel::MR).min(m);
            // SAFETY: disjoint tile ranges own disjoint output rows.
            let chunk = unsafe {
                std::slice::from_raw_parts_mut(optr.0.add(rs * bcols), (re - rs) * bcols)
            };
            kernel::gemm_panel_rows(a, panel, bcols, rs, re, chunk);
        });
    });
}

/// Row-parallel sparse matvec: `y = A x` (the `bcols = 1` case).
pub fn par_spmv_into<S: Scalar>(pool: &ThreadPool, a: &Csr<S>, x: &[S], y: &mut [S]) {
    par_spmm_into(pool, a, x, 1, y);
}

/// Row-parallel dense matvec: `y = A x`.
pub fn par_gemv_into<S: Scalar>(pool: &ThreadPool, a: &Mat<S>, x: &[S], y: &mut [S]) {
    par_gemm_into(pool, a, x, 1, y);
}

/// Column-parallel dense transposed matvec: `y = Aᵀ x`. The output is
/// partitioned over `A`'s columns; within a chunk the scan stays row-major
/// (each row contributes to the chunk's column stripe), so every output
/// element accumulates its terms in row order regardless of the thread
/// count — results are bitwise thread-invariant, which the ExecCtx's
/// pooled power iterations rely on for deterministic factorization.
pub fn par_gemv_t_into<S: Scalar>(pool: &ThreadPool, a: &Mat<S>, x: &[S], y: &mut [S]) {
    assert_eq!(x.len(), a.rows(), "par_gemv_t x dim mismatch");
    assert_eq!(y.len(), a.cols(), "par_gemv_t y dim mismatch");
    let min_cols = grain_rows(2 * a.rows() * a.cols(), a.cols());
    let yptr = SendPtr(y.as_mut_ptr());
    pool.par_ranges(a.cols(), min_cols, |s, e| {
        // SAFETY: disjoint column ranges own disjoint slices of y.
        let chunk = unsafe { std::slice::from_raw_parts_mut(yptr.0.add(s), e - s) };
        gemv_t_cols(a, x, s, e, chunk);
    });
}

/// Serial `y[s..e] = (Aᵀ x)[s..e]` column stripe — the per-chunk kernel
/// of [`par_gemv_t_into`], shared with the fleet's per-operator serial
/// power iterations so both compute identical bits. Routed through the
/// width-dispatched [`super::kernel::gemv_t_tiled_cols`]; its per-element
/// accumulation order (ascending rows, `x[i] == 0` skipped) is unchanged
/// from the scalar reference, so any column chunking yields the same
/// bits.
pub(crate) fn gemv_t_cols<S: Scalar>(a: &Mat<S>, x: &[S], s: usize, e: usize, chunk: &mut [S]) {
    kernel::gemv_t_tiled_cols(a, x, s, e, chunk);
}

/// Raw cell pointer for job-granular fan-out; tasks index disjoint slots.
struct SendCell<T>(*mut T);
// SAFETY: the pointer targets the caller's slot vectors, which outlive
// the `par_ranges` call; `par_map_jobs` indexes them by job id and the
// pool partitions job ids disjointly, so each cell has a single writer.
unsafe impl<T> Send for SendCell<T> {}
// SAFETY: shared references only copy the address; every dereference is
// at a job index owned by exactly one task (see `Send` above).
unsafe impl<T> Sync for SendCell<T> {}
impl<T> Clone for SendCell<T> {
    fn clone(&self) -> Self {
        SendCell(self.0)
    }
}
impl<T> Copy for SendCell<T> {}

/// Run `f` over a list of independent jobs, parallel across the pool at
/// *job* granularity (each job executes serially inside one task), and
/// return the results in job order.
///
/// This is the fleet fan-out primitive: when N small independent pieces
/// of work (per-operator GEMMs, power iterations, projections) are each
/// below the pool's parallel grain, splitting any one of them wastes more
/// in wake-ups than it gains — but running whole jobs on different
/// threads keeps the pool busy with zero intra-job coordination. Jobs
/// must not touch the pool themselves (nested `par_ranges` from a worker
/// can deadlock: every worker could end up waiting on subtasks that no
/// free worker remains to run).
///
/// A panicking job no longer takes its chunk-mates down with it: each
/// job runs under its own `catch_unwind`, so every remaining job in the
/// chunk still executes (previously the chunk unwound and its later
/// jobs were silently skipped), and the first captured payload is
/// re-raised verbatim via `resume_unwind` after all jobs settle —
/// instead of the pool's generic "engine pool task panicked" replacing
/// the original message. All result slots are therefore settled before
/// the re-raise; the collect below can only run when every slot is
/// `Some`.
pub fn par_map_jobs<J, T>(
    pool: &ThreadPool,
    jobs: Vec<J>,
    f: impl Fn(J) -> T + Sync,
) -> Vec<T>
where
    J: Send,
    T: Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let mut slots: Vec<Option<J>> = jobs.into_iter().map(Some).collect();
    let mut out: Vec<Option<T>> = std::iter::repeat_with(|| None).take(n).collect();
    let sp = SendCell(slots.as_mut_ptr());
    let op = SendCell(out.as_mut_ptr());
    // Deliberately `std::sync::Mutex`, not the `engine::sync` shim: the
    // payload capture is not part of the modeled settlement protocol (the
    // loom model below rebuilds it on shim types), and `into_inner` is a
    // std-only API.
    let panic_payload: std::sync::Mutex<Option<Box<dyn std::any::Any + Send>>> =
        std::sync::Mutex::new(None);
    pool.par_ranges(n, 1, |s, e| {
        for i in s..e {
            // SAFETY: par_ranges partitions [0, n) into disjoint index
            // ranges, so each slot / output cell is touched exactly once.
            let job = unsafe { (*sp.0.add(i)).take().expect("fleet job taken once") };
            match catch_unwind(AssertUnwindSafe(|| f(job))) {
                // SAFETY: same disjoint partition as the slot take above —
                // output cell `i` has exactly one writer.
                Ok(r) => unsafe { *op.0.add(i) = Some(r) },
                Err(p) => {
                    let mut slot = panic_payload.lock().unwrap();
                    slot.get_or_insert(p);
                }
            }
        }
    });
    if let Some(p) = panic_payload.into_inner().unwrap() {
        resume_unwind(p);
    }
    out.into_iter()
        .map(|t| t.expect("fleet job completed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn par_ranges_covers_everything_once() {
        let pool = ThreadPool::new(4);
        let n = 1013;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        pool.par_ranges(n, 1, |s, e| {
            for h in &hits[s..e] {
                h.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn serial_pool_runs_inline() {
        let pool = ThreadPool::serial();
        assert_eq!(pool.n_threads(), 1);
        let sum = AtomicUsize::new(0);
        pool.par_ranges(100, 10, |s, e| {
            sum.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn empty_range_is_noop() {
        let pool = ThreadPool::new(2);
        pool.par_ranges(0, 1, |_, _| panic!("must not run"));
    }

    #[test]
    #[should_panic(expected = "engine pool task panicked")]
    fn worker_panic_propagates() {
        let pool = ThreadPool::new(4);
        pool.par_ranges(100, 1, |s, _| {
            if s > 0 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn pool_survives_task_panic() {
        let pool = ThreadPool::new(4);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.par_ranges(100, 1, |_, _| panic!("boom"));
        }));
        assert!(r.is_err());
        // Pool still usable afterwards.
        let sum = AtomicUsize::new(0);
        pool.par_ranges(64, 1, |s, e| {
            sum.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn par_spmm_matches_serial_spmm() {
        let mut rng = Rng::new(301);
        let pool = ThreadPool::new(4);
        let cases = [(37usize, 29usize, 200usize, 5usize), (64, 64, 64, 1), (5, 80, 111, 7)];
        for &(m, n, nnz, b) in &cases {
            let mut d = Mat::zeros(m, n);
            for i in rng.sample_indices(m * n, nnz.min(m * n)) {
                d.data_mut()[i] = rng.gauss();
            }
            let s = Csr::from_dense(&d, 0.0);
            let bm = Mat::randn(n, b, &mut rng);
            let want = s.spmm(&bm);
            let mut got = vec![0.0; m * b];
            par_spmm_into(&pool, &s, bm.data(), b, &mut got);
            for (g, w) in got.iter().zip(want.data()) {
                assert!((g - w).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn par_gemm_matches_matmul() {
        let mut rng = Rng::new(302);
        let pool = ThreadPool::new(3);
        let a = Mat::randn(41, 23, &mut rng);
        let b = Mat::randn(23, 9, &mut rng);
        let want = a.matmul(&b);
        let mut got = vec![0.0; 41 * 9];
        par_gemm_into(&pool, &a, b.data(), 9, &mut got);
        for (g, w) in got.iter().zip(want.data()) {
            assert!((g - w).abs() < 1e-12);
        }
    }

    #[test]
    fn par_spmv_matches_spmv() {
        let mut rng = Rng::new(303);
        let pool = ThreadPool::new(4);
        let d = Mat::randn(130, 70, &mut rng);
        let s = Csr::from_dense(&d, 0.0);
        let x = rng.gauss_vec(70);
        let want = s.spmv(&x);
        let mut got = vec![0.0; 130];
        par_spmv_into(&pool, &s, &x, &mut got);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-12);
        }
    }

    #[test]
    fn par_gemv_t_matches_matvec_t() {
        let mut rng = Rng::new(304);
        let pool = ThreadPool::new(4);
        for &(m, n) in &[(130usize, 70usize), (3, 200), (64, 64)] {
            let a = Mat::randn(m, n, &mut rng);
            let x = rng.gauss_vec(m);
            let want = a.matvec_t(&x);
            let mut got = vec![0.0; n];
            par_gemv_t_into(&pool, &a, &x, &mut got);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-12 * (1.0 + w.abs()));
            }
        }
    }

    #[test]
    fn par_map_jobs_preserves_order_and_runs_every_job() {
        let pool = ThreadPool::new(4);
        let jobs: Vec<usize> = (0..37).collect();
        let got = par_map_jobs(&pool, jobs, |i| i * i);
        assert_eq!(got.len(), 37);
        for (i, v) in got.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
        // Empty job lists and serial pools degrade gracefully.
        assert!(par_map_jobs(&pool, Vec::<usize>::new(), |i| i).is_empty());
        let serial = ThreadPool::serial();
        assert_eq!(par_map_jobs(&serial, vec![1usize, 2, 3], |i| i + 1), vec![2, 3, 4]);
    }

    #[test]
    #[should_panic(expected = "job boom")]
    fn par_map_jobs_propagates_job_panics_with_their_payload() {
        let pool = ThreadPool::new(4);
        let _ = par_map_jobs(&pool, (0..16usize).collect(), |i| {
            if i == 7 {
                panic!("job boom");
            }
            i
        });
    }

    #[test]
    fn par_map_jobs_settles_every_other_job_before_reraising() {
        let pool = ThreadPool::new(4);
        let ran = AtomicUsize::new(0);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            par_map_jobs(&pool, (0..32usize).collect(), |i| {
                if i == 5 {
                    panic!("fleet job 5 exploded");
                }
                ran.fetch_add(1, Ordering::Relaxed);
                i
            })
        }));
        let payload = r.expect_err("panic must propagate");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(msg.contains("fleet job 5 exploded"), "payload lost: {msg:?}");
        assert_eq!(
            ran.load(Ordering::Relaxed),
            31,
            "non-panicking jobs must all run before the re-raise"
        );
        // The pool and the fan-out stay usable afterwards.
        assert_eq!(par_map_jobs(&pool, vec![1usize, 2], |i| i * 10), vec![10, 20]);
    }

    #[test]
    fn par_ranges_awkward_sizes_cover_everything_exactly_once() {
        // Sweep n just above nchunks × min_chunk (and other awkward
        // combinations): every index must be covered exactly once and no
        // empty or inverted range may reach a worker.
        for &threads in &[2usize, 4, 7] {
            let pool = ThreadPool::new(threads);
            for &n in &[1usize, 2, 3, 5, 7, 9, 13, 17, 31, 33, 65, 101, 127, 129] {
                for &min_chunk in &[1usize, 2, 3, 7, 16, 64, 1000] {
                    let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                    pool.par_ranges(n, min_chunk, |s, e| {
                        assert!(s < e && e <= n, "bad range {s}..{e} (n={n})");
                        for h in &hits[s..e] {
                            h.fetch_add(1, Ordering::Relaxed);
                        }
                    });
                    for (i, h) in hits.iter().enumerate() {
                        assert_eq!(
                            h.load(Ordering::Relaxed),
                            1,
                            "index {i} (n={n}, min_chunk={min_chunk}, threads={threads})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn par_gemm_is_bitwise_thread_invariant_off_the_tile_grid() {
        // 23 rows: not a multiple of the microkernel's MR, so the pooled
        // tile-aligned split and the serial full range must still agree
        // bit for bit (scalar edge rows included).
        let mut rng = Rng::new(306);
        let a = Mat::randn(23, 17, &mut rng);
        let b = Mat::randn(17, 11, &mut rng);
        let mut base = vec![0.0; 23 * 11];
        kernel::gemm_tiled_rows(&a, b.data(), 11, 0, 23, &mut base);
        for threads in [1usize, 2, 8] {
            let pool = ThreadPool::new(threads);
            let mut got = vec![0.0; 23 * 11];
            par_gemm_into(&pool, &a, b.data(), 11, &mut got);
            for (g, w) in got.iter().zip(&base) {
                assert_eq!(g.to_bits(), w.to_bits(), "threads={threads}");
            }
        }
    }

    #[test]
    fn gemv_t_cols_matches_pooled_transposed_matvec() {
        let mut rng = Rng::new(305);
        let pool = ThreadPool::new(4);
        let a = Mat::randn(33, 21, &mut rng);
        let x = rng.gauss_vec(33);
        let mut pooled = vec![0.0; 21];
        par_gemv_t_into(&pool, &a, &x, &mut pooled);
        let mut serial = vec![0.0; 21];
        gemv_t_cols(&a, &x, 0, 21, &mut serial);
        for (s, p) in serial.iter().zip(&pooled) {
            assert_eq!(s.to_bits(), p.to_bits());
        }
    }

    #[test]
    fn sync_shim_std_build_keeps_pool_bitwise_thread_invariant() {
        // Regression pin for the `engine::sync` shim: in the default
        // (std) build the shim re-exports are the std types, so routing
        // the pool's Latch / task queue through them must leave every
        // pooled kernel bitwise identical to the serial reference. A
        // behavioural change here means the shim stopped being a pure
        // re-export.
        let mut rng = Rng::new(307);
        for &(m, k, n) in &[(23usize, 17usize, 11usize), (64, 64, 8), (5, 80, 3)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let mut base = vec![0.0; m * n];
            kernel::gemm_tiled_rows(&a, b.data(), n, 0, m, &mut base);
            let x = rng.gauss_vec(m);
            let mut base_t = vec![0.0; k];
            gemv_t_cols(&a, &x, 0, k, &mut base_t);
            for threads in [1usize, 2, 8] {
                let pool = ThreadPool::new(threads);
                let mut got = vec![0.0; m * n];
                par_gemm_into(&pool, &a, b.data(), n, &mut got);
                for (g, w) in got.iter().zip(&base) {
                    assert_eq!(g.to_bits(), w.to_bits(), "gemm threads={threads}");
                }
                let mut got_t = vec![0.0; k];
                par_gemv_t_into(&pool, &a, &x, &mut got_t);
                for (g, w) in got_t.iter().zip(&base_t) {
                    assert_eq!(g.to_bits(), w.to_bits(), "gemv_t threads={threads}");
                }
            }
        }
    }

    #[test]
    fn concurrent_callers_share_pool() {
        let pool = Arc::new(ThreadPool::new(4));
        let mut handles = vec![];
        for t in 0..4u64 {
            let p = pool.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(400 + t);
                let d = Mat::randn(60, 40, &mut rng);
                let s = Csr::from_dense(&d, 0.0);
                let x = rng.gauss_vec(40);
                for _ in 0..50 {
                    let want = s.spmv(&x);
                    let mut got = vec![0.0; 60];
                    par_spmv_into(&p, &s, &x, &mut got);
                    for (g, w) in got.iter().zip(&want) {
                        assert!((g - w).abs() < 1e-12);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}

/// Exhaustive interleaving checks for the pool's synchronization
/// protocols, run under [`loom`](https://docs.rs/loom) via the
/// `loom-model` feature (`cargo test --features loom-model --release
/// loom_`). Each test wraps a protocol in `loom::model`, which executes
/// the body under *every* reachable thread interleaving instead of the
/// handful a runtime test samples.
#[cfg(all(test, feature = "loom-model"))]
mod loom_tests {
    use super::{Latch, Ordering, Task, TaskQueue};
    use loom::sync::atomic::AtomicUsize;
    use loom::sync::{Arc, Mutex};
    use loom::thread;

    /// Latch countdown has no lost wakeup: whatever order the workers
    /// decrement in, `wait` always returns (loom flags any interleaving
    /// where the main thread blocks forever as a deadlock).
    #[test]
    fn loom_latch_counts_down_without_lost_wakeups() {
        loom::model(|| {
            let latch = Arc::new(Latch::new(2));
            for _ in 0..2 {
                let l = latch.clone();
                thread::spawn(move || l.count_down());
            }
            latch.wait();
            assert_eq!(*latch.remaining.lock().unwrap(), 0);
        });
    }

    /// A worker's panic flag (Release store before `count_down`) is
    /// visible to the waiter after `wait` under every interleaving —
    /// the pool's "panics are never swallowed" contract.
    #[test]
    fn loom_latch_panic_flag_visible_after_wait() {
        loom::model(|| {
            let latch = Arc::new(Latch::new(1));
            let l = latch.clone();
            thread::spawn(move || {
                l.panicked.store(true, Ordering::Release);
                l.count_down();
            });
            latch.wait();
            assert!(latch.panicked.load(Ordering::Acquire));
        });
    }

    /// Tasks pushed before `close` are all delivered exactly once, and
    /// `pop` terminates (returns `None`) after close — the Drop-path
    /// protocol. Covers the push/close vs. pop race in every order.
    #[test]
    fn loom_task_queue_close_loses_no_tasks_and_terminates() {
        loom::model(|| {
            let q = Arc::new(TaskQueue::new());
            // `Task.latch` is a production field: it stays `std::sync::Arc`
            // (deliberately unshimmed — refcounting, not a protocol).
            let latch = std::sync::Arc::new(Latch::new(0));
            let f: &'static (dyn Fn(usize, usize) + Sync) = &|_, _| {};
            for i in 0..2 {
                q.push(Task { f, start: i, end: i + 1, latch: latch.clone() });
            }
            let qc = q.clone();
            let worker = thread::spawn(move || {
                let mut starts = Vec::new();
                while let Some(t) = qc.pop() {
                    starts.push(t.start);
                }
                starts
            });
            q.close();
            let starts = worker.join().unwrap();
            assert_eq!(starts, vec![0, 1], "tasks lost, duplicated, or reordered");
        });
    }

    /// Protocol model of `par_map_jobs` settlement: each output slot has
    /// exactly one writer, a panicking job records its payload and still
    /// settles, and after the latch opens the caller observes every
    /// non-panicking slot written. Slots are `loom::cell::UnsafeCell`, so
    /// loom itself proves the latch synchronizes the unsynchronized slot
    /// writes (an aliased or unordered access fails the model).
    #[test]
    fn loom_job_settlement_settles_each_slot_exactly_once_under_panic() {
        loom::model(|| {
            let slots: Arc<Vec<loom::cell::UnsafeCell<Option<usize>>>> =
                Arc::new((0..2).map(|_| loom::cell::UnsafeCell::new(None)).collect());
            let payload: Arc<Mutex<Option<&'static str>>> = Arc::new(Mutex::new(None));
            let latch = Arc::new(Latch::new(2));
            // Job 0 succeeds and writes its slot.
            {
                let (s, l) = (slots.clone(), latch.clone());
                thread::spawn(move || {
                    // SAFETY: slot 0 has this task as its only writer, and
                    // the main thread reads it only after `latch.wait()`.
                    s[0].with_mut(|p| unsafe { *p = Some(10) });
                    l.count_down();
                });
            }
            // Job 1 "panics": records a payload, settles without writing.
            {
                let (pl, l) = (payload.clone(), latch.clone());
                thread::spawn(move || {
                    pl.lock().unwrap().get_or_insert("job boom");
                    l.count_down();
                });
            }
            latch.wait();
            assert_eq!(*payload.lock().unwrap(), Some("job boom"));
            // SAFETY: both writers settled above; the latch orders their
            // writes before this read.
            let v = slots[0].with(|p| unsafe { *p });
            assert_eq!(v, Some(10), "settled job's slot must be visible");
            // SAFETY: same argument — slot 1's only (would-be) writer has
            // settled, and the latch orders that before this read.
            let empty = slots[1].with(|p| unsafe { (*p).is_none() });
            assert!(empty, "panicked job must not write its slot");
        });
    }

    /// The counter type the production settlement uses for metrics-style
    /// flags stays coherent across the latch: increments before
    /// `count_down` are all visible after `wait`.
    #[test]
    fn loom_latch_orders_relaxed_counters_for_the_waiter() {
        loom::model(|| {
            let hits = Arc::new(AtomicUsize::new(0));
            let latch = Arc::new(Latch::new(2));
            for _ in 0..2 {
                let (h, l) = (hits.clone(), latch.clone());
                thread::spawn(move || {
                    h.fetch_add(1, Ordering::Relaxed);
                    l.count_down();
                });
            }
            latch.wait();
            assert_eq!(hits.load(Ordering::Relaxed), 2);
        });
    }
}
