//! `ExecCtx` — the shared execution context that runs *training* on the
//! engine.
//!
//! PR 1 gave serving a substrate (plans, pool, arenas); this module hands
//! the same substrate to the factorization stack. An [`ExecCtx`] bundles
//! the engine's [`ThreadPool`] with the flop/byte cost model and exposes
//! the dense-GEMM entry points palm4MSA's gradients bottom out in:
//! cost-dispatched [`ExecCtx::gemm`] (serial / row-parallel /
//! transpose-rewrite picked per call), the transpose variants
//! [`ExecCtx::gemm_tn`] / [`ExecCtx::gemm_nt`], and pooled power
//! iterations for spectral norms ([`ExecCtx::spectral_norm_warm`]).
//!
//! How execution flows — serving and training share one substrate:
//!
//! ```text
//!   serving                             training
//!   ───────                             ────────
//!   coordinator                         palm4msa / hierarchical / dictlearn
//!        │ apply_batch                       │ gemm / gemm_tn / gemm_nt /
//!        ▼                                   │ spectral_norm_warm
//!   EngineOp ──► ApplyPlan                   ▼
//!        │        (cost model)           ExecCtx ◄── ApplyEngine::ctx()
//!        │ execute_*                         │        (same pool, same
//!        ▼                                   │         cost-model β)
//!      Arena ◄──── scratch ────┐             │
//!        │                     │             │
//!        └────► ThreadPool ◄───┴─────────────┘
//!                 par_ranges (row-partitioned, bitwise
//!                 thread-invariant kernels)
//! ```
//!
//! Every parallel kernel the ctx dispatches is **bitwise
//! thread-invariant**: outputs are partitioned into disjoint row/column
//! ranges and each output element is accumulated in the same index order
//! regardless of the thread count, so `ExecCtx::serial()` and
//! `ExecCtx::new(8)` produce identical bits. The dense inner loops are
//! the register-tiled [`super::kernel`] microkernels (lane width
//! selected once per process and exposed via [`ExecCtx::simd_lanes`]);
//! pooled chunks split at the kernel's tile boundaries, which is what
//! keeps the tile grid thread-independent. Factorization results are
//! therefore reproducible from the seed alone, independent of
//! `--threads` — checked by the determinism proptests and the
//! `factorize_scaling` bench.
//!
//! Zero-config callers use [`ExecCtx::global`] (shares the process-wide
//! serving engine's pool); a coordinator deployment reuses its engine for
//! on-line refactorization via [`super::ApplyEngine::ctx`].

#![forbid(unsafe_code)]

use super::kernel::{self, SimdLevel};
use super::plan::PlanConfig;
use super::pool::{par_gemm_into, par_gemv_into, par_gemv_t_into, ThreadPool};
use crate::linalg::{spectral_norm_with, Mat};
use std::sync::{Arc, OnceLock};

/// Shared execution context: thread pool + cost-model dispatch for the
/// dense kernels of the factorization stack. Cheap to clone (the pool is
/// behind an `Arc`).
#[derive(Clone)]
pub struct ExecCtx {
    pool: Arc<ThreadPool>,
    /// β in the dispatch cost `flops + β·bytes` (same knob as
    /// [`PlanConfig::bytes_per_flop_weight`]).
    beta: f64,
}

impl ExecCtx {
    /// Context with its own pool of `n_threads` total threads
    /// (1 = inline serial) and the default cost-model weight.
    pub fn new(n_threads: usize) -> Self {
        Self::from_pool(
            Arc::new(ThreadPool::new(n_threads)),
            PlanConfig::default().bytes_per_flop_weight,
        )
    }

    /// Inline serial context (no workers, no dispatch overhead).
    pub fn serial() -> Self {
        Self::new(1)
    }

    /// Context sharing an existing pool (how [`super::ApplyEngine::ctx`]
    /// hands the serving pool to factorization).
    pub fn from_pool(pool: Arc<ThreadPool>, beta: f64) -> Self {
        ExecCtx { pool, beta }
    }

    /// Process-default context: shares the global serving engine's pool
    /// (`FAUST_THREADS` / available parallelism — see [`super::global`]).
    pub fn global() -> &'static ExecCtx {
        static CTX: OnceLock<ExecCtx> = OnceLock::new();
        CTX.get_or_init(|| super::global().ctx())
    }

    /// Total threads participating in each parallel kernel.
    pub fn n_threads(&self) -> usize {
        self.pool.n_threads()
    }

    /// The underlying worker pool.
    pub fn pool(&self) -> &Arc<ThreadPool> {
        &self.pool
    }

    /// β of the cost model `flops + β·bytes` this ctx dispatches with —
    /// the same weight the plan compiler and the coordinator's adaptive
    /// batch sizing use, so one knob describes the machine everywhere.
    pub fn beta(&self) -> f64 {
        self.beta
    }

    /// Microkernel build this ctx's dense GEMM paths dispatch to —
    /// runtime-selected once per process ([`super::kernel::simd_level`]),
    /// so it is fixed for the ctx's whole lifetime.
    pub fn simd_level(&self) -> SimdLevel {
        kernel::simd_level()
    }

    /// Width of the explicit f64 lane chunks of this ctx's microkernels
    /// (4 or 8; also recorded in every [`super::CostProfile`]).
    pub fn simd_lanes(&self) -> usize {
        self.simd_level().lane_width()
    }

    /// Cost-model decision for `a·b`: is the double-transpose rewrite
    /// `(bᵀ aᵀ)ᵀ` (zero-skip lands on `b`'s entries) cheaper than the
    /// direct ikj pass (zero-skip on `a`), three extra transpose passes
    /// included? PALM factors are dense-stored but often extremely sparse
    /// after projection, so this is regularly a ~10× call. Shared with
    /// [`super::FleetCtx`] so fused cross-operator GEMMs make the same
    /// per-product choice as solo dispatch (bitwise-identity contract).
    pub(crate) fn rewrite_wins(&self, a: &Mat, b: &Mat) -> bool {
        self.rewrite_wins_nnz(a, b, a.nnz(), b.nnz())
    }

    /// [`ExecCtx::rewrite_wins`] with the operand nnz counts precomputed —
    /// the fleet's batched entry point scans each operand once and reuses
    /// the counts for both this decision and its crossover flop estimate.
    pub(crate) fn rewrite_wins_nnz(
        &self,
        a: &Mat,
        b: &Mat,
        a_nnz: usize,
        b_nnz: usize,
    ) -> bool {
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        let base_bytes = 8 * (m * k + k * n + m * n);
        let direct = (2 * a_nnz * n) as f64 + self.beta * base_bytes as f64;
        // Rewrite pays the same streaming traffic plus one full pass each
        // for aᵀ, bᵀ and the final out-transpose.
        let transpose_bytes = 8 * (m * k + k * n + 2 * m * n);
        let rewrite =
            (2 * b_nnz * m) as f64 + self.beta * (base_bytes + transpose_bytes) as f64;
        rewrite < direct
    }

    /// `a · b`, dispatched by the cost model between the direct
    /// row-parallel kernel and the transpose rewrite. Serial-vs-parallel
    /// is decided per call by the pool's work grain, so tiny products run
    /// inline with zero dispatch overhead.
    pub fn gemm(&self, a: &Mat, b: &Mat) -> Mat {
        assert_eq!(a.cols(), b.rows(), "ctx gemm dim mismatch");
        if self.rewrite_wins(a, b) {
            let bt = b.t();
            let at = a.t();
            let mut out_t = Mat::zeros(b.cols(), a.rows());
            par_gemm_into(&self.pool, &bt, at.data(), a.rows(), out_t.data_mut());
            out_t.t()
        } else {
            let mut out = Mat::zeros(a.rows(), b.cols());
            par_gemm_into(&self.pool, a, b.data(), b.cols(), out.data_mut());
            out
        }
    }

    /// `aᵀ · b` via explicit transpose + the dispatched kernel: better
    /// cache behaviour than a scatter-accumulate, and the zero-skip lands
    /// on `aᵀ`'s rows.
    pub fn gemm_tn(&self, a: &Mat, b: &Mat) -> Mat {
        assert_eq!(a.rows(), b.rows(), "ctx gemm_tn dim mismatch");
        self.gemm(&a.t(), b)
    }

    /// `a · bᵀ` via explicit transpose + the dispatched kernel.
    pub fn gemm_nt(&self, a: &Mat, b: &Mat) -> Mat {
        assert_eq!(a.cols(), b.cols(), "ctx gemm_nt dim mismatch");
        self.gemm(a, &b.t())
    }

    /// Spectral norm `‖a‖₂` by pooled power iteration on `aᵀa`, with a
    /// caller-owned warm-start vector (see
    /// [`crate::linalg::spectral_norm_warm`] for the warm-start
    /// contract). Both half-iterations run row/column-partitioned on the
    /// pool; the accumulation order per output element is fixed, so the
    /// result is bitwise independent of the thread count.
    pub fn spectral_norm_warm(
        &self,
        a: &Mat,
        x: &mut Vec<f64>,
        max_iter: usize,
        tol: f64,
    ) -> f64 {
        let (m, n) = a.shape();
        if m == 0 || n == 0 {
            return 0.0;
        }
        let mut y = vec![0.0; m];
        spectral_norm_with(n, x, max_iter, tol, |xv, z| {
            par_gemv_into(&self.pool, a, xv, &mut y);
            par_gemv_t_into(&self.pool, a, &y, z);
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::ApplyEngine;
    use crate::linalg::svd_jacobi;
    use crate::rng::Rng;

    fn sparse_mat(rng: &mut Rng, r: usize, c: usize, nnz: usize) -> Mat {
        let mut m = Mat::zeros(r, c);
        for i in rng.sample_indices(r * c, nnz.min(r * c)) {
            m.data_mut()[i] = rng.gauss();
        }
        m
    }

    #[test]
    fn gemm_matches_matmul_both_dispatch_branches() {
        let mut rng = Rng::new(701);
        let ctx = ExecCtx::new(3);
        // Dense·sparse forces the transpose rewrite; sparse·dense the
        // direct kernel; dense·dense exercises the tie region.
        let cases = [
            (Mat::randn(20, 16, &mut rng), sparse_mat(&mut rng, 16, 12, 10)),
            (sparse_mat(&mut rng, 18, 14, 9), Mat::randn(14, 11, &mut rng)),
            (Mat::randn(9, 7, &mut rng), Mat::randn(7, 13, &mut rng)),
        ];
        for (a, b) in &cases {
            let got = ctx.gemm(a, b);
            let want = a.matmul(b);
            assert!(got.rel_fro_err(&want) < 1e-13);
        }
    }

    #[test]
    fn gemm_transpose_variants_match_reference() {
        let mut rng = Rng::new(702);
        let ctx = ExecCtx::new(2);
        let a = Mat::randn(8, 6, &mut rng);
        let b = Mat::randn(8, 5, &mut rng);
        let c = Mat::randn(4, 6, &mut rng);
        assert!(ctx.gemm_tn(&a, &b).rel_fro_err(&a.t().matmul(&b)) < 1e-13);
        assert!(ctx.gemm_nt(&a, &c).rel_fro_err(&a.matmul(&c.t())) < 1e-13);
    }

    #[test]
    fn gemm_is_bitwise_thread_invariant() {
        let mut rng = Rng::new(703);
        let a = sparse_mat(&mut rng, 60, 50, 400);
        let b = Mat::randn(50, 40, &mut rng);
        let base = ExecCtx::serial().gemm(&a, &b);
        for threads in [2usize, 8] {
            let got = ExecCtx::new(threads).gemm(&a, &b);
            assert_eq!(got.data(), base.data(), "threads={threads}");
        }
    }

    #[test]
    fn pooled_spectral_norm_matches_svd() {
        let mut rng = Rng::new(705);
        let ctx = ExecCtx::new(4);
        let a = Mat::randn(15, 9, &mut rng);
        let s = svd_jacobi(&a);
        let mut warm = vec![];
        let sn = ctx.spectral_norm_warm(&a, &mut warm, 200, 1e-10);
        assert!((sn - s.s[0]).abs() < 1e-6 * s.s[0], "sn={sn} s0={}", s.s[0]);
        // Warm restart converges to the same value.
        let sn2 = ctx.spectral_norm_warm(&a, &mut warm, 200, 1e-10);
        assert!((sn2 - sn).abs() < 1e-8 * sn);
    }

    #[test]
    fn spectral_norm_is_thread_invariant() {
        let mut rng = Rng::new(706);
        let a = Mat::randn(30, 22, &mut rng);
        let mut w1 = vec![];
        let n1 = ExecCtx::serial().spectral_norm_warm(&a, &mut w1, 40, 0.0);
        let mut w8 = vec![];
        let n8 = ExecCtx::new(8).spectral_norm_warm(&a, &mut w8, 40, 0.0);
        assert_eq!(n1.to_bits(), n8.to_bits());
        assert_eq!(w1, w8);
    }

    #[test]
    fn ctx_records_the_process_simd_level() {
        let ctx = ExecCtx::new(2);
        assert_eq!(ctx.simd_level(), crate::engine::kernel::simd_level());
        let w = ctx.simd_lanes();
        assert!(w == 4 || w == 8);
    }

    #[test]
    fn engine_ctx_shares_the_serving_pool() {
        let engine = ApplyEngine::with_threads(3);
        let ctx = engine.ctx();
        assert!(Arc::ptr_eq(engine.pool(), ctx.pool()));
        assert_eq!(ctx.n_threads(), 3);
    }

    #[test]
    fn global_ctx_is_usable() {
        let ctx = ExecCtx::global();
        assert!(ctx.n_threads() >= 1);
        let a = Mat::eye(4, 4);
        let b = Mat::eye(4, 4);
        assert!(ctx.gemm(&a, &b).rel_fro_err(&Mat::eye(4, 4)) < 1e-15);
    }
}
