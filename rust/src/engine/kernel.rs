//! SIMD-width-aware dense microkernels — the register-tiled, cache-blocked
//! GEMM layer every dense hot path bottoms out in (ROADMAP items d and j).
//!
//! The engine's previous dense kernels streamed the output row through
//! memory once per `k` step and leaned entirely on auto-vectorization.
//! This module replaces those scalar inner loops with an explicit
//! microkernel layer shared by **every** dense GEMM path: the
//! cost-dispatched [`super::ExecCtx::gemm`] family, the pooled
//! [`super::pool::par_gemm_into`] / `gemm_rows` / `gemv_t_cols` kernels,
//! the dense stages of [`super::plan::ApplyPlan`], and the fleet's fused
//! per-operator jobs ([`super::FleetCtx::gemm_many`]).
//!
//! **Blocking scheme.** `C = A·B` is computed in fixed [`MR`]×NR register
//! tiles: `B` is packed once per product into NR-column stripes
//! (`k`-major, zero-padded to the lane width — `with_pack_panel`), and
//! each tile of [`MR`] consecutive `A` rows walks one packed stripe
//! keeping all `MR × NR` partial sums in registers for the whole `k`
//! loop. The packed panel is built on the dispatching thread and shared
//! read-only across all row chunks of a pooled call, so every chunk
//! streams the same L1/L2-resident stripe instead of re-striding the raw
//! `B`. Rows beyond the last full tile and columns beyond the last full
//! stripe take a scalar edge path.
//!
//! **Lane-width selection.** The whole layer is generic over the
//! [`Scalar`] element type (`f64` for factorization and the default
//! serving tier, `f32` for the quantized serving tier — ROADMAP item j),
//! and the stripe width NR is picked per scalar from the machine's SIMD
//! level, detected once per process ([`simd_level`]):
//!
//! | level      | f64 lanes | f32 lanes |
//! |------------|-----------|-----------|
//! | `Avx512`   | 8         | 16        |
//! | `Avx2`     | 4         | 8         |
//! | `Portable` | 4         | 8         |
//!
//! The microkernel body is monomorphized per scalar × width and entered
//! through `#[target_feature(enable = "avx2")]` wrappers (256-bit
//! codegen: the widest width every supported stable toolchain can emit,
//! and the preferred width on most AVX-512 silicon — there the widest
//! chunk lands as two 256-bit ops, doubling the register tile and
//! halving loop overhead per flop), with no unstable intrinsics
//! anywhere. f32 doubles the elements per 256-bit op *and* halves the
//! bytes streamed per packed-panel walk — the two levers that make the
//! f32 serving tier faster than f64 on the same silicon.
//!
//! **Determinism contract.** Every output element accumulates its `k`
//! terms in ascending-`k` order with a single accumulator, and tile
//! membership depends only on *absolute* row indices (`MR` is a
//! compile-time constant; pooled callers split work at tile boundaries).
//! The lane width only changes how independent output elements are
//! *grouped*, never the per-element operation sequence, so results are
//! bitwise identical across thread counts, across the solo/fleet
//! dispatch routes, and even across machines with different SIMD levels
//! — separately *within each scalar type* (f32 results are bitwise
//! thread-invariant too; they are of course not bitwise equal to f64).
//! The one deliberate deviation from the scalar reference
//! ([`gemm_scalar_rows`]) is the zero-skip: the tiled kernel skips a `k`
//! step only when *all* [`MR`] rows of the tile are zero there, which
//! can flip the sign of an exact-zero output where the scalar path's
//! per-row skip would not — hence the kernel proptests compare tiled to
//! scalar within 1e-12 but thread counts bitwise.

use crate::linalg::Mat;
use std::cell::RefCell;
use std::sync::OnceLock;

/// Row-tile height of the register microkernel. Compile-time fixed so
/// the tile a row belongs to depends only on its absolute index — the
/// pooled dispatchers split work at `MR` boundaries, which is what keeps
/// the zero-skip pattern (and therefore every output bit) independent of
/// the thread count.
pub const MR: usize = 4;

/// Dense products narrower than this many output columns stay on the
/// scalar path (a packed stripe cannot amortize below half a lane).
const MIN_TILED_BCOLS: usize = 4;

/// Instruction-set level the microkernels were dispatched for, detected
/// once per process and recorded in [`super::ExecCtx`] /
/// [`super::CostProfile`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// AVX-512F hardware: 8-wide f64 / 16-wide f32 lane chunks (emitted
    /// as pairs of 256-bit ops — see the module docs on width selection).
    Avx512,
    /// AVX2: 4 × f64 / 8 × f32 lane chunks.
    Avx2,
    /// Portable fallback: chunks compiled for the baseline target
    /// (pairs of SSE2 lanes on x86-64, NEON on aarch64).
    Portable,
}

impl SimdLevel {
    /// Width of one explicit **f64** lane chunk (the NR of the f64
    /// microkernel). For the per-scalar width use [`Scalar::lanes`] /
    /// [`lane_width_of`].
    pub fn lane_width(self) -> usize {
        match self {
            SimdLevel::Avx512 => 8,
            SimdLevel::Avx2 | SimdLevel::Portable => 4,
        }
    }
}

fn detect() -> SimdLevel {
    // Miri interprets MIR and cannot execute `#[target_feature]` code, so
    // under `cargo miri test` every dispatch takes the portable scalar
    // path — same math (identical accumulation order by the bitwise
    // contract), no SIMD intrinsics for the interpreter to reject.
    if cfg!(miri) {
        return SimdLevel::Portable;
    }
    #[cfg(target_arch = "x86_64")]
    {
        // Both width-specialized builds are compiled under `avx2`, so
        // every non-portable level requires it (avx512f implies avx2 on
        // real silicon; checking both keeps the dispatch sound anyway).
        if std::arch::is_x86_feature_detected!("avx2") {
            if std::arch::is_x86_feature_detected!("avx512f") {
                return SimdLevel::Avx512;
            }
            return SimdLevel::Avx2;
        }
    }
    SimdLevel::Portable
}

/// The process-wide SIMD level (detected on first use, then cached).
pub fn simd_level() -> SimdLevel {
    static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
    *LEVEL.get_or_init(detect)
}

/// The selected f64 lane-chunk width (4 or 8).
pub fn lane_width() -> usize {
    simd_level().lane_width()
}

/// The selected lane-chunk width for scalar type `S` (f64: 4 or 8;
/// f32: 8 or 16).
pub fn lane_width_of<S: Scalar>() -> usize {
    S::lanes(simd_level())
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f64 {}
    impl Sealed for f32 {}
}

/// The element types the kernel/pool/plan/arena stack is generic over:
/// exactly `f64` and `f32` (sealed). Carries the per-type SIMD lane
/// count, the conversions the quantized serving tier is built from, and
/// the width-dispatch hooks that route each monomorphization to its
/// `#[target_feature]` microkernel build.
pub trait Scalar:
    sealed::Sealed
    + Copy
    + Send
    + Sync
    + Default
    + PartialEq
    + PartialOrd
    + std::fmt::Debug
    + std::fmt::Display
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::Div<Output = Self>
    + std::ops::Neg<Output = Self>
    + std::ops::AddAssign
    + std::ops::SubAssign
    + std::ops::MulAssign
    + 'static
{
    /// Additive identity.
    const ZERO: Self;
    /// Multiplicative identity.
    const ONE: Self;
    /// Bytes per element (the plan cost model's `elem_bytes`).
    const BYTES: usize;
    /// Display name ("f64" / "f32") for stats and wire dtype labels.
    const NAME: &'static str;

    /// Lane-chunk width (microkernel NR) at a given SIMD level — the
    /// per-type lane table in the module docs.
    fn lanes(level: SimdLevel) -> usize;

    /// Quantize from the f64 reference representation.
    fn from_f64(v: f64) -> Self;
    /// Widen back to f64 (exact for both types).
    fn to_f64(self) -> f64;

    /// Hand `f` this thread's reusable pack buffer for `Self`.
    #[doc(hidden)]
    fn with_pack_buf<R>(f: impl FnOnce(&mut Vec<Self>) -> R) -> R;
    /// Pack one stripe set at this type's process lane width.
    #[doc(hidden)]
    fn pack_panel(b: &[Self], ktot: usize, bcols: usize, buf: &mut [Self]);
    /// Width-dispatched tiled GEMM over rows `[rs, re)` (see
    /// [`gemm_panel_rows`]).
    #[doc(hidden)]
    fn dispatch_gemm_panel(
        a: &Mat<Self>,
        panel: &[Self],
        bcols: usize,
        rs: usize,
        re: usize,
        out: &mut [Self],
    );
    /// Width-dispatched tiled transposed-matvec stripe (see
    /// [`gemv_t_tiled_cols`]).
    #[doc(hidden)]
    fn dispatch_gemv_t(a: &Mat<Self>, x: &[Self], s: usize, e: usize, chunk: &mut [Self]);
}

impl Scalar for f64 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const BYTES: usize = 8;
    const NAME: &'static str = "f64";

    fn lanes(level: SimdLevel) -> usize {
        match level {
            SimdLevel::Avx512 => 8,
            SimdLevel::Avx2 | SimdLevel::Portable => 4,
        }
    }

    fn from_f64(v: f64) -> Self {
        v
    }
    fn to_f64(self) -> f64 {
        self
    }

    fn with_pack_buf<R>(f: impl FnOnce(&mut Vec<Self>) -> R) -> R {
        PACK_BUF.with(|cell| f(&mut cell.borrow_mut()))
    }

    fn pack_panel(b: &[Self], ktot: usize, bcols: usize, buf: &mut [Self]) {
        match Self::lanes(simd_level()) {
            8 => pack_b::<f64, 8>(b, ktot, bcols, buf),
            _ => pack_b::<f64, 4>(b, ktot, bcols, buf),
        }
    }

    fn dispatch_gemm_panel(
        a: &Mat<Self>,
        panel: &[Self],
        bcols: usize,
        rs: usize,
        re: usize,
        out: &mut [Self],
    ) {
        #[cfg(target_arch = "x86_64")]
        match simd_level() {
            // SAFETY: avx2 was verified present by `detect()` (avx512f
            // implies avx2 on every shipping CPU and in the detection
            // order).
            SimdLevel::Avx512 => unsafe { gemm_panel_range_w8(a, panel, bcols, rs, re, out) },
            // SAFETY: avx2 verified present by `detect()`.
            SimdLevel::Avx2 => unsafe { gemm_panel_range_w4(a, panel, bcols, rs, re, out) },
            SimdLevel::Portable => gemm_panel_range::<f64, 4>(a, panel, bcols, rs, re, out),
        }
        #[cfg(not(target_arch = "x86_64"))]
        gemm_panel_range::<f64, 4>(a, panel, bcols, rs, re, out)
    }

    fn dispatch_gemv_t(a: &Mat<Self>, x: &[Self], s: usize, e: usize, chunk: &mut [Self]) {
        #[cfg(target_arch = "x86_64")]
        match simd_level() {
            // SAFETY: avx2 verified present by `detect()` for both
            // non-portable levels.
            SimdLevel::Avx512 => unsafe { gemv_t_range_w8(a, x, s, e, chunk) },
            // SAFETY: avx2 verified present by `detect()`.
            SimdLevel::Avx2 => unsafe { gemv_t_range_w4(a, x, s, e, chunk) },
            SimdLevel::Portable => gemv_t_range::<f64, 4>(a, x, s, e, chunk),
        }
        #[cfg(not(target_arch = "x86_64"))]
        gemv_t_range::<f64, 4>(a, x, s, e, chunk)
    }
}

impl Scalar for f32 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const BYTES: usize = 4;
    const NAME: &'static str = "f32";

    fn lanes(level: SimdLevel) -> usize {
        match level {
            SimdLevel::Avx512 => 16,
            SimdLevel::Avx2 | SimdLevel::Portable => 8,
        }
    }

    fn from_f64(v: f64) -> Self {
        v as f32
    }
    fn to_f64(self) -> f64 {
        self as f64
    }

    fn with_pack_buf<R>(f: impl FnOnce(&mut Vec<Self>) -> R) -> R {
        PACK_BUF_F32.with(|cell| f(&mut cell.borrow_mut()))
    }

    fn pack_panel(b: &[Self], ktot: usize, bcols: usize, buf: &mut [Self]) {
        match Self::lanes(simd_level()) {
            16 => pack_b::<f32, 16>(b, ktot, bcols, buf),
            _ => pack_b::<f32, 8>(b, ktot, bcols, buf),
        }
    }

    fn dispatch_gemm_panel(
        a: &Mat<Self>,
        panel: &[Self],
        bcols: usize,
        rs: usize,
        re: usize,
        out: &mut [Self],
    ) {
        #[cfg(target_arch = "x86_64")]
        match simd_level() {
            // SAFETY: avx2 verified present by `detect()` (see the f64
            // dispatch above).
            SimdLevel::Avx512 => unsafe { gemm_panel_range_f32_w16(a, panel, bcols, rs, re, out) },
            // SAFETY: avx2 verified present by `detect()`.
            SimdLevel::Avx2 => unsafe { gemm_panel_range_f32_w8(a, panel, bcols, rs, re, out) },
            SimdLevel::Portable => gemm_panel_range::<f32, 8>(a, panel, bcols, rs, re, out),
        }
        #[cfg(not(target_arch = "x86_64"))]
        gemm_panel_range::<f32, 8>(a, panel, bcols, rs, re, out)
    }

    fn dispatch_gemv_t(a: &Mat<Self>, x: &[Self], s: usize, e: usize, chunk: &mut [Self]) {
        #[cfg(target_arch = "x86_64")]
        match simd_level() {
            // SAFETY: as above.
            SimdLevel::Avx512 => unsafe { gemv_t_range_f32_w16(a, x, s, e, chunk) },
            // SAFETY: avx2 verified present by `detect()`.
            SimdLevel::Avx2 => unsafe { gemv_t_range_f32_w8(a, x, s, e, chunk) },
            SimdLevel::Portable => gemv_t_range::<f32, 8>(a, x, s, e, chunk),
        }
        #[cfg(not(target_arch = "x86_64"))]
        gemv_t_range::<f32, 8>(a, x, s, e, chunk)
    }
}

/// Does the tiled path apply to an `m`-row, `bcols`-column product?
/// Deterministic in the shape alone, so the solo and fleet routes always
/// agree on the kernel choice.
pub(crate) fn tiled_applies(m: usize, bcols: usize) -> bool {
    m >= MR && bcols >= MIN_TILED_BCOLS
}

thread_local! {
    /// Reusable f64 pack buffer: packing allocates only until the buffer
    /// has grown to the deployment's largest operand (the serving plans'
    /// zero-alloc steady state keeps holding).
    static PACK_BUF: RefCell<Vec<f64>> = RefCell::new(Vec::new());
    /// f32 twin of [`PACK_BUF`] for the quantized serving tier.
    static PACK_BUF_F32: RefCell<Vec<f32>> = RefCell::new(Vec::new());
}

/// Number of NR-wide column stripes covering `bcols` columns.
fn n_stripes(bcols: usize, nr: usize) -> usize {
    bcols.div_ceil(nr)
}

/// Pack row-major `b` (`ktot × bcols`) into NR-column stripes,
/// stripe-major then `k`-major, zero-padded to the lane width:
/// `buf[(s·ktot + k)·NR + l] = b[k][s·NR + l]`.
fn pack_b<S: Scalar, const NR: usize>(b: &[S], ktot: usize, bcols: usize, buf: &mut [S]) {
    let stripes = n_stripes(bcols, NR);
    debug_assert_eq!(buf.len(), stripes * ktot * NR);
    for (k, brow) in b.chunks_exact(bcols).enumerate() {
        for s in 0..stripes {
            let j0 = s * NR;
            let w = NR.min(bcols - j0);
            let dst = &mut buf[(s * ktot + k) * NR..][..NR];
            dst[..w].copy_from_slice(&brow[j0..j0 + w]);
            dst[w..].fill(S::ZERO);
        }
    }
}

/// Pack `b` into this thread's reusable panel buffer at the scalar's
/// process lane width and hand the packed panel to `f`. The panel is a
/// plain slice, safe to share read-only with pool workers for the
/// duration of the call — "packed once, reused across row chunks".
pub(crate) fn with_pack_panel<S: Scalar, R>(
    b: &[S],
    ktot: usize,
    bcols: usize,
    f: impl FnOnce(&[S]) -> R,
) -> R {
    let nr = S::lanes(simd_level());
    let len = n_stripes(bcols, nr) * ktot * nr;
    S::with_pack_buf(|buf| {
        if buf.len() < len {
            buf.resize(len, S::ZERO);
        }
        S::pack_panel(b, ktot, bcols, &mut buf[..len]);
        f(&buf[..len])
    })
}

/// MR×NR register tile: accumulate `acc[r][l] += a_r[k] · panel[k][l]`
/// over the whole `k` range, skipping `k` steps where all four `a` rows
/// are zero (PALM factors are dense-stored but extremely sparse after
/// projection). Single accumulator per output element, `k` ascending —
/// the determinism contract.
#[inline(always)]
#[allow(clippy::needless_range_loop)]
fn mr_tile<S: Scalar, const NR: usize>(
    a0: &[S],
    a1: &[S],
    a2: &[S],
    a3: &[S],
    panel: &[S],
    acc: &mut [[S; NR]; MR],
) {
    let it = panel.chunks_exact(NR).zip(a0).zip(a1).zip(a2).zip(a3);
    for ((((bv, &v0), &v1), &v2), &v3) in it {
        if v0 == S::ZERO && v1 == S::ZERO && v2 == S::ZERO && v3 == S::ZERO {
            continue;
        }
        let bv: &[S; NR] = bv.try_into().expect("stripe chunk is NR wide");
        for l in 0..NR {
            acc[0][l] += v0 * bv[l];
            acc[1][l] += v1 * bv[l];
            acc[2][l] += v2 * bv[l];
            acc[3][l] += v3 * bv[l];
        }
    }
}

/// 1×NR edge tile for the rows past the last full MR tile (per-row
/// zero-skip, same as the scalar reference).
#[inline(always)]
#[allow(clippy::needless_range_loop)]
fn row_tile<S: Scalar, const NR: usize>(arow: &[S], panel: &[S], acc: &mut [S; NR]) {
    for (bv, &av) in panel.chunks_exact(NR).zip(arow) {
        if av == S::ZERO {
            continue;
        }
        let bv: &[S; NR] = bv.try_into().expect("stripe chunk is NR wide");
        for l in 0..NR {
            acc[l] += av * bv[l];
        }
    }
}

/// Tiled GEMM over output rows `[rs, re)` against a packed panel.
/// `rs` must sit on an `MR` tile boundary (pooled callers split at tile
/// granularity); `out` holds exactly rows `[rs, re)`.
///
/// `inline(always)` is load-bearing: the body must inline into the
/// `#[target_feature(enable = "avx2")]` wrappers below (a callee with
/// fewer features may inline into a more-featured caller) so the lane
/// chunks are actually emitted as AVX ops — out-of-line it would compile
/// once for the baseline target and the dispatch would be cosmetic.
#[inline(always)]
fn gemm_panel_range<S: Scalar, const NR: usize>(
    a: &Mat<S>,
    panel: &[S],
    bcols: usize,
    rs: usize,
    re: usize,
    out: &mut [S],
) {
    let ktot = a.cols();
    let stripes = n_stripes(bcols, NR);
    debug_assert_eq!(out.len(), (re - rs) * bcols);
    debug_assert_eq!(panel.len(), stripes * ktot * NR);
    debug_assert_eq!(rs % MR, 0, "chunk start off the tile grid");
    let mut i = rs;
    while i + MR <= re {
        let (a0, a1, a2, a3) = (a.row(i), a.row(i + 1), a.row(i + 2), a.row(i + 3));
        for s in 0..stripes {
            let stripe = &panel[s * ktot * NR..][..ktot * NR];
            let mut acc = [[S::ZERO; NR]; MR];
            mr_tile::<S, NR>(a0, a1, a2, a3, stripe, &mut acc);
            let j0 = s * NR;
            let w = NR.min(bcols - j0);
            for (r, accr) in acc.iter().enumerate() {
                out[(i - rs + r) * bcols + j0..][..w].copy_from_slice(&accr[..w]);
            }
        }
        i += MR;
    }
    // Scalar edge path: the (m mod MR) rows past the last full tile.
    for row in i..re {
        let arow = a.row(row);
        for s in 0..stripes {
            let stripe = &panel[s * ktot * NR..][..ktot * NR];
            let mut acc = [S::ZERO; NR];
            row_tile::<S, NR>(arow, stripe, &mut acc);
            let j0 = s * NR;
            let w = NR.min(bcols - j0);
            out[(row - rs) * bcols + j0..][..w].copy_from_slice(&acc[..w]);
        }
    }
}

// The width-specialized builds are compiled under `avx2` (stable as a
// `target_feature` since Rust 1.27) rather than `avx512f` (stable only
// in much newer toolchains): 256-bit is the preferred vector width LLVM
// picks on most AVX-512 silicon anyway, so the widest chunk lands as two
// 256-bit ops — wider register tiles, halved loop overhead per flop —
// while the crate keeps building on every supported stable toolchain.

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn gemm_panel_range_w8(
    a: &Mat,
    panel: &[f64],
    bcols: usize,
    rs: usize,
    re: usize,
    out: &mut [f64],
) {
    gemm_panel_range::<f64, 8>(a, panel, bcols, rs, re, out)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn gemm_panel_range_w4(
    a: &Mat,
    panel: &[f64],
    bcols: usize,
    rs: usize,
    re: usize,
    out: &mut [f64],
) {
    gemm_panel_range::<f64, 4>(a, panel, bcols, rs, re, out)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn gemm_panel_range_f32_w16(
    a: &Mat<f32>,
    panel: &[f32],
    bcols: usize,
    rs: usize,
    re: usize,
    out: &mut [f32],
) {
    gemm_panel_range::<f32, 16>(a, panel, bcols, rs, re, out)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn gemm_panel_range_f32_w8(
    a: &Mat<f32>,
    panel: &[f32],
    bcols: usize,
    rs: usize,
    re: usize,
    out: &mut [f32],
) {
    gemm_panel_range::<f32, 8>(a, panel, bcols, rs, re, out)
}

/// Run the tiled kernel for rows `[rs, re)` of `a · B` against a packed
/// panel, dispatched to the microkernel build selected at process start
/// for the scalar type.
pub(crate) fn gemm_panel_rows<S: Scalar>(
    a: &Mat<S>,
    panel: &[S],
    bcols: usize,
    rs: usize,
    re: usize,
    out: &mut [S],
) {
    S::dispatch_gemm_panel(a, panel, bcols, rs, re, out)
}

/// Scalar reference GEMM over an output row range (the engine's
/// pre-kernel inner loop, kept verbatim): ikj order with per-row
/// zero-skip, output row streamed through memory each `k` step. This is
/// the baseline the kernel proptests and the scalar-vs-tiled benches
/// compare against.
pub fn gemm_scalar_rows<S: Scalar>(
    a: &Mat<S>,
    b: &[S],
    bcols: usize,
    start: usize,
    end: usize,
    out: &mut [S],
) {
    debug_assert_eq!(out.len(), (end - start) * bcols);
    let k = a.cols();
    for i in start..end {
        let orow = &mut out[(i - start) * bcols..(i - start + 1) * bcols];
        orow.fill(S::ZERO);
        let arow = a.row(i);
        for (kk, &av) in arow.iter().enumerate().take(k) {
            if av == S::ZERO {
                continue;
            }
            let brow = &b[kk * bcols..][..bcols];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// Serial kernel-layer GEMM over an output row range: packs `b` into this
/// thread's panel buffer and runs the tiled microkernel, falling back to
/// the scalar reference for shapes the tiles cannot cover (narrow
/// batches, fewer than [`MR`] rows) and for ranges off the absolute
/// [`MR`] tile grid — both `start` and `end` must sit on a tile
/// boundary (`end == a.rows()` counts) to take the tiled route, because
/// a mid-tile range would regroup the tile zero-skip and silently break
/// the bitwise identity with full-range/tile-chunked calls. Produces
/// the same bits as the pooled path at any thread count — the fleet's
/// fused per-operator jobs call this directly.
pub fn gemm_tiled_rows<S: Scalar>(
    a: &Mat<S>,
    b: &[S],
    bcols: usize,
    start: usize,
    end: usize,
    out: &mut [S],
) {
    let off_grid = start % MR != 0 || (end % MR != 0 && end != a.rows());
    if !tiled_applies(a.rows(), bcols) || off_grid {
        gemm_scalar_rows(a, b, bcols, start, end, out);
        return;
    }
    with_pack_panel(b, a.cols(), bcols, |panel| {
        gemm_panel_rows(a, panel, bcols, start, end, out);
    });
}

/// Tiled transposed matvec stripe: `chunk = (Aᵀ x)[s..e)`. Columns are
/// processed in NR-wide register chunks with a scalar tail; each output
/// element accumulates its terms in ascending row order with the same
/// per-row `x[i] == 0` skip as the scalar reference, so the result is
/// bitwise identical to [`gemv_t_scalar_cols`] for every chunking.
///
/// `inline(always)` is load-bearing for the same reason as on
/// `gemm_panel_range`: the body must inline into the `target_feature`
/// wrappers so the lane chunks compile as AVX ops.
#[inline(always)]
#[allow(clippy::needless_range_loop)]
fn gemv_t_range<S: Scalar, const NR: usize>(
    a: &Mat<S>,
    x: &[S],
    s: usize,
    e: usize,
    chunk: &mut [S],
) {
    debug_assert_eq!(chunk.len(), e - s);
    let mut j = s;
    while j + NR <= e {
        let mut acc = [S::ZERO; NR];
        for (i, &xi) in x.iter().enumerate() {
            if xi == S::ZERO {
                continue;
            }
            let row: &[S; NR] = a.row(i)[j..j + NR]
                .try_into()
                .expect("column chunk is NR wide");
            for l in 0..NR {
                acc[l] += xi * row[l];
            }
        }
        chunk[j - s..j - s + NR].copy_from_slice(&acc);
        j += NR;
    }
    if j < e {
        let tail = &mut chunk[j - s..];
        tail.fill(S::ZERO);
        for (i, &xi) in x.iter().enumerate() {
            if xi == S::ZERO {
                continue;
            }
            let row = &a.row(i)[j..e];
            for (o, &v) in tail.iter_mut().zip(row) {
                *o += xi * v;
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn gemv_t_range_w8(a: &Mat, x: &[f64], s: usize, e: usize, chunk: &mut [f64]) {
    gemv_t_range::<f64, 8>(a, x, s, e, chunk)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn gemv_t_range_w4(a: &Mat, x: &[f64], s: usize, e: usize, chunk: &mut [f64]) {
    gemv_t_range::<f64, 4>(a, x, s, e, chunk)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn gemv_t_range_f32_w16(a: &Mat<f32>, x: &[f32], s: usize, e: usize, chunk: &mut [f32]) {
    gemv_t_range::<f32, 16>(a, x, s, e, chunk)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn gemv_t_range_f32_w8(a: &Mat<f32>, x: &[f32], s: usize, e: usize, chunk: &mut [f32]) {
    gemv_t_range::<f32, 8>(a, x, s, e, chunk)
}

/// Serial `chunk = (Aᵀ x)[s..e)` through the width-dispatched tiled
/// kernel — the per-chunk routine of the pooled transposed matvec and
/// the fleet's fused power iterations.
pub fn gemv_t_tiled_cols<S: Scalar>(a: &Mat<S>, x: &[S], s: usize, e: usize, chunk: &mut [S]) {
    S::dispatch_gemv_t(a, x, s, e, chunk)
}

/// Scalar reference for the transposed matvec stripe (the pre-kernel
/// inner loop, kept as the comparison baseline).
pub fn gemv_t_scalar_cols<S: Scalar>(a: &Mat<S>, x: &[S], s: usize, e: usize, chunk: &mut [S]) {
    debug_assert_eq!(chunk.len(), e - s);
    chunk.fill(S::ZERO);
    for (i, &xi) in x.iter().enumerate() {
        if xi == S::ZERO {
            continue;
        }
        let row = &a.row(i)[s..e];
        for (o, &v) in chunk.iter_mut().zip(row) {
            *o += xi * v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn sparse_mat(rng: &mut Rng, r: usize, c: usize, nnz: usize) -> Mat {
        let mut m = Mat::zeros(r, c);
        for i in rng.sample_indices(r * c, nnz.min(r * c)) {
            m.data_mut()[i] = rng.gauss();
        }
        m
    }

    #[test]
    fn lane_width_is_4_or_8_and_stable() {
        let w = lane_width();
        assert!(w == 4 || w == 8, "unexpected lane width {w}");
        assert_eq!(w, lane_width());
        assert_eq!(w, simd_level().lane_width());
    }

    #[test]
    fn f32_lane_width_doubles_f64() {
        assert_eq!(lane_width_of::<f32>(), 2 * lane_width_of::<f64>());
        assert_eq!(lane_width_of::<f64>(), lane_width());
        let w = lane_width_of::<f32>();
        assert!(w == 8 || w == 16, "unexpected f32 lane width {w}");
    }

    #[test]
    fn scalar_consts_and_conversions() {
        assert_eq!(f64::BYTES, 8);
        assert_eq!(f32::BYTES, 4);
        assert_eq!(f64::NAME, "f64");
        assert_eq!(f32::NAME, "f32");
        assert_eq!(f32::from_f64(1.5).to_f64(), 1.5);
        let lossy = f32::from_f64(0.1);
        assert!((lossy.to_f64() - 0.1).abs() < 1e-8);
        assert_ne!(lossy.to_f64(), 0.1); // quantization is real
    }

    #[test]
    fn pack_b_stripes_and_pads() {
        // 3×5 matrix packed at NR=4: two stripes, second padded.
        let b: Vec<f64> = (1..=15).map(|v| v as f64).collect();
        let mut buf = vec![-1.0; 2 * 3 * 4];
        pack_b::<f64, 4>(&b, 3, 5, &mut buf);
        // Stripe 0, k=0 holds b[0][0..4]; stripe 1, k=2 holds b[2][4] + pad
        // at offset (s·ktot + k)·NR = (3 + 2)·4.
        assert_eq!(&buf[0..4], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(&buf[(3 + 2) * 4..][..4], &[15.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn tiled_matches_scalar_across_edge_shapes() {
        let mut rng = Rng::new(901);
        // Lane remainders on both axes, sub-tile rows, narrow batches,
        // empty inner dimension.
        let shapes = [
            (12usize, 9usize, 8usize),
            (13, 7, 9),
            (4, 5, 4),
            (3, 6, 8),   // fewer rows than MR -> scalar fallback
            (17, 1, 5),  // k = 1
            (9, 4, 3),   // bcols below the tiled floor
            (5, 0, 6),   // empty k: output must be all zeros
            (21, 11, 17),
        ];
        for &(m, k, n) in &shapes {
            let a = sparse_mat(&mut rng, m, k, (m * k) / 2 + 1);
            let b = Mat::randn(k, n, &mut rng);
            let mut want = vec![0.0; m * n];
            gemm_scalar_rows(&a, b.data(), n, 0, m, &mut want);
            let mut got = vec![1.0; m * n];
            gemm_tiled_rows(&a, b.data(), n, 0, m, &mut got);
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    (g - w).abs() <= 1e-12 * (1.0 + w.abs()),
                    "({m},{k},{n}): {g} vs {w}"
                );
            }
        }
    }

    #[test]
    fn f32_tiled_matches_f32_scalar_across_edge_shapes() {
        let mut rng = Rng::new(905);
        // Same shape sweep as the f64 test, on the f32 monomorphization
        // (16-lane stripes on AVX-512 exercise wider remainders).
        let shapes = [
            (12usize, 9usize, 8usize),
            (13, 7, 9),
            (4, 5, 4),
            (3, 6, 8),
            (17, 1, 5),
            (9, 4, 3),
            (5, 0, 6),
            (21, 11, 17),
            (19, 6, 15), // bcols between the f64 and f32 stripe widths
        ];
        for &(m, k, n) in &shapes {
            let a = sparse_mat(&mut rng, m, k, (m * k) / 2 + 1).to_f32();
            let b = Mat::randn(k, n, &mut rng).to_f32();
            let mut want = vec![0.0f32; m * n];
            gemm_scalar_rows(&a, b.data(), n, 0, m, &mut want);
            let mut got = vec![1.0f32; m * n];
            gemm_tiled_rows(&a, b.data(), n, 0, m, &mut got);
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    (g - w).abs() <= 1e-4 * (1.0 + w.abs()),
                    "({m},{k},{n}): {g} vs {w}"
                );
            }
        }
    }

    #[test]
    fn tiled_chunked_at_tile_boundaries_is_bitwise_identical_to_full_range() {
        let mut rng = Rng::new(902);
        let (m, k, n) = (23usize, 14usize, 11usize);
        let a = sparse_mat(&mut rng, m, k, 150);
        let b = Mat::randn(k, n, &mut rng);
        let mut full = vec![0.0; m * n];
        gemm_tiled_rows(&a, b.data(), n, 0, m, &mut full);
        // Split at every MR boundary, as the pooled dispatcher does.
        for split_tile in 1..m.div_ceil(MR) {
            let mid = split_tile * MR;
            let mut lo = vec![0.0; mid * n];
            let mut hi = vec![0.0; (m - mid) * n];
            gemm_tiled_rows(&a, b.data(), n, 0, mid, &mut lo);
            gemm_tiled_rows(&a, b.data(), n, mid, m, &mut hi);
            let stitched: Vec<f64> = lo.into_iter().chain(hi).collect();
            for (s, f) in stitched.iter().zip(&full) {
                assert_eq!(s.to_bits(), f.to_bits(), "split at row {mid}");
            }
        }
    }

    #[test]
    fn f32_tiled_chunked_at_tile_boundaries_is_bitwise_identical() {
        let mut rng = Rng::new(906);
        let (m, k, n) = (23usize, 14usize, 11usize);
        let a = sparse_mat(&mut rng, m, k, 150).to_f32();
        let b = Mat::randn(k, n, &mut rng).to_f32();
        let mut full = vec![0.0f32; m * n];
        gemm_tiled_rows(&a, b.data(), n, 0, m, &mut full);
        for split_tile in 1..m.div_ceil(MR) {
            let mid = split_tile * MR;
            let mut lo = vec![0.0f32; mid * n];
            let mut hi = vec![0.0f32; (m - mid) * n];
            gemm_tiled_rows(&a, b.data(), n, 0, mid, &mut lo);
            gemm_tiled_rows(&a, b.data(), n, mid, m, &mut hi);
            let stitched: Vec<f32> = lo.into_iter().chain(hi).collect();
            for (s, f) in stitched.iter().zip(&full) {
                assert_eq!(s.to_bits(), f.to_bits(), "split at row {mid}");
            }
        }
    }

    #[test]
    fn gemv_t_tiled_matches_scalar_bitwise_for_any_stripe_split() {
        let mut rng = Rng::new(903);
        for &(m, n) in &[(15usize, 13usize), (40, 6), (7, 32), (9, 3)] {
            let a = Mat::randn(m, n, &mut rng);
            let mut x = rng.gauss_vec(m);
            x[0] = 0.0; // exercise the zero-skip
            let mut want = vec![0.0; n];
            gemv_t_scalar_cols(&a, &x, 0, n, &mut want);
            let mut got = vec![0.0; n];
            gemv_t_tiled_cols(&a, &x, 0, n, &mut got);
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w.to_bits(), "({m},{n})");
            }
            // Arbitrary column splits must not change a single bit.
            for split in 1..n {
                let mut lo = vec![0.0; split];
                let mut hi = vec![0.0; n - split];
                gemv_t_tiled_cols(&a, &x, 0, split, &mut lo);
                gemv_t_tiled_cols(&a, &x, split, n, &mut hi);
                let stitched: Vec<f64> = lo.into_iter().chain(hi).collect();
                for (s, w) in stitched.iter().zip(&want) {
                    assert_eq!(s.to_bits(), w.to_bits(), "split {split} ({m},{n})");
                }
            }
        }
    }

    #[test]
    fn f32_gemv_t_tiled_matches_scalar_bitwise() {
        let mut rng = Rng::new(907);
        for &(m, n) in &[(15usize, 13usize), (40, 6), (7, 32), (9, 3), (11, 21)] {
            let a = Mat::randn(m, n, &mut rng).to_f32();
            let x: Vec<f32> = rng.gauss_vec(m).iter().map(|&v| v as f32).collect();
            let mut want = vec![0.0f32; n];
            gemv_t_scalar_cols(&a, &x, 0, n, &mut want);
            let mut got = vec![0.0f32; n];
            gemv_t_tiled_cols(&a, &x, 0, n, &mut got);
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w.to_bits(), "({m},{n})");
            }
        }
    }

    #[test]
    fn pack_buffer_is_reused_across_calls() {
        let mut rng = Rng::new(904);
        let a = Mat::randn(16, 12, &mut rng);
        let b = Mat::randn(12, 8, &mut rng);
        let mut out = vec![0.0; 16 * 8];
        gemm_tiled_rows(&a, b.data(), 8, 0, 16, &mut out);
        let cap_after_warm = PACK_BUF.with(|c| c.borrow().capacity());
        for _ in 0..5 {
            gemm_tiled_rows(&a, b.data(), 8, 0, 16, &mut out);
        }
        let cap_after_reuse = PACK_BUF.with(|c| c.borrow().capacity());
        assert_eq!(cap_after_warm, cap_after_reuse, "pack buffer must not regrow");
    }

    /// Miri-scoped aliasing check (also a normal test): reusing the
    /// thread-local pack buffer across panels of different shapes must be
    /// sound — the second pack overwrites a live-capacity buffer sized for
    /// the first, which is exactly where a stale-length or provenance bug
    /// would surface under the interpreter. Kept tiny because Miri runs
    /// ~100× slower than native (`cargo +nightly miri test miri_`).
    #[test]
    fn miri_packed_panel_reuse_is_alias_clean() {
        let mut rng = Rng::new(908);
        for &(m, k, n) in &[(8usize, 6usize, 5usize), (5, 9, 3)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let mut want = vec![0.0; m * n];
            gemm_scalar_rows(&a, b.data(), n, 0, m, &mut want);
            let mut got = vec![0.0; m * n];
            gemm_tiled_rows(&a, b.data(), n, 0, m, &mut got);
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w.to_bits(), "({m},{k},{n})");
            }
        }
    }
}
