//! Ping-pong buffer arenas: zero-alloc steady-state apply.
//!
//! A multi-layer apply needs two scratch buffers (read one, write the
//! other, swap) sized `max_intermediate_dim × batch`. The arena owns both
//! and grows monotonically, so after the first call at a given size every
//! subsequent apply reuses the same heap blocks — the reuse/alloc counters
//! make that claim checkable from benches and metrics instead of folklore.
//!
//! Generic over the engine's [`Scalar`] element type (default `f64`), so
//! the f32 serving tier gets its own arenas at half the footprint — the
//! byte accounting below derives from `size_of::<S>()`, never a
//! hardcoded 8 (an f32 batch would otherwise be priced 2× too large by
//! the adaptive batcher and undersized).

#![forbid(unsafe_code)]

use super::kernel::Scalar;

/// Two reusable scratch buffers plus reuse accounting.
#[derive(Debug, Default)]
pub struct Arena<S = f64> {
    ping: Vec<S>,
    pong: Vec<S>,
    allocs: u64,
    reuses: u64,
}

impl<S: Scalar> Arena<S> {
    /// Empty arena; first acquire allocates.
    pub fn new() -> Self {
        Arena { ping: Vec::new(), pong: Vec::new(), allocs: 0, reuses: 0 }
    }

    /// Arena pre-sized for `n`-element scratch buffers.
    pub fn with_capacity(n: usize) -> Self {
        let mut a = Arena::new();
        a.reserve(n);
        a
    }

    /// Ensure both buffers hold at least `n` elements.
    fn reserve(&mut self, n: usize) {
        if self.ping.len() < n {
            self.ping.resize(n, S::ZERO);
            self.pong.resize(n, S::ZERO);
            self.allocs += 1;
        } else {
            self.reuses += 1;
        }
    }

    /// Borrow both scratch buffers at length `n`, growing if needed.
    /// Counts one reuse when the capacity was already sufficient.
    pub fn acquire(&mut self, n: usize) -> (&mut [S], &mut [S]) {
        self.reserve(n);
        (&mut self.ping[..n], &mut self.pong[..n])
    }

    /// Times `acquire` grew the buffers (1 in steady state per size step).
    pub fn allocs(&self) -> u64 {
        self.allocs
    }

    /// Times `acquire` was served without touching the heap.
    pub fn reuses(&self) -> u64 {
        self.reuses
    }

    /// Current per-buffer capacity in elements.
    pub fn capacity(&self) -> usize {
        self.ping.len()
    }

    /// Total heap footprint of the ping-pong pair in bytes — what the
    /// coordinator's adaptive batch sizing bounds when it caps a batch
    /// width (`2 buffers × size_of::<S>() × capacity`).
    pub fn footprint_bytes(&self) -> usize {
        2 * S::BYTES * self.capacity()
    }

    /// Footprint a scratch request of `n` elements would pin (the
    /// adaptive batcher checks this *before* sizing a batch, so the
    /// zero-alloc steady state is preserved by construction). For the
    /// element size of a specific *plan* rather than a monomorphized
    /// arena, use [`footprint_for_elem`].
    pub fn footprint_for(n: usize) -> usize {
        footprint_for_elem(n, S::BYTES)
    }
}

/// Ping-pong footprint of an `n`-element scratch request at a given
/// element size in bytes — the form the adaptive batcher uses, since it
/// prices plans whose precision is only known at runtime (via
/// [`super::CostProfile::elem_bytes`]).
pub fn footprint_for_elem(n: usize, elem_bytes: usize) -> usize {
    2 * elem_bytes * n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_then_reuses() {
        let mut a = Arena::<f64>::new();
        {
            let (p, q) = a.acquire(100);
            assert_eq!(p.len(), 100);
            assert_eq!(q.len(), 100);
        }
        assert_eq!(a.allocs(), 1);
        assert_eq!(a.reuses(), 0);
        for _ in 0..10 {
            let _ = a.acquire(100);
        }
        assert_eq!(a.allocs(), 1);
        assert_eq!(a.reuses(), 10);
        // Shrinking requests still reuse.
        let _ = a.acquire(10);
        assert_eq!(a.reuses(), 11);
        // Growth allocates again.
        let _ = a.acquire(500);
        assert_eq!(a.allocs(), 2);
        assert_eq!(a.capacity(), 500);
    }

    #[test]
    fn with_capacity_prewarms() {
        let mut a = Arena::<f64>::with_capacity(64);
        assert_eq!(a.allocs(), 1);
        let _ = a.acquire(64);
        assert_eq!(a.allocs(), 1);
        assert_eq!(a.reuses(), 1);
    }

    #[test]
    fn footprint_counts_both_buffers() {
        let mut a = Arena::<f64>::new();
        let _ = a.acquire(32);
        assert_eq!(a.footprint_bytes(), 2 * 8 * 32);
        assert_eq!(Arena::<f64>::footprint_for(32), a.footprint_bytes());
    }

    #[test]
    fn f32_footprint_is_half_of_f64() {
        let mut a = Arena::<f32>::new();
        let _ = a.acquire(32);
        assert_eq!(a.footprint_bytes(), 2 * 4 * 32);
        assert_eq!(Arena::<f32>::footprint_for(32), a.footprint_bytes());
        assert_eq!(
            2 * Arena::<f32>::footprint_for(32),
            Arena::<f64>::footprint_for(32)
        );
        assert_eq!(footprint_for_elem(32, 4), a.footprint_bytes());
    }

    #[test]
    fn buffers_are_disjoint() {
        let mut a = Arena::<f64>::new();
        let (p, q) = a.acquire(4);
        p[0] = 1.0;
        q[0] = 2.0;
        assert_eq!(p[0], 1.0);
        assert_eq!(q[0], 2.0);
    }

    /// Part of the miri-scoped suite (`cargo miri test miri_`): exercises
    /// the ping/pong `&mut` pair across grow, reuse, and shrink so the
    /// borrow pattern every apply leans on is checked under the aliasing
    /// model, not just the borrow checker.
    #[test]
    fn miri_arena_ping_pong_aliasing() {
        let mut a = Arena::<f64>::new();
        {
            let (p, q) = a.acquire(8);
            for i in 0..8 {
                p[i] = i as f64;
                q[i] = -(i as f64);
            }
            // Writes through one half must never show through the other.
            assert!(p.iter().zip(q.iter()).all(|(x, y)| *x == -*y));
        }
        // A shrinking acquire hands back prefixes of the same blocks.
        {
            let (p, q) = a.acquire(3);
            assert_eq!(p, &[0.0, 1.0, 2.0]);
            assert_eq!(q, &[-0.0, -1.0, -2.0]);
            std::mem::swap(&mut p[0], &mut q[0]);
        }
        // A growing acquire reallocates; old contents beyond the resize
        // boundary are preserved by `Vec::resize` semantics.
        let (p, q) = a.acquire(16);
        assert_eq!(p[1], 1.0);
        assert_eq!(q[1], -1.0);
        assert_eq!(p[8], 0.0);
        assert_eq!(q[15], 0.0);
        assert_eq!(a.allocs(), 2);
    }
}
