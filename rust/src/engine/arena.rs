//! Ping-pong buffer arenas: zero-alloc steady-state apply.
//!
//! A multi-layer apply needs two scratch buffers (read one, write the
//! other, swap) sized `max_intermediate_dim × batch`. The arena owns both
//! and grows monotonically, so after the first call at a given size every
//! subsequent apply reuses the same heap blocks — the reuse/alloc counters
//! make that claim checkable from benches and metrics instead of folklore.

/// Two reusable scratch buffers plus reuse accounting.
#[derive(Debug, Default)]
pub struct Arena {
    ping: Vec<f64>,
    pong: Vec<f64>,
    allocs: u64,
    reuses: u64,
}

impl Arena {
    /// Empty arena; first acquire allocates.
    pub fn new() -> Self {
        Arena::default()
    }

    /// Arena pre-sized for `n`-element scratch buffers.
    pub fn with_capacity(n: usize) -> Self {
        let mut a = Arena::new();
        a.reserve(n);
        a
    }

    /// Ensure both buffers hold at least `n` elements.
    fn reserve(&mut self, n: usize) {
        if self.ping.len() < n {
            self.ping.resize(n, 0.0);
            self.pong.resize(n, 0.0);
            self.allocs += 1;
        } else {
            self.reuses += 1;
        }
    }

    /// Borrow both scratch buffers at length `n`, growing if needed.
    /// Counts one reuse when the capacity was already sufficient.
    pub fn acquire(&mut self, n: usize) -> (&mut [f64], &mut [f64]) {
        self.reserve(n);
        (&mut self.ping[..n], &mut self.pong[..n])
    }

    /// Times `acquire` grew the buffers (1 in steady state per size step).
    pub fn allocs(&self) -> u64 {
        self.allocs
    }

    /// Times `acquire` was served without touching the heap.
    pub fn reuses(&self) -> u64 {
        self.reuses
    }

    /// Current per-buffer capacity in elements.
    pub fn capacity(&self) -> usize {
        self.ping.len()
    }

    /// Total heap footprint of the ping-pong pair in bytes — what the
    /// coordinator's adaptive batch sizing bounds when it caps a batch
    /// width (`2 buffers × 8 bytes × capacity`).
    pub fn footprint_bytes(&self) -> usize {
        16 * self.capacity()
    }

    /// Footprint a scratch request of `n` elements would pin (the
    /// adaptive batcher checks this *before* sizing a batch, so the
    /// zero-alloc steady state is preserved by construction).
    pub fn footprint_for(n: usize) -> usize {
        16 * n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_then_reuses() {
        let mut a = Arena::new();
        {
            let (p, q) = a.acquire(100);
            assert_eq!(p.len(), 100);
            assert_eq!(q.len(), 100);
        }
        assert_eq!(a.allocs(), 1);
        assert_eq!(a.reuses(), 0);
        for _ in 0..10 {
            let _ = a.acquire(100);
        }
        assert_eq!(a.allocs(), 1);
        assert_eq!(a.reuses(), 10);
        // Shrinking requests still reuse.
        let _ = a.acquire(10);
        assert_eq!(a.reuses(), 11);
        // Growth allocates again.
        let _ = a.acquire(500);
        assert_eq!(a.allocs(), 2);
        assert_eq!(a.capacity(), 500);
    }

    #[test]
    fn with_capacity_prewarms() {
        let mut a = Arena::with_capacity(64);
        assert_eq!(a.allocs(), 1);
        let _ = a.acquire(64);
        assert_eq!(a.allocs(), 1);
        assert_eq!(a.reuses(), 1);
    }

    #[test]
    fn footprint_counts_both_buffers() {
        let mut a = Arena::new();
        let _ = a.acquire(32);
        assert_eq!(a.footprint_bytes(), 2 * 8 * 32);
        assert_eq!(Arena::footprint_for(32), a.footprint_bytes());
    }

    #[test]
    fn buffers_are_disjoint() {
        let mut a = Arena::new();
        let (p, q) = a.acquire(4);
        p[0] = 1.0;
        q[0] = 2.0;
        assert_eq!(p[0], 1.0);
        assert_eq!(q[0], 2.0);
    }
}
