//! Synchronization shim: `std::sync` by default, `loom::sync` under the
//! `loom-model` feature.
//!
//! Every hand-rolled concurrency protocol in the crate — the pool's
//! [`Latch`](super::pool) and task queue, the coordinator's `JobQueue`
//! (work donation), and the server's shutdown stop-flag — imports its
//! `Mutex` / `Condvar` / atomics from here instead of `std::sync`. In the
//! default build these re-exports *are* the std types, so the production
//! binary is bitwise identical to a direct-std build (pinned by the
//! `sync_shim_*` regression tests). Under `--features loom-model` they
//! become the [loom](https://docs.rs/loom) versions, which lets the
//! `loom_*` tests exhaustively enumerate thread interleavings of those
//! protocols instead of sampling a handful at runtime.
//!
//! Two deliberate scope limits:
//!
//! - `std::thread` and `std::sync::Arc` are *not* shimmed. Threads in the
//!   loom tests come from `loom::thread` directly, and `Arc` is only used
//!   for reference counting (never as a synchronization protocol), so the
//!   production structs keep the std type under every build.
//! - `std::sync::mpsc` has no loom equivalent. The bounded channels in
//!   `coordinator::online` (observe/finish) and `server::conn` (FIFO
//!   response tickets) are therefore checked via loom *protocol models*:
//!   the same bounded-queue protocol rebuilt on the shim `Mutex`/`Condvar`
//!   in their `loom_tests` modules, rather than a type swap in production
//!   code.
//!
//! The `loom` dependency itself stays commented out in `Cargo.toml` so the
//! default build remains offline/zero-dependency; the CI `loom-model` job
//! uncomments it before testing (see `docs/OPERATIONS.md`).

#![forbid(unsafe_code)]

#[cfg(feature = "loom-model")]
pub(crate) use loom::sync::atomic::{AtomicBool, Ordering};
#[cfg(feature = "loom-model")]
pub(crate) use loom::sync::{Condvar, Mutex};

#[cfg(not(feature = "loom-model"))]
pub(crate) use std::sync::atomic::{AtomicBool, Ordering};
#[cfg(not(feature = "loom-model"))]
pub(crate) use std::sync::{Condvar, Mutex};
