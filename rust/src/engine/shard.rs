//! [`ShardSet`] — N independent worker pools behind one serving process
//! (ROADMAP item l).
//!
//! One [`ThreadPool`](super::ThreadPool) is the scaling ceiling of a
//! single coordinator: every operator's kernels contend for the same
//! workers. A `ShardSet` splits the process into N independent pools;
//! the coordinator's [`Registry`](crate::coordinator::Registry) pins
//! each operator to one shard at register time (cost-model balanced by
//! its plan's [`CostProfile`](super::CostProfile), rebalanced on
//! retire), the router dispatches each `(operator, class)` batch to its
//! owning shard's job queue, and idle shards steal whole flush jobs
//! from busy ones (work donation — see `coordinator`).
//!
//! **Why donation can never change results:** every engine kernel is
//! bitwise thread-invariant — a batch executed on shard k with t
//! threads equals the solo `ExecCtx` result bit-for-bit. Pinning,
//! rebalancing, and stealing therefore only move *where* the flops run,
//! never what they produce; the shard-invariance proptests in the
//! coordinator assert exactly this across shard counts {1, 2, 4}.

#![forbid(unsafe_code)]

use super::ThreadPool;
use std::sync::Arc;

/// A fixed set of independent engine pools, one per shard.
pub struct ShardSet {
    shards: Vec<Arc<ThreadPool>>,
}

impl ShardSet {
    /// Build `n_shards` independent pools of `threads_per_shard` threads
    /// each (both clamped to ≥ 1).
    pub fn new(n_shards: usize, threads_per_shard: usize) -> Self {
        let n = n_shards.max(1);
        ShardSet {
            shards: (0..n)
                .map(|_| Arc::new(ThreadPool::new(threads_per_shard.max(1))))
                .collect(),
        }
    }

    /// A one-shard set wrapping an existing pool — the seed path: a
    /// single-pool coordinator is exactly a `ShardSet` of one, with no
    /// operator rebinding and no donation.
    pub fn single(pool: Arc<ThreadPool>) -> Self {
        ShardSet { shards: vec![pool] }
    }

    /// Number of shards (≥ 1).
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    /// Never true — a `ShardSet` always has at least one shard.
    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// Shard `k`'s pool.
    pub fn pool(&self, k: usize) -> &Arc<ThreadPool> {
        &self.shards[k]
    }

    /// Total worker threads across all shards.
    pub fn threads_total(&self) -> usize {
        self.shards.iter().map(|p| p.n_threads()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::ApplyEngine;
    use crate::linalg::Mat;
    use crate::rng::Rng;
    use crate::transforms::hadamard_faust;

    #[test]
    fn construction_clamps_and_counts() {
        let s = ShardSet::new(0, 0);
        assert_eq!(s.len(), 1);
        assert!(!s.is_empty());
        assert_eq!(s.pool(0).n_threads(), 1);
        let s = ShardSet::new(3, 2);
        assert_eq!(s.len(), 3);
        assert_eq!(s.threads_total(), 6);
    }

    #[test]
    fn single_wraps_an_existing_pool() {
        let eng = ApplyEngine::with_threads(2);
        let s = ShardSet::single(eng.pool().clone());
        assert_eq!(s.len(), 1);
        assert!(Arc::ptr_eq(s.pool(0), eng.pool()));
    }

    #[test]
    fn rebound_op_is_bitwise_identical_on_every_shard() {
        // The contract the coordinator's shard placement relies on:
        // the same plan executed on any shard's pool (any thread count)
        // produces identical bits.
        let f = hadamard_faust(32);
        let eng = ApplyEngine::with_threads(1);
        let op = eng.op(&f);
        let shards = ShardSet::new(3, 2);
        let mut rng = Rng::new(0x5A4D);
        let x = Mat::randn(32, 5, &mut rng);
        let want = op.apply_batch(&x);
        for k in 0..shards.len() {
            let moved = op.on_pool(shards.pool(k).clone());
            let got = moved.apply_batch(&x);
            for (g, w) in got.data().iter().zip(want.data()) {
                assert_eq!(g.to_bits(), w.to_bits(), "shard {k} changed bits");
            }
            // Source factors ride along, so a rebound op stays persistable.
            assert!(moved.source().is_some());
        }
    }

    #[test]
    fn rebound_f32_op_keeps_plan_and_bound() {
        let f = hadamard_faust(16);
        let eng = ApplyEngine::with_threads(1);
        let op32 = eng.op(&f).to_f32();
        let shards = ShardSet::new(2, 2);
        let moved = op32.on_pool(shards.pool(1).clone());
        assert_eq!(
            moved.bound().declared_rel_err.to_bits(),
            op32.bound().declared_rel_err.to_bits()
        );
        let mut rng = Rng::new(0x5A4E);
        let x = Mat::randn(16, 3, &mut rng);
        let (a, b) = (op32.apply_batch(&x), moved.apply_batch(&x));
        for (g, w) in a.data().iter().zip(b.data()) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }
}
