//! Parallel apply engine: the execution layer between [`crate::faust`] and
//! the [`crate::coordinator`].
//!
//! The paper's value proposition is that a FAμST applies in `O(s_tot)`
//! instead of `O(mn)`; this module is what turns that flop count into
//! wall-clock. Three parts:
//!
//! - [`plan`] — [`ApplyPlan`], compiled once per operator by a flop/byte
//!   cost model: per-factor CSR-vs-dense strategy, fusion of adjacent tiny
//!   factors, transpose-aware kernel materialization, λ folding.
//! - [`pool`] — [`ThreadPool`], a `std::thread` chunked worker pool with
//!   row-partitioned parallel `spmv`/`spmm`/GEMM, shared by the engine and
//!   the coordinator's batch workers.
//! - [`kernel`] — the SIMD-width-aware dense microkernels every dense
//!   GEMM path bottoms out in: fixed MR×NR register tiles over packed,
//!   lane-width-aligned `B` panels (explicit f64 lane chunks of 4/8,
//!   runtime-selected once per process), with absolute tile blocking so
//!   results are bitwise identical across thread counts and across the
//!   solo/fleet dispatch routes.
//! - [`arena`] — [`Arena`], ping-pong scratch buffers sized from the
//!   plan's max intermediate dimension, so steady-state applies perform
//!   zero heap allocations (checkable via [`EngineMetricsSnapshot`]).
//! - [`ctx`] — [`ExecCtx`], the same pool + cost model packaged for the
//!   *training* side: cost-dispatched dense GEMM and pooled spectral
//!   norms consumed by `palm4msa`, `hierarchical`, and `dictlearn`
//!   (see the module's "how execution flows" diagram). The engine is the
//!   repo's single execution substrate — serving and factorization share
//!   one pool via [`ApplyEngine::ctx`].
//! - [`fleet`] — [`FleetCtx`], cross-operator batched execution: the
//!   small independent GEMMs / power iterations / projections of many
//!   *concurrent* factorization problems fuse into operator-granular
//!   pool dispatches when the cost model says N solo dispatches would
//!   leave the pool idle. Drives `palm4msa_fleet` /
//!   `hierarchical::factorize_fleet` and the registry's
//!   `refactorize_fleet` (fleets of operators behind one service).
//! - [`shard`] — [`ShardSet`], N independent pools behind one
//!   coordinator (`serve --shards N`): the registry pins each operator
//!   to a shard by its plan's [`CostProfile`] and idle shards steal
//!   whole flush jobs; bitwise identical to one pool because every
//!   kernel here is thread-invariant.
//!
//! The plan/kernel/pool/arena stack is generic over the serving scalar
//! ([`Scalar`]: `f64` master, `f32` tier with doubled SIMD lanes) — the
//! coordinator's precision policy picks which generation serves, the
//! engine just compiles and runs both.
//!
//! [`ApplyEngine`] owns a pool + config and compiles plans;
//! [`EngineOp`] bundles plan + pool + metrics into a servable operator
//! (it implements the coordinator's `BatchOp`), drawing scratch from a
//! per-thread arena so concurrent callers never serialize on a lock.
//! Each plan also exposes a [`CostProfile`] (flops/bytes per column +
//! fixed per-batch operand traffic) that the coordinator's adaptive
//! batcher sizes per-operator batches from.
//!
//! **Architecture** (the deployment end to end): `plan` → `kernel` →
//! `pool` → `shard` → `arena` → `coordinator::batcher` →
//! `coordinator::Registry` → `server::admission` → `server::wire` →
//! `store` → `coordinator::online` — the engine compiles and executes,
//! the coordinator decides *when* (batch sizing) and *what* (live
//! operator registry, precision, online learning) to execute. The
//! layer-by-layer map with paper-section and PR cross-references lives
//! in `docs/ARCHITECTURE.md`.
//!
//! **Paper map:** this layer realizes §II's Relative Complexity Gain as
//! wall-clock — `faust bench engine_scaling` measures it; the fig6
//! (Hadamard §IV-C), fig8 (MEG §V) and fig12 (denoising §VI) benches all
//! apply their operators through plans compiled here.

pub mod arena;
pub mod ctx;
pub mod fleet;
pub mod kernel;
pub mod plan;
pub mod pool;
pub mod shard;
pub(crate) mod sync;

pub use arena::{footprint_for_elem, Arena};
pub use ctx::ExecCtx;
pub use fleet::{FleetConfig, FleetCtx, FleetMetricsSnapshot};
pub use kernel::{Scalar, SimdLevel};
pub use plan::{ApplyPlan, CostProfile, F32Bound, PlanConfig, Stage, StageKernel};
pub use pool::{
    par_gemm_into, par_gemv_into, par_gemv_t_into, par_map_jobs, par_spmm_into,
    par_spmv_into, ThreadPool,
};
pub use shard::ShardSet;

use crate::faust::Faust;
use crate::linalg::Mat;
use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

thread_local! {
    /// Per-thread reusable scratch: concurrent applies (e.g. coordinator
    /// workers sharing one [`EngineOp`]) never serialize on a lock, and
    /// each thread's buffers stay warm across calls.
    static THREAD_ARENA: RefCell<Arena> = RefCell::new(Arena::new());
    /// f32 twin of [`THREAD_ARENA`]: the f32 serving tier keeps separate
    /// per-thread scratch so mixed-precision workers never thrash one
    /// buffer between element types.
    static THREAD_ARENA_F32: RefCell<Arena<f32>> = RefCell::new(Arena::new());
}

/// Run `f` with this thread's reusable scratch arena.
pub fn with_thread_arena<R>(f: impl FnOnce(&mut Arena) -> R) -> R {
    THREAD_ARENA.with(|a| f(&mut a.borrow_mut()))
}

/// Run `f` with this thread's reusable f32 scratch arena.
pub fn with_thread_arena_f32<R>(f: impl FnOnce(&mut Arena<f32>) -> R) -> R {
    THREAD_ARENA_F32.with(|a| f(&mut a.borrow_mut()))
}

/// Engine configuration: thread count + plan tuning.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Threads participating in each apply (1 = inline serial).
    pub n_threads: usize,
    /// Plan-compilation knobs.
    pub plan: PlanConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { n_threads: 1, plan: PlanConfig::default() }
    }
}

/// Lock-free engine counters (shared by every op of one engine).
#[derive(Default)]
pub struct EngineMetrics {
    plans_compiled: AtomicU64,
    applies: AtomicU64,
    arena_allocs: AtomicU64,
    arena_reuses: AtomicU64,
}

/// Point-in-time copy of [`EngineMetrics`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct EngineMetricsSnapshot {
    pub plans_compiled: u64,
    pub applies: u64,
    /// Times an apply had to grow its arena (≤ a handful ever, in steady
    /// state 0 per apply — the "zero-alloc hot loop" claim, measured).
    pub arena_allocs: u64,
    /// Applies served entirely from pre-allocated arena buffers.
    pub arena_reuses: u64,
}

impl EngineMetrics {
    fn snapshot(&self) -> EngineMetricsSnapshot {
        EngineMetricsSnapshot {
            plans_compiled: self.plans_compiled.load(Ordering::Relaxed),
            applies: self.applies.load(Ordering::Relaxed),
            arena_allocs: self.arena_allocs.load(Ordering::Relaxed),
            arena_reuses: self.arena_reuses.load(Ordering::Relaxed),
        }
    }
}

/// The apply engine: a worker pool + plan compiler.
pub struct ApplyEngine {
    pool: Arc<ThreadPool>,
    cfg: EngineConfig,
    metrics: Arc<EngineMetrics>,
}

impl ApplyEngine {
    pub fn new(cfg: EngineConfig) -> Self {
        ApplyEngine {
            pool: Arc::new(ThreadPool::new(cfg.n_threads)),
            cfg,
            metrics: Arc::new(EngineMetrics::default()),
        }
    }

    /// Engine with `n` threads and default plan config.
    pub fn with_threads(n: usize) -> Self {
        Self::new(EngineConfig { n_threads: n, ..EngineConfig::default() })
    }

    /// Inline serial engine (no workers).
    pub fn serial() -> Self {
        Self::with_threads(1)
    }

    pub fn n_threads(&self) -> usize {
        self.pool.n_threads()
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// The engine's shared worker pool.
    pub fn pool(&self) -> &Arc<ThreadPool> {
        &self.pool
    }

    /// An [`ExecCtx`] sharing this engine's pool and cost-model weight:
    /// on-line refactorization runs on the same threads that serve
    /// applies, so a deployment needs exactly one pool.
    ///
    /// ```
    /// use faust::engine::ApplyEngine;
    /// use faust::hierarchical::{factorize_with_ctx, HierarchicalConfig};
    ///
    /// let engine = ApplyEngine::with_threads(2);
    /// let ctx = engine.ctx();
    /// // Same pool: factorization and serving share the worker threads.
    /// assert!(std::sync::Arc::ptr_eq(ctx.pool(), engine.pool()));
    ///
    /// // Factorize on the serving threads, then serve the result.
    /// let h = faust::transforms::hadamard(8);
    /// let f = factorize_with_ctx(&ctx, &h, &HierarchicalConfig::hadamard(8));
    /// let op = engine.op(&f);
    /// let x = [1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
    /// let (y, want) = (op.apply(&x), h.matvec(&x));
    /// for i in 0..8 {
    ///     assert!((y[i] - want[i]).abs() < 1e-5);
    /// }
    /// ```
    pub fn ctx(&self) -> ExecCtx {
        ExecCtx::from_pool(self.pool.clone(), self.cfg.plan.bytes_per_flop_weight)
    }

    /// Compile an execution plan for `faust` under this engine's config.
    pub fn plan(&self, faust: &Faust) -> ApplyPlan {
        self.metrics.plans_compiled.fetch_add(1, Ordering::Relaxed);
        ApplyPlan::compile(faust, &self.cfg.plan)
    }

    /// Build a servable planned operator: plan + pool + pre-warmed arena.
    pub fn op(&self, faust: &Faust) -> EngineOp {
        self.op_batch_hint(faust, 1)
    }

    /// Like [`ApplyEngine::op`] with the calling thread's arena pre-sized
    /// for batches of `batch_hint` columns (its first apply is already
    /// allocation-free; other threads warm up on their first call).
    pub fn op_batch_hint(&self, faust: &Faust, batch_hint: usize) -> EngineOp {
        let plan = Arc::new(self.plan(faust));
        with_thread_arena(|a| {
            a.acquire(plan.scratch_len(batch_hint));
        });
        EngineOp {
            plan,
            source: Some(Arc::new(faust.clone())),
            pool: self.pool.clone(),
            metrics: self.metrics.clone(),
        }
    }

    /// Wrap an already-compiled plan as a servable op on this engine's
    /// pool (no recompilation — for plans cached elsewhere, e.g.
    /// [`Faust::plan`]). Carries no source factors, so it is not
    /// persistable (see [`EngineOp::source`]).
    pub fn op_from_plan(&self, plan: Arc<ApplyPlan>) -> EngineOp {
        EngineOp { plan, source: None, pool: self.pool.clone(), metrics: self.metrics.clone() }
    }

    /// Wrap an already-quantized f32 plan and its calibrated bound as a
    /// servable op (no re-quantization, no fresh probe — for cached
    /// conversions, e.g. [`Faust::plan_f32`]).
    pub fn op_f32(&self, plan: Arc<ApplyPlan<f32>>, bound: F32Bound) -> EngineOpF32 {
        EngineOpF32 { plan, bound, pool: self.pool.clone(), metrics: self.metrics.clone() }
    }

    /// Engine-wide metrics snapshot (covers all ops of this engine).
    pub fn metrics(&self) -> EngineMetricsSnapshot {
        self.metrics.snapshot()
    }
}

/// A planned, pooled operator ready for serving. Scratch comes from the
/// per-thread arena, so concurrent callers run fully in parallel.
pub struct EngineOp {
    plan: Arc<ApplyPlan>,
    /// The factors this plan was compiled from, when the op was built
    /// through [`ApplyEngine::op`]/[`ApplyEngine::op_batch_hint`] — what
    /// `Registry::persist_all` snapshots to disk.
    source: Option<Arc<Faust>>,
    pool: Arc<ThreadPool>,
    metrics: Arc<EngineMetrics>,
}

impl EngineOp {
    pub fn plan(&self) -> &ApplyPlan {
        &self.plan
    }

    /// The learned FAμST behind this op, if it retains one (built from
    /// factors rather than a bare plan) — the durable-store source.
    pub fn source(&self) -> Option<&Arc<Faust>> {
        self.source.as_ref()
    }

    /// The same compiled plan, served from a different pool — the shard
    /// placement path ([`ShardSet`]). Every kernel is bitwise
    /// thread-invariant, so results are identical on any pool; only
    /// *which threads* do the work changes.
    pub fn on_pool(&self, pool: Arc<ThreadPool>) -> EngineOp {
        EngineOp {
            plan: self.plan.clone(),
            source: self.source.clone(),
            pool,
            metrics: self.metrics.clone(),
        }
    }

    pub fn rows(&self) -> usize {
        self.plan.rows()
    }

    pub fn cols(&self) -> usize {
        self.plan.cols()
    }

    fn with_arena<R>(&self, f: impl FnOnce(&ThreadPool, &mut Arena) -> R) -> R {
        with_thread_arena(|arena| {
            let (a0, r0) = (arena.allocs(), arena.reuses());
            let out = f(&self.pool, arena);
            self.metrics.applies.fetch_add(1, Ordering::Relaxed);
            self.metrics
                .arena_allocs
                .fetch_add(arena.allocs() - a0, Ordering::Relaxed);
            self.metrics
                .arena_reuses
                .fetch_add(arena.reuses() - r0, Ordering::Relaxed);
            out
        })
    }

    /// `out = λ·S_J⋯S_1·x` for a row-major column-batch; zero heap
    /// allocations once the arena is warm.
    pub fn apply_batch_into(&self, x: &Mat, out: &mut Mat) {
        assert_eq!(x.rows(), self.cols(), "engine op: x rows mismatch");
        assert_eq!(out.shape(), (self.rows(), x.cols()), "engine op: out shape mismatch");
        let bcols = x.cols();
        self.with_arena(|pool, arena| {
            self.plan
                .execute_batch_into(pool, arena, x.data(), bcols, out.data_mut());
        });
    }

    /// Allocating batch apply.
    pub fn apply_batch(&self, x: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows(), x.cols());
        self.apply_batch_into(x, &mut out);
        out
    }

    /// Transpose batch apply into a caller buffer.
    pub fn apply_t_batch_into(&self, x: &Mat, out: &mut Mat) {
        assert_eq!(x.rows(), self.rows(), "engine op: x rows mismatch (t)");
        assert_eq!(out.shape(), (self.cols(), x.cols()), "engine op: out shape mismatch (t)");
        let bcols = x.cols();
        self.with_arena(|pool, arena| {
            self.plan
                .execute_t_batch_into(pool, arena, x.data(), bcols, out.data_mut());
        });
    }

    /// Allocating transpose batch apply.
    pub fn apply_t_batch(&self, x: &Mat) -> Mat {
        let mut out = Mat::zeros(self.cols(), x.cols());
        self.apply_t_batch_into(x, &mut out);
        out
    }

    /// Single-vector apply.
    pub fn apply(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols(), "engine op: apply dim mismatch");
        let mut y = vec![0.0; self.rows()];
        self.with_arena(|pool, arena| self.plan.execute_into(pool, arena, x, &mut y));
        y
    }

    /// Single-vector transpose apply.
    pub fn apply_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows(), "engine op: apply_t dim mismatch");
        let mut y = vec![0.0; self.cols()];
        self.with_arena(|pool, arena| self.plan.execute_t_into(pool, arena, x, &mut y));
        y
    }

    /// Flops of one planned matvec (for serving metrics).
    pub fn flops_per_matvec(&self) -> usize {
        self.plan.planned_flops()
    }

    /// The plan's flop/byte [`CostProfile`] — what the coordinator's
    /// adaptive batcher sizes this operator's batches from.
    pub fn profile(&self) -> CostProfile {
        self.plan.profile()
    }

    /// Metrics of the engine this op belongs to.
    pub fn metrics(&self) -> EngineMetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Quantized f32 serving twin of this op: same pool and engine
    /// metrics, plan converted via [`ApplyPlan::to_f32_with_bound`] (so
    /// the returned op carries its calibrated error bound). The f64 op
    /// is untouched — precision is a per-generation serving choice, not
    /// a property of the operator.
    pub fn to_f32(&self) -> EngineOpF32 {
        let (plan32, bound) = self.plan.to_f32_with_bound(&self.pool);
        EngineOpF32 {
            plan: Arc::new(plan32),
            bound,
            pool: self.pool.clone(),
            metrics: self.metrics.clone(),
        }
    }

    /// Like [`EngineOp::to_f32`] but installing a previously-measured
    /// bound instead of re-probing — the warm-restart path
    /// ([`crate::store`] persists the bound alongside the factors).
    pub fn to_f32_with_stored_bound(&self, bound: F32Bound) -> EngineOpF32 {
        EngineOpF32 {
            plan: Arc::new(self.plan.to_f32()),
            bound,
            pool: self.pool.clone(),
            metrics: self.metrics.clone(),
        }
    }
}

/// The f32 serving tier of an [`EngineOp`]: a quantized plan plus its
/// calibrated [`F32Bound`]. Inputs/outputs stay `f64` at the API edge —
/// the op quantizes the batch on entry and widens on exit, so callers
/// (coordinator workers, wire handlers) are precision-agnostic; only the
/// chain arithmetic, operand storage, and arena scratch are f32.
pub struct EngineOpF32 {
    plan: Arc<ApplyPlan<f32>>,
    bound: F32Bound,
    pool: Arc<ThreadPool>,
    metrics: Arc<EngineMetrics>,
}

impl EngineOpF32 {
    pub fn plan(&self) -> &ApplyPlan<f32> {
        &self.plan
    }

    pub fn rows(&self) -> usize {
        self.plan.rows()
    }

    pub fn cols(&self) -> usize {
        self.plan.cols()
    }

    /// The probe-calibrated f32-vs-f64 error bound measured at
    /// conversion time ("measured at swap" — the registry converts when
    /// a generation is registered or swapped in).
    pub fn bound(&self) -> F32Bound {
        self.bound
    }

    /// The same quantized plan + bound, served from a different pool —
    /// the f32 twin of [`EngineOp::on_pool`] (bitwise-invariant kernels,
    /// so shard placement never changes results).
    pub fn on_pool(&self, pool: Arc<ThreadPool>) -> EngineOpF32 {
        EngineOpF32 {
            plan: self.plan.clone(),
            bound: self.bound,
            pool,
            metrics: self.metrics.clone(),
        }
    }

    /// Batch apply with f64 edges: quantize → f32 chain → widen.
    pub fn apply_batch(&self, x: &Mat) -> Mat {
        assert_eq!(x.rows(), self.cols(), "engine op f32: x rows mismatch");
        let bcols = x.cols();
        let x32: Vec<f32> = x.data().iter().map(|&v| v as f32).collect();
        let mut y32 = vec![0.0f32; self.rows() * bcols];
        with_thread_arena_f32(|arena| {
            let (a0, r0) = (arena.allocs(), arena.reuses());
            self.plan
                .execute_batch_into(&self.pool, arena, &x32, bcols, &mut y32);
            self.metrics.applies.fetch_add(1, Ordering::Relaxed);
            self.metrics
                .arena_allocs
                .fetch_add(arena.allocs() - a0, Ordering::Relaxed);
            self.metrics
                .arena_reuses
                .fetch_add(arena.reuses() - r0, Ordering::Relaxed);
        });
        let mut out = Mat::zeros(self.rows(), bcols);
        for (o, &v) in out.data_mut().iter_mut().zip(&y32) {
            *o = v as f64;
        }
        out
    }

    /// Single-vector apply with f64 edges.
    pub fn apply(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols(), "engine op f32: apply dim mismatch");
        let x32: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let mut y32 = vec![0.0f32; self.rows()];
        with_thread_arena_f32(|arena| {
            self.plan.execute_into(&self.pool, arena, &x32, &mut y32);
            self.metrics.applies.fetch_add(1, Ordering::Relaxed);
        });
        y32.iter().map(|&v| v as f64).collect()
    }

    /// Flops of one planned matvec (same chain structure as the f64 op).
    pub fn flops_per_matvec(&self) -> usize {
        self.plan.planned_flops()
    }

    /// The f32 plan's [`CostProfile`] (`elem_bytes = 4`, f32 lane width)
    /// — the adaptive batcher prices f32 generations from this, halving
    /// the arena footprint per batch column vs the f64 profile.
    pub fn profile(&self) -> CostProfile {
        self.plan.profile()
    }
}

/// Process-wide shared engine: threads from `FAUST_THREADS` (default:
/// available parallelism, capped at 8). [`Faust::apply`] and friends route
/// their kernels through this pool; small operators still run inline
/// because the pool only splits work above its per-chunk grain.
pub fn global() -> &'static ApplyEngine {
    static GLOBAL: OnceLock<ApplyEngine> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let threads = std::env::var("FAUST_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
                    .min(8)
            });
        ApplyEngine::with_threads(threads)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::transforms::{hadamard, hadamard_faust};

    #[test]
    fn engine_op_matches_faust_apply() {
        let n = 32;
        let f = hadamard_faust(n);
        let h = hadamard(n);
        let eng = ApplyEngine::with_threads(4);
        let op = eng.op(&f);
        let mut rng = Rng::new(601);
        let x = rng.gauss_vec(n);
        let y = op.apply(&x);
        let want = h.matvec(&x);
        for (g, w) in y.iter().zip(&want) {
            assert!((g - w).abs() < 1e-10);
        }
        let yt = op.apply_t(&x);
        let want_t = h.matvec_t(&x);
        for (g, w) in yt.iter().zip(&want_t) {
            assert!((g - w).abs() < 1e-10);
        }
    }

    #[test]
    fn engine_op_batch_matches_columns() {
        let n = 16;
        let f = hadamard_faust(n);
        let eng = ApplyEngine::with_threads(2);
        let op = eng.op(&f);
        let mut rng = Rng::new(602);
        let x = Mat::randn(n, 7, &mut rng);
        let y = op.apply_batch(&x);
        for j in 0..7 {
            let ycol = op.apply(&x.col(j));
            for i in 0..n {
                assert!((y.at(i, j) - ycol[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn steady_state_applies_do_not_allocate() {
        let f = hadamard_faust(64);
        let eng = ApplyEngine::with_threads(2);
        let op = eng.op_batch_hint(&f, 8);
        let mut rng = Rng::new(603);
        let x = Mat::randn(64, 8, &mut rng);
        let mut out = Mat::zeros(64, 8);
        for _ in 0..20 {
            op.apply_batch_into(&x, &mut out);
        }
        let m = op.metrics();
        assert_eq!(m.applies, 20);
        assert_eq!(m.arena_allocs, 0, "arena was pre-warmed; no allocs allowed");
        assert_eq!(m.arena_reuses, 20);
    }

    #[test]
    fn metrics_count_plans_and_applies() {
        let f = hadamard_faust(8);
        let eng = ApplyEngine::serial();
        let op = eng.op(&f);
        let mut rng = Rng::new(604);
        let x = rng.gauss_vec(8);
        let _ = op.apply(&x);
        let _ = op.apply(&x);
        let snap = eng.metrics();
        assert_eq!(snap.plans_compiled, 1);
        assert_eq!(snap.applies, 2);
    }

    #[test]
    fn global_engine_is_usable() {
        let eng = global();
        assert!(eng.n_threads() >= 1);
        let f = hadamard_faust(8);
        let op = eng.op(&f);
        let y = op.apply(&[1.0; 8]);
        assert_eq!(y.len(), 8);
    }

    #[test]
    fn f32_op_matches_f64_within_bound_and_counts_applies() {
        let n = 64;
        let f = hadamard_faust(n);
        let eng = ApplyEngine::with_threads(2);
        let op = eng.op(&f);
        let op32 = op.to_f32();
        assert_eq!((op32.rows(), op32.cols()), (n, n));
        assert_eq!(op32.profile().elem_bytes, 4);
        let mut rng = Rng::new(605);
        let x = Mat::randn(n, 5, &mut rng);
        let y64 = op.apply_batch(&x);
        let y32 = op32.apply_batch(&x);
        let (mut err2, mut ref2) = (0.0f64, 0.0f64);
        for (a, b) in y32.data().iter().zip(y64.data()) {
            err2 += (a - b) * (a - b);
            ref2 += b * b;
        }
        let rel = (err2 / ref2.max(1e-300)).sqrt();
        assert!(rel <= op32.bound().declared_rel_err, "rel={rel:e}");
        // f32 applies land in the shared engine counters.
        assert!(eng.metrics().applies >= 2);
    }

    #[test]
    fn engine_op_is_shareable_across_threads() {
        let f = hadamard_faust(32);
        let h = hadamard(32);
        let eng = ApplyEngine::with_threads(4);
        let op = Arc::new(eng.op(&f));
        let h = Arc::new(h);
        let mut handles = vec![];
        for t in 0..4u64 {
            let op = op.clone();
            let h = h.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(700 + t);
                for _ in 0..25 {
                    let x = rng.gauss_vec(32);
                    let y = op.apply(&x);
                    let want = h.matvec(&x);
                    for (g, w) in y.iter().zip(&want) {
                        assert!((g - w).abs() < 1e-10);
                    }
                }
            }));
        }
        for hd in handles {
            hd.join().unwrap();
        }
    }
}
