//! Cost-modeled execution plans for multi-layer apply.
//!
//! A [`ApplyPlan`] is compiled once per operator and reused for every
//! apply. Compilation does three things the naive per-factor CSR chain
//! cannot:
//!
//! 1. **Strategy selection** — a flop/byte cost model (`flops + β·bytes`)
//!    scores each factor as CSR spmm vs dense GEMM; a factor runs dense
//!    when it clears the density threshold *and* the model prices the
//!    dense pass cheaper (regular access beats index-chasing once most
//!    entries are filled — with the default β = 0.25 the crossover sits
//!    near density 0.8, and raising β pushes it lower).
//! 2. **Fusion** — adjacent *tiny* factors are multiplied out at plan time
//!    (sparse `spgemm`) when the precomputed product strictly reduces
//!    total apply flops; the classic case is a chain of small residual
//!    factors left over from hierarchical factorization.
//! 3. **Transpose-aware compilation** — on first transpose apply the
//!    chain is materialized as transposed kernels (lazily, so
//!    forward-only operators pay nothing), making `apply_t` the same
//!    row-parallel, output-partitioned code path as `apply` instead of a
//!    scatter.
//!
//! λ is folded into the last stage at compile time, removing the final
//! scale pass from the hot loop.
//!
//! **Precision tier (ROADMAP item j).** Plans are generic over the
//! engine's [`Scalar`] element type. Compilation always happens at `f64`
//! ([`ApplyPlan::compile`]); the f32 serving tier is derived from a
//! compiled f64 plan by [`ApplyPlan::to_f32_with_bound`], which
//! quantizes every stage operand *once* (post-fusion, post-λ-fold, so
//! the f32 chain is structurally identical) and calibrates an
//! [`F32Bound`] — the measured f32-vs-f64 relative error on a
//! deterministic probe batch plus the declared (headroom-padded) bound
//! the registry's accuracy budget and the proptests check against.

#![forbid(unsafe_code)]

use super::arena::Arena;
use super::kernel::Scalar;
use super::pool::{par_gemm_into, par_spmm_into, ThreadPool};
use crate::faust::Faust;
use crate::linalg::Mat;
use crate::sparse::Csr;
use std::sync::{Arc, OnceLock};

/// Tuning knobs for plan compilation.
#[derive(Clone, Debug)]
pub struct PlanConfig {
    /// Density floor below which a factor always stays CSR; at or above
    /// it, the flop/byte cost model decides between CSR and dense GEMM.
    pub dense_threshold: f64,
    /// Attempt fusing adjacent factors when both sides are small enough.
    pub fuse: bool,
    /// Only factors with `nnz ≤ fuse_nnz_cap` are fusion candidates
    /// (keeps plan-time spgemm cheap and skips hopeless large pairs).
    pub fuse_nnz_cap: usize,
    /// β in the stage cost `flops + β·bytes` — how expensive a byte of
    /// memory traffic is relative to a flop on the target machine.
    pub bytes_per_flop_weight: f64,
}

impl Default for PlanConfig {
    fn default() -> Self {
        PlanConfig {
            dense_threshold: 0.25,
            fuse: true,
            fuse_nnz_cap: 8192,
            bytes_per_flop_weight: 0.25,
        }
    }
}

/// Kernel variant chosen for one stage.
#[derive(Clone, Debug)]
pub enum StageKernel<S = f64> {
    /// Row-parallel CSR spmm. Unfused factors share the owning
    /// [`Faust`]'s `Arc<Csr>` — compiling a plan for an already-sparse
    /// operator copies no factor data (fused products, transposed chains,
    /// λ-folded stages, and f32 serving copies own fresh allocations).
    Sparse(Arc<Csr<S>>),
    /// Row-parallel dense GEMM over the densified factor, executed on
    /// the register-tiled [`super::kernel`] microkernels.
    Dense(Mat<S>),
}

/// One executable layer of the plan (possibly several fused factors).
#[derive(Clone, Debug)]
pub struct Stage<S = f64> {
    kernel: StageKernel<S>,
    /// Half-open range of original factor indices covered (len > 1 ⇒
    /// fused). Indices refer to the rightmost-first factor order.
    factor_range: (usize, usize),
}

impl<S: Scalar> Stage<S> {
    pub fn rows(&self) -> usize {
        match &self.kernel {
            StageKernel::Sparse(s) => s.rows(),
            StageKernel::Dense(m) => m.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match &self.kernel {
            StageKernel::Sparse(s) => s.cols(),
            StageKernel::Dense(m) => m.cols(),
        }
    }

    /// Stored non-zeros (dense stages count every entry).
    pub fn nnz(&self) -> usize {
        match &self.kernel {
            StageKernel::Sparse(s) => s.nnz(),
            StageKernel::Dense(m) => m.rows() * m.cols(),
        }
    }

    /// Flops for one matvec through this stage.
    pub fn flops(&self) -> usize {
        2 * self.nnz()
    }

    pub fn is_dense(&self) -> bool {
        matches!(self.kernel, StageKernel::Dense(_))
    }

    pub fn is_fused(&self) -> bool {
        self.factor_range.1 - self.factor_range.0 > 1
    }

    pub fn factor_range(&self) -> (usize, usize) {
        self.factor_range
    }

    /// Execute: `out = K · input` with `input ∈ R^{cols×bcols}` row-major.
    fn run(&self, pool: &ThreadPool, input: &[S], bcols: usize, out: &mut [S]) {
        match &self.kernel {
            StageKernel::Sparse(s) => par_spmm_into(pool, s, input, bcols, out),
            StageKernel::Dense(m) => par_gemm_into(pool, m, input, bcols, out),
        }
    }

    /// Operand bytes streamed once per batch, independent of the batch
    /// width: the kernel's own storage (CSR vals + indices + row pointers,
    /// or the full dense block) at this stage's element size.
    pub fn operand_bytes(&self) -> usize {
        match &self.kernel {
            StageKernel::Sparse(s) => (S::BYTES + 4) * s.nnz() + 4 * (s.rows() + 1),
            StageKernel::Dense(m) => S::BYTES * m.rows() * m.cols(),
        }
    }

    /// Longest per-output-element accumulation through this stage (max
    /// row nnz for CSR, the full inner dimension for dense) — the term
    /// count the f32 error model's structural floor sums over.
    fn max_terms(&self) -> usize {
        match &self.kernel {
            StageKernel::Sparse(s) => (0..s.rows())
                .map(|r| (s.indptr[r + 1] - s.indptr[r]) as usize)
                .max()
                .unwrap_or(0),
            StageKernel::Dense(m) => m.cols(),
        }
    }

    /// Transposed copy of this stage (kernel materialized transposed).
    fn transposed(&self) -> Stage<S> {
        let kernel = match &self.kernel {
            StageKernel::Sparse(s) => StageKernel::Sparse(Arc::new(s.transpose())),
            StageKernel::Dense(m) => StageKernel::Dense(m.t()),
        };
        Stage { kernel, factor_range: self.factor_range }
    }
}

impl Stage {
    /// Cost-model score: `flops + β·bytes` (compile-time decisions are
    /// always made on the f64 master plan).
    fn cost(&self, beta: f64) -> f64 {
        match &self.kernel {
            StageKernel::Sparse(s) => sparse_cost(s.nnz(), s.rows(), s.cols(), beta),
            StageKernel::Dense(m) => dense_cost(m.rows(), m.cols(), beta),
        }
    }

    /// Quantized serving copy of this stage (fresh storage, never aliases
    /// the f64 factor).
    fn to_f32(&self) -> Stage<f32> {
        let kernel = match &self.kernel {
            StageKernel::Sparse(s) => StageKernel::Sparse(Arc::new(s.to_f32())),
            StageKernel::Dense(m) => StageKernel::Dense(m.to_f32()),
        };
        Stage { kernel, factor_range: self.factor_range }
    }

    fn scale(&mut self, s: f64) {
        match &mut self.kernel {
            // `make_mut` un-shares a stage that aliases a Faust factor, so
            // λ folding never mutates the operator's own CSR.
            StageKernel::Sparse(c) => Arc::make_mut(c).scale(s),
            StageKernel::Dense(m) => m.scale(s),
        }
    }
}

/// Modeled cost of one CSR spmv: flops + β · bytes touched
/// (vals f64 + col indices u32 per nnz, row pointers, in/out vectors).
fn sparse_cost(nnz: usize, rows: usize, cols: usize, beta: f64) -> f64 {
    let flops = 2 * nnz;
    let bytes = 12 * nnz + 4 * (rows + 1) + 8 * (rows + cols);
    flops as f64 + beta * bytes as f64
}

/// Modeled cost of one dense GEMV over the densified factor.
fn dense_cost(rows: usize, cols: usize, beta: f64) -> f64 {
    let flops = 2 * rows * cols;
    let bytes = 8 * rows * cols + 8 * (rows + cols);
    flops as f64 + beta * bytes as f64
}

/// Flop/byte profile of one compiled plan, split into the part that scales
/// with the batch width and the part that is paid once per batch.
///
/// Executing a `b`-column batch streams every stage operand once
/// (`fixed_bytes`, amortized over the batch) and does `b · flops_per_col`
/// arithmetic while moving `b · bytes_per_col` of vector data. The
/// coordinator's adaptive batcher sizes per-operator batches from exactly
/// this split (`coordinator::target_batch`): a FAμST with heavy factors
/// but cheap columns wants wide batches, a dense operator saturates early.
///
/// ```
/// use faust::engine::{ApplyPlan, PlanConfig};
/// let f = faust::transforms::hadamard_faust(16);
/// let p = ApplyPlan::compile(&f, &PlanConfig::default()).profile();
/// assert_eq!(p.flops_per_col, 2 * f.s_tot()); // butterflies never fuse
/// assert!(p.fixed_bytes > 0 && p.max_dim == 16);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CostProfile {
    /// Arithmetic per batch column (one matvec through the chain).
    pub flops_per_col: usize,
    /// Vector bytes moved per batch column: the input column plus every
    /// intermediate/output column written along the chain.
    pub bytes_per_col: usize,
    /// Operand bytes streamed once per batch regardless of width
    /// (the plan's fixed cost the batcher amortizes).
    pub fixed_bytes: usize,
    /// Largest intermediate dimension — ties a batch width to its arena
    /// ping-pong footprint (`2 · elem_bytes · max_dim · b` bytes).
    pub max_dim: usize,
    /// Lane-chunk width of the dense microkernels this profile's stages
    /// execute on at the plan's element type (f64: 4/8, f32: 8/16;
    /// runtime-selected once per process — see
    /// [`super::kernel::lane_width_of`]). Recorded so serving metrics
    /// and bench artifacts state which kernel build produced them.
    pub simd_lanes: usize,
    /// Bytes per scratch/vector element (8 for f64 plans, 4 for f32) —
    /// the adaptive batcher prices arena footprints with this instead of
    /// a hardcoded 8, so f32 batches are not overestimated 2×.
    pub elem_bytes: usize,
}

impl CostProfile {
    /// Model cost of one batch column: `flops + β·bytes`.
    pub fn col_cost(&self, beta: f64) -> f64 {
        self.flops_per_col as f64 + beta * self.bytes_per_col as f64
    }

    /// Model cost paid once per batch: `β·fixed_bytes`.
    pub fn fixed_cost(&self, beta: f64) -> f64 {
        beta * self.fixed_bytes as f64
    }

    /// Profile of a plain dense `rows×cols` GEMM operator (used by the
    /// coordinator for dense [`Mat`] operators that bypass the engine).
    pub fn dense(rows: usize, cols: usize) -> CostProfile {
        CostProfile {
            flops_per_col: 2 * rows * cols,
            bytes_per_col: 8 * (rows + cols),
            fixed_bytes: 8 * rows * cols,
            max_dim: rows.max(cols),
            simd_lanes: super::kernel::lane_width(),
            elem_bytes: 8,
        }
    }
}

/// Measured + declared f32-vs-f64 error bound of a quantized serving
/// plan, calibrated at conversion time by [`ApplyPlan::to_f32_with_bound`].
///
/// `measured_rel_err` is what the registry's `auto` accuracy budget
/// compares against (the honest probe number, reported in metrics);
/// `declared_rel_err` is the headroom-padded bound the proptests and the
/// in-bench assertion hold arbitrary inputs to.
#[derive(Clone, Copy, Debug, Default)]
pub struct F32Bound {
    /// Max per-column relative ℓ2 error observed on the deterministic
    /// gaussian probe batch (f32 output vs the f64 master plan).
    pub measured_rel_err: f64,
    /// Declared bound: `max(64 × measured, structural floor)` where the
    /// structural floor is `16 · ε_f32 · Σ_stages (max_terms + 1)` —
    /// covers near-exact probes (e.g. operators with exactly
    /// representable entries) without ever under-promising.
    pub declared_rel_err: f64,
}

/// Compiled execution plan for one FAμST operator.
#[derive(Clone, Debug)]
pub struct ApplyPlan<S = f64> {
    /// Forward chain, applied first-to-last (`stages[0]` consumes x).
    stages: Vec<Stage<S>>,
    /// Transpose chain, applied first-to-last (pre-transposed kernels),
    /// built lazily on the first transpose apply.
    t_stages: OnceLock<Vec<Stage<S>>>,
    rows: usize,
    cols: usize,
    /// Largest intermediate dimension (scratch sizing).
    max_dim: usize,
    lambda: f64,
    n_factors: usize,
    /// Flops of the naive per-factor CSR chain (2·s_tot).
    naive_flops: usize,
}

impl ApplyPlan {
    /// Compile a plan for `faust` under `cfg`.
    pub fn compile(faust: &Faust, cfg: &PlanConfig) -> ApplyPlan {
        let factors = faust.factors();
        // 1. Fusion pass (greedy, rightmost-first): precompute products of
        //    adjacent tiny factors when that strictly reduces apply flops.
        //    Unfused factors keep the Faust's own `Arc<Csr>` (zero-copy);
        //    only fused products allocate.
        let mut fused: Vec<(Arc<Csr>, (usize, usize))> =
            Vec::with_capacity(factors.len());
        let mut cur = factors[0].clone();
        let mut range = (0usize, 1usize);
        for (j, next) in factors.iter().enumerate().skip(1) {
            let candidate = cfg.fuse
                && cur.nnz() <= cfg.fuse_nnz_cap
                && next.nnz() <= cfg.fuse_nnz_cap;
            if candidate {
                // Chain order: `next` applies after `cur` ⇒ product next·cur.
                let product = next.spgemm(&cur);
                if product.nnz() < cur.nnz() + next.nnz() {
                    cur = Arc::new(product);
                    range.1 = j + 1;
                    continue;
                }
            }
            fused.push((cur, range));
            cur = next.clone();
            range = (j, j + 1);
        }
        fused.push((cur, range));

        // 2. Strategy selection: above the density floor, let the
        //    flop/byte model price CSR spmm against dense GEMM.
        let beta = cfg.bytes_per_flop_weight;
        let mut stages: Vec<Stage> = fused
            .into_iter()
            .map(|(csr, factor_range)| {
                let dense_wins = csr.density() >= cfg.dense_threshold
                    && dense_cost(csr.rows(), csr.cols(), beta)
                        <= sparse_cost(csr.nnz(), csr.rows(), csr.cols(), beta);
                let kernel = if dense_wins {
                    StageKernel::Dense(csr.to_dense())
                } else {
                    StageKernel::Sparse(csr)
                };
                Stage { kernel, factor_range }
            })
            .collect();

        // 3. Fold λ into the last stage (drops the scale pass at apply).
        let lambda = faust.lambda();
        if lambda != 1.0 {
            stages.last_mut().unwrap().scale(lambda);
        }

        let rows = faust.rows();
        let cols = faust.cols();
        let max_dim = stages
            .iter()
            .map(|s| s.rows().max(s.cols()))
            .max()
            .unwrap();
        ApplyPlan {
            stages,
            t_stages: OnceLock::new(),
            rows,
            cols,
            max_dim,
            lambda,
            n_factors: factors.len(),
            naive_flops: 2 * faust.s_tot(),
        }
    }

    /// Quantized f32 serving copy of this compiled plan. Structure is
    /// inherited verbatim — fusion, CSR/dense strategy, and the folded λ
    /// were all decided on the f64 master, so the f32 chain differs only
    /// in element type. Use [`ApplyPlan::to_f32_with_bound`] to also
    /// calibrate the error bound the serving tier needs.
    pub fn to_f32(&self) -> ApplyPlan<f32> {
        ApplyPlan {
            stages: self.stages.iter().map(Stage::to_f32).collect(),
            t_stages: OnceLock::new(),
            rows: self.rows,
            cols: self.cols,
            max_dim: self.max_dim,
            lambda: self.lambda,
            n_factors: self.n_factors,
            naive_flops: self.naive_flops,
        }
    }

    /// Quantize to f32 and calibrate the [`F32Bound`] by pushing a
    /// deterministic seeded gaussian probe batch through both plans and
    /// taking the worst per-column relative ℓ2 error. Both executions use
    /// `pool`, which is sound because plan outputs are bitwise
    /// thread-count-invariant within each scalar type.
    pub fn to_f32_with_bound(&self, pool: &ThreadPool) -> (ApplyPlan<f32>, F32Bound) {
        let plan32 = self.to_f32();
        const PROBE_COLS: usize = 8;
        let mut rng = crate::rng::Rng::new(0xF32B0021);
        let x64 = rng.gauss_vec(self.cols * PROBE_COLS);
        let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();

        let mut arena64 = Arena::<f64>::new();
        let mut y64 = vec![0.0f64; self.rows * PROBE_COLS];
        self.execute_batch_into(pool, &mut arena64, &x64, PROBE_COLS, &mut y64);

        let mut arena32 = Arena::<f32>::new();
        let mut y32 = vec![0.0f32; self.rows * PROBE_COLS];
        plan32.execute_batch_into(pool, &mut arena32, &x32, PROBE_COLS, &mut y32);

        // Worst per-column relative ℓ2 error (row-major layout: column j
        // lives at stride PROBE_COLS).
        let mut measured = 0.0f64;
        for j in 0..PROBE_COLS {
            let (mut err2, mut ref2) = (0.0f64, 0.0f64);
            for i in 0..self.rows {
                let w = y64[i * PROBE_COLS + j];
                let d = y32[i * PROBE_COLS + j] as f64 - w;
                err2 += d * d;
                ref2 += w * w;
            }
            if ref2 > 0.0 {
                measured = measured.max((err2 / ref2).sqrt());
            }
        }

        // Structural floor: quantization plus one rounding per
        // accumulation term along the chain, so exactly-representable
        // operators (measured ≈ 0) still declare an honest nonzero bound.
        let terms: usize = self.stages.iter().map(|s| s.max_terms() + 1).sum();
        let structural = 16.0 * f32::EPSILON as f64 * terms as f64;
        let declared = (64.0 * measured).max(structural);
        (plan32, F32Bound { measured_rel_err: measured, declared_rel_err: declared })
    }
}

impl<S: Scalar> ApplyPlan<S> {
    /// The transpose chain, materialized on first use (forward-only
    /// operators never pay for the transposed copies).
    fn t_chain(&self) -> &[Stage<S>] {
        self.t_stages
            .get_or_init(|| self.stages.iter().rev().map(Stage::transposed).collect())
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    pub fn stages(&self) -> &[Stage<S>] {
        &self.stages
    }

    /// Largest intermediate dimension along the chain.
    pub fn max_dim(&self) -> usize {
        self.max_dim
    }

    /// Flops of one planned matvec.
    pub fn planned_flops(&self) -> usize {
        self.stages.iter().map(Stage::flops).sum()
    }

    /// Flops of the naive per-factor CSR chain this plan replaces.
    pub fn naive_flops(&self) -> usize {
        self.naive_flops
    }

    /// The plan's [`CostProfile`]: per-column flops/bytes plus the fixed
    /// per-batch operand traffic, for batch sizing and RCG reporting.
    pub fn profile(&self) -> CostProfile {
        CostProfile {
            flops_per_col: self.planned_flops(),
            bytes_per_col: S::BYTES
                * (self.cols + self.stages.iter().map(Stage::rows).sum::<usize>()),
            fixed_bytes: self.stages.iter().map(Stage::operand_bytes).sum(),
            max_dim: self.max_dim,
            simd_lanes: super::kernel::lane_width_of::<S>(),
            elem_bytes: S::BYTES,
        }
    }

    /// Scratch elements needed for a batch of `bcols` columns.
    pub fn scratch_len(&self, bcols: usize) -> usize {
        self.max_dim * bcols.max(1)
    }

    /// Execute the forward chain on a row-major column-batch:
    /// `out = λ·S_J⋯S_1 · x`, `x ∈ R^{cols×bcols}`, `out ∈ R^{rows×bcols}`.
    /// Steady-state allocation-free: scratch comes from `arena`.
    pub fn execute_batch_into(
        &self,
        pool: &ThreadPool,
        arena: &mut Arena<S>,
        x: &[S],
        bcols: usize,
        out: &mut [S],
    ) {
        assert_eq!(x.len(), self.cols * bcols, "plan execute: x dim mismatch");
        assert_eq!(out.len(), self.rows * bcols, "plan execute: out dim mismatch");
        run_chain(&self.stages, pool, arena, self.scratch_len(bcols), x, bcols, out);
    }

    /// Execute the transpose chain: `out = λ·S_1ᵀ⋯S_Jᵀ · x`.
    pub fn execute_t_batch_into(
        &self,
        pool: &ThreadPool,
        arena: &mut Arena<S>,
        x: &[S],
        bcols: usize,
        out: &mut [S],
    ) {
        assert_eq!(x.len(), self.rows * bcols, "plan execute_t: x dim mismatch");
        assert_eq!(out.len(), self.cols * bcols, "plan execute_t: out dim mismatch");
        run_chain(self.t_chain(), pool, arena, self.scratch_len(bcols), x, bcols, out);
    }

    /// Single-vector forward apply (`bcols = 1`).
    pub fn execute_into(&self, pool: &ThreadPool, arena: &mut Arena<S>, x: &[S], y: &mut [S]) {
        self.execute_batch_into(pool, arena, x, 1, y);
    }

    /// Single-vector transpose apply.
    pub fn execute_t_into(&self, pool: &ThreadPool, arena: &mut Arena<S>, x: &[S], y: &mut [S]) {
        self.execute_t_batch_into(pool, arena, x, 1, y);
    }
}

impl ApplyPlan {
    /// Human-readable plan dump (the CLI's `--plan dump`).
    pub fn dump(&self, cfg: &PlanConfig) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "ApplyPlan {}x{}: {} factor(s) -> {} stage(s), lambda={:.6} (folded)\n",
            self.rows,
            self.cols,
            self.n_factors,
            self.stages.len(),
            self.lambda,
        ));
        out.push_str(&format!(
            "  flops/matvec: naive={} planned={} ({:.2}x)\n",
            self.naive_flops,
            self.planned_flops(),
            self.naive_flops as f64 / self.planned_flops().max(1) as f64,
        ));
        out.push_str(&format!("  max intermediate dim: {}\n", self.max_dim));
        for (i, s) in self.stages.iter().enumerate() {
            let (f0, f1) = s.factor_range();
            let kind = match (&s.kernel, s.is_fused()) {
                (StageKernel::Sparse(_), false) => "sparse".to_string(),
                (StageKernel::Dense(_), false) => "dense ".to_string(),
                (StageKernel::Sparse(_), true) => format!("sparse fused[{f0}..{f1}]"),
                (StageKernel::Dense(_), true) => format!("dense  fused[{f0}..{f1}]"),
            };
            out.push_str(&format!(
                "  stage {i}: {kind} {}x{} nnz={} density={:.3} cost={:.0}\n",
                s.rows(),
                s.cols(),
                s.nnz(),
                s.nnz() as f64 / (s.rows() * s.cols()) as f64,
                s.cost(cfg.bytes_per_flop_weight),
            ));
        }
        out
    }
}

/// Shared chain runner: ping-pong through arena scratch.
fn run_chain<S: Scalar>(
    stages: &[Stage<S>],
    pool: &ThreadPool,
    arena: &mut Arena<S>,
    scratch_len: usize,
    x: &[S],
    bcols: usize,
    out: &mut [S],
) {
    if stages.len() == 1 {
        stages[0].run(pool, x, bcols, out);
        return;
    }
    let (mut src, mut dst) = arena.acquire(scratch_len);
    let first = &stages[0];
    first.run(pool, x, bcols, &mut src[..first.rows() * bcols]);
    let mut cur_rows = first.rows();
    for st in &stages[1..stages.len() - 1] {
        st.run(pool, &src[..cur_rows * bcols], bcols, &mut dst[..st.rows() * bcols]);
        cur_rows = st.rows();
        std::mem::swap(&mut src, &mut dst);
    }
    let last = stages.last().unwrap();
    last.run(pool, &src[..cur_rows * bcols], bcols, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn sparse_mat(rng: &mut Rng, r: usize, c: usize, nnz: usize) -> Mat {
        let mut m = Mat::zeros(r, c);
        for i in rng.sample_indices(r * c, nnz.min(r * c)) {
            m.data_mut()[i] = rng.gauss();
        }
        m
    }

    fn chain(rng: &mut Rng, dims: &[usize], fill: f64, lambda: f64) -> (Faust, Mat) {
        let mats: Vec<Mat> = (0..dims.len() - 1)
            .map(|i| {
                let (r, c) = (dims[i + 1], dims[i]);
                let nnz = ((r * c) as f64 * fill).ceil() as usize;
                sparse_mat(rng, r, c, nnz.max(1))
            })
            .collect();
        let refs: Vec<&Mat> = mats.iter().rev().collect();
        let dense = crate::linalg::chain_product(&refs, dims[0]).scaled(lambda);
        (Faust::from_dense_factors(&mats, lambda), dense)
    }

    fn apply_via_plan(plan: &ApplyPlan, x: &[f64]) -> Vec<f64> {
        let pool = ThreadPool::serial();
        let mut arena = Arena::new();
        let mut y = vec![0.0; plan.rows()];
        plan.execute_into(&pool, &mut arena, x, &mut y);
        y
    }

    #[test]
    fn planned_apply_matches_dense_reference() {
        let mut rng = Rng::new(501);
        for fill in [0.05, 0.2, 0.6] {
            let (f, dense) = chain(&mut rng, &[9, 7, 7, 5], fill, 1.4);
            let plan = ApplyPlan::compile(&f, &PlanConfig::default());
            let x = rng.gauss_vec(9);
            let got = apply_via_plan(&plan, &x);
            let want = dense.matvec(&x);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-10 * (1.0 + w.abs()), "fill={fill}");
            }
        }
    }

    #[test]
    fn planned_transpose_matches_dense_reference() {
        let mut rng = Rng::new(502);
        let (f, dense) = chain(&mut rng, &[8, 6, 10, 4], 0.3, 0.7);
        let plan = ApplyPlan::compile(&f, &PlanConfig::default());
        let pool = ThreadPool::serial();
        let mut arena = Arena::new();
        let x = rng.gauss_vec(4);
        let mut y = vec![0.0; 8];
        plan.execute_t_into(&pool, &mut arena, &x, &mut y);
        let want = dense.matvec_t(&x);
        for (g, w) in y.iter().zip(&want) {
            assert!((g - w).abs() < 1e-10 * (1.0 + w.abs()));
        }
    }

    #[test]
    fn dense_threshold_selects_gemm() {
        let mut rng = Rng::new(503);
        let (f, _) = chain(&mut rng, &[12, 12], 0.9, 1.0);
        let cfg = PlanConfig { fuse: false, ..PlanConfig::default() };
        let plan = ApplyPlan::compile(&f, &cfg);
        assert!(plan.stages()[0].is_dense());
        let sparse_cfg = PlanConfig { dense_threshold: 0.95, fuse: false, ..PlanConfig::default() };
        let plan2 = ApplyPlan::compile(&f, &sparse_cfg);
        assert!(!plan2.stages()[0].is_dense());
    }

    #[test]
    fn fusion_reduces_flops_and_preserves_results() {
        let mut rng = Rng::new(504);
        // Diagonal-ish tiny factors: products stay tiny, so fusing wins.
        let d1 = Mat::from_fn(6, 6, |i, j| if i == j { 1.0 + 0.1 * i as f64 } else { 0.0 });
        let d2 = Mat::from_fn(6, 6, |i, j| if i == j { 2.0 - 0.1 * i as f64 } else { 0.0 });
        let d3 = sparse_mat(&mut rng, 5, 6, 10);
        let f = Faust::from_dense_factors(&[d1.clone(), d2.clone(), d3.clone()], 1.0);
        let plan = ApplyPlan::compile(&f, &PlanConfig::default());
        assert!(plan.n_stages() < 3, "diagonal factors should fuse");
        assert!(plan.planned_flops() < plan.naive_flops());
        let x = rng.gauss_vec(6);
        let want = d3.matmul(&d2.matmul(&d1)).matvec(&x);
        let got = apply_via_plan(&plan, &x);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-10 * (1.0 + w.abs()));
        }
    }

    #[test]
    fn fusion_rejected_when_it_grows_flops() {
        // Hadamard butterflies: fusing two 2-nnz/row stages yields
        // 4 nnz/row — no flop reduction, so the plan must keep them apart.
        let f = crate::transforms::hadamard_faust(32);
        let plan = ApplyPlan::compile(&f, &PlanConfig::default());
        assert_eq!(plan.n_stages(), f.n_factors());
        assert_eq!(plan.planned_flops(), plan.naive_flops());
    }

    #[test]
    fn lambda_folded_once() {
        let mut rng = Rng::new(505);
        let (f, dense) = chain(&mut rng, &[5, 5, 5], 0.4, 3.25);
        let plan = ApplyPlan::compile(&f, &PlanConfig::default());
        let x = rng.gauss_vec(5);
        let got = apply_via_plan(&plan, &x);
        let want = dense.matvec(&x);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-10 * (1.0 + w.abs()));
        }
        // Transpose path sees λ exactly once too.
        let pool = ThreadPool::serial();
        let mut arena = Arena::new();
        let mut yt = vec![0.0; 5];
        plan.execute_t_into(&pool, &mut arena, &x, &mut yt);
        let want_t = dense.matvec_t(&x);
        for (g, w) in yt.iter().zip(&want_t) {
            assert!((g - w).abs() < 1e-10 * (1.0 + w.abs()));
        }
    }

    #[test]
    fn single_factor_plan_runs_straight_through() {
        let mut rng = Rng::new(506);
        let (f, dense) = chain(&mut rng, &[7, 4], 0.5, 2.0);
        let plan = ApplyPlan::compile(&f, &PlanConfig::default());
        assert_eq!(plan.n_stages(), 1);
        let mut arena = Arena::new();
        let pool = ThreadPool::serial();
        let x = rng.gauss_vec(7);
        let mut y = vec![0.0; 4];
        plan.execute_into(&pool, &mut arena, &x, &mut y);
        // Single-stage chains never touch the arena.
        assert_eq!(arena.allocs() + arena.reuses(), 0);
        let want = dense.matvec(&x);
        for (g, w) in y.iter().zip(&want) {
            assert!((g - w).abs() < 1e-10 * (1.0 + w.abs()));
        }
    }

    #[test]
    fn batch_execution_matches_columnwise() {
        let mut rng = Rng::new(507);
        let (f, _) = chain(&mut rng, &[10, 8, 6], 0.3, 1.1);
        let plan = ApplyPlan::compile(&f, &PlanConfig::default());
        let pool = ThreadPool::new(3);
        let mut arena = Arena::new();
        let b = 5;
        let x = Mat::randn(10, b, &mut rng);
        let mut out = vec![0.0; 6 * b];
        plan.execute_batch_into(&pool, &mut arena, x.data(), b, &mut out);
        for j in 0..b {
            let xcol = x.col(j);
            let ycol = apply_via_plan(&plan, &xcol);
            for i in 0..6 {
                assert!((out[i * b + j] - ycol[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn unfused_sparse_stages_share_factor_storage() {
        // ROADMAP item (e): a compiled plan must alias the Faust's own
        // Arc<Csr> for every unfused sparse stage — MEG-scale operators
        // used to hold ~2x factor memory per plan.
        let f = crate::transforms::hadamard_faust(32);
        let plan = ApplyPlan::compile(&f, &PlanConfig::default());
        assert_eq!(plan.n_stages(), f.n_factors());
        for (stage, fac) in plan.stages().iter().zip(f.factors()) {
            match &stage.kernel {
                StageKernel::Sparse(s) => {
                    assert!(Arc::ptr_eq(s, fac), "stage copied its factor")
                }
                StageKernel::Dense(_) => panic!("butterfly stage went dense"),
            }
        }
    }

    #[test]
    fn lambda_folding_unshares_the_last_stage() {
        // λ ≠ 1 must scale a copy, never the operator's own factor.
        let mut rng = Rng::new(508);
        let (f, dense) = chain(&mut rng, &[6, 6, 6], 0.1, 2.5);
        let before: Vec<f64> = f.factors().last().unwrap().vals.clone();
        let plan = ApplyPlan::compile(&f, &PlanConfig { fuse: false, ..PlanConfig::default() });
        assert_eq!(f.factors().last().unwrap().vals, before, "factor mutated");
        let x = rng.gauss_vec(6);
        let got = apply_via_plan(&plan, &x);
        let want = dense.matvec(&x);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-10 * (1.0 + w.abs()));
        }
    }

    #[test]
    fn profile_accounts_flops_and_operand_bytes() {
        let n = 32;
        let f = crate::transforms::hadamard_faust(n);
        let plan = ApplyPlan::compile(&f, &PlanConfig::default());
        let p = plan.profile();
        // Butterfly chains never fuse, so planned == naive flops.
        assert_eq!(p.flops_per_col, 2 * f.s_tot());
        // Input column + one n-row output per stage.
        assert_eq!(p.bytes_per_col, 8 * n * (1 + f.n_factors()));
        // All stages stay CSR: vals+cols per nnz, row pointers per stage.
        let per_stage = 12 * 2 * n + 4 * (n + 1);
        assert_eq!(p.fixed_bytes, per_stage * f.n_factors());
        assert_eq!(p.max_dim, n);
        assert_eq!(p.simd_lanes, crate::engine::kernel::lane_width());
        assert_eq!(p.elem_bytes, 8);
        assert!(p.col_cost(0.25) > p.flops_per_col as f64);
        assert!(p.fixed_cost(0.25) > 0.0);
    }

    #[test]
    fn f32_profile_reports_four_byte_elements_and_wider_lanes() {
        let f = crate::transforms::hadamard_faust(32);
        let plan = ApplyPlan::compile(&f, &PlanConfig::default());
        let p64 = plan.profile();
        let p32 = plan.to_f32().profile();
        assert_eq!(p32.elem_bytes, 4);
        assert_eq!(p32.flops_per_col, p64.flops_per_col);
        assert_eq!(p32.bytes_per_col, p64.bytes_per_col / 2);
        // Sparse stage operands: (4+4)·nnz + 4·(rows+1) vs (8+4)·nnz + ….
        assert!(p32.fixed_bytes < p64.fixed_bytes);
        assert_eq!(p32.max_dim, p64.max_dim);
        assert_eq!(p32.simd_lanes, crate::engine::kernel::lane_width_of::<f32>());
        assert_eq!(p32.simd_lanes, 2 * p64.simd_lanes);
    }

    #[test]
    fn f32_plan_matches_f64_within_declared_bound() {
        let mut rng = Rng::new(509);
        let pool = ThreadPool::new(2);
        for (dims, fill, lambda) in [
            (vec![17, 11, 9, 13], 0.2, 1.7),
            (vec![33, 33, 33], 0.1, 0.9),
            (vec![6, 21], 0.5, 2.5),
        ] {
            let (f, _) = chain(&mut rng, &dims, fill, lambda);
            let plan = ApplyPlan::compile(&f, &PlanConfig::default());
            let (plan32, bound) = plan.to_f32_with_bound(&pool);
            assert!(bound.measured_rel_err <= bound.declared_rel_err);
            assert!(bound.declared_rel_err > 0.0, "structural floor must be nonzero");
            assert!(bound.declared_rel_err < 1e-3, "bound uselessly loose");
            // Fresh input (not the probe): still within the declared bound.
            let x64 = rng.gauss_vec(plan.cols());
            let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
            let mut a64 = Arena::<f64>::new();
            let mut a32 = Arena::<f32>::new();
            let mut y64 = vec![0.0f64; plan.rows()];
            let mut y32 = vec![0.0f32; plan.rows()];
            plan.execute_into(&pool, &mut a64, &x64, &mut y64);
            plan32.execute_into(&pool, &mut a32, &x32, &mut y32);
            let (mut err2, mut ref2) = (0.0f64, 0.0f64);
            for i in 0..plan.rows() {
                let d = y32[i] as f64 - y64[i];
                err2 += d * d;
                ref2 += y64[i] * y64[i];
            }
            let rel = (err2 / ref2.max(1e-300)).sqrt();
            assert!(
                rel <= bound.declared_rel_err,
                "rel={rel:e} declared={:e} dims={dims:?}",
                bound.declared_rel_err
            );
        }
    }

    #[test]
    fn exactly_representable_operator_still_declares_structural_floor() {
        // Hadamard entries are ±1 — f32 quantization is exact, so the
        // probe measures ~0 error and the declared bound must come from
        // the structural floor, not collapse to zero.
        let f = crate::transforms::hadamard_faust(64);
        let plan = ApplyPlan::compile(&f, &PlanConfig::default());
        let pool = ThreadPool::serial();
        let (_, bound) = plan.to_f32_with_bound(&pool);
        let terms: usize = plan.stages.iter().map(|s| s.max_terms() + 1).sum();
        let floor = 16.0 * f32::EPSILON as f64 * terms as f64;
        assert!(bound.declared_rel_err >= floor);
    }

    #[test]
    fn f32_plan_shares_no_storage_with_f64_factors() {
        let mut rng = Rng::new(510);
        let (f, _) = chain(&mut rng, &[8, 8, 8], 0.3, 1.0);
        let before = crate::testutil::faust_fingerprint(&f);
        let plan = ApplyPlan::compile(&f, &PlanConfig::default());
        let _ = plan.to_f32();
        assert_eq!(crate::testutil::faust_fingerprint(&f), before);
    }

    #[test]
    fn dense_profile_matches_gemm_accounting() {
        let p = CostProfile::dense(6, 9);
        assert_eq!(p.flops_per_col, 108);
        assert_eq!(p.fixed_bytes, 8 * 54);
        assert_eq!(p.bytes_per_col, 8 * 15);
        assert_eq!(p.max_dim, 9);
        assert_eq!(p.simd_lanes, crate::engine::kernel::lane_width());
        assert_eq!(p.elem_bytes, 8);
    }

    #[test]
    fn dump_mentions_stages_and_flops() {
        let f = crate::transforms::hadamard_faust(16);
        let cfg = PlanConfig::default();
        let plan = ApplyPlan::compile(&f, &cfg);
        let d = plan.dump(&cfg);
        assert!(d.contains("ApplyPlan 16x16"));
        assert!(d.contains("stage 0"));
        assert!(d.contains("flops/matvec"));
    }
}
