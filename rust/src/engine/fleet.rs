//! `FleetCtx` — cross-operator batched execution for factorizing *fleets*
//! of operators on one shared [`ExecCtx`].
//!
//! The paper's deployments hold many operators at once: one MEG gain
//! matrix per subject (§V), one dictionary per image class (§VI). Each
//! individual factorization bottoms out in GEMMs and power iterations
//! that are *small* — a 64×64 sparse-factor product carries a few
//! thousand flops, far below the pool's parallel grain — so a
//! one-operator-at-a-time loop leaves the `ExecCtx` pool idle between
//! dispatches. This module batches the independent per-operator kernels
//! of *separate* factorization problems into fused pool calls:
//!
//! - [`FleetCtx::gemm_many`] — N independent dense GEMMs in one pooled
//!   dispatch, each product executing serially inside its own task
//!   (operator-level parallelism) when the cost model says fusion beats N
//!   solo dispatches, and falling back to the solo cost-dispatched
//!   [`ExecCtx::gemm`] path for products big enough to feed every thread
//!   a full grain on their own;
//! - [`FleetCtx::spectral_norm_many`] — N independent warm-started power
//!   iterations, one per task, each bitwise identical to
//!   [`ExecCtx::spectral_norm_warm`];
//! - [`FleetCtx::map_many`] — N independent element-wise/projection jobs
//!   (gradient steps, proximal projections, objective evaluations)
//!   fanned out at job granularity.
//!
//! **Crossover cost model.** A GEMM with `F` flops splits into at most
//! `F / PAR_GRAIN` useful chunks; if `F ≥ n_threads · PAR_GRAIN` the solo
//! row-parallel kernel already saturates the pool and fusing adds nothing
//! (the fused task would serialize a product that wanted to spread out).
//! Below that, a solo dispatch degenerates to (mostly) serial execution,
//! so running whole small products on different threads is the only
//! parallelism available — exactly the regime hierarchical sparse
//! factorization lives in. [`FleetConfig::solo_flops`] is that threshold.
//!
//! **Bitwise contract.** Every fused kernel reuses the same serial
//! row/column kernels the pooled solo paths chunk over
//! (`pool::gemm_rows`, `pool::gemv_t_cols` — both routing into the
//! register-tiled [`super::kernel`] microkernels over the same absolute
//! tile grid), and the per-product transpose-rewrite decision is the
//! same [`ExecCtx`] cost model — so a fleet-batched factorization
//! produces **bit-identical** factors to N independent `_with_ctx` runs
//! at any thread count (enforced by the fleet proptests).
//!
//! Fleet methods must be called from an orchestrator thread, never from
//! inside a pool task (nested dispatch can deadlock the pool — see
//! [`pool::par_map_jobs`]).

#![forbid(unsafe_code)]

use super::ctx::ExecCtx;
use super::pool::{self, par_gemm_into, par_map_jobs};
use crate::linalg::{spectral_norm_with, Mat};
use std::sync::atomic::{AtomicU64, Ordering};

/// Crossover knobs for the fleet's fuse-vs-solo decision.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Products with at least this many flops dispatch solo (internally
    /// row-parallel via [`ExecCtx::gemm`]); smaller ones fuse into one
    /// operator-granular pool call. `0` means "derive from the pool":
    /// `n_threads × PAR_GRAIN` at [`FleetCtx`] construction.
    pub solo_flops: usize,
    /// Fewer than this many fusable jobs in a call → no fusion (a batch
    /// of one gains nothing over the solo path). Governs both
    /// [`FleetCtx::gemm_many`] and [`FleetCtx::spectral_norm_many`].
    pub min_fused: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig { solo_flops: 0, min_fused: 2 }
    }
}

/// Lifetime counters for the crossover decisions a [`FleetCtx`] made.
#[derive(Clone, Debug, Default)]
pub struct FleetMetricsSnapshot {
    /// `gemm_many` calls that fused at least two products.
    pub fused_calls: u64,
    /// Products executed inside fused dispatches.
    pub fused_gemms: u64,
    /// Products routed to the solo cost-dispatched path.
    pub solo_gemms: u64,
    /// Power iterations executed through `spectral_norm_many`.
    pub spectral_jobs: u64,
}

#[derive(Default)]
struct FleetMetrics {
    fused_calls: AtomicU64,
    fused_gemms: AtomicU64,
    solo_gemms: AtomicU64,
    spectral_jobs: AtomicU64,
}

/// One prepared product: the transpose-rewrite decision is already made
/// (identically to [`ExecCtx::gemm`]), operands are ready for the shared
/// serial kernel.
enum Prep<'p> {
    /// Direct ikj pass: `out = a · b`.
    Direct { a: &'p Mat, b: &'p Mat },
    /// Double-transpose rewrite: `out = (bᵀ · aᵀ)ᵀ`, zero-skip on `b`.
    Rewrite { bt: Mat, at: Mat, m: usize },
}

impl Prep<'_> {
    /// Execute serially with the shared row kernel (a fused task). The
    /// kernel is the same SIMD-width-dispatched microkernel the solo
    /// pooled path runs, over the same absolute tile grid, so fused bits
    /// equal solo bits.
    fn run_serial(self) -> Mat {
        match self {
            Prep::Direct { a, b } => {
                let (m, n) = (a.rows(), b.cols());
                let mut out = Mat::zeros(m, n);
                pool::gemm_rows(a, b.data(), n, 0, m, out.data_mut());
                out
            }
            Prep::Rewrite { bt, at, m } => {
                let n = bt.rows();
                let mut out_t = Mat::zeros(n, m);
                pool::gemm_rows(&bt, at.data(), m, 0, n, out_t.data_mut());
                out_t.t()
            }
        }
    }
}

/// Shared execution context for fleets: an [`ExecCtx`] plus the
/// fuse-vs-solo crossover. Cheap to clone.
#[derive(Clone)]
pub struct FleetCtx {
    ctx: ExecCtx,
    solo_flops: usize,
    min_fused: usize,
    metrics: std::sync::Arc<FleetMetrics>,
}

impl FleetCtx {
    /// Fleet context on `ctx`'s pool with the default crossover
    /// (`solo_flops = n_threads × PAR_GRAIN`).
    pub fn new(ctx: ExecCtx) -> Self {
        Self::with_config(ctx, FleetConfig::default())
    }

    /// Fleet context with explicit crossover knobs.
    pub fn with_config(ctx: ExecCtx, cfg: FleetConfig) -> Self {
        let solo_flops = if cfg.solo_flops == 0 {
            ctx.n_threads() * pool::PAR_GRAIN_FLOPS
        } else {
            cfg.solo_flops
        };
        FleetCtx {
            ctx,
            solo_flops,
            min_fused: cfg.min_fused.max(2),
            metrics: std::sync::Arc::new(FleetMetrics::default()),
        }
    }

    /// The underlying execution context (shared pool + cost model).
    pub fn ctx(&self) -> &ExecCtx {
        &self.ctx
    }

    /// Threads participating in fleet dispatches.
    pub fn n_threads(&self) -> usize {
        self.ctx.n_threads()
    }

    /// Crossover counters accumulated so far.
    pub fn metrics(&self) -> FleetMetricsSnapshot {
        FleetMetricsSnapshot {
            fused_calls: self.metrics.fused_calls.load(Ordering::Relaxed),
            fused_gemms: self.metrics.fused_gemms.load(Ordering::Relaxed),
            solo_gemms: self.metrics.solo_gemms.load(Ordering::Relaxed),
            spectral_jobs: self.metrics.spectral_jobs.load(Ordering::Relaxed),
        }
    }

    /// N independent products `aᵢ · bᵢ`, results in input order.
    ///
    /// Each product gets the same transpose-rewrite decision as
    /// [`ExecCtx::gemm`]; the crossover then routes it either into the
    /// fused operator-granular dispatch (small products, parallel across
    /// the fleet) or the solo row-parallel path (large products, parallel
    /// within the product). Results are bitwise identical to calling
    /// `ctx.gemm(aᵢ, bᵢ)` in a loop.
    pub fn gemm_many(&self, pairs: &[(&Mat, &Mat)]) -> Vec<Mat> {
        let n = pairs.len();
        if n == 0 {
            return Vec::new();
        }
        let mut preps: Vec<Option<(Prep, usize)>> = Vec::with_capacity(n);
        for &(a, b) in pairs {
            assert_eq!(a.cols(), b.rows(), "fleet gemm dim mismatch");
            // One nnz scan per operand, reused for the (solo-identical)
            // rewrite decision and the crossover flop estimate.
            let (a_nnz, b_nnz) = (a.nnz(), b.nnz());
            if self.ctx.rewrite_wins_nnz(a, b, a_nnz, b_nnz) {
                let flops = 2 * b_nnz * a.rows();
                preps.push(Some((
                    Prep::Rewrite { bt: b.t(), at: a.t(), m: a.rows() },
                    flops,
                )));
            } else {
                let flops = 2 * a_nnz * b.cols();
                preps.push(Some((Prep::Direct { a, b }, flops)));
            }
        }
        let fusable: Vec<usize> = (0..n)
            .filter(|&i| preps[i].as_ref().is_some_and(|(_, f)| *f < self.solo_flops))
            .collect();
        let mut out: Vec<Option<Mat>> = std::iter::repeat_with(|| None).take(n).collect();
        if self.n_threads() > 1 && fusable.len() >= self.min_fused {
            // Fused dispatch: whole small products run serially on
            // different threads.
            let jobs: Vec<(usize, Prep)> = fusable
                .iter()
                .map(|&i| (i, preps[i].take().expect("fusable prep present").0))
                .collect();
            self.metrics.fused_calls.fetch_add(1, Ordering::Relaxed);
            self.metrics
                .fused_gemms
                .fetch_add(jobs.len() as u64, Ordering::Relaxed);
            for (i, m) in par_map_jobs(self.ctx.pool(), jobs, |(i, p)| (i, p.run_serial())) {
                out[i] = Some(m);
            }
        }
        // Solo path: everything still unexecuted (large products, or the
        // whole batch when fusion did not clear the crossover).
        for (i, slot) in preps.into_iter().enumerate() {
            if let Some((p, _)) = slot {
                self.metrics.solo_gemms.fetch_add(1, Ordering::Relaxed);
                out[i] = Some(self.run_solo(p));
            }
        }
        out.into_iter()
            .map(|m| m.expect("fleet gemm produced"))
            .collect()
    }

    /// Execute one prepared product through the pooled row-parallel
    /// kernel — exactly the code path [`ExecCtx::gemm`] takes after its
    /// (identical) rewrite decision.
    fn run_solo(&self, p: Prep) -> Mat {
        match p {
            Prep::Direct { a, b } => {
                let mut out = Mat::zeros(a.rows(), b.cols());
                par_gemm_into(self.ctx.pool(), a, b.data(), b.cols(), out.data_mut());
                out
            }
            Prep::Rewrite { bt, at, m } => {
                let mut out_t = Mat::zeros(bt.rows(), m);
                par_gemm_into(self.ctx.pool(), &bt, at.data(), m, out_t.data_mut());
                out_t.t()
            }
        }
    }

    /// N independent spectral norms `‖aᵢ‖₂` by warm-started power
    /// iteration. Takes each job's warm-start vector by value and hands
    /// it back (updated) with the norm, in job order.
    ///
    /// Same crossover as [`FleetCtx::gemm_many`]: operators whose
    /// per-iteration gram-apply (two gemv passes, `4·m·n` flops) clears
    /// the solo threshold run through the pooled
    /// [`ExecCtx::spectral_norm_warm`] (row-parallel within the
    /// operator); the small rest fuse one-operator-per-task. Both routes
    /// are bitwise identical — the fused serial gram-apply reuses the
    /// pooled kernels' shared per-chunk row/column routines.
    pub fn spectral_norm_many(
        &self,
        jobs: Vec<(&Mat, Vec<f64>)>,
        max_iter: usize,
        tol: f64,
    ) -> Vec<(f64, Vec<f64>)> {
        let njobs = jobs.len();
        self.metrics
            .spectral_jobs
            .fetch_add(njobs as u64, Ordering::Relaxed);
        let mut out: Vec<Option<(f64, Vec<f64>)>> =
            std::iter::repeat_with(|| None).take(njobs).collect();
        let mut small: Vec<(usize, &Mat, Vec<f64>)> = Vec::new();
        for (idx, (a, warm)) in jobs.into_iter().enumerate() {
            if self.n_threads() > 1 && 4 * a.rows() * a.cols() < self.solo_flops {
                small.push((idx, a, warm));
            } else {
                let mut w = warm;
                let v = self.ctx.spectral_norm_warm(a, &mut w, max_iter, tol);
                out[idx] = Some((v, w));
            }
        }
        if small.len() < self.min_fused {
            // Below the fusion floor (same knob as gemm_many): too few
            // jobs to amortize a fused dispatch — run them solo instead.
            for (idx, a, warm) in small.drain(..) {
                let mut w = warm;
                let v = self.ctx.spectral_norm_warm(a, &mut w, max_iter, tol);
                out[idx] = Some((v, w));
            }
        }
        let fused = par_map_jobs(self.ctx.pool(), small, move |(idx, a, mut warm)| {
            let (m, n) = a.shape();
            if m == 0 || n == 0 {
                return (idx, 0.0, warm);
            }
            let mut y = vec![0.0; m];
            let norm = spectral_norm_with(n, &mut warm, max_iter, tol, |xv, z| {
                pool::gemm_rows(a, xv, 1, 0, m, &mut y);
                pool::gemv_t_cols(a, &y, 0, n, z);
            });
            (idx, norm, warm)
        });
        for (idx, norm, warm) in fused {
            out[idx] = Some((norm, warm));
        }
        out.into_iter()
            .map(|o| o.expect("spectral job completed"))
            .collect()
    }

    /// Fan N independent jobs out at job granularity (element-wise factor
    /// updates, proximal projections, objective evaluations). Results in
    /// job order. Jobs must not touch the pool (no nested dispatch).
    pub fn map_many<J: Send, T: Send>(
        &self,
        jobs: Vec<J>,
        f: impl Fn(J) -> T + Sync,
    ) -> Vec<T> {
        par_map_jobs(self.ctx.pool(), jobs, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn sparse_mat(rng: &mut Rng, r: usize, c: usize, nnz: usize) -> Mat {
        let mut m = Mat::zeros(r, c);
        for i in rng.sample_indices(r * c, nnz.min(r * c)) {
            m.data_mut()[i] = rng.gauss();
        }
        m
    }

    /// Mixed shapes + sparsity: both rewrite branches, both crossover
    /// routes must match solo `ctx.gemm` bitwise.
    #[test]
    fn gemm_many_matches_solo_gemm_bitwise() {
        let mut rng = Rng::new(811);
        let ctx = ExecCtx::new(4);
        let cases: Vec<(Mat, Mat)> = vec![
            (Mat::randn(20, 16, &mut rng), sparse_mat(&mut rng, 16, 12, 10)),
            (sparse_mat(&mut rng, 18, 14, 9), Mat::randn(14, 11, &mut rng)),
            (Mat::randn(9, 7, &mut rng), Mat::randn(7, 13, &mut rng)),
            (Mat::randn(40, 40, &mut rng), Mat::randn(40, 40, &mut rng)),
            (Mat::randn(3, 5, &mut rng), Mat::randn(5, 2, &mut rng)),
        ];
        let want: Vec<Mat> = cases.iter().map(|(a, b)| ctx.gemm(a, b)).collect();
        for cfg in [
            FleetConfig::default(),
            FleetConfig { solo_flops: usize::MAX, min_fused: 2 }, // force fused
            FleetConfig { solo_flops: 1, min_fused: 2 },          // force solo
        ] {
            let fleet = FleetCtx::with_config(ctx.clone(), cfg);
            let pairs: Vec<(&Mat, &Mat)> = cases.iter().map(|(a, b)| (a, b)).collect();
            let got = fleet.gemm_many(&pairs);
            for ((g, w), (a, _)) in got.iter().zip(&want).zip(&cases) {
                assert_eq!(g.shape(), w.shape());
                assert_eq!(g.data(), w.data(), "a.rows={}", a.rows());
            }
        }
    }

    #[test]
    fn gemm_many_crossover_routes_by_size() {
        let mut rng = Rng::new(812);
        // 2 threads, tiny solo threshold: the big product goes solo, the
        // small ones fuse.
        let fleet = FleetCtx::with_config(
            ExecCtx::new(2),
            FleetConfig { solo_flops: 10_000, min_fused: 2 },
        );
        let big = (Mat::randn(40, 40, &mut rng), Mat::randn(40, 40, &mut rng)); // 128k flops
        let s1 = (Mat::randn(6, 6, &mut rng), Mat::randn(6, 6, &mut rng));
        let s2 = (Mat::randn(5, 7, &mut rng), Mat::randn(7, 4, &mut rng));
        let pairs = vec![(&big.0, &big.1), (&s1.0, &s1.1), (&s2.0, &s2.1)];
        let _ = fleet.gemm_many(&pairs);
        let m = fleet.metrics();
        assert_eq!(m.solo_gemms, 1, "big product must dispatch solo");
        assert_eq!(m.fused_gemms, 2, "small products must fuse");
        assert_eq!(m.fused_calls, 1);
    }

    #[test]
    fn single_threaded_fleet_never_fuses() {
        let mut rng = Rng::new(813);
        let fleet = FleetCtx::with_config(
            ExecCtx::serial(),
            FleetConfig { solo_flops: usize::MAX, min_fused: 2 },
        );
        let a = Mat::randn(6, 6, &mut rng);
        let b = Mat::randn(6, 6, &mut rng);
        let got = fleet.gemm_many(&[(&a, &b), (&a, &b)]);
        assert_eq!(fleet.metrics().fused_calls, 0);
        assert!(got[0].rel_fro_err(&a.matmul(&b)) < 1e-13);
    }

    #[test]
    fn spectral_norm_many_matches_ctx_bitwise() {
        let mut rng = Rng::new(814);
        let ctx = ExecCtx::new(4);
        let fleet = FleetCtx::new(ctx.clone());
        let mats: Vec<Mat> = (0..5)
            .map(|i| Mat::randn(10 + i, 7 + i, &mut rng))
            .collect();
        // Reference: solo ctx norms, fresh warm vectors.
        let mut want = Vec::new();
        for a in &mats {
            let mut w = vec![];
            let n = ctx.spectral_norm_warm(a, &mut w, 40, 1e-9);
            want.push((n, w));
        }
        let jobs: Vec<(&Mat, Vec<f64>)> = mats.iter().map(|a| (a, vec![])).collect();
        let got = fleet.spectral_norm_many(jobs, 40, 1e-9);
        assert_eq!(fleet.metrics().spectral_jobs, 5);
        for ((gn, gw), (wn, ww)) in got.iter().zip(&want) {
            assert_eq!(gn.to_bits(), wn.to_bits());
            assert_eq!(gw, ww, "warm-start vector diverged");
        }
        // Warm restarts flow through the fleet path too.
        let jobs2: Vec<(&Mat, Vec<f64>)> =
            mats.iter().zip(got).map(|(a, (_, w))| (a, w)).collect();
        let got2 = fleet.spectral_norm_many(jobs2, 40, 1e-9);
        for ((gn, _), (wn, _)) in got2.iter().zip(&want) {
            assert!((gn - wn).abs() <= 1e-9 * (1.0 + wn.abs()));
        }
    }

    #[test]
    fn map_many_runs_everything_in_order() {
        let fleet = FleetCtx::new(ExecCtx::new(3));
        let got = fleet.map_many((0..20usize).collect(), |i| 2 * i);
        assert_eq!(got, (0..20usize).map(|i| 2 * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_batches_are_noops() {
        let fleet = FleetCtx::new(ExecCtx::new(2));
        assert!(fleet.gemm_many(&[]).is_empty());
        assert!(fleet.spectral_norm_many(vec![], 10, 1e-9).is_empty());
    }
}
