//! Deterministic pseudo-random number generation.
//!
//! The offline vendor set has no `rand` crate, so the library carries its
//! own small, reproducible RNG: [`Rng`] is xoshiro256++ seeded via
//! SplitMix64. Every experiment in the repo takes an explicit seed so that
//! paper-reproduction runs are bit-stable across machines.

#![forbid(unsafe_code)]

/// SplitMix64 step — used to expand a single `u64` seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG with convenience samplers for the numeric code.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller Gaussian deviate.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Deterministically seed from a single `u64`.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Next raw 64-bit output (xoshiro256++).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of entropy.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 64-bit multiply-shift; bias is negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard Gaussian via Box–Muller (cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        // Avoid u == 0 for the log.
        let u = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Vector of iid standard Gaussians.
    pub fn gauss_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.gauss()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices sampled uniformly from `[0, n)`.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        if k * 4 >= n {
            // Dense case: shuffle a full index vector.
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            // Sparse case: rejection sampling with a small set.
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let i = self.below(n);
                if seen.insert(i) {
                    out.push(i);
                }
            }
            out
        }
    }

    /// Split off an independent child RNG (for parallel workers).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(5);
        let mut hits = [0usize; 10];
        for _ in 0..10_000 {
            let i = r.below(10);
            assert!(i < 10);
            hits[i] += 1;
        }
        for (i, &h) in hits.iter().enumerate() {
            assert!(h > 700, "bucket {i} underrepresented: {h}");
        }
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(9);
        for &(n, k) in &[(100usize, 5usize), (10, 10), (1000, 100), (8, 3)] {
            let idx = r.sample_indices(n, k);
            assert_eq!(idx.len(), k);
            let set: std::collections::HashSet<_> = idx.iter().collect();
            assert_eq!(set.len(), k, "duplicates for n={n} k={k}");
            assert!(idx.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_independent() {
        let mut a = Rng::new(17);
        let mut c1 = a.split();
        let mut c2 = a.split();
        // Children should differ from each other.
        let same = (0..32).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
