//! Analytic transforms: Hadamard, DCT, Haar — dense forms, reference
//! butterfly factorizations, and the overcomplete-DCT dictionary baseline.
//!
//! These are the paper's motivating examples (§I Fig. 1): operators that
//! *already* admit exact multi-layer sparse forms — the ground truth the
//! hierarchical algorithm must reverse-engineer (§IV-C) and the analytic
//! dictionary baseline of the denoising experiment (§VI-C).

#![forbid(unsafe_code)]

use crate::faust::Faust;
use crate::linalg::Mat;
use crate::sparse::Csr;

/// Dense Walsh–Hadamard matrix of size `n = 2^N`, normalized so that
/// `H Hᵀ = Id` (entries `±1/√n`).
pub fn hadamard(n: usize) -> Mat {
    assert!(n.is_power_of_two() && n >= 1);
    let scale = 1.0 / (n as f64).sqrt();
    Mat::from_fn(n, n, |i, j| {
        // (-1)^{popcount(i & j)}
        if (i & j).count_ones() % 2 == 0 {
            scale
        } else {
            -scale
        }
    })
}

/// Exact butterfly factorization of the normalized Hadamard matrix:
/// `H = B_N ⋯ B_1` with each `B` having `2n` non-zeros (paper Fig. 1).
///
/// Each stage is the block butterfly `B = P · (Id_{n/2} ⊗ [[1,1],[1,-1]])`
/// realized directly on index pairs differing in one bit.
pub fn hadamard_faust(n: usize) -> Faust {
    assert!(n.is_power_of_two() && n >= 2);
    let nbits = n.trailing_zeros() as usize;
    let scale = 1.0 / 2f64.sqrt(); // each stage normalized; product = 1/√n
    let mut factors = Vec::with_capacity(nbits);
    for b in 0..nbits {
        let mut m = Mat::zeros(n, n);
        let bit = 1usize << b;
        for i in 0..n {
            let partner = i ^ bit;
            // Row i combines inputs i and partner.
            if i & bit == 0 {
                m.set(i, i, scale);
                m.set(i, partner, scale);
            } else {
                m.set(i, partner, scale);
                m.set(i, i, -scale);
            }
        }
        factors.push(Csr::from_dense(&m, 0.0));
    }
    Faust::new(factors, 1.0)
}

/// Dense orthonormal DCT-II matrix (`n×n`).
pub fn dct2(n: usize) -> Mat {
    let mut m = Mat::from_fn(n, n, |k, i| {
        ((std::f64::consts::PI / n as f64) * (i as f64 + 0.5) * k as f64).cos()
    });
    // Orthonormalize: row 0 scaled by sqrt(1/n), others sqrt(2/n).
    let s0 = (1.0 / n as f64).sqrt();
    let s = (2.0 / n as f64).sqrt();
    for k in 0..n {
        let f = if k == 0 { s0 } else { s };
        for i in 0..n {
            let v = m.at(k, i) * f;
            m.set(k, i, v);
        }
    }
    m
}

/// Overcomplete 2-D DCT dictionary for `p×p` patches with `natoms` atoms
/// (the classical K-SVD baseline dictionary; §VI-C "overcomplete DCT").
///
/// Atoms are outer products of 1-D sampled-cosine atoms; `natoms` must be a
/// perfect square ≥ `p²` for the standard construction.
pub fn overcomplete_dct(p: usize, natoms: usize) -> Mat {
    let side = (natoms as f64).sqrt().round() as usize;
    assert_eq!(side * side, natoms, "natoms must be a perfect square");
    assert!(side >= p, "need natoms >= p^2");
    // 1-D overcomplete DCT p×side.
    let mut d1 = Mat::from_fn(p, side, |i, k| {
        ((std::f64::consts::PI / side as f64) * (i as f64 + 0.5) * k as f64).cos()
    });
    // Remove mean from non-DC atoms, then l2-normalize columns.
    for k in 1..side {
        let mean: f64 = (0..p).map(|i| d1.at(i, k)).sum::<f64>() / p as f64;
        for i in 0..p {
            let v = d1.at(i, k) - mean;
            d1.set(i, k, v);
        }
    }
    d1.normalize_cols();
    // 2-D: atom (k1,k2) = outer(d1[:,k1], d1[:,k2]) flattened row-major.
    let mut d = Mat::zeros(p * p, natoms);
    for k1 in 0..side {
        for k2 in 0..side {
            let a = k1 * side + k2;
            for i in 0..p {
                for j in 0..p {
                    d.set(i * p + j, a, d1.at(i, k1) * d1.at(j, k2));
                }
            }
        }
    }
    d.normalize_cols();
    d
}

/// Dense orthonormal Haar wavelet transform matrix (`n = 2^N`).
pub fn haar(n: usize) -> Mat {
    assert!(n.is_power_of_two() && n >= 2);
    // Build recursively: H_1 = [1]; H_{2n} rows = scaled [H_n ⊗ (1,1);
    // Id_n ⊗ (1,-1)].
    let mut h = Mat::from_vec(1, 1, vec![1.0]);
    let mut size = 1;
    while size < n {
        let mut next = Mat::zeros(2 * size, 2 * size);
        let s = 1.0 / 2f64.sqrt();
        for r in 0..size {
            for c in 0..size {
                let v = h.at(r, c) * s;
                if v != 0.0 {
                    next.set(r, 2 * c, v);
                    next.set(r, 2 * c + 1, v);
                }
            }
            next.set(size + r, 2 * r, s);
            next.set(size + r, 2 * r + 1, -s);
        }
        h = next;
        size *= 2;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hadamard_is_orthonormal() {
        for n in [2usize, 4, 8, 32] {
            let h = hadamard(n);
            let hht = h.matmul_nt(&h);
            assert!(hht.rel_fro_err(&Mat::eye(n, n)) < 1e-12, "n={n}");
        }
    }

    #[test]
    fn hadamard_faust_matches_dense() {
        for n in [2usize, 8, 32, 64] {
            let h = hadamard(n);
            let f = hadamard_faust(n);
            assert!(f.to_dense().rel_fro_err(&h) < 1e-12, "n={n}");
            // Butterfly sparsity: exactly 2n nnz per factor (paper Fig. 1).
            for fac in f.factors() {
                assert_eq!(fac.nnz(), 2 * n);
            }
            // RCG = n / (2 log2 n).
            let expected = n as f64 / (2.0 * (n as f64).log2());
            assert!((f.rcg() - expected).abs() < 1e-9);
        }
    }

    #[test]
    fn dct2_is_orthonormal() {
        for n in [4usize, 8, 16] {
            let d = dct2(n);
            assert!(d.matmul_nt(&d).rel_fro_err(&Mat::eye(n, n)) < 1e-10, "n={n}");
        }
    }

    #[test]
    fn overcomplete_dct_shape_and_norms() {
        let d = overcomplete_dct(8, 256);
        assert_eq!(d.shape(), (64, 256));
        for j in 0..256 {
            let n: f64 = d.col(j).iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!((n - 1.0).abs() < 1e-10, "atom {j} norm {n}");
        }
    }

    #[test]
    fn haar_is_orthonormal() {
        for n in [2usize, 4, 16] {
            let h = haar(n);
            assert!(h.matmul_nt(&h).rel_fro_err(&Mat::eye(n, n)) < 1e-12, "n={n}");
        }
    }
}
