//! Coordinate-list sparse format (§II-B storage analysis).

use crate::linalg::Mat;

/// COO sparse matrix: parallel `(row, col, val)` triplets.
#[derive(Clone, Debug)]
pub struct Coo {
    rows: usize,
    cols: usize,
    pub row_idx: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub vals: Vec<f64>,
}

impl Coo {
    /// Empty matrix of the given shape.
    pub fn new(rows: usize, cols: usize) -> Self {
        Coo { rows, cols, row_idx: vec![], col_idx: vec![], vals: vec![] }
    }

    /// Extract non-zeros (|x| > `threshold`) from a dense matrix.
    pub fn from_dense(m: &Mat, threshold: f64) -> Self {
        let mut c = Coo::new(m.rows(), m.cols());
        for i in 0..m.rows() {
            for j in 0..m.cols() {
                let v = m.at(i, j);
                if v.abs() > threshold {
                    c.push(i, j, v);
                }
            }
        }
        c
    }

    /// Append one entry (caller keeps entries unique).
    pub fn push(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.row_idx.push(i as u32);
        self.col_idx.push(j as u32);
        self.vals.push(v);
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Densify.
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for k in 0..self.nnz() {
            m.set(self.row_idx[k] as usize, self.col_idx[k] as usize, self.vals[k]);
        }
        m
    }

    /// Floats stored (paper §II-B1: `s_tot`).
    pub fn storage_floats(&self) -> usize {
        self.nnz()
    }

    /// Integers stored (paper §II-B1: `3 s_tot` — factor + row + col index).
    pub fn storage_ints(&self) -> usize {
        3 * self.nnz()
    }

    /// Total storage in bytes (f64 values, u32 indices — the "floats and
    /// integers" of §II-B made concrete).
    pub fn storage_bytes(&self) -> usize {
        self.nnz() * (8 + 3 * 4)
    }
}
