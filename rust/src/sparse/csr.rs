//! Compressed sparse row format — the FAμST apply hot path.
//!
//! Generic over the engine's [`Scalar`] value type (default `f64`): the
//! structural accessors and `transpose` work for both precisions, while
//! construction, factorization arithmetic, and spgemm stay `f64`-only —
//! an f32 CSR only ever comes from quantizing a learned f64 factor via
//! [`Csr::to_f32`] at plan-build time.

use super::coo::Coo;
use crate::engine::kernel::Scalar;
use crate::linalg::Mat;

/// CSR sparse matrix with [`Scalar`] values (`f64` by default).
#[derive(Clone, Debug)]
pub struct Csr<S = f64> {
    rows: usize,
    cols: usize,
    /// Row pointers, length `rows + 1`.
    pub indptr: Vec<u32>,
    /// Column indices, length `nnz`.
    pub indices: Vec<u32>,
    /// Values, length `nnz`.
    pub vals: Vec<S>,
}

impl<S: Scalar> Csr<S> {
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Densify.
    pub fn to_dense(&self) -> Mat<S> {
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for k in self.indptr[i] as usize..self.indptr[i + 1] as usize {
                m.set(i, self.indices[k] as usize, self.vals[k]);
            }
        }
        m
    }

    /// Sparse transpose (CSR → CSR of the transpose; counting sort, O(nnz)).
    pub fn transpose(&self) -> Csr<S> {
        let nnz = self.nnz();
        let mut counts = vec![0u32; self.cols + 1];
        for &c in &self.indices {
            counts[c as usize + 1] += 1;
        }
        for i in 0..self.cols {
            counts[i + 1] += counts[i];
        }
        let indptr = counts.clone();
        let mut next = counts;
        let mut indices = vec![0u32; nnz];
        let mut vals = vec![S::ZERO; nnz];
        for i in 0..self.rows {
            for k in self.indptr[i] as usize..self.indptr[i + 1] as usize {
                let c = self.indices[k] as usize;
                let pos = next[c] as usize;
                indices[pos] = i as u32;
                vals[pos] = self.vals[k];
                next[c] += 1;
            }
        }
        Csr { rows: self.cols, cols: self.rows, indptr, indices, vals }
    }

    /// Fill fraction `nnz / (rows·cols)`.
    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            0.0
        } else {
            self.nnz() as f64 / (self.rows * self.cols) as f64
        }
    }

    /// Flops for one `spmv` (one multiply + one add per stored entry).
    pub fn flops_per_matvec(&self) -> usize {
        2 * self.nnz()
    }

    /// Reassemble a CSR from its raw arrays **bitwise-verbatim** — the
    /// [`store`](crate::store) load path. Unlike [`Csr::from_coo`] this
    /// never re-sorts or merges, so a persisted factor round-trips with
    /// identical bits; in exchange every structural invariant is checked
    /// (a corrupt file must surface as `Err`, never as UB or a panic in
    /// the apply kernels):
    /// `indptr.len() == rows + 1`, `indptr[0] == 0`, `indptr`
    /// monotonically non-decreasing, `indptr[rows] == nnz`, and every
    /// column index `< cols`.
    pub fn from_raw_parts(
        rows: usize,
        cols: usize,
        indptr: Vec<u32>,
        indices: Vec<u32>,
        vals: Vec<S>,
    ) -> Result<Csr<S>, String> {
        if indptr.len() != rows + 1 {
            return Err(format!("indptr len {} != rows+1 {}", indptr.len(), rows + 1));
        }
        if indptr[0] != 0 {
            return Err(format!("indptr[0] = {} != 0", indptr[0]));
        }
        if indptr.windows(2).any(|w| w[1] < w[0]) {
            return Err("indptr not monotonically non-decreasing".to_string());
        }
        if indices.len() != vals.len() {
            return Err(format!("indices len {} != vals len {}", indices.len(), vals.len()));
        }
        if indptr[rows] as usize != vals.len() {
            return Err(format!("indptr[rows] = {} != nnz {}", indptr[rows], vals.len()));
        }
        if let Some(&bad) = indices.iter().find(|&&c| c as usize >= cols) {
            return Err(format!("column index {bad} out of range (cols = {cols})"));
        }
        Ok(Csr { rows, cols, indptr, indices, vals })
    }
}

impl Csr {
    /// Quantized f32 copy with identical sparsity structure — the serving
    /// tier's one-time plan-build conversion (values round to nearest;
    /// indices/indptr are copied verbatim, so structure and flop counts
    /// match the f64 original exactly).
    pub fn to_f32(&self) -> Csr<f32> {
        Csr {
            rows: self.rows,
            cols: self.cols,
            indptr: self.indptr.clone(),
            indices: self.indices.clone(),
            vals: self.vals.iter().map(|&v| v as f32).collect(),
        }
    }
    /// Build from COO (entries need not be sorted; duplicates are summed).
    pub fn from_coo(coo: &Coo) -> Self {
        let rows = coo.rows();
        let cols = coo.cols();
        let nnz = coo.nnz();
        // Counting sort by row.
        let mut counts = vec![0u32; rows + 1];
        for &r in &coo.row_idx {
            counts[r as usize + 1] += 1;
        }
        for i in 0..rows {
            counts[i + 1] += counts[i];
        }
        let indptr = counts.clone();
        let mut next = counts;
        let mut indices = vec![0u32; nnz];
        let mut vals = vec![0.0; nnz];
        for k in 0..nnz {
            let r = coo.row_idx[k] as usize;
            let pos = next[r] as usize;
            indices[pos] = coo.col_idx[k];
            vals[pos] = coo.vals[k];
            next[r] += 1;
        }
        // Sort each row by column index (insertion sort; rows are short).
        let mut out = Csr { rows, cols, indptr, indices, vals };
        out.sort_rows();
        out.sum_duplicates();
        out
    }

    /// Drop stored entries with `|v| <= threshold` in place. With
    /// `threshold = 0.0` this removes exactly the explicitly-stored zeros,
    /// so `nnz` (and the RC/RCG metrics built on it) counts only true
    /// non-zeros.
    ///
    /// The result is left **canonical**: `indptr` is rebuilt to exactly
    /// `rows + 1` non-decreasing offsets with `indptr[rows] == nnz()`,
    /// surviving entries keep their column-sorted order, rows emptied by
    /// the prune collapse to zero-width ranges, and the backing buffers
    /// release their now-unused slack — so the plan compiler's
    /// flop/byte cost models (which price stages from `nnz()`) never
    /// over-count a pruned factor.
    pub fn prune(&mut self, threshold: f64) {
        let mut new_indptr = vec![0u32; self.rows + 1];
        let mut w = 0usize;
        for i in 0..self.rows {
            for k in self.indptr[i] as usize..self.indptr[i + 1] as usize {
                if self.vals[k].abs() > threshold {
                    self.indices[w] = self.indices[k];
                    self.vals[w] = self.vals[k];
                    w += 1;
                }
            }
            new_indptr[i + 1] = w as u32;
        }
        self.indices.truncate(w);
        self.vals.truncate(w);
        self.indices.shrink_to_fit();
        self.vals.shrink_to_fit();
        self.indptr = new_indptr;
    }

    /// Extract non-zeros (|x| > `threshold`) from a dense matrix.
    pub fn from_dense(m: &Mat, threshold: f64) -> Self {
        let rows = m.rows();
        let cols = m.cols();
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut vals = Vec::new();
        indptr.push(0u32);
        for i in 0..rows {
            for j in 0..cols {
                let v = m.at(i, j);
                if v.abs() > threshold {
                    indices.push(j as u32);
                    vals.push(v);
                }
            }
            indptr.push(indices.len() as u32);
        }
        Csr { rows, cols, indptr, indices, vals }
    }

    fn sort_rows(&mut self) {
        for i in 0..self.rows {
            let lo = self.indptr[i] as usize;
            let hi = self.indptr[i + 1] as usize;
            // Simple index-zip sort.
            let mut pairs: Vec<(u32, f64)> = (lo..hi)
                .map(|k| (self.indices[k], self.vals[k]))
                .collect();
            pairs.sort_by_key(|p| p.0);
            for (off, (c, v)) in pairs.into_iter().enumerate() {
                self.indices[lo + off] = c;
                self.vals[lo + off] = v;
            }
        }
    }

    /// Merge duplicate `(row, col)` entries by summation, dropping results
    /// that are exactly zero (explicitly-stored zeros and exact
    /// cancellations must not inflate `nnz`).
    fn sum_duplicates(&mut self) {
        let mut new_indptr = vec![0u32; self.rows + 1];
        let mut new_indices = Vec::with_capacity(self.indices.len());
        let mut new_vals = Vec::with_capacity(self.vals.len());
        for i in 0..self.rows {
            let lo = self.indptr[i] as usize;
            let hi = self.indptr[i + 1] as usize;
            let mut k = lo;
            while k < hi {
                let c = self.indices[k];
                let mut v = self.vals[k];
                let mut k2 = k + 1;
                while k2 < hi && self.indices[k2] == c {
                    v += self.vals[k2];
                    k2 += 1;
                }
                if v != 0.0 {
                    new_indices.push(c);
                    new_vals.push(v);
                }
                k = k2;
            }
            new_indptr[i + 1] = new_indices.len() as u32;
        }
        self.indptr = new_indptr;
        self.indices = new_indices;
        self.vals = new_vals;
    }

    /// Convert to COO.
    pub fn to_coo(&self) -> Coo {
        let mut coo = Coo::new(self.rows, self.cols);
        for i in 0..self.rows {
            for k in self.indptr[i] as usize..self.indptr[i + 1] as usize {
                coo.push(i, self.indices[k] as usize, self.vals[k]);
            }
        }
        coo
    }

    /// Sparse matrix × dense vector: `y = A x` — O(nnz).
    pub fn spmv(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "spmv dim mismatch");
        let mut y = vec![0.0; self.rows];
        self.spmv_into(x, &mut y);
        y
    }

    /// `spmv` into a caller-provided buffer (allocation-free hot path).
    pub fn spmv_into(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.cols);
        debug_assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            let lo = self.indptr[i] as usize;
            let hi = self.indptr[i + 1] as usize;
            let mut acc = 0.0;
            for k in lo..hi {
                acc += self.vals[k] * x[self.indices[k] as usize];
            }
            y[i] = acc;
        }
    }

    /// Transposed spmv: `y = Aᵀ x` without materializing the transpose.
    pub fn spmv_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows, "spmv_t dim mismatch");
        let mut y = vec![0.0; self.cols];
        self.spmv_t_into(x, &mut y);
        y
    }

    /// `spmv_t` into a caller-provided buffer.
    pub fn spmv_t_into(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.rows);
        debug_assert_eq!(y.len(), self.cols);
        for v in y.iter_mut() {
            *v = 0.0;
        }
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            for k in self.indptr[i] as usize..self.indptr[i + 1] as usize {
                y[self.indices[k] as usize] += xi * self.vals[k];
            }
        }
    }

    /// Sparse × dense: `A B` — O(nnz · B.cols).
    pub fn spmm(&self, b: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, b.cols());
        self.spmm_into(b, &mut out);
        out
    }

    /// `spmm` into a caller-provided buffer.
    pub fn spmm_into(&self, b: &Mat, out: &mut Mat) {
        assert_eq!(b.rows(), self.cols, "spmm dim mismatch");
        assert_eq!(out.shape(), (self.rows, b.cols()));
        let n = b.cols();
        for v in out.data_mut().iter_mut() {
            *v = 0.0;
        }
        for i in 0..self.rows {
            let lo = self.indptr[i] as usize;
            let hi = self.indptr[i + 1] as usize;
            // Split borrow: out row i is disjoint from b.
            let orow_ptr = &mut out.data_mut()[i * n..(i + 1) * n];
            for k in lo..hi {
                let a = self.vals[k];
                let brow = b.row(self.indices[k] as usize);
                for (o, &bv) in orow_ptr.iter_mut().zip(brow) {
                    *o += a * bv;
                }
            }
        }
    }

    /// Transposed sparse × dense: `Aᵀ B`.
    pub fn spmm_t(&self, b: &Mat) -> Mat {
        assert_eq!(b.rows(), self.rows, "spmm_t dim mismatch");
        let n = b.cols();
        let mut out = Mat::zeros(self.cols, n);
        for i in 0..self.rows {
            let brow = b.row(i).to_vec();
            for k in self.indptr[i] as usize..self.indptr[i + 1] as usize {
                let a = self.vals[k];
                let r = self.indices[k] as usize;
                let orow = out.row_mut(r);
                for (o, &bv) in orow.iter_mut().zip(&brow) {
                    *o += a * bv;
                }
            }
        }
        out
    }

    /// Sparse × sparse product `self · other` (Gustavson row-merge with a
    /// dense accumulator + touched-column markers, `O(flops)`). Exact-zero
    /// results (cancellations) are dropped so the product's `nnz` is
    /// honest. Used by the engine planner to fuse adjacent tiny factors.
    pub fn spgemm(&self, other: &Csr) -> Csr {
        assert_eq!(self.cols, other.rows, "spgemm dim mismatch");
        let n = other.cols;
        let mut indptr = Vec::with_capacity(self.rows + 1);
        indptr.push(0u32);
        let mut indices: Vec<u32> = Vec::new();
        let mut vals: Vec<f64> = Vec::new();
        let mut acc = vec![0.0f64; n];
        let mut last_row = vec![u32::MAX; n];
        let mut touched: Vec<u32> = Vec::new();
        for i in 0..self.rows {
            touched.clear();
            for k in self.indptr[i] as usize..self.indptr[i + 1] as usize {
                let a = self.vals[k];
                let r = self.indices[k] as usize;
                for k2 in other.indptr[r] as usize..other.indptr[r + 1] as usize {
                    let c = other.indices[k2] as usize;
                    if last_row[c] != i as u32 {
                        last_row[c] = i as u32;
                        acc[c] = 0.0;
                        touched.push(c as u32);
                    }
                    acc[c] += a * other.vals[k2];
                }
            }
            touched.sort_unstable();
            for &c in &touched {
                let v = acc[c as usize];
                if v != 0.0 {
                    indices.push(c);
                    vals.push(v);
                }
            }
            indptr.push(indices.len() as u32);
        }
        Csr { rows: self.rows, cols: n, indptr, indices, vals }
    }

    /// Frobenius norm.
    pub fn fro(&self) -> f64 {
        self.vals.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Scale all values in place.
    pub fn scale(&mut self, s: f64) {
        for v in &mut self.vals {
            *v *= s;
        }
    }
}
