//! Sparse matrix substrates: COO and CSR.
//!
//! The paper's §II-B storage analysis uses COO (one float + three integers
//! per non-zero across the whole factorization); the hot apply path uses
//! CSR whose `spmv`/`spmm` make the `O(s_tot)` multiplication cost of a
//! FAμST concrete.

#![forbid(unsafe_code)]

mod coo;
mod csr;

pub use coo::Coo;
pub use csr::Csr;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Rng;

    /// Random sparse dense-matrix with `nnz` non-zeros.
    pub(crate) fn random_sparse(rows: usize, cols: usize, nnz: usize, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        let idx = rng.sample_indices(rows * cols, nnz.min(rows * cols));
        for i in idx {
            m.data_mut()[i] = rng.gauss();
        }
        m
    }

    #[test]
    fn coo_csr_dense_roundtrip() {
        let mut rng = Rng::new(41);
        let d = random_sparse(9, 13, 30, &mut rng);
        let coo = Coo::from_dense(&d, 0.0);
        let csr = Csr::from_coo(&coo);
        assert_eq!(coo.nnz(), d.nnz());
        assert_eq!(csr.nnz(), d.nnz());
        assert!(csr.to_dense().rel_fro_err(&d) < 1e-15);
        assert!(coo.to_dense().rel_fro_err(&d) < 1e-15);
        // And back through COO again.
        let coo2 = csr.to_coo();
        assert!(coo2.to_dense().rel_fro_err(&d) < 1e-15);
    }

    #[test]
    fn spmv_matches_dense() {
        let mut rng = Rng::new(42);
        for &(m, n, z) in &[(5usize, 8usize, 12usize), (20, 20, 50), (1, 7, 3), (7, 1, 4)] {
            let d = random_sparse(m, n, z, &mut rng);
            let s = Csr::from_dense(&d, 0.0);
            let x = rng.gauss_vec(n);
            let yd = d.matvec(&x);
            let ys = s.spmv(&x);
            for i in 0..m {
                assert!((yd[i] - ys[i]).abs() < 1e-12);
            }
            let z_in = rng.gauss_vec(m);
            let td = d.matvec_t(&z_in);
            let ts = s.spmv_t(&z_in);
            for j in 0..n {
                assert!((td[j] - ts[j]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn spmm_matches_dense() {
        let mut rng = Rng::new(43);
        let d = random_sparse(6, 9, 20, &mut rng);
        let s = Csr::from_dense(&d, 0.0);
        let b = Mat::randn(9, 4, &mut rng);
        let yd = d.matmul(&b);
        let ys = s.spmm(&b);
        assert!(ys.rel_fro_err(&yd) < 1e-13);
        let c = Mat::randn(6, 5, &mut rng);
        let td = d.t().matmul(&c);
        let ts = s.spmm_t(&c);
        assert!(ts.rel_fro_err(&td) < 1e-13);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(44);
        let d = random_sparse(7, 11, 25, &mut rng);
        let s = Csr::from_dense(&d, 0.0);
        let stt = s.transpose().transpose();
        assert!(stt.to_dense().rel_fro_err(&d) < 1e-15);
        assert!(s.transpose().to_dense().rel_fro_err(&d.t()) < 1e-15);
    }

    #[test]
    fn empty_and_full_matrices() {
        let z = Mat::zeros(4, 5);
        let s = Csr::from_dense(&z, 0.0);
        assert_eq!(s.nnz(), 0);
        let y = s.spmv(&[1.0; 5]);
        assert!(y.iter().all(|&v| v == 0.0));

        let mut rng = Rng::new(45);
        let f = Mat::randn(4, 5, &mut rng);
        let sf = Csr::from_dense(&f, 0.0);
        assert_eq!(sf.nnz(), 20);
        assert!(sf.to_dense().rel_fro_err(&f) < 1e-15);
    }

    #[test]
    fn threshold_drops_small_entries() {
        let d = Mat::from_vec(2, 2, vec![0.5, 1e-12, -2.0, 0.0]);
        let s = Csr::from_dense(&d, 1e-9);
        assert_eq!(s.nnz(), 2);
    }

    #[test]
    fn storage_accounting_matches_paper() {
        // §II-B: COO storage = nnz floats + 3·nnz integers.
        let mut rng = Rng::new(46);
        let d = random_sparse(10, 10, 17, &mut rng);
        let coo = Coo::from_dense(&d, 0.0);
        assert_eq!(coo.storage_floats(), 17);
        assert_eq!(coo.storage_ints(), 3 * 17);
    }

    #[test]
    fn spgemm_matches_dense_product() {
        let mut rng = Rng::new(48);
        for &(m, k, n, z1, z2) in &[(6usize, 7, 8, 15, 18), (10, 3, 10, 12, 9), (4, 4, 4, 16, 16)] {
            let a = random_sparse(m, k, z1, &mut rng);
            let b = random_sparse(k, n, z2, &mut rng);
            let sa = Csr::from_dense(&a, 0.0);
            let sb = Csr::from_dense(&b, 0.0);
            let sp = sa.spgemm(&sb);
            assert!(sp.to_dense().rel_fro_err(&a.matmul(&b)) < 1e-13);
            assert_eq!(sp.nnz(), a.matmul(&b).nnz());
        }
    }

    #[test]
    fn spgemm_drops_exact_cancellations() {
        // [[1, -1]] · [[1], [1]] = [[0]] — the product must have nnz = 0.
        let a = Csr::from_dense(&Mat::from_vec(1, 2, vec![1.0, -1.0]), 0.0);
        let b = Csr::from_dense(&Mat::from_vec(2, 1, vec![1.0, 1.0]), 0.0);
        let p = a.spgemm(&b);
        assert_eq!(p.nnz(), 0);
        assert_eq!(p.rows(), 1);
        assert_eq!(p.cols(), 1);
    }

    #[test]
    fn from_coo_drops_explicit_zeros_and_cancellations() {
        // Regression: explicitly-stored zeros (e.g. from a serialized
        // operator) and duplicates summing to zero must not inflate nnz,
        // which would corrupt the RC/RCG metrics downstream.
        let mut coo = Coo::new(3, 3);
        coo.push(0, 0, 0.0); // explicit zero
        coo.push(1, 1, 2.0);
        coo.push(2, 2, 1.5);
        coo.push(2, 2, -1.5); // duplicate pair cancelling exactly
        let csr = Csr::from_coo(&coo);
        assert_eq!(csr.nnz(), 1);
        assert_eq!(csr.to_dense().at(1, 1), 2.0);
    }

    #[test]
    fn prune_drops_small_entries_in_place() {
        let d = Mat::from_vec(2, 3, vec![0.5, 1e-12, 0.0, -2.0, 3.0, -1e-13]);
        let mut s = Csr::from_dense(&d, 0.0);
        assert_eq!(s.nnz(), 4);
        s.prune(1e-9);
        assert_eq!(s.nnz(), 3);
        let dd = s.to_dense();
        assert_eq!(dd.at(0, 0), 0.5);
        assert_eq!(dd.at(1, 0), -2.0);
        assert_eq!(dd.at(1, 1), 3.0);
        let x = [1.0, 1.0, 1.0];
        let y = s.spmv(&x);
        assert!((y[0] - 0.5).abs() < 1e-15);
        assert!((y[1] - 1.0).abs() < 1e-15);
    }

    #[test]
    fn prune_after_from_coo_with_zeros_and_duplicates_is_canonical() {
        // Regression (ISSUE 5): pruning must leave the matrix canonical —
        // indptr rebuilt and consistent with nnz(), emptied rows collapsed
        // to zero-width ranges, per-row column order intact — so plan
        // cost models never over-count a pruned factor.
        let mut coo = Coo::new(4, 5);
        coo.push(0, 3, 0.5);
        coo.push(0, 1, 0.0); // explicit zero (dropped by from_coo)
        coo.push(0, 1, 1e-12); // survives from_coo, pruned below
        coo.push(1, 4, 1e-12); // row 1 empties entirely after prune
        coo.push(1, 4, 1e-12); // duplicate: sums to 2e-12, still tiny
        coo.push(2, 2, 1.0);
        coo.push(2, 0, -2.0);
        coo.push(3, 3, 1.5);
        coo.push(3, 3, 1.5); // duplicate summed -> 3.0
        let mut s = Csr::from_coo(&coo);
        assert_eq!(s.nnz(), 6);
        s.prune(1e-9);
        // Canonical structure.
        assert_eq!(s.indptr.len(), s.rows() + 1);
        assert_eq!(s.indptr[0], 0);
        assert_eq!(*s.indptr.last().unwrap() as usize, s.nnz());
        for w in s.indptr.windows(2) {
            assert!(w[0] <= w[1], "indptr must be non-decreasing");
        }
        assert_eq!(s.indices.len(), s.nnz());
        assert_eq!(s.vals.len(), s.nnz());
        for i in 0..s.rows() {
            let row = &s.indices[s.indptr[i] as usize..s.indptr[i + 1] as usize];
            for w in row.windows(2) {
                assert!(w[0] < w[1], "row {i} lost its column order");
            }
        }
        // Emptied row collapses; survivors and nnz-derived metrics agree.
        assert_eq!(s.indptr[1], s.indptr[2], "row 1 must be empty");
        assert_eq!(s.nnz(), 4);
        assert_eq!(s.flops_per_matvec(), 2 * 4);
        assert!((s.density() - 4.0 / 20.0).abs() < 1e-15);
        let mut want = Mat::zeros(4, 5);
        want.set(0, 3, 0.5);
        want.set(2, 0, -2.0);
        want.set(2, 2, 1.0);
        want.set(3, 3, 3.0);
        assert!(s.to_dense().rel_fro_err(&want) < 1e-15);
        // Idempotent, and a full prune leaves a canonical empty matrix.
        let before = (s.indptr.clone(), s.indices.clone(), s.vals.clone());
        s.prune(1e-9);
        assert_eq!(before.0, s.indptr);
        assert_eq!(before.1, s.indices);
        assert_eq!(before.2, s.vals);
        s.prune(f64::INFINITY);
        assert_eq!(s.nnz(), 0);
        assert_eq!(*s.indptr.last().unwrap(), 0);
        assert_eq!(s.indptr.len(), 5);
    }

    #[test]
    fn csr_spmm_into_reuses_buffer() {
        let mut rng = Rng::new(47);
        let d = random_sparse(6, 7, 15, &mut rng);
        let s = Csr::from_dense(&d, 0.0);
        let b = Mat::randn(7, 3, &mut rng);
        let mut out = Mat::zeros(6, 3);
        s.spmm_into(&b, &mut out);
        assert!(out.rel_fro_err(&d.matmul(&b)) < 1e-13);
    }

    /// Part of the miri-scoped suite (`cargo miri test miri_`): one small
    /// end-to-end construction chain (dense → COO → CSR → transpose →
    /// dense, plus an spmv) sized so the interpreter walks every indexing
    /// path in seconds, not minutes.
    #[test]
    fn miri_csr_construction_round_trip() {
        let d = Mat::from_vec(
            3,
            4,
            vec![1.0, 0.0, -2.0, 0.0, 0.0, 3.0, 0.0, 0.5, 4.0, 0.0, 0.0, 0.0],
        );
        let coo = Coo::from_dense(&d, 0.0);
        let csr = Csr::from_coo(&coo);
        assert_eq!(csr.nnz(), 5);
        assert!(csr.to_dense().rel_fro_err(&d) < 1e-15);
        assert!(csr.transpose().to_dense().rel_fro_err(&d.t()) < 1e-15);
        let y = csr.spmv(&[1.0, 1.0, 1.0, 1.0]);
        let want = d.matvec(&[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(y, want);
        let mut pruned = Csr::from_dense(&d, 0.0);
        pruned.prune(2.5);
        assert_eq!(pruned.nnz(), 2);
    }
}
