//! Image-processing substrate for the denoising experiment (paper §VI-C).
//!
//! The paper uses 12 standard 512×512 grey images ([49]); those files are
//! not redistributable, so [`corpus`] generates 12 procedural images
//! spanning the same regimes — piecewise-smooth "cartoon" content, heavy
//! texture ("mandrill-like"), and smooth portrait-like gradients — which is
//! what drives the σ-dependent FAμST-vs-DDL trade-off of Fig. 12 (see
//! DESIGN.md §6). Grayscale images are `Mat`s with values in `[0, 255]`.

#![forbid(unsafe_code)]

use crate::linalg::Mat;
use crate::rng::Rng;
use crate::solvers::{omp, LinOp};
use std::io::Write;
use std::path::Path;

/// Peak signal-to-noise ratio in dB (peak = 255).
pub fn psnr(img: &Mat, reference: &Mat) -> f64 {
    assert_eq!(img.shape(), reference.shape());
    let n = (img.rows() * img.cols()) as f64;
    let mse = img.sub(reference).fro2() / n;
    10.0 * (255.0 * 255.0 / mse.max(1e-300)).log10()
}

/// Add iid Gaussian noise of standard deviation `sigma`.
pub fn add_noise(img: &Mat, sigma: f64, rng: &mut Rng) -> Mat {
    let mut out = img.clone();
    for v in out.data_mut() {
        *v += sigma * rng.gauss();
    }
    out
}

/// Clamp pixel values into `[0, 255]`.
pub fn clamp_pixels(img: &mut Mat) {
    for v in img.data_mut() {
        *v = v.clamp(0.0, 255.0);
    }
}

// ---------------------------------------------------------------- corpus

/// Kinds of procedural test images.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ImageKind {
    /// Piecewise-constant polygons + circles (cartoon; like "Peppers").
    Cartoon,
    /// High-frequency band-pass texture (like "Mandrill").
    Texture,
    /// Smooth large-scale gradients + a few edges (like "WomanDarkHair").
    Smooth,
    /// Mixed: smooth background with textured regions (like "Pirate").
    Mixed,
}

/// Generate one procedural image of the given kind and size.
pub fn make_image(kind: ImageKind, size: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    let s = size as f64;
    match kind {
        ImageKind::Cartoon => {
            // Background gradient + random constant disks and half-planes.
            let mut img = Mat::from_fn(size, size, |i, j| {
                60.0 + 60.0 * (i as f64 / s) + 20.0 * (j as f64 / s)
            });
            for _ in 0..10 {
                let cx = rng.range(0.0, s);
                let cy = rng.range(0.0, s);
                let r = rng.range(s * 0.05, s * 0.25);
                let level = rng.range(20.0, 235.0);
                for i in 0..size {
                    for j in 0..size {
                        let dx = i as f64 - cx;
                        let dy = j as f64 - cy;
                        if dx * dx + dy * dy < r * r {
                            img.set(i, j, level);
                        }
                    }
                }
            }
            img
        }
        ImageKind::Texture => {
            // Sum of oriented sinusoids + granular noise → dense texture.
            let mut freqs = Vec::new();
            for _ in 0..8 {
                freqs.push((
                    rng.range(0.1, 0.9),
                    rng.range(0.1, 0.9),
                    rng.range(0.0, std::f64::consts::TAU),
                    rng.range(10.0, 30.0),
                ));
            }
            let mut img = Mat::from_fn(size, size, |i, j| {
                let mut v = 128.0;
                for &(fx, fy, ph, amp) in &freqs {
                    v += amp * (fx * i as f64 + fy * j as f64 + ph).sin();
                }
                v
            });
            for v in img.data_mut() {
                *v += rng.gauss() * 12.0;
            }
            clamp_pixels(&mut img);
            img
        }
        ImageKind::Smooth => {
            // Sum of a few broad Gaussian bumps (portrait-like lighting).
            let mut bumps = Vec::new();
            for _ in 0..5 {
                bumps.push((
                    rng.range(0.0, s),
                    rng.range(0.0, s),
                    rng.range(s * 0.2, s * 0.6),
                    rng.range(-80.0, 110.0),
                ));
            }
            let mut img = Mat::from_fn(size, size, |i, j| {
                let mut v = 110.0;
                for &(cx, cy, w, amp) in &bumps {
                    let dx = i as f64 - cx;
                    let dy = j as f64 - cy;
                    v += amp * (-(dx * dx + dy * dy) / (2.0 * w * w)).exp();
                }
                v
            });
            clamp_pixels(&mut img);
            img
        }
        ImageKind::Mixed => {
            // Smooth base, textured band, one strong edge.
            let base = make_image(ImageKind::Smooth, size, seed ^ 0xABCD);
            let tex = make_image(ImageKind::Texture, size, seed ^ 0x1234);
            let split = size / 2 + (rng.below(size / 4)) as usize;
            Mat::from_fn(size, size, |i, j| {
                if j > split {
                    0.35 * base.at(i, j) + 0.65 * tex.at(i, j)
                } else {
                    base.at(i, j)
                }
            })
        }
    }
}

/// The 12-image corpus standing in for the paper's standard database:
/// 4 kinds × 3 seeds, named for reporting.
pub fn corpus(size: usize) -> Vec<(String, Mat)> {
    let kinds = [
        (ImageKind::Cartoon, "cartoon"),
        (ImageKind::Texture, "texture"),
        (ImageKind::Smooth, "smooth"),
        (ImageKind::Mixed, "mixed"),
    ];
    let mut out = Vec::with_capacity(12);
    for (kind, name) in kinds {
        for v in 0..3u64 {
            out.push((format!("{name}_{v}"), make_image(kind, size, 1000 + v * 17)));
        }
    }
    out
}

// ----------------------------------------------------------------- PGM IO

/// Write a grayscale image as binary PGM (P5).
pub fn write_pgm(img: &Mat, path: impl AsRef<Path>) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    write!(f, "P5\n{} {}\n255\n", img.cols(), img.rows())?;
    let bytes: Vec<u8> = img
        .data()
        .iter()
        .map(|&v| v.clamp(0.0, 255.0).round() as u8)
        .collect();
    f.write_all(&bytes)
}

/// Read a binary PGM (P5) image.
pub fn read_pgm(path: impl AsRef<Path>) -> std::io::Result<Mat> {
    let buf = std::fs::read(path)?;
    // Parse header tokens: P5, width, height, maxval.
    let mut pos = 0usize;
    let mut tokens = Vec::new();
    while tokens.len() < 4 && pos < buf.len() {
        // skip whitespace + comments
        while pos < buf.len() && (buf[pos] as char).is_whitespace() {
            pos += 1;
        }
        if pos < buf.len() && buf[pos] == b'#' {
            while pos < buf.len() && buf[pos] != b'\n' {
                pos += 1;
            }
            continue;
        }
        let start = pos;
        while pos < buf.len() && !(buf[pos] as char).is_whitespace() {
            pos += 1;
        }
        tokens.push(String::from_utf8_lossy(&buf[start..pos]).to_string());
    }
    if tokens.len() < 4 || tokens[0] != "P5" {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "not a P5 PGM",
        ));
    }
    let w: usize = tokens[1].parse().unwrap_or(0);
    let h: usize = tokens[2].parse().unwrap_or(0);
    pos += 1; // single whitespace after maxval
    let data = &buf[pos..];
    if data.len() < w * h {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "truncated PGM",
        ));
    }
    Ok(Mat::from_fn(h, w, |i, j| data[i * w + j] as f64))
}

// --------------------------------------------------------------- patches

/// Extract `count` random `p×p` patches as columns of a `p² × count`
/// matrix (the dictionary-learning training set; paper uses 10 000).
pub fn random_patches(img: &Mat, p: usize, count: usize, rng: &mut Rng) -> Mat {
    assert!(img.rows() >= p && img.cols() >= p);
    let mut out = Mat::zeros(p * p, count);
    for c in 0..count {
        let i0 = rng.below(img.rows() - p + 1);
        let j0 = rng.below(img.cols() - p + 1);
        for di in 0..p {
            for dj in 0..p {
                out.set(di * p + dj, c, img.at(i0 + di, j0 + dj));
            }
        }
    }
    out
}

/// Patch-based denoising: sparse-code every `p×p` patch (stride
/// `stride`) in the dictionary with `k` atoms, reconstruct, and average
/// overlaps. Per-patch DC (mean) is removed before coding and restored
/// after, as in standard K-SVD denoising pipelines.
pub fn denoise(img: &Mat, dict: &dyn LinOp, p: usize, k: usize, stride: usize) -> Mat {
    let (h, w) = img.shape();
    assert!(h >= p && w >= p);
    let mut acc = Mat::zeros(h, w);
    let mut weight = Mat::zeros(h, w);
    let mut patch = vec![0.0; p * p];
    // Pre-compute dictionary column norms once for correlation scaling.
    let norms: Vec<f64> = (0..dict.cols())
        .map(|j| {
            let c = dict.column(j);
            c.iter().map(|x| x * x).sum::<f64>().sqrt()
        })
        .collect();
    let mut rows: Vec<usize> = (0..=(h - p)).step_by(stride).collect();
    if *rows.last().unwrap() != h - p {
        rows.push(h - p);
    }
    let mut cols: Vec<usize> = (0..=(w - p)).step_by(stride).collect();
    if *cols.last().unwrap() != w - p {
        cols.push(w - p);
    }
    for &i0 in &rows {
        for &j0 in &cols {
            // Extract + de-mean.
            let mut mean = 0.0;
            for di in 0..p {
                for dj in 0..p {
                    let v = img.at(i0 + di, j0 + dj);
                    patch[di * p + dj] = v;
                    mean += v;
                }
            }
            mean /= (p * p) as f64;
            for v in patch.iter_mut() {
                *v -= mean;
            }
            // Sparse code with k atoms.
            let code = omp(dict, &patch, k, Some(&norms));
            // Reconstruct.
            let mut recon = vec![mean; p * p];
            for (&j, &c) in code.support.iter().zip(&code.coefs) {
                let atom = dict.column(j);
                for (r, &a) in recon.iter_mut().zip(&atom) {
                    *r += c * a;
                }
            }
            for di in 0..p {
                for dj in 0..p {
                    let v = acc.at(i0 + di, j0 + dj) + recon[di * p + dj];
                    acc.set(i0 + di, j0 + dj, v);
                    let wv = weight.at(i0 + di, j0 + dj) + 1.0;
                    weight.set(i0 + di, j0 + dj, wv);
                }
            }
        }
    }
    let mut out = Mat::from_fn(h, w, |i, j| {
        let wv = weight.at(i, j);
        if wv > 0.0 {
            acc.at(i, j) / wv
        } else {
            img.at(i, j)
        }
    });
    clamp_pixels(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn psnr_identical_is_huge_and_noise_reduces_it() {
        let img = make_image(ImageKind::Smooth, 64, 1);
        assert!(psnr(&img, &img) > 100.0);
        let mut rng = Rng::new(2);
        let noisy = add_noise(&img, 20.0, &mut rng);
        let p = psnr(&noisy, &img);
        // PSNR of σ=20 noise ≈ 20·log10(255/20) ≈ 22.1 dB.
        assert!((p - 22.1).abs() < 1.0, "psnr={p}");
    }

    #[test]
    fn corpus_has_12_images_with_valid_range() {
        let c = corpus(32);
        assert_eq!(c.len(), 12);
        for (name, img) in &c {
            assert_eq!(img.shape(), (32, 32), "{name}");
            for &v in img.data() {
                assert!((-1.0..=256.0).contains(&v), "{name}: pixel {v}");
            }
        }
    }

    #[test]
    fn image_kinds_have_different_roughness() {
        // Texture should have much higher high-frequency energy than Smooth.
        let rough = |img: &Mat| {
            let mut e = 0.0;
            for i in 0..img.rows() - 1 {
                for j in 0..img.cols() - 1 {
                    let dx = img.at(i + 1, j) - img.at(i, j);
                    let dy = img.at(i, j + 1) - img.at(i, j);
                    e += dx * dx + dy * dy;
                }
            }
            e
        };
        let t = make_image(ImageKind::Texture, 64, 3);
        let s = make_image(ImageKind::Smooth, 64, 3);
        assert!(rough(&t) > 10.0 * rough(&s));
    }

    #[test]
    fn pgm_roundtrip() {
        let img = make_image(ImageKind::Cartoon, 40, 4);
        let dir = std::env::temp_dir().join("faust_img_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.pgm");
        write_pgm(&img, &path).unwrap();
        let back = read_pgm(&path).unwrap();
        assert_eq!(back.shape(), img.shape());
        // Quantization to u8: max error 0.5.
        assert!(img.sub(&back).max_abs() <= 0.5 + 1e-9);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn random_patches_shape_and_content() {
        let img = make_image(ImageKind::Mixed, 48, 5);
        let mut rng = Rng::new(6);
        let p = random_patches(&img, 8, 50, &mut rng);
        assert_eq!(p.shape(), (64, 50));
        // Every patch value exists in the image range.
        for &v in p.data() {
            assert!((0.0..=255.0).contains(&v));
        }
    }

    #[test]
    fn denoising_with_dct_improves_psnr() {
        let img = make_image(ImageKind::Smooth, 48, 7);
        let mut rng = Rng::new(8);
        let noisy = add_noise(&img, 25.0, &mut rng);
        let d = crate::transforms::overcomplete_dct(8, 64);
        let den = denoise(&noisy, &d, 8, 4, 4);
        let before = psnr(&noisy, &img);
        let after = psnr(&den, &img);
        assert!(
            after > before + 2.0,
            "denoising didn't help: {before:.2} -> {after:.2} dB"
        );
    }
}
