//! Tiny hand-rolled CLI argument parser (clap is not in the offline vendor
//! set). Supports `faust <subcommand> [--key value ...] [--flag]`.

#![forbid(unsafe_code)]

use std::collections::HashMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse(mut argv: impl Iterator<Item = String>) -> Result<Args, String> {
        let mut args = Args::default();
        let mut pending_key: Option<String> = None;
        if let Some(first) = argv.next() {
            if first.starts_with("--") {
                pending_key = Some(first.trim_start_matches('-').to_string());
            } else {
                args.subcommand = Some(first);
            }
        }
        for a in argv {
            if let Some(k) = pending_key.take() {
                if a.starts_with("--") {
                    // previous was a flag
                    args.flags.push(k);
                    pending_key = Some(a.trim_start_matches('-').to_string());
                } else {
                    args.opts.insert(k, a);
                }
            } else if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else {
                    pending_key = Some(stripped.to_string());
                }
            } else {
                return Err(format!("unexpected positional argument '{a}'"));
            }
        }
        if let Some(k) = pending_key {
            args.flags.push(k);
        }
        Ok(args)
    }

    /// Get an option with a default.
    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.opts
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Get a required string option.
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// Was a boolean flag present?
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
            || self.opts.get(key).map(|v| v == "true").unwrap_or(false)
    }
}

/// Top-level usage text.
pub const USAGE: &str = "\
faust — Flexible Approximate Multi-layer Sparse Transforms
(reproduction of Le Magoarou & Gribonval, IEEE JSTSP 2016)

USAGE: faust <subcommand> [--key value ...]

SUBCOMMANDS:
  hadamard    --n 32 [--save out.faust] [--threads N]
              reverse-engineer the Hadamard transform (paper §IV-C)
  factorize   --rows R --cols C --j J --k K --s S [--rho 0.8] [--seed 0]
              [--threads N]
              hierarchically factorize a synthetic MEG-like operator on
              an N-thread ExecCtx (0 / omitted = process default)
  fleet       --ops 8 --n 32 [--threads 4]
              factorize a fleet of operators *concurrently* on one shared
              ctx (cross-operator batched PALM sweeps, per-operator
              convergence) vs the same jobs sequentially; verifies the
              fleet is bitwise identical to the solo runs and reports the
              throughput speedup + fusion counters
  dict        --m 32 --atoms 64 --samples 400 [--sparsity 4] [--j 3]
              [--iters 10] [--threads N] [--save out.faust]
              K-SVD + hierarchical FAuST dictionary learning (paper §VI)
              on planted k-sparse data, on a shared ExecCtx
  localize    --sensors 204 --sources 1024 --trials 100 --rcg-target 6
              [--threads N]
              source-localization experiment (paper Fig. 9, scaled)
  denoise     --size 128 --sigma 30 --atoms 128 [--stride 2] [--threads N]
              FAuST vs K-SVD vs DCT image denoising (paper Fig. 12, scaled)
  serve       --n 64 [--requests 10000] [--batch 32] [--workers 2]
              [--threads 2] [--shards 1] [--store DIR] [--adaptive-batch]
              [--factorize] [--factorize-fleet N] [--listen HOST:PORT]
              [--repl] [--precision f64|f32|auto[:EPS]] [--online-learn]
              [--online-passes 24] [--online-drift 0.01]
              run the operator-serving coordinator on a Hadamard FAuST,
              planned + parallelized by the apply engine.
              --adaptive-batch sizes each operator's batches from its
              plan's flop/byte profile instead of the fixed --batch;
              --precision selects the serving tier: f64 (default,
              bitwise-stable master), f32 (serve every operator's
              quantized generation when it has one), or auto[:EPS]
              (serve f32 per operator only when its measured probe
              error fits the budget; bare auto means auto:1e-6);
              --shards N splits the coordinator into N independent
              worker pools: the registry pins each operator to a shard
              (cost-balanced, rebalanced on retire) and idle shards
              steal whole flush jobs — bitwise identical to --shards 1
              by the engine's thread-invariance contract;
              --store DIR makes the fleet durable: snapshots present in
              DIR warm-restore at startup (zero re-factorization), an
              empty DIR gets a cold snapshot, and shutdown writes a
              final one (CRC-sealed versioned files, torn/corrupt
              snapshots are skipped with a typed report — see store);
              --factorize starts serving the reference butterfly, then
              refactorizes on-line on the serving engine's ctx and
              hot-swaps the learned operator in mid-traffic (registry
              swap_epoch, zero stall); --factorize-fleet N additionally
              serves N operators op0..op{N-1} and refactorizes them all
              *concurrently* on the serving engine (cross-operator
              batched sweeps), epoch-swapping each one the moment its
              own factorization finishes; --online-learn turns on
              streaming factorization (palm::online): a learner
              warm-started from the served generation's factors and λ
              ingests observed columns of a slowly rotating true
              operator (--online-drift rad/pass, --online-passes full
              passes), updates the sparse factors by weighted
              mini-batch PALM sweeps on a running surrogate, and
              epoch-swaps each improved generation into the live
              registry (stats grows online batch/column/swap counters
              and a drift gauge); --listen puts the TCP ingress
              front end (length-prefixed wire protocol, admission
              control, QoS deadline classes — see server::wire) in
              front of the coordinator so remote `faust client` traffic
              is served alongside; --repl drops into an interactive
              operator console:
                ops | ops add <name> <n> | ops swap <name> |
                ops rm <name> | apply <name> | stats | quit
              (stats includes the ingress accepted/shed-per-class/
              connection counters when --listen is active, plus
              per-precision apply counts and each operator's serving
              precision with its measured f32 error)
  client      --addr HOST:PORT [--op faust] [--n 64] [--rate 5000]
              [--requests 20000] [--class all|interactive|standard|bulk]
              [--seed 42] [--dtype f64|f32]
              open-loop Poisson load client against a serve --listen
              ingress: paces sends by an absolute arrival schedule
              (never waits for responses), reports per-class p50/p99/
              p999 latency and shed rates; exits non-zero on any
              misrouted or protocol failure
  engine      --n 1024 [--threads 4] [--batch 32] [--plan dump]
              compile a cost-modeled execution plan, optionally dump it,
              and time planned/pooled apply vs the naive factor chain
  runtime     [--artifacts artifacts]
              check PJRT artifacts load + execute, compare vs rust-native
              (needs --features pjrt,pjrt-xla plus the vendored xla/anyhow
              deps uncommented in rust/Cargo.toml; plain --features pjrt
              compiles a stub backend that reports unavailability)
  help        print this message
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["hadamard", "--n", "64", "--save", "x.faust"]);
        assert_eq!(a.subcommand.as_deref(), Some("hadamard"));
        assert_eq!(a.get("n", 0usize), 64);
        assert_eq!(a.get_str("save"), Some("x.faust"));
    }

    #[test]
    fn equals_syntax_and_flags() {
        let a = parse(&["serve", "--n=32", "--verbose"]);
        assert_eq!(a.get("n", 0usize), 32);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["denoise"]);
        assert_eq!(a.get("sigma", 30.0), 30.0);
    }

    #[test]
    fn rejects_stray_positional() {
        let e = Args::parse(["hadamard", "oops"].iter().map(|s| s.to_string()));
        assert!(e.is_err());
    }
}
