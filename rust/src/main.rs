//! `faust` CLI — drive every subsystem of the reproduction from one binary.

#![forbid(unsafe_code)]

use faust::bench_util::{fmt, open_loop_load, OpenLoopConfig, Table};
use faust::cli::{Args, USAGE};
use faust::coordinator::{
    engine_ops, AdaptiveBatchConfig, BatchOp, Coordinator, CoordinatorConfig,
    OnlineLearnConfig, OnlineLearnerTask, Precision, QosClass, RegistryError,
};
use faust::faust::Faust;
use faust::palm::online::{OnlineConfig, OnlinePalm};
use faust::palm::{FactorState, PalmConfig};
use faust::prox::Constraint;
use faust::server::wire::Dtype;
use faust::server::{Server, ServerConfig};
use faust::dictlearn::{faust_dictionary_learning_with_ctx, KsvdConfig};
use faust::engine::{ApplyEngine, EngineConfig, ExecCtx, FleetCtx, PlanConfig};
use faust::hierarchical::{factorize_with_ctx, HierarchicalConfig};
use faust::image::{add_noise, corpus, denoise, psnr, random_patches};
use faust::linalg::Mat;
use faust::meg::{localization_experiment, meg_model};
use faust::rng::Rng;
use faust::transforms::{hadamard, hadamard_faust, overcomplete_dct};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Offline-friendly error type (`anyhow` is reserved for the `pjrt`
/// feature set; the default build has zero dependencies).
type Result<T> = std::result::Result<T, Box<dyn std::error::Error>>;

fn err(msg: impl Into<String>) -> Box<dyn std::error::Error> {
    msg.into().into()
}

/// `--threads N` → an [`ExecCtx`] with its own N-thread pool; `0` (the
/// default) → the process-default ctx shared with the serving engine.
fn ctx_for(threads: usize) -> ExecCtx {
    if threads == 0 {
        ExecCtx::global().clone()
    } else {
        ExecCtx::new(threads)
    }
}

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("hadamard") => cmd_hadamard(&args),
        Some("factorize") => cmd_factorize(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("dict") => cmd_dict(&args),
        Some("localize") => cmd_localize(&args),
        Some("denoise") => cmd_denoise(&args),
        Some("serve") => cmd_serve(&args),
        Some("client") => cmd_client(&args),
        Some("engine") => cmd_engine(&args),
        Some("runtime") => cmd_runtime(&args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown subcommand '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// §IV-C: reverse-engineer the Hadamard transform.
fn cmd_hadamard(args: &Args) -> Result<()> {
    let n: usize = args.get("n", 32);
    if !n.is_power_of_two() || n < 4 {
        return Err(err("--n must be a power of two ≥ 4"));
    }
    let ctx = ctx_for(args.get("threads", 0));
    let a = hadamard(n);
    let cfg = HierarchicalConfig::hadamard(n);
    println!(
        "factorizing the {n}x{n} Hadamard matrix into {} factors ({} ctx threads)...",
        cfg.n_factors(),
        ctx.n_threads()
    );
    let t0 = Instant::now();
    let fst = factorize_with_ctx(&ctx, &a, &cfg);
    let dt = t0.elapsed();
    let rel = fst.relative_error_fro(&a);
    let reference = hadamard_faust(n);
    println!("  time              : {:.2?}", dt);
    println!("  relative error    : {rel:.3e}");
    println!("  s_tot             : {} (reference butterfly: {})", fst.s_tot(), reference.s_tot());
    println!("  RCG               : {:.2} (reference: {:.2})", fst.rcg(), reference.rcg());
    if let Some(path) = args.get_str("save") {
        fst.save(path)?;
        println!("  saved to {path}");
    }
    Ok(())
}

/// Hierarchical factorization of a synthetic MEG-like operator.
fn cmd_factorize(args: &Args) -> Result<()> {
    let rows: usize = args.get("rows", 128);
    let cols: usize = args.get("cols", 1024);
    let j: usize = args.get("j", 4);
    let k: usize = args.get("k", 10);
    let s: usize = args.get("s", 2 * rows);
    let rho: f64 = args.get("rho", 0.8);
    let seed: u64 = args.get("seed", 0);
    let ctx = ctx_for(args.get("threads", 0));
    let model = meg_model(rows, cols, seed);
    let cfg = HierarchicalConfig::meg(rows, cols, j, k, s, rho, 1.4 * (rows * rows) as f64);
    println!(
        "factorizing {rows}x{cols} synthetic MEG gain (J={j}, k={k}, s={s}, rho={rho}, \
         {} ctx threads)...",
        ctx.n_threads()
    );
    let t0 = Instant::now();
    let fst = factorize_with_ctx(&ctx, &model.gain, &cfg);
    let mut rng = Rng::new(seed ^ 1);
    let re = fst.relative_error_spectral(&model.gain, &mut rng);
    println!("  time           : {:.2?}", t0.elapsed());
    println!("  RE (spectral)  : {re:.4}");
    println!("  RCG            : {:.2}", fst.rcg());
    println!("  s_tot          : {}", fst.s_tot());
    if let Some(path) = args.get_str("save") {
        fst.save(path)?;
        println!("  saved to {path}");
    }
    Ok(())
}

/// Fleet factorization: factorize `--ops` operators *concurrently* on one
/// shared ctx (cross-operator batched PALM sweeps) and compare against
/// the same jobs run sequentially — the paper's many-operators deployment
/// (§V: one gain matrix per subject; §VI: one dictionary per class).
/// Verifies the fleet results are bitwise identical to the solo runs.
fn cmd_fleet(args: &Args) -> Result<()> {
    let ops: usize = args.get("ops", 8);
    let n: usize = args.get("n", 32);
    let threads: usize = args.get("threads", 4);
    if !n.is_power_of_two() || n < 8 {
        return Err(err("--n must be a power of two ≥ 8"));
    }
    if ops == 0 {
        return Err(err("--ops must be ≥ 1"));
    }
    let ctx = ctx_for(threads.max(1));
    println!(
        "fleet factorization: {ops} × {n}x{n} Hadamard, {} ctx threads",
        ctx.n_threads()
    );
    // Shared protocol with benches/fleet_scaling.rs — one harness, so the
    // CLI and the CI-gated bench cannot drift apart.
    let cmp = faust::bench_util::fleet_compare(ops, n, &ctx);
    let mut table = Table::new(&["mode", "wall_s", "ops/s", "speedup"]);
    table.row(&[
        "sequential".into(),
        format!("{:.3}", cmp.seq_s),
        fmt(ops as f64 / cmp.seq_s),
        fmt(1.0),
    ]);
    table.row(&[
        "fleet".into(),
        format!("{:.3}", cmp.fleet_s),
        fmt(ops as f64 / cmp.fleet_s),
        fmt(cmp.speedup()),
    ]);
    table.print();
    let m = &cmp.metrics;
    println!(
        "  bitwise identical to solo runs : {}\n  max relative error             : \
         {:.2e}\n  fused gemms                    : {} (in {} fused dispatches, \
         {} solo)\n  batched power iterations       : {}",
        cmp.identical, cmp.max_rel_err, m.fused_gemms, m.fused_calls, m.solo_gemms,
        m.spectral_jobs
    );
    if !cmp.identical {
        return Err(err("fleet factorization diverged from the solo runs"));
    }
    Ok(())
}

/// Paper Fig. 9 (scaled): source localization with M vs FAuST M̂.
fn cmd_localize(args: &Args) -> Result<()> {
    let sensors: usize = args.get("sensors", 128);
    let sources: usize = args.get("sources", 2048);
    let trials: usize = args.get("trials", 100);
    let j: usize = args.get("j", 4);
    let k: usize = args.get("k", 10);
    let seed: u64 = args.get("seed", 0);
    let ctx = ctx_for(args.get("threads", 0));
    println!("building synthetic MEG model {sensors}x{sources}...");
    let model = meg_model(sensors, sources, seed);
    let cfg = HierarchicalConfig::meg(
        sensors,
        sources,
        j,
        k,
        2 * sensors,
        0.8,
        1.4 * (sensors * sensors) as f64,
    );
    println!("factorizing (J={j}, k={k})...");
    let fst = factorize_with_ctx(&ctx, &model.gain, &cfg);
    let mut rng = Rng::new(seed ^ 2);
    println!(
        "  FAuST: RCG={:.1}, RE={:.4}",
        fst.rcg(),
        fst.relative_error_spectral(&model.gain, &mut rng)
    );
    let mut table = Table::new(&["separation", "matrix", "median(cm)", "q3(cm)", "exact%"]);
    for (dmin, dmax, label) in [(1.0, 5.0, "1-5cm"), (5.0, 8.0, "5-8cm"), (8.0, 100.0, ">8cm")] {
        let backends = [
            ("M (dense)", &model.gain as &dyn faust::solvers::LinOp),
            ("M^ (faust)", &fst),
        ];
        for (name, op) in backends {
            let stats = localization_experiment(&model, op, trials, dmin, dmax, seed ^ 3);
            table.row(&[
                label.to_string(),
                name.to_string(),
                fmt(stats.median()),
                fmt(stats.quantile(0.75)),
                format!("{:.0}", stats.exact_rate() * 100.0),
            ]);
        }
    }
    table.print();
    Ok(())
}

/// Paper Fig. 12 (scaled): denoising with FAuST vs K-SVD vs DCT dictionaries.
fn cmd_denoise(args: &Args) -> Result<()> {
    let size: usize = args.get("size", 128);
    let sigma: f64 = args.get("sigma", 30.0);
    let atoms: usize = args.get("atoms", 128);
    let stride: usize = args.get("stride", 2);
    let seed: u64 = args.get("seed", 0);
    let ctx = ctx_for(args.get("threads", 0));
    let p = 8usize;
    let imgs = corpus(size);
    let (name, img) = &imgs[args.get("image", 9usize).min(imgs.len() - 1)];
    println!("image '{name}' ({size}x{size}), sigma={sigma}");
    let mut rng = Rng::new(seed);
    let noisy = add_noise(img, sigma, &mut rng);
    println!("  noisy PSNR         : {:.2} dB", psnr(&noisy, img));
    let patches = random_patches(&noisy, p, 2000, &mut rng);

    // K-SVD (DDL baseline).
    let kcfg = KsvdConfig { n_atoms: atoms, sparsity: 5, n_iter: 10, seed };
    let t0 = Instant::now();
    let ddl = faust::dictlearn::ksvd_with_ctx(&ctx, &patches, &kcfg);
    let ddl_den = denoise(&noisy, &ddl.dict, p, 5, stride);
    println!(
        "  DDL (K-SVD)        : {:.2} dB   [{:.1?}]",
        psnr(&ddl_den, img),
        t0.elapsed()
    );

    // FAuST dictionary.
    let hcfg = HierarchicalConfig::dictionary(
        p * p,
        atoms,
        4,
        4,
        2 * p * p * 2,
        0.5,
        (p * p * p * p) as f64,
    );
    let t0 = Instant::now();
    let (fst, _) = faust_dictionary_learning_with_ctx(&ctx, &patches, &kcfg, &hcfg);
    let fden = denoise(&noisy, &fst, p, 5, stride);
    println!(
        "  FAuST (s_tot={})  : {:.2} dB   [{:.1?}]  RCG={:.1}",
        fst.s_tot(),
        psnr(&fden, img),
        t0.elapsed(),
        fst.rcg()
    );

    // Overcomplete DCT.
    let side = (atoms as f64).sqrt().ceil() as usize;
    let dct = overcomplete_dct(p, side * side);
    let dct_den = denoise(&noisy, &dct, p, 5, stride);
    println!("  DCT ({} atoms)   : {:.2} dB", side * side, psnr(&dct_den, img));
    Ok(())
}

/// Paper §VI-C scaled to synthetic data: learn a FAuST dictionary from
/// planted k-sparse samples — K-SVD warm-up then hierarchical
/// factorization, all on one shared [`ExecCtx`].
fn cmd_dict(args: &Args) -> Result<()> {
    let m: usize = args.get("m", 32);
    let atoms: usize = args.get("atoms", 64);
    let samples: usize = args.get("samples", 400);
    let sparsity: usize = args.get("sparsity", 4);
    let j: usize = args.get("j", 3);
    let iters: usize = args.get("iters", 10);
    let seed: u64 = args.get("seed", 0);
    let ctx = ctx_for(args.get("threads", 0));
    if atoms < m {
        return Err(err("--atoms must be >= --m (overcomplete dictionary)"));
    }
    // Planted dictionary + k-sparse codes.
    let mut rng = Rng::new(seed);
    let mut d = Mat::randn(m, atoms, &mut rng);
    d.normalize_cols();
    let mut gamma = Mat::zeros(atoms, samples);
    for c in 0..samples {
        for i in rng.sample_indices(atoms, sparsity.min(atoms)) {
            gamma.set(i, c, rng.gauss());
        }
    }
    let y = d.matmul(&gamma);
    let kcfg = KsvdConfig { n_atoms: atoms, sparsity, n_iter: iters, seed };
    let hcfg = HierarchicalConfig::dictionary(
        m,
        atoms,
        j,
        sparsity.max(2),
        4 * m,
        0.7,
        (m * m) as f64,
    );
    println!(
        "dictionary learning: Y {m}x{samples}, {atoms} atoms, k={sparsity}, \
         J={j}, ctx threads={}",
        ctx.n_threads()
    );
    let t0 = Instant::now();
    let (fst, g) = faust_dictionary_learning_with_ctx(&ctx, &y, &kcfg, &hcfg);
    let resid = fst.to_dense().matmul(&g).sub(&y).fro() / y.fro();
    println!("  time           : {:.2?}", t0.elapsed());
    println!("  residual       : {resid:.4}");
    println!("  s_tot          : {}", fst.s_tot());
    println!("  RCG            : {:.2}", fst.rcg());
    if let Some(path) = args.get_str("save") {
        fst.save(path)?;
        println!("  saved to {path}");
    }
    Ok(())
}

/// Serve a Hadamard FAuST + dense twin through the coordinator, with the
/// FAuST planned + parallelized by the engine. `--adaptive-batch` sizes
/// each operator's batches from its plan's flop/byte profile.
/// `--factorize` serves the reference butterfly from t=0, refactorizes
/// on-line *on the serving engine's ctx* (one pool for training and
/// serving) and hot-swaps the learned generation in mid-traffic.
/// `--repl` opens an interactive operator console on the live registry.
fn cmd_serve(args: &Args) -> Result<()> {
    let n: usize = args.get("n", 64);
    let requests: usize = args.get("requests", 10_000);
    let batch: usize = args.get("batch", 32);
    let workers: usize = args.get("workers", 2);
    let threads: usize = args.get("threads", 2);
    let shards: usize = args.get("shards", 1);
    let store: Option<std::path::PathBuf> = args.get_str("store").map(Into::into);
    let adaptive = args.flag("adaptive-batch");
    // `--precision f64|f32|auto[:EPS]` picks the serving tier; the
    // default keeps the bitwise-f64 contract of every earlier PR.
    let precision: Precision = match args.get_str("precision") {
        Some(s) => s.parse().map_err(err)?,
        None => Precision::F64,
    };
    let h = hadamard(n);
    let engine = Arc::new(ApplyEngine::with_threads(threads));
    let hf = hadamard_faust(n);
    println!(
        "serving {n}x{n} operator: dense + FAuST (RCG={:.1}), engine threads={threads}, \
         shards={shards}, batching={}, precision={precision}",
        hf.rcg(),
        if adaptive { "adaptive (plan-aware)" } else { "fixed" }
    );
    let fleet_n: usize = args.get("factorize-fleet", 0);
    let online_learn = args.flag("online-learn");
    // The online demo warm-starts from the generation being served.
    let hf_warm = if online_learn { Some(hf.clone()) } else { None };
    let mut ops = engine_ops(&engine, vec![("faust".to_string(), hf)], batch);
    ops.push(("dense".to_string(), Arc::new(h.clone()) as Arc<dyn BatchOp>));
    // A fleet of served operators (one per "subject", §V framing): all
    // start as the reference butterfly and get hot-swapped one by one as
    // their on-line refactorizations finish.
    ops.extend(engine_ops(
        &engine,
        (0..fleet_n)
            .map(|i| (format!("op{i}"), hadamard_faust(n)))
            .collect(),
        batch,
    ));
    let cfg = CoordinatorConfig {
        max_batch: batch,
        batch_timeout: Duration::from_micros(200),
        n_workers: workers,
        queue_capacity: 4096,
        adaptive: if adaptive { Some(AdaptiveBatchConfig::default()) } else { None },
        precision,
        n_shards: shards,
        online: if online_learn { Some(OnlineLearnConfig::default()) } else { None },
    };
    let coord = Coordinator::start(ops, cfg);
    let registry = coord.registry();
    // `--store DIR` makes the fleet durable: a directory that already
    // holds snapshots warm-restores them (hot-swapping over the cold
    // seeds, zero re-factorization); an empty one gets an initial cold
    // snapshot so the *next* start is warm.
    if let Some(dir) = &store {
        let has_snapshots = std::fs::read_dir(dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .any(|e| e.path().extension().is_some_and(|x| x == faust::store::EXTENSION))
            })
            .unwrap_or(false);
        let t0 = Instant::now();
        if has_snapshots {
            let restore = registry
                .load_store(dir, |_, f| {
                    Arc::new(engine.op_batch_hint(f, batch)) as Arc<dyn BatchOp>
                })
                .map_err(|e| err(format!("load store {}: {e}", dir.display())))?;
            println!(
                "store: warm-restored {} operator(s) from {} in {:.2?} (zero PALM)",
                restore.loaded.len(),
                dir.display(),
                t0.elapsed()
            );
            for (path, e) in &restore.corrupt {
                println!("store: skipped {}: {e}", path.display());
            }
        } else {
            let report = registry
                .persist_all(dir)
                .map_err(|e| err(format!("snapshot to {}: {e}", dir.display())))?;
            println!(
                "store: cold start — snapshotted {} operator(s) to {} in {:.2?} \
                 ({} not persistable)",
                report.persisted.len(),
                dir.display(),
                t0.elapsed(),
                report.skipped.len()
            );
        }
    }
    if adaptive {
        for name in registry.names() {
            if let Some(t) = registry.batch_limit(&name) {
                println!("  adaptive batch target for '{name}': {t} cols");
            }
        }
    }
    if precision != Precision::F64 {
        for (name, served, err) in registry.precision_report() {
            match err {
                Some(e) => println!(
                    "  '{name}' serves {} (measured f32 rel err {e:.2e})",
                    served.name()
                ),
                None => println!("  '{name}' serves {} (no f32 generation)", served.name()),
            }
        }
    }
    // On-line *fleet* refactorization: learn a fresh generation for every
    // op<i> concurrently on the serving engine's ctx (cross-operator
    // batched sweeps) and epoch-swap each one as its own factorization
    // finishes — no global barrier, zero stall.
    let fleet_swapper = if fleet_n > 0 {
        let registry = registry.clone();
        let engine = engine.clone();
        let h = h.clone();
        Some(std::thread::spawn(move || {
            let fleet = FleetCtx::new(engine.ctx());
            let cfgs: Vec<HierarchicalConfig> = (0..fleet_n)
                .map(|i| {
                    let mut c = HierarchicalConfig::hadamard(n);
                    c.seed ^= i as u64;
                    c
                })
                .collect();
            let jobs: Vec<(String, &Mat, &HierarchicalConfig)> = cfgs
                .iter()
                .enumerate()
                .map(|(i, c)| (format!("op{i}"), &h, c))
                .collect();
            let t0 = Instant::now();
            let outcomes = registry.refactorize_fleet(&fleet, &jobs, |_, f| {
                Arc::new(engine.op_batch_hint(f, batch)) as Arc<dyn BatchOp>
            });
            for o in &outcomes {
                match &o.outcome {
                    Ok(epoch) => println!(
                        "fleet-swapped '{}' at epoch {epoch} (rel err {:.1e})",
                        o.name, o.rel_err
                    ),
                    Err(e) => println!("fleet job '{}' not published: {e}", o.name),
                }
            }
            println!(
                "fleet refactorization of {fleet_n} operators done in {:.2?} \
                 (fused gemms: {})",
                t0.elapsed(),
                fleet.metrics().fused_gemms
            );
        }))
    } else {
        None
    };
    // On-line refactorization: learn a fresh generation on the serving
    // engine's ctx while the butterfly serves, then hot-swap it in.
    let swapper = if args.flag("factorize") {
        let registry = registry.clone();
        let engine = engine.clone();
        let h = h.clone();
        Some(std::thread::spawn(move || {
            let t0 = Instant::now();
            let f = factorize_with_ctx(&engine.ctx(), &h, &HierarchicalConfig::hadamard(n));
            let rel = f.relative_error_fro(&h);
            let op = Arc::new(engine.op_batch_hint(&f, batch)) as Arc<dyn BatchOp>;
            match registry.swap_epoch("faust", op) {
                Ok(epoch) => println!(
                    "hot-swapped freshly factorized 'faust' at epoch {epoch} \
                     ({:.2?}, rel err {rel:.1e}) — zero stall",
                    t0.elapsed()
                ),
                // 'faust' may have been retired from the REPL meanwhile.
                Err(e) => println!("on-line refactorization not published: {e}"),
            }
        }))
    } else {
        None
    };
    // `--online-learn`: streaming factorization under drift (ROADMAP
    // item i). A feeder thread observes columns of a slowly *rotating*
    // true operator; the learner — warm-started from the served
    // butterfly's factors and λ, sweeping on the serving engine's ctx —
    // folds each mini-batch into its surrogate and epoch-swaps improved
    // generations through the live registry, zero stall.
    let online_demo = hf_warm.map(|warm| {
        let init = FactorState {
            mats: warm.factors().iter().map(|csr| csr.to_dense()).collect(),
            lambda: warm.lambda(),
        };
        let palm = OnlinePalm::warm(
            init,
            OnlineConfig::new(PalmConfig::new(
                vec![Constraint::SpRowCol(2); warm.n_factors()],
                1,
            ))
            .with_forgetting(0.8),
        );
        let learner = coord
            .online_learner("faust", palm)
            .expect("--online-learn sets CoordinatorConfig::online");
        let publish = {
            let engine = engine.clone();
            move |f: &Faust| Arc::new(engine.op_batch_hint(f, batch)) as Arc<dyn BatchOp>
        };
        let task = OnlineLearnerTask::spawn(learner, engine.ctx(), publish, 1024);
        let passes: usize = args.get("online-passes", 24);
        let theta: f64 = args.get("online-drift", 0.01);
        let h = h.clone();
        println!(
            "online: learning 'faust' from {passes} passes over a drifting operator \
             (rotation {theta:.3} rad/pass, forgetting 0.8)"
        );
        // The feeder hands the task back so the main thread can drain
        // the tail and collect the final report after the load finishes.
        std::thread::spawn(move || {
            let mut a = h;
            let (s, c) = theta.sin_cos();
            for _ in 0..passes {
                for j in 0..n {
                    if !task.observe(j, a.col(j)) {
                        return task;
                    }
                }
                // Drift: rotate adjacent row pairs of the true operator
                // by θ — the slowly rotating operator scenario the
                // online_drift bench gates.
                for i in (0..n - 1).step_by(2) {
                    for j in 0..n {
                        let (u, v) = (a.at(i, j), a.at(i + 1, j));
                        a.set(i, j, c * u - s * v);
                        a.set(i + 1, j, s * u + c * v);
                    }
                }
            }
            task
        })
    });
    // `--listen ADDR` puts the TCP ingress front end (wire protocol +
    // admission control + QoS classes) in front of the coordinator; it
    // serves remote `faust client` traffic alongside the local load.
    let ingress = match args.get_str("listen") {
        Some(addr) => {
            let server = Server::start(
                coord.client(),
                ServerConfig {
                    addr: addr.to_string(),
                    store_dir: store.clone(),
                    ..ServerConfig::default()
                },
            )
            .map_err(|e| err(format!("bind {addr}: {e}")))?;
            println!("ingress listening on {}", server.local_addr());
            Some(server)
        }
        None => None,
    };
    if args.flag("repl") {
        // Settle the online demo first so its swaps are visible to
        // `stats`; the swapper (if any) publishes into the same live
        // registry while the console runs and finishes on its own.
        if let Some(feeder) = online_demo {
            let task = feeder.join().map_err(|_| err("online feeder panicked"))?;
            let rep = task.finish();
            println!(
                "online: {} mini-batches over {} columns, {} swap(s), final rel err {:.2e}",
                rep.batches, rep.cols, rep.swaps, rep.rel_err
            );
        }
        return serve_repl(coord, ingress, &engine);
    }
    let client = coord.client();
    let mut table =
        Table::new(&["operator", "throughput(req/s)", "mean latency(us)", "mean batch"]);
    // Fleet operators take traffic while their refactorizations train on
    // the same engine — the hot-swap happens mid-benchmark.
    let mut bench_ops = vec!["dense".to_string(), "faust".to_string()];
    bench_ops.extend((0..fleet_n).map(|i| format!("op{i}")));
    for op in bench_ops.iter().map(|s| s.as_str()) {
        let t0 = Instant::now();
        let mut rng = Rng::new(7);
        let mut pending = Vec::with_capacity(256);
        let mut done = 0usize;
        while done < requests {
            match client.submit(op, rng.gauss_vec(n)) {
                Ok(rx) => pending.push(rx),
                Err(_) => {
                    // backpressure: drain some
                    for rx in pending.drain(..) {
                        let _ = rx.recv();
                        done += 1;
                    }
                }
            }
            if pending.len() >= 256 {
                for rx in pending.drain(..) {
                    let _ = rx.recv();
                    done += 1;
                }
            }
        }
        for rx in pending.drain(..) {
            let _ = rx.recv();
            done += 1;
        }
        let dt = t0.elapsed().as_secs_f64();
        let snap = client.metrics();
        table.row(&[
            op.to_string(),
            fmt(done as f64 / dt),
            fmt(snap.mean_latency_us()),
            fmt(snap.mean_batch_size()),
        ]);
    }
    table.print();
    if let Some(s) = swapper {
        s.join().map_err(|_| err("refactorization thread panicked"))?;
    }
    if let Some(s) = fleet_swapper {
        s.join()
            .map_err(|_| err("fleet refactorization thread panicked"))?;
    }
    if let Some(feeder) = online_demo {
        let task = feeder.join().map_err(|_| err("online feeder panicked"))?;
        let rep = task.finish();
        println!(
            "online: {} mini-batches over {} observed columns, {} generation swap(s), \
             final rel err {:.2e}",
            rep.batches, rep.cols, rep.swaps, rep.rel_err
        );
    }
    if let Some(server) = ingress {
        server.shutdown();
    }
    // Final snapshot so the next `serve --store` start is warm; the
    // ingress shutdown above already wrote one when --listen was active,
    // and both writes are atomic under the same per-operator names.
    if let Some(dir) = &store {
        match registry.persist_all(dir) {
            Ok(r) => println!(
                "store: final snapshot — {} persisted, {} skipped",
                r.persisted.len(),
                r.skipped.len()
            ),
            Err(e) => println!("store: final snapshot to {} failed: {e}", dir.display()),
        }
    }
    let precision_lines: Vec<String> = registry
        .precision_report()
        .iter()
        .map(|(name, served, err)| match err {
            Some(e) => format!("{name}={} (f32 rel err {e:.1e})", served.name()),
            None => format!("{name}={}", served.name()),
        })
        .collect();
    let snap = coord.shutdown();
    let em = engine.metrics();
    println!(
        "engine: applies={} arena_reuses={} arena_allocs={} | registry: \
         registered={} swaps={}",
        em.applies, em.arena_reuses, em.arena_allocs, snap.registered, snap.swaps
    );
    println!(
        "precision: applies_f64={} applies_f32={} (f32 fraction {:.0}%) | {}",
        snap.applies_f64,
        snap.applies_f32,
        snap.f32_apply_frac() * 100.0,
        precision_lines.join(" ")
    );
    if snap.online_batches > 0 {
        println!(
            "online: batches={} cols={} swaps={} rel_err={:.2e}",
            snap.online_batches, snap.online_cols, snap.online_swaps, snap.online_rel_err
        );
    }
    if snap.ingress_connections > 0 {
        println!(
            "ingress: accepted={} shed=[interactive={} standard={} bulk={}] \
             connections={} hwm={}",
            snap.ingress_accepted,
            snap.ingress_shed[0],
            snap.ingress_shed[1],
            snap.ingress_shed[2],
            snap.ingress_connections,
            snap.ingress_queue_hwm
        );
    }
    Ok(())
}

/// Interactive operator console on a live coordinator (`serve --repl`).
fn serve_repl(
    coord: Coordinator,
    ingress: Option<Server>,
    engine: &Arc<ApplyEngine>,
) -> Result<()> {
    use std::io::BufRead;
    let client = coord.client();
    let registry = coord.registry();
    let mut rng = Rng::new(0xCAFE);
    println!(
        "serve REPL — ops | ops add <name> <n> | ops swap <name> | \
         ops rm <name> | apply <name> | stats | quit"
    );
    let stdin = std::io::stdin();
    for line in stdin.lock().lines() {
        let line = line?;
        let toks: Vec<&str> = line.split_whitespace().collect();
        match toks.as_slice() {
            [] => {}
            ["quit"] | ["exit"] => break,
            ["ops"] => {
                for name in registry.names() {
                    let op = registry.get(&name).expect("listed name resolves");
                    println!(
                        "  {name}: {}x{} epoch={} target_batch={} precision={}",
                        op.rows(),
                        op.cols(),
                        registry.epoch_of(&name).unwrap_or(0),
                        registry
                            .batch_limit(&name)
                            .map(|t| t.to_string())
                            .unwrap_or_else(|| "fixed".into()),
                        registry
                            .serving_of(&name)
                            .map(|s| s.name())
                            .unwrap_or("f64"),
                    );
                }
            }
            ["ops", "add", name, nstr] => match nstr.parse::<usize>() {
                Ok(sz) if sz.is_power_of_two() && sz >= 4 => {
                    let op = Arc::new(engine.op(&hadamard_faust(sz))) as Arc<dyn BatchOp>;
                    match registry.register(name.to_string(), op) {
                        Ok(e) => println!("registered '{name}' ({sz}x{sz}) at epoch {e}"),
                        Err(e) => println!("error: {e}"),
                    }
                }
                _ => println!("error: <n> must be a power of two >= 4"),
            },
            ["ops", "swap", name] => match registry.get(name) {
                Some(cur) if cur.rows() == cur.cols() && cur.rows().is_power_of_two() => {
                    let sz = cur.rows();
                    let t0 = Instant::now();
                    let f = factorize_with_ctx(
                        &engine.ctx(),
                        &hadamard(sz),
                        &HierarchicalConfig::hadamard(sz),
                    );
                    let op = Arc::new(engine.op(&f)) as Arc<dyn BatchOp>;
                    match registry.swap_epoch(name, op) {
                        Ok(e) => println!(
                            "swapped '{name}' to a freshly factorized generation \
                             at epoch {e} ({:.2?})",
                            t0.elapsed()
                        ),
                        Err(e) => println!("error: {e}"),
                    }
                }
                Some(_) => println!("error: demo swap needs a square power-of-two operator"),
                // Same typed error (and Display) the API's swap_epoch
                // returns for a never-registered key.
                None => println!(
                    "error: {}",
                    RegistryError::UnknownOperator(name.to_string())
                ),
            },
            ["ops", "rm", name] => match registry.retire(name) {
                Ok(op) => println!("retired '{name}' ({}x{})", op.rows(), op.cols()),
                Err(e) => println!("error: {e}"),
            },
            ["apply", name] => match registry.get(name) {
                Some(op) => {
                    let x = rng.gauss_vec(op.cols());
                    match client.apply(name, x) {
                        Ok(y) => {
                            let norm = y.iter().map(|v| v * v).sum::<f64>().sqrt();
                            println!("||y||_2 = {norm:.6}  ({} rows)", y.len());
                        }
                        Err(e) => println!("error: {e}"),
                    }
                }
                None => println!(
                    "error: {}",
                    RegistryError::UnknownOperator(name.to_string())
                ),
            },
            ["stats"] => {
                let s = client.metrics();
                println!(
                    "  completed={} batches={} mean_batch={:.1} mean_latency_us={:.1} \
                     registered={} swaps={} retired={}",
                    s.completed,
                    s.batches,
                    s.mean_batch_size(),
                    s.mean_latency_us(),
                    s.registered,
                    s.swaps,
                    s.retired,
                );
                println!(
                    "  ingress: accepted={} shed=[interactive={} standard={} bulk={}] \
                     connections={} active={} hwm={}",
                    s.ingress_accepted,
                    s.ingress_shed[0],
                    s.ingress_shed[1],
                    s.ingress_shed[2],
                    s.ingress_connections,
                    s.ingress_active_connections,
                    s.ingress_queue_hwm,
                );
                println!(
                    "  precision: applies_f64={} applies_f32={} (f32 fraction {:.0}%)",
                    s.applies_f64,
                    s.applies_f32,
                    s.f32_apply_frac() * 100.0,
                );
                println!(
                    "  online: batches={} cols={} swaps={} rel_err={:.2e}",
                    s.online_batches, s.online_cols, s.online_swaps, s.online_rel_err,
                );
                for (name, served, err) in registry.precision_report() {
                    match err {
                        Some(e) => println!(
                            "    {name}: serving {} (measured f32 rel err {e:.2e})",
                            served.name()
                        ),
                        None => println!("    {name}: serving {}", served.name()),
                    }
                }
            }
            _ => println!("unknown command (ops | ops add/swap/rm | apply | stats | quit)"),
        }
    }
    if let Some(server) = ingress {
        server.shutdown();
    }
    coord.shutdown();
    Ok(())
}

/// Open-loop load client against a running `serve --listen` ingress:
/// Poisson arrivals per QoS class over the wire protocol, reporting
/// per-class latency percentiles and shed rates.
fn cmd_client(args: &Args) -> Result<()> {
    let addr = args
        .get_str("addr")
        .ok_or_else(|| err("client needs --addr HOST:PORT (see serve --listen)"))?;
    let op = args.get_str("op").unwrap_or("faust").to_string();
    let n: usize = args.get("n", 64);
    let rate: f64 = args.get("rate", 5_000.0);
    let requests: usize = args.get("requests", 20_000);
    let seed: u64 = args.get("seed", 42);
    // `--dtype f32` rides the v2 wire tier: payload bytes halve both
    // ways and values quantize in transit.
    let dtype: Dtype = match args.get_str("dtype") {
        Some(s) => s.parse().map_err(err)?,
        None => Dtype::F64,
    };
    let class_arg = args.get_str("class").unwrap_or("all");
    // `--class all` splits the aggregate ~30/40/30 like the latency
    // bench; a single class name sends one stream.
    let streams: Vec<(QosClass, f64)> = if class_arg == "all" {
        vec![
            (QosClass::Interactive, 0.3),
            (QosClass::Standard, 0.4),
            (QosClass::Bulk, 0.3),
        ]
    } else {
        vec![(class_arg.parse::<QosClass>().map_err(err)?, 1.0)]
    };
    println!(
        "open-loop client → {addr} op='{op}' n={n} rate={rate} req/s \
         requests={requests} classes={} dtype={dtype}",
        streams.len()
    );
    let mut handles = Vec::new();
    for (k, (class, share)) in streams.iter().enumerate() {
        let cfg = OpenLoopConfig {
            addr: addr.to_string(),
            op: op.clone(),
            class: *class,
            rate_hz: rate * share,
            requests: (requests as f64 * share).round() as usize,
            dim: n,
            seed: seed.wrapping_add(k as u64),
            dtype,
            verify_tol: if dtype == Dtype::F32 { 1e-4 } else { 1e-6 },
        };
        handles.push(std::thread::spawn(move || open_loop_load(&cfg, None)));
    }
    let mut table = Table::new(&[
        "class", "sent", "ok", "shed", "errors", "p50_us", "p99_us", "p999_us",
    ]);
    let mut failures = 0usize;
    for h in handles {
        let r = h.join().map_err(|_| err("load thread panicked"))?.map_err(err)?;
        failures += r.misrouted + r.protocol_errors;
        table.row(&[
            r.class.name().to_string(),
            r.sent.to_string(),
            r.ok.to_string(),
            r.shed.to_string(),
            (r.other_errors + r.protocol_errors + r.misrouted).to_string(),
            fmt(r.latency.p50_us),
            fmt(r.latency.p99_us),
            fmt(r.latency.p999_us),
        ]);
    }
    table.print();
    if failures > 0 {
        return Err(err(format!("{failures} misrouted/protocol failures")));
    }
    Ok(())
}

/// Engine section: compile a plan for an operator, optionally dump it,
/// and time planned/pooled apply against the naive per-factor chain.
fn cmd_engine(args: &Args) -> Result<()> {
    let n: usize = args.get("n", 1024);
    if !n.is_power_of_two() || n < 4 {
        return Err(err("--n must be a power of two ≥ 4"));
    }
    let threads: usize = args.get("threads", 4);
    let batch: usize = args.get("batch", 32);
    let fst = hadamard_faust(n);
    let plan_cfg = PlanConfig::default();
    let engine = ApplyEngine::new(EngineConfig { n_threads: threads, plan: plan_cfg.clone() });
    let op = engine.op_batch_hint(&fst, batch);
    if args.get_str("plan") == Some("dump") || args.flag("plan-dump") {
        print!("{}", op.plan().dump(&plan_cfg));
    }
    let mut rng = Rng::new(11);
    let x = faust::linalg::Mat::randn(n, batch, &mut rng);
    let mut out = faust::linalg::Mat::zeros(n, batch);

    let tn =
        faust::bench_util::time_auto(200.0, || std::hint::black_box(fst.apply_mat_naive(&x)));
    let tp = faust::bench_util::time_auto(200.0, || {
        op.apply_batch_into(std::hint::black_box(&x), &mut out);
    });
    let m = engine.metrics();
    println!(
        "engine bench: {n}x{n}, {} factors, batch={batch}, threads={threads}",
        fst.n_factors()
    );
    println!("  naive serial apply : {:.1} us", tn.median_us());
    println!(
        "  planned engine     : {:.1} us  ({:.2}x)",
        tp.median_us(),
        tn.median_ns / tp.median_ns
    );
    println!("  arena              : {} reuses, {} allocs", m.arena_reuses, m.arena_allocs);
    Ok(())
}

/// Check the PJRT runtime: load artifacts, execute, compare vs rust-native.
#[cfg(feature = "pjrt")]
fn cmd_runtime(args: &Args) -> Result<()> {
    let dir = args.get_str("artifacts").unwrap_or("artifacts");
    let mut engine = faust::runtime::Engine::cpu(dir)?;
    println!("PJRT platform: {}", engine.platform());
    for name in ["faust_apply_had32", "palm_grad_step"] {
        if !engine.available(name) {
            println!("  {name}: artifact missing (run `make artifacts`)");
            continue;
        }
        let t0 = Instant::now();
        engine.load(name)?;
        println!("  {name}: loaded+compiled in {:.2?}", t0.elapsed());
    }
    // Numerical check of the faust apply artifact vs rust-native.
    if engine.available("faust_apply_had32") {
        let n = 32;
        let b = 8;
        let hf = hadamard_faust(n);
        let mut rng = Rng::new(9);
        // Batch input (column-major batch: shape (n, b) row-major f32).
        let xcols: Vec<Vec<f64>> = (0..b).map(|_| rng.gauss_vec(n)).collect();
        let mut x = vec![0f32; n * b];
        for (c, col) in xcols.iter().enumerate() {
            for i in 0..n {
                x[i * b + c] = col[i] as f32;
            }
        }
        // Factors rightmost-first as dense f32.
        let facs: Vec<Vec<f32>> = hf
            .factors()
            .iter()
            .map(|f| f.to_dense().data().iter().map(|&v| v as f32).collect())
            .collect();
        let xdims = [n, b];
        let fdims = [n, n];
        let mut inputs: Vec<(&[f32], &[usize])> = vec![(&x, &xdims[..])];
        for f in &facs {
            inputs.push((f, &fdims[..]));
        }
        let out = engine.run_f32("faust_apply_had32", &inputs)?;
        let y_pjrt = &out[0].0;
        let mut max_err = 0.0_f64;
        for (c, col) in xcols.iter().enumerate() {
            let y_native = hf.apply(col);
            for i in 0..n {
                max_err = max_err.max((y_pjrt[i * b + c] as f64 - y_native[i]).abs());
            }
        }
        println!("  faust_apply_had32 vs rust-native: max |Δ| = {max_err:.3e}");
        if max_err > 1e-4 {
            return Err(err(format!("PJRT/native mismatch: {max_err}")));
        }
    }
    Ok(())
}

/// Without the `pjrt` feature the runtime module is compiled out.
#[cfg(not(feature = "pjrt"))]
fn cmd_runtime(_args: &Args) -> Result<()> {
    println!(
        "runtime: built without the `pjrt` feature. Rebuild with \
         `--features pjrt` for the API surface (stub backend), or \
         uncomment the `xla`/`anyhow` dependencies in rust/Cargo.toml \
         (vendored crates required) and use `--features pjrt,pjrt-xla` \
         for real PJRT execution."
    );
    Ok(())
}
