//! Synthetic MEG forward-model substrate (paper §V substitution).
//!
//! The paper factorizes a real 204×8193 MEG gain matrix computed by MNE's
//! boundary-element method. That matrix is not redistributable, so this
//! module builds the closest synthetic equivalent exercising the same code
//! paths (see DESIGN.md §6): a quasi-spherical head with 204
//! tangential-gradiometer-like sensors on an upper cap and 8193 cortical
//! current dipoles at *irregular* (non-grid) positions, with the magnetic
//! dipole kernel `B(r) ∝ q × (r − r_s) / ‖r − r_s‖³`. What matters to the
//! experiments is preserved: strong correlation between nearby source
//! columns, smooth low-rank-ish structure that a truncated SVD cannot fully
//! capture, no spatial grid (so analytic compression à la FMM/wavelets does
//! not apply — the paper's own argument for data-driven factorization).

#![forbid(unsafe_code)]

use crate::linalg::Mat;
use crate::rng::Rng;
use crate::solvers::{omp, LinOp};

/// 3-vector helpers.
type V3 = [f64; 3];

fn sub3(a: V3, b: V3) -> V3 {
    [a[0] - b[0], a[1] - b[1], a[2] - b[2]]
}

fn cross3(a: V3, b: V3) -> V3 {
    [
        a[1] * b[2] - a[2] * b[1],
        a[2] * b[0] - a[0] * b[2],
        a[0] * b[1] - a[1] * b[0],
    ]
}

fn dot3(a: V3, b: V3) -> f64 {
    a[0] * b[0] + a[1] * b[1] + a[2] * b[2]
}

fn norm3(a: V3) -> f64 {
    dot3(a, a).sqrt()
}

fn normalize3(a: V3) -> V3 {
    let n = norm3(a).max(1e-300);
    [a[0] / n, a[1] / n, a[2] / n]
}

/// A synthetic MEG head model: sensor geometry + source space + gain.
pub struct MegModel {
    /// Gain (lead-field) matrix, `n_sensors × n_sources`.
    pub gain: Mat,
    /// Sensor positions on the helmet (metres).
    pub sensor_pos: Vec<V3>,
    /// Source (dipole) positions in the head (metres).
    pub source_pos: Vec<V3>,
}

/// Build the synthetic model. Defaults mirroring the paper: `n_sensors =
/// 204`, `n_sources = 8193`. Head radius 0.10 m, sensor helmet 0.115 m,
/// cortical shell 0.070–0.085 m.
pub fn meg_model(n_sensors: usize, n_sources: usize, seed: u64) -> MegModel {
    let mut rng = Rng::new(seed);
    // --- Sensors: Fibonacci spiral on the upper cap (z > 0.25·R).
    let helmet_r = 0.115;
    let golden = std::f64::consts::PI * (3.0 - 5f64.sqrt());
    let mut sensor_pos = Vec::with_capacity(n_sensors);
    let mut sensor_ori = Vec::with_capacity(n_sensors);
    for i in 0..n_sensors {
        // z in [0.25, 0.98] of the sphere — an EEG/MEG cap.
        let frac = (i as f64 + 0.5) / n_sensors as f64;
        let z = 0.25 + 0.73 * frac;
        let r_xy = (1.0 - z * z).max(0.0).sqrt();
        let th = golden * i as f64;
        let p = [
            helmet_r * r_xy * th.cos(),
            helmet_r * r_xy * th.sin(),
            helmet_r * z,
        ];
        sensor_pos.push(p);
        // Gradiometer-like tangential orientation (alternating the two
        // tangent directions, as paired planar gradiometers do).
        let radial = normalize3(p);
        let up = if radial[2].abs() < 0.9 { [0.0, 0.0, 1.0] } else { [1.0, 0.0, 0.0] };
        let t1 = normalize3(cross3(radial, up));
        let t2 = normalize3(cross3(radial, t1));
        sensor_ori.push(if i % 2 == 0 { t1 } else { t2 });
    }
    // --- Sources: irregular shell 0.070–0.085 m, random directions
    // (approximately cortex: no grid!), with tangential-ish dipole moments.
    let mut source_pos = Vec::with_capacity(n_sources);
    let mut source_ori = Vec::with_capacity(n_sources);
    for _ in 0..n_sources {
        // Random point on the sphere via Gaussian normalization.
        let g = [rng.gauss(), rng.gauss(), rng.gauss()];
        let dir = normalize3(g);
        let radius = rng.range(0.070, 0.085);
        // Bias towards the upper hemisphere (cortex under the cap).
        let dir = if dir[2] < -0.3 { [dir[0], dir[1], -dir[2]] } else { dir };
        source_pos.push([dir[0] * radius, dir[1] * radius, dir[2] * radius]);
        // Dipole orientation: random unit vector (free orientation).
        let o = normalize3([rng.gauss(), rng.gauss(), rng.gauss()]);
        source_ori.push(o);
    }
    // --- Lead field: magnetic dipole in free space, projected on sensor
    // orientation. B(r) = k · q × (r − r_s) / ‖r − r_s‖³.
    let mut gain = Mat::zeros(n_sensors, n_sources);
    for s in 0..n_sources {
        let q = source_ori[s];
        let rs = source_pos[s];
        for c in 0..n_sensors {
            let d = sub3(sensor_pos[c], rs);
            let dist = norm3(d).max(1e-6);
            let b = cross3(q, d);
            let val = dot3(b, sensor_ori[c]) / (dist * dist * dist);
            gain.set(c, s, val);
        }
    }
    // Scale to unit Frobenius norm per column average (keeps conditioning
    // comparable across runs; absolute units are irrelevant here).
    let f = gain.fro();
    if f > 0.0 {
        gain.scale((n_sensors as f64).sqrt() / f * (n_sources as f64).sqrt() / 10.0);
    }
    MegModel { gain, sensor_pos, source_pos }
}

impl MegModel {
    /// Distance between two sources in centimetres.
    pub fn source_distance_cm(&self, i: usize, j: usize) -> f64 {
        norm3(sub3(self.source_pos[i], self.source_pos[j])) * 100.0
    }

    /// Sample a source pair whose separation lies in `[dmin_cm, dmax_cm)`.
    pub fn sample_source_pair(&self, rng: &mut Rng, dmin_cm: f64, dmax_cm: f64) -> (usize, usize) {
        let n = self.source_pos.len();
        for _ in 0..100_000 {
            let i = rng.below(n);
            let j = rng.below(n);
            if i == j {
                continue;
            }
            let d = self.source_distance_cm(i, j);
            if d >= dmin_cm && d < dmax_cm {
                return (i, j);
            }
        }
        panic!("no source pair found in [{dmin_cm}, {dmax_cm}) cm");
    }
}

/// Statistics of localization errors (distances in cm).
#[derive(Clone, Debug, Default)]
pub struct LocStats {
    /// One entry per (trial, true source): distance to closest retrieved.
    pub distances_cm: Vec<f64>,
}

impl LocStats {
    pub fn median(&self) -> f64 {
        self.quantile(0.5)
    }

    pub fn quantile(&self, q: f64) -> f64 {
        if self.distances_cm.is_empty() {
            return f64::NAN;
        }
        let mut v = self.distances_cm.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((v.len() - 1) as f64 * q).round() as usize;
        v[idx]
    }

    pub fn mean(&self) -> f64 {
        self.distances_cm.iter().sum::<f64>() / self.distances_cm.len().max(1) as f64
    }

    /// Fraction of sources retrieved exactly (distance == 0).
    pub fn exact_rate(&self) -> f64 {
        let exact = self.distances_cm.iter().filter(|&&d| d < 1e-9).count();
        exact as f64 / self.distances_cm.len().max(1) as f64
    }
}

/// Paper Fig. 9: source-localization experiment.
///
/// For `n_trials` random 2-sparse source configurations with separation in
/// `[dmin_cm, dmax_cm)`, generate `y = M γ` with the **true** gain, run OMP
/// (2 atoms) with the given recovery operator (the true gain or a FAμST
/// approximation), and record the distance from each true source to the
/// closest retrieved source.
pub fn localization_experiment(
    model: &MegModel,
    recovery_op: &dyn LinOp,
    n_trials: usize,
    dmin_cm: f64,
    dmax_cm: f64,
    seed: u64,
) -> LocStats {
    assert_eq!(recovery_op.cols(), model.gain.cols());
    let mut rng = Rng::new(seed);
    let mut stats = LocStats::default();
    for _ in 0..n_trials {
        let (i, j) = model.sample_source_pair(&mut rng, dmin_cm, dmax_cm);
        // Gaussian random source amplitudes (paper: "gaussian random
        // weights").
        let wi = rng.gauss();
        let wj = rng.gauss();
        // y = M γ with the true gain.
        let ci = model.gain.col(i);
        let cj = model.gain.col(j);
        let y: Vec<f64> = ci
            .iter()
            .zip(&cj)
            .map(|(a, b)| wi * a + wj * b)
            .collect();
        let res = omp(recovery_op, &y, 2, None);
        for &true_src in &[i, j] {
            let best = res
                .support
                .iter()
                .map(|&got| model.source_distance_cm(true_src, got))
                .fold(f64::INFINITY, f64::min);
            stats
                .distances_cm
                .push(if best.is_finite() { best } else { f64::NAN });
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_dimensions_and_determinism() {
        let m1 = meg_model(24, 100, 7);
        let m2 = meg_model(24, 100, 7);
        assert_eq!(m1.gain.shape(), (24, 100));
        assert!(m1.gain.rel_fro_err(&m2.gain) < 1e-15, "not deterministic");
        assert_eq!(m1.sensor_pos.len(), 24);
        assert_eq!(m1.source_pos.len(), 100);
    }

    #[test]
    fn sensors_on_upper_cap() {
        let m = meg_model(32, 10, 1);
        for p in &m.sensor_pos {
            let r = norm3(*p);
            assert!((r - 0.115).abs() < 1e-9);
            assert!(p[2] > 0.0, "sensor below equator");
        }
    }

    #[test]
    fn sources_in_cortical_shell() {
        let m = meg_model(8, 200, 2);
        for p in &m.source_pos {
            let r = norm3(*p);
            assert!((0.070..=0.085).contains(&r), "r={r}");
        }
    }

    #[test]
    fn nearby_sources_have_correlated_columns() {
        let m = meg_model(64, 400, 3);
        // Find the closest and a far pair; compare column correlations.
        let mut best = (0, 1, f64::INFINITY);
        let mut worst = (0, 1, 0.0);
        for i in 0..50 {
            for j in (i + 1)..50 {
                let d = m.source_distance_cm(i, j);
                if d < best.2 {
                    best = (i, j, d);
                }
                if d > worst.2 {
                    worst = (i, j, d);
                }
            }
        }
        let corr = |i: usize, j: usize| {
            let a = m.gain.col(i);
            let b = m.gain.col(j);
            let na: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
            let nb: f64 = b.iter().map(|x| x * x).sum::<f64>().sqrt();
            (a.iter().zip(&b).map(|(x, y)| x * y).sum::<f64>() / (na * nb)).abs()
        };
        assert!(
            corr(best.0, best.1) > corr(worst.0, worst.1),
            "near-pair correlation {} should exceed far-pair {}",
            corr(best.0, best.1),
            corr(worst.0, worst.1)
        );
    }

    #[test]
    fn localization_with_true_gain_is_good() {
        let m = meg_model(48, 300, 5);
        let stats = localization_experiment(&m, &m.gain, 30, 6.0, 100.0, 11);
        assert_eq!(stats.distances_cm.len(), 60);
        // Well-separated sources with the exact matrix: mostly retrieved
        // at or very near the true location. (This small 48-sensor test
        // model is much harder than the 204-sensor benchmark scale; the
        // bench fig9 harness reproduces the paper's >75% exact regime.)
        assert!(
            stats.exact_rate() > 0.25,
            "exact rate too low: {}",
            stats.exact_rate()
        );
        assert!(stats.median() < 3.0, "median {}", stats.median());
    }

    #[test]
    fn pair_sampling_respects_bins() {
        let m = meg_model(8, 200, 6);
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let (i, j) = m.sample_source_pair(&mut rng, 3.0, 6.0);
            let d = m.source_distance_cm(i, j);
            assert!((3.0..6.0).contains(&d));
        }
    }
}
