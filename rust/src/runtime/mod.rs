//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin). Artifacts are HLO *text*
//! produced by `python/compile/aot.py` (see repo README for why text, not
//! serialized protos). One compiled executable per model variant, cached.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A lazily-compiled registry of HLO artifacts on a single PJRT client.
pub struct Engine {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    artifact_dir: PathBuf,
}

impl Engine {
    /// Create an engine backed by the PJRT CPU client, loading artifacts
    /// from `artifact_dir` on demand.
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Self { client, exes: HashMap::new(), artifact_dir: artifact_dir.as_ref().to_path_buf() })
    }

    /// Name of the PJRT platform backing this engine (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<artifact_dir>/<name>.hlo.txt` if not already cached.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow!("parse HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// True if the artifact file exists on disk (whether or not loaded).
    pub fn available(&self, name: &str) -> bool {
        self.artifact_dir.join(format!("{name}.hlo.txt")).exists()
    }

    /// Execute a loaded artifact on f32 buffers.
    ///
    /// Each input is `(data, dims)`; the computation was lowered with
    /// `return_tuple=True`, so outputs come back as a tuple of literals,
    /// flattened here into `Vec<(Vec<f32>, Vec<usize>)>`.
    pub fn run_f32(
        &self,
        name: &str,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<(Vec<f32>, Vec<usize>)>> {
        let exe = self
            .exes
            .get(name)
            .with_context(|| format!("artifact {name} not loaded"))?;
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims_i64)
                .map_err(|e| anyhow!("reshape input: {e:?}"))?;
            lits.push(lit);
        }
        let mut result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let tuple = result
            .decompose_tuple()
            .map_err(|e| anyhow!("decompose tuple: {e:?}"))?;
        let mut out = Vec::with_capacity(tuple.len());
        for lit in tuple {
            let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e:?}"))?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let vals = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
            out.push((vals, dims));
        }
        Ok(out)
    }
}
