//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! Artifacts are HLO *text* produced by `python/compile/aot.py` (see repo
//! README for why text, not serialized protos). One compiled executable
//! per model variant, cached.
//!
//! Backend selection: the real implementation wraps the vendored `xla`
//! crate (PJRT C API, CPU plugin) behind the additional `pjrt-xla`
//! feature. With only `pjrt` enabled the module compiles against a stub
//! backend whose constructor reports a clear error, so
//! `cargo check --features pjrt` stays green (and CI exercises it) in
//! environments without the vendored crate. Errors use a local
//! dependency-free type — `anyhow` is no longer required.

#![forbid(unsafe_code)]

use std::fmt;

/// Runtime error: a message with optional nested context.
#[derive(Debug)]
pub struct RuntimeError(String);

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "pjrt runtime: {}", self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// Result alias used throughout the runtime module.
pub type Result<T> = std::result::Result<T, RuntimeError>;

fn rt_err(msg: impl Into<String>) -> RuntimeError {
    RuntimeError(msg.into())
}

#[cfg(feature = "pjrt-xla")]
mod backend {
    //! Real PJRT backend over the vendored `xla` crate.

    use super::{rt_err, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    /// A lazily-compiled registry of HLO artifacts on a single PJRT client.
    pub struct Engine {
        client: xla::PjRtClient,
        exes: HashMap<String, xla::PjRtLoadedExecutable>,
        artifact_dir: PathBuf,
    }

    impl Engine {
        /// Create an engine backed by the PJRT CPU client, loading
        /// artifacts from `artifact_dir` on demand.
        pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Self> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| rt_err(format!("pjrt cpu client: {e:?}")))?;
            Ok(Self {
                client,
                exes: HashMap::new(),
                artifact_dir: artifact_dir.as_ref().to_path_buf(),
            })
        }

        /// Name of the PJRT platform backing this engine (e.g. "cpu").
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile `<artifact_dir>/<name>.hlo.txt` if not cached.
        pub fn load(&mut self, name: &str) -> Result<()> {
            if self.exes.contains_key(name) {
                return Ok(());
            }
            let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| rt_err("artifact path not utf-8"))?,
            )
            .map_err(|e| rt_err(format!("parse HLO text {path:?}: {e:?}")))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| rt_err(format!("compile {name}: {e:?}")))?;
            self.exes.insert(name.to_string(), exe);
            Ok(())
        }

        /// True if the artifact file exists on disk (loaded or not).
        pub fn available(&self, name: &str) -> bool {
            self.artifact_dir.join(format!("{name}.hlo.txt")).exists()
        }

        /// Execute a loaded artifact on f32 buffers.
        ///
        /// Each input is `(data, dims)`; the computation was lowered with
        /// `return_tuple=True`, so outputs come back as a tuple of
        /// literals, flattened here into `Vec<(Vec<f32>, Vec<usize>)>`.
        pub fn run_f32(
            &self,
            name: &str,
            inputs: &[(&[f32], &[usize])],
        ) -> Result<Vec<(Vec<f32>, Vec<usize>)>> {
            let exe = self
                .exes
                .get(name)
                .ok_or_else(|| rt_err(format!("artifact {name} not loaded")))?;
            let mut lits = Vec::with_capacity(inputs.len());
            for (data, dims) in inputs {
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(data)
                    .reshape(&dims_i64)
                    .map_err(|e| rt_err(format!("reshape input: {e:?}")))?;
                lits.push(lit);
            }
            let mut result = exe
                .execute::<xla::Literal>(&lits)
                .map_err(|e| rt_err(format!("execute {name}: {e:?}")))?[0][0]
                .to_literal_sync()
                .map_err(|e| rt_err(format!("fetch result: {e:?}")))?;
            let tuple = result
                .decompose_tuple()
                .map_err(|e| rt_err(format!("decompose tuple: {e:?}")))?;
            let mut out = Vec::with_capacity(tuple.len());
            for lit in tuple {
                let shape = lit
                    .array_shape()
                    .map_err(|e| rt_err(format!("shape: {e:?}")))?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let vals = lit
                    .to_vec::<f32>()
                    .map_err(|e| rt_err(format!("to_vec: {e:?}")))?;
                out.push((vals, dims));
            }
            Ok(out)
        }
    }
}

#[cfg(not(feature = "pjrt-xla"))]
mod backend {
    //! Stub backend: the full `Engine` API surface, failing at
    //! construction with instructions — keeps `--features pjrt`
    //! compiling (and type-checked in CI) without the vendored crates.

    use super::{rt_err, Result};
    use std::path::Path;

    const UNAVAILABLE: &str = "PJRT backend unavailable: the vendored `xla` crate is not \
         present in this build. Uncomment the `xla`/`anyhow` dependencies \
         in rust/Cargo.toml and rebuild with `--features pjrt,pjrt-xla`.";

    /// Stub engine — same public API as the real backend.
    pub struct Engine {}

    impl Engine {
        /// Always fails: the vendored `xla` crate is absent.
        pub fn cpu(_artifact_dir: impl AsRef<Path>) -> Result<Self> {
            Err(rt_err(UNAVAILABLE))
        }

        /// Platform name placeholder.
        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        /// Always fails (no backend to load into).
        pub fn load(&mut self, name: &str) -> Result<()> {
            Err(rt_err(format!("load {name}: {UNAVAILABLE}")))
        }

        /// No artifacts are reachable without a backend.
        pub fn available(&self, _name: &str) -> bool {
            false
        }

        /// Always fails (no backend to execute on).
        pub fn run_f32(
            &self,
            name: &str,
            _inputs: &[(&[f32], &[usize])],
        ) -> Result<Vec<(Vec<f32>, Vec<usize>)>> {
            Err(rt_err(format!("run {name}: {UNAVAILABLE}")))
        }
    }
}

pub use backend::Engine;

#[cfg(all(test, not(feature = "pjrt-xla")))]
mod tests {
    use super::*;

    #[test]
    fn stub_backend_reports_unavailable() {
        let e = Engine::cpu("artifacts");
        assert!(e.is_err());
        let msg = format!("{}", e.err().unwrap());
        assert!(msg.contains("pjrt-xla"), "unhelpful error: {msg}");
    }
}
