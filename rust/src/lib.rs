//! # FAuST — Flexible Approximate Multi-layer Sparse Transforms
//!
//! A Rust + JAX + Pallas reproduction of Le Magoarou & Gribonval,
//! *"Flexible Multi-layer Sparse Approximations of Matrices and
//! Applications"*, IEEE JSTSP 2016 (DOI 10.1109/JSTSP.2016.2543461).
//!
//! The library approximates a dense operator `A ∈ R^{m×n}` by a product of
//! `J` sparse factors `A ≈ λ · S_J ⋯ S_1` (a **FAμST**), so matrix–vector
//! products cost `O(s_tot)` instead of `O(mn)`.
//!
//! Layer map (see `DESIGN.md`):
//! - **L3 (this crate)**: the factorization algorithms ([`palm`],
//!   [`hierarchical`]), projection operators ([`prox`]), the [`faust`]
//!   operator type, solvers, dictionary learning, and the MEG / image
//!   application substrates.
//! - **L3-exec ([`engine`])**: the repo's single execution substrate —
//!   cost-modeled [`engine::ApplyPlan`]s (CSR-vs-dense strategy, factor
//!   fusion, transpose-aware kernels), a `std::thread` chunked worker
//!   pool with row-partitioned parallel spmv/spmm, SIMD-width-aware
//!   register-tiled dense microkernels ([`engine::kernel`]: explicit
//!   f64 lane chunks of 4/8 selected once per process, packed `B`
//!   panels, bitwise thread-invariant tiling), zero-alloc ping-pong
//!   buffer arenas, and the [`engine::ExecCtx`] that runs *training* on
//!   the same pool (cost-dispatched GEMM + pooled power iterations for
//!   palm4MSA / hierarchical / dictlearn). Every `Faust::apply*` routes
//!   through it; the coordinator serves [`engine::EngineOp`]s; the
//!   factorizers take a ctx (`_with_ctx` variants) or default to the
//!   process-wide one. [`engine::FleetCtx`] extends the substrate to
//!   *fleets*: the small independent kernels of many concurrent
//!   factorization problems fuse into operator-granular pool dispatches
//!   ([`palm::palm4msa_fleet_with_ctx`],
//!   [`hierarchical::factorize_fleet`]), bitwise identical to solo runs.
//! - **L3-serve ([`coordinator`])**: live operator registry
//!   (register / hot-swap / retire with epoch draining, plus
//!   `Registry::refactorize_fleet` — re-learn a whole served fleet
//!   concurrently and swap each operator as it finishes) + plan-aware,
//!   traffic-class-aware adaptive batcher (per-operator, per-QoS-class
//!   batch widths from each plan's flop/byte [`engine::CostProfile`])
//!   + worker pool turning planned operators into a matvec service.
//! - **L3-durability ([`store`])**: versioned, CRC-sealed on-disk
//!   snapshots of learned operators (factors + λ + f32 bound + epoch);
//!   `Registry::persist_all` / `load_store` make a whole served fleet
//!   durable so `serve --store DIR` restarts warm in milliseconds
//!   instead of re-running PALM.
//! - **L3-ingress ([`server`])**: std-only TCP front end over the
//!   coordinator — length-prefixed binary wire protocol
//!   ([`server::wire`]), admission control shedding load *before* the
//!   batcher ([`server::admission`]), per-connection reader/writer
//!   threads, QoS deadline classes end to end, graceful drain on
//!   shutdown, and an open-loop Poisson load generator
//!   ([`bench_util::open_loop_load`]).
//! - **L2/L1 (python/, build-time only)**: JAX palm4MSA step + Pallas
//!   gradient kernel, AOT-lowered to HLO text loaded by the `runtime`
//!   module (feature `pjrt`, off by default so the crate builds offline).
//!
//! ## Quickstart
//! ```
//! use faust::transforms::hadamard;
//! use faust::hierarchical::{factorize, HierarchicalConfig};
//!
//! let a = hadamard(32);
//! let cfg = HierarchicalConfig::hadamard(32);
//! let fst = factorize(&a, &cfg);
//! assert!(fst.relative_error_fro(&a) < 1e-6); // exact re-factorization
//! assert!(fst.rcg() > 3.0);                   // and it is actually faster
//! ```

// Numeric-kernel idiom: index-heavy loops mirror the paper's math and the
// CSR layout; the lint's iterator rewrites obscure them.
#![allow(clippy::needless_range_loop)]
// Memory-safety invariant gate (PR 10): unsafe code is confined to
// `engine::{kernel,pool}` — every other module carries
// `#![forbid(unsafe_code)]` — and what remains is audited: operations
// inside `unsafe fn` bodies need their own blocks, and every block
// carries a `// SAFETY:` justification (enforced by clippy in CI with
// `-D warnings`; see `docs/ARCHITECTURE.md` § verification layers).
#![deny(unsafe_op_in_unsafe_fn)]
#![deny(clippy::undocumented_unsafe_blocks)]

pub mod bench_util;
pub mod cli;
pub mod coordinator;
pub mod dictlearn;
pub mod engine;
pub mod faust;
pub mod graph;
pub mod hierarchical;
pub mod image;
pub mod linalg;
pub mod meg;
pub mod palm;
pub mod prox;
pub mod rng;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod server;
pub mod solvers;
pub mod sparse;
pub mod store;
pub mod testutil;
pub mod transforms;
