//! # FAuST — Flexible Approximate Multi-layer Sparse Transforms
//!
//! A Rust + JAX + Pallas reproduction of Le Magoarou & Gribonval,
//! *"Flexible Multi-layer Sparse Approximations of Matrices and
//! Applications"*, IEEE JSTSP 2016 (DOI 10.1109/JSTSP.2016.2543461).
//!
//! The library approximates a dense operator `A ∈ R^{m×n}` by a product of
//! `J` sparse factors `A ≈ λ · S_J ⋯ S_1` (a **FAμST**), so matrix–vector
//! products cost `O(s_tot)` instead of `O(mn)`.
//!
//! Layer map (see `DESIGN.md`):
//! - **L3 (this crate)**: the factorization algorithms ([`palm`],
//!   [`hierarchical`]), projection operators ([`prox`]), the [`faust`]
//!   operator type, solvers, dictionary learning, MEG / image application
//!   substrates, and a threaded operator-serving [`coordinator`].
//! - **L2/L1 (python/, build-time only)**: JAX palm4MSA step + Pallas
//!   gradient kernel, AOT-lowered to HLO text loaded by [`runtime`].
//!
//! ## Quickstart
//! ```
//! use faust::transforms::hadamard;
//! use faust::hierarchical::{factorize, HierarchicalConfig};
//!
//! let a = hadamard(32);
//! let cfg = HierarchicalConfig::hadamard(32);
//! let fst = factorize(&a, &cfg);
//! assert!(fst.relative_error_fro(&a) < 1e-6); // exact re-factorization
//! assert!(fst.rcg() > 3.0);                   // and it is actually faster
//! ```

pub mod bench_util;
pub mod cli;
pub mod coordinator;
pub mod dictlearn;
pub mod faust;
pub mod graph;
pub mod hierarchical;
pub mod image;
pub mod linalg;
pub mod meg;
pub mod palm;
pub mod prox;
pub mod rng;
pub mod runtime;
pub mod solvers;
pub mod sparse;
pub mod testutil;
pub mod transforms;
