//! Minimal benchmarking harness (criterion is not in the offline vendor
//! set): warmup + timed iterations with robust statistics, an aligned
//! table printer used by every `cargo bench` target to emit the paper's
//! figure series as text, and a machine-readable [`BenchReport`] writer
//! (`BENCH_<name>.json`) that CI uploads as artifacts and gates against
//! `benches/baseline.json` (see `scripts/bench_gate.py`) — the perf
//! trajectory is enforced, not just printed.

#![forbid(unsafe_code)]

use std::time::{Duration, Instant};

/// Timing statistics over repeated runs (nanoseconds).
#[derive(Clone, Debug)]
pub struct Timing {
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub mean_ns: f64,
    pub iters: usize,
}

impl Timing {
    pub fn median_us(&self) -> f64 {
        self.median_ns / 1e3
    }
    pub fn median_ms(&self) -> f64 {
        self.median_ns / 1e6
    }
}

/// Time `f` with `warmup` discarded runs then `iters` measured runs.
pub fn time_fn<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Timing {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters.max(1));
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| samples[((samples.len() - 1) as f64 * p).round() as usize];
    Timing {
        median_ns: q(0.5),
        p10_ns: q(0.1),
        p90_ns: q(0.9),
        mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
        iters: samples.len(),
    }
}

/// Adaptive timing: run for at least `min_total_ms` total, at least 3 iters.
pub fn time_auto<T>(min_total_ms: f64, mut f: impl FnMut() -> T) -> Timing {
    // Calibrate with one run.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let one = t0.elapsed().as_secs_f64() * 1e3;
    let iters = ((min_total_ms / one.max(1e-6)).ceil() as usize).clamp(3, 10_000);
    time_fn(1, iters, f)
}

/// Fixed-width table printer for bench outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "table arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with column alignment.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(r[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                line.push_str(&format!("{:<width$}  ", cell, width = widths[c]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Result of the shared fleet-vs-sequential comparison protocol
/// ([`fleet_compare`]): both the `faust fleet` CLI and the CI-gated
/// `benches/fleet_scaling.rs` consume this, so they cannot drift into
/// measuring different things.
pub struct FleetComparison {
    pub ops: usize,
    pub n: usize,
    /// Threads of the shared ctx both modes ran on.
    pub threads: usize,
    /// Wall clock of the `ops` sequential `factorize_with_ctx` calls.
    pub seq_s: f64,
    /// Wall clock of the single `factorize_fleet_with_ctx` call.
    pub fleet_s: f64,
    /// Fleet results fingerprint-identical to the sequential runs.
    pub identical: bool,
    /// Worst relative Frobenius error across the fleet's operators.
    pub max_rel_err: f64,
    /// The fleet ctx's crossover counters.
    pub metrics: crate::engine::FleetMetricsSnapshot,
}

impl FleetComparison {
    /// Sequential-over-fleet wall-clock ratio (> 1 ⇒ the fleet won).
    pub fn speedup(&self) -> f64 {
        self.seq_s / self.fleet_s
    }
}

/// Factorize `ops` seeded `n`-point Hadamard problems sequentially, then
/// the same jobs as one fleet on the same ctx, and compare: wall clock,
/// bitwise identity (fingerprints), worst relative error. One member per
/// "subject" (§V framing) — identical shapes, independent trajectories
/// via per-member seeds.
pub fn fleet_compare(ops: usize, n: usize, ctx: &crate::engine::ExecCtx) -> FleetComparison {
    use crate::engine::FleetCtx;
    use crate::hierarchical::{factorize_fleet_with_ctx, factorize_with_ctx, HierarchicalConfig};
    use crate::testutil::faust_fingerprint;

    assert!(n.is_power_of_two() && n >= 8, "fleet_compare needs n = 2^k >= 8");
    assert!(ops >= 1, "fleet_compare needs at least one operator");
    let a = crate::transforms::hadamard(n);
    let cfgs: Vec<HierarchicalConfig> = (0..ops)
        .map(|i| {
            let mut c = HierarchicalConfig::hadamard(n);
            c.seed ^= i as u64;
            c
        })
        .collect();

    // Untimed warmup: one throwaway factorization so first-touch
    // allocation, allocator growth and cold caches don't land entirely on
    // whichever mode is timed first (the sequential pass) and inflate the
    // reported speedup.
    std::hint::black_box(factorize_with_ctx(ctx, &a, &cfgs[0]));

    let t0 = Instant::now();
    let solo: Vec<crate::faust::Faust> = cfgs
        .iter()
        .map(|c| factorize_with_ctx(ctx, &a, c))
        .collect();
    let seq_s = t0.elapsed().as_secs_f64();

    let fleet = FleetCtx::new(ctx.clone());
    let jobs: Vec<(&crate::linalg::Mat, &HierarchicalConfig)> =
        cfgs.iter().map(|c| (&a, c)).collect();
    let t1 = Instant::now();
    let flt = factorize_fleet_with_ctx(&fleet, &jobs);
    let fleet_s = t1.elapsed().as_secs_f64();

    let identical = solo
        .iter()
        .zip(&flt)
        .all(|(s, f)| faust_fingerprint(s) == faust_fingerprint(f));
    let max_rel_err = flt
        .iter()
        .map(|f| f.relative_error_fro(&a))
        .fold(0.0_f64, f64::max);
    FleetComparison {
        ops,
        n,
        threads: ctx.n_threads(),
        seq_s,
        fleet_s,
        identical,
        max_rel_err,
        metrics: fleet.metrics(),
    }
}

/// Result of the shared scalar-vs-tiled dense-microkernel comparison
/// ([`compare_scalar_vs_tiled`]) — consumed by both `engine_scaling`
/// and `factorize_scaling`, so the two gated speedup metrics cannot
/// drift into measuring different protocols.
pub struct KernelComparison {
    /// Scalar-reference kernel timing.
    pub scalar: Timing,
    /// Register-tiled kernel timing.
    pub tiled: Timing,
    /// Worst relative deviation between the two results (asserted
    /// ≤ 1e-12 before this struct is returned).
    pub max_rel_dev: f64,
    /// f64 lane-chunk width of the tiled build (4 or 8).
    pub lanes: usize,
}

impl KernelComparison {
    /// Scalar-over-tiled median ratio (> 1 ⇒ the tiled kernel won).
    pub fn speedup(&self) -> f64 {
        self.scalar.median_ns / self.tiled.median_ns
    }
}

/// Time the scalar-reference GEMM against the register-tiled
/// `engine::kernel` build on one seeded `m×k · k×bcols` product, single
/// thread on both sides so the ratio isolates the microkernel. Outputs
/// are `black_box`ed (dead-code-elimination-proof) and checked to agree
/// within 1e-12 relative before the ratio is reported; panics on
/// divergence.
pub fn compare_scalar_vs_tiled(
    m: usize,
    k: usize,
    bcols: usize,
    min_ms: f64,
    seed: u64,
) -> KernelComparison {
    use crate::engine::kernel;
    use std::hint::black_box;
    let mut rng = crate::rng::Rng::new(seed);
    let a = crate::linalg::Mat::randn(m, k, &mut rng);
    let b = crate::linalg::Mat::randn(k, bcols, &mut rng);
    let mut scalar_out = vec![0.0; m * bcols];
    let mut tiled_out = vec![0.0; m * bcols];
    let scalar = time_auto(min_ms, || {
        kernel::gemm_scalar_rows(&a, b.data(), bcols, 0, m, &mut scalar_out);
        black_box(&mut scalar_out);
    });
    let tiled = time_auto(min_ms, || {
        kernel::gemm_tiled_rows(&a, b.data(), bcols, 0, m, &mut tiled_out);
        black_box(&mut tiled_out);
    });
    let max_rel_dev = scalar_out
        .iter()
        .zip(&tiled_out)
        .map(|(s, t)| (t - s).abs() / (1.0 + s.abs()))
        .fold(0.0f64, f64::max);
    assert!(
        max_rel_dev <= 1e-12,
        "tiled kernel diverged from the scalar reference: {max_rel_dev:.3e}"
    );
    KernelComparison { scalar, tiled, max_rel_dev, lanes: kernel::lane_width() }
}

/// Result of the shared f64-vs-f32 plan-apply comparison
/// ([`compare_apply_f32_vs_f64`]) — consumed by `engine_scaling`, so the
/// gated precision metrics and the in-bench speedup claim measure one
/// protocol (identical plan structure, identical shapes, one thread).
pub struct PrecisionComparison {
    /// f64 master-plan timing (tiled kernels — the strongest baseline).
    pub t64: Timing,
    /// Quantized f32 serving-plan timing on the identical shape.
    pub t32: Timing,
    /// Worst per-column relative ℓ2 error of the f32 outputs against the
    /// f64 reference (asserted ≤ the declared bound before returning).
    pub max_rel_err: f64,
    /// f32 lane-chunk width of the kernel build (16/8/8 by SIMD level).
    pub lanes_f32: usize,
}

impl PrecisionComparison {
    /// f64-over-f32 median ratio (> 1 ⇒ the f32 tier won).
    pub fn speedup(&self) -> f64 {
        self.t64.median_ns / self.t32.median_ns
    }
}

/// Time one operator's compiled f64 plan against its quantized f32
/// serving plan on a seeded `cols×bcols` batch, single thread on both
/// sides so the ratio isolates element width (bytes moved + SIMD lanes),
/// not scheduling. The f32 outputs are checked against the f64 master
/// within the conversion's declared error bound; panics on divergence —
/// a speedup bought with accuracy outside the declared envelope would be
/// a lie, so the comparison refuses to report one.
pub fn compare_apply_f32_vs_f64(
    f: &crate::faust::Faust,
    bcols: usize,
    min_ms: f64,
    seed: u64,
) -> (PrecisionComparison, crate::engine::F32Bound) {
    use crate::engine::{kernel, ApplyPlan, Arena, PlanConfig, ThreadPool};
    use std::hint::black_box;
    let pool = ThreadPool::new(1);
    let plan = ApplyPlan::compile(f, &PlanConfig::default());
    let (plan32, bound) = plan.to_f32_with_bound(&pool);
    let mut rng = crate::rng::Rng::new(seed);
    let x64 = rng.gauss_vec(f.cols() * bcols);
    let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
    let rows = f.rows();
    let mut y64 = vec![0.0f64; rows * bcols];
    let mut y32 = vec![0.0f32; rows * bcols];
    let mut a64 = Arena::<f64>::new();
    let mut a32 = Arena::<f32>::new();
    let t64 = time_auto(min_ms, || {
        plan.execute_batch_into(&pool, &mut a64, black_box(&x64), bcols, &mut y64);
        black_box(&mut y64);
    });
    let t32 = time_auto(min_ms, || {
        plan32.execute_batch_into(&pool, &mut a32, black_box(&x32), bcols, &mut y32);
        black_box(&mut y32);
    });
    let mut max_rel_err = 0.0f64;
    for j in 0..bcols {
        let (mut err2, mut ref2) = (0.0f64, 0.0f64);
        for i in 0..rows {
            let w = y64[i * bcols + j];
            let d = y32[i * bcols + j] as f64 - w;
            err2 += d * d;
            ref2 += w * w;
        }
        if ref2 > 0.0 {
            max_rel_err = max_rel_err.max((err2 / ref2).sqrt());
        }
    }
    assert!(
        max_rel_err <= bound.declared_rel_err,
        "f32 serving plan diverged beyond its declared bound: {max_rel_err:.3e} > {:.3e}",
        bound.declared_rel_err
    );
    let cmp = PrecisionComparison {
        t64,
        t32,
        max_rel_err,
        lanes_f32: kernel::lane_width_of::<f32>(),
    };
    (cmp, bound)
}

/// Machine-readable bench results: named float metrics serialized to
/// `BENCH_<name>.json` (hand-rolled writer — no serde in the offline
/// vendor set). Benches call [`BenchReport::write`] when invoked with
/// `--json`; CI uploads the files as workflow artifacts and
/// `scripts/bench_gate.py` compares them against the committed
/// `benches/baseline.json`, failing the build on regressions.
pub struct BenchReport {
    name: String,
    metrics: Vec<(String, f64)>,
}

impl BenchReport {
    /// Empty report for bench target `name` (used in the file name; keep
    /// it to `[A-Za-z0-9_-]`).
    pub fn new(name: &str) -> Self {
        BenchReport { name: name.to_string(), metrics: Vec::new() }
    }

    /// Record one metric (later values with the same key are kept too —
    /// keys should be unique for the gate to be meaningful).
    pub fn push(&mut self, key: &str, value: f64) {
        self.metrics.push((key.to_string(), value));
    }

    /// JSON body: `{"name": "...", "metrics": {"k": v, ...}}`.
    /// Non-finite values serialize as `null` (JSON has no NaN/Inf).
    pub fn to_json(&self) -> String {
        let esc = |s: &str| s.replace('\\', "\\\\").replace('"', "\\\"");
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"name\": \"{}\",\n", esc(&self.name)));
        out.push_str("  \"metrics\": {\n");
        for (k, (key, v)) in self.metrics.iter().enumerate() {
            let val = if v.is_finite() { format!("{v}") } else { "null".to_string() };
            let comma = if k + 1 < self.metrics.len() { "," } else { "" };
            out.push_str(&format!("    \"{}\": {val}{comma}\n", esc(key)));
        }
        out.push_str("  }\n}\n");
        out
    }

    /// Write `BENCH_<name>.json` into `dir` and return the path.
    pub fn write(&self, dir: &str) -> std::io::Result<String> {
        let path = format!("{}/BENCH_{}.json", dir.trim_end_matches('/'), self.name);
        std::fs::write(&path, self.to_json())?;
        Ok(path)
    }
}

/// Latency percentiles (µs) over one request population.
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    pub n: usize,
    pub p50_us: f64,
    pub p90_us: f64,
    pub p99_us: f64,
    pub p999_us: f64,
    pub max_us: f64,
}

/// Percentiles of a sample set in nanoseconds → µs (nearest-rank on the
/// sorted samples; 0s when empty).
pub fn latency_stats_us(samples_ns: &[u64]) -> LatencyStats {
    let mut s: Vec<u64> = samples_ns.to_vec();
    s.sort_unstable();
    let q = |p: f64| {
        if s.is_empty() {
            0.0
        } else {
            s[((s.len() - 1) as f64 * p).round() as usize] as f64 / 1e3
        }
    };
    LatencyStats {
        n: s.len(),
        p50_us: q(0.5),
        p90_us: q(0.9),
        p99_us: q(0.99),
        p999_us: q(0.999),
        max_us: q(1.0),
    }
}

/// Configuration of one open-loop load stream (one QoS class on one
/// connection).
#[derive(Clone, Debug)]
pub struct OpenLoopConfig {
    /// Server address (`host:port`).
    pub addr: String,
    /// Operator to hit.
    pub op: String,
    pub class: crate::coordinator::QosClass,
    /// Mean Poisson arrival rate (requests/s).
    pub rate_hz: f64,
    /// Requests to send.
    pub requests: usize,
    /// Input dimension (the operator's cols).
    pub dim: usize,
    /// Seed of the arrival process and the per-request inputs.
    pub seed: u64,
    /// Payload element type on the wire (f32 halves payload bytes both
    /// ways; values quantize in transit and the server echoes the dtype).
    pub dtype: crate::server::wire::Dtype,
    /// Absolute per-element tolerance of the response verification. f64
    /// streams use 1e-6; f32 streams need headroom for the wire
    /// quantization of both the input and the result.
    pub verify_tol: f64,
}

/// Outcome of one open-loop stream.
#[derive(Clone, Debug)]
pub struct ClassLoadReport {
    pub class: crate::coordinator::QosClass,
    pub sent: usize,
    /// OK responses whose payload verified (when a reference operator
    /// was supplied; unverified OKs count here too).
    pub ok: usize,
    /// Typed `Overloaded` responses — the only acceptable shed signal.
    pub shed: usize,
    /// Any other typed error response.
    pub other_errors: usize,
    /// Wire/IO failures on the response path (should be zero).
    pub protocol_errors: usize,
    /// Responses that failed verification against the reference
    /// operator, or whose req_id broke FIFO order (must be zero).
    pub misrouted: usize,
    /// Latency percentiles over the OK responses.
    pub latency: LatencyStats,
    /// Distinct registry epochs observed in OK responses (a mid-traffic
    /// swap shows up as a second epoch).
    pub epochs: Vec<u64>,
    /// Wall clock of the whole stream.
    pub wall_s: f64,
}

impl ClassLoadReport {
    /// Shed responses over sent requests.
    pub fn shed_rate(&self) -> f64 {
        if self.sent == 0 {
            0.0
        } else {
            self.shed as f64 / self.sent as f64
        }
    }
}

/// Seed mixer for per-request inputs: both the sender and the verifier
/// regenerate request `i`'s input as `Rng::new(seed ^ (i+1)·GOLDEN)`.
const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

fn request_input(seed: u64, req_id: u64, dim: usize) -> Vec<f64> {
    let mut rng = crate::rng::Rng::new(seed ^ (req_id + 1).wrapping_mul(GOLDEN));
    rng.gauss_vec(dim)
}

/// Drive one **open-loop** load stream against a running ingress server:
/// Poisson arrivals at `cfg.rate_hz` paced by an absolute schedule — the
/// sender never waits for responses, so server slowdown shows up as
/// latency, not as a reduced offered rate (closed-loop coordination
/// omission is the classic way serving benchmarks lie to themselves).
///
/// A receiver thread drains responses concurrently. Responses on one
/// connection are FIFO, so each is matched to its send timestamp in
/// order; an out-of-order `req_id` counts as misrouted. When `verify` is
/// given, each OK payload is checked against `verify · x` for the
/// deterministically regenerated input `x` (`cfg.verify_tol` absolute,
/// sized to the stream's wire dtype) — a swap to
/// a same-operator new generation must not change results, so this is
/// the end-to-end no-corruption check the soak gates on.
pub fn open_loop_load(
    cfg: &OpenLoopConfig,
    verify: Option<&crate::linalg::Mat>,
) -> Result<ClassLoadReport, String> {
    use crate::coordinator::QosClass;
    use crate::server::wire::{ErrorCode, WireResponse};
    use crate::server::ServeConn;
    use std::sync::mpsc;

    let mut conn =
        ServeConn::connect(&cfg.addr).map_err(|e| format!("connect {}: {e}", cfg.addr))?;
    conn.set_dtype(cfg.dtype);
    let (mut tx_half, mut rx_half) = conn.split().map_err(|e| format!("split: {e}"))?;
    let (ts_tx, ts_rx) = mpsc::channel::<(u64, Instant)>();
    let class: QosClass = cfg.class;
    let dim = cfg.dim;
    let seed = cfg.seed;
    let verify_tol = cfg.verify_tol;
    let verify = verify.cloned();

    let t_start = Instant::now();
    let receiver = std::thread::Builder::new()
        .name(format!("faust-load-rx-{}", class.name()))
        .spawn(move || {
            let mut ok = 0usize;
            let mut shed = 0usize;
            let mut other_errors = 0usize;
            let mut protocol_errors = 0usize;
            let mut misrouted = 0usize;
            let mut samples_ns: Vec<u64> = Vec::new();
            let mut epochs = std::collections::BTreeSet::new();
            while let Ok((sent_id, t0)) = ts_rx.recv() {
                let resp = match rx_half.recv() {
                    Ok(r) => r,
                    Err(_) => {
                        protocol_errors += 1;
                        break;
                    }
                };
                let latency_ns = t0.elapsed().as_nanos() as u64;
                if resp.req_id() != sent_id {
                    misrouted += 1;
                    continue;
                }
                match resp {
                    WireResponse::Ok { epoch, rows, cols, data, .. } => {
                        let mut good = cols == 1;
                        if let Some(a) = &verify {
                            let x = request_input(seed, sent_id, dim);
                            let want = a.matvec(&x);
                            good = good
                                && rows == want.len()
                                && data.len() == want.len()
                                && data
                                    .iter()
                                    .zip(&want)
                                    .all(|(y, w)| (y - w).abs() < verify_tol);
                        }
                        if good {
                            ok += 1;
                            epochs.insert(epoch);
                            samples_ns.push(latency_ns);
                        } else {
                            misrouted += 1;
                        }
                    }
                    WireResponse::Err { code: ErrorCode::Overloaded, .. } => shed += 1,
                    WireResponse::Err { .. } => other_errors += 1,
                }
            }
            (ok, shed, other_errors, protocol_errors, misrouted, samples_ns, epochs)
        })
        .map_err(|e| format!("spawn receiver: {e}"))?;

    // Sender: absolute Poisson schedule from the seeded RNG.
    let mut rng = crate::rng::Rng::new(cfg.seed);
    let mean_gap_s = 1.0 / cfg.rate_hz.max(1e-9);
    let mut t_next = 0.0f64;
    let mut sent = 0usize;
    for i in 0..cfg.requests {
        let u: f64 = rng.uniform();
        t_next += -mean_gap_s * (1.0 - u).max(1e-300).ln();
        let elapsed = t_start.elapsed().as_secs_f64();
        if t_next > elapsed {
            std::thread::sleep(Duration::from_secs_f64(t_next - elapsed));
        }
        let x = request_input(cfg.seed, i as u64, cfg.dim);
        let t0 = Instant::now();
        match tx_half.send(&cfg.op, cfg.class, 0, cfg.dim, 1, x) {
            Ok(req_id) => {
                sent += 1;
                if ts_tx.send((req_id, t0)).is_err() {
                    break; // receiver died (protocol error)
                }
            }
            Err(_) => break, // connection gone; receiver will report
        }
    }
    drop(ts_tx); // receiver drains the remaining responses, then exits
    let (ok, shed, other_errors, protocol_errors, misrouted, samples_ns, epochs) =
        receiver.join().map_err(|_| "receiver thread panicked".to_string())?;
    Ok(ClassLoadReport {
        class: cfg.class,
        sent,
        ok,
        shed,
        other_errors,
        protocol_errors,
        misrouted,
        latency: latency_stats_us(&samples_ns),
        epochs: epochs.into_iter().collect(),
        wall_s: t_start.elapsed().as_secs_f64(),
    })
}

/// Format a float compactly for tables.
pub fn fmt(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 1000.0 || v.abs() < 0.001 {
        format!("{v:.3e}")
    } else {
        format!("{v:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_orders_sensible() {
        let t = time_fn(2, 20, || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(t.p10_ns <= t.median_ns && t.median_ns <= t.p90_ns);
        assert_eq!(t.iters, 20);
        assert!(t.median_ns > 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "metric"]);
        t.row(&["x".into(), "1.0".into()]);
        t.row(&["longer".into(), "2.5".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("a"));
        assert!(lines[2].starts_with("x"));
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt(0.0), "0");
        assert!(fmt(1234.5).contains('e'));
        assert!(fmt(0.25).starts_with("0.25"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn fleet_compare_runs_and_verifies_identity() {
        let ctx = crate::engine::ExecCtx::new(2);
        let cmp = fleet_compare(2, 8, &ctx);
        assert_eq!((cmp.ops, cmp.n, cmp.threads), (2, 8, 2));
        assert!(cmp.identical, "fleet diverged from sequential runs");
        assert!(cmp.max_rel_err < 1e-6);
        assert!(cmp.seq_s > 0.0 && cmp.fleet_s > 0.0);
        assert!(cmp.speedup() > 0.0);
    }

    #[test]
    fn precision_comparison_stays_within_declared_bound() {
        let f = fleet_test_op();
        let (cmp, bound) = compare_apply_f32_vs_f64(&f, 8, 1.0, 99);
        assert!(cmp.max_rel_err <= bound.declared_rel_err);
        assert!(bound.declared_rel_err > 0.0);
        assert!(cmp.lanes_f32 == 8 || cmp.lanes_f32 == 16);
        assert!(cmp.speedup() > 0.0);
        assert!(cmp.t64.median_ns > 0.0 && cmp.t32.median_ns > 0.0);
    }

    /// Small mixed sparse/dense operator for the precision comparison.
    fn fleet_test_op() -> crate::faust::Faust {
        let mut rng = crate::rng::Rng::new(3);
        let mats = vec![
            crate::linalg::Mat::randn(24, 16, &mut rng),
            crate::linalg::Mat::randn(24, 24, &mut rng),
        ];
        crate::faust::Faust::from_dense_factors(&mats, 1.5)
    }

    #[test]
    fn kernel_comparison_agrees_and_reports() {
        let cmp = compare_scalar_vs_tiled(12, 9, 8, 1.0, 42);
        assert!(cmp.max_rel_dev <= 1e-12);
        assert!(cmp.lanes == 4 || cmp.lanes == 8);
        assert!(cmp.speedup() > 0.0);
        assert!(cmp.scalar.median_ns > 0.0 && cmp.tiled.median_ns > 0.0);
    }

    #[test]
    fn bench_report_serializes_valid_json() {
        let mut r = BenchReport::new("unit_test");
        r.push("wall_s", 1.25);
        r.push("speedup", 2.0);
        r.push("weird", f64::NAN);
        let j = r.to_json();
        assert!(j.contains("\"name\": \"unit_test\""));
        assert!(j.contains("\"wall_s\": 1.25"));
        assert!(j.contains("\"speedup\": 2"));
        assert!(j.contains("\"weird\": null"));
        // Every metric line but the last carries a trailing comma.
        assert_eq!(j.matches(",\n").count(), 3); // name + 2 metric commas
    }

    #[test]
    fn bench_report_writes_named_file() {
        let dir = std::env::temp_dir();
        let dir = dir.to_str().unwrap();
        let mut r = BenchReport::new("writer_check");
        r.push("x", 3.5);
        let path = r.write(dir).unwrap();
        assert!(path.ends_with("BENCH_writer_check.json"));
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains("\"x\": 3.5"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn latency_stats_rank_the_tail() {
        // 1..=1000 µs in ns: the percentiles are exact ranks.
        let samples: Vec<u64> = (1..=1000u64).map(|us| us * 1000).collect();
        let s = latency_stats_us(&samples);
        assert_eq!(s.n, 1000);
        assert!((s.p50_us - 500.0).abs() <= 1.0);
        assert!((s.p99_us - 990.0).abs() <= 1.0);
        assert!((s.p999_us - 999.0).abs() <= 1.0);
        assert!((s.max_us - 1000.0).abs() < 1e-9);
        // Empty populations report zeros, not a panic.
        let z = latency_stats_us(&[]);
        assert_eq!(z.n, 0);
        assert_eq!(z.max_us, 0.0);
    }

    #[test]
    fn request_inputs_are_deterministic_and_distinct() {
        let a = request_input(7, 3, 16);
        let b = request_input(7, 3, 16);
        let c = request_input(7, 4, 16);
        assert_eq!(a, b);
        assert!(a.iter().zip(&c).any(|(x, y)| x != y));
    }
}
