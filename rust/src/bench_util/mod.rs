//! Minimal benchmarking harness (criterion is not in the offline vendor
//! set): warmup + timed iterations with robust statistics, plus an aligned
//! table printer used by every `cargo bench` target to emit the paper's
//! figure series as text.

use std::time::Instant;

/// Timing statistics over repeated runs (nanoseconds).
#[derive(Clone, Debug)]
pub struct Timing {
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub mean_ns: f64,
    pub iters: usize,
}

impl Timing {
    pub fn median_us(&self) -> f64 {
        self.median_ns / 1e3
    }
    pub fn median_ms(&self) -> f64 {
        self.median_ns / 1e6
    }
}

/// Time `f` with `warmup` discarded runs then `iters` measured runs.
pub fn time_fn<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Timing {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters.max(1));
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| samples[((samples.len() - 1) as f64 * p).round() as usize];
    Timing {
        median_ns: q(0.5),
        p10_ns: q(0.1),
        p90_ns: q(0.9),
        mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
        iters: samples.len(),
    }
}

/// Adaptive timing: run for at least `min_total_ms` total, at least 3 iters.
pub fn time_auto<T>(min_total_ms: f64, mut f: impl FnMut() -> T) -> Timing {
    // Calibrate with one run.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let one = t0.elapsed().as_secs_f64() * 1e3;
    let iters = ((min_total_ms / one.max(1e-6)).ceil() as usize).clamp(3, 10_000);
    time_fn(1, iters, f)
}

/// Fixed-width table printer for bench outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "table arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with column alignment.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(r[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                line.push_str(&format!("{:<width$}  ", cell, width = widths[c]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float compactly for tables.
pub fn fmt(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 1000.0 || v.abs() < 0.001 {
        format!("{v:.3e}")
    } else {
        format!("{v:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_orders_sensible() {
        let t = time_fn(2, 20, || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(t.p10_ns <= t.median_ns && t.median_ns <= t.p90_ns);
        assert_eq!(t.iters, 20);
        assert!(t.median_ns > 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "metric"]);
        t.row(&["x".into(), "1.0".into()]);
        t.row(&["longer".into(), "2.5".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("a"));
        assert!(lines[2].starts_with("x"));
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt(0.0), "0");
        assert!(fmt(1234.5).contains('e'));
        assert!(fmt(0.25).starts_with("0.25"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
