//! Lock-free serving metrics: request/batch/latency counters updated on
//! the hot path, plus registry lifecycle counters (register/swap/retire)
//! so a deployment can see operator churn next to its throughput.

use std::sync::atomic::{AtomicU64, Ordering};

/// Atomic counters updated on the hot path.
pub struct Metrics {
    submitted: AtomicU64,
    rejected: AtomicU64,
    completed: AtomicU64,
    batches: AtomicU64,
    batched_requests: AtomicU64,
    max_batch_size: AtomicU64,
    exec_ns_total: AtomicU64,
    latency_ns_total: AtomicU64,
    latency_ns_max: AtomicU64,
    flops_total: AtomicU64,
    registered: AtomicU64,
    swaps: AtomicU64,
    retired: AtomicU64,
}

/// Point-in-time copy of the metrics.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub max_batch_size: u64,
    pub exec_ns_total: u64,
    pub latency_ns_total: u64,
    pub latency_ns_max: u64,
    pub flops_total: u64,
    /// Operators published via `Registry::register`.
    pub registered: u64,
    /// Live hot swaps (`Registry::swap_epoch`).
    pub swaps: u64,
    /// Operators removed via `Registry::retire`.
    pub retired: u64,
}

impl MetricsSnapshot {
    /// Mean batch size actually executed.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }

    /// Mean end-to-end latency in microseconds.
    pub fn mean_latency_us(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.latency_ns_total as f64 / self.completed as f64 / 1e3
        }
    }

    /// Effective GFLOP/s over executor time.
    pub fn gflops(&self) -> f64 {
        if self.exec_ns_total == 0 {
            0.0
        } else {
            self.flops_total as f64 / self.exec_ns_total as f64
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            submitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            max_batch_size: AtomicU64::new(0),
            exec_ns_total: AtomicU64::new(0),
            latency_ns_total: AtomicU64::new(0),
            latency_ns_max: AtomicU64::new(0),
            flops_total: AtomicU64::new(0),
            registered: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
            retired: AtomicU64::new(0),
        }
    }

    pub fn record_submitted(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
        self.max_batch_size.fetch_max(size as u64, Ordering::Relaxed);
    }

    pub fn record_exec(&self, _batch: usize, exec_ns: u64, flops: u64) {
        self.exec_ns_total.fetch_add(exec_ns, Ordering::Relaxed);
        self.flops_total.fetch_add(flops, Ordering::Relaxed);
    }

    pub fn record_completed(&self, latency_ns: u64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latency_ns_total.fetch_add(latency_ns, Ordering::Relaxed);
        self.latency_ns_max.fetch_max(latency_ns, Ordering::Relaxed);
    }

    pub fn record_registered(&self) {
        self.registered.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_swap(&self) {
        self.swaps.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_retired(&self) {
        self.retired.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_requests: self.batched_requests.load(Ordering::Relaxed),
            max_batch_size: self.max_batch_size.load(Ordering::Relaxed),
            exec_ns_total: self.exec_ns_total.load(Ordering::Relaxed),
            latency_ns_total: self.latency_ns_total.load(Ordering::Relaxed),
            latency_ns_max: self.latency_ns_max.load(Ordering::Relaxed),
            flops_total: self.flops_total.load(Ordering::Relaxed),
            registered: self.registered.load(Ordering::Relaxed),
            swaps: self.swaps.load(Ordering::Relaxed),
            retired: self.retired.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_submitted();
        m.record_submitted();
        m.record_batch(2);
        m.record_exec(2, 1000, 400);
        m.record_completed(500);
        m.record_completed(1500);
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.completed, 2);
        assert_eq!(s.batches, 1);
        assert_eq!(s.mean_batch_size(), 2.0);
        assert_eq!(s.latency_ns_max, 1500);
        assert!((s.mean_latency_us() - 1.0).abs() < 1e-12);
        assert!((s.gflops() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn registry_lifecycle_counters() {
        let m = Metrics::new();
        m.record_registered();
        m.record_registered();
        m.record_swap();
        m.record_retired();
        let s = m.snapshot();
        assert_eq!((s.registered, s.swaps, s.retired), (2, 1, 1));
    }

    #[test]
    fn empty_snapshot_is_safe() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.mean_batch_size(), 0.0);
        assert_eq!(s.mean_latency_us(), 0.0);
        assert_eq!(s.gflops(), 0.0);
    }
}
