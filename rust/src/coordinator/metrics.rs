//! Lock-free serving metrics: request/batch/latency counters updated on
//! the hot path, plus registry lifecycle counters (register/swap/retire)
//! so a deployment can see operator churn next to its throughput, plus
//! network-ingress counters (accepted / shed-per-class / connections /
//! intake-queue high-water) recorded by the TCP front end's admission
//! controller (see [`crate::server`]).

use super::{QosClass, ServedPrecision};
use std::sync::atomic::{AtomicU64, Ordering};

/// Atomic counters updated on the hot path.
pub struct Metrics {
    submitted: AtomicU64,
    rejected: AtomicU64,
    completed: AtomicU64,
    batches: AtomicU64,
    batched_requests: AtomicU64,
    max_batch_size: AtomicU64,
    exec_ns_total: AtomicU64,
    latency_ns_total: AtomicU64,
    latency_ns_max: AtomicU64,
    flops_total: AtomicU64,
    registered: AtomicU64,
    swaps: AtomicU64,
    retired: AtomicU64,
    ingress_accepted: AtomicU64,
    ingress_shed: [AtomicU64; 3],
    ingress_connections: AtomicU64,
    ingress_active_connections: AtomicU64,
    ingress_queue_hwm: AtomicU64,
    applies_f64: AtomicU64,
    applies_f32: AtomicU64,
    jobs_donated: AtomicU64,
    store_persisted: AtomicU64,
    store_loaded: AtomicU64,
    store_skipped: AtomicU64,
    online_batches: AtomicU64,
    online_cols: AtomicU64,
    online_swaps: AtomicU64,
    online_rel_err_bits: AtomicU64,
}

/// Point-in-time copy of the metrics.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub max_batch_size: u64,
    pub exec_ns_total: u64,
    pub latency_ns_total: u64,
    pub latency_ns_max: u64,
    pub flops_total: u64,
    /// Operators published via `Registry::register`.
    pub registered: u64,
    /// Live hot swaps (`Registry::swap_epoch`).
    pub swaps: u64,
    /// Operators removed via `Registry::retire`.
    pub retired: u64,
    /// Wire requests admitted by the ingress admission controller.
    pub ingress_accepted: u64,
    /// Wire requests shed (`Overloaded`), per QoS class
    /// (indexed by [`QosClass::index`]).
    pub ingress_shed: [u64; 3],
    /// TCP connections accepted over the server's lifetime.
    pub ingress_connections: u64,
    /// TCP connections currently open.
    pub ingress_active_connections: u64,
    /// High-water mark of the admission controller's in-flight depth.
    pub ingress_queue_hwm: u64,
    /// Requests executed on an f64 generation (precision tier).
    pub applies_f64: u64,
    /// Requests executed on a quantized f32 generation.
    pub applies_f32: u64,
    /// Whole flush jobs stolen by an idle shard's worker from a sibling
    /// shard's queue (work donation; 0 on a single-shard coordinator).
    pub jobs_donated: u64,
    /// Operator snapshots written by `Registry::persist_all`.
    pub store_persisted: u64,
    /// Operator snapshots restored by `Registry::load_store`.
    pub store_loaded: u64,
    /// Store files skipped as torn/corrupt during a restore.
    pub store_skipped: u64,
    /// Mini-batches ingested by the online learner.
    pub online_batches: u64,
    /// Observed columns ingested by the online learner.
    pub online_cols: u64,
    /// Improved generations the online learner published via
    /// `Registry::swap_epoch` (a subset of `swaps`).
    pub online_swaps: u64,
    /// Latest relative approximation error reported by the online
    /// learner's sweep (the drift gauge; 0.0 before the first sweep).
    pub online_rel_err: f64,
}

impl MetricsSnapshot {
    /// Mean batch size actually executed.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }

    /// Mean end-to-end latency in microseconds.
    pub fn mean_latency_us(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.latency_ns_total as f64 / self.completed as f64 / 1e3
        }
    }

    /// Effective GFLOP/s over executor time.
    pub fn gflops(&self) -> f64 {
        if self.exec_ns_total == 0 {
            0.0
        } else {
            self.flops_total as f64 / self.exec_ns_total as f64
        }
    }

    /// Total wire requests shed across all QoS classes.
    pub fn ingress_shed_total(&self) -> u64 {
        self.ingress_shed.iter().sum()
    }

    /// Share of executed requests served by f32 generations (0 when
    /// nothing has executed yet).
    pub fn f32_apply_frac(&self) -> f64 {
        let total = self.applies_f64 + self.applies_f32;
        if total == 0 {
            0.0
        } else {
            self.applies_f32 as f64 / total as f64
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            submitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            max_batch_size: AtomicU64::new(0),
            exec_ns_total: AtomicU64::new(0),
            latency_ns_total: AtomicU64::new(0),
            latency_ns_max: AtomicU64::new(0),
            flops_total: AtomicU64::new(0),
            registered: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
            retired: AtomicU64::new(0),
            ingress_accepted: AtomicU64::new(0),
            ingress_shed: [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)],
            ingress_connections: AtomicU64::new(0),
            ingress_active_connections: AtomicU64::new(0),
            ingress_queue_hwm: AtomicU64::new(0),
            applies_f64: AtomicU64::new(0),
            applies_f32: AtomicU64::new(0),
            jobs_donated: AtomicU64::new(0),
            store_persisted: AtomicU64::new(0),
            store_loaded: AtomicU64::new(0),
            store_skipped: AtomicU64::new(0),
            online_batches: AtomicU64::new(0),
            online_cols: AtomicU64::new(0),
            online_swaps: AtomicU64::new(0),
            online_rel_err_bits: AtomicU64::new(0),
        }
    }

    pub fn record_submitted(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
        self.max_batch_size.fetch_max(size as u64, Ordering::Relaxed);
    }

    pub fn record_exec(&self, _batch: usize, exec_ns: u64, flops: u64) {
        self.exec_ns_total.fetch_add(exec_ns, Ordering::Relaxed);
        self.flops_total.fetch_add(flops, Ordering::Relaxed);
    }

    pub fn record_completed(&self, latency_ns: u64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latency_ns_total.fetch_add(latency_ns, Ordering::Relaxed);
        self.latency_ns_max.fetch_max(latency_ns, Ordering::Relaxed);
    }

    pub fn record_registered(&self) {
        self.registered.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_swap(&self) {
        self.swaps.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_retired(&self) {
        self.retired.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_ingress_accepted(&self) {
        self.ingress_accepted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_ingress_shed(&self, class: QosClass) {
        self.ingress_shed[class.index()].fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_conn_opened(&self) {
        self.ingress_connections.fetch_add(1, Ordering::Relaxed);
        self.ingress_active_connections.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_conn_closed(&self) {
        self.ingress_active_connections.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn record_ingress_depth(&self, depth: u64) {
        self.ingress_queue_hwm.fetch_max(depth, Ordering::Relaxed);
    }

    /// One whole job stolen across shards (work donation).
    pub fn record_job_donated(&self) {
        self.jobs_donated.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_store_persisted(&self) {
        self.store_persisted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_store_loaded(&self) {
        self.store_loaded.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_store_skipped(&self) {
        self.store_skipped.fetch_add(1, Ordering::Relaxed);
    }

    /// One online mini-batch ingested, carrying `cols` observed columns.
    pub fn record_online_batch(&self, cols: u64) {
        self.online_batches.fetch_add(1, Ordering::Relaxed);
        self.online_cols.fetch_add(cols, Ordering::Relaxed);
    }

    /// One improved generation published by the online learner.
    pub fn record_online_swap(&self) {
        self.online_swaps.fetch_add(1, Ordering::Relaxed);
    }

    /// Latest relative error from the online learner's sweep (a gauge,
    /// not a counter: each call overwrites the previous value).
    pub fn record_online_rel_err(&self, rel_err: f64) {
        self.online_rel_err_bits
            .store(rel_err.to_bits(), Ordering::Relaxed);
    }

    /// Count `n` requests executed at `precision` (one call per batch).
    pub fn record_precision_applies(&self, precision: ServedPrecision, n: u64) {
        match precision {
            ServedPrecision::F64 => self.applies_f64.fetch_add(n, Ordering::Relaxed),
            ServedPrecision::F32 => self.applies_f32.fetch_add(n, Ordering::Relaxed),
        };
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_requests: self.batched_requests.load(Ordering::Relaxed),
            max_batch_size: self.max_batch_size.load(Ordering::Relaxed),
            exec_ns_total: self.exec_ns_total.load(Ordering::Relaxed),
            latency_ns_total: self.latency_ns_total.load(Ordering::Relaxed),
            latency_ns_max: self.latency_ns_max.load(Ordering::Relaxed),
            flops_total: self.flops_total.load(Ordering::Relaxed),
            registered: self.registered.load(Ordering::Relaxed),
            swaps: self.swaps.load(Ordering::Relaxed),
            retired: self.retired.load(Ordering::Relaxed),
            ingress_accepted: self.ingress_accepted.load(Ordering::Relaxed),
            ingress_shed: [
                self.ingress_shed[0].load(Ordering::Relaxed),
                self.ingress_shed[1].load(Ordering::Relaxed),
                self.ingress_shed[2].load(Ordering::Relaxed),
            ],
            ingress_connections: self.ingress_connections.load(Ordering::Relaxed),
            ingress_active_connections: self.ingress_active_connections.load(Ordering::Relaxed),
            ingress_queue_hwm: self.ingress_queue_hwm.load(Ordering::Relaxed),
            applies_f64: self.applies_f64.load(Ordering::Relaxed),
            applies_f32: self.applies_f32.load(Ordering::Relaxed),
            jobs_donated: self.jobs_donated.load(Ordering::Relaxed),
            store_persisted: self.store_persisted.load(Ordering::Relaxed),
            store_loaded: self.store_loaded.load(Ordering::Relaxed),
            store_skipped: self.store_skipped.load(Ordering::Relaxed),
            online_batches: self.online_batches.load(Ordering::Relaxed),
            online_cols: self.online_cols.load(Ordering::Relaxed),
            online_swaps: self.online_swaps.load(Ordering::Relaxed),
            online_rel_err: f64::from_bits(self.online_rel_err_bits.load(Ordering::Relaxed)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_submitted();
        m.record_submitted();
        m.record_batch(2);
        m.record_exec(2, 1000, 400);
        m.record_completed(500);
        m.record_completed(1500);
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.completed, 2);
        assert_eq!(s.batches, 1);
        assert_eq!(s.mean_batch_size(), 2.0);
        assert_eq!(s.latency_ns_max, 1500);
        assert!((s.mean_latency_us() - 1.0).abs() < 1e-12);
        assert!((s.gflops() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn registry_lifecycle_counters() {
        let m = Metrics::new();
        m.record_registered();
        m.record_registered();
        m.record_swap();
        m.record_retired();
        let s = m.snapshot();
        assert_eq!((s.registered, s.swaps, s.retired), (2, 1, 1));
    }

    #[test]
    fn ingress_counters_accumulate() {
        let m = Metrics::new();
        m.record_conn_opened();
        m.record_conn_opened();
        m.record_conn_closed();
        m.record_ingress_accepted();
        m.record_ingress_shed(QosClass::Bulk);
        m.record_ingress_shed(QosClass::Bulk);
        m.record_ingress_shed(QosClass::Interactive);
        m.record_ingress_depth(7);
        m.record_ingress_depth(3); // high-water never regresses
        let s = m.snapshot();
        assert_eq!(s.ingress_connections, 2);
        assert_eq!(s.ingress_active_connections, 1);
        assert_eq!(s.ingress_accepted, 1);
        assert_eq!(s.ingress_shed, [1, 0, 2]);
        assert_eq!(s.ingress_shed_total(), 3);
        assert_eq!(s.ingress_queue_hwm, 7);
    }

    #[test]
    fn precision_apply_counters_accumulate() {
        let m = Metrics::new();
        m.record_precision_applies(ServedPrecision::F64, 3);
        m.record_precision_applies(ServedPrecision::F32, 5);
        m.record_precision_applies(ServedPrecision::F32, 4);
        let s = m.snapshot();
        assert_eq!((s.applies_f64, s.applies_f32), (3, 9));
        assert!((s.f32_apply_frac() - 0.75).abs() < 1e-12);
        // An all-f64 deployment reports a zero fraction, not NaN.
        assert_eq!(Metrics::new().snapshot().f32_apply_frac(), 0.0);
    }

    #[test]
    fn shard_and_store_counters_accumulate() {
        let m = Metrics::new();
        m.record_job_donated();
        m.record_job_donated();
        m.record_store_persisted();
        m.record_store_loaded();
        m.record_store_loaded();
        m.record_store_loaded();
        m.record_store_skipped();
        let s = m.snapshot();
        assert_eq!(s.jobs_donated, 2);
        assert_eq!(
            (s.store_persisted, s.store_loaded, s.store_skipped),
            (1, 3, 1)
        );
    }

    #[test]
    fn online_counters_accumulate() {
        let m = Metrics::new();
        m.record_online_batch(8);
        m.record_online_batch(4);
        m.record_online_swap();
        m.record_online_rel_err(0.25);
        m.record_online_rel_err(0.125); // gauge: latest value wins
        let s = m.snapshot();
        assert_eq!((s.online_batches, s.online_cols, s.online_swaps), (2, 12, 1));
        assert_eq!(s.online_rel_err, 0.125);
        // Before the first sweep the gauge reads an exact 0.0, not NaN.
        assert_eq!(Metrics::new().snapshot().online_rel_err, 0.0);
    }

    #[test]
    fn empty_snapshot_is_safe() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.mean_batch_size(), 0.0);
        assert_eq!(s.mean_latency_us(), 0.0);
        assert_eq!(s.gflops(), 0.0);
    }
}
