//! Live operator registry: register, hot-swap and retire operators while
//! the coordinator serves traffic.
//!
//! The seed coordinator froze its operator set at startup — useless for
//! the paper's on-line story (Mairal et al.'s online dictionary learning
//! re-learns the operator *while* requests flow). The registry fixes
//! that with epoch-based swaps:
//!
//! - every mutation bumps a global **epoch**; each entry remembers the
//!   epoch it was published at;
//! - readers (the router resolving a flush, the client checking
//!   dimensions) take a cheap `RwLock` read and clone the operator's
//!   `Arc` — a swap never blocks on in-flight work;
//! - in-flight batches keep serving on the `Arc` they resolved, so a
//!   retired generation **drains** naturally: the old operator is freed
//!   when its last batch completes, with zero service stall.
//!
//! [`Registry::swap_epoch`] refuses shape-changing swaps: queued requests
//! were dimension-checked against the old operator, and a same-shape
//! guarantee is what makes "no failed, no misrouted requests during a
//! swap" a theorem instead of a race.
//!
//! Under adaptive batching the registry also re-derives the operator's
//! target batch width from its [`CostProfile`](crate::engine::CostProfile)
//! on every publish, so a
//! swap to a differently-shaped *plan* (same matrix shape, different
//! sparsity) immediately re-sizes its batches.
//!
//! **Precision tier.** Under a non-default [`Precision`] policy every
//! publish also builds the operator's f32 serving generation (via
//! [`BatchOp::to_f32_op`]) and calibrates its error bound right then —
//! "measured at swap". [`Registry::get_serving`] resolves the generation
//! the policy selects per flush; batch targets derive from the *serving*
//! generation's profile, so f32 entries batch wider under the same arena
//! cap. [`Registry::get`] keeps returning the f64 master (same shape),
//! which is what dimension checks and shape guards want.

use super::batcher::{target_batch_for_class, AdaptiveBatchConfig};
use super::metrics::Metrics;
use super::{BatchOp, F32Serving, Precision, QosClass, ServedPrecision};
use crate::engine::{F32Bound, FleetCtx, ShardSet, ThreadPool};
use crate::faust::Faust;
use crate::hierarchical::{factorize_fleet_traced_with_ctx, HierarchicalConfig};
use crate::linalg::Mat;
use crate::store::{self, StoreError, StoredOp};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Errors from registry mutations. The unknown-key case is the *same
/// typed error* on every path — `swap_epoch`, `retire`,
/// [`Registry::refactorize_fleet`] outcomes, and the `serve --repl` ops
/// console all surface [`RegistryError::UnknownOperator`]'s `Display`,
/// never a hand-rolled string or a `Debug` dump.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RegistryError {
    /// `register` on a name that is already live (use `swap_epoch`).
    AlreadyRegistered(String),
    /// `swap_epoch` / `retire` on a name that is not registered.
    UnknownOperator(String),
    /// `swap_epoch` with an operator of a different shape.
    ShapeMismatch {
        expected: (usize, usize),
        got: (usize, usize),
    },
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegistryError::AlreadyRegistered(n) => {
                write!(f, "operator '{n}' already registered (swap instead)")
            }
            RegistryError::UnknownOperator(n) => write!(f, "operator '{n}' not registered"),
            RegistryError::ShapeMismatch { expected, got } => write!(
                f,
                "swap shape mismatch: expected {}x{}, got {}x{}",
                expected.0, expected.1, got.0, got.1
            ),
        }
    }
}

impl std::error::Error for RegistryError {}

struct Entry {
    op: Arc<dyn BatchOp>,
    /// f32 serving generation built (and error-calibrated) at publish
    /// time — `None` under the `f64` policy or when the operator cannot
    /// quantize ([`BatchOp::to_f32_op`] returned `None`).
    f32_gen: Option<F32Serving>,
    /// Which generation the precision policy selected for this entry.
    serving: ServedPrecision,
    /// Epoch this generation of the operator was published at.
    epoch: u64,
    /// Per-QoS-class flush thresholds derived from the **serving**
    /// generation's cost profile, indexed by [`QosClass::index`]
    /// (None ⇒ no profile / fixed sizing ⇒ the policy default applies).
    target_batch: Option<[usize; 3]>,
    /// Shard this operator is pinned to (always 0 on a one-shard set).
    shard: usize,
    /// Placement weight: flops per served column from the serving
    /// profile, falling back to `flops_per_matvec` for profile-less ops.
    cost: f64,
}

/// Concurrent name → operator map with epoch-stamped hot swap.
pub struct Registry {
    ops: RwLock<HashMap<String, Entry>>,
    epoch: AtomicU64,
    adaptive: Option<AdaptiveBatchConfig>,
    precision: Precision,
    metrics: Arc<Metrics>,
    /// Engine pools operators are pinned to. A one-shard set (the
    /// default) disables pinning entirely: no rebinding, every entry on
    /// shard 0 — bitwise the pre-sharding registry.
    shards: Arc<ShardSet>,
}

impl Registry {
    /// Empty registry serving everything in f64. `adaptive = Some(_)`
    /// turns on plan-aware batch sizing for every operator published
    /// with a cost profile.
    pub fn new(adaptive: Option<AdaptiveBatchConfig>) -> Self {
        Self::with_metrics(adaptive, Precision::F64, Arc::new(Metrics::new()))
    }

    /// Empty registry with an explicit precision policy.
    pub fn with_precision(
        adaptive: Option<AdaptiveBatchConfig>,
        precision: Precision,
    ) -> Self {
        Self::with_metrics(adaptive, precision, Arc::new(Metrics::new()))
    }

    pub(crate) fn with_metrics(
        adaptive: Option<AdaptiveBatchConfig>,
        precision: Precision,
        metrics: Arc<Metrics>,
    ) -> Self {
        // Placeholder one-shard set: with a single shard the registry
        // never rebinds, so the pool is never touched (ThreadPool::new(1)
        // spawns zero worker threads).
        let single = Arc::new(ShardSet::single(Arc::new(ThreadPool::new(1))));
        Self::with_shards(adaptive, precision, metrics, single)
    }

    pub(crate) fn with_shards(
        adaptive: Option<AdaptiveBatchConfig>,
        precision: Precision,
        metrics: Arc<Metrics>,
        shards: Arc<ShardSet>,
    ) -> Self {
        Registry {
            ops: RwLock::new(HashMap::new()),
            epoch: AtomicU64::new(0),
            adaptive,
            precision,
            metrics,
            shards,
        }
    }

    /// The precision policy every publish is evaluated under.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    fn entry_for(&self, op: Arc<dyn BatchOp>, epoch: u64, shard: usize) -> Entry {
        // Pin the operator to its shard's pool. One-shard sets skip this
        // entirely — the seed single-pool path stays untouched — and
        // pool-free operators (`rebound_to` = None) serve from anywhere.
        let op = if self.shards.len() > 1 {
            op.rebound_to(self.shards.pool(shard)).unwrap_or(op)
        } else {
            op
        };
        // Quantize + calibrate only when the policy can ever serve f32:
        // under `f64` a publish must stay bitwise-free of new work.
        let f32_gen = match self.precision {
            Precision::F64 => None,
            Precision::F32 | Precision::Auto(_) => op.to_f32_op(),
        };
        let serving = match (self.precision, &f32_gen) {
            (Precision::F32, Some(_)) => ServedPrecision::F32,
            (Precision::Auto(budget), Some(s)) if s.measured_rel_err <= budget => {
                ServedPrecision::F32
            }
            _ => ServedPrecision::F64,
        };
        // Batch targets price the generation that actually executes:
        // an f32 generation's 4-byte elements batch wider under the
        // same arena cap.
        let profile = match (serving, &f32_gen) {
            (ServedPrecision::F32, Some(s)) => s.op.cost_profile(),
            _ => op.cost_profile(),
        };
        let target_batch = match (&self.adaptive, profile) {
            (Some(cfg), Some(p)) => {
                Some(QosClass::ALL.map(|c| target_batch_for_class(&p, cfg, c)))
            }
            _ => None,
        };
        let cost = profile
            .map(|p| p.flops_per_col as f64)
            .unwrap_or(op.flops_per_matvec() as f64);
        Entry { op, f32_gen, serving, epoch, target_batch, shard, cost }
    }

    /// Greedy placement: the shard with the least accumulated serving
    /// cost gets the next operator (ties break to the lowest index, so
    /// placement is deterministic).
    fn place(&self, g: &HashMap<String, Entry>) -> usize {
        if self.shards.len() <= 1 {
            return 0;
        }
        // Accumulate per-shard loads in sorted-name order, not map order:
        // float addition is order-sensitive, so summing in `RandomState`
        // iteration order made near-tie placements flip run to run (the
        // registry sibling of the Batcher flush-order bug fixed in PR 10).
        let mut named: Vec<(&String, &Entry)> = g.iter().collect(); // det-ok: sorted below
        named.sort_by(|a, b| a.0.cmp(b.0));
        let mut loads = vec![0.0f64; self.shards.len()];
        for (_, e) in named {
            loads[e.shard] += e.cost;
        }
        let mut best = 0;
        for k in 1..loads.len() {
            if loads[k] < loads[best] {
                best = k;
            }
        }
        best
    }

    /// Re-balance after a retire: longest-processing-time greedy — sort
    /// by cost descending (name-tiebroken, so the assignment is
    /// deterministic), assign each to the least-loaded shard, and rebind
    /// entries whose shard changed. Bounds kept — moving pools never
    /// changes results (thread invariance), so no re-calibration.
    fn rebalance(&self, g: &mut HashMap<String, Entry>) {
        if self.shards.len() <= 1 {
            return;
        }
        let mut items: Vec<(String, f64)> =
            g.iter().map(|(n, e)| (n.clone(), e.cost)).collect(); // det-ok: sorted below
        items.sort_by(|a, b| {
            b.1.partial_cmp(&a.1)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| a.0.cmp(&b.0))
        });
        let mut loads = vec![0.0f64; self.shards.len()];
        for (name, cost) in items {
            let mut best = 0;
            for k in 1..loads.len() {
                if loads[k] < loads[best] {
                    best = k;
                }
            }
            loads[best] += cost;
            let e = g.get_mut(&name).expect("rebalance over live names");
            if e.shard != best {
                e.shard = best;
                let pool = self.shards.pool(best);
                if let Some(op) = e.op.rebound_to(pool) {
                    e.op = op;
                }
                if let Some(s) = &mut e.f32_gen {
                    if let Some(op) = s.op.rebound_to(pool) {
                        s.op = op;
                    }
                }
            }
        }
    }

    /// Publish a new operator under `name`. Errors if the name is live.
    /// Returns the publish epoch.
    pub fn register(
        &self,
        name: impl Into<String>,
        op: Arc<dyn BatchOp>,
    ) -> Result<u64, RegistryError> {
        let name = name.into();
        let mut g = self.ops.write().unwrap();
        if g.contains_key(&name) {
            return Err(RegistryError::AlreadyRegistered(name));
        }
        let epoch = self.epoch.fetch_add(1, Ordering::AcqRel) + 1;
        let shard = self.place(&g);
        g.insert(name, self.entry_for(op, epoch, shard));
        self.metrics.record_registered();
        Ok(epoch)
    }

    /// Atomically replace `name`'s operator with a same-shape successor
    /// and return the new epoch. Readers that already resolved the old
    /// `Arc` keep it until their batch completes (drain-by-epoch); every
    /// request submitted after this returns is served by the successor.
    pub fn swap_epoch(
        &self,
        name: &str,
        op: Arc<dyn BatchOp>,
    ) -> Result<u64, RegistryError> {
        let mut g = self.ops.write().unwrap();
        let cur = g
            .get(name)
            .ok_or_else(|| RegistryError::UnknownOperator(name.to_string()))?;
        let expected = (cur.op.rows(), cur.op.cols());
        let got = (op.rows(), op.cols());
        if expected != got {
            return Err(RegistryError::ShapeMismatch { expected, got });
        }
        // A successor generation inherits its predecessor's shard:
        // in-flight routing for this name stays valid across the swap.
        let shard = cur.shard;
        let epoch = self.epoch.fetch_add(1, Ordering::AcqRel) + 1;
        g.insert(name.to_string(), self.entry_for(op, epoch, shard));
        self.metrics.record_swap();
        Ok(epoch)
    }

    /// Remove `name` and hand back its operator (in-flight batches still
    /// complete on their own `Arc` clones; later submissions get
    /// `UnknownOperator`).
    pub fn retire(&self, name: &str) -> Result<Arc<dyn BatchOp>, RegistryError> {
        let mut g = self.ops.write().unwrap();
        let entry = g
            .remove(name)
            .ok_or_else(|| RegistryError::UnknownOperator(name.to_string()))?;
        self.epoch.fetch_add(1, Ordering::AcqRel);
        self.metrics.record_retired();
        // A departure can leave the shard loads skewed; re-spread the
        // survivors (no-op on one-shard sets).
        self.rebalance(&mut g);
        Ok(entry.op)
    }

    /// Resolve an operator (a cheap read-lock + `Arc` clone). Always the
    /// f64 master — shape checks and swap guards key off it.
    pub fn get(&self, name: &str) -> Option<Arc<dyn BatchOp>> {
        self.ops.read().unwrap().get(name).map(|e| e.op.clone())
    }

    /// Resolve the generation the precision policy selected at publish
    /// time, plus which element type it executes in. Same cost as
    /// [`Registry::get`]: a read-lock and an `Arc` clone.
    pub fn get_serving(&self, name: &str) -> Option<(Arc<dyn BatchOp>, ServedPrecision)> {
        self.ops.read().unwrap().get(name).map(|e| match (e.serving, &e.f32_gen) {
            (ServedPrecision::F32, Some(s)) => (s.op.clone(), ServedPrecision::F32),
            _ => (e.op.clone(), ServedPrecision::F64),
        })
    }

    /// [`Registry::get_serving`] plus the shard the operator is pinned
    /// to — what the router needs to push a flush onto the right queue.
    pub fn get_serving_routed(
        &self,
        name: &str,
    ) -> Option<(Arc<dyn BatchOp>, ServedPrecision, usize)> {
        self.ops.read().unwrap().get(name).map(|e| match (e.serving, &e.f32_gen) {
            (ServedPrecision::F32, Some(s)) => (s.op.clone(), ServedPrecision::F32, e.shard),
            _ => (e.op.clone(), ServedPrecision::F64, e.shard),
        })
    }

    /// Which shard `name` is currently pinned to.
    pub fn shard_of(&self, name: &str) -> Option<usize> {
        self.ops.read().unwrap().get(name).map(|e| e.shard)
    }

    /// Number of shards this registry places over (1 ⇒ no sharding).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Which precision `name`'s current generation serves in.
    pub fn serving_of(&self, name: &str) -> Option<ServedPrecision> {
        self.ops.read().unwrap().get(name).map(|e| e.serving)
    }

    /// Per-operator precision report, sorted by name: `(name, serving
    /// precision, measured f32 relative error if a quantized generation
    /// was built)`. The error is the swap-time probe measurement — the
    /// number `auto` budgets are compared against.
    pub fn precision_report(&self) -> Vec<(String, ServedPrecision, Option<f64>)> {
        let g = self.ops.read().unwrap();
        let mut v: Vec<(String, ServedPrecision, Option<f64>)> = g
            .iter() // det-ok: sorted below
            .map(|(n, e)| {
                (
                    n.clone(),
                    e.serving,
                    e.f32_gen.as_ref().map(|s| s.measured_rel_err),
                )
            })
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// The standard-class flush threshold for `name`'s current
    /// generation, if adaptive sizing derived one (identical to the
    /// class-less [`target_batch`](super::target_batch) of the profile).
    pub fn batch_limit(&self, name: &str) -> Option<usize> {
        self.batch_limit_class(name, QosClass::Standard)
    }

    /// The flush threshold for `name` as seen by one QoS `class`, if
    /// adaptive sizing derived one: each class feeds its own deadline
    /// budget into the latency term of the target-batch model.
    pub fn batch_limit_class(&self, name: &str, class: QosClass) -> Option<usize> {
        self.ops
            .read()
            .unwrap()
            .get(name)
            .and_then(|e| e.target_batch.map(|t| t[class.index()]))
    }

    /// Epoch `name`'s current generation was published at.
    pub fn epoch_of(&self, name: &str) -> Option<u64> {
        self.ops.read().unwrap().get(name).map(|e| e.epoch)
    }

    /// Global mutation epoch (bumped by register / swap / retire).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Names currently live, sorted.
    pub fn names(&self) -> Vec<String> {
        // det-ok: sorted below
        let mut v: Vec<String> = self.ops.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// Number of live operators.
    pub fn len(&self) -> usize {
        self.ops.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.read().unwrap().is_empty()
    }

    /// Refactorize a fleet of served operators concurrently and hot-swap
    /// each one **the moment its own factorization finishes** — not at a
    /// global barrier.
    ///
    /// `jobs` names each target operator, the dense matrix to factorize
    /// toward it, and its hierarchical configuration; the whole fleet
    /// trains on `fleet`'s shared context
    /// ([`factorize_fleet_traced_with_ctx`] batches the split/refit
    /// kernels of separate members into fused cross-operator
    /// dispatches). As each member completes, `publish` wraps the learned
    /// [`Faust`] into a servable operator (typically
    /// `engine.op(&faust)`), and [`Registry::swap_epoch`] publishes it
    /// while the rest of the fleet keeps training — traffic on already
    /// finished operators is served by their new generation immediately.
    ///
    /// Per-operator outcomes are reported in job order; a swap that fails
    /// (operator retired meanwhile → [`RegistryError::UnknownOperator`],
    /// or a shape-changing job → [`RegistryError::ShapeMismatch`]) never
    /// aborts the rest of the fleet. Jobs naming a key that is not
    /// registered *when the fleet starts* are rejected up front with the
    /// same typed error — they never train (their `rel_err` is NaN) and
    /// never slow the valid members' fused batches.
    pub fn refactorize_fleet<F>(
        &self,
        fleet: &FleetCtx,
        jobs: &[(String, &Mat, &HierarchicalConfig)],
        mut publish: F,
    ) -> Vec<FleetRefactorization>
    where
        F: FnMut(&str, &Faust) -> Arc<dyn BatchOp>,
    {
        // Reject never-registered names before spending any training time
        // on them (a name retired mid-training still surfaces the typed
        // error from its swap attempt below).
        let mut outcomes: Vec<Option<FleetRefactorization>> = jobs
            .iter()
            .map(|(name, _, _)| {
                if self.get(name).is_none() {
                    Some(FleetRefactorization {
                        name: name.clone(),
                        outcome: Err(RegistryError::UnknownOperator(name.clone())),
                        rel_err: f64::NAN,
                    })
                } else {
                    None
                }
            })
            .collect();
        let active: Vec<usize> = (0..jobs.len()).filter(|&i| outcomes[i].is_none()).collect();
        let hier_jobs: Vec<(&Mat, &HierarchicalConfig)> =
            active.iter().map(|&i| (jobs[i].1, jobs[i].2)).collect();
        let _ = factorize_fleet_traced_with_ctx(fleet, &hier_jobs, |k, f| {
            let i = active[k];
            let (name, a, _) = &jobs[i];
            let rel_err = f.relative_error_fro(a);
            let op = publish(name, f);
            let outcome = self.swap_epoch(name, op);
            outcomes[i] = Some(FleetRefactorization {
                name: name.clone(),
                outcome,
                rel_err,
            });
        });
        outcomes
            .into_iter()
            .map(|o| o.expect("every fleet member reports an outcome"))
            .collect()
    }

    /// Snapshot every persistable live operator into `dir` as a
    /// CRC-sealed [`crate::store`] file (factors + λ + f32 bound +
    /// publish epoch), atomically per operator. Operators with no
    /// durable state ([`BatchOp::persist_source`] = `None`, e.g. plain
    /// dense `Mat`s) are reported in `skipped`, not errored.
    ///
    /// The op list is cloned out under a read lock and serialization
    /// runs lock-free, so persisting never stalls serving; a swap that
    /// lands mid-persist simply isn't in *this* snapshot.
    pub fn persist_all(&self, dir: &Path) -> Result<PersistReport, StoreError> {
        let mut snaps: Vec<(String, u64, Arc<dyn BatchOp>, Option<F32Bound>)> = {
            let g = self.ops.read().unwrap();
            g.iter() // det-ok: sorted below
                .map(|(n, e)| {
                    let bound = e.f32_gen.as_ref().map(|s| F32Bound {
                        measured_rel_err: s.measured_rel_err,
                        declared_rel_err: s.declared_rel_err,
                    });
                    (n.clone(), e.epoch, e.op.clone(), bound)
                })
                .collect()
        };
        snaps.sort_by(|a, b| a.0.cmp(&b.0));
        let mut report = PersistReport { persisted: Vec::new(), skipped: Vec::new() };
        for (name, epoch, op, f32_bound) in snaps {
            match op.persist_source() {
                Some(faust) => {
                    let stored = StoredOp { name: name.clone(), epoch, faust, f32_bound };
                    store::save_op(dir, &stored)?;
                    self.metrics.record_store_persisted();
                    report.persisted.push(name);
                }
                None => report.skipped.push(name),
            }
        }
        Ok(report)
    }

    /// Restore a fleet from `dir`: every readable snapshot is wrapped by
    /// `publish` (typically `|_, f| Arc::new(engine.op(f))`) and
    /// register-or-swapped under its stored name — so a warm restart
    /// over an already-cold-started registry upgrades in place. Stored
    /// f32 bounds are preloaded into each FAμST's plan cache *before*
    /// publishing, so no re-probe (and no PALM iteration) runs.
    ///
    /// Torn or corrupt files come back in
    /// [`StoreRestore::corrupt`] — typed, skipped, never a panic, and
    /// never silently served. The registry's global epoch is advanced to
    /// at least the newest stored epoch, so every restored generation
    /// publishes at an epoch `>` its snapshot.
    pub fn load_store<F>(&self, dir: &Path, mut publish: F) -> Result<StoreRestore, StoreError>
    where
        F: FnMut(&str, &Faust) -> Arc<dyn BatchOp>,
    {
        let loaded = store::load_dir(dir)?;
        let max_stored = loaded.ops.iter().map(|s| s.epoch).max().unwrap_or(0);
        self.epoch.fetch_max(max_stored, Ordering::AcqRel);
        let mut restore = StoreRestore {
            loaded: Vec::new(),
            rejected: Vec::new(),
            corrupt: loaded.skipped,
        };
        for s in &loaded.ops {
            if let Some(b) = s.f32_bound {
                s.faust.preload_f32_bound(b);
            }
            let op = publish(&s.name, &s.faust);
            let outcome = match self.register(s.name.clone(), op.clone()) {
                Err(RegistryError::AlreadyRegistered(_)) => self.swap_epoch(&s.name, op),
                other => other,
            };
            match outcome {
                Ok(_) => {
                    self.metrics.record_store_loaded();
                    restore.loaded.push(s.name.clone());
                }
                Err(e) => restore.rejected.push((s.name.clone(), e)),
            }
        }
        for _ in &restore.corrupt {
            self.metrics.record_store_skipped();
        }
        Ok(restore)
    }
}

/// Outcome of [`Registry::persist_all`].
#[derive(Clone, Debug, Default)]
pub struct PersistReport {
    /// Names snapshotted to disk, sorted.
    pub persisted: Vec<String>,
    /// Live names with no durable state (not an error), sorted.
    pub skipped: Vec<String>,
}

/// Outcome of [`Registry::load_store`].
#[derive(Debug, Default)]
pub struct StoreRestore {
    /// Names restored and published (fresh register or in-place swap).
    pub loaded: Vec<String>,
    /// Readable snapshots the registry refused (e.g. a shape-changing
    /// swap against a live operator), with the typed registry error.
    pub rejected: Vec<(String, RegistryError)>,
    /// Unreadable files: torn writes, bit flips, wrong magic — each with
    /// its typed [`StoreError`]. Detected by checksum, skipped, served
    /// never.
    pub corrupt: Vec<(PathBuf, StoreError)>,
}

/// Per-operator outcome of [`Registry::refactorize_fleet`].
#[derive(Clone, Debug)]
pub struct FleetRefactorization {
    /// Registry key the job targeted.
    pub name: String,
    /// Publish epoch on success; the typed registry error otherwise
    /// (same [`RegistryError::UnknownOperator`] the API paths return).
    pub outcome: Result<u64, RegistryError>,
    /// Relative Frobenius error of the learned FAμST vs. its target
    /// (NaN when the job was rejected up front and never trained).
    pub rel_err: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn op(m: usize, n: usize) -> Arc<dyn BatchOp> {
        Arc::new(Mat::eye(m, n)) as Arc<dyn BatchOp>
    }

    #[test]
    fn register_swap_retire_lifecycle() {
        let r = Registry::new(None);
        assert!(r.is_empty());
        let e1 = r.register("a", op(4, 4)).unwrap();
        assert_eq!(e1, 1);
        assert_eq!(r.epoch_of("a"), Some(1));
        assert_eq!(r.names(), vec!["a".to_string()]);
        // Duplicate registration is refused.
        assert_eq!(
            r.register("a", op(4, 4)),
            Err(RegistryError::AlreadyRegistered("a".into()))
        );
        // Swap bumps the epoch and keeps the name.
        let e2 = r.swap_epoch("a", op(4, 4)).unwrap();
        assert!(e2 > e1);
        assert_eq!(r.epoch_of("a"), Some(e2));
        assert_eq!(r.len(), 1);
        // Retire removes and returns the operator.
        let old = r.retire("a").unwrap();
        assert_eq!(old.rows(), 4);
        assert!(r.get("a").is_none());
        assert!(matches!(r.retire("a"), Err(RegistryError::UnknownOperator(_))));
    }

    #[test]
    fn swap_refuses_shape_changes() {
        let r = Registry::new(None);
        r.register("a", op(4, 6)).unwrap();
        let err = r.swap_epoch("a", op(4, 5)).unwrap_err();
        assert_eq!(
            err,
            RegistryError::ShapeMismatch { expected: (4, 6), got: (4, 5) }
        );
        // The failed swap left the original in place.
        assert_eq!(r.get("a").unwrap().cols(), 6);
        assert_eq!(
            r.swap_epoch("nope", op(1, 1)),
            Err(RegistryError::UnknownOperator("nope".into()))
        );
    }

    #[test]
    fn retired_generation_drains_on_arc() {
        let r = Registry::new(None);
        r.register("a", op(3, 3)).unwrap();
        // A "worker" holding the old generation mid-batch.
        let in_flight = r.get("a").unwrap();
        let weak = Arc::downgrade(&in_flight);
        r.swap_epoch("a", op(3, 3)).unwrap();
        // Old generation is still alive while the batch runs...
        assert!(weak.upgrade().is_some());
        drop(in_flight);
        // ...and freed once the last in-flight reference drops.
        assert!(weak.upgrade().is_none());
    }

    #[test]
    fn unknown_operator_error_is_one_typed_value_on_every_path() {
        // The REPL and the API paths must surface the same typed error
        // with the same Display — no hand-rolled strings, no Debug dumps.
        let r = Registry::new(None);
        let via_swap = r.swap_epoch("ghost", op(2, 2)).unwrap_err();
        let via_retire = r.retire("ghost").unwrap_err();
        let expected = RegistryError::UnknownOperator("ghost".to_string());
        assert_eq!(via_swap, expected);
        assert_eq!(via_retire, expected);
        assert_eq!(via_swap.to_string(), "operator 'ghost' not registered");
        assert_eq!(via_swap.to_string(), via_retire.to_string());
    }

    #[test]
    fn refactorize_fleet_swaps_each_operator_and_reports_outcomes() {
        use crate::engine::{ExecCtx, FleetCtx};
        use crate::hierarchical::HierarchicalConfig;
        use crate::transforms::{hadamard, hadamard_faust};

        let r = Registry::new(None);
        // Two served operators of different sizes + one name that is not
        // registered (its swap must fail with the typed error while the
        // others still publish).
        let h8 = hadamard(8);
        let h16 = hadamard(16);
        r.register("a", Arc::new(hadamard_faust(8)) as Arc<dyn BatchOp>)
            .unwrap();
        r.register("b", Arc::new(hadamard_faust(16)) as Arc<dyn BatchOp>)
            .unwrap();
        let e_a0 = r.epoch_of("a").unwrap();
        let e_b0 = r.epoch_of("b").unwrap();
        let cfg8 = HierarchicalConfig::hadamard(8);
        let cfg16 = HierarchicalConfig::hadamard(16);
        let fleet = FleetCtx::new(ExecCtx::new(2));
        let jobs = vec![
            ("a".to_string(), &h8, &cfg8),
            ("b".to_string(), &h16, &cfg16),
            ("ghost".to_string(), &h8, &cfg8),
        ];
        let outcomes = r.refactorize_fleet(&fleet, &jobs, |_, f| {
            Arc::new(f.clone()) as Arc<dyn BatchOp>
        });
        assert_eq!(outcomes.len(), 3);
        assert!(outcomes[0].outcome.as_ref().unwrap() > &e_a0);
        assert!(outcomes[1].outcome.as_ref().unwrap() > &e_b0);
        assert_eq!(
            outcomes[2].outcome,
            Err(RegistryError::UnknownOperator("ghost".to_string()))
        );
        // Rejected up front: the doomed job never trained.
        assert!(outcomes[2].rel_err.is_nan());
        // The learned generations really replaced the originals and
        // approximate their targets.
        assert!(outcomes[0].rel_err < 1e-6);
        assert!(outcomes[1].rel_err < 1e-6);
        assert_eq!(r.epoch_of("a").unwrap(), *outcomes[0].outcome.as_ref().unwrap());
        assert_eq!(r.epoch_of("b").unwrap(), *outcomes[1].outcome.as_ref().unwrap());
    }

    #[test]
    fn adaptive_registry_sizes_batches_from_the_profile() {
        let r = Registry::new(Some(AdaptiveBatchConfig::default()));
        // A dense Mat exposes a profile → a target is derived.
        r.register("m", op(64, 64)).unwrap();
        let t = r.batch_limit("m").expect("dense op has a profile");
        assert!(t >= 1);
        // Per-class limits order with the class deadline budgets, and
        // batch_limit is exactly the standard class.
        let ti = r.batch_limit_class("m", QosClass::Interactive).unwrap();
        let ts = r.batch_limit_class("m", QosClass::Standard).unwrap();
        let tb = r.batch_limit_class("m", QosClass::Bulk).unwrap();
        assert_eq!(ts, t);
        assert!(ti <= ts && ts <= tb, "class limits out of order: {ti} {ts} {tb}");
        // Fixed-mode registry never derives targets.
        let fixed = Registry::new(None);
        fixed.register("m", op(64, 64)).unwrap();
        assert_eq!(fixed.batch_limit("m"), None);
        assert_eq!(fixed.batch_limit_class("m", QosClass::Bulk), None);
    }

    #[test]
    fn f64_policy_never_builds_a_quantized_generation() {
        use crate::transforms::hadamard_faust;
        let r = Registry::new(None);
        r.register("h", Arc::new(hadamard_faust(8)) as Arc<dyn BatchOp>)
            .unwrap();
        let (served, prec) = r.get_serving("h").unwrap();
        assert_eq!(prec, ServedPrecision::F64);
        assert_eq!(served.rows(), 8);
        assert_eq!(r.serving_of("h"), Some(ServedPrecision::F64));
        // No probe ran, so the report carries no measured error.
        assert_eq!(r.precision_report(), vec![("h".to_string(), ServedPrecision::F64, None)]);
    }

    #[test]
    fn f32_policy_serves_quantized_generation_and_falls_back_per_op() {
        use crate::transforms::hadamard_faust;
        let r = Registry::with_precision(None, Precision::F32);
        // A Faust quantizes; a plain dense Mat does not (to_f32_op =
        // None) — the same registry serves them at different precisions.
        r.register("h", Arc::new(hadamard_faust(8)) as Arc<dyn BatchOp>)
            .unwrap();
        r.register("m", op(8, 8)).unwrap();
        let (served, prec) = r.get_serving("h").unwrap();
        assert_eq!(prec, ServedPrecision::F32);
        assert_eq!((served.rows(), served.cols()), (8, 8));
        assert_eq!(r.serving_of("m"), Some(ServedPrecision::F64));
        // `get` still resolves the f64 master for shape checks.
        let master = r.get("h").unwrap();
        assert_eq!((master.rows(), master.cols()), (8, 8));
        // The quantized generation really computes the operator: compare
        // a batch against the f64 master within the measured-err report.
        let x = Mat::from_vec(8, 2, (0..16).map(|i| (i as f64).sin()).collect());
        let y32 = served.apply_batch(&x);
        let y64 = master.apply_batch(&x);
        let mut err2 = 0.0;
        let mut ref2 = 0.0;
        for (a, b) in y32.data().iter().zip(y64.data().iter()) {
            err2 += (a - b) * (a - b);
            ref2 += b * b;
        }
        let rel = (err2 / ref2).sqrt();
        assert!(rel < 1e-3, "f32 generation far from f64 master: rel={rel:e}");
        let report = r.precision_report();
        assert_eq!(report.len(), 2);
        assert_eq!(report[0].0, "h");
        assert_eq!(report[0].1, ServedPrecision::F32);
        assert!(report[0].2.unwrap() >= 0.0);
        assert_eq!(report[1], ("m".to_string(), ServedPrecision::F64, None));
    }

    #[test]
    fn auto_policy_selects_by_measured_error_budget() {
        use crate::transforms::hadamard_faust;
        // A Hadamard FAμST quantizes exactly (±1 factors); its probe
        // error is tiny, so a sane budget admits it…
        let loose = Registry::with_precision(None, Precision::Auto(1e-6));
        loose
            .register("h", Arc::new(hadamard_faust(16)) as Arc<dyn BatchOp>)
            .unwrap();
        assert_eq!(loose.serving_of("h"), Some(ServedPrecision::F32));
        // …while an absurdly tight budget (below f32 input-quantization
        // noise) rejects the same operator back to f64.
        let tight = Registry::with_precision(None, Precision::Auto(1e-13));
        tight
            .register("h", Arc::new(hadamard_faust(16)) as Arc<dyn BatchOp>)
            .unwrap();
        assert_eq!(tight.serving_of("h"), Some(ServedPrecision::F64));
        // The rejected entry still reports the measured error it was
        // judged on.
        let rep = tight.precision_report();
        assert!(rep[0].2.unwrap() > 1e-13);
    }

    fn tmp_store_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("faust_registry_{}_{tag}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    #[test]
    fn sharded_registry_places_rebinds_and_rebalances() {
        use crate::engine::{ApplyEngine, ShardSet};
        use crate::transforms::hadamard_faust;
        let engine = ApplyEngine::with_threads(1);
        let shards = Arc::new(ShardSet::new(2, 1));
        let r = Registry::with_shards(
            None,
            Precision::F64,
            Arc::new(Metrics::new()),
            shards,
        );
        assert_eq!(r.n_shards(), 2);
        for i in 0..4 {
            let op = Arc::new(engine.op(&hadamard_faust(16))) as Arc<dyn BatchOp>;
            r.register(format!("op{i}"), op).unwrap();
        }
        // Equal-cost ops alternate: greedy argmin spreads 2/2.
        let shard_of = |n: &str| r.shard_of(n).unwrap();
        let count0 = (0..4).filter(|i| shard_of(&format!("op{i}")) == 0).count();
        assert_eq!(count0, 2, "placement skewed: {count0}/4 on shard 0");
        // Routed resolution reports the pinned shard.
        let (_, _, s) = r.get_serving_routed("op0").unwrap();
        assert_eq!(s, shard_of("op0"));
        // A swap keeps its predecessor's shard.
        let before = shard_of("op2");
        r.swap_epoch("op2", Arc::new(engine.op(&hadamard_faust(16))) as Arc<dyn BatchOp>)
            .unwrap();
        assert_eq!(shard_of("op2"), before);
        // Retiring both shard-0 ops forces a rebalance back to 1/1.
        let on0: Vec<String> = (0..4)
            .map(|i| format!("op{i}"))
            .filter(|n| shard_of(n) == 0)
            .collect();
        for n in &on0 {
            r.retire(n).unwrap();
        }
        let left: Vec<usize> = r.names().iter().map(|n| shard_of(n)).collect();
        assert_eq!(left.len(), 2);
        assert!(
            left.contains(&0) && left.contains(&1),
            "rebalance left both survivors on one shard: {left:?}"
        );
        // Rebound survivors still serve — bitwise equal to a fresh op.
        let mut rng = crate::rng::Rng::new(77);
        let x = Mat::randn(16, 3, &mut rng);
        let want = engine.op(&hadamard_faust(16)).apply_batch(&x);
        let (op, _, _) = r.get_serving_routed(&r.names()[0]).unwrap();
        let got = op.apply_batch(&x);
        for (g, w) in got.data().iter().zip(want.data()) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }

    #[test]
    fn single_shard_registry_never_rebinds() {
        use crate::engine::ApplyEngine;
        use crate::transforms::hadamard_faust;
        let engine = ApplyEngine::with_threads(2);
        let r = Registry::new(None);
        assert_eq!(r.n_shards(), 1);
        let op = Arc::new(engine.op(&hadamard_faust(8))) as Arc<dyn BatchOp>;
        let keep = op.clone();
        r.register("h", op).unwrap();
        // The exact Arc registered is the one served — no rebinding.
        let served = r.get("h").unwrap();
        assert!(Arc::ptr_eq(&served, &keep), "single-shard registry rebound the op");
        assert_eq!(r.shard_of("h"), Some(0));
    }

    #[test]
    fn persist_all_and_load_store_round_trip_a_fleet() {
        use crate::engine::ApplyEngine;
        use crate::testutil::faust_fingerprint;
        use crate::transforms::hadamard_faust;
        let dir = tmp_store_dir("roundtrip");
        let engine = ApplyEngine::with_threads(1);
        let r = Registry::new(None);
        let f8 = hadamard_faust(8);
        let f16 = hadamard_faust(16);
        r.register("h8", Arc::new(engine.op(&f8)) as Arc<dyn BatchOp>).unwrap();
        r.register("h16", Arc::new(engine.op(&f16)) as Arc<dyn BatchOp>).unwrap();
        // A plain dense Mat has no durable state: skipped, not an error.
        r.register("dense", Arc::new(Mat::eye(4, 4)) as Arc<dyn BatchOp>).unwrap();
        let snap_epoch = r.epoch();
        let report = r.persist_all(&dir).unwrap();
        assert_eq!(report.persisted, vec!["h16".to_string(), "h8".to_string()]);
        assert_eq!(report.skipped, vec!["dense".to_string()]);

        // Cold restore into a fresh registry.
        let r2 = Registry::new(None);
        let engine2 = ApplyEngine::with_threads(1);
        let restore = r2
            .load_store(&dir, |_, f| Arc::new(engine2.op(f)) as Arc<dyn BatchOp>)
            .unwrap();
        assert_eq!(restore.loaded, vec!["h16".to_string(), "h8".to_string()]);
        assert!(restore.rejected.is_empty() && restore.corrupt.is_empty());
        // Restored factors are bitwise the persisted ones.
        let got = r2.get("h8").unwrap().persist_source().unwrap();
        assert_eq!(faust_fingerprint(&got), faust_fingerprint(&f8));
        // Epochs moved strictly past the snapshot.
        assert!(r2.epoch() > snap_epoch);
        assert!(r2.epoch_of("h8").unwrap() > snap_epoch);

        // Warm restore over a live registry upgrades in place (swap).
        let restore2 = r
            .load_store(&dir, |_, f| Arc::new(engine.op(f)) as Arc<dyn BatchOp>)
            .unwrap();
        assert_eq!(restore2.loaded.len(), 2);
        assert_eq!(r.len(), 3, "in-place restore must not duplicate names");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_store_skips_corrupt_files_and_loads_the_rest() {
        use crate::engine::ApplyEngine;
        use crate::transforms::hadamard_faust;
        let dir = tmp_store_dir("corrupt");
        let engine = ApplyEngine::with_threads(1);
        let r = Registry::new(None);
        r.register("good", Arc::new(engine.op(&hadamard_faust(8))) as Arc<dyn BatchOp>)
            .unwrap();
        r.persist_all(&dir).unwrap();
        // A torn neighbor: half a valid file.
        let good = std::fs::read(crate::store::op_path(&dir, "good")).unwrap();
        std::fs::write(dir.join("torn.fstore"), &good[..good.len() / 2]).unwrap();
        let r2 = Registry::new(None);
        let restore = r2
            .load_store(&dir, |_, f| Arc::new(engine.op(f)) as Arc<dyn BatchOp>)
            .unwrap();
        assert_eq!(restore.loaded, vec!["good".to_string()]);
        assert_eq!(restore.corrupt.len(), 1, "torn file must be reported");
        assert!(r2.get("good").is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn persisted_f32_bound_restores_without_a_reprobe() {
        use crate::engine::ApplyEngine;
        use crate::transforms::hadamard_faust;
        let dir = tmp_store_dir("bound");
        let engine = ApplyEngine::with_threads(1);
        // Publish under an f32 policy so a calibrated bound exists.
        let r = Registry::with_precision(None, Precision::F32);
        r.register("h", Arc::new(engine.op(&hadamard_faust(16))) as Arc<dyn BatchOp>)
            .unwrap();
        let want_err = r.precision_report()[0].2.unwrap();
        r.persist_all(&dir).unwrap();
        let r2 = Registry::with_precision(None, Precision::F32);
        r2.load_store(&dir, |_, f| Arc::new(f.clone()) as Arc<dyn BatchOp>)
            .unwrap();
        // The restored generation serves f32 with the *stored* probe
        // measurement, bit for bit — no fresh calibration ran.
        assert_eq!(r2.serving_of("h"), Some(ServedPrecision::F32));
        let got_err = r2.precision_report()[0].2.unwrap();
        assert_eq!(got_err.to_bits(), want_err.to_bits());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn swap_recalibrates_and_f32_batches_at_four_byte_prices() {
        use crate::transforms::hadamard_faust;
        let cfg = AdaptiveBatchConfig::default();
        let r64 = Registry::new(Some(cfg.clone()));
        let r32 = Registry::with_precision(Some(cfg), Precision::F32);
        r64.register("h", Arc::new(hadamard_faust(32)) as Arc<dyn BatchOp>)
            .unwrap();
        r32.register("h", Arc::new(hadamard_faust(32)) as Arc<dyn BatchOp>)
            .unwrap();
        let t64 = r64.batch_limit("h").expect("faust exposes a profile");
        let t32 = r32.batch_limit("h").expect("f32 generation exposes a profile");
        // Same operator, same arena cap: 4-byte elements can never batch
        // narrower than 8-byte ones.
        assert!(t32 >= t64, "f32 batch target {t32} narrower than f64 {t64}");
        // A swap re-quantizes and re-selects: the successor generation is
        // served in f32 too, at a fresh epoch.
        let e1 = r32.epoch_of("h").unwrap();
        let e2 = r32
            .swap_epoch("h", Arc::new(hadamard_faust(32)) as Arc<dyn BatchOp>)
            .unwrap();
        assert!(e2 > e1);
        assert_eq!(r32.serving_of("h"), Some(ServedPrecision::F32));
        assert!(r32.precision_report()[0].2.is_some());
    }
}
