//! Live operator registry: register, hot-swap and retire operators while
//! the coordinator serves traffic.
//!
//! The seed coordinator froze its operator set at startup — useless for
//! the paper's on-line story (Mairal et al.'s online dictionary learning
//! re-learns the operator *while* requests flow). The registry fixes
//! that with epoch-based swaps:
//!
//! - every mutation bumps a global **epoch**; each entry remembers the
//!   epoch it was published at;
//! - readers (the router resolving a flush, the client checking
//!   dimensions) take a cheap `RwLock` read and clone the operator's
//!   `Arc` — a swap never blocks on in-flight work;
//! - in-flight batches keep serving on the `Arc` they resolved, so a
//!   retired generation **drains** naturally: the old operator is freed
//!   when its last batch completes, with zero service stall.
//!
//! [`Registry::swap_epoch`] refuses shape-changing swaps: queued requests
//! were dimension-checked against the old operator, and a same-shape
//! guarantee is what makes "no failed, no misrouted requests during a
//! swap" a theorem instead of a race.
//!
//! Under adaptive batching the registry also re-derives the operator's
//! target batch width from its [`CostProfile`](crate::engine::CostProfile)
//! on every publish, so a
//! swap to a differently-shaped *plan* (same matrix shape, different
//! sparsity) immediately re-sizes its batches.

use super::batcher::{target_batch, AdaptiveBatchConfig};
use super::metrics::Metrics;
use super::BatchOp;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Errors from registry mutations.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RegistryError {
    /// `register` on a name that is already live (use `swap_epoch`).
    AlreadyRegistered(String),
    /// `swap_epoch` / `retire` on a name that is not registered.
    Unknown(String),
    /// `swap_epoch` with an operator of a different shape.
    ShapeMismatch {
        expected: (usize, usize),
        got: (usize, usize),
    },
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegistryError::AlreadyRegistered(n) => {
                write!(f, "operator '{n}' already registered (swap instead)")
            }
            RegistryError::Unknown(n) => write!(f, "operator '{n}' not registered"),
            RegistryError::ShapeMismatch { expected, got } => write!(
                f,
                "swap shape mismatch: expected {}x{}, got {}x{}",
                expected.0, expected.1, got.0, got.1
            ),
        }
    }
}

impl std::error::Error for RegistryError {}

struct Entry {
    op: Arc<dyn BatchOp>,
    /// Epoch this generation of the operator was published at.
    epoch: u64,
    /// Flush threshold derived from the operator's cost profile
    /// (None ⇒ no profile / fixed sizing ⇒ the policy default applies).
    target_batch: Option<usize>,
}

/// Concurrent name → operator map with epoch-stamped hot swap.
pub struct Registry {
    ops: RwLock<HashMap<String, Entry>>,
    epoch: AtomicU64,
    adaptive: Option<AdaptiveBatchConfig>,
    metrics: Arc<Metrics>,
}

impl Registry {
    /// Empty registry. `adaptive = Some(_)` turns on plan-aware batch
    /// sizing for every operator published with a cost profile.
    pub fn new(adaptive: Option<AdaptiveBatchConfig>) -> Self {
        Self::with_metrics(adaptive, Arc::new(Metrics::new()))
    }

    pub(crate) fn with_metrics(
        adaptive: Option<AdaptiveBatchConfig>,
        metrics: Arc<Metrics>,
    ) -> Self {
        Registry {
            ops: RwLock::new(HashMap::new()),
            epoch: AtomicU64::new(0),
            adaptive,
            metrics,
        }
    }

    fn entry_for(&self, op: Arc<dyn BatchOp>, epoch: u64) -> Entry {
        let target_batch = match (&self.adaptive, op.cost_profile()) {
            (Some(cfg), Some(p)) => Some(target_batch(&p, cfg)),
            _ => None,
        };
        Entry { op, epoch, target_batch }
    }

    /// Publish a new operator under `name`. Errors if the name is live.
    /// Returns the publish epoch.
    pub fn register(
        &self,
        name: impl Into<String>,
        op: Arc<dyn BatchOp>,
    ) -> Result<u64, RegistryError> {
        let name = name.into();
        let mut g = self.ops.write().unwrap();
        if g.contains_key(&name) {
            return Err(RegistryError::AlreadyRegistered(name));
        }
        let epoch = self.epoch.fetch_add(1, Ordering::AcqRel) + 1;
        g.insert(name, self.entry_for(op, epoch));
        self.metrics.record_registered();
        Ok(epoch)
    }

    /// Atomically replace `name`'s operator with a same-shape successor
    /// and return the new epoch. Readers that already resolved the old
    /// `Arc` keep it until their batch completes (drain-by-epoch); every
    /// request submitted after this returns is served by the successor.
    pub fn swap_epoch(
        &self,
        name: &str,
        op: Arc<dyn BatchOp>,
    ) -> Result<u64, RegistryError> {
        let mut g = self.ops.write().unwrap();
        let cur = g
            .get(name)
            .ok_or_else(|| RegistryError::Unknown(name.to_string()))?;
        let expected = (cur.op.rows(), cur.op.cols());
        let got = (op.rows(), op.cols());
        if expected != got {
            return Err(RegistryError::ShapeMismatch { expected, got });
        }
        let epoch = self.epoch.fetch_add(1, Ordering::AcqRel) + 1;
        g.insert(name.to_string(), self.entry_for(op, epoch));
        self.metrics.record_swap();
        Ok(epoch)
    }

    /// Remove `name` and hand back its operator (in-flight batches still
    /// complete on their own `Arc` clones; later submissions get
    /// `UnknownOperator`).
    pub fn retire(&self, name: &str) -> Result<Arc<dyn BatchOp>, RegistryError> {
        let mut g = self.ops.write().unwrap();
        let entry = g
            .remove(name)
            .ok_or_else(|| RegistryError::Unknown(name.to_string()))?;
        self.epoch.fetch_add(1, Ordering::AcqRel);
        self.metrics.record_retired();
        Ok(entry.op)
    }

    /// Resolve an operator (a cheap read-lock + `Arc` clone).
    pub fn get(&self, name: &str) -> Option<Arc<dyn BatchOp>> {
        self.ops.read().unwrap().get(name).map(|e| e.op.clone())
    }

    /// The flush threshold for `name`'s current generation, if adaptive
    /// sizing derived one.
    pub fn batch_limit(&self, name: &str) -> Option<usize> {
        self.ops.read().unwrap().get(name).and_then(|e| e.target_batch)
    }

    /// Epoch `name`'s current generation was published at.
    pub fn epoch_of(&self, name: &str) -> Option<u64> {
        self.ops.read().unwrap().get(name).map(|e| e.epoch)
    }

    /// Global mutation epoch (bumped by register / swap / retire).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Names currently live, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.ops.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// Number of live operators.
    pub fn len(&self) -> usize {
        self.ops.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.read().unwrap().is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn op(m: usize, n: usize) -> Arc<dyn BatchOp> {
        Arc::new(Mat::eye(m, n)) as Arc<dyn BatchOp>
    }

    #[test]
    fn register_swap_retire_lifecycle() {
        let r = Registry::new(None);
        assert!(r.is_empty());
        let e1 = r.register("a", op(4, 4)).unwrap();
        assert_eq!(e1, 1);
        assert_eq!(r.epoch_of("a"), Some(1));
        assert_eq!(r.names(), vec!["a".to_string()]);
        // Duplicate registration is refused.
        assert_eq!(
            r.register("a", op(4, 4)),
            Err(RegistryError::AlreadyRegistered("a".into()))
        );
        // Swap bumps the epoch and keeps the name.
        let e2 = r.swap_epoch("a", op(4, 4)).unwrap();
        assert!(e2 > e1);
        assert_eq!(r.epoch_of("a"), Some(e2));
        assert_eq!(r.len(), 1);
        // Retire removes and returns the operator.
        let old = r.retire("a").unwrap();
        assert_eq!(old.rows(), 4);
        assert!(r.get("a").is_none());
        assert!(matches!(r.retire("a"), Err(RegistryError::Unknown(_))));
    }

    #[test]
    fn swap_refuses_shape_changes() {
        let r = Registry::new(None);
        r.register("a", op(4, 6)).unwrap();
        let err = r.swap_epoch("a", op(4, 5)).unwrap_err();
        assert_eq!(
            err,
            RegistryError::ShapeMismatch { expected: (4, 6), got: (4, 5) }
        );
        // The failed swap left the original in place.
        assert_eq!(r.get("a").unwrap().cols(), 6);
        assert_eq!(
            r.swap_epoch("nope", op(1, 1)),
            Err(RegistryError::Unknown("nope".into()))
        );
    }

    #[test]
    fn retired_generation_drains_on_arc() {
        let r = Registry::new(None);
        r.register("a", op(3, 3)).unwrap();
        // A "worker" holding the old generation mid-batch.
        let in_flight = r.get("a").unwrap();
        let weak = Arc::downgrade(&in_flight);
        r.swap_epoch("a", op(3, 3)).unwrap();
        // Old generation is still alive while the batch runs...
        assert!(weak.upgrade().is_some());
        drop(in_flight);
        // ...and freed once the last in-flight reference drops.
        assert!(weak.upgrade().is_none());
    }

    #[test]
    fn adaptive_registry_sizes_batches_from_the_profile() {
        let r = Registry::new(Some(AdaptiveBatchConfig::default()));
        // A dense Mat exposes a profile → a target is derived.
        r.register("m", op(64, 64)).unwrap();
        let t = r.batch_limit("m").expect("dense op has a profile");
        assert!(t >= 1);
        // Fixed-mode registry never derives targets.
        let fixed = Registry::new(None);
        fixed.register("m", op(64, 64)).unwrap();
        assert_eq!(fixed.batch_limit("m"), None);
    }
}
