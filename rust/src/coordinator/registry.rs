//! Live operator registry: register, hot-swap and retire operators while
//! the coordinator serves traffic.
//!
//! The seed coordinator froze its operator set at startup — useless for
//! the paper's on-line story (Mairal et al.'s online dictionary learning
//! re-learns the operator *while* requests flow). The registry fixes
//! that with epoch-based swaps:
//!
//! - every mutation bumps a global **epoch**; each entry remembers the
//!   epoch it was published at;
//! - readers (the router resolving a flush, the client checking
//!   dimensions) take a cheap `RwLock` read and clone the operator's
//!   `Arc` — a swap never blocks on in-flight work;
//! - in-flight batches keep serving on the `Arc` they resolved, so a
//!   retired generation **drains** naturally: the old operator is freed
//!   when its last batch completes, with zero service stall.
//!
//! [`Registry::swap_epoch`] refuses shape-changing swaps: queued requests
//! were dimension-checked against the old operator, and a same-shape
//! guarantee is what makes "no failed, no misrouted requests during a
//! swap" a theorem instead of a race.
//!
//! Under adaptive batching the registry also re-derives the operator's
//! target batch width from its [`CostProfile`](crate::engine::CostProfile)
//! on every publish, so a
//! swap to a differently-shaped *plan* (same matrix shape, different
//! sparsity) immediately re-sizes its batches.
//!
//! **Precision tier.** Under a non-default [`Precision`] policy every
//! publish also builds the operator's f32 serving generation (via
//! [`BatchOp::to_f32_op`]) and calibrates its error bound right then —
//! "measured at swap". [`Registry::get_serving`] resolves the generation
//! the policy selects per flush; batch targets derive from the *serving*
//! generation's profile, so f32 entries batch wider under the same arena
//! cap. [`Registry::get`] keeps returning the f64 master (same shape),
//! which is what dimension checks and shape guards want.

use super::batcher::{target_batch_for_class, AdaptiveBatchConfig};
use super::metrics::Metrics;
use super::{BatchOp, F32Serving, Precision, QosClass, ServedPrecision};
use crate::engine::FleetCtx;
use crate::faust::Faust;
use crate::hierarchical::{factorize_fleet_traced_with_ctx, HierarchicalConfig};
use crate::linalg::Mat;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Errors from registry mutations. The unknown-key case is the *same
/// typed error* on every path — `swap_epoch`, `retire`,
/// [`Registry::refactorize_fleet`] outcomes, and the `serve --repl` ops
/// console all surface [`RegistryError::UnknownOperator`]'s `Display`,
/// never a hand-rolled string or a `Debug` dump.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RegistryError {
    /// `register` on a name that is already live (use `swap_epoch`).
    AlreadyRegistered(String),
    /// `swap_epoch` / `retire` on a name that is not registered.
    UnknownOperator(String),
    /// `swap_epoch` with an operator of a different shape.
    ShapeMismatch {
        expected: (usize, usize),
        got: (usize, usize),
    },
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegistryError::AlreadyRegistered(n) => {
                write!(f, "operator '{n}' already registered (swap instead)")
            }
            RegistryError::UnknownOperator(n) => write!(f, "operator '{n}' not registered"),
            RegistryError::ShapeMismatch { expected, got } => write!(
                f,
                "swap shape mismatch: expected {}x{}, got {}x{}",
                expected.0, expected.1, got.0, got.1
            ),
        }
    }
}

impl std::error::Error for RegistryError {}

struct Entry {
    op: Arc<dyn BatchOp>,
    /// f32 serving generation built (and error-calibrated) at publish
    /// time — `None` under the `f64` policy or when the operator cannot
    /// quantize ([`BatchOp::to_f32_op`] returned `None`).
    f32_gen: Option<F32Serving>,
    /// Which generation the precision policy selected for this entry.
    serving: ServedPrecision,
    /// Epoch this generation of the operator was published at.
    epoch: u64,
    /// Per-QoS-class flush thresholds derived from the **serving**
    /// generation's cost profile, indexed by [`QosClass::index`]
    /// (None ⇒ no profile / fixed sizing ⇒ the policy default applies).
    target_batch: Option<[usize; 3]>,
}

/// Concurrent name → operator map with epoch-stamped hot swap.
pub struct Registry {
    ops: RwLock<HashMap<String, Entry>>,
    epoch: AtomicU64,
    adaptive: Option<AdaptiveBatchConfig>,
    precision: Precision,
    metrics: Arc<Metrics>,
}

impl Registry {
    /// Empty registry serving everything in f64. `adaptive = Some(_)`
    /// turns on plan-aware batch sizing for every operator published
    /// with a cost profile.
    pub fn new(adaptive: Option<AdaptiveBatchConfig>) -> Self {
        Self::with_metrics(adaptive, Precision::F64, Arc::new(Metrics::new()))
    }

    /// Empty registry with an explicit precision policy.
    pub fn with_precision(
        adaptive: Option<AdaptiveBatchConfig>,
        precision: Precision,
    ) -> Self {
        Self::with_metrics(adaptive, precision, Arc::new(Metrics::new()))
    }

    pub(crate) fn with_metrics(
        adaptive: Option<AdaptiveBatchConfig>,
        precision: Precision,
        metrics: Arc<Metrics>,
    ) -> Self {
        Registry {
            ops: RwLock::new(HashMap::new()),
            epoch: AtomicU64::new(0),
            adaptive,
            precision,
            metrics,
        }
    }

    /// The precision policy every publish is evaluated under.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    fn entry_for(&self, op: Arc<dyn BatchOp>, epoch: u64) -> Entry {
        // Quantize + calibrate only when the policy can ever serve f32:
        // under `f64` a publish must stay bitwise-free of new work.
        let f32_gen = match self.precision {
            Precision::F64 => None,
            Precision::F32 | Precision::Auto(_) => op.to_f32_op(),
        };
        let serving = match (self.precision, &f32_gen) {
            (Precision::F32, Some(_)) => ServedPrecision::F32,
            (Precision::Auto(budget), Some(s)) if s.measured_rel_err <= budget => {
                ServedPrecision::F32
            }
            _ => ServedPrecision::F64,
        };
        // Batch targets price the generation that actually executes:
        // an f32 generation's 4-byte elements batch wider under the
        // same arena cap.
        let profile = match (serving, &f32_gen) {
            (ServedPrecision::F32, Some(s)) => s.op.cost_profile(),
            _ => op.cost_profile(),
        };
        let target_batch = match (&self.adaptive, profile) {
            (Some(cfg), Some(p)) => {
                Some(QosClass::ALL.map(|c| target_batch_for_class(&p, cfg, c)))
            }
            _ => None,
        };
        Entry { op, f32_gen, serving, epoch, target_batch }
    }

    /// Publish a new operator under `name`. Errors if the name is live.
    /// Returns the publish epoch.
    pub fn register(
        &self,
        name: impl Into<String>,
        op: Arc<dyn BatchOp>,
    ) -> Result<u64, RegistryError> {
        let name = name.into();
        let mut g = self.ops.write().unwrap();
        if g.contains_key(&name) {
            return Err(RegistryError::AlreadyRegistered(name));
        }
        let epoch = self.epoch.fetch_add(1, Ordering::AcqRel) + 1;
        g.insert(name, self.entry_for(op, epoch));
        self.metrics.record_registered();
        Ok(epoch)
    }

    /// Atomically replace `name`'s operator with a same-shape successor
    /// and return the new epoch. Readers that already resolved the old
    /// `Arc` keep it until their batch completes (drain-by-epoch); every
    /// request submitted after this returns is served by the successor.
    pub fn swap_epoch(
        &self,
        name: &str,
        op: Arc<dyn BatchOp>,
    ) -> Result<u64, RegistryError> {
        let mut g = self.ops.write().unwrap();
        let cur = g
            .get(name)
            .ok_or_else(|| RegistryError::UnknownOperator(name.to_string()))?;
        let expected = (cur.op.rows(), cur.op.cols());
        let got = (op.rows(), op.cols());
        if expected != got {
            return Err(RegistryError::ShapeMismatch { expected, got });
        }
        let epoch = self.epoch.fetch_add(1, Ordering::AcqRel) + 1;
        g.insert(name.to_string(), self.entry_for(op, epoch));
        self.metrics.record_swap();
        Ok(epoch)
    }

    /// Remove `name` and hand back its operator (in-flight batches still
    /// complete on their own `Arc` clones; later submissions get
    /// `UnknownOperator`).
    pub fn retire(&self, name: &str) -> Result<Arc<dyn BatchOp>, RegistryError> {
        let mut g = self.ops.write().unwrap();
        let entry = g
            .remove(name)
            .ok_or_else(|| RegistryError::UnknownOperator(name.to_string()))?;
        self.epoch.fetch_add(1, Ordering::AcqRel);
        self.metrics.record_retired();
        Ok(entry.op)
    }

    /// Resolve an operator (a cheap read-lock + `Arc` clone). Always the
    /// f64 master — shape checks and swap guards key off it.
    pub fn get(&self, name: &str) -> Option<Arc<dyn BatchOp>> {
        self.ops.read().unwrap().get(name).map(|e| e.op.clone())
    }

    /// Resolve the generation the precision policy selected at publish
    /// time, plus which element type it executes in. Same cost as
    /// [`Registry::get`]: a read-lock and an `Arc` clone.
    pub fn get_serving(&self, name: &str) -> Option<(Arc<dyn BatchOp>, ServedPrecision)> {
        self.ops.read().unwrap().get(name).map(|e| match (e.serving, &e.f32_gen) {
            (ServedPrecision::F32, Some(s)) => (s.op.clone(), ServedPrecision::F32),
            _ => (e.op.clone(), ServedPrecision::F64),
        })
    }

    /// Which precision `name`'s current generation serves in.
    pub fn serving_of(&self, name: &str) -> Option<ServedPrecision> {
        self.ops.read().unwrap().get(name).map(|e| e.serving)
    }

    /// Per-operator precision report, sorted by name: `(name, serving
    /// precision, measured f32 relative error if a quantized generation
    /// was built)`. The error is the swap-time probe measurement — the
    /// number `auto` budgets are compared against.
    pub fn precision_report(&self) -> Vec<(String, ServedPrecision, Option<f64>)> {
        let g = self.ops.read().unwrap();
        let mut v: Vec<(String, ServedPrecision, Option<f64>)> = g
            .iter()
            .map(|(n, e)| {
                (
                    n.clone(),
                    e.serving,
                    e.f32_gen.as_ref().map(|s| s.measured_rel_err),
                )
            })
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// The standard-class flush threshold for `name`'s current
    /// generation, if adaptive sizing derived one (identical to the
    /// class-less [`target_batch`](super::target_batch) of the profile).
    pub fn batch_limit(&self, name: &str) -> Option<usize> {
        self.batch_limit_class(name, QosClass::Standard)
    }

    /// The flush threshold for `name` as seen by one QoS `class`, if
    /// adaptive sizing derived one: each class feeds its own deadline
    /// budget into the latency term of the target-batch model.
    pub fn batch_limit_class(&self, name: &str, class: QosClass) -> Option<usize> {
        self.ops
            .read()
            .unwrap()
            .get(name)
            .and_then(|e| e.target_batch.map(|t| t[class.index()]))
    }

    /// Epoch `name`'s current generation was published at.
    pub fn epoch_of(&self, name: &str) -> Option<u64> {
        self.ops.read().unwrap().get(name).map(|e| e.epoch)
    }

    /// Global mutation epoch (bumped by register / swap / retire).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Names currently live, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.ops.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// Number of live operators.
    pub fn len(&self) -> usize {
        self.ops.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.read().unwrap().is_empty()
    }

    /// Refactorize a fleet of served operators concurrently and hot-swap
    /// each one **the moment its own factorization finishes** — not at a
    /// global barrier.
    ///
    /// `jobs` names each target operator, the dense matrix to factorize
    /// toward it, and its hierarchical configuration; the whole fleet
    /// trains on `fleet`'s shared context
    /// ([`factorize_fleet_traced_with_ctx`] batches the split/refit
    /// kernels of separate members into fused cross-operator
    /// dispatches). As each member completes, `publish` wraps the learned
    /// [`Faust`] into a servable operator (typically
    /// `engine.op(&faust)`), and [`Registry::swap_epoch`] publishes it
    /// while the rest of the fleet keeps training — traffic on already
    /// finished operators is served by their new generation immediately.
    ///
    /// Per-operator outcomes are reported in job order; a swap that fails
    /// (operator retired meanwhile → [`RegistryError::UnknownOperator`],
    /// or a shape-changing job → [`RegistryError::ShapeMismatch`]) never
    /// aborts the rest of the fleet. Jobs naming a key that is not
    /// registered *when the fleet starts* are rejected up front with the
    /// same typed error — they never train (their `rel_err` is NaN) and
    /// never slow the valid members' fused batches.
    pub fn refactorize_fleet<F>(
        &self,
        fleet: &FleetCtx,
        jobs: &[(String, &Mat, &HierarchicalConfig)],
        mut publish: F,
    ) -> Vec<FleetRefactorization>
    where
        F: FnMut(&str, &Faust) -> Arc<dyn BatchOp>,
    {
        // Reject never-registered names before spending any training time
        // on them (a name retired mid-training still surfaces the typed
        // error from its swap attempt below).
        let mut outcomes: Vec<Option<FleetRefactorization>> = jobs
            .iter()
            .map(|(name, _, _)| {
                if self.get(name).is_none() {
                    Some(FleetRefactorization {
                        name: name.clone(),
                        outcome: Err(RegistryError::UnknownOperator(name.clone())),
                        rel_err: f64::NAN,
                    })
                } else {
                    None
                }
            })
            .collect();
        let active: Vec<usize> = (0..jobs.len()).filter(|&i| outcomes[i].is_none()).collect();
        let hier_jobs: Vec<(&Mat, &HierarchicalConfig)> =
            active.iter().map(|&i| (jobs[i].1, jobs[i].2)).collect();
        let _ = factorize_fleet_traced_with_ctx(fleet, &hier_jobs, |k, f| {
            let i = active[k];
            let (name, a, _) = &jobs[i];
            let rel_err = f.relative_error_fro(a);
            let op = publish(name, f);
            let outcome = self.swap_epoch(name, op);
            outcomes[i] = Some(FleetRefactorization {
                name: name.clone(),
                outcome,
                rel_err,
            });
        });
        outcomes
            .into_iter()
            .map(|o| o.expect("every fleet member reports an outcome"))
            .collect()
    }
}

/// Per-operator outcome of [`Registry::refactorize_fleet`].
#[derive(Clone, Debug)]
pub struct FleetRefactorization {
    /// Registry key the job targeted.
    pub name: String,
    /// Publish epoch on success; the typed registry error otherwise
    /// (same [`RegistryError::UnknownOperator`] the API paths return).
    pub outcome: Result<u64, RegistryError>,
    /// Relative Frobenius error of the learned FAμST vs. its target
    /// (NaN when the job was rejected up front and never trained).
    pub rel_err: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn op(m: usize, n: usize) -> Arc<dyn BatchOp> {
        Arc::new(Mat::eye(m, n)) as Arc<dyn BatchOp>
    }

    #[test]
    fn register_swap_retire_lifecycle() {
        let r = Registry::new(None);
        assert!(r.is_empty());
        let e1 = r.register("a", op(4, 4)).unwrap();
        assert_eq!(e1, 1);
        assert_eq!(r.epoch_of("a"), Some(1));
        assert_eq!(r.names(), vec!["a".to_string()]);
        // Duplicate registration is refused.
        assert_eq!(
            r.register("a", op(4, 4)),
            Err(RegistryError::AlreadyRegistered("a".into()))
        );
        // Swap bumps the epoch and keeps the name.
        let e2 = r.swap_epoch("a", op(4, 4)).unwrap();
        assert!(e2 > e1);
        assert_eq!(r.epoch_of("a"), Some(e2));
        assert_eq!(r.len(), 1);
        // Retire removes and returns the operator.
        let old = r.retire("a").unwrap();
        assert_eq!(old.rows(), 4);
        assert!(r.get("a").is_none());
        assert!(matches!(r.retire("a"), Err(RegistryError::UnknownOperator(_))));
    }

    #[test]
    fn swap_refuses_shape_changes() {
        let r = Registry::new(None);
        r.register("a", op(4, 6)).unwrap();
        let err = r.swap_epoch("a", op(4, 5)).unwrap_err();
        assert_eq!(
            err,
            RegistryError::ShapeMismatch { expected: (4, 6), got: (4, 5) }
        );
        // The failed swap left the original in place.
        assert_eq!(r.get("a").unwrap().cols(), 6);
        assert_eq!(
            r.swap_epoch("nope", op(1, 1)),
            Err(RegistryError::UnknownOperator("nope".into()))
        );
    }

    #[test]
    fn retired_generation_drains_on_arc() {
        let r = Registry::new(None);
        r.register("a", op(3, 3)).unwrap();
        // A "worker" holding the old generation mid-batch.
        let in_flight = r.get("a").unwrap();
        let weak = Arc::downgrade(&in_flight);
        r.swap_epoch("a", op(3, 3)).unwrap();
        // Old generation is still alive while the batch runs...
        assert!(weak.upgrade().is_some());
        drop(in_flight);
        // ...and freed once the last in-flight reference drops.
        assert!(weak.upgrade().is_none());
    }

    #[test]
    fn unknown_operator_error_is_one_typed_value_on_every_path() {
        // The REPL and the API paths must surface the same typed error
        // with the same Display — no hand-rolled strings, no Debug dumps.
        let r = Registry::new(None);
        let via_swap = r.swap_epoch("ghost", op(2, 2)).unwrap_err();
        let via_retire = r.retire("ghost").unwrap_err();
        let expected = RegistryError::UnknownOperator("ghost".to_string());
        assert_eq!(via_swap, expected);
        assert_eq!(via_retire, expected);
        assert_eq!(via_swap.to_string(), "operator 'ghost' not registered");
        assert_eq!(via_swap.to_string(), via_retire.to_string());
    }

    #[test]
    fn refactorize_fleet_swaps_each_operator_and_reports_outcomes() {
        use crate::engine::{ExecCtx, FleetCtx};
        use crate::hierarchical::HierarchicalConfig;
        use crate::transforms::{hadamard, hadamard_faust};

        let r = Registry::new(None);
        // Two served operators of different sizes + one name that is not
        // registered (its swap must fail with the typed error while the
        // others still publish).
        let h8 = hadamard(8);
        let h16 = hadamard(16);
        r.register("a", Arc::new(hadamard_faust(8)) as Arc<dyn BatchOp>)
            .unwrap();
        r.register("b", Arc::new(hadamard_faust(16)) as Arc<dyn BatchOp>)
            .unwrap();
        let e_a0 = r.epoch_of("a").unwrap();
        let e_b0 = r.epoch_of("b").unwrap();
        let cfg8 = HierarchicalConfig::hadamard(8);
        let cfg16 = HierarchicalConfig::hadamard(16);
        let fleet = FleetCtx::new(ExecCtx::new(2));
        let jobs = vec![
            ("a".to_string(), &h8, &cfg8),
            ("b".to_string(), &h16, &cfg16),
            ("ghost".to_string(), &h8, &cfg8),
        ];
        let outcomes = r.refactorize_fleet(&fleet, &jobs, |_, f| {
            Arc::new(f.clone()) as Arc<dyn BatchOp>
        });
        assert_eq!(outcomes.len(), 3);
        assert!(outcomes[0].outcome.as_ref().unwrap() > &e_a0);
        assert!(outcomes[1].outcome.as_ref().unwrap() > &e_b0);
        assert_eq!(
            outcomes[2].outcome,
            Err(RegistryError::UnknownOperator("ghost".to_string()))
        );
        // Rejected up front: the doomed job never trained.
        assert!(outcomes[2].rel_err.is_nan());
        // The learned generations really replaced the originals and
        // approximate their targets.
        assert!(outcomes[0].rel_err < 1e-6);
        assert!(outcomes[1].rel_err < 1e-6);
        assert_eq!(r.epoch_of("a").unwrap(), *outcomes[0].outcome.as_ref().unwrap());
        assert_eq!(r.epoch_of("b").unwrap(), *outcomes[1].outcome.as_ref().unwrap());
    }

    #[test]
    fn adaptive_registry_sizes_batches_from_the_profile() {
        let r = Registry::new(Some(AdaptiveBatchConfig::default()));
        // A dense Mat exposes a profile → a target is derived.
        r.register("m", op(64, 64)).unwrap();
        let t = r.batch_limit("m").expect("dense op has a profile");
        assert!(t >= 1);
        // Per-class limits order with the class deadline budgets, and
        // batch_limit is exactly the standard class.
        let ti = r.batch_limit_class("m", QosClass::Interactive).unwrap();
        let ts = r.batch_limit_class("m", QosClass::Standard).unwrap();
        let tb = r.batch_limit_class("m", QosClass::Bulk).unwrap();
        assert_eq!(ts, t);
        assert!(ti <= ts && ts <= tb, "class limits out of order: {ti} {ts} {tb}");
        // Fixed-mode registry never derives targets.
        let fixed = Registry::new(None);
        fixed.register("m", op(64, 64)).unwrap();
        assert_eq!(fixed.batch_limit("m"), None);
        assert_eq!(fixed.batch_limit_class("m", QosClass::Bulk), None);
    }

    #[test]
    fn f64_policy_never_builds_a_quantized_generation() {
        use crate::transforms::hadamard_faust;
        let r = Registry::new(None);
        r.register("h", Arc::new(hadamard_faust(8)) as Arc<dyn BatchOp>)
            .unwrap();
        let (served, prec) = r.get_serving("h").unwrap();
        assert_eq!(prec, ServedPrecision::F64);
        assert_eq!(served.rows(), 8);
        assert_eq!(r.serving_of("h"), Some(ServedPrecision::F64));
        // No probe ran, so the report carries no measured error.
        assert_eq!(r.precision_report(), vec![("h".to_string(), ServedPrecision::F64, None)]);
    }

    #[test]
    fn f32_policy_serves_quantized_generation_and_falls_back_per_op() {
        use crate::transforms::hadamard_faust;
        let r = Registry::with_precision(None, Precision::F32);
        // A Faust quantizes; a plain dense Mat does not (to_f32_op =
        // None) — the same registry serves them at different precisions.
        r.register("h", Arc::new(hadamard_faust(8)) as Arc<dyn BatchOp>)
            .unwrap();
        r.register("m", op(8, 8)).unwrap();
        let (served, prec) = r.get_serving("h").unwrap();
        assert_eq!(prec, ServedPrecision::F32);
        assert_eq!((served.rows(), served.cols()), (8, 8));
        assert_eq!(r.serving_of("m"), Some(ServedPrecision::F64));
        // `get` still resolves the f64 master for shape checks.
        let master = r.get("h").unwrap();
        assert_eq!((master.rows(), master.cols()), (8, 8));
        // The quantized generation really computes the operator: compare
        // a batch against the f64 master within the measured-err report.
        let x = Mat::from_vec(8, 2, (0..16).map(|i| (i as f64).sin()).collect());
        let y32 = served.apply_batch(&x);
        let y64 = master.apply_batch(&x);
        let mut err2 = 0.0;
        let mut ref2 = 0.0;
        for (a, b) in y32.data().iter().zip(y64.data().iter()) {
            err2 += (a - b) * (a - b);
            ref2 += b * b;
        }
        let rel = (err2 / ref2).sqrt();
        assert!(rel < 1e-3, "f32 generation far from f64 master: rel={rel:e}");
        let report = r.precision_report();
        assert_eq!(report.len(), 2);
        assert_eq!(report[0].0, "h");
        assert_eq!(report[0].1, ServedPrecision::F32);
        assert!(report[0].2.unwrap() >= 0.0);
        assert_eq!(report[1], ("m".to_string(), ServedPrecision::F64, None));
    }

    #[test]
    fn auto_policy_selects_by_measured_error_budget() {
        use crate::transforms::hadamard_faust;
        // A Hadamard FAμST quantizes exactly (±1 factors); its probe
        // error is tiny, so a sane budget admits it…
        let loose = Registry::with_precision(None, Precision::Auto(1e-6));
        loose
            .register("h", Arc::new(hadamard_faust(16)) as Arc<dyn BatchOp>)
            .unwrap();
        assert_eq!(loose.serving_of("h"), Some(ServedPrecision::F32));
        // …while an absurdly tight budget (below f32 input-quantization
        // noise) rejects the same operator back to f64.
        let tight = Registry::with_precision(None, Precision::Auto(1e-13));
        tight
            .register("h", Arc::new(hadamard_faust(16)) as Arc<dyn BatchOp>)
            .unwrap();
        assert_eq!(tight.serving_of("h"), Some(ServedPrecision::F64));
        // The rejected entry still reports the measured error it was
        // judged on.
        let rep = tight.precision_report();
        assert!(rep[0].2.unwrap() > 1e-13);
    }

    #[test]
    fn swap_recalibrates_and_f32_batches_at_four_byte_prices() {
        use crate::transforms::hadamard_faust;
        let cfg = AdaptiveBatchConfig::default();
        let r64 = Registry::new(Some(cfg.clone()));
        let r32 = Registry::with_precision(Some(cfg), Precision::F32);
        r64.register("h", Arc::new(hadamard_faust(32)) as Arc<dyn BatchOp>)
            .unwrap();
        r32.register("h", Arc::new(hadamard_faust(32)) as Arc<dyn BatchOp>)
            .unwrap();
        let t64 = r64.batch_limit("h").expect("faust exposes a profile");
        let t32 = r32.batch_limit("h").expect("f32 generation exposes a profile");
        // Same operator, same arena cap: 4-byte elements can never batch
        // narrower than 8-byte ones.
        assert!(t32 >= t64, "f32 batch target {t32} narrower than f64 {t64}");
        // A swap re-quantizes and re-selects: the successor generation is
        // served in f32 too, at a fresh epoch.
        let e1 = r32.epoch_of("h").unwrap();
        let e2 = r32
            .swap_epoch("h", Arc::new(hadamard_faust(32)) as Arc<dyn BatchOp>)
            .unwrap();
        assert!(e2 > e1);
        assert_eq!(r32.serving_of("h"), Some(ServedPrecision::F32));
        assert!(r32.precision_report()[0].2.is_some());
    }
}
