//! Operator-serving coordinator: the L3 runtime that turns a FAμST into a
//! *service*.
//!
//! The paper's motivating workload (§V, the fig8/fig9 MEG experiments) is
//! an iterative solver issuing many matvec requests against an operator.
//! This module provides the deployment shape for that, the tail of the
//! repo's serving pipeline **plan → kernel → pool → shard → arena →
//! batcher → registry → admission → wire → store → online** (the
//! layer-by-layer map, with paper and PR cross-references, lives in
//! `docs/ARCHITECTURE.md`):
//!
//! - a live [`Registry`] mapping names to operators, supporting
//!   [`register`](Registry::register) / [`swap_epoch`](Registry::swap_epoch)
//!   / [`retire`](Registry::retire) while traffic flows — on-line
//!   refactorization (Mairal-style re-learning) publishes a fresh operator
//!   into the running service with zero stall, old generations draining on
//!   their `Arc`s; [`Registry::refactorize_fleet`] re-learns a whole
//!   *fleet* of served operators concurrently on one shared context
//!   (cross-operator batched PALM sweeps) and swaps each one in the
//!   moment its own factorization finishes;
//! - a **router** thread grouping requests per operator into dynamic
//!   **batches** — flushed on a deadline or at a per-operator width that
//!   adaptive sizing derives from the plan's flop/byte
//!   [`CostProfile`](crate::engine::CostProfile) (see [`target_batch`];
//!   fixed-size batching remains the default);
//! - a **worker pool** executing each batch as a single `spmm`, which is
//!   cache-friendlier and amortizes dispatch. Bounded queues give
//!   backpressure; metrics are lock-free atomics.
//!
//! **Precision selection (ROADMAP item j).** The registry stores, next
//! to every operator's f64 master generation, an optional f32 serving
//! generation built by [`BatchOp::to_f32_op`] at register/swap time —
//! factors quantize once, and the f32-vs-f64 relative error is measured
//! right then on a deterministic probe ("measured at swap", so the bound
//! always describes the exact generation being served). Which generation
//! a batch executes on is the [`CoordinatorConfig::precision`] policy:
//! [`Precision::F64`] (default, bitwise identical to the pre-tier
//! coordinator), [`Precision::F32`] (serve f32 wherever one exists), or
//! [`Precision::Auto`]`(budget)` — serve f32 iff the generation's
//! *measured* error is within the accuracy budget. Batches are sized
//! from the *serving* generation's [`CostProfile`] (f32 profiles report
//! `elem_bytes = 4`, halving the arena price per column), and
//! per-precision apply counts land in [`MetricsSnapshot`]. Factorization
//! never runs in f32 — precision is strictly a serving-tier choice.
//!
//! **Sharding (ROADMAP item l).** With
//! [`CoordinatorConfig::n_shards`]` > 1` the coordinator runs N
//! independent [`ShardSet`] pools instead of one: the registry pins each
//! operator to a shard at register time (greedy cost-model placement from
//! its [`CostProfile`], rebalanced on retire), the router pushes each
//! `(operator, class)` batch onto its owning shard's job queue, and a
//! shard whose own queue runs dry steals whole flush jobs from its
//! siblings (**work donation**). Because every engine kernel is bitwise
//! thread-invariant, moving a job between shards moves only *where* the
//! flops run — the shard-invariance proptests below hold results bitwise
//! identical to the single-pool seed path across shard counts {1, 2, 4},
//! donation included. `n_shards = 1` (the default) is exactly the seed
//! coordinator: no rebinding, no routing, no stealing.
//!
//! **Durability (ROADMAP item l, [`crate::store`]).**
//! [`Registry::persist_all`] snapshots every persistable operator
//! (factors + λ + f32 bound + epoch) into a versioned, CRC-sealed store
//! directory; [`Registry::load_store`] restores a whole fleet — warm
//! restarts re-plan in milliseconds instead of re-running PALM.
//!
//! **Online learning (ROADMAP item i, [`crate::palm::online`]).** With
//! [`CoordinatorConfig::online`]` = Some(_)` the deployment can attach
//! an [`OnlineLearner`] per operator: a streaming Mairal-style
//! factorization that ingests observed columns, updates the sparse
//! factors by weighted mini-batch PALM sweeps on a running surrogate,
//! and — on the configured [`OnlineLearnConfig::swap_every`] cadence,
//! gated on measured improvement — publishes each better generation via
//! [`Registry::swap_epoch`] while traffic flows. [`OnlineLearnerTask`]
//! runs the learner on its own thread behind a bounded observation
//! channel, so a sweep never stalls a request; drift is observable as
//! the `online_*` counters and the `online_rel_err` gauge in
//! [`MetricsSnapshot`]. The default (`online: None`) spawns nothing and
//! keeps the f64 serving path bitwise identical to the pre-online
//! coordinator.
//!
//! Operators are best registered as [`EngineOp`]s (see [`engine_ops`]):
//! the batch a worker executes then runs through the engine's cost-modeled
//! plan, row-parallel pooled spmm, and zero-alloc arena. A deployment
//! needs exactly one engine: `ApplyEngine::ctx()` hands the same pool to
//! the factorization stack, so on-line refactorization shares the serving
//! threads instead of oversubscribing the machine.
//!
//! Hot-swapping an operator mid-serve:
//!
//! ```
//! use faust::coordinator::{Coordinator, CoordinatorConfig, BatchOp};
//! use faust::transforms::{hadamard, hadamard_faust};
//! use std::sync::Arc;
//!
//! let n = 16;
//! let coord = Coordinator::start(
//!     vec![("h".to_string(), Arc::new(hadamard(n)) as Arc<dyn BatchOp>)],
//!     CoordinatorConfig::default(),
//! );
//! let client = coord.client();
//! let y0 = client.apply("h", vec![1.0; n]).unwrap();
//!
//! // Publish the factorized generation while the service runs.
//! let epoch = coord
//!     .registry()
//!     .swap_epoch("h", Arc::new(hadamard_faust(n)) as Arc<dyn BatchOp>)
//!     .unwrap();
//! assert!(epoch > 1);
//! let y1 = client.apply("h", vec![1.0; n]).unwrap();
//! for i in 0..n {
//!     assert!((y0[i] - y1[i]).abs() < 1e-10); // same operator, new factors
//! }
//! coord.shutdown();
//! ```
//!
//! tokio is not available offline; a compute-bound matvec service needs
//! threads, not async IO, so the pool is `std::thread` + channels.

// The coordinator's synchronization is all safe-Rust protocols over the
// `engine::sync` shim (loom-checkable); raw pointers stay confined to
// `engine::{kernel,pool}`.
#![forbid(unsafe_code)]

mod batcher;
mod metrics;
mod online;
mod registry;

pub use batcher::{
    target_batch, target_batch_for_class, AdaptiveBatchConfig, BatchPolicy, Batcher,
};
pub use metrics::{Metrics, MetricsSnapshot};
pub use online::{OnlineLearnConfig, OnlineLearner, OnlineLearnerReport, OnlineLearnerTask};
pub use registry::{
    FleetRefactorization, PersistReport, Registry, RegistryError, StoreRestore,
};

use crate::engine::sync::{AtomicBool, Condvar, Mutex, Ordering};
use crate::engine::{ApplyEngine, CostProfile, EngineOp, EngineOpF32, ShardSet, ThreadPool};
use crate::faust::Faust;
use crate::linalg::Mat;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Serving precision policy, applied per request by the [`Registry`]
/// (see the module docs' precision-selection section). Factorization is
/// always f64; this only chooses which *serving generation* executes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Precision {
    /// Always serve the f64 master generation (the default — bitwise
    /// identical to the pre-precision-tier coordinator).
    F64,
    /// Serve the f32 generation of every operator that publishes one
    /// (operators without one fall back to f64).
    F32,
    /// Accuracy-budgeted: serve f32 iff the generation's *measured*
    /// relative error (probe-calibrated at register/swap time) is within
    /// the budget; anything that can't prove it stays f64.
    Auto(f64),
}

impl Default for Precision {
    fn default() -> Self {
        Precision::F64
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Precision::F64 => f.write_str("f64"),
            Precision::F32 => f.write_str("f32"),
            Precision::Auto(eps) => write!(f, "auto:{eps:.0e}"),
        }
    }
}

impl std::str::FromStr for Precision {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "f64" => Ok(Precision::F64),
            "f32" => Ok(Precision::F32),
            "auto" => Ok(Precision::Auto(1e-6)),
            other => match other.strip_prefix("auto:") {
                Some(eps) => eps
                    .parse::<f64>()
                    .ok()
                    .filter(|e| e.is_finite() && *e > 0.0)
                    .map(Precision::Auto)
                    .ok_or_else(|| format!("bad accuracy budget '{eps}' in '{other}'")),
                None => Err(format!(
                    "unknown precision '{other}' (f64|f32|auto|auto:EPS)"
                )),
            },
        }
    }
}

/// Which element type actually executed a request's batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServedPrecision {
    /// The f64 master generation ran the batch (always the case for
    /// factorization-path outputs and for operators without a published
    /// f32 generation).
    F64,
    /// The quantized f32 serving generation ran the batch — chosen by
    /// the [`Precision`] policy against the generation's probe-measured
    /// error (see [`F32Serving`]).
    F32,
}

impl ServedPrecision {
    pub fn name(self) -> &'static str {
        match self {
            ServedPrecision::F64 => "f64",
            ServedPrecision::F32 => "f32",
        }
    }
}

impl std::fmt::Display for ServedPrecision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A published f32 serving generation: the quantized op plus the error
/// calibration the registry's precision policy decides with. Built by
/// [`BatchOp::to_f32_op`] when a generation is registered or swapped in
/// ("measured at swap" — the bound always describes the exact factors
/// being served, not some earlier generation).
#[derive(Clone)]
pub struct F32Serving {
    /// The quantized operator (f64 edges, f32 chain).
    pub op: Arc<dyn BatchOp>,
    /// Probe-measured f32-vs-f64 relative error (what `auto` budgets
    /// compare against, and what metrics report).
    pub measured_rel_err: f64,
    /// Declared headroom-padded bound (what tests hold outputs to).
    pub declared_rel_err: f64,
}

/// A batched linear operator servable by the coordinator.
pub trait BatchOp: Send + Sync {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    /// Apply to a column-batch `X ∈ R^{cols×b}` → `Y ∈ R^{rows×b}`.
    fn apply_batch(&self, x: &Mat) -> Mat;
    /// Flops per single matvec (for metrics / RCG reporting).
    fn flops_per_matvec(&self) -> usize;
    /// Flop/byte profile for adaptive batch sizing; `None` opts the
    /// operator out (it then batches at the policy's fixed default).
    fn cost_profile(&self) -> Option<CostProfile> {
        None
    }
    /// Build this operator's f32 serving generation, if it supports one.
    /// `None` (the default) keeps the operator f64-only — the registry
    /// then serves it at f64 under every precision policy.
    fn to_f32_op(&self) -> Option<F32Serving> {
        None
    }
    /// The learned FAμST behind this operator, if it carries durable
    /// state worth snapshotting ([`crate::store`]). `None` (the default)
    /// opts the operator out of [`Registry::persist_all`].
    fn persist_source(&self) -> Option<Faust> {
        None
    }
    /// Rebind this operator onto another engine pool (shard placement).
    /// `None` (the default) means the operator is pool-free — it serves
    /// unchanged from any shard. Implementations must be bitwise
    /// result-preserving (guaranteed by engine thread invariance).
    fn rebound_to(&self, _pool: &Arc<ThreadPool>) -> Option<Arc<dyn BatchOp>> {
        None
    }
}

impl BatchOp for Mat {
    fn rows(&self) -> usize {
        Mat::rows(self)
    }
    fn cols(&self) -> usize {
        Mat::cols(self)
    }
    fn apply_batch(&self, x: &Mat) -> Mat {
        self.matmul(x)
    }
    fn flops_per_matvec(&self) -> usize {
        2 * Mat::rows(self) * Mat::cols(self)
    }
    fn cost_profile(&self) -> Option<CostProfile> {
        Some(CostProfile::dense(Mat::rows(self), Mat::cols(self)))
    }
}

impl BatchOp for Faust {
    fn rows(&self) -> usize {
        Faust::rows(self)
    }
    fn cols(&self) -> usize {
        Faust::cols(self)
    }
    /// Routed through the cached engine plan (see [`crate::engine`]).
    fn apply_batch(&self, x: &Mat) -> Mat {
        self.apply_mat(x)
    }
    fn flops_per_matvec(&self) -> usize {
        self.flops_per_matvec()
    }
    /// Profile of the operator's cached engine plan.
    fn cost_profile(&self) -> Option<CostProfile> {
        Some(self.plan().profile())
    }
    /// The Faust's cached quantized plan, wrapped as a global-engine op
    /// (quantization + probe run at most once per operator).
    fn to_f32_op(&self) -> Option<F32Serving> {
        let (plan, bound) = self.plan_f32();
        Some(F32Serving {
            op: Arc::new(crate::engine::global().op_f32(plan, bound)),
            measured_rel_err: bound.measured_rel_err,
            declared_rel_err: bound.declared_rel_err,
        })
    }
    /// A bare Faust *is* its own durable state.
    fn persist_source(&self) -> Option<Faust> {
        Some(self.clone())
    }
}

impl BatchOp for EngineOp {
    fn rows(&self) -> usize {
        EngineOp::rows(self)
    }
    fn cols(&self) -> usize {
        EngineOp::cols(self)
    }
    /// Planned, pool-parallel, arena-backed batch apply.
    fn apply_batch(&self, x: &Mat) -> Mat {
        EngineOp::apply_batch(self, x)
    }
    fn flops_per_matvec(&self) -> usize {
        EngineOp::flops_per_matvec(self)
    }
    fn cost_profile(&self) -> Option<CostProfile> {
        Some(EngineOp::profile(self))
    }
    /// Quantize the plan and calibrate the bound on this op's own pool.
    fn to_f32_op(&self) -> Option<F32Serving> {
        let op32 = EngineOp::to_f32(self);
        let bound = op32.bound();
        Some(F32Serving {
            op: Arc::new(op32),
            measured_rel_err: bound.measured_rel_err,
            declared_rel_err: bound.declared_rel_err,
        })
    }
    /// The source factors the op was planned from (retained by
    /// [`ApplyEngine::op`]; `None` for plan-only ops).
    fn persist_source(&self) -> Option<Faust> {
        EngineOp::source(self).map(|f| (**f).clone())
    }
    /// Same plan, same arenas, different pool — bitwise identical by
    /// engine thread invariance.
    fn rebound_to(&self, pool: &Arc<ThreadPool>) -> Option<Arc<dyn BatchOp>> {
        Some(Arc::new(EngineOp::on_pool(self, pool.clone())))
    }
}

impl BatchOp for EngineOpF32 {
    fn rows(&self) -> usize {
        EngineOpF32::rows(self)
    }
    fn cols(&self) -> usize {
        EngineOpF32::cols(self)
    }
    /// f64 edges, f32 chain (see [`EngineOpF32::apply_batch`]).
    fn apply_batch(&self, x: &Mat) -> Mat {
        EngineOpF32::apply_batch(self, x)
    }
    fn flops_per_matvec(&self) -> usize {
        EngineOpF32::flops_per_matvec(self)
    }
    /// f32 profile: `elem_bytes = 4`, so the adaptive batcher prices the
    /// arena at half the f64 footprint (wider batches fit the same cap).
    fn cost_profile(&self) -> Option<CostProfile> {
        Some(EngineOpF32::profile(self))
    }
    /// Rebind the quantized generation onto a shard's pool, keeping the
    /// swap-time calibrated bound.
    fn rebound_to(&self, pool: &Arc<ThreadPool>) -> Option<Arc<dyn BatchOp>> {
        Some(Arc::new(EngineOpF32::on_pool(self, pool.clone())))
    }
}

/// Plan each FAμST on `engine` and box the resulting [`EngineOp`]s for
/// registration — the standard way to stand up an engine-backed service.
/// Arenas are pre-warmed for `batch_hint`-column batches.
pub fn engine_ops(
    engine: &ApplyEngine,
    ops: Vec<(String, Faust)>,
    batch_hint: usize,
) -> Vec<(String, Arc<dyn BatchOp>)> {
    ops.into_iter()
        .map(|(name, f)| {
            (
                name,
                Arc::new(engine.op_batch_hint(&f, batch_hint)) as Arc<dyn BatchOp>,
            )
        })
        .collect()
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Flush threshold for operators without an adaptive target
    /// (all of them when `adaptive` is `None`).
    pub max_batch: usize,
    /// Deadline before a partial batch is flushed.
    pub batch_timeout: Duration,
    /// Worker threads.
    pub n_workers: usize,
    /// Bounded request-queue capacity (backpressure).
    pub queue_capacity: usize,
    /// Plan-aware batch sizing: `Some(_)` derives a per-operator flush
    /// threshold from each operator's [`CostProfile`] (see
    /// [`target_batch`]); `None` keeps the fixed `max_batch` for all.
    pub adaptive: Option<AdaptiveBatchConfig>,
    /// Serving precision policy (see [`Precision`]); `F64` — the default
    /// — reproduces the pre-precision-tier coordinator bitwise.
    pub precision: Precision,
    /// Independent engine-pool shards (clamped to ≥ 1). `1` — the
    /// default — is exactly the seed single-pool coordinator; `> 1`
    /// pins each operator to a shard (cost-balanced), routes its batches
    /// there, spawns `n_workers` job workers *per shard*, and lets idle
    /// shards steal whole jobs from busy ones (work donation). Results
    /// are bitwise independent of the shard count.
    pub n_shards: usize,
    /// Online-learning cadence policy. `Some(_)` lets the deployment
    /// attach [`OnlineLearner`]s via [`Coordinator::online_learner`];
    /// `None` — the default — spawns nothing and keeps the f64 serving
    /// path bitwise identical to the pre-online coordinator.
    pub online: Option<OnlineLearnConfig>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_batch: 32,
            batch_timeout: Duration::from_micros(200),
            n_workers: 2,
            queue_capacity: 1024,
            adaptive: None,
            precision: Precision::F64,
            n_shards: 1,
            online: None,
        }
    }
}

impl CoordinatorConfig {
    /// Default config with plan-aware adaptive batching enabled.
    pub fn adaptive() -> Self {
        CoordinatorConfig { adaptive: Some(AdaptiveBatchConfig::default()), ..Self::default() }
    }

    /// Default config with online learning enabled at the default
    /// cadence ([`OnlineLearnConfig::default`]).
    pub fn online_learning() -> Self {
        CoordinatorConfig { online: Some(OnlineLearnConfig::default()), ..Self::default() }
    }
}

/// Traffic class of a request: how tight its latency budget is.
///
/// Classes are served through class-separated batches — an interactive
/// request never waits behind a bulk batch filling up — and each class
/// feeds its own deadline budget into [`target_batch`]'s latency term
/// (see [`target_batch_for_class`]), so batch sizing is traffic-class
/// aware end to end:
///
/// - [`Interactive`](QosClass::Interactive): half the base budget —
///   smaller batches, earlier flushes, tightest tail latency;
/// - [`Standard`](QosClass::Standard): reproduces the class-less
///   behavior exactly (the default for [`Client::apply`]);
/// - [`Bulk`](QosClass::Bulk): throughput traffic — a wide budget lets
///   batches grow toward the arena/flop caps.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[repr(u8)]
pub enum QosClass {
    Interactive = 0,
    Standard = 1,
    Bulk = 2,
}

impl QosClass {
    /// All classes, in priority order (index == wire code).
    pub const ALL: [QosClass; 3] = [QosClass::Interactive, QosClass::Standard, QosClass::Bulk];

    /// Dense index for per-class counters (same as the wire code).
    pub fn index(self) -> usize {
        self as usize
    }

    /// Decode a wire byte.
    pub fn from_u8(b: u8) -> Option<QosClass> {
        match b {
            0 => Some(QosClass::Interactive),
            1 => Some(QosClass::Standard),
            2 => Some(QosClass::Bulk),
            _ => None,
        }
    }

    /// Lower-case class name (CLI flags, metrics keys).
    pub fn name(self) -> &'static str {
        match self {
            QosClass::Interactive => "interactive",
            QosClass::Standard => "standard",
            QosClass::Bulk => "bulk",
        }
    }

    /// The class's end-to-end deadline budget, scaled from the service's
    /// base budget (`2 × latency_cap` under adaptive sizing — so standard
    /// reproduces the class-less [`target_batch`] exactly).
    pub fn deadline_budget(self, base: Duration) -> Duration {
        match self {
            QosClass::Interactive => base / 2,
            QosClass::Standard => base * 2,
            QosClass::Bulk => base * 20,
        }
    }
}

impl std::fmt::Display for QosClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for QosClass {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "interactive" => Ok(QosClass::Interactive),
            "standard" => Ok(QosClass::Standard),
            "bulk" => Ok(QosClass::Bulk),
            other => Err(format!("unknown QoS class '{other}' (interactive|standard|bulk)")),
        }
    }
}

/// One in-flight request.
struct Request {
    op: String,
    x: Vec<f64>,
    class: QosClass,
    /// Caller-supplied deadline override; `None` uses the class budget.
    deadline: Option<Duration>,
    enqueued: Instant,
    resp: SyncSender<Result<Vec<f64>, ServeError>>,
}

/// A batch ready for execution.
struct Job {
    op: Arc<dyn BatchOp>,
    /// Element type of the serving generation `op` resolved to (for
    /// per-precision metrics).
    precision: ServedPrecision,
    reqs: Vec<Request>,
}

/// Serving errors.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    UnknownOperator(String),
    WrongDimension { expected: usize, got: usize },
    QueueFull,
    ShuttingDown,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::UnknownOperator(n) => write!(f, "unknown operator '{n}'"),
            ServeError::WrongDimension { expected, got } => {
                write!(f, "wrong input dimension: expected {expected}, got {got}")
            }
            ServeError::QueueFull => write!(f, "request queue full"),
            ServeError::ShuttingDown => write!(f, "coordinator shutting down"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Shared worker queue (Mutex + Condvar; mpsc receivers are not cloneable).
/// Generic over the job payload so the loom models below can drive the
/// exact production donation protocol with plain integers.
struct JobQueue<T> {
    q: Mutex<Vec<T>>,
    cv: Condvar,
    closed: AtomicBool,
}

impl<T> JobQueue<T> {
    fn new() -> Self {
        JobQueue { q: Mutex::new(Vec::new()), cv: Condvar::new(), closed: AtomicBool::new(false) }
    }

    fn push(&self, job: T) {
        self.q.lock().unwrap().push(job);
        self.cv.notify_one();
    }

    /// Pop, waiting at most `d` for a job (used by shard workers so an
    /// idle shard periodically looks for donation work instead of
    /// blocking forever on its own queue).
    fn pop_timeout(&self, d: Duration) -> Option<T> {
        let mut g = self.q.lock().unwrap();
        if let Some(j) = g.pop() {
            return Some(j);
        }
        if self.closed.load(Ordering::Acquire) {
            return None;
        }
        let (mut g, _) = self.cv.wait_timeout(g, d).unwrap();
        g.pop()
    }

    /// Non-blocking pop — the donation path: a worker from another shard
    /// lifts a whole job off this queue.
    fn try_pop(&self) -> Option<T> {
        self.q.lock().unwrap().pop()
    }

    /// Closed and fully drained — nothing left for anyone to serve.
    fn is_done(&self) -> bool {
        self.closed.load(Ordering::Acquire) && self.q.lock().unwrap().is_empty()
    }

    fn close(&self) {
        self.closed.store(true, Ordering::Release);
        self.cv.notify_all();
    }
}

/// One shard's serving state: its private job queue plus the
/// busy-marking test hook the forced-donation tests flip.
struct ShardRuntime {
    jobs: JobQueue<Job>,
    /// When set, this shard's workers stall (as if wedged on a long
    /// batch); its queued jobs must be rescued by sibling donation.
    /// Test hook only — never set in production paths.
    busy: AtomicBool,
}

impl ShardRuntime {
    fn new() -> Self {
        ShardRuntime { jobs: JobQueue::new(), busy: AtomicBool::new(false) }
    }
}

/// Handle for submitting requests; cloneable and thread-safe.
#[derive(Clone)]
pub struct Client {
    tx: SyncSender<Request>,
    registry: Arc<Registry>,
    metrics: Arc<Metrics>,
}

impl Client {
    /// Blocking single matvec through the service (standard class).
    pub fn apply(&self, op: &str, x: Vec<f64>) -> Result<Vec<f64>, ServeError> {
        self.apply_class(op, x, QosClass::Standard, None)
    }

    /// Blocking single matvec with an explicit QoS class and optional
    /// per-request deadline override.
    pub fn apply_class(
        &self,
        op: &str,
        x: Vec<f64>,
        class: QosClass,
        deadline: Option<Duration>,
    ) -> Result<Vec<f64>, ServeError> {
        let rx = self.submit_class(op, x, class, deadline)?;
        rx.recv().map_err(|_| ServeError::ShuttingDown)?
    }

    /// Submit without blocking on the result; returns the response
    /// channel. Standard class — [`Client::apply`]'s non-blocking form.
    pub fn submit(
        &self,
        op: &str,
        x: Vec<f64>,
    ) -> Result<Receiver<Result<Vec<f64>, ServeError>>, ServeError> {
        self.submit_class(op, x, QosClass::Standard, None)
    }

    /// Submit with an explicit QoS class and optional deadline override.
    /// The class selects the batch the request joins (classes never mix
    /// in one batch) and scales its flush deadline; an explicit
    /// `deadline` tightens — never extends — the class budget.
    pub fn submit_class(
        &self,
        op: &str,
        x: Vec<f64>,
        class: QosClass,
        deadline: Option<Duration>,
    ) -> Result<Receiver<Result<Vec<f64>, ServeError>>, ServeError> {
        let handle = self
            .registry
            .get(op)
            .ok_or_else(|| ServeError::UnknownOperator(op.to_string()))?;
        if x.len() != handle.cols() {
            return Err(ServeError::WrongDimension { expected: handle.cols(), got: x.len() });
        }
        let (rtx, rrx) = sync_channel(1);
        let req = Request {
            op: op.to_string(),
            x,
            class,
            deadline,
            enqueued: Instant::now(),
            resp: rtx,
        };
        match self.tx.try_send(req) {
            Ok(()) => {
                self.metrics.record_submitted();
                Ok(rrx)
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.record_rejected();
                Err(ServeError::QueueFull)
            }
            Err(TrySendError::Disconnected(_)) => Err(ServeError::ShuttingDown),
        }
    }

    /// Snapshot of serving metrics.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// The live operator registry behind this client (register / swap /
    /// retire operators without stopping the service).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Shared metrics handle for subsystems that record into the same
    /// counters (the ingress server's admission controller).
    pub(crate) fn metrics_handle(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }
}

/// The running coordinator: router + per-shard workers.
pub struct Coordinator {
    client: Client,
    router: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    shards: Arc<Vec<ShardRuntime>>,
    stop: Arc<AtomicBool>,
    online: Option<OnlineLearnConfig>,
}

impl Coordinator {
    /// Start serving the given named operators.
    ///
    /// # Panics
    /// If two operators share a name. The pre-registry coordinator
    /// silently kept the last duplicate; a name collision at startup is
    /// a deployment bug, so it now fails loudly (after startup, use
    /// [`Registry::swap_epoch`] to replace an operator).
    pub fn start(ops: Vec<(String, Arc<dyn BatchOp>)>, cfg: CoordinatorConfig) -> Self {
        let metrics = Arc::new(Metrics::new());
        let n_shards = cfg.n_shards.max(1);
        // One engine pool per shard. Thread budget divides the machine
        // across shards; the bitwise thread-invariance contract makes the
        // per-shard width a pure throughput knob, never a results knob.
        let pools = if n_shards > 1 {
            let avail = std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1);
            Arc::new(ShardSet::new(n_shards, (avail / n_shards).max(1)))
        } else {
            // Seed path: a placeholder single shard — the registry never
            // rebinds on a one-shard set, so this pool is never used.
            Arc::new(ShardSet::single(Arc::new(ThreadPool::new(1))))
        };
        let registry = Arc::new(Registry::with_shards(
            cfg.adaptive.clone(),
            cfg.precision,
            metrics.clone(),
            pools,
        ));
        for (name, op) in ops {
            registry
                .register(name, op)
                .expect("duplicate operator name at startup");
        }
        let (tx, rx) = sync_channel::<Request>(cfg.queue_capacity);
        let shards: Arc<Vec<ShardRuntime>> =
            Arc::new((0..n_shards).map(|_| ShardRuntime::new()).collect());
        let stop = Arc::new(AtomicBool::new(false));

        // Router thread: drain the request channel, batch per op.
        let r_registry = registry.clone();
        let r_shards = shards.clone();
        let r_metrics = metrics.clone();
        let r_stop = stop.clone();
        let policy = BatchPolicy { max_batch: cfg.max_batch, timeout: cfg.batch_timeout };
        // Base deadline budget the QoS classes scale from: the adaptive
        // latency cap when plan-aware sizing is on, else a multiple of
        // the flush timeout (standard's budget is 2× the base, so the
        // fixed-mode standard deadline stays well clear of the timeout).
        let base_budget = cfg
            .adaptive
            .as_ref()
            .map(|a| a.latency_cap)
            .unwrap_or(cfg.batch_timeout * 4);
        let router = std::thread::Builder::new()
            .name("faust-router".into())
            .spawn(move || {
                router_loop(rx, r_registry, r_shards, r_metrics, policy, base_budget, r_stop)
            })
            .expect("spawn router");

        // Worker pool: `n_workers` job workers per shard, each bound to
        // a home queue and free to donate cycles to any sibling's.
        let per_shard = cfg.n_workers.max(1);
        let mut workers = Vec::with_capacity(n_shards * per_shard);
        for s in 0..n_shards {
            for w in 0..per_shard {
                let w_shards = shards.clone();
                let w_metrics = metrics.clone();
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("faust-worker-{s}.{w}"))
                        .spawn(move || worker_loop(s, w_shards, w_metrics))
                        .expect("spawn worker"),
                );
            }
        }

        let client = Client { tx, registry, metrics };
        let online = cfg.online.clone();
        Coordinator { client, router: Some(router), workers, shards, stop, online }
    }

    /// Get a submission handle.
    pub fn client(&self) -> Client {
        self.client.clone()
    }

    /// The live operator registry: register, hot-swap (`swap_epoch`) or
    /// retire operators while the service runs.
    pub fn registry(&self) -> Arc<Registry> {
        self.client.registry.clone()
    }

    /// Number of shards this coordinator runs (≥ 1).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The online-learning cadence policy this coordinator was started
    /// with (`None` when online learning is off).
    pub fn online_config(&self) -> Option<OnlineLearnConfig> {
        self.online.clone()
    }

    /// Build an [`OnlineLearner`] for registry operator `name`, wired to
    /// this coordinator's registry, metrics, and configured
    /// [`CoordinatorConfig::online`] cadence. `None` when online
    /// learning is off. `palm` carries the warm/cold start and the
    /// constraint set — warm-start it from the serving generation's
    /// factors via [`crate::palm::online::OnlinePalm::warm`]. Run it
    /// inline or hand it to [`OnlineLearnerTask::spawn`].
    pub fn online_learner(
        &self,
        name: impl Into<String>,
        palm: crate::palm::online::OnlinePalm,
    ) -> Option<OnlineLearner> {
        let cfg = self.online.clone()?;
        Some(OnlineLearner::new(
            name,
            self.client.registry.clone(),
            self.client.metrics.clone(),
            palm,
            cfg,
        ))
    }

    /// Test hook: wedge (or un-wedge) shard `shard`'s workers so its
    /// queued jobs can only complete via sibling donation. No-op
    /// returning `false` on a single-shard coordinator (wedging the only
    /// shard would deadlock) or an out-of-range index.
    #[doc(hidden)]
    pub fn debug_mark_shard_busy(&self, shard: usize, busy: bool) -> bool {
        if self.shards.len() <= 1 || shard >= self.shards.len() {
            return false;
        }
        self.shards[shard].busy.store(busy, Ordering::Release);
        true
    }

    /// Graceful shutdown: stop accepting, drain in-flight work, join.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.stop.store(true, Ordering::Release);
        if let Some(r) = self.router.take() {
            let _ = r.join();
        }
        for s in self.shards.iter() {
            s.jobs.close();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.client.metrics()
    }
}

fn router_loop(
    rx: Receiver<Request>,
    registry: Arc<Registry>,
    shards: Arc<Vec<ShardRuntime>>,
    metrics: Arc<Metrics>,
    policy: BatchPolicy,
    base_budget: Duration,
    stop: Arc<AtomicBool>,
) {
    // Batches are keyed by (operator, class): classes never mix in one
    // batch, so an interactive request is never held hostage by a bulk
    // batch filling toward a wide target.
    let mut batcher: Batcher<(String, QosClass), Request> = Batcher::new(policy.clone());
    // Per-(operator, class) flush threshold, re-resolved on every request
    // so a registry swap that changes the plan re-sizes batches
    // immediately.
    let limit_for = |registry: &Registry, key: &(String, QosClass)| {
        registry
            .batch_limit_class(&key.0, key.1)
            .unwrap_or(policy.max_batch)
    };
    // A request's flush timeout: the policy deadline, tightened (never
    // extended) by the request's effective deadline budget — a quarter
    // of it, leaving the rest for queueing + execution.
    let timeout_for = |req: &Request| {
        let budget = req
            .deadline
            .unwrap_or_else(|| req.class.deadline_budget(base_budget));
        policy.timeout.min(budget / 4)
    };
    let route = |batcher: &mut Batcher<(String, QosClass), Request>, req: Request| {
        let key = (req.op.clone(), req.class);
        let limit = limit_for(&registry, &key);
        let timeout = timeout_for(&req);
        if let Some((key, reqs)) = batcher.add_with_timeout(key, req, limit, timeout) {
            flush(&registry, &shards, &metrics, key.0, reqs, limit);
        }
    };
    loop {
        let timeout = batcher
            .next_deadline_in()
            .unwrap_or(Duration::from_millis(5));
        match rx.recv_timeout(timeout) {
            Ok(req) => route(&mut batcher, req),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
        }
        for (key, reqs) in batcher.take_expired() {
            let limit = limit_for(&registry, &key);
            flush(&registry, &shards, &metrics, key.0, reqs, limit);
        }
        if stop.load(Ordering::Acquire) {
            // Drain anything still in the channel, then stop.
            while let Ok(req) = rx.try_recv() {
                route(&mut batcher, req);
            }
            break;
        }
    }
    // Drain remaining partial batches on shutdown.
    for (key, reqs) in batcher.drain() {
        let limit = limit_for(&registry, &key);
        flush(&registry, &shards, &metrics, key.0, reqs, limit);
    }
}

/// Hand a batch to its owning shard's workers, split into `limit`-sized
/// jobs. The split is what upholds the adaptive arena cap even on paths
/// where more than `limit` requests had already accumulated (timeout
/// expiry, or a swap that lowered the operator's target mid-batch).
fn flush(
    registry: &Registry,
    shards: &Arc<Vec<ShardRuntime>>,
    metrics: &Arc<Metrics>,
    op_name: String,
    mut reqs: Vec<Request>,
    limit: usize,
) {
    match registry.get_serving_routed(&op_name) {
        Some((op, precision, shard)) => {
            let queue = &shards[shard % shards.len()].jobs;
            let limit = limit.max(1);
            while !reqs.is_empty() {
                let rest = reqs.split_off(reqs.len().min(limit));
                let batch = std::mem::replace(&mut reqs, rest);
                metrics.record_batch(batch.len());
                queue.push(Job { op: op.clone(), precision, reqs: batch });
            }
        }
        None => {
            for r in reqs {
                let _ = r
                    .resp
                    .send(Err(ServeError::UnknownOperator(op_name.clone())));
            }
        }
    }
}

/// Shard worker: serve the home queue; when it runs dry, donate cycles
/// to any sibling with stranded jobs; exit once every queue is closed
/// and drained. A stolen job executes exactly as it would have on its
/// owner — its operator carries its own engine pool, so donation moves
/// scheduling, never results.
fn worker_loop(me: usize, shards: Arc<Vec<ShardRuntime>>, metrics: Arc<Metrics>) {
    loop {
        if shards[me].busy.load(Ordering::Acquire) {
            // Wedged-shard test hook: stall until un-wedged or shutdown.
            if shards.iter().all(|s| s.jobs.is_done()) {
                return;
            }
            std::thread::sleep(Duration::from_micros(200));
            continue;
        }
        if let Some(job) = shards[me].jobs.pop_timeout(Duration::from_millis(1)) {
            run_job(job, &metrics);
            continue;
        }
        // Home queue idle: scan siblings for work to steal.
        let mut stole = false;
        for d in 1..shards.len() {
            let k = (me + d) % shards.len();
            if let Some(job) = shards[k].jobs.try_pop() {
                metrics.record_job_donated();
                run_job(job, &metrics);
                stole = true;
                break;
            }
        }
        if !stole && shards.iter().all(|s| s.jobs.is_done()) {
            return;
        }
    }
}

/// Execute one batch job and answer its requests.
fn run_job(job: Job, metrics: &Arc<Metrics>) {
    let n = job.op.cols();
    // Re-validate dimensions against the operator that actually
    // resolved: a retire + register under the same name can change
    // the shape after a request was submit-checked (swap_epoch can't
    // — it is shape-checked — but the worker must never panic on a
    // stale request either way).
    let (reqs, stale): (Vec<Request>, Vec<Request>) =
        job.reqs.into_iter().partition(|r| r.x.len() == n);
    for r in stale {
        let _ = r
            .resp
            .send(Err(ServeError::WrongDimension { expected: n, got: r.x.len() }));
    }
    if reqs.is_empty() {
        return;
    }
    let b = reqs.len();
    // Assemble the column batch.
    let mut x = Mat::zeros(n, b);
    for (c, r) in reqs.iter().enumerate() {
        for i in 0..n {
            x.set(i, c, r.x[i]);
        }
    }
    let t0 = Instant::now();
    let y = job.op.apply_batch(&x);
    let exec_ns = t0.elapsed().as_nanos() as u64;
    metrics.record_exec(b, exec_ns, job.op.flops_per_matvec() as u64 * b as u64);
    metrics.record_precision_applies(job.precision, b as u64);
    for (c, r) in reqs.into_iter().enumerate() {
        let latency = r.enqueued.elapsed().as_nanos() as u64;
        metrics.record_completed(latency);
        let _ = r.resp.send(Ok(y.col(c)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn dense_op(m: usize, n: usize, seed: u64) -> (Arc<Mat>, Mat) {
        let mut rng = Rng::new(seed);
        let a = Mat::randn(m, n, &mut rng);
        (Arc::new(a.clone()), a)
    }

    #[test]
    fn serves_correct_results() {
        let (op, a) = dense_op(6, 9, 161);
        let coord = Coordinator::start(
            vec![("m".to_string(), op as Arc<dyn BatchOp>)],
            CoordinatorConfig::default(),
        );
        let client = coord.client();
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let x = rng.gauss_vec(9);
            let y = client.apply("m", x.clone()).unwrap();
            let want = a.matvec(&x);
            for i in 0..6 {
                assert!((y[i] - want[i]).abs() < 1e-12);
            }
        }
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 20);
    }

    #[test]
    fn unknown_operator_and_bad_dims_rejected() {
        let (op, _) = dense_op(4, 4, 162);
        let coord = Coordinator::start(
            vec![("a".to_string(), op as Arc<dyn BatchOp>)],
            CoordinatorConfig::default(),
        );
        let client = coord.client();
        assert!(matches!(
            client.apply("nope", vec![0.0; 4]),
            Err(ServeError::UnknownOperator(_))
        ));
        assert!(matches!(
            client.apply("a", vec![0.0; 3]),
            Err(ServeError::WrongDimension { expected: 4, got: 3 })
        ));
        coord.shutdown();
    }

    #[test]
    fn concurrent_clients_batch_and_complete() {
        let (op, a) = dense_op(8, 8, 163);
        let mut cfg = CoordinatorConfig::default();
        cfg.max_batch = 16;
        cfg.batch_timeout = Duration::from_millis(2);
        let coord = Coordinator::start(vec![("m".to_string(), op as Arc<dyn BatchOp>)], cfg);
        let client = coord.client();
        let nthreads = 4;
        let per = 25;
        let mut handles = vec![];
        for t in 0..nthreads {
            let c = client.clone();
            let a = a.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(200 + t as u64);
                for _ in 0..per {
                    let x = rng.gauss_vec(8);
                    let y = c.apply("m", x.clone()).unwrap();
                    let want = a.matvec(&x);
                    for i in 0..8 {
                        assert!((y[i] - want[i]).abs() < 1e-12);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = coord.shutdown();
        assert_eq!(snap.completed, (nthreads * per) as u64);
        // With concurrency + a 2ms window we expect at least one batch > 1.
        assert!(snap.max_batch_size >= 1);
    }

    #[test]
    fn faust_and_dense_agree_through_service() {
        let h = crate::transforms::hadamard(32);
        let hf = crate::transforms::hadamard_faust(32);
        let coord = Coordinator::start(
            vec![
                ("dense".to_string(), Arc::new(h.clone()) as Arc<dyn BatchOp>),
                ("faust".to_string(), Arc::new(hf) as Arc<dyn BatchOp>),
            ],
            CoordinatorConfig::default(),
        );
        let client = coord.client();
        let mut rng = Rng::new(3);
        let x = rng.gauss_vec(32);
        let yd = client.apply("dense", x.clone()).unwrap();
        let yf = client.apply("faust", x).unwrap();
        for i in 0..32 {
            assert!((yd[i] - yf[i]).abs() < 1e-10);
        }
        coord.shutdown();
    }

    #[test]
    fn engine_backed_ops_serve_correctly() {
        let n = 32;
        let h = crate::transforms::hadamard(n);
        let hf = crate::transforms::hadamard_faust(n);
        let engine = crate::engine::ApplyEngine::with_threads(2);
        let ops = engine_ops(&engine, vec![("f".to_string(), hf)], 8);
        let coord = Coordinator::start(ops, CoordinatorConfig::default());
        let client = coord.client();
        let mut rng = Rng::new(5);
        for _ in 0..10 {
            let x = rng.gauss_vec(n);
            let y = client.apply("f", x.clone()).unwrap();
            let want = h.matvec(&x);
            for i in 0..n {
                assert!((y[i] - want[i]).abs() < 1e-10);
            }
        }
        coord.shutdown();
        let m = engine.metrics();
        assert!(m.applies >= 1, "engine never executed a batch");
        assert_eq!(m.plans_compiled, 1);
    }

    #[test]
    fn serving_and_refactorization_share_one_engine() {
        // The deployment story: one engine serves planned applies while
        // the same engine's ctx factorizes the next operator on-line.
        use crate::hierarchical::{factorize_with_ctx, HierarchicalConfig};
        let n = 16;
        let h = crate::transforms::hadamard(n);
        let engine = crate::engine::ApplyEngine::with_threads(2);
        let ops = engine_ops(
            &engine,
            vec![("served".to_string(), crate::transforms::hadamard_faust(n))],
            8,
        );
        let coord = Coordinator::start(ops, CoordinatorConfig::default());
        let client = coord.client();
        // On-line refactorization on the serving engine's own pool.
        let ctx = engine.ctx();
        assert!(std::sync::Arc::ptr_eq(ctx.pool(), engine.pool()));
        let fst = factorize_with_ctx(&ctx, &h, &HierarchicalConfig::hadamard(n));
        assert!(fst.relative_error_fro(&h) < 1e-6);
        // The service stayed correct throughout.
        let mut rng = Rng::new(9);
        let x = rng.gauss_vec(n);
        let y = client.apply("served", x.clone()).unwrap();
        let want = h.matvec(&x);
        for i in 0..n {
            assert!((y[i] - want[i]).abs() < 1e-10);
        }
        coord.shutdown();
    }

    #[test]
    fn swap_epoch_mid_serve_loses_no_requests() {
        // Hot-swap the operator while clients hammer it: every request
        // must succeed and every response must match one of the two
        // generations exactly (no misrouting, no mixing).
        let n = 32;
        let h = crate::transforms::hadamard(n);
        let engine = crate::engine::ApplyEngine::with_threads(2);
        let ops = engine_ops(
            &engine,
            vec![("op".to_string(), crate::transforms::hadamard_faust(n))],
            8,
        );
        let coord = Coordinator::start(ops, CoordinatorConfig::default());
        let client = coord.client();
        let registry = coord.registry();
        let stop = Arc::new(AtomicBool::new(false));

        // Generation 2: the same operator scaled by 2 — distinguishable.
        let h2 = h.scaled(2.0);
        let mut handles = vec![];
        for t in 0..3u64 {
            let c = client.clone();
            let h = h.clone();
            let h2 = h2.clone();
            let stop = stop.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(900 + t);
                let mut served = 0u64;
                while !stop.load(Ordering::Acquire) {
                    let x = rng.gauss_vec(n);
                    let y = c.apply("op", x.clone()).expect("request failed mid-swap");
                    let (w1, w2) = (h.matvec(&x), h2.matvec(&x));
                    let matches = |w: &[f64]| {
                        y.iter().zip(w).all(|(a, b)| (a - b).abs() < 1e-9)
                    };
                    assert!(
                        matches(&w1) || matches(&w2),
                        "response matches neither generation"
                    );
                    served += 1;
                }
                served
            }));
        }
        // Let traffic flow, then publish generation 2 mid-flight.
        std::thread::sleep(Duration::from_millis(20));
        let weak_old = Arc::downgrade(&registry.get("op").unwrap());
        let e = registry
            .swap_epoch("op", Arc::new(h2.clone()) as Arc<dyn BatchOp>)
            .unwrap();
        assert!(e >= 2);
        // Every request submitted from here on is served by generation 2.
        let mut rng = Rng::new(999);
        let x = rng.gauss_vec(n);
        let y = client.apply("op", x.clone()).unwrap();
        let want = h2.matvec(&x);
        for i in 0..n {
            assert!((y[i] - want[i]).abs() < 1e-9, "post-swap request misrouted");
        }
        std::thread::sleep(Duration::from_millis(20));
        stop.store(true, Ordering::Release);
        let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(total > 0, "no traffic flowed during the swap");
        let snap = coord.shutdown();
        assert_eq!(snap.swaps, 1);
        assert_eq!(snap.rejected, 0, "swap caused rejections");
        // The old generation drained: its last Arc died with its batches.
        assert!(weak_old.upgrade().is_none(), "old generation never drained");
    }

    #[test]
    fn register_and_retire_while_serving() {
        let (op, a) = dense_op(5, 5, 164);
        let coord = Coordinator::start(vec![], CoordinatorConfig::default());
        let client = coord.client();
        // Nothing registered yet.
        assert!(matches!(
            client.apply("late", vec![0.0; 5]),
            Err(ServeError::UnknownOperator(_))
        ));
        // Register after startup; the running service picks it up.
        coord.registry().register("late", op).unwrap();
        let y = client.apply("late", vec![1.0, 0.0, 0.0, 0.0, 0.0]).unwrap();
        for i in 0..5 {
            assert!((y[i] - a.at(i, 0)).abs() < 1e-12);
        }
        // Retire: later submissions are rejected cleanly.
        coord.registry().retire("late").unwrap();
        assert!(matches!(
            client.apply("late", vec![0.0; 5]),
            Err(ServeError::UnknownOperator(_))
        ));
        let snap = coord.shutdown();
        assert_eq!((snap.registered, snap.retired), (1, 1));
    }

    #[test]
    fn adaptive_batches_never_exceed_the_derived_target() {
        // Regression for the zero-alloc invariant: under adaptive sizing
        // the router must never flush a batch wider than the target the
        // arena was budgeted for.
        let n = 64;
        let acfg = AdaptiveBatchConfig {
            max_arena_bytes: crate::engine::Arena::<f64>::footprint_for(n) * 6,
            ..AdaptiveBatchConfig::default()
        };
        let engine = crate::engine::ApplyEngine::with_threads(2);
        let f = crate::transforms::hadamard_faust(n);
        let profile = engine.plan(&f).profile();
        let target = target_batch(&profile, &acfg);
        assert!(target <= 6, "arena cap ignored: target={target}");
        let cfg = CoordinatorConfig {
            adaptive: Some(acfg.clone()),
            max_batch: 512, // fixed default must NOT apply to profiled ops
            batch_timeout: Duration::from_millis(5),
            ..CoordinatorConfig::default()
        };
        let ops = engine_ops(&engine, vec![("f".to_string(), f)], target);
        let coord = Coordinator::start(ops, cfg);
        assert_eq!(coord.registry().batch_limit("f"), Some(target));
        let client = coord.client();
        let mut rng = Rng::new(1234);
        let mut pending = vec![];
        for _ in 0..200 {
            if let Ok(rx) = client.submit("f", rng.gauss_vec(n)) {
                pending.push(rx);
            }
        }
        for rx in pending {
            let _ = rx.recv();
        }
        let snap = coord.shutdown();
        assert!(
            snap.max_batch_size <= target as u64,
            "flushed a batch of {} > target {target}",
            snap.max_batch_size
        );
        // And the batch width the batcher chose fits the arena budget.
        assert!(
            crate::engine::Arena::<f64>::footprint_for(profile.max_dim * target)
                <= acfg.max_arena_bytes
        );
    }

    #[test]
    fn auto_precision_serves_f32_within_budget_end_to_end() {
        // The full path: policy parses from a flag string, the registry
        // quantizes at register time, the router resolves the f32
        // generation, workers count per-precision applies, and responses
        // stay within the accuracy budget of the f64 truth.
        let n = 64;
        let h = crate::transforms::hadamard(n);
        let hf = crate::transforms::hadamard_faust(n);
        let cfg = CoordinatorConfig {
            precision: "auto:1e-3".parse().expect("flag syntax"),
            ..CoordinatorConfig::default()
        };
        assert_eq!(cfg.precision, Precision::Auto(1e-3));
        let coord = Coordinator::start(
            vec![("h".to_string(), Arc::new(hf) as Arc<dyn BatchOp>)],
            cfg,
        );
        assert_eq!(
            coord.registry().serving_of("h"),
            Some(ServedPrecision::F32),
            "hadamard quantizes well under a 1e-3 budget"
        );
        let client = coord.client();
        let mut rng = Rng::new(41);
        for _ in 0..12 {
            let x = rng.gauss_vec(n);
            let y = client.apply("h", x.clone()).unwrap();
            let want = h.matvec(&x);
            let mut err2 = 0.0;
            let mut ref2 = 0.0;
            for i in 0..n {
                err2 += (y[i] - want[i]) * (y[i] - want[i]);
                ref2 += want[i] * want[i];
            }
            assert!(
                (err2 / ref2).sqrt() < 1e-3,
                "f32 response outside the accuracy budget"
            );
        }
        let snap = coord.shutdown();
        assert_eq!(snap.applies_f32, 12, "f32 applies uncounted");
        assert_eq!(snap.applies_f64, 0);
        assert_eq!(snap.f32_apply_frac(), 1.0);
    }

    #[test]
    fn default_precision_stays_f64_and_counts_as_such() {
        let (op, a) = dense_op(6, 6, 167);
        let coord = Coordinator::start(
            vec![("m".to_string(), op as Arc<dyn BatchOp>)],
            CoordinatorConfig::default(),
        );
        let client = coord.client();
        let x = vec![1.0, -2.0, 3.0, 0.5, -0.25, 4.0];
        let y = client.apply("m", x.clone()).unwrap();
        let want = a.matvec(&x);
        // The default policy runs the pre-tier f64 path.
        for i in 0..6 {
            assert!((y[i] - want[i]).abs() < 1e-12);
        }
        let snap = coord.shutdown();
        assert_eq!(snap.applies_f64, 1);
        assert_eq!(snap.applies_f32, 0);
        assert_eq!(snap.f32_apply_frac(), 0.0);
    }

    #[test]
    fn precision_flag_round_trips_and_rejects_garbage() {
        for (s, want) in [
            ("f64", Precision::F64),
            ("f32", Precision::F32),
            ("auto", Precision::Auto(1e-6)),
            ("auto:5e-4", Precision::Auto(5e-4)),
        ] {
            assert_eq!(s.parse::<Precision>().unwrap(), want);
        }
        assert_eq!(Precision::F64.to_string(), "f64");
        assert_eq!(Precision::Auto(1e-6).to_string(), "auto:1e-6");
        assert!("single".parse::<Precision>().is_err());
        assert!("auto:-1".parse::<Precision>().is_err());
        assert!("auto:nan".parse::<Precision>().is_err());
        assert!("auto:".parse::<Precision>().is_err());
    }

    #[test]
    fn reshape_reregistration_never_panics_workers() {
        // retire + register under the same name may legally change the
        // shape (unlike swap_epoch); stale queued requests must resolve
        // with a clean error, never a worker panic or a hang.
        struct Slow(usize, usize);
        impl BatchOp for Slow {
            fn rows(&self) -> usize {
                self.0
            }
            fn cols(&self) -> usize {
                self.1
            }
            fn apply_batch(&self, x: &Mat) -> Mat {
                std::thread::sleep(Duration::from_millis(10));
                Mat::zeros(self.0, x.cols())
            }
            fn flops_per_matvec(&self) -> usize {
                1
            }
        }
        let cfg = CoordinatorConfig {
            max_batch: 1,
            n_workers: 1,
            ..CoordinatorConfig::default()
        };
        let coord = Coordinator::start(
            vec![("s".to_string(), Arc::new(Slow(4, 4)) as Arc<dyn BatchOp>)],
            cfg,
        );
        let client = coord.client();
        // Queue several 4-dim requests; the slow worker keeps a backlog.
        let pending: Vec<_> = (0..6)
            .filter_map(|_| client.submit("s", vec![0.0; 4]).ok())
            .collect();
        let registry = coord.registry();
        registry.retire("s").unwrap();
        registry
            .register("s", Arc::new(Slow(2, 2)) as Arc<dyn BatchOp>)
            .unwrap();
        for rx in pending {
            match rx.recv() {
                // Flushed against the old generation before the retire.
                Ok(Ok(y)) => assert_eq!(y.len(), 4),
                // Resolved against the gap or the reshaped successor.
                Ok(Err(e)) => assert!(matches!(
                    e,
                    ServeError::WrongDimension { .. } | ServeError::UnknownOperator(_)
                )),
                Err(_) => panic!("worker died (response channel closed)"),
            }
        }
        // The service still works for the new shape.
        let y = client.apply("s", vec![0.0; 2]).unwrap();
        assert_eq!(y.len(), 2);
        coord.shutdown();
    }

    #[test]
    fn config_defaults_to_the_single_pool_seed_path() {
        let cfg = CoordinatorConfig::default();
        assert_eq!(cfg.n_shards, 1);
        let (op, _) = dense_op(4, 4, 171);
        let coord = Coordinator::start(vec![("m".to_string(), op as Arc<dyn BatchOp>)], cfg);
        assert_eq!(coord.n_shards(), 1);
        // Wedging the only shard would deadlock, so the hook refuses.
        assert!(!coord.debug_mark_shard_busy(0, true));
        let y = coord.client().apply("m", vec![1.0; 4]).unwrap();
        assert_eq!(y.len(), 4);
        coord.shutdown();
    }

    #[test]
    fn sharded_coordinator_pins_operators_and_serves() {
        let n = 16;
        let engine = crate::engine::ApplyEngine::with_threads(2);
        let h = crate::transforms::hadamard(n);
        let ops = engine_ops(
            &engine,
            (0..4)
                .map(|i| (format!("op{i}"), crate::transforms::hadamard_faust(n)))
                .collect(),
            4,
        );
        let cfg = CoordinatorConfig { n_shards: 2, ..CoordinatorConfig::default() };
        let coord = Coordinator::start(ops, cfg);
        assert_eq!(coord.n_shards(), 2);
        let registry = coord.registry();
        assert_eq!(registry.n_shards(), 2);
        // Equal-cost ops spread across both shards, deterministically.
        let shards: Vec<usize> = (0..4)
            .map(|i| registry.shard_of(&format!("op{i}")).unwrap())
            .collect();
        assert!(shards.iter().any(|&s| s == 0) && shards.iter().any(|&s| s == 1));
        let client = coord.client();
        let mut rng = Rng::new(61);
        for i in 0..8 {
            let x = rng.gauss_vec(n);
            let y = client.apply(&format!("op{}", i % 4), x.clone()).unwrap();
            let want = h.matvec(&x);
            for k in 0..n {
                assert!((y[k] - want[k]).abs() < 1e-10);
            }
        }
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 8);
    }

    #[test]
    fn shard_invariance_results_bitwise_match_single_pool() {
        // The tentpole contract: random operator fleets served across
        // shard counts {1, 2, 4} produce responses bitwise identical to
        // the single-pool seed path. Requests are applied one at a time,
        // so batch composition is fixed and any difference would come
        // from sharding itself.
        use crate::testutil::{check, ensure, PropConfig};
        check(
            "shard_invariance",
            &PropConfig { cases: 6, base_seed: 0x5A4D0001 },
            |rng| {
                let sizes = [8usize, 16, 32];
                let n_ops = 1 + rng.below(3);
                let specs: Vec<(String, usize)> = (0..n_ops)
                    .map(|i| (format!("op{i}"), sizes[rng.below(sizes.len())]))
                    .collect();
                let reqs: Vec<(usize, Vec<f64>)> = (0..10)
                    .map(|_| {
                        let k = rng.below(n_ops);
                        let x = rng.gauss_vec(specs[k].1);
                        (k, x)
                    })
                    .collect();
                let run = |n_shards: usize| -> Vec<Vec<f64>> {
                    let engine = crate::engine::ApplyEngine::with_threads(2);
                    let ops: Vec<(String, Arc<dyn BatchOp>)> = specs
                        .iter()
                        .map(|(name, sz)| {
                            let f = crate::transforms::hadamard_faust(*sz);
                            (name.clone(), Arc::new(engine.op(&f)) as Arc<dyn BatchOp>)
                        })
                        .collect();
                    let cfg =
                        CoordinatorConfig { n_shards, ..CoordinatorConfig::default() };
                    let coord = Coordinator::start(ops, cfg);
                    let client = coord.client();
                    let out = reqs
                        .iter()
                        .map(|(k, x)| client.apply(&specs[*k].0, x.clone()).unwrap())
                        .collect();
                    coord.shutdown();
                    out
                };
                let want = run(1);
                for n_shards in [2usize, 4] {
                    let got = run(n_shards);
                    for (w, g) in want.iter().zip(&got) {
                        ensure(w.len() == g.len(), "response length changed")?;
                        for (a, b) in w.iter().zip(g) {
                            ensure(
                                a.to_bits() == b.to_bits(),
                                format!("{n_shards}-shard result differs bitwise"),
                            )?;
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn donation_rescues_a_wedged_shard_bitwise() {
        // Wedge the shard that owns an operator: its flush jobs must be
        // stolen and completed by the sibling shard's workers, with
        // responses bitwise identical to an unsharded apply.
        let n = 32;
        let engine = crate::engine::ApplyEngine::with_threads(2);
        let f = crate::transforms::hadamard_faust(n);
        let reference = engine.op(&f);
        let ops = engine_ops(
            &engine,
            vec![
                ("a".to_string(), f.clone()),
                ("b".to_string(), crate::transforms::hadamard_faust(n)),
            ],
            4,
        );
        let cfg = CoordinatorConfig { n_shards: 2, ..CoordinatorConfig::default() };
        let coord = Coordinator::start(ops, cfg);
        let owner = coord.registry().shard_of("a").unwrap();
        assert!(coord.debug_mark_shard_busy(owner, true));
        let client = coord.client();
        let mut rng = Rng::new(0xD0A7);
        for _ in 0..6 {
            let x = rng.gauss_vec(n);
            let y = client.apply("a", x.clone()).expect("donation never lost a request");
            let want = reference.apply_batch(&Mat::from_vec(n, 1, x));
            for i in 0..n {
                assert_eq!(
                    y[i].to_bits(),
                    want.at(i, 0).to_bits(),
                    "donated job changed bits"
                );
            }
        }
        assert!(coord.debug_mark_shard_busy(owner, false));
        let snap = coord.shutdown();
        assert!(
            snap.jobs_donated >= 6,
            "wedged shard's jobs were not donated (donated={})",
            snap.jobs_donated
        );
        assert_eq!(snap.completed, 6);
    }

    #[test]
    fn backpressure_queue_full() {
        // Tiny queue + a blocking operator to keep it busy.
        struct Slow;
        impl BatchOp for Slow {
            fn rows(&self) -> usize {
                1
            }
            fn cols(&self) -> usize {
                1
            }
            fn apply_batch(&self, x: &Mat) -> Mat {
                std::thread::sleep(Duration::from_millis(30));
                x.clone()
            }
            fn flops_per_matvec(&self) -> usize {
                1
            }
        }
        let mut cfg = CoordinatorConfig::default();
        cfg.queue_capacity = 1;
        cfg.max_batch = 1;
        cfg.n_workers = 1;
        let coord = Coordinator::start(
            vec![("s".to_string(), Arc::new(Slow) as Arc<dyn BatchOp>)],
            cfg,
        );
        let client = coord.client();
        // Flood; at least one submission must be rejected with QueueFull.
        let mut rejected = 0;
        let mut pending = vec![];
        for _ in 0..50 {
            match client.submit("s", vec![1.0]) {
                Ok(rx) => pending.push(rx),
                Err(ServeError::QueueFull) => rejected += 1,
                Err(e) => panic!("unexpected: {e}"),
            }
        }
        assert!(rejected > 0, "backpressure never engaged");
        for rx in pending {
            let _ = rx.recv();
        }
        coord.shutdown();
    }
}

/// Exhaustive interleaving checks for the shard `JobQueue` donation
/// protocol (`cargo test --features loom-model --release loom_`; see
/// `engine::sync`). The models drive the *production* generic queue with
/// integer payloads, so any double-pop, lost job, or lost shutdown
/// wakeup reachable in the real donation path is reachable here.
#[cfg(all(test, feature = "loom-model"))]
mod loom_tests {
    use super::JobQueue;
    use loom::sync::Arc;
    use loom::thread;
    use std::time::Duration;

    /// A home worker (`pop_timeout`) racing a donating sibling
    /// (`try_pop`) over a closed queue: every job is served exactly once
    /// — never lost, never double-popped — under every interleaving.
    #[test]
    fn loom_donation_never_loses_or_double_pops_a_job() {
        loom::model(|| {
            let q: Arc<JobQueue<u32>> = Arc::new(JobQueue::new());
            q.push(1);
            q.push(2);
            q.close();
            let home = {
                let q = q.clone();
                thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(j) = q.pop_timeout(Duration::from_millis(1)) {
                        got.push(j);
                    }
                    got
                })
            };
            let thief = {
                let q = q.clone();
                thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(j) = q.try_pop() {
                        got.push(j);
                    }
                    got
                })
            };
            let mut all = home.join().unwrap();
            all.extend(thief.join().unwrap());
            all.sort_unstable();
            assert_eq!(all, vec![1, 2], "donation lost or double-served a job");
            assert!(q.is_done(), "drained + closed queue must report done");
        });
    }

    /// Push/close racing a blocked `pop_timeout`: the worker always
    /// returns (loom flags a hang as a deadlock), and the pushed job is
    /// never stranded — it reaches either the waiting worker or the
    /// post-close drain.
    #[test]
    fn loom_close_wakes_waiter_without_stranding_jobs() {
        loom::model(|| {
            let q: Arc<JobQueue<u32>> = Arc::new(JobQueue::new());
            let worker = {
                let q = q.clone();
                thread::spawn(move || q.pop_timeout(Duration::from_millis(1)))
            };
            q.push(7);
            q.close();
            match worker.join().unwrap() {
                Some(j) => assert_eq!(j, 7),
                // Timed out before the push landed: the job must still be
                // drainable by the shutdown path.
                None => assert_eq!(q.try_pop(), Some(7)),
            }
            assert!(q.is_done());
        });
    }
}
