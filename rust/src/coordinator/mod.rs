//! Operator-serving coordinator: the L3 runtime that turns a FAμST into a
//! *service*.
//!
//! The paper's motivating workload (§V) is an iterative solver issuing many
//! matvec requests against a fixed operator. This module provides the
//! deployment shape for that: an operator **registry**, a **router** thread
//! that groups incoming requests per operator into dynamic **batches**
//! (size- or deadline-triggered), and a **worker pool** executing batches
//! as a single `spmm` — which is both cache-friendlier and, for the PJRT
//! backend, amortizes executable dispatch. Bounded queues give
//! backpressure; metrics are lock-free atomics.
//!
//! Operators are best registered as [`EngineOp`]s (see [`engine_ops`]):
//! the batch a worker executes then runs through the engine's cost-modeled
//! plan, row-parallel pooled spmm, and zero-alloc arena. A deployment
//! needs exactly one engine: `ApplyEngine::ctx()` hands the same pool to
//! the factorization stack, so on-line refactorization (building or
//! refreshing an operator while the service runs) shares the serving
//! threads instead of oversubscribing the machine.
//!
//! tokio is not available offline; a compute-bound matvec service needs
//! threads, not async IO, so the pool is `std::thread` + channels.

mod batcher;
mod metrics;

pub use batcher::{BatchPolicy, Batcher};
pub use metrics::{Metrics, MetricsSnapshot};

use crate::engine::{ApplyEngine, EngineOp};
use crate::faust::Faust;
use crate::linalg::Mat;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A batched linear operator servable by the coordinator.
pub trait BatchOp: Send + Sync {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    /// Apply to a column-batch `X ∈ R^{cols×b}` → `Y ∈ R^{rows×b}`.
    fn apply_batch(&self, x: &Mat) -> Mat;
    /// Flops per single matvec (for metrics / RCG reporting).
    fn flops_per_matvec(&self) -> usize;
}

impl BatchOp for Mat {
    fn rows(&self) -> usize {
        Mat::rows(self)
    }
    fn cols(&self) -> usize {
        Mat::cols(self)
    }
    fn apply_batch(&self, x: &Mat) -> Mat {
        self.matmul(x)
    }
    fn flops_per_matvec(&self) -> usize {
        2 * Mat::rows(self) * Mat::cols(self)
    }
}

impl BatchOp for Faust {
    fn rows(&self) -> usize {
        Faust::rows(self)
    }
    fn cols(&self) -> usize {
        Faust::cols(self)
    }
    /// Routed through the cached engine plan (see [`crate::engine`]).
    fn apply_batch(&self, x: &Mat) -> Mat {
        self.apply_mat(x)
    }
    fn flops_per_matvec(&self) -> usize {
        self.flops_per_matvec()
    }
}

impl BatchOp for EngineOp {
    fn rows(&self) -> usize {
        EngineOp::rows(self)
    }
    fn cols(&self) -> usize {
        EngineOp::cols(self)
    }
    /// Planned, pool-parallel, arena-backed batch apply.
    fn apply_batch(&self, x: &Mat) -> Mat {
        EngineOp::apply_batch(self, x)
    }
    fn flops_per_matvec(&self) -> usize {
        EngineOp::flops_per_matvec(self)
    }
}

/// Plan each FAμST on `engine` and box the resulting [`EngineOp`]s for
/// registration — the standard way to stand up an engine-backed service.
/// Arenas are pre-warmed for `batch_hint`-column batches.
pub fn engine_ops(
    engine: &ApplyEngine,
    ops: Vec<(String, Faust)>,
    batch_hint: usize,
) -> Vec<(String, Arc<dyn BatchOp>)> {
    ops.into_iter()
        .map(|(name, f)| {
            (
                name,
                Arc::new(engine.op_batch_hint(&f, batch_hint)) as Arc<dyn BatchOp>,
            )
        })
        .collect()
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Maximum vectors per batch.
    pub max_batch: usize,
    /// Deadline before a partial batch is flushed.
    pub batch_timeout: Duration,
    /// Worker threads.
    pub n_workers: usize,
    /// Bounded request-queue capacity (backpressure).
    pub queue_capacity: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_batch: 32,
            batch_timeout: Duration::from_micros(200),
            n_workers: 2,
            queue_capacity: 1024,
        }
    }
}

/// One in-flight request.
struct Request {
    op: String,
    x: Vec<f64>,
    enqueued: Instant,
    resp: SyncSender<Result<Vec<f64>, ServeError>>,
}

/// A batch ready for execution.
struct Job {
    op: Arc<dyn BatchOp>,
    reqs: Vec<Request>,
}

/// Serving errors.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    UnknownOperator(String),
    WrongDimension { expected: usize, got: usize },
    QueueFull,
    ShuttingDown,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::UnknownOperator(n) => write!(f, "unknown operator '{n}'"),
            ServeError::WrongDimension { expected, got } => {
                write!(f, "wrong input dimension: expected {expected}, got {got}")
            }
            ServeError::QueueFull => write!(f, "request queue full"),
            ServeError::ShuttingDown => write!(f, "coordinator shutting down"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Shared worker queue (Mutex + Condvar; mpsc receivers are not cloneable).
struct JobQueue {
    q: Mutex<Vec<Job>>,
    cv: Condvar,
    closed: AtomicBool,
}

impl JobQueue {
    fn new() -> Self {
        JobQueue { q: Mutex::new(Vec::new()), cv: Condvar::new(), closed: AtomicBool::new(false) }
    }

    fn push(&self, job: Job) {
        self.q.lock().unwrap().push(job);
        self.cv.notify_one();
    }

    fn pop(&self) -> Option<Job> {
        let mut g = self.q.lock().unwrap();
        loop {
            if let Some(j) = g.pop() {
                return Some(j);
            }
            if self.closed.load(Ordering::Acquire) {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    fn close(&self) {
        self.closed.store(true, Ordering::Release);
        self.cv.notify_all();
    }
}

/// Handle for submitting requests; cloneable and thread-safe.
#[derive(Clone)]
pub struct Client {
    tx: SyncSender<Request>,
    registry: Arc<HashMap<String, Arc<dyn BatchOp>>>,
    metrics: Arc<Metrics>,
}

impl Client {
    /// Blocking single matvec through the service.
    pub fn apply(&self, op: &str, x: Vec<f64>) -> Result<Vec<f64>, ServeError> {
        let rx = self.submit(op, x)?;
        rx.recv().map_err(|_| ServeError::ShuttingDown)?
    }

    /// Submit without blocking on the result; returns the response channel.
    pub fn submit(
        &self,
        op: &str,
        x: Vec<f64>,
    ) -> Result<Receiver<Result<Vec<f64>, ServeError>>, ServeError> {
        let handle = self
            .registry
            .get(op)
            .ok_or_else(|| ServeError::UnknownOperator(op.to_string()))?;
        if x.len() != handle.cols() {
            return Err(ServeError::WrongDimension { expected: handle.cols(), got: x.len() });
        }
        let (rtx, rrx) = sync_channel(1);
        let req = Request { op: op.to_string(), x, enqueued: Instant::now(), resp: rtx };
        match self.tx.try_send(req) {
            Ok(()) => {
                self.metrics.record_submitted();
                Ok(rrx)
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.record_rejected();
                Err(ServeError::QueueFull)
            }
            Err(TrySendError::Disconnected(_)) => Err(ServeError::ShuttingDown),
        }
    }

    /// Snapshot of serving metrics.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }
}

/// The running coordinator: router + workers.
pub struct Coordinator {
    client: Client,
    router: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    jobs: Arc<JobQueue>,
    stop: Arc<AtomicBool>,
}

impl Coordinator {
    /// Start serving the given named operators.
    pub fn start(ops: Vec<(String, Arc<dyn BatchOp>)>, cfg: CoordinatorConfig) -> Self {
        let registry: Arc<HashMap<String, Arc<dyn BatchOp>>> =
            Arc::new(ops.into_iter().collect());
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<Request>(cfg.queue_capacity);
        let jobs = Arc::new(JobQueue::new());
        let stop = Arc::new(AtomicBool::new(false));

        // Router thread: drain the request channel, batch per op.
        let r_registry = registry.clone();
        let r_jobs = jobs.clone();
        let r_metrics = metrics.clone();
        let r_stop = stop.clone();
        let policy = BatchPolicy { max_batch: cfg.max_batch, timeout: cfg.batch_timeout };
        let router = std::thread::Builder::new()
            .name("faust-router".into())
            .spawn(move || router_loop(rx, r_registry, r_jobs, r_metrics, policy, r_stop))
            .expect("spawn router");

        // Worker pool.
        let mut workers = Vec::with_capacity(cfg.n_workers);
        for w in 0..cfg.n_workers.max(1) {
            let w_jobs = jobs.clone();
            let w_metrics = metrics.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("faust-worker-{w}"))
                    .spawn(move || worker_loop(w_jobs, w_metrics))
                    .expect("spawn worker"),
            );
        }

        let client = Client { tx, registry, metrics };
        Coordinator { client, router: Some(router), workers, jobs, stop }
    }

    /// Get a submission handle.
    pub fn client(&self) -> Client {
        self.client.clone()
    }

    /// Graceful shutdown: stop accepting, drain in-flight work, join.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.stop.store(true, Ordering::Release);
        if let Some(r) = self.router.take() {
            let _ = r.join();
        }
        self.jobs.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.client.metrics()
    }
}

fn router_loop(
    rx: Receiver<Request>,
    registry: Arc<HashMap<String, Arc<dyn BatchOp>>>,
    jobs: Arc<JobQueue>,
    metrics: Arc<Metrics>,
    policy: BatchPolicy,
    stop: Arc<AtomicBool>,
) {
    let mut batcher: Batcher<Request> = Batcher::new(policy.clone());
    loop {
        let timeout = batcher
            .next_deadline_in()
            .unwrap_or(Duration::from_millis(5));
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                let key = req.op.clone();
                if let Some((op_name, reqs)) = batcher.add(key, req) {
                    flush(&registry, &jobs, &metrics, op_name, reqs);
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
        }
        for (op_name, reqs) in batcher.take_expired() {
            flush(&registry, &jobs, &metrics, op_name, reqs);
        }
        if stop.load(Ordering::Acquire) {
            // Drain anything still in the channel, then stop.
            while let Ok(req) = rx.try_recv() {
                let key = req.op.clone();
                if let Some((op_name, reqs)) = batcher.add(key, req) {
                    flush(&registry, &jobs, &metrics, op_name, reqs);
                }
            }
            break;
        }
    }
    // Drain remaining partial batches on shutdown.
    for (op_name, reqs) in batcher.drain() {
        flush(&registry, &jobs, &metrics, op_name, reqs);
    }
}

fn flush(
    registry: &Arc<HashMap<String, Arc<dyn BatchOp>>>,
    jobs: &Arc<JobQueue>,
    metrics: &Arc<Metrics>,
    op_name: String,
    reqs: Vec<Request>,
) {
    match registry.get(&op_name) {
        Some(op) => {
            metrics.record_batch(reqs.len());
            jobs.push(Job { op: op.clone(), reqs });
        }
        None => {
            for r in reqs {
                let _ = r
                    .resp
                    .send(Err(ServeError::UnknownOperator(op_name.clone())));
            }
        }
    }
}

fn worker_loop(jobs: Arc<JobQueue>, metrics: Arc<Metrics>) {
    while let Some(job) = jobs.pop() {
        let b = job.reqs.len();
        let n = job.op.cols();
        // Assemble the column batch.
        let mut x = Mat::zeros(n, b);
        for (c, r) in job.reqs.iter().enumerate() {
            for i in 0..n {
                x.set(i, c, r.x[i]);
            }
        }
        let t0 = Instant::now();
        let y = job.op.apply_batch(&x);
        let exec_ns = t0.elapsed().as_nanos() as u64;
        metrics.record_exec(b, exec_ns, job.op.flops_per_matvec() as u64 * b as u64);
        for (c, r) in job.reqs.into_iter().enumerate() {
            let latency = r.enqueued.elapsed().as_nanos() as u64;
            metrics.record_completed(latency);
            let _ = r.resp.send(Ok(y.col(c)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn dense_op(m: usize, n: usize, seed: u64) -> (Arc<Mat>, Mat) {
        let mut rng = Rng::new(seed);
        let a = Mat::randn(m, n, &mut rng);
        (Arc::new(a.clone()), a)
    }

    #[test]
    fn serves_correct_results() {
        let (op, a) = dense_op(6, 9, 161);
        let coord = Coordinator::start(
            vec![("m".to_string(), op as Arc<dyn BatchOp>)],
            CoordinatorConfig::default(),
        );
        let client = coord.client();
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let x = rng.gauss_vec(9);
            let y = client.apply("m", x.clone()).unwrap();
            let want = a.matvec(&x);
            for i in 0..6 {
                assert!((y[i] - want[i]).abs() < 1e-12);
            }
        }
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 20);
    }

    #[test]
    fn unknown_operator_and_bad_dims_rejected() {
        let (op, _) = dense_op(4, 4, 162);
        let coord = Coordinator::start(
            vec![("a".to_string(), op as Arc<dyn BatchOp>)],
            CoordinatorConfig::default(),
        );
        let client = coord.client();
        assert!(matches!(
            client.apply("nope", vec![0.0; 4]),
            Err(ServeError::UnknownOperator(_))
        ));
        assert!(matches!(
            client.apply("a", vec![0.0; 3]),
            Err(ServeError::WrongDimension { expected: 4, got: 3 })
        ));
        coord.shutdown();
    }

    #[test]
    fn concurrent_clients_batch_and_complete() {
        let (op, a) = dense_op(8, 8, 163);
        let mut cfg = CoordinatorConfig::default();
        cfg.max_batch = 16;
        cfg.batch_timeout = Duration::from_millis(2);
        let coord = Coordinator::start(vec![("m".to_string(), op as Arc<dyn BatchOp>)], cfg);
        let client = coord.client();
        let nthreads = 4;
        let per = 25;
        let mut handles = vec![];
        for t in 0..nthreads {
            let c = client.clone();
            let a = a.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(200 + t as u64);
                for _ in 0..per {
                    let x = rng.gauss_vec(8);
                    let y = c.apply("m", x.clone()).unwrap();
                    let want = a.matvec(&x);
                    for i in 0..8 {
                        assert!((y[i] - want[i]).abs() < 1e-12);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = coord.shutdown();
        assert_eq!(snap.completed, (nthreads * per) as u64);
        // With concurrency + a 2ms window we expect at least one batch > 1.
        assert!(snap.max_batch_size >= 1);
    }

    #[test]
    fn faust_and_dense_agree_through_service() {
        let h = crate::transforms::hadamard(32);
        let hf = crate::transforms::hadamard_faust(32);
        let coord = Coordinator::start(
            vec![
                ("dense".to_string(), Arc::new(h.clone()) as Arc<dyn BatchOp>),
                ("faust".to_string(), Arc::new(hf) as Arc<dyn BatchOp>),
            ],
            CoordinatorConfig::default(),
        );
        let client = coord.client();
        let mut rng = Rng::new(3);
        let x = rng.gauss_vec(32);
        let yd = client.apply("dense", x.clone()).unwrap();
        let yf = client.apply("faust", x).unwrap();
        for i in 0..32 {
            assert!((yd[i] - yf[i]).abs() < 1e-10);
        }
        coord.shutdown();
    }

    #[test]
    fn engine_backed_ops_serve_correctly() {
        let n = 32;
        let h = crate::transforms::hadamard(n);
        let hf = crate::transforms::hadamard_faust(n);
        let engine = crate::engine::ApplyEngine::with_threads(2);
        let ops = engine_ops(&engine, vec![("f".to_string(), hf)], 8);
        let coord = Coordinator::start(ops, CoordinatorConfig::default());
        let client = coord.client();
        let mut rng = Rng::new(5);
        for _ in 0..10 {
            let x = rng.gauss_vec(n);
            let y = client.apply("f", x.clone()).unwrap();
            let want = h.matvec(&x);
            for i in 0..n {
                assert!((y[i] - want[i]).abs() < 1e-10);
            }
        }
        coord.shutdown();
        let m = engine.metrics();
        assert!(m.applies >= 1, "engine never executed a batch");
        assert_eq!(m.plans_compiled, 1);
    }

    #[test]
    fn serving_and_refactorization_share_one_engine() {
        // The deployment story: one engine serves planned applies while
        // the same engine's ctx factorizes the next operator on-line.
        use crate::hierarchical::{factorize_with_ctx, HierarchicalConfig};
        let n = 16;
        let h = crate::transforms::hadamard(n);
        let engine = crate::engine::ApplyEngine::with_threads(2);
        let ops = engine_ops(
            &engine,
            vec![("served".to_string(), crate::transforms::hadamard_faust(n))],
            8,
        );
        let coord = Coordinator::start(ops, CoordinatorConfig::default());
        let client = coord.client();
        // On-line refactorization on the serving engine's own pool.
        let ctx = engine.ctx();
        assert!(std::sync::Arc::ptr_eq(ctx.pool(), engine.pool()));
        let fst = factorize_with_ctx(&ctx, &h, &HierarchicalConfig::hadamard(n));
        assert!(fst.relative_error_fro(&h) < 1e-6);
        // The service stayed correct throughout.
        let mut rng = Rng::new(9);
        let x = rng.gauss_vec(n);
        let y = client.apply("served", x.clone()).unwrap();
        let want = h.matvec(&x);
        for i in 0..n {
            assert!((y[i] - want[i]).abs() < 1e-10);
        }
        coord.shutdown();
    }

    #[test]
    fn backpressure_queue_full() {
        // Tiny queue + a blocking operator to keep it busy.
        struct Slow;
        impl BatchOp for Slow {
            fn rows(&self) -> usize {
                1
            }
            fn cols(&self) -> usize {
                1
            }
            fn apply_batch(&self, x: &Mat) -> Mat {
                std::thread::sleep(Duration::from_millis(30));
                x.clone()
            }
            fn flops_per_matvec(&self) -> usize {
                1
            }
        }
        let mut cfg = CoordinatorConfig::default();
        cfg.queue_capacity = 1;
        cfg.max_batch = 1;
        cfg.n_workers = 1;
        let coord = Coordinator::start(
            vec![("s".to_string(), Arc::new(Slow) as Arc<dyn BatchOp>)],
            cfg,
        );
        let client = coord.client();
        // Flood; at least one submission must be rejected with QueueFull.
        let mut rejected = 0;
        let mut pending = vec![];
        for _ in 0..50 {
            match client.submit("s", vec![1.0]) {
                Ok(rx) => pending.push(rx),
                Err(ServeError::QueueFull) => rejected += 1,
                Err(e) => panic!("unexpected: {e}"),
            }
        }
        assert!(rejected > 0, "backpressure never engaged");
        for rx in pending {
            let _ = rx.recv();
        }
        coord.shutdown();
    }
}
